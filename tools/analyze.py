#!/usr/bin/env python
"""Convenience shim: ``python tools/analyze.py`` == ``python -m repro.analyze``.

Adds ``src/`` to ``sys.path`` so the analyzer runs from a bare checkout
without an editable install; every CLI flag passes through unchanged.
"""
from __future__ import annotations

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.analyze.__main__ import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
