#!/usr/bin/env python
"""CI lint: forbid new in-repo calls to the deprecated aggregation wrappers.

The four legacy entry points (``aggregate_stacked``, ``exact_aggregate``,
``psum_aggregate``, ``psum_aggregate_stacked``) survive only as
DeprecationWarning shims in ``core/ota.py`` — every in-repo aggregation call
must go through ``ota.aggregate`` / ``ota.aggregate_apply``.  This script
greps ``src/``, ``benchmarks/`` and ``examples/`` for call syntax on the
legacy names and fails if any appear outside ``core/ota.py`` itself.

``tests/`` is deliberately NOT linted: the test suite keeps legacy-name
coverage so the deprecated wrappers stay correct until they are removed.

Usage: python tools/lint_aggregation_api.py  (exit 0 clean, 1 violations)
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

DEPRECATED = (
    "aggregate_stacked",
    "exact_aggregate",
    "psum_aggregate",
    "psum_aggregate_stacked",
)

LINT_DIRS = ("src", "benchmarks", "examples")
ALLOWED = {REPO / "src" / "repro" / "core" / "ota.py"}

# a call or an import of the bare name (doc mentions in strings/comments are
# filtered by stripping comment tails and skipping pure-prose lines)
CALL_RE = re.compile(
    r"(?<![\w.])(" + "|".join(DEPRECATED) + r")\s*\("
)
IMPORT_RE = re.compile(
    r"^\s*from\s+repro\.core\.ota\s+import\s+.*\b("
    + "|".join(DEPRECATED) + r")\b"
)


def lint_file(path: pathlib.Path) -> list[tuple[int, str]]:
    hits = []
    for lineno, line in enumerate(
            path.read_text(encoding="utf-8").splitlines(), 1):
        code = line.split("#", 1)[0]
        if CALL_RE.search(code) or IMPORT_RE.search(code):
            hits.append((lineno, line.strip()))
    return hits


def main() -> int:
    violations = []
    for d in LINT_DIRS:
        for path in sorted((REPO / d).rglob("*.py")):
            if path in ALLOWED:
                continue
            for lineno, line in lint_file(path):
                violations.append((path.relative_to(REPO), lineno, line))
    if violations:
        print("deprecated aggregation API calls found "
              "(use ota.aggregate / ota.aggregate_apply):")
        for path, lineno, line in violations:
            print(f"  {path}:{lineno}: {line}")
        return 1
    print("aggregation API lint clean "
          f"({', '.join(d + '/' for d in LINT_DIRS)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
