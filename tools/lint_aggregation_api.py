#!/usr/bin/env python
"""DEPRECATED shim: the aggregation-API lint now lives in ``repro.analyze``.

The original grep-based checker was absorbed into the AST rule
``deprecated-aggregation`` (``repro.analyze.rules.deprecated_api``), which
this script simply runs — same scan roots (``src/``, ``benchmarks/``,
``examples/``; ``tests/`` deliberately unlinted), same exit-code contract
(0 clean, 1 violations) — so existing CI invocations and habits keep
working.  Prefer the full analyzer::

    python -m repro.analyze --strict                          # everything
    python -m repro.analyze --ast-only --rules deprecated-aggregation
"""
from __future__ import annotations

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.analyze import get_rules, repo_root, scan  # noqa: E402


def main() -> int:
    report = scan(repo_root(), rules=get_rules(["deprecated-aggregation"]))
    if report.findings:
        print("deprecated aggregation API calls found "
              "(use ota.aggregate / ota.aggregate_apply):")
        print(report.render_text())
    else:
        print("aggregation API lint clean (src/, benchmarks/, examples/)")
    return report.exit_code(strict=True)


if __name__ == "__main__":
    sys.exit(main())
