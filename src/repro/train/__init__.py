"""Distributed training/serving steps with OTA aggregation as a first-class
gradient-aggregation mode."""
from repro.train import server, trainer  # noqa: F401
