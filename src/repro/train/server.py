"""Serving: batched decode steps over sharded KV caches.

``decode_32k`` / ``long_500k`` lower ``serve_step`` — ONE new token against a
``seq_len`` KV cache.  Cache capacity honours the architecture's serving
window (DESIGN.md §4): SWA archs and the beyond-paper SWA serving variant use
a ring buffer of ``window`` slots (sub-quadratic memory); SSM/hybrid archs
carry O(1) recurrent state.

``cache_specs`` builds the PartitionSpec tree for the cache by mirroring
``transformer.init_cache``'s structure: batch over ('pod','data') when
divisible, KV heads over 'model' when divisible, with a sequence-sharded
fallback for batch=1 long-context serving (flash-decode style).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer
from repro.models.model import Model, serve_capacity
from repro.models.ssm import SSMState
from repro.models.attention import KVCache

PyTree = Any


@dataclass(frozen=True)
class ServeConfig:
    temperature: float = 0.0      # 0 = greedy
    seed: int = 0


def make_serve_step(model: Model, shape: InputShape):
    """serve_step(params, cache, token) -> (next_token, logits, cache')."""
    cfg = model.cfg
    window = cfg.window or cfg.serve_window
    eff_window = window if (window and window < shape.seq_len) else None

    def serve_step(params, cache, token):
        logits, cache = transformer.decode(
            params, cfg, cache, token, window=eff_window
        )
        next_token = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        return next_token, logits, cache

    return serve_step


def init_cache_for_shape(model: Model, shape: InputShape) -> PyTree:
    cfg = model.cfg
    cap = serve_capacity(cfg, shape.seq_len)
    mem_len = transformer.cross_len(cfg, shape.seq_len)
    cache = model.init_cache(shape.global_batch, cap, mem_len)
    # decode_32k/long_500k semantics: the cache is already full up to seq_len-1
    return cache._replace(pos=jnp.asarray(shape.seq_len - 1, jnp.int32))


def abstract_cache_for_shape(model: Model, shape: InputShape) -> PyTree:
    return jax.eval_shape(lambda: init_cache_for_shape(model, shape))


# --------------------------------------------------------------------------
# Cache sharding
# --------------------------------------------------------------------------

def _axes_ok(mesh: Mesh, axes: Tuple[str, ...], dim: int) -> bool:
    n = 1
    for a in axes:
        if a not in mesh.shape:
            return False
        n *= mesh.shape[a]
    return dim % n == 0 and n > 1


def _batch_entry(mesh: Mesh, batch: int):
    for cand in (("pod", "data"), ("data",)):
        axes = tuple(a for a in cand if a in mesh.shape)
        if axes and _axes_ok(mesh, axes, batch):
            return axes if len(axes) > 1 else axes[0]
    return None


def cache_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> PyTree:
    """PartitionSpec tree matching init_cache's structure for (cfg, shape)."""
    batch = shape.global_batch
    cap = serve_capacity(cfg, shape.seq_len)
    b_entry = _batch_entry(mesh, batch)
    kvh = "model" if _axes_ok(mesh, ("model",), max(cfg.n_kv_heads, 1)) else None
    # The cache sequence dim picks up whatever axes remain unused: 'model'
    # when the (few) KV heads can't split 16 ways, 'data' when batch=1
    # (long-context serving) — flash-decode style sequence parallelism.
    seq_axes = []
    if b_entry is None:
        seq_axes.append("data")
    if kvh is None:
        seq_axes.append("model")
    seq_axes = tuple(a for a in seq_axes if a in mesh.shape)
    seq_entry = None
    if seq_axes and _axes_ok(mesh, seq_axes, cap):
        seq_entry = seq_axes if len(seq_axes) > 1 else seq_axes[0]

    def kv_spec(lead: int):
        # (lead..., B, cap, Hkv, Dh)
        lead_spec = (None,) * lead
        return KVCache(
            k=P(*lead_spec, b_entry, seq_entry, kvh, None),
            v=P(*lead_spec, b_entry, seq_entry, kvh, None),
        )

    def ssm_spec(lead: int):
        d_inner_ok = cfg.ssm and _axes_ok(
            mesh, ("model",), cfg.ssm.expand * cfg.d_model
        )
        din = "model" if d_inner_ok else None
        hg_total = (cfg.ssm.expand * cfg.d_model) // cfg.ssm.headdim
        hg = hg_total // cfg.ssm.n_groups
        heads_ok = _axes_ok(mesh, ("model",), hg)
        hco = "model" if heads_ok else None
        lead_spec = (None,) * lead
        return SSMState(
            ssm=P(*lead_spec, b_entry, None, hco, None, None),
            conv_x=P(*lead_spec, b_entry, None, din),
            conv_B=P(*lead_spec, b_entry, None, None),
            conv_C=P(*lead_spec, b_entry, None, None),
        )

    def cross_spec(lead: int):
        lead_spec = (None,) * lead
        s = P(*lead_spec, b_entry, None, kvh, None)
        return (s, s)

    pos = P()
    fam = cfg.family
    C = transformer.Cache
    if fam in ("dense", "moe"):
        return C(kv=kv_spec(1), pos=pos)
    if fam == "ssm":
        return C(ssm=ssm_spec(1), pos=pos)
    if fam == "hybrid":
        per = cfg.shared_attn_every
        n_groups, tail = divmod(cfg.n_layers, per)
        return C(
            groups_ssm=ssm_spec(2),
            groups_kv=kv_spec(1),
            tail_ssm=ssm_spec(1) if tail else None,
            pos=pos,
        )
    if fam == "vlm":
        return C(
            groups_kv=kv_spec(2),
            cross_self_kv=kv_spec(1),
            cross_kv=cross_spec(1),
            pos=pos,
        )
    if fam == "encdec":
        return C(kv=kv_spec(1), cross_kv=cross_spec(1), pos=pos)
    raise ValueError(fam)
