"""The production train step: fwd+bwd, OTA/exact aggregation, optimizer.

The paper's technique enters here through exactly one seam — the gradient
aggregation mode:

* ``aggregator="exact"``  — Algorithm 1 semantics: ideal uplink, the batch
  gradient is the plain mean (vanilla data-parallel psum).
* ``aggregator="ota"``    — Algorithm 2: per-agent channel gains are folded
  into the per-sequence loss weights *before* autodiff (so autodiff emits
  ``(1/N) sum_i h_i g_i`` with zero extra collectives), then the server AWGN
  ``n_k / N`` is added to the aggregated gradient and the update optionally
  debiased by ``m_h``.  Each data-parallel shard group is one "agent".

Microbatching (gradient accumulation) uses an agent-major layout
(n_micro, n_agents, per, ...): the batch dim every shard owns stays the
second axis, so every mesh shard stays busy in every microbatch.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ota
from repro.core.channel import make_channel, noise_sigma_from_db
from repro.models.layers import lm_loss
from repro.models.model import Model
from repro.models import transformer
from repro.utils import unroll as uscan
from repro.optim.optimizers import (
    Optimizer, adamw, apply_updates, clip_by_global_norm, warmup_cosine,
)
from repro.utils.tree import tree_global_norm, tree_scale, tree_add, tree_zeros_like

PyTree = Any


@dataclass(frozen=True)
class TrainConfig:
    # paper technique ------------------------------------------------------
    aggregator: str = "ota"            # "exact" (Alg. 1) | "ota" (Alg. 2)
    channel: str = "rayleigh"
    channel_kwargs: Tuple = ()
    noise_db: float = -60.0            # sigma^2 of the uplink AWGN, in dB
    debias: bool = True                # divide aggregated grad by m_h
    n_agents: int = 16                 # data-parallel replica groups
    # optimisation ---------------------------------------------------------
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    microbatch: int = 1                # gradient-accumulation steps
    grad_accum_dtype: str = ""         # "" = param dtype; "float32" for exact
    seed: int = 0
    # uplink implementation ------------------------------------------------
    ota_backend: str = "auto"          # "xla" | "pallas" | "auto"
    wire_dtype: str = ""               # pallas uplink payload ("bfloat16")

    def ota_config(self) -> Optional[ota.OTAConfig]:
        if self.aggregator == "exact":
            return None
        if self.aggregator != "ota":
            raise ValueError(f"unknown aggregator {self.aggregator!r}")
        ch = make_channel(self.channel, **dict(self.channel_kwargs))
        return ota.OTAConfig(
            channel=ch,
            noise_sigma=noise_sigma_from_db(self.noise_db),
            debias=self.debias,
            wire_dtype=self.wire_dtype,
        )


class TrainState(NamedTuple):
    params: PyTree
    opt_state: Any
    step: jax.Array


def make_optimizer(tcfg: TrainConfig) -> Optimizer:
    sched = warmup_cosine(tcfg.lr, tcfg.warmup, tcfg.total_steps)
    return adamw(sched, weight_decay=tcfg.weight_decay)


def init_state(model: Model, tcfg: TrainConfig, key: jax.Array) -> TrainState:
    params = model.init(key)
    opt = make_optimizer(tcfg)
    return TrainState(
        params=params, opt_state=opt.init(params), step=jnp.zeros((), jnp.int32)
    )


def _agent_major(batch: Dict[str, jax.Array], n_agents: int, n_micro: int):
    """(B, ...) -> (n_micro, n_agents, B/(N*mu), ...) without reordering the
    agent ownership of examples (agent i owns the i-th contiguous slice)."""

    def _r(x):
        b = x.shape[0]
        per = b // n_agents
        assert per % n_micro == 0, (b, n_agents, n_micro)
        y = x.reshape((n_agents, n_micro, per // n_micro) + x.shape[1:])
        return jnp.moveaxis(y, 1, 0)

    return jax.tree.map(_r, batch)


def make_loss_fn(model: Model):
    """loss(params, microbatch, weights) over (n_agents, per, ...) batches."""

    def loss_fn(params, mb, weights):
        na, per = mb["tokens"].shape[:2]

        def flat(x):
            return x.reshape((na * per,) + x.shape[2:])

        fb = {k: flat(v) for k, v in mb.items()}
        logits, aux = transformer.forward(
            params, model.cfg, fb["tokens"], fb.get("memory")
        )
        w = None
        if weights is not None:
            w = jnp.repeat(weights, per)
        return lm_loss(logits, fb["labels"], w) + aux

    return loss_fn


def make_train_step(model: Model, tcfg: TrainConfig):
    """Returns train_step(state, batch, key) -> (state', metrics)."""
    opt = make_optimizer(tcfg)
    ota_cfg = tcfg.ota_config()
    loss_fn = make_loss_fn(model)
    n = tcfg.n_agents

    def train_step(state: TrainState, batch: Dict[str, jax.Array], key: jax.Array):
        key = jax.random.fold_in(key, state.step)
        key_h, key_n = jax.random.split(key)

        if ota_cfg is None:
            gains = None
        else:
            gains = ota.sample_gains(ota_cfg, key_h, n)   # (N,)

        mbs = _agent_major(batch, n, tcfg.microbatch)
        grad_fn = jax.value_and_grad(loss_fn)
        acc_dtype = jnp.dtype(tcfg.grad_accum_dtype) if tcfg.grad_accum_dtype \
            else None

        def micro(acc, mb):
            loss_acc, g_acc = acc
            loss, g = grad_fn(state.params, mb, gains)
            if acc_dtype is not None:
                g = jax.tree.map(lambda x: x.astype(acc_dtype), g)
            return (loss_acc + loss, tree_add(g_acc, g)), None

        acc0 = tree_zeros_like(state.params)
        if acc_dtype is not None:
            acc0 = jax.tree.map(lambda x: x.astype(acc_dtype), acc0)
        (loss_sum, grads), _ = uscan.scan(
            micro, (jnp.zeros(()), acc0), mbs
        )
        inv = 1.0 / tcfg.microbatch
        loss = loss_sum * inv
        grads = tree_scale(grads, inv)

        # --- the paper's uplink: server AWGN + optional m_h debias --------
        if ota_cfg is not None:
            grads = ota.add_awgn(ota_cfg, key_n, grads, n,
                                 backend=tcfg.ota_backend)

        grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)

        gain_mean = jnp.mean(gains) if gains is not None else jnp.ones(())
        metrics = {
            # the lowered loss is channel-weighted; de-scale by the mean gain
            # so the reported value estimates the plain CE.
            "loss": loss / jnp.maximum(gain_mean, 1e-6),
            "grad_norm": gnorm,
            "gain_mean": gain_mean,
            "update_norm": tree_global_norm(updates),
        }
        return TrainState(params=params, opt_state=opt_state, step=state.step + 1), metrics

    return train_step


# ---------------------------------------------------------------------------
# Explicit shard_map OTA aggregation (Form 2) — optional drop-in used by the
# paper-faithful trainer variant; semantics equal to the weighted-loss form.
# ---------------------------------------------------------------------------

def make_psum_train_step(model: Model, tcfg: TrainConfig, mesh, data_axes=("data",)):
    """Per-shard gradients aggregated with ``ota.aggregate`` (axis form)
    inside shard_map — the literal Eq. (6) dataflow.  Model axes must be unsharded
    (pure DP); used for equivalence tests and the paper-faithful RL-scale
    runs, not for the tensor-parallel production meshes."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    opt = make_optimizer(tcfg)
    ota_cfg = tcfg.ota_config()
    loss_fn = make_loss_fn(model)
    axes = tuple(a for a in data_axes if a in mesh.shape)

    bspec = P(axes)
    rep = P()

    def local_grads(params, batch, key):
        # batch here is this shard's (per, ...) slice; lift to (1, per, ...)
        def lf(p):
            mb = jax.tree.map(lambda x: x[None], batch)
            return loss_fn(p, mb, None)

        loss, g = jax.value_and_grad(lf)(params)
        g = ota.aggregate(g, ota_cfg, key=key, axis=axes)[0]
        return loss, g

    def train_step(state: TrainState, batch, key: jax.Array):
        key = jax.random.fold_in(key, state.step)
        sm = shard_map(
            local_grads,
            mesh=mesh,
            in_specs=(rep, bspec, rep),
            out_specs=(bspec, rep),
            check_rep=False,
        )
        losses, grads = sm(state.params, batch, key)
        grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        metrics = {"loss": jnp.mean(losses), "grad_norm": gnorm}
        return TrainState(params, opt_state, state.step + 1), metrics

    return train_step
