"""Logical-axis sharding hints for activation constraints.

Model code is mesh-agnostic; launchers activate hints mapping *logical*
activation axes ('heads', 'q_seq', 'batch', ...) to mesh axes for the
duration of tracing/lowering.  ``constrain(x, ...axes)`` then inserts
``with_sharding_constraint`` where it matters (attention internals), steering
GSPMD away from replicated attention compute:

* head-sharded attention (Megatron TP) when n_heads % |model| == 0,
* context-parallel attention (shard the query sequence over 'model')
  otherwise — the fallback that keeps e.g. 24-head llama3.2-3b sharded on a
  16-way model axis.

Outside a hints context every ``constrain`` is a no-op, so tests and eager
code never need a mesh.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE: Dict = {"mesh": None, "map": {}}


@contextmanager
def hints(mesh: Mesh, **logical_to_mesh):
    """Activate hints, e.g. hints(mesh, heads='model', batch=('pod','data'))."""
    prev = dict(_STATE)
    _STATE["mesh"] = mesh
    _STATE["map"] = {k: v for k, v in logical_to_mesh.items() if v is not None}
    try:
        yield
    finally:
        _STATE.update(prev)


def active() -> bool:
    return _STATE["mesh"] is not None


def has(name: str) -> bool:
    """Whether a logical axis name is mapped in the active hints."""
    return name in _STATE["map"]


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical axis names.

    Dims that don't resolve to a concrete mesh axis (unknown/unmapped name,
    literal None, or non-divisible size) are left UNCONSTRAINED — GSPMD keeps
    full freedom there; a constraint with NO resolved dim is skipped
    entirely.  (Forcing replication on unresolved dims measurably regressed
    MoE training and SSM prefill — EXPERIMENTS.md §Perf.)  No-op outside a
    hints context.
    """
    mesh = _STATE["mesh"]
    if mesh is None:
        return x
    entries = []
    used = set()
    any_resolved = False
    for a in axes:
        ent = _STATE["map"].get(a) if a else None
        if ent is not None:
            axs = (ent,) if isinstance(ent, str) else tuple(ent)
            axs = tuple(m for m in axs if m in mesh.shape and m not in used)
            size = 1
            for m in axs:
                size *= mesh.shape[m]
            dim = x.shape[len(entries)]
            if not axs or size <= 1 or dim % size != 0:
                ent = None
            else:
                used.update(axs)
                ent = axs if len(axs) > 1 else axs[0]
                any_resolved = True
        entries.append(ent if ent is not None else P.UNCONSTRAINED)
    if not any_resolved:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*entries)))


def attn_hints(cfg, mesh: Mesh, kind: str = "train") -> Dict[str, object]:
    """Pick head-sharding vs context-parallel for this arch on this mesh.

    ``kind``: "train" | "prefill" | "decode" — a few constraints are only
    beneficial on one side (see inline notes)."""
    model_sz = mesh.shape.get("model", 1)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    out: Dict[str, object] = {"batch": batch_axes}
    if cfg.n_heads and cfg.n_heads % model_sz == 0:
        out["heads"] = "model"
    elif cfg.n_heads:
        # context-parallel fallback — attention archs only; sharding the
        # sequence under an SSM recurrence reshards every chunked-scan step
        out["q_seq"] = "model"
    # activation-sharding discipline: pin the MLP/MoE hidden activations to
    # (batch -> data, d_ff -> model).  Without this, GSPMD sometimes resolves
    # the FSDP weight-sharding conflict by ALL-GATHERING ACTIVATIONS over the
    # batch axis in f32 (measured 5.9 GB/layer/microbatch on deepseek-67b —
    # EXPERIMENTS.md §Perf) instead of un-sharding the weights.
    if cfg.d_ff and cfg.d_ff % model_sz == 0:
        out["d_ff"] = "model"
    if cfg.moe is not None:
        if cfg.moe.num_experts % model_sz == 0:
            out["experts"] = "model"
        # Sharding the capacity dim over data is a pure win for serve paths
        # (kills the 16x global-capacity replication, §Perf Pair 1b) but a
        # large regression under training's per-microbatch grad reduction
        # (the f32 buffer cotangents reshard every layer) — measured 169 ->
        # 722 s collective on mixtral train. Serve-only.
        if kind != "train":
            out["moe_cap"] = batch_axes
    if cfg.ssm is not None:
        d_in = cfg.ssm.expand * cfg.d_model
        n_ssm_heads = d_in // cfg.ssm.headdim
        if n_ssm_heads % model_sz == 0:
            out["ssm_heads"] = "model"
            # only pin d_inner when the SSD heads shard too — otherwise each
            # layer reshards model-sharded projections to a replicated SSD
            # and back (measured 4x memory-term regression on mamba2-130m)
            if d_in % model_sz == 0:
                out["d_inner"] = "model"
    return out
