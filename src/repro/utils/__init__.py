"""Shared utilities: pytree helpers, HLO collective parsing, roofline math."""
