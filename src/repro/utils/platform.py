"""Process-level platform setup: XLA flags, x64, emulated device counts.

One place for the env mangling that used to be copy-pasted ad hoc into
benchmark drivers, conftest and the dry-run launcher.  Everything here is
import-light: ``jax`` is imported lazily inside the functions that need it,
so the flag setters can run *before* jax initialises — which is the only
time they have any effect (jax locks the platform and the host device count
on first init).

Typical uses::

    from repro.utils import platform as rplat
    rplat.set_host_device_count(8)      # BEFORE the first jax import/init
    import jax                          # sees 8 emulated CPU devices

    rplat.enable_x64()                  # float64 for reference numerics
    rplat.set_platform("cpu")           # force CPU even on an accelerator

CI and test runs opt into device emulation with the ``REPRO_EMULATED_DEVICES``
environment variable (see :func:`emulated_device_count` /
:func:`apply_emulated_devices`); tests/conftest.py applies it before jax
loads, replacing per-job ``XLA_FLAGS`` string surgery.
"""
from __future__ import annotations

import os
from typing import Optional

_DEVCOUNT_FLAG = "--xla_force_host_platform_device_count"

# Environment knob: number of emulated host (CPU) devices a test/bench
# process should see.  "" / unset / "0" means "leave jax alone".
EMULATED_DEVICES_VAR = "REPRO_EMULATED_DEVICES"


def _merge_xla_flag(flag: str, value: str) -> None:
    """Set ``flag=value`` in XLA_FLAGS, replacing any previous setting of
    the same flag and preserving every other flag already there."""
    existing = [
        tok for tok in os.environ.get("XLA_FLAGS", "").split()
        if not tok.startswith(flag + "=")
    ]
    existing.append(f"{flag}={value}")
    os.environ["XLA_FLAGS"] = " ".join(existing)


def set_host_device_count(n: int) -> None:
    """Make the CPU backend expose ``n`` emulated devices.

    Must run before jax initialises — jax locks the device count on first
    init; calling this afterwards is a silent no-op for the current process
    (the flag still propagates to subprocesses).
    """
    _merge_xla_flag(_DEVCOUNT_FLAG, str(int(n)))


def emulated_device_count(default: int = 0) -> int:
    """The requested emulated host device count (``REPRO_EMULATED_DEVICES``),
    or ``default`` when unset/empty/invalid."""
    raw = os.environ.get(EMULATED_DEVICES_VAR, "").strip()
    if not raw:
        return default
    try:
        return max(int(raw), 0)
    except ValueError:
        return default


def apply_emulated_devices(default: int = 0) -> int:
    """Honour ``REPRO_EMULATED_DEVICES`` if set: force that many emulated
    host devices (before jax init!).  Returns the applied count (0 = left
    untouched)."""
    n = emulated_device_count(default)
    if n > 0:
        set_host_device_count(n)
    return n


def set_platform(platform: Optional[str] = None) -> None:
    """Pick the jax backend: "cpu", "gpu", "tpu", or None for jax's default.

    Safe to call before first use of jax (lazily imports it)."""
    import jax

    jax.config.update("jax_platform_name", platform)


def enable_x64(use_x64: bool = True) -> None:
    """Toggle float64/int64 as the default wide types (off = jax default).

    Reference numerics (e.g. float64-folded sweep scales) flip this per
    computation instead via ``jax.experimental.enable_x64``; this is the
    process-wide switch for scripts."""
    import jax

    jax.config.update("jax_enable_x64", use_x64)


def describe() -> dict:
    """A record of the effective platform config (for bench artifacts)."""
    import jax

    return {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "local_device_count": jax.local_device_count(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "emulated_devices": emulated_device_count(),
    }
