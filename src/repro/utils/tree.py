"""Pytree utilities used across the framework.

All model parameters in this framework are plain nested dicts of jnp arrays
(no flax/optax dependency).  These helpers cover the recurring patterns:
global norms, tree-wide random perturbations, leaf counting, and structural
zip-maps between a parameter tree and a parallel "spec" tree.
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


def tree_size(tree: PyTree) -> int:
    """Total number of scalar elements across all leaves."""
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    """Total bytes across all leaves (respects dtype)."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_global_norm(tree: PyTree) -> jax.Array:
    """sqrt(sum of squared leaves) — the ||.|| used in the paper's analysis."""
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_global_norm_sq(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree: PyTree, c) -> PyTree:
    return jax.tree.map(lambda x: x * c, tree)


def tree_dot(a: PyTree, b: PyTree) -> jax.Array:
    """Inner product <a, b> across the whole tree (float32 accumulation)."""
    parts = jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return sum(jax.tree.leaves(parts))


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_normal_like(key: jax.Array, tree: PyTree, stddev: float = 1.0) -> PyTree:
    """A tree of iid N(0, stddev^2) noise with the same structure/shapes.

    This is the server-side AWGN `n_k ~ N(0, sigma^2 I_d)` of Eq. (6), applied
    leaf-wise so the concatenation of all leaves is the d-dimensional vector.
    """
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noisy = [
        jax.random.normal(k, x.shape, jnp.float32).astype(x.dtype) * stddev
        for k, x in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, noisy)


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_map_with_path_names(fn: Callable[[str, Any], Any], tree: PyTree) -> PyTree:
    """Map fn(path_string, leaf) over the tree; path is '/'-joined dict keys."""

    def _fmt(path) -> str:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return "/".join(parts)

    return jax.tree_util.tree_map_with_path(lambda p, x: fn(_fmt(p), x), tree)


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"


def human_flops(n: float) -> str:
    for unit in ("", "K", "M", "G", "T", "P"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}FLOP"
        n /= 1000.0
    return f"{n:.2f}EFLOP"


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b


def next_pow2(x: int) -> int:
    return 1 if x <= 1 else 2 ** math.ceil(math.log2(x))
