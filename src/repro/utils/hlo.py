"""Parse collective-communication volume out of compiled HLO text.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but *not* inter-chip
traffic, so the roofline's collective term is derived here by scanning the
compiled per-device module for collective ops.

Modern HLO dumps print operand lists without type annotations, so sizes are
taken from each op's *result* shape, converted to an approximate per-device
wire-bytes figure per op kind (ring-algorithm estimates, group size g):

    all-reduce       result R      wire ~ 2R(g-1)/g      -> counted as 2R(g-1)/g
    all-gather       result R      wire ~ R(g-1)/g       -> R(g-1)/g
    reduce-scatter   result R      wire ~ R(g-1)         -> R(g-1)
    all-to-all       result R      wire ~ R(g-1)/g       -> R(g-1)/g
    collective-permute result R    wire = R              -> R

The compiled module under SPMD partitioning is the per-device program, so
these are per-chip bytes-on-the-wire estimates.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g. "bf16[256,4096,1024]" or "f32[]" (scalar)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

_COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
    "ragged-all-to-all",
)

# "%x = f32[8,1,3072]{2,1,0} all-reduce(" or "= (f32[..], f32[..]) all-gather-start("
_OP_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(" + "|".join(_COLLECTIVE_OPS) + r")(-start|-done)?\("
)

# Fallback for result regions the strict regex cannot span: nested tuples
# (multi-operand async pairs print "((f32[..], ...), (f32[..], ...))", whose
# inner parens break the flat "\([^)]*\)" alternative).  Lazy-captures
# everything between "=" and the first collective token; only consulted when
# the strict form fails, so well-formed lines keep the precise parse.
_OP_FALLBACK_RE = re.compile(
    r"=\s*(.+?)\s+(" + "|".join(_COLLECTIVE_OPS) + r")(-start|-done)?\("
)

# replica_groups=[16,16]<=[256]  (16 groups of 16)  |  iota forms with dims
_GROUPS_BRACKET_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
# replica_groups={{0,1,2,...},{...}}
_GROUPS_BRACES_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0  # token/opaque types carry no payload
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nbytes


def _result_bytes(result_region: str) -> int:
    return sum(shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(result_region))


def _tuple_members(region: str) -> list:
    """Top-level members of a tuple result region, nesting-aware:
    ``"(f32[8], (f32[64], f32[64]))"`` -> ``["f32[8]", "(f32[64], f32[64])"]``.
    A non-tuple region is its own single member."""
    inner = region.strip()
    if inner.startswith("("):
        depth = 0
        for i, ch in enumerate(inner):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    inner = inner[1:i]
                    break
    members, depth, start = [], 0, 0
    for i, ch in enumerate(inner):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            members.append(inner[start:i])
            start = i + 1
    members.append(inner[start:])
    return [m.strip() for m in members if m.strip()]


def _start_result_bytes(kind: str, region: str, g: Optional[int]) -> int:
    """Result bytes of an async ``-start`` op, whose result tuple carries
    the operand(s) alongside the result(s).

    Per kind: ``all-gather-start``'s result is the g-times-larger member
    (take the max), ``reduce-scatter-start``'s is the operand scattered
    g ways (max member / g — tuples also carry small context members, so
    min-member is not reliable), and for the size-preserving kinds
    (all-reduce, all-to-all, collective-permute) operand and result halves
    are equal, so half the total is exact."""
    sizes = [_result_bytes(m) for m in _tuple_members(region)]
    if len(sizes) <= 1:
        return sizes[0] if sizes else 0
    if kind == "all-gather":
        return max(sizes)
    if kind == "reduce-scatter":
        return max(sizes) // (g if g and g > 1 else 2)
    return sum(sizes) // 2


def _group_size(line: str) -> Optional[int]:
    m = _GROUPS_BRACKET_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACES_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return None


def _wire_bytes(kind: str, result: int, g: Optional[int]) -> float:
    g = g if g and g > 1 else 2  # conservative default when groups unparsable
    if kind == "all-reduce":
        return 2.0 * result * (g - 1) / g
    if kind in ("all-gather", "all-to-all", "ragged-all-to-all",
                "collective-broadcast"):
        return result * (g - 1) / g
    if kind == "reduce-scatter":
        return float(result * (g - 1))
    return float(result)  # collective-permute


@dataclass
class CollectiveStats:
    """Per-op-kind wire-byte totals for one HLO module (per-device view)."""

    bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def summary(self) -> str:
        parts = [
            f"{k}: n={self.count_by_kind[k]} bytes={self.bytes_by_kind[k]:,.0f}"
            for k in sorted(self.bytes_by_kind)
        ]
        return "; ".join(parts) if parts else "no collectives"


def parse_collective_bytes(hlo_text: str) -> CollectiveStats:
    """Estimate per-device wire bytes of every collective in an HLO dump.

    ``-done`` halves of async collectives are skipped; ``-start`` result
    tuples carry operands alongside results and are unpacked per op kind
    (see :func:`_start_result_bytes`).  Result regions the strict line
    grammar cannot span (nested tuples) fall back to a lazy capture so the
    op is estimated rather than silently dropped.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_LINE_RE.search(line) or _OP_FALLBACK_RE.search(line)
        if m is None:
            continue
        kind, variant = m.group(2), m.group(3)
        if variant == "-done":
            continue
        g = _group_size(line)
        if variant == "-start":
            result = _start_result_bytes(kind, m.group(1), g)
        else:
            result = _result_bytes(m.group(1))
        nbytes = _wire_bytes(kind, result, g)
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0.0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


def count_op(hlo_text: str, opcode: str) -> int:
    """Count occurrences of an opcode (e.g. 'fusion', 'dot') in HLO text."""
    return len(re.findall(rf"=\s*[^=]*\b{re.escape(opcode)}\(", hlo_text))
