"""Global scan-unroll switch for cost calibration.

XLA's ``cost_analysis`` counts a ``while`` body ONCE regardless of trip
count, so FLOPs/bytes/collectives of scanned layer stacks are undercounted.
The dry-run therefore lowers *shallow, fully-unrolled* calibration variants
(identical per-layer shapes) to measure per-body costs and extrapolates to
the true depth (launch/dryrun.py::calibrated_costs).

All framework scans go through :func:`scan` so the calibration pass can flip
them to ``unroll=True`` process-wide.  Never enabled outside the dry-run.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax

_UNROLL = False


def unroll_enabled() -> bool:
    return _UNROLL


@contextmanager
def unrolled(enable: bool = True):
    global _UNROLL
    prev = _UNROLL
    _UNROLL = enable
    try:
        yield
    finally:
        _UNROLL = prev


def scan(f, init, xs, length=None):
    """lax.scan that honours the calibration unroll flag."""
    return jax.lax.scan(f, init, xs, length=length, unroll=True if _UNROLL else 1)
