"""Roofline terms for TPU v5e, derived from a compiled dry-run artifact.

Three-term model (all per-chip seconds; the compiled SPMD module is the
per-device program, so ``cost_analysis`` FLOPs/bytes are already per chip):

    compute term    = HLO_FLOPs  / peak_FLOPs_per_chip
    memory term     = HLO_bytes  / HBM_bw_per_chip
    collective term = collective_bytes / ICI_bw_per_chip

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI. We charge collectives against a single link's bandwidth — the
conservative end (ring collectives stream over one link per direction).
"""
from __future__ import annotations

from dataclasses import dataclass, asdict
from typing import Optional

PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_LINK_BW = 50e9        # bytes/s per link


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops: float          # per-chip, from compiled.cost_analysis()
    hlo_bytes: float          # per-chip HBM traffic, from cost_analysis()
    collective_bytes: float   # per-chip, from utils.hlo parser
    model_flops: float        # 6*N*D (dense) / 6*N_active*D (MoE), per chip
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    useful_flop_ratio: float = 0.0   # MODEL_FLOPS / HLO_FLOPs
    step_time_s: float = 0.0         # max of the three terms (no overlap)
    mfu: float = 0.0                 # model_flops / (step_time * peak)
    note: str = ""

    def finalize(self) -> "RooflineReport":
        self.compute_s = self.hlo_flops / PEAK_FLOPS_BF16
        self.memory_s = self.hlo_bytes / HBM_BW
        self.collective_s = self.collective_bytes / ICI_LINK_BW
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        self.dominant = max(terms, key=terms.get)
        self.useful_flop_ratio = (
            self.model_flops / self.hlo_flops if self.hlo_flops else 0.0
        )
        self.step_time_s = max(terms.values())
        self.mfu = (
            self.model_flops / (self.step_time_s * PEAK_FLOPS_BF16)
            if self.step_time_s
            else 0.0
        )
        return self

    def row(self) -> dict:
        return asdict(self)


def model_flops_per_step(
    *,
    n_params_active: int,
    tokens: int,
    training: bool,
) -> float:
    """The classic 6ND (train) / 2ND (inference fwd) useful-FLOPs estimate.

    ``n_params_active``: for MoE, embedding+attn+router plus top_k experts'
    FFN params; for dense, all params. ``tokens``: tokens processed this step
    (decode = batch * 1).
    """
    mult = 6.0 if training else 2.0
    return mult * float(n_params_active) * float(tokens)
