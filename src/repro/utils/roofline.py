"""Roofline terms for TPU v5e, derived from a compiled dry-run artifact.

Three-term model (all per-chip seconds; the compiled SPMD module is the
per-device program, so ``cost_analysis`` FLOPs/bytes are already per chip):

    compute term    = HLO_FLOPs  / peak_FLOPs_per_chip
    memory term     = HLO_bytes  / HBM_bw_per_chip
    collective term = collective_bytes / ICI_bw_per_chip

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI. We charge collectives against a single link's bandwidth — the
conservative end (ring collectives stream over one link per direction).
"""
from __future__ import annotations

from dataclasses import dataclass, asdict
from typing import Optional

PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_LINK_BW = 50e9        # bytes/s per link


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops: float          # per-chip, from compiled.cost_analysis()
    hlo_bytes: float          # per-chip HBM traffic, from cost_analysis()
    collective_bytes: float   # per-chip, from utils.hlo parser
    model_flops: float        # 6*N*D (dense) / 6*N_active*D (MoE), per chip
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    useful_flop_ratio: float = 0.0   # MODEL_FLOPS / HLO_FLOPs
    step_time_s: float = 0.0         # max of the three terms (no overlap)
    mfu: float = 0.0                 # model_flops / (step_time * peak)
    note: str = ""

    def finalize(self) -> "RooflineReport":
        self.compute_s = self.hlo_flops / PEAK_FLOPS_BF16
        self.memory_s = self.hlo_bytes / HBM_BW
        self.collective_s = self.collective_bytes / ICI_LINK_BW
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        self.dominant = max(terms, key=terms.get)
        self.useful_flop_ratio = (
            self.model_flops / self.hlo_flops if self.hlo_flops else 0.0
        )
        self.step_time_s = max(terms.values())
        self.mfu = (
            self.model_flops / (self.step_time_s * PEAK_FLOPS_BF16)
            if self.step_time_s
            else 0.0
        )
        return self

    def row(self) -> dict:
        return asdict(self)


def ota_fused_cost(
    n_params: int,
    n_agents: int,
    *,
    wire_bytes: int = 4,
    with_noise: bool = True,
    mode: str = "sgd",
) -> dict:
    """Analytic flop/byte estimate for the fused OTA aggregation kernel
    (``repro.kernels.ota_fused``) vs the unfused XLA op chain.

    The fused kernel streams the (N, P) gradient stack once and writes one
    P-vector (plus the optimizer state it updates in the same pass); the
    XLA chain additionally materialises the weighted sum, the sampled noise
    tensor, and the scaled update as separate HBM round trips.  Per
    element: 2N flops for the gain matvec, ~25 for the counter-PRNG
    Box-Muller draw, and a handful for scale/update.

    Returns a dict with ``flops``, ``fused_bytes``, ``xla_bytes``,
    ``fused_s`` / ``xla_s`` (HBM-bound roofline times on v5e) and
    ``speedup_est`` — the numbers ``launch/dryrun.py`` records and
    ``benchmarks/ota_kernel.py`` measures against.
    """
    p = float(n_params)
    n = float(n_agents)
    state = {"agg": 0, "sgd": 1, "adam": 3}[mode]  # extra P-vectors touched
    flops = p * (2.0 * n + (25.0 if with_noise else 0.0)
                 + {"agg": 1, "sgd": 3, "adam": 12}[mode])
    # fused: read the wire-format stack once, read+write each state vector
    fused_bytes = p * n * wire_bytes + p * 4.0 * (1.0 + 2.0 * state)
    # XLA chain: gain-weighted reduce (read stack, write sum), noise
    # materialise (write + read), add (read sum, write), scale (read,
    # write), then the update's read-modify-write per state vector
    xla_bytes = (
        p * n * 4.0                     # read fp32 stack for the reduce
        + p * 4.0 * 2.0                 # write sum + re-read for noise add
        + (p * 4.0 * 2.0 if with_noise else 0.0)   # noise write + read
        + p * 4.0 * 2.0                 # scale pass
        + p * 4.0 * 2.0 * max(state, 1)  # update read-modify-write
    )
    fused_s = fused_bytes / HBM_BW
    xla_s = xla_bytes / HBM_BW
    return {
        "n_params": int(n_params),
        "n_agents": int(n_agents),
        "mode": mode,
        "wire_bytes": int(wire_bytes),
        "flops": flops,
        "fused_bytes": fused_bytes,
        "xla_bytes": xla_bytes,
        "fused_s": fused_s,
        "xla_s": xla_s,
        "compute_s": flops / PEAK_FLOPS_BF16,
        "speedup_est": xla_s / fused_s if fused_s else 0.0,
    }


def model_flops_per_step(
    *,
    n_params_active: int,
    tokens: int,
    training: bool,
) -> float:
    """The classic 6ND (train) / 2ND (inference fwd) useful-FLOPs estimate.

    ``n_params_active``: for MoE, embedding+attn+router plus top_k experts'
    FFN params; for dense, all params. ``tokens``: tokens processed this step
    (decode = batch * 1).
    """
    mult = 6.0 if training else 2.0
    return mult * float(n_params_active) * float(tokens)
