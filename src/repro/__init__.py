"""Reproduction of "Over-the-air Federated Policy Gradient" (arXiv 2310.16592).

Subpackages: ``core`` (channel/OTA/estimators/theory/fedpg/sweep), ``rl``
(envs, policies, samplers), ``models``/``train``/``launch`` (the scaled
trainer substrate), ``kernels`` (Pallas), ``utils``.
"""
