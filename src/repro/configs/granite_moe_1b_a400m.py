"""granite-moe-1b-a400m [moe] — 32 experts, top-8.

24L d_model=1024 16H (GQA kv=8) d_ff=512 (per expert) vocab=49155
[hf:ibm-granite/granite-3.0-1b-a400m-base].
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    moe=MoEConfig(num_experts=32, top_k=8),
    serve_window=8192,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

SMOKE_CONFIG = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=64, vocab=512,
    moe=MoEConfig(num_experts=4, top_k=2), remat=False,
)
