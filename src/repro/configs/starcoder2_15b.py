"""starcoder2-15b [dense] — GQA, RoPE.

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152 [arXiv:2402.19173].
StarCoder2-15B natively uses a 4096 sliding window for part of its context
handling; we keep full attention for train/prefill/decode_32k per the
assignment and use the ring-cache SWA only for long_500k serving.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    rope_theta=100000.0,
    serve_window=4096,      # the model's own SWA width
    source="arXiv:2402.19173",
)

SMOKE_CONFIG = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
    remat=False,
)
