"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768 [arXiv:2401.04088].
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    moe=MoEConfig(num_experts=8, top_k=2),
    window=4096,            # native SWA — sub-quadratic by construction
    serve_window=4096,
    rope_theta=1000000.0,
    source="arXiv:2401.04088",
)

SMOKE_CONFIG = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
    moe=MoEConfig(num_experts=4, top_k=2), window=64, serve_window=64,
    remat=False,
)
