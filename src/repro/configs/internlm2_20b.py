"""internlm2-20b [dense] — GQA.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544 [arXiv:2403.17297].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92544,
    rope_theta=1000000.0,
    serve_window=8192,
    source="arXiv:2403.17297",
)

SMOKE_CONFIG = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
    remat=False,
)
