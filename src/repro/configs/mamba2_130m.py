"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

24L d_model=768 d_ff=0 vocab=50280, ssm_state=128 [arXiv:2405.21060].
d_inner = 2*768 = 1536, headdim 64 => 24 SSD heads.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,              # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(state=128, headdim=64, expand=2, n_groups=1, chunk=128),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)

SMOKE_CONFIG = CONFIG.with_(
    n_layers=2, d_model=128, vocab=512,
    ssm=SSMConfig(state=16, headdim=32, expand=2, n_groups=1, chunk=32),
    remat=False,
)
