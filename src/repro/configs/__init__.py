"""Architecture configs (one module per assigned architecture) + input shapes.

``get_config(arch_id)`` resolves any of the 10 assigned architectures (plus
the paper's own RL config) by id; ``repro.configs.shapes`` defines the 4
assigned input shapes.
"""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig  # noqa: F401

ARCH_IDS = (
    "seamless-m4t-large-v2",
    "granite-moe-1b-a400m",
    "llama-3.2-vision-11b",
    "internlm2-20b",
    "starcoder2-15b",
    "mamba2-130m",
    "mixtral-8x22b",
    "zamba2-7b",
    "deepseek-67b",
    "llama3.2-3b",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str) -> "ModelConfig":
    """Full-size config for an assigned architecture id."""
    if arch_id not in _MODULES:
        raise ValueError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def get_smoke_config(arch_id: str) -> "ModelConfig":
    """Reduced same-family config (<=2 layers, d_model<=512, <=4 experts)."""
    if arch_id not in _MODULES:
        raise ValueError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch_id]).SMOKE_CONFIG
