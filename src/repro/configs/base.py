"""Model configuration dataclasses shared by every architecture family.

A single ``ModelConfig`` describes all six assigned families (dense / moe /
ssm / hybrid / encdec / vlm); family-specific blocks are optional sub-configs.
Configs are frozen and hashable so they can be closed over by jitted code.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0          # optional routing noise (train)
    load_balance_coef: float = 0.01     # aux loss weight


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block parameters."""

    state: int                 # N — SSM state size per head
    headdim: int = 64          # P
    expand: int = 2            # d_inner = expand * d_model
    n_groups: int = 1          # B/C groups (G)
    conv_width: int = 4        # causal depthwise conv
    chunk: int = 128           # SSD chunk length (MXU-aligned)
    dt_min: float = 1e-3
    dt_max: float = 1e-1


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                # 0 for attention-free layers
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0             # 0 -> derived d_model // n_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    window: Optional[int] = None          # sliding-window attention width
    serve_window: Optional[int] = None    # SWA applied only for long-context serving
    cross_attn_every: int = 0             # vlm/audio: cross-attn each k-th layer
    n_cross_tokens: int = 0               # stub frontend: patches / audio frames
    encoder_layers: int = 0               # encdec: encoder depth
    shared_attn_every: int = 0            # hybrid: shared attn block period
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True                    # activation checkpoint each layer
    source: str = ""                      # citation for the config

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def q_per_kv(self) -> int:
        return max(self.n_heads, 1) // max(self.n_kv_heads, 1)

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    @property
    def has_ssm(self) -> bool:
        return self.ssm is not None

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    # ---- parameter counting (for 6ND roofline terms) ---------------------
    def param_counts(self) -> Tuple[int, int]:
        """(total_params, active_params) — active = per-token touched params
        (MoE counts only top_k experts; shared/tied embeddings once)."""
        d, dh = self.d_model, self.head_dim
        nh, nkv = max(self.n_heads, 1), max(self.n_kv_heads, 1)

        def attn_block() -> int:
            qkv = d * (nh * dh) + 2 * d * (nkv * dh) + (nh * dh) * d
            return qkv + 2 * d  # + norms

        def mlp_block(ff: int) -> int:
            return 3 * d * ff + d  # SwiGLU (gate, up, down) + norm

        def ssm_block() -> int:
            s = self.ssm
            d_in = s.expand * d
            h = d_in // s.headdim
            in_proj = d * (2 * d_in + 2 * s.n_groups * s.state + h)
            conv = (d_in + 2 * s.n_groups * s.state) * s.conv_width
            out = d_in * d
            return in_proj + conv + out + 2 * h + d  # + A_log, D, norm

        total = 0
        per_layer_active = 0
        n_layers = self.n_layers

        if self.family in ("dense", "vlm", "audio"):
            layer = attn_block() + mlp_block(self.d_ff)
            total += n_layers * layer
            per_layer_active += n_layers * layer
            if self.cross_attn_every:
                n_cross = n_layers // self.cross_attn_every
                cross = attn_block() + mlp_block(self.d_ff)
                total += n_cross * cross
                per_layer_active += n_cross * cross
        elif self.family == "encdec":
            enc = self.encoder_layers * (attn_block() + mlp_block(self.d_ff))
            dec = n_layers * (2 * attn_block() + mlp_block(self.d_ff))
            total += enc + dec
            per_layer_active += enc + dec
        elif self.family == "moe":
            m = self.moe
            router = d * m.num_experts
            experts_total = m.num_experts * 3 * d * self.d_ff
            experts_active = m.top_k * 3 * d * self.d_ff
            layer_shared = attn_block() + router + d
            total += n_layers * (layer_shared + experts_total)
            per_layer_active += n_layers * (layer_shared + experts_active)
        elif self.family == "ssm":
            total += n_layers * ssm_block()
            per_layer_active += n_layers * ssm_block()
        elif self.family == "hybrid":
            total += n_layers * ssm_block()
            per_layer_active += n_layers * ssm_block()
            if self.shared_attn_every:
                shared = attn_block() + mlp_block(self.d_ff)
                total += shared  # shared weights stored once
                n_applied = n_layers // self.shared_attn_every
                per_layer_active += n_applied * shared
        else:
            raise ValueError(self.family)

        emb = self.vocab * d
        total += emb + d  # embedding + final norm
        per_layer_active += emb + d
        if not self.tie_embeddings:
            total += emb      # lm head
            per_layer_active += emb
        return int(total), int(per_layer_active)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch            # one new token per sequence
        return self.global_batch * self.seq_len
