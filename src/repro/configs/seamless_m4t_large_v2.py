"""seamless-m4t-large-v2 [audio] — encoder-decoder multimodal backbone.

24L d_model=1024 16H (GQA kv=16 => MHA) d_ff=8192 vocab=256206
[arXiv:2308.11596].  Backbone only: the speech frontend (mel-spectrogram +
conformer feature extractor) is a stub — ``input_specs()`` supplies
precomputed frame embeddings (B, seq//4, d_model); the text decoder
cross-attends to the 24-layer encoder's output.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,            # decoder depth (assigned backbone depth)
    encoder_layers=24,      # speech encoder transformer depth
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    n_cross_tokens=0,       # encdec: cross length = frame count (seq//4)
    serve_window=8192,      # beyond-paper SWA ring cache for long_500k decode
    source="arXiv:2308.11596",
)

SMOKE_CONFIG = CONFIG.with_(
    n_layers=2, encoder_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab=512, remat=False,
)
