"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (GQA kv=32 => MHA in the shared block) d_ff=14336
vocab=32000, ssm_state=64 [arXiv:2411.15242].  The shared attention+MLP
block's weights are stored once and applied every 6th layer.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm=SSMConfig(state=64, headdim=64, expand=2, n_groups=1, chunk=128),
    shared_attn_every=6,
    serve_window=8192,      # shared-attn KV ring for long_500k
    source="arXiv:2411.15242",
)

SMOKE_CONFIG = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
    ssm=SSMConfig(state=16, headdim=32, expand=2, n_groups=1, chunk=32),
    shared_attn_every=2, remat=False,
)
