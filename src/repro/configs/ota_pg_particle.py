"""The paper's own experiment configuration (Section IV).

LandmarkNav particle env, 2-layer MLP policy (16 hidden, ReLU, softmax over 5
actions), T=20, gamma=0.99, sigma^2 = -60 dB; Rayleigh (alpha=1e-4) and
Nakagami-m (m=0.1, Omega=1, alpha=1e-3) channel settings, 20 Monte Carlo runs.
"""
from dataclasses import dataclass

from repro.core.channel import noise_sigma_from_db
from repro.core.fedpg import FedPGConfig


@dataclass(frozen=True)
class PaperSetting:
    name: str
    channel: str
    channel_kwargs: tuple        # ((key, value), ...) — hashable
    alpha: float
    noise_sigma: float
    horizon: int = 20
    gamma: float = 0.99
    mc_runs: int = 20

    def fedpg(self, *, n_agents: int, batch_m: int, n_rounds: int) -> FedPGConfig:
        return FedPGConfig(
            n_agents=n_agents,
            batch_m=batch_m,
            horizon=self.horizon,
            gamma=self.gamma,
            alpha=self.alpha,
            n_rounds=n_rounds,
        )


RAYLEIGH = PaperSetting(
    name="rayleigh",
    channel="rayleigh",
    channel_kwargs=(),
    alpha=1e-4,
    noise_sigma=noise_sigma_from_db(-60.0),
)

NAKAGAMI = PaperSetting(
    name="nakagami",
    channel="nakagami",
    channel_kwargs=(("m", 0.1), ("omega", 1.0)),
    alpha=1e-3,
    noise_sigma=noise_sigma_from_db(-60.0),
)
