"""llama-3.2-vision-11b [vlm] — cross-attn image layers every 5 blocks.

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256
[hf:meta-llama/Llama-3.2-11B-Vision].  Backbone only: the ViT vision encoder
+ projector are stubs — ``input_specs()`` supplies patch embeddings
(B, n_patches, d_model); every 5th decoder layer gains a gated cross-attn
sub-block over them.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    cross_attn_every=5,
    n_cross_tokens=1601,    # 1 tile x (40x40 patches + cls), ViT-H/14 @ 560px
    rope_theta=500000.0,
    serve_window=8192,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)

SMOKE_CONFIG = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
    cross_attn_every=2, n_cross_tokens=16, remat=False,
)
