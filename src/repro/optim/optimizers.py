"""Minimal production optimizer stack: sgd / momentum / adam / adamw.

API mirrors the familiar gradient-transform pattern:

    opt = adamw(schedule, b1=0.9, b2=0.95, weight_decay=0.1)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Optimizer states are plain pytrees whose leaves mirror the parameter tree, so
they inherit the parameters' PartitionSpecs (FSDP-sharded moments for free).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_global_norm

PyTree = Any
Schedule = Callable[[jax.Array], jax.Array]
ScalarOrSchedule = Union[float, Schedule]


class OptState(NamedTuple):
    step: jax.Array
    mu: Any = None       # first moment  (momentum / adam)
    nu: Any = None       # second moment (adam)


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, Optional[PyTree]], Tuple[PyTree, OptState]]


def _lr_at(lr: ScalarOrSchedule, step: jax.Array) -> jax.Array:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def sgd(lr: ScalarOrSchedule) -> Optimizer:
    def init(params):
        del params
        return OptState(step=jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        del params
        step = state.step + 1
        a = _lr_at(lr, step)
        upd = jax.tree.map(lambda g: (-a * g.astype(jnp.float32)).astype(g.dtype), grads)
        return upd, OptState(step=step)

    return Optimizer(init=init, update=update)


def momentum(lr: ScalarOrSchedule, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        )

    def update(grads, state, params=None):
        del params
        step = state.step + 1
        a = _lr_at(lr, step)
        mu = jax.tree.map(
            lambda m, g: beta * m + g.astype(jnp.float32), state.mu, grads
        )
        if nesterov:
            upd = jax.tree.map(
                lambda m, g: (-a * (beta * m + g.astype(jnp.float32))).astype(g.dtype),
                mu, grads,
            )
        else:
            upd = jax.tree.map(lambda m, g: (-a * m).astype(g.dtype), mu, grads)
        return upd, OptState(step=step, mu=mu)

    return Optimizer(init=init, update=update)


def _adam_core(
    lr: ScalarOrSchedule, b1: float, b2: float, eps: float, weight_decay: float
) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(z, params),
            nu=jax.tree.map(z, params),
        )

    def update(grads, state, params=None):
        step = state.step + 1
        a = _lr_at(lr, step)
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1**t
        c2 = 1.0 - b2**t
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads,
        )

        def upd_leaf(m, v, p):
            u = -(a * (m / c1) / (jnp.sqrt(v / c2) + eps))
            if weight_decay and p is not None:
                u = u - a * weight_decay * p.astype(jnp.float32)
            return u

        if weight_decay:
            if params is None:
                raise ValueError("adamw.update needs params for weight decay")
            upd = jax.tree.map(upd_leaf, mu, nu, params)
        else:
            upd = jax.tree.map(lambda m, v: upd_leaf(m, v, None), mu, nu)
        upd = jax.tree.map(lambda u, g: u.astype(g.dtype), upd, grads)
        return upd, OptState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def adam(lr: ScalarOrSchedule, b1=0.9, b2=0.999, eps=1e-8) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, 0.0)


def adamw(lr: ScalarOrSchedule, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, weight_decay)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32)
                                      + u.astype(jnp.float32)).astype(p.dtype),
                        params, updates)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    norm = tree_global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1) -> Schedule:
    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return base_lr * (final_frac + (1 - final_frac) * cos)

    return fn


def warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                  final_frac: float = 0.1) -> Schedule:
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), final_frac)

    def fn(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(warmup, 1)
        return jnp.where(step <= warmup, warm, cos(step - warmup))

    return fn
