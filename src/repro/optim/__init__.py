"""In-house optimizers (no optax dependency)."""
from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adam, adamw, clip_by_global_norm, cosine_schedule, momentum,
    sgd, warmup_cosine,
)
