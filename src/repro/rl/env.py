"""Pure-JAX environments.

``LandmarkNav`` is the paper's simulation environment (Section IV, from the
OpenAI particle-env family [29]): the agent and a landmark live in the plane,
state s = (x, y, x', y'), five discrete actions {stay,left,right,up,down},
per-step loss l(s,a) = Euclidean distance to the landmark (reward = -l).

``TabularMDP`` is a small finite MDP with *known* transition kernel and loss
table, for which the exact discounted objective J(theta) — and therefore the
exact policy gradient via autodiff — can be computed by propagating the state
distribution.  It anchors the estimator-unbiasedness property tests.

Both are stateless pure-function environments:
    reset(key)            -> state
    step(key, state, a)   -> (next_state, loss)
compatible with ``lax.scan`` rollouts in ``sampler.py``.

The wider environment zoo (windy/multi-landmark particle tasks, cliff-walk
grids, LQR, Garnet MDPs, heterogeneous per-agent wrappers) lives in
``repro.rl.envs``, which also hosts the env registry that makes the
environment a first-class sweep axis.  Envs may expose:

    kind_tag()        -> str     structural tag for sweep partitioning
    default_policy()  -> policy  a compatible policy (registry hook)
    l_bar_for(T)      -> float   loss envelope at horizon T (Assumption 1)
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class LandmarkNav:
    """The paper's landmark-covering particle task."""

    arena: float = 1.0       # initial positions uniform in [-arena, arena]^2
    step_size: float = 0.1
    n_actions: int = 5       # stay, left, right, up, down
    obs_dim: int = 4

    # action -> displacement table
    @property
    def moves(self) -> jnp.ndarray:
        return jnp.array(
            [[0.0, 0.0], [-1.0, 0.0], [1.0, 0.0], [0.0, 1.0], [0.0, -1.0]],
            jnp.float32,
        ) * self.step_size

    def reset(self, key: jax.Array) -> jax.Array:
        """state = (x, y, x_landmark, y_landmark)."""
        return jax.random.uniform(
            key, (4,), jnp.float32, minval=-self.arena, maxval=self.arena
        )

    def step(
        self, key: jax.Array, state: jax.Array, action: jax.Array
    ) -> Tuple[jax.Array, jax.Array]:
        del key  # deterministic dynamics
        pos = state[:2] + self.moves[action]
        nxt = jnp.concatenate([pos, state[2:]])
        loss = self.loss(nxt)
        return nxt, loss

    def loss(self, state: jax.Array) -> jax.Array:
        """l(s, a) = distance to landmark (computed on the post-move state)."""
        d = state[:2] - state[2:]
        return jnp.sqrt(jnp.sum(d * d) + 1e-12)

    def l_bar_for(self, horizon: int) -> float:
        """Loss envelope for Assumption 1 at the *actual* configured horizon.

        Positions start in [-a, a]^2 and can drift step_size*T further, so
        the worst-case distance to the landmark is the diagonal of
        [-(a + step_size*T), a + step_size*T]^2.  (Used only for theory
        tables — pass the horizon the run actually uses, e.g.
        ``FedPGConfig.horizon``.)
        """
        reach = self.arena + self.step_size * horizon
        return float(2.0 * reach * math.sqrt(2.0))

    @property
    def l_bar(self) -> float:
        """Legacy fixed-horizon envelope: ``l_bar_for(20)`` (the paper's
        T=20).  Theory tables for other horizons must use ``l_bar_for``."""
        return self.l_bar_for(20)

    def default_policy(self):
        """The paper's target policy for this task (registry hook)."""
        from repro.rl.policy import MLPPolicy

        return MLPPolicy(obs_dim=self.obs_dim, hidden=16,
                         n_actions=self.n_actions)


@dataclass(frozen=True)
class TabularMDP:
    """Finite MDP with a known model; supports exact J(theta) by autodiff.

    P:   (S, A, S) transition kernel
    l:   (S, A) loss table in [0, l_bar]
    rho: (S,) initial distribution
    """

    P: jnp.ndarray
    l: jnp.ndarray
    rho: jnp.ndarray
    gamma: float
    horizon: int

    @property
    def n_states(self) -> int:
        return self.P.shape[0]

    @property
    def n_actions(self) -> int:
        return self.P.shape[1]

    @property
    def obs_dim(self) -> int:
        return self.n_states  # one-hot observation

    def kind_tag(self) -> str:
        """Structural sweep tag: the (S, A) shape is what changes the trace;
        the P/l/rho tables themselves batch as lane parameters."""
        return f"tabular:{self.n_states}x{self.n_actions}"

    def default_policy(self):
        from repro.rl.policy import TabularSoftmaxPolicy

        return TabularSoftmaxPolicy(self.n_states, self.n_actions)

    def l_bar_for(self, horizon: int) -> float:
        """sup loss straight off the (known) loss table."""
        del horizon  # table bound is horizon-independent
        return float(jnp.max(self.l))

    @property
    def l_bar(self) -> float:
        return self.l_bar_for(0)

    @staticmethod
    def random(key: jax.Array, n_states: int = 4, n_actions: int = 3,
               gamma: float = 0.9, horizon: int = 5) -> "TabularMDP":
        kp, kl, kr = jax.random.split(key, 3)
        logits = jax.random.normal(kp, (n_states, n_actions, n_states))
        P = jax.nn.softmax(2.0 * logits, axis=-1)
        l = jax.random.uniform(kl, (n_states, n_actions))
        rho = jax.nn.softmax(jax.random.normal(kr, (n_states,)))
        return TabularMDP(P=P, l=l, rho=rho, gamma=gamma, horizon=horizon)

    def reset(self, key: jax.Array) -> jax.Array:
        s = jax.random.categorical(key, jnp.log(self.rho + 1e-30))
        return jax.nn.one_hot(s, self.n_states)

    def step(
        self, key: jax.Array, state: jax.Array, action: jax.Array
    ) -> Tuple[jax.Array, jax.Array]:
        s = jnp.argmax(state)
        loss = self.l[s, action]
        nxt = jax.random.categorical(key, jnp.log(self.P[s, action] + 1e-30))
        return jax.nn.one_hot(nxt, self.n_states), loss

    def exact_J(self, policy_probs: jnp.ndarray) -> jax.Array:
        """Exact J = E[sum_{t=0}^{T} gamma^t l(s_t, a_t)] for pi(a|s) table.

        Differentiable in ``policy_probs`` — jax.grad of this (through a
        softmax parameterisation) is the *exact* policy gradient that the
        G(PO)MDP estimator must match in expectation.

        Note the paper's objective sums t = 0..T inclusive (T+1 action steps).
        """
        def body(carry, _):
            d, acc, disc = carry
            step_loss = jnp.sum(d[:, None] * policy_probs * self.l)
            acc = acc + disc * step_loss
            # next-state distribution
            d = jnp.einsum("s,sa,sat->t", d, policy_probs, self.P)
            return (d, acc, disc * self.gamma), None

        init = (self.rho, jnp.zeros(()), jnp.ones(()))
        (d, acc, disc), _ = jax.lax.scan(body, init, None, length=self.horizon + 1)
        return acc
