"""Pure-JAX environments.

``LandmarkNav`` is the paper's simulation environment (Section IV, from the
OpenAI particle-env family [29]): the agent and a landmark live in the plane,
state s = (x, y, x', y'), five discrete actions {stay,left,right,up,down},
per-step loss l(s,a) = Euclidean distance to the landmark (reward = -l).

``TabularMDP`` is a small finite MDP with *known* transition kernel and loss
table, for which the exact discounted objective J(theta) — and therefore the
exact policy gradient via autodiff — can be computed by propagating the state
distribution.  It anchors the estimator-unbiasedness property tests.

Both are stateless pure-function environments:
    reset(key)            -> state
    step(key, state, a)   -> (next_state, loss)
compatible with ``lax.scan`` rollouts in ``sampler.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class LandmarkNav:
    """The paper's landmark-covering particle task."""

    arena: float = 1.0       # initial positions uniform in [-arena, arena]^2
    step_size: float = 0.1
    n_actions: int = 5       # stay, left, right, up, down
    obs_dim: int = 4

    # action -> displacement table
    @property
    def moves(self) -> jnp.ndarray:
        return jnp.array(
            [[0.0, 0.0], [-1.0, 0.0], [1.0, 0.0], [0.0, 1.0], [0.0, -1.0]],
            jnp.float32,
        ) * self.step_size

    def reset(self, key: jax.Array) -> jax.Array:
        """state = (x, y, x_landmark, y_landmark)."""
        return jax.random.uniform(
            key, (4,), jnp.float32, minval=-self.arena, maxval=self.arena
        )

    def step(
        self, key: jax.Array, state: jax.Array, action: jax.Array
    ) -> Tuple[jax.Array, jax.Array]:
        del key  # deterministic dynamics
        pos = state[:2] + self.moves[action]
        nxt = jnp.concatenate([pos, state[2:]])
        loss = self.loss(nxt)
        return nxt, loss

    def loss(self, state: jax.Array) -> jax.Array:
        """l(s, a) = distance to landmark (computed on the post-move state)."""
        d = state[:2] - state[2:]
        return jnp.sqrt(jnp.sum(d * d) + 1e-12)

    @property
    def l_bar(self) -> float:
        """Loss envelope for Assumption 1 given the bounded arena + T moves.

        Positions start in [-a, a]^2 and can drift step_size*T further, so the
        worst-case distance is bounded.  (Used only for theory tables.)
        """
        # conservative: diag of [-(a+0.1*T), a+0.1*T]^2 with T<=20 at build
        reach = self.arena + self.step_size * 20
        return float(2.0 * reach * jnp.sqrt(2.0))


@dataclass(frozen=True)
class TabularMDP:
    """Finite MDP with a known model; supports exact J(theta) by autodiff.

    P:   (S, A, S) transition kernel
    l:   (S, A) loss table in [0, l_bar]
    rho: (S,) initial distribution
    """

    P: jnp.ndarray
    l: jnp.ndarray
    rho: jnp.ndarray
    gamma: float
    horizon: int

    @property
    def n_states(self) -> int:
        return self.P.shape[0]

    @property
    def n_actions(self) -> int:
        return self.P.shape[1]

    @property
    def obs_dim(self) -> int:
        return self.n_states  # one-hot observation

    @staticmethod
    def random(key: jax.Array, n_states: int = 4, n_actions: int = 3,
               gamma: float = 0.9, horizon: int = 5) -> "TabularMDP":
        kp, kl, kr = jax.random.split(key, 3)
        logits = jax.random.normal(kp, (n_states, n_actions, n_states))
        P = jax.nn.softmax(2.0 * logits, axis=-1)
        l = jax.random.uniform(kl, (n_states, n_actions))
        rho = jax.nn.softmax(jax.random.normal(kr, (n_states,)))
        return TabularMDP(P=P, l=l, rho=rho, gamma=gamma, horizon=horizon)

    def reset(self, key: jax.Array) -> jax.Array:
        s = jax.random.categorical(key, jnp.log(self.rho + 1e-30))
        return jax.nn.one_hot(s, self.n_states)

    def step(
        self, key: jax.Array, state: jax.Array, action: jax.Array
    ) -> Tuple[jax.Array, jax.Array]:
        s = jnp.argmax(state)
        loss = self.l[s, action]
        nxt = jax.random.categorical(key, jnp.log(self.P[s, action] + 1e-30))
        return jax.nn.one_hot(nxt, self.n_states), loss

    def exact_J(self, policy_probs: jnp.ndarray) -> jax.Array:
        """Exact J = E[sum_{t=0}^{T} gamma^t l(s_t, a_t)] for pi(a|s) table.

        Differentiable in ``policy_probs`` — jax.grad of this (through a
        softmax parameterisation) is the *exact* policy gradient that the
        G(PO)MDP estimator must match in expectation.

        Note the paper's objective sums t = 0..T inclusive (T+1 action steps).
        """
        def body(carry, _):
            d, acc, disc = carry
            step_loss = jnp.sum(d[:, None] * policy_probs * self.l)
            acc = acc + disc * step_loss
            # next-state distribution
            d = jnp.einsum("s,sa,sat->t", d, policy_probs, self.P)
            return (d, acc, disc * self.gamma), None

        init = (self.rho, jnp.zeros(()), jnp.ones(()))
        (d, acc, disc), _ = jax.lax.scan(body, init, None, length=self.horizon + 1)
        return acc
