"""Finite grid worlds with one-hot observations.

``CliffWalk`` is the classic Sutton & Barto cliff-walking task in the
paper's loss (cost) convention: a W x H grid, start bottom-left, goal
bottom-right, a cliff along the bottom edge between them.  Stepping into
the cliff costs ``cliff_cost`` and teleports the agent back to the start;
every other step costs ``step_cost`` except the absorbing goal (cost 0).
``slip`` is the probability the chosen action is replaced by a uniformly
random one — the stochasticity knob, and a continuous sweep-lane
parameter (grid size is structural via the kind tag).

Observations are one-hot over the W*H cells, so ``TabularSoftmaxPolicy``
pairs with it naturally; losses are bounded by ``max(cliff_cost,
step_cost)``, giving an exact Assumption-1 envelope.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.rl.envs.registry import register_env

# action -> (dx, dy): up, down, left, right
_MOVES = ((0, 1), (0, -1), (-1, 0), (1, 0))


@dataclass(frozen=True)
class CliffWalk:
    """W x H cliff-walk grid; cells are indexed s = y * width + x."""

    width: int = 6
    height: int = 4
    slip: float = 0.05
    cliff_cost: float = 1.0
    step_cost: float = 0.1
    n_actions: int = 4

    @property
    def obs_dim(self) -> int:
        return self.width * self.height

    @property
    def start_state(self) -> int:
        return 0  # (0, 0), bottom-left

    @property
    def goal_state(self) -> int:
        return self.width - 1  # (W-1, 0), bottom-right

    def kind_tag(self) -> str:
        return f"cliffwalk:{self.width}x{self.height}"

    def _cliff_mask(self) -> jnp.ndarray:
        """(W*H,) bool: bottom-row cells strictly between start and goal."""
        cell = jnp.arange(self.width * self.height)
        x, y = cell % self.width, cell // self.width
        return (y == 0) & (x > 0) & (x < self.width - 1)

    def reset(self, key: jax.Array) -> jax.Array:
        del key  # deterministic start
        return jax.nn.one_hot(self.start_state, self.obs_dim)

    def step(
        self, key: jax.Array, state: jax.Array, action: jax.Array
    ) -> Tuple[jax.Array, jax.Array]:
        s = jnp.argmax(state)
        key_slip, key_act = jax.random.split(key)
        u = jax.random.uniform(key_slip, (), jnp.float32)
        rand_a = jax.random.randint(key_act, (), 0, self.n_actions)
        a = jnp.where(u < self.slip, rand_a, action)

        moves = jnp.array(_MOVES, jnp.int32)
        x, y = s % self.width, s // self.width
        x2 = jnp.clip(x + moves[a, 0], 0, self.width - 1)
        y2 = jnp.clip(y + moves[a, 1], 0, self.height - 1)
        nxt = y2 * self.width + x2

        in_cliff = self._cliff_mask()[nxt]
        at_goal = s == self.goal_state
        # goal is absorbing: stay put, zero loss
        nxt = jnp.where(at_goal, s, jnp.where(in_cliff, self.start_state, nxt))
        loss = jnp.where(
            at_goal,
            0.0,
            jnp.where(in_cliff, self.cliff_cost, self.step_cost),
        ).astype(jnp.float32)
        return jax.nn.one_hot(nxt, self.obs_dim), loss

    def l_bar_for(self, horizon: int) -> float:
        del horizon  # per-step cost bound is horizon-independent
        return float(max(self.cliff_cost, self.step_cost))

    @property
    def l_bar(self) -> float:
        return self.l_bar_for(0)

    def default_policy(self):
        from repro.rl.policy import TabularSoftmaxPolicy

        return TabularSoftmaxPolicy(self.obs_dim, self.n_actions)


register_env("cliffwalk", CliffWalk)
