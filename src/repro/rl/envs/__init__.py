"""Environment zoo: the ``env`` axis of the scenario-sweep engine.

Importing this package registers every built-in family:

    landmark        — the paper's landmark-covering particle task
    windy           — LandmarkNav + constant wind drift and Gaussian gusts
    multilandmark   — nearest-of-L landmark covering (multi-modal loss)
    cliffwalk       — Sutton-Barto cliff walking (one-hot states, slip)
    lqr             — linear-quadratic regulation (continuous actions,
                      pairs with GaussianPolicy)
    tabular         — known-model finite MDPs (incl. the Garnet generator)
                      with exact J/gradients; P/l/rho batch as lanes
    hetero          — per-agent heterogeneous wrapper over any family

See ``registry.register_env`` to add families (packer/builder hooks make
continuous env parameters batch as sweep lanes, exactly like
``channel.register_channel``).
"""
from repro.rl.envs.gridworld import CliffWalk  # noqa: F401
from repro.rl.envs.heterogeneous import (  # noqa: F401
    HeterogeneousEnv, check_agent_count, make_heterogeneous_env,
)
from repro.rl.envs.lqr import LQRTask  # noqa: F401
from repro.rl.envs.particle import (  # noqa: F401
    MultiLandmarkNav, WindyLandmarkNav,
)
from repro.rl.envs.registry import (  # noqa: F401
    batched_env_arrays, build_lane_env, default_policy, env_kind,
    is_float_field, make_env, register_env, registered_envs, robust_eq,
    values_vary,
)
from repro.rl.envs.tabular import garnet  # noqa: F401
