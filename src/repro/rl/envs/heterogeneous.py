"""Per-agent heterogeneous environments for the federated loops.

The paper's agents all face the same MDP; the over-the-air FL literature
stresses exactly the opposite regime — per-client heterogeneity.
``HeterogeneousEnv`` carries a prototype env plus per-agent stacked values
for the fields that differ, and ``fedpg.make_round_fn`` /
``event_triggered.run`` vmap the agent axis over those stacks, so agent i
samples its trajectories from its OWN dynamics inside the same single
jitted program.

Mirrors ``power_control``'s per-agent contract: ``check_agent_count``
guards against running a wrapper built for N agents with a different
``FedPGConfig.n_agents`` (the vmap would silently mis-broadcast or crash
deep inside the scan otherwise).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.rl.envs.registry import default_policy as _default_policy
from repro.rl.envs.registry import (
    env_kind, is_float_field, register_env, robust_eq,
)


@dataclass(frozen=True)
class HeterogeneousEnv:
    """A fleet of same-family envs: ``base`` + per-agent field stacks.

    ``params[name]`` has a leading ``(n_agents,)`` axis; agent i runs
    ``dataclasses.replace(base, **{name: params[name][i]})``.  Fields not in
    ``params`` are shared (closed over as the base literals).  Build with
    :func:`make_heterogeneous_env`.
    """

    base: Any
    params: Dict[str, Any]
    n_agents: int

    def lane(self, lane_params: Dict[str, Any]) -> Any:
        """The member env for one agent's slice of the stacks (called under
        vmap, so values are traced scalars)."""
        return dataclasses.replace(self.base, **lane_params)

    def member(self, i: int) -> Any:
        """Concrete member env for agent ``i`` (inspection / per-scenario
        reference paths)."""
        return dataclasses.replace(
            self.base,
            **{k: (float(v[i]) if jnp.ndim(v[i]) == 0 else v[i])
               for k, v in self.params.items()},
        )

    def kind_tag(self) -> str:
        return f"hetero:{env_kind(self.base)}:{self.n_agents}"

    @property
    def obs_dim(self) -> int:
        return self.base.obs_dim  # one shared policy across the fleet

    def default_policy(self):
        return _default_policy(self.base)


def _is_array(v: Any) -> bool:
    return isinstance(v, (np.ndarray, jax.Array))


def make_heterogeneous_env(envs: Sequence[Any]) -> HeterogeneousEnv:
    """Stack a list of same-type envs (one per agent) into a wrapper.

    Declared-float fields that differ across members become per-agent
    stacks; fields that agree stay on the base prototype as shared literals
    (so a degenerate all-equal fleet runs the closest possible program to
    the plain env).  Array-valued fields (TabularMDP/Garnet P/l/rho tables)
    stack per agent whenever any member differs, so a fleet of Garnet draws
    gives every agent its own MDP.  Other fields must agree — they are
    structural.
    """
    if not envs:
        raise ValueError("empty env list")
    base = envs[0]
    types = {type(e) for e in envs}
    if len(types) != 1:
        raise ValueError(
            f"heterogeneous agents must share one env family, got "
            f"{sorted(t.__name__ for t in types)}"
        )
    params: Dict[str, Any] = {}
    for f in dataclasses.fields(base):
        vals = [getattr(e, f.name) for e in envs]
        if is_float_field(f):
            if any(float(v) != float(vals[0]) for v in vals):
                params[f.name] = jnp.asarray([float(v) for v in vals],
                                             jnp.float32)
        elif _is_array(vals[0]):
            if not all(np.array_equal(np.asarray(v), np.asarray(vals[0]))
                       for v in vals[1:]):
                params[f.name] = jnp.stack([jnp.asarray(v) for v in vals])
        elif any(v != vals[0] for v in vals[1:]):
            raise ValueError(
                f"non-float field {f.name!r} varies across agents; such "
                "fields are structural and cannot differ within one fleet"
            )
    return HeterogeneousEnv(base=base, params=params, n_agents=len(envs))


def check_agent_count(env: Any, n_agents: int) -> None:
    """Guard against a HeterogeneousEnv built for a different fleet size
    than the config runs with (mirrors ``power_control.check_agent_count``)."""
    if isinstance(env, HeterogeneousEnv) and env.n_agents != n_agents:
        raise ValueError(
            f"HeterogeneousEnv carries per-agent params for n_agents="
            f"{env.n_agents} but the scenario runs {n_agents} agents; "
            f"rebuild it with one member env per agent"
        )


def _pack_hetero(envs: Sequence[HeterogeneousEnv]) -> Dict[str, np.ndarray]:
    """Sweep packer: several same-shape fleets batch as lanes — each lane
    carries its own per-agent stacks (``pa.<field>`` of shape
    ``(lanes, n_agents, ...)``).  Fleets must stack the same fields and
    agree on every *non-stacked* base field (stacked fields are always
    overridden per agent, so their base values are irrelevant and are
    neutralised before the comparison)."""
    keys = {tuple(sorted(e.params)) for e in envs}
    if len(keys) != 1:
        raise ValueError(
            f"cannot batch HeterogeneousEnv fleets stacking different "
            f"fields {sorted(keys)}; stack the same per-agent fields in "
            "every fleet (constant members are fine)"
        )
    base = envs[0].base
    stacked = dict.fromkeys(envs[0].params)

    def neutral(e: HeterogeneousEnv) -> Any:
        # stacked fields never reach the program from the base — pin them
        # to fleet-0's values so only genuinely shared fields compare
        return dataclasses.replace(
            e.base, **{k: getattr(base, k) for k in stacked}
        )

    if not all(robust_eq(neutral(e), base) for e in envs[1:]):
        raise ValueError(
            "cannot batch HeterogeneousEnv fleets whose bases differ in a "
            "non-stacked field in one partition; for array-valued bases "
            "reuse one base instance across fleets"
        )
    return {
        f"pa.{k}": np.stack([np.asarray(e.params[k], np.float64)
                             for e in envs])
        for k in envs[0].params
    }


def _build_hetero(kind: str, proto: HeterogeneousEnv, params: Dict[str, Any]):
    del kind
    return HeterogeneousEnv(
        base=proto.base,
        params={k[len("pa."):]: v for k, v in params.items()},
        n_agents=proto.n_agents,
    )


register_env("hetero", HeterogeneousEnv, packer=_pack_hetero,
             builder=_build_hetero)
