"""Environment registry: ``env`` as a first-class, batchable sweep axis.

Mirrors ``repro.core.channel``'s registry contract exactly:

* ``register_env(name, cls, packer=..., builder=...)`` adds an environment
  family; ``env_kind`` reverse-looks-up the structural kind tag (classes may
  refine theirs via a ``kind_tag()`` method, e.g.
  ``CliffWalk -> 'cliffwalk:6x4'``), and ``make_env(name, **kw)`` is the
  string factory.
* ``batched_env_arrays(envs)`` stacks a same-kind env list into per-parameter
  float64 arrays for the sweep engine.  The default packer stacks every
  *float* dataclass field (matching ``batched_channel_arrays``: all fields of
  the varying dataclass travel as lane parameters) and requires non-float
  fields — grid sizes, action counts — to agree, since those are structural
  and belong in the kind tag.  Families with array-valued parameters
  (``TabularMDP``) register a custom ``packer``.
* ``build_lane_env(kind, proto, params)`` reconstructs a lane's environment
  from traced scalar parameters.  The default builder is
  ``dataclasses.replace(proto, **params)`` — the concrete frozen dataclasses
  hold tracers fine, and because the lane env runs the *same methods* as the
  concrete instance (same ops, same PRNG layout), rollouts are bit-identical
  to the per-scenario path at equal parameter values.

``default_policy(env)`` dispatches to the env's ``default_policy()`` hook so
a scenario that only names an environment still resolves to a compatible
policy (obs dim / action space follow the env family).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Sequence, Tuple

import numpy as np

from repro.rl.env import LandmarkNav, TabularMDP

_REGISTRY: Dict[str, type] = {}
_PACKERS: Dict[str, Callable[..., Dict[str, np.ndarray]]] = {}
_BUILDERS: Dict[str, Callable[..., Any]] = {}


def register_env(
    name: str,
    cls: type,
    *,
    packer: Callable[..., Dict[str, np.ndarray]] | None = None,
    builder: Callable[..., Any] | None = None,
) -> None:
    """Add an environment family to the registry (and the sweep engine).

    ``packer``/``builder`` are only needed when the dataclass fields are not
    all plain floats; a class may also define ``kind_tag()`` returning a
    refined structural tag (``'<name>:<...>'``) so structurally incompatible
    members of the family land in separate sweep partitions.  Hooks are
    keyed by the *root* of the kind tag (the part before the first ':').
    """
    _REGISTRY[name] = cls
    if packer is not None:
        _PACKERS[name] = packer
    if builder is not None:
        _BUILDERS[name] = builder


def registered_envs() -> Dict[str, type]:
    """Snapshot of the registry: family name -> class.  The contract
    checker (``repro.analyze.contracts.check_lane_contract``) iterates this
    so every registered family — including ones added after this module
    shipped — gets its pack-only-varying invariant verified."""
    return dict(_REGISTRY)


def env_kind(env: Any) -> str:
    """Reverse registry lookup: LandmarkNav() -> 'landmark'.

    Registered classes may refine their tag via ``kind_tag()`` (e.g.
    ``CliffWalk() -> 'cliffwalk:6x4'``) so partitioning distinguishes
    structurally different members of one family.
    """
    for name, cls in _REGISTRY.items():
        if type(env) is cls:
            tag = getattr(env, "kind_tag", None)
            return tag() if callable(tag) else name
    raise ValueError(f"environment {type(env).__name__} is not in the registry")


def make_env(name: str, **kwargs) -> Any:
    """Factory: make_env('landmark'), make_env('cliffwalk', width=5)."""
    try:
        return _REGISTRY[name](**kwargs)
    except KeyError as e:
        raise ValueError(
            f"unknown environment {name!r}; choose from {sorted(_REGISTRY)}"
        ) from e


def default_policy(env: Any):
    """A policy compatible with ``env`` (the env's ``default_policy`` hook)."""
    hook = getattr(env, "default_policy", None)
    if callable(hook):
        return hook()
    raise ValueError(
        f"environment {type(env).__name__} exposes no default_policy(); "
        "pass an explicit policy (Scenario.policy or sweep(..., policy=...))"
    )


def is_float_field(f: dataclasses.Field) -> bool:
    """Whether a dataclass field is *declared* float (continuous parameter).

    The declaration, not the runtime value, is the schema: ``wind=0`` (an
    int literal in a ``wind: float`` field) is still a lane parameter, while
    ``width: int = 5`` is structural whatever its value.  Annotations may be
    strings under ``from __future__ import annotations``.
    """
    return f.type is float or f.type == "float"


def values_vary(vals: Sequence[Any]) -> bool:
    """Robust inequality over field values: falls back to identity for
    unhashable values (dicts, envs carrying arrays) — so reuse ONE instance
    when a value must read as partition-constant.  Shared by the sweep
    engine's ``Partition.varying``."""
    try:
        return len(set(vals)) > 1
    except TypeError:
        return any(v is not vals[0] for v in vals[1:])


def robust_eq(a: Any, b: Any) -> bool:
    """``a == b`` that treats ambiguous comparisons (array-valued dataclass
    fields) as unequal instead of raising.  Shared by ``SweepResult.index``
    and the heterogeneous-fleet base check."""
    if a is b:
        return True
    try:
        return bool(a == b)
    except (TypeError, ValueError):
        return False


def batched_env_arrays(envs: Sequence[Any]) -> Tuple[str, Dict[str, np.ndarray]]:
    """Stack a same-kind env list into per-parameter float64 arrays.

    Returns ``(kind, params)`` where each ``params[name]`` has a leading
    ``len(envs)`` axis.  The default packer stacks every declared-float
    dataclass field; other fields must not vary (they are structural —
    refine the family's ``kind_tag()`` instead).  Families registered with
    a ``packer`` (array-valued parameters) stack through their hook.
    """
    kinds = {env_kind(e) for e in envs}
    if len(kinds) != 1:
        raise ValueError(f"cannot batch across env kinds {sorted(kinds)}")
    kind = kinds.pop()
    root = kind.split(":", 1)[0]
    if root in _PACKERS:
        return kind, _PACKERS[root](envs)
    params: Dict[str, np.ndarray] = {}
    for f in dataclasses.fields(envs[0]):
        vals = [getattr(e, f.name) for e in envs]
        if is_float_field(f):
            # only *varying* fields become lane parameters: constant fields
            # stay closed over as the same Python literals the per-scenario
            # program folds in (the engine's exactness contract)
            if any(float(v) != float(vals[0]) for v in vals[1:]):
                params[f.name] = np.array([float(v) for v in vals], np.float64)
        elif values_vary(vals):
            raise ValueError(
                f"env kind {kind!r} varies non-float field {f.name!r} inside "
                "one sweep partition; such fields are structural and must be "
                "encoded in the family's kind_tag()"
            )
    return kind, params


def build_lane_env(kind: str, proto: Any, params: Dict[str, Any]) -> Any:
    """Reconstruct a lane environment from one slice of the packed arrays.

    ``proto`` is the partition's prototype env (carries every structural /
    constant field); ``params`` holds the lane's traced parameter scalars.
    The default builder replaces the packed fields on the prototype — the
    frozen dataclasses hold tracers fine, and their methods then run the
    identical ops the concrete instance would.
    """
    root = kind.split(":", 1)[0]
    if root in _BUILDERS:
        return _BUILDERS[root](kind, proto, params)
    return dataclasses.replace(proto, **params)


# The seed environments are first-class registry citizens; ``TabularMDP``
# gets its array packer/builder in ``repro.rl.envs.tabular``.
register_env("landmark", LandmarkNav)
