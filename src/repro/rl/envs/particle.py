"""Particle-family environments beyond the paper's landmark task.

``WindyLandmarkNav`` perturbs the paper's dynamics with a constant wind
drift plus Gaussian gusts — the smallest change that makes the transition
kernel stochastic (the paper's task is deterministic given the action), and
the canonical per-agent heterogeneity knob: a ``HeterogeneousEnv`` over
per-agent winds models a fleet of drones in different air columns.

``MultiLandmarkNav`` generalises the loss to the nearest of L landmarks,
so the reward landscape is multi-modal and the policy must commit to a
target.  Both keep the paper's 5-action discrete control and are pure
``lax.scan``-compatible functions of (key, state, action).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.rl.env import LandmarkNav
from repro.rl.envs.registry import register_env


@dataclass(frozen=True)
class WindyLandmarkNav(LandmarkNav):
    """LandmarkNav with stochastic drift: pos += move + wind + gust.

    ``wind`` is a constant +x drift per step; ``gust_sigma`` scales an
    isotropic Gaussian perturbation.  With ``wind=0, gust_sigma=0`` the
    dynamics reduce bit-for-bit to ``LandmarkNav`` (the gust draw is still
    consumed, keeping the PRNG layout self-consistent but distinct from the
    base class, which never splits its step key).
    """

    wind: float = 0.05
    gust_sigma: float = 0.02

    def step(
        self, key: jax.Array, state: jax.Array, action: jax.Array
    ) -> Tuple[jax.Array, jax.Array]:
        gust = self.gust_sigma * jax.random.normal(key, (2,), jnp.float32)
        drift = jnp.stack(
            [jnp.asarray(self.wind, jnp.float32), jnp.zeros((), jnp.float32)]
        )
        pos = state[:2] + self.moves[action] + drift + gust
        nxt = jnp.concatenate([pos, state[2:]])
        return nxt, self.loss(nxt)

    def l_bar_for(self, horizon: int) -> float:
        """Envelope accounting for the drift; the Gaussian gusts are
        unbounded, so this is the 3-sigma high-probability envelope (noted
        caveat to Assumption 1 — exact for ``gust_sigma=0``)."""
        per_step = self.step_size + abs(self.wind) + 3.0 * self.gust_sigma
        reach = self.arena + per_step * horizon
        return float(2.0 * reach * math.sqrt(2.0))


@dataclass(frozen=True)
class MultiLandmarkNav:
    """Nearest-of-L landmark covering: l(s) = min_j ||pos - landmark_j||.

    state = (x, y, x_1, y_1, ..., x_L, y_L); same 5 discrete actions as
    ``LandmarkNav``.  ``n_landmarks`` changes the observation size and is
    therefore structural (encoded in the kind tag); ``arena``/``step_size``
    batch as sweep lanes.
    """

    n_landmarks: int = 3
    arena: float = 1.0
    step_size: float = 0.1
    n_actions: int = 5

    @property
    def obs_dim(self) -> int:
        return 2 + 2 * self.n_landmarks

    def kind_tag(self) -> str:
        return f"multilandmark:{self.n_landmarks}"

    @property
    def moves(self) -> jnp.ndarray:
        return jnp.array(
            [[0.0, 0.0], [-1.0, 0.0], [1.0, 0.0], [0.0, 1.0], [0.0, -1.0]],
            jnp.float32,
        ) * self.step_size

    def reset(self, key: jax.Array) -> jax.Array:
        return jax.random.uniform(
            key, (self.obs_dim,), jnp.float32,
            minval=-self.arena, maxval=self.arena,
        )

    def step(
        self, key: jax.Array, state: jax.Array, action: jax.Array
    ) -> Tuple[jax.Array, jax.Array]:
        del key  # deterministic dynamics
        pos = state[:2] + self.moves[action]
        nxt = jnp.concatenate([pos, state[2:]])
        return nxt, self.loss(nxt)

    def loss(self, state: jax.Array) -> jax.Array:
        marks = state[2:].reshape(self.n_landmarks, 2)
        d = marks - state[:2]
        return jnp.sqrt(jnp.min(jnp.sum(d * d, axis=-1)) + 1e-12)

    def l_bar_for(self, horizon: int) -> float:
        reach = self.arena + self.step_size * horizon
        return float(2.0 * reach * math.sqrt(2.0))

    def default_policy(self):
        from repro.rl.policy import MLPPolicy

        return MLPPolicy(obs_dim=self.obs_dim, hidden=16,
                         n_actions=self.n_actions)


register_env("windy", WindyLandmarkNav)
register_env("multilandmark", MultiLandmarkNav)
