"""Linear-quadratic regulation as a policy-gradient benchmark.

Dynamics  s' = A s + gain * a + process_sigma * w,  w ~ N(0, I)
Loss      l(s, a) = q_cost * ||s||^2 + r_cost * ||a||^2

with A = drift * I + coupling * (rotation couple): a stable (for
``hypot(drift, coupling) < 1``) linear system whose optimal policy is a
linear state feedback — exactly what ``GaussianPolicy`` parameterises, so
continuous actions exercise the whole federated G(PO)MDP path (which only
needs ``log_prob``/``sample``) with a task whose optimum is analytically
understood.

All four scalars (``drift``, ``coupling``, ``gain``, ``process_sigma``,
plus the two costs) are continuous sweep-lane parameters; ``dim`` changes
the trace shape and is structural (kind tag ``lqr:<dim>``).

Note: the quadratic loss is unbounded, so Assumption 1 (and the Theorem 1/2
tables) do not apply to this family — it is a simulation-only workload.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.rl.envs.registry import register_env


@dataclass(frozen=True)
class LQRTask:
    """d-dimensional LQR with isotropic process noise."""

    dim: int = 2
    drift: float = 0.9
    coupling: float = 0.1
    gain: float = 0.5
    process_sigma: float = 0.05
    q_cost: float = 1.0
    r_cost: float = 0.1
    init_scale: float = 1.0

    @property
    def obs_dim(self) -> int:
        return self.dim

    @property
    def act_dim(self) -> int:
        return self.dim

    def kind_tag(self) -> str:
        return f"lqr:{self.dim}"

    def _A(self) -> jnp.ndarray:
        d = self.dim
        eye = jnp.eye(d, dtype=jnp.float32)
        skew = jnp.eye(d, k=1, dtype=jnp.float32) - jnp.eye(d, k=-1, dtype=jnp.float32)
        return self.drift * eye + self.coupling * skew

    def reset(self, key: jax.Array) -> jax.Array:
        return self.init_scale * jax.random.normal(key, (self.dim,), jnp.float32)

    def step(
        self, key: jax.Array, state: jax.Array, action: jax.Array
    ) -> Tuple[jax.Array, jax.Array]:
        w = jax.random.normal(key, (self.dim,), jnp.float32)
        nxt = self._A() @ state + self.gain * action + self.process_sigma * w
        loss = self.q_cost * jnp.sum(state * state) + self.r_cost * jnp.sum(
            action * action
        )
        return nxt, loss

    def default_policy(self):
        from repro.rl.policy import GaussianPolicy

        return GaussianPolicy(obs_dim=self.dim, act_dim=self.act_dim)


register_env("lqr", LQRTask)
