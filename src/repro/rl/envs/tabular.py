"""Garnet-style tabular MDP generation + registry packing for TabularMDP.

Garnet ("Generalized Average Reward Non-stationary Environment Testbench",
Archibald et al.) MDPs are the standard random-MDP family for anchoring
estimators against exact quantities: every (s, a) pair transitions to a
small random subset of ``branching`` next states with Dirichlet weights, so
the kernel is sparse but fully known — ``TabularMDP.exact_J`` (and its
autodiff gradient) remain available for unbiasedness tests at any size.

``TabularMDP`` is registered here with array-valued packer/builder hooks:
same-shaped instances (the ``tabular:SxA`` kind tag) batch their P/l/rho
tables as sweep lanes, so a grid over Garnet draws compiles ONE program.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.rl.env import TabularMDP
from repro.rl.envs.registry import register_env


def garnet(
    key: jax.Array,
    n_states: int = 8,
    n_actions: int = 4,
    branching: int = 3,
    gamma: float = 0.9,
    horizon: int = 5,
) -> TabularMDP:
    """Sample a Garnet MDP: each (s, a) reaches ``branching`` distinct next
    states with Dirichlet(1) weights; losses uniform in [0, 1]."""
    if not 1 <= branching <= n_states:
        raise ValueError(
            f"branching must be in [1, n_states={n_states}], got {branching}"
        )
    kp, kl, kr = jax.random.split(key, 3)

    def one_row(k: jax.Array) -> jax.Array:
        k_idx, k_w = jax.random.split(k)
        idx = jax.random.choice(k_idx, n_states, (branching,), replace=False)
        w = jax.random.dirichlet(k_w, jnp.ones((branching,), jnp.float32))
        return jnp.zeros((n_states,), jnp.float32).at[idx].add(w)

    rows = jax.vmap(one_row)(jax.random.split(kp, n_states * n_actions))
    P = rows.reshape(n_states, n_actions, n_states)
    loss = jax.random.uniform(kl, (n_states, n_actions), jnp.float32)
    rho = jax.random.dirichlet(kr, jnp.ones((n_states,), jnp.float32))
    return TabularMDP(P=P, l=loss, rho=rho, gamma=gamma, horizon=horizon)


def _pack_tabular(envs: Sequence[TabularMDP]) -> Dict[str, np.ndarray]:
    """Stack the P/l/rho tables (same (S, A) shape — guaranteed by the kind
    tag) into arrays with a leading lane axis.  ``gamma``/``horizon`` are
    run metadata (rollouts use ``FedPGConfig``'s), not lane parameters."""
    return {
        "P": np.stack([np.asarray(e.P, np.float64) for e in envs]),
        "l": np.stack([np.asarray(e.l, np.float64) for e in envs]),
        "rho": np.stack([np.asarray(e.rho, np.float64) for e in envs]),
    }


def _build_tabular(kind: str, proto: TabularMDP, params: Dict[str, Any]):
    del kind
    return dataclasses.replace(
        proto, P=params["P"], l=params["l"], rho=params["rho"]
    )


register_env("tabular", TabularMDP, packer=_pack_tabular, builder=_build_tabular)
