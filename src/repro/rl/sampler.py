"""Batched trajectory sampling with ``lax.scan``.

``rollout`` samples one trajectory of T+1 action steps (the paper's objective
sums t = 0..T); ``rollout_batch`` vmaps it over a trajectory batch, and the
federated loops vmap once more over agents, giving fully-jitted
(agents x batch x time) sampling with independent PRNG streams.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class Trajectory(NamedTuple):
    """One rollout: arrays are time-major (T+1, ...)."""

    obs: jax.Array      # (T+1, obs_dim) — state the action was taken in
    actions: jax.Array  # (T+1,) discrete; (T+1, act_dim) continuous policies
    losses: jax.Array   # (T+1,)  l(s_t, a_t)

    @property
    def horizon(self) -> int:
        return self.obs.shape[-2] - 1


def rollout(env, policy, params: PyTree, key: jax.Array, horizon: int) -> Trajectory:
    """Sample s_0 ~ rho, then T+1 policy steps (t = 0..T inclusive)."""
    key_reset, key_scan = jax.random.split(key)
    s0 = env.reset(key_reset)

    def body(carry, key_t):
        state = carry
        key_a, key_s = jax.random.split(key_t)
        action = policy.sample(params, key_a, state)
        nxt, loss = env.step(key_s, state, action)
        return nxt, (state, action, loss)

    keys = jax.random.split(key_scan, horizon + 1)
    _, (obs, actions, losses) = jax.lax.scan(body, s0, keys)
    return Trajectory(obs=obs, actions=actions, losses=losses)


def rollout_batch(
    env, policy, params: PyTree, key: jax.Array, horizon: int, batch: int
) -> Trajectory:
    """(batch,) independent trajectories; arrays gain a leading batch dim."""
    keys = jax.random.split(key, batch)
    return jax.vmap(lambda k: rollout(env, policy, params, k, horizon))(keys)


def discounted_return(losses: jax.Array, gamma: float) -> jax.Array:
    """sum_t gamma^t l_t along the last axis."""
    t = jnp.arange(losses.shape[-1], dtype=jnp.float32)
    return jnp.sum(losses * gamma**t, axis=-1)


def empirical_reward(traj: Trajectory, gamma: float) -> jax.Array:
    """The paper's 'empirical cumulative reward' = -discounted loss, averaged
    over the batch dims."""
    return -jnp.mean(discounted_return(traj.losses, gamma))
