"""JAX-native RL substrate: environments, policies, trajectory sampling,
and the environment zoo/registry (``repro.rl.envs``)."""
from repro.rl import env, envs, policy, sampler  # noqa: F401
