"""JAX-native RL substrate: environments, policies, trajectory sampling."""
from repro.rl import env, policy, sampler  # noqa: F401
