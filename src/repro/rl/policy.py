"""Policy parameterisations.

``MLPPolicy`` is the paper's target policy (Section IV): a two-layer network,
16 hidden ReLU units, softmax output over the discrete action set.
``TabularSoftmaxPolicy`` (theta[s, a] logits) pairs with ``TabularMDP`` for
exact-gradient tests.

All policies expose the same pure-function interface over a params pytree:
    init(key)               -> params
    logits(params, obs)     -> (n_actions,)
    log_prob(params, obs, a)-> scalar
    sample(params, key, obs)-> action
    action_probs(params)    -> (S, A)        [tabular only]
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class MLPPolicy:
    obs_dim: int = 4
    hidden: int = 16
    n_actions: int = 5

    def init(self, key: jax.Array) -> Dict[str, jax.Array]:
        k1, k2 = jax.random.split(key)
        scale1 = 1.0 / jnp.sqrt(self.obs_dim)
        scale2 = 1.0 / jnp.sqrt(self.hidden)
        return {
            "w1": jax.random.normal(k1, (self.obs_dim, self.hidden), jnp.float32)
            * scale1,
            "b1": jnp.zeros((self.hidden,), jnp.float32),
            "w2": jax.random.normal(k2, (self.hidden, self.n_actions), jnp.float32)
            * scale2,
            "b2": jnp.zeros((self.n_actions,), jnp.float32),
        }

    def logits(self, params: PyTree, obs: jax.Array) -> jax.Array:
        h = jax.nn.relu(obs @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]

    def log_prob(self, params: PyTree, obs: jax.Array, action: jax.Array) -> jax.Array:
        logp = jax.nn.log_softmax(self.logits(params, obs))
        return logp[action]

    def sample(self, params: PyTree, key: jax.Array, obs: jax.Array) -> jax.Array:
        return jax.random.categorical(key, self.logits(params, obs))

    def entropy(self, params: PyTree, obs: jax.Array) -> jax.Array:
        logp = jax.nn.log_softmax(self.logits(params, obs))
        return -jnp.sum(jnp.exp(logp) * logp)


@dataclass(frozen=True)
class TabularSoftmaxPolicy:
    n_states: int
    n_actions: int

    def init(self, key: jax.Array) -> Dict[str, jax.Array]:
        return {
            "theta": 0.1
            * jax.random.normal(key, (self.n_states, self.n_actions), jnp.float32)
        }

    def logits(self, params: PyTree, obs: jax.Array) -> jax.Array:
        # obs is one-hot over states
        return obs @ params["theta"]

    def log_prob(self, params: PyTree, obs: jax.Array, action: jax.Array) -> jax.Array:
        logp = jax.nn.log_softmax(self.logits(params, obs))
        return logp[action]

    def sample(self, params: PyTree, key: jax.Array, obs: jax.Array) -> jax.Array:
        return jax.random.categorical(key, self.logits(params, obs))

    def action_probs(self, params: PyTree) -> jax.Array:
        """(S, A) table — feeds TabularMDP.exact_J for exact gradients."""
        return jax.nn.softmax(params["theta"], axis=-1)
