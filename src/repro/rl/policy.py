"""Policy parameterisations.

``MLPPolicy`` is the paper's target policy (Section IV): a two-layer network,
16 hidden ReLU units, softmax output over the discrete action set.
``TabularSoftmaxPolicy`` (theta[s, a] logits) pairs with ``TabularMDP`` for
exact-gradient tests.  ``GaussianPolicy`` (linear mean, learnable diagonal
log-std) opens continuous action spaces — the G(PO)MDP/REINFORCE path only
needs ``log_prob`` and ``sample``, so LQR-style tasks ride the same
estimators and federated loops unchanged.

All policies expose the same pure-function interface over a params pytree:
    init(key)               -> params
    log_prob(params, obs, a)-> scalar
    sample(params, key, obs)-> action        (int for discrete, vector else)
    entropy(params, obs)    -> scalar
    logits(params, obs)     -> (n_actions,)  [discrete only]
    action_probs(params)    -> (S, A)        [tabular only]
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class MLPPolicy:
    obs_dim: int = 4
    hidden: int = 16
    n_actions: int = 5

    def init(self, key: jax.Array) -> Dict[str, jax.Array]:
        k1, k2 = jax.random.split(key)
        scale1 = 1.0 / jnp.sqrt(self.obs_dim)
        scale2 = 1.0 / jnp.sqrt(self.hidden)
        return {
            "w1": jax.random.normal(k1, (self.obs_dim, self.hidden), jnp.float32)
            * scale1,
            "b1": jnp.zeros((self.hidden,), jnp.float32),
            "w2": jax.random.normal(k2, (self.hidden, self.n_actions), jnp.float32)
            * scale2,
            "b2": jnp.zeros((self.n_actions,), jnp.float32),
        }

    def logits(self, params: PyTree, obs: jax.Array) -> jax.Array:
        h = jax.nn.relu(obs @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]

    def log_prob(self, params: PyTree, obs: jax.Array, action: jax.Array) -> jax.Array:
        logp = jax.nn.log_softmax(self.logits(params, obs))
        return logp[action]

    def sample(self, params: PyTree, key: jax.Array, obs: jax.Array) -> jax.Array:
        return jax.random.categorical(key, self.logits(params, obs))

    def entropy(self, params: PyTree, obs: jax.Array) -> jax.Array:
        logp = jax.nn.log_softmax(self.logits(params, obs))
        return -jnp.sum(jnp.exp(logp) * logp)


@dataclass(frozen=True)
class TabularSoftmaxPolicy:
    n_states: int
    n_actions: int

    def init(self, key: jax.Array) -> Dict[str, jax.Array]:
        return {
            "theta": 0.1
            * jax.random.normal(key, (self.n_states, self.n_actions), jnp.float32)
        }

    def logits(self, params: PyTree, obs: jax.Array) -> jax.Array:
        # obs is one-hot over states
        return obs @ params["theta"]

    def log_prob(self, params: PyTree, obs: jax.Array, action: jax.Array) -> jax.Array:
        logp = jax.nn.log_softmax(self.logits(params, obs))
        return logp[action]

    def sample(self, params: PyTree, key: jax.Array, obs: jax.Array) -> jax.Array:
        return jax.random.categorical(key, self.logits(params, obs))

    def action_probs(self, params: PyTree) -> jax.Array:
        """(S, A) table — feeds TabularMDP.exact_J for exact gradients."""
        return jax.nn.softmax(params["theta"], axis=-1)


@dataclass(frozen=True)
class GaussianPolicy:
    """Diagonal Gaussian over continuous actions: a ~ N(W obs + b, e^{2s}).

    The mean is linear in the observation and the per-dimension log-std
    ``s`` is a learnable parameter vector, so the policy covers the LQR
    setting (linear state feedback + exploration noise) while staying a
    plain params-pytree pure-function policy.
    """

    obs_dim: int = 2
    act_dim: int = 2
    init_scale: float = 0.1

    def init(self, key: jax.Array) -> Dict[str, jax.Array]:
        return {
            "w": self.init_scale
            * jax.random.normal(key, (self.obs_dim, self.act_dim), jnp.float32)
            / jnp.sqrt(float(self.obs_dim)),
            "b": jnp.zeros((self.act_dim,), jnp.float32),
            "log_std": jnp.zeros((self.act_dim,), jnp.float32),
        }

    def mean(self, params: PyTree, obs: jax.Array) -> jax.Array:
        return obs @ params["w"] + params["b"]

    def log_prob(self, params: PyTree, obs: jax.Array, action: jax.Array) -> jax.Array:
        mu, log_std = self.mean(params, obs), params["log_std"]
        z = (action - mu) * jnp.exp(-log_std)
        return (
            -0.5 * jnp.sum(z * z)
            - jnp.sum(log_std)
            - 0.5 * self.act_dim * math.log(2.0 * math.pi)
        )

    def sample(self, params: PyTree, key: jax.Array, obs: jax.Array) -> jax.Array:
        eps = jax.random.normal(key, (self.act_dim,), jnp.float32)
        return self.mean(params, obs) + jnp.exp(params["log_std"]) * eps

    def entropy(self, params: PyTree, obs: jax.Array) -> jax.Array:
        """Closed form: sum(log_std) + (d/2)(1 + log 2 pi); obs-independent
        (kept in the signature for interface parity)."""
        del obs
        return jnp.sum(params["log_std"]) + 0.5 * self.act_dim * (
            1.0 + math.log(2.0 * math.pi)
        )
