"""Pallas TPU kernel for the paper's server-side OTA update (Eq. 6-7).

Every training step applies  u = (v + sigma*n) / (N * m_h)  over every
gradient element — a memory-bound elementwise pass over up to tens of GB.
Fusing the AWGN generation (threefry counter bits -> Box-Muller) with the
scale keeps it to ONE HBM read + ONE write per element; materialising the
noise tensor first (the naive jnp path) costs two extra HBM round-trips, so
the roofline win is ~3x on the aggregation step.

Layout: gradients are flattened and padded to (rows, 128) lanes; grid over
row blocks, each tile (block_rows, 128) resident in VMEM.  Noise bits come
from a counter-based integer-mix PRNG keyed on (seed, absolute element
index): bitwise deterministic for a given seed regardless of grid/block
size and portable between the TPU backend and interpret mode (the
``pltpu.prng_random_bits`` hardware path has no CPU interpret rule).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams as _CompilerParams

LANES = 128


def _mix(x: jax.Array, salt: jax.Array) -> jax.Array:
    """One murmur3-finalizer round over uint32 counters (statistically ample
    for AWGN; two independent streams come from different salts)."""
    x = x ^ salt
    x = (x ^ (x >> 16)) * jnp.uint32(0x85EBCA6B)
    x = (x ^ (x >> 13)) * jnp.uint32(0xC2B2AE35)
    return x ^ (x >> 16)


def _kernel(v_ref, o_ref, *, sigma: float, scale: float, seed: int,
            block_rows: int):
    i = pl.program_id(0)
    v = v_ref[...].astype(jnp.float32)
    if sigma > 0.0:
        shape = v.shape
        # absolute element counter (row-major within the full padded buffer)
        row = jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
        lane = jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
        counter = (jnp.uint32(i * block_rows) + row) * jnp.uint32(LANES) + lane
        base = _mix(counter, jnp.uint32(seed) * jnp.uint32(0x9E3779B9))
        u1 = _mix(base, jnp.uint32(0xA511E9B3))
        u2 = _mix(base, jnp.uint32(0x63D83595))
        # uniform in (0, 1]: (bits >> 8) * 2^-24, offset by 2^-25 to avoid 0
        f1 = (u1 >> 8).astype(jnp.float32) * (1.0 / (1 << 24)) + (1.0 / (1 << 25))
        f2 = (u2 >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
        # Box-Muller
        r = jnp.sqrt(-2.0 * jnp.log(f1))
        n = r * jnp.cos(2.0 * jnp.pi * f2)
        v = v + sigma * n
    o_ref[...] = (v * scale).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("sigma", "n_agents", "m_h", "debias", "seed",
                     "block_rows", "interpret"),
)
def ota_channel_apply(
    v: jax.Array,
    *,
    sigma: float,
    n_agents: int,
    m_h: float = 1.0,
    debias: bool = True,
    seed: int = 0,
    block_rows: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Fused (v + sigma*AWGN) / (N*m_h) over an arbitrary-shape tensor."""
    scale = 1.0 / (n_agents * (m_h if debias else 1.0))
    shape = v.shape
    flat = v.reshape(-1)
    n = flat.shape[0]
    per_block = block_rows * LANES
    n_pad = -n % per_block
    flat = jnp.pad(flat, (0, n_pad))
    rows = flat.shape[0] // LANES
    grid = rows // block_rows
    tiled = flat.reshape(rows, LANES)

    out = pl.pallas_call(
        functools.partial(_kernel, sigma=sigma, scale=scale, seed=seed,
                          block_rows=block_rows),
        grid=(grid,),
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), v.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(tiled)
    return out.reshape(-1)[:n].reshape(shape)
