"""Pallas-TPU version compatibility helpers shared by the kernel modules.

jax<0.5 ships the TPU compiler-params class as ``TPUCompilerParams``; newer
releases renamed it to ``CompilerParams``.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
