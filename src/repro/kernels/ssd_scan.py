"""Pallas TPU kernel for the Mamba2 SSD chunked scan [arXiv:2405.21060].

TPU adaptation of the SSD algorithm (DESIGN.md §6): the chunk dimension is
the *sequential* grid axis; the (state_dim x head_dim) running state lives in
VMEM scratch across chunk steps, and each chunk does three MXU matmuls —

    scores  = C_c B_c^T                    (Q x Q, the "duality" matmul)
    y_intra = (scores . decay_mask) X_c    (Q x P)
    y_inter = C_c S_prev . exp(cum)        (Q x P)
    S_new   = chunk_decay S_prev + (B_c . decay_to_end)^T X_c   (N x P)

Grid = (batch*heads, n_chunks); chunk length Q defaults to 128 (MXU-aligned).
Inputs are pre-scaled outside the kernel (dax = x*dt, da = dt*A): those are
cheap elementwise ops that XLA fuses into the producers, keeping the kernel's
working set to 4 tiles + scratch.

B/C are shared within a head group (G groups): the ops wrapper passes
per-head views via the BlockSpec index_map (head -> group), so no
materialised broadcast.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams as _CompilerParams


def _kernel(dax_ref, da_ref, b_ref, c_ref, y_ref, state_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    dax = dax_ref[...].astype(jnp.float32)          # (Q, P)
    da = da_ref[...].astype(jnp.float32)            # (Q, 1)
    B = b_ref[...].astype(jnp.float32)              # (Q, N)
    C = c_ref[...].astype(jnp.float32)              # (Q, N)

    cum = jnp.cumsum(da, axis=0)                    # (Q, 1)
    last = cum[chunk - 1, 0]

    # intra-chunk: (C B^T . decay_mask) @ dax
    scores = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (Q, Q)
    seg = cum - cum.T                               # seg[q,k] = cum[q]-cum[k]
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(ki <= qi, jnp.exp(seg), 0.0)
    y = jax.lax.dot_general(
        scores * decay, dax, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                               # (Q, P)

    # inter-chunk: contribution of the carried state
    s_prev = state_scr[...]                         # (N, P)
    y += jax.lax.dot_general(
        C * jnp.exp(cum), s_prev, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # state update: S_new = e^{sum da} S_prev + (B . e^{last-cum})^T dax
    decay_to_end = jnp.exp(last - cum)              # (Q, 1)
    state_scr[...] = jnp.exp(last) * s_prev + jax.lax.dot_general(
        B * decay_to_end, dax, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    y_ref[...] = y.astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret")
)
def ssd_scan(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H) — post-softplus
    A: jax.Array,      # (H,) — negative
    B: jax.Array,      # (B, S, G, N)
    C: jax.Array,      # (B, S, G, N)
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    hg = h // g

    f32 = jnp.float32
    dax = (x.astype(f32) * dt.astype(f32)[..., None])
    da = dt.astype(f32) * A.astype(f32)[None, None, :]

    # layout: (B*H, S, *) with heads-major flattening
    dax_f = jnp.moveaxis(dax, 2, 1).reshape(b * h, s, p)
    da_f = jnp.moveaxis(da, 2, 1).reshape(b * h, s, 1)
    b_f = jnp.moveaxis(B.astype(f32), 2, 1).reshape(b * g, s, n)
    c_f = jnp.moveaxis(C.astype(f32), 2, 1).reshape(b * g, s, n)

    def x_map(bh, ci):
        return (bh, ci, 0)

    def bc_map(bh, ci):
        # head -> its B/C group
        bi = bh // h
        hi = bh % h
        return (bi * g + hi // hg, ci, 0)

    y = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, p), x_map),
            pl.BlockSpec((None, chunk, 1), x_map),
            pl.BlockSpec((None, chunk, n), bc_map),
            pl.BlockSpec((None, chunk, n), bc_map),
        ],
        out_specs=pl.BlockSpec((None, chunk, p), x_map),
        out_shape=jax.ShapeDtypeStruct((b * h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(dax_f, da_f, b_f, c_f)
    return jnp.moveaxis(y.reshape(b, h, s, p), 1, 2)
