"""jit'd dispatch layer over the Pallas kernels.

``use_pallas=True`` targets the TPU kernels (interpret=False); the default
``interpret=True`` executes the same kernel bodies in Python on CPU for
correctness work, and ``use_pallas=False`` falls back to the jnp reference
path (used inside dry-run lowering, where Pallas TPU lowering is unavailable
on the CPU backend).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import ota_channel as _ota
from repro.kernels import ota_fused as _fused
from repro.kernels import ref as _ref
from repro.kernels import ssd_scan as _ssd


def attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    use_pallas: bool = True,
    interpret: bool = True,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """(B, H, S, Dh) attention; GQA via Hkv < H."""
    if use_pallas:
        return _fa.flash_attention(
            q, k, v, causal=causal, window=window,
            block_q=block_q, block_k=block_k, interpret=interpret,
        )
    return _ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def ssd(
    x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array, C: jax.Array,
    *,
    chunk: int = 128,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """(B, S, H, P) Mamba2 SSD scan."""
    if use_pallas:
        return _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)
    return _ref.ssd_ref(x, dt, A, B, C, chunk)


def ota_update(
    v: jax.Array,
    *,
    sigma: float,
    n_agents: int,
    m_h: float = 1.0,
    debias: bool = True,
    seed: int = 0,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """The paper's fused server update (v + sigma*n) / (N * m_h)."""
    if use_pallas:
        return _ota.ota_channel_apply(
            v, sigma=sigma, n_agents=n_agents, m_h=m_h, debias=debias,
            seed=seed, interpret=interpret,
        )
    noise = jax.random.normal(jax.random.key(seed), v.shape, jnp.float32)
    return _ref.ota_channel_ref(
        v, noise, sigma=sigma, n_agents=n_agents, m_h=m_h, debias=debias
    )


def ota_aggregate(
    grads: jax.Array,          # (n_agents, n_params) stacked flat gradients
    gains: jax.Array,          # (n_agents,)
    *,
    sigma=0.0,
    scale=1.0,
    seed=0,
    with_noise: Optional[bool] = None,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
    block_rows: Optional[int] = None,
    wire_dtype=None,
) -> jax.Array:
    """The whole uplink — gain matvec + AWGN + debias — in one pass.

    ``use_pallas=False`` runs the jnp oracle with a threefry noise draw
    (different stream than the kernel's counter PRNG — reference numerics,
    not a bitwise twin; parity tests feed the oracle the kernel's own noise).
    """
    if use_pallas:
        return _fused.fused_aggregate(
            grads, gains, sigma=sigma, scale=scale, seed=seed,
            with_noise=with_noise, block_rows=block_rows,
            wire_dtype=wire_dtype, interpret=interpret,
        )
    noise = None
    if with_noise or (with_noise is None):
        noise = jax.random.normal(
            jax.random.key(seed), (grads.shape[1],), jnp.float32)
    return _ref.ota_fused_ref(grads, gains, noise, sigma=sigma, scale=scale)


def ota_aggregate_sgd(
    grads: jax.Array,
    gains: jax.Array,
    params: jax.Array,
    *,
    alpha,
    sigma=0.0,
    scale=1.0,
    seed=0,
    with_noise: Optional[bool] = None,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
    block_rows: Optional[int] = None,
    wire_dtype=None,
) -> jax.Array:
    """Uplink + server SGD step fused: p' = p - alpha * u."""
    if use_pallas:
        return _fused.fused_aggregate_sgd(
            grads, gains, params, alpha=alpha, sigma=sigma, scale=scale,
            seed=seed, with_noise=with_noise, block_rows=block_rows,
            wire_dtype=wire_dtype, interpret=interpret,
        )
    noise = None
    if with_noise or (with_noise is None):
        noise = jax.random.normal(
            jax.random.key(seed), (grads.shape[1],), jnp.float32)
    return _ref.ota_fused_sgd_ref(
        grads, gains, params, noise, alpha=alpha, sigma=sigma, scale=scale)
