"""Pallas TPU flash-attention forward (causal / sliding-window, GQA-aware).

Schedule (TPU-native, not a CUDA port): grid = (batch*heads, q_blocks,
k_blocks) with the k dimension sequential ('arbitrary'); each (bh, qi) owns a
``(block_q, head_dim)`` Q tile resident in VMEM, KV tiles stream through VMEM
``block_k`` rows at a time, and the online-softmax accumulators (m, l, acc)
live in VMEM scratch across the k steps.  GQA reads the *grouped* KV head via
the BlockSpec index_map (head -> head // group) — no materialised KV head
expansion, unlike the XLA fallback path.

MXU alignment: block_q/block_k default 128; head_dim must be a multiple of
8 (TPU lane packing) — all assigned configs use 64/112/128.

Causality is exploited at the *grid* level: k blocks strictly above the
diagonal are skipped by masking the whole tile cheaply (no MXU work saved in
interpret mode, but on TPU the mask short-circuits via @pl.when).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_q: int, block_k: int, n_k_blocks: int, causal: bool,
            window: Optional[int], sm_scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    run = True
    if causal:
        # whole KV tile above the diagonal contributes nothing
        run = (ki * block_k) <= (qi * block_q + block_q - 1)
    if window is not None:
        run = jnp.logical_and(
            run, (ki * block_k + block_k) > (qi * block_q - window)
        ) if causal else run

    @pl.when(run)
    def _step():
        q = q_ref[...].astype(jnp.float32) * sm_scale      # (bq, d)
        k = k_ref[...].astype(jnp.float32)                  # (bk, d)
        v = v_ref[...].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                   # (bq, bk)
        ok = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            ok = jnp.logical_and(ok, k_pos <= q_pos)
        if window is not None:
            ok = jnp.logical_and(ok, k_pos > q_pos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                                 # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                              # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                      # (bq, 1)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == n_k_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,        # (B, H, Sq, Dh)
    k: jax.Array,        # (B, Hkv, Sk, Dh)
    v: jax.Array,        # (B, Hkv, Sk, Dh)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, h, sq, dh = q.shape
    _, hkv, sk, _ = k.shape
    g = h // hkv
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    nq, nk = sq // block_q, sk // block_k

    qf = q.reshape(b * h, sq, dh)
    kf = k.reshape(b * hkv, sk, dh)
    vf = v.reshape(b * hkv, sk, dh)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        # GQA: flat q head -> flat kv head, via integer division by the group
        bi = bh // h
        hi = bh % h
        return (bi * hkv + hi // g, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _kernel, block_q=block_q, block_k=block_k, n_k_blocks=nk,
            causal=causal, window=window, sm_scale=1.0 / (dh ** 0.5),
        ),
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((None, block_q, dh), q_map),
            pl.BlockSpec((None, block_k, dh), kv_map),
            pl.BlockSpec((None, block_k, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((None, block_q, dh), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, dh)
