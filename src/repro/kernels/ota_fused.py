"""Fused Pallas TPU kernel for the whole OTA aggregation step (Eq. 6-7).

One kernel launch performs, per grid step, everything the uplink + server do
to one block of the flattened parameter vector:

    1. per-agent gain application and superposition  v = sum_i h_i g_i
       (an (1, A) x (A, block) matvec on the MXU — the "air" sum),
    2. AWGN injection  v += sigma * n  from a counter-based PRNG keyed on
       the absolute element index (bitwise-deterministic for a given seed,
       independent of block size, portable to interpret mode),
    3. the debias/normalisation  u = v * scale  where ``scale`` is the
       server constant 1 / (N * E[c p(c)]) (``OTAConfig.norm_const_for``),
    4. optionally the parameter update: plain SGD  p' = p - alpha * u, or
       the full Adam/AdamW moment update (matching
       ``repro.optim.optimizers._adam_core`` bit for bit in fp32).

The naive XLA chain materialises the gain-scaled stack, the summed signal
and the noise tensor; the fused kernel reads each gradient element ONCE and
writes each parameter ONCE — at transformer scale the step is memory-bound,
so the roofline win is the ratio of HBM passes (see
``repro.utils.roofline.ota_fused_cost`` and ``benchmarks/ota_kernel.py``).

Wire format: gradients may enter as bfloat16 (the over-the-air "wire"
precision); the gain matvec accumulates in float32 and the master parameter
copy stays float32, so only the uplink payload is narrowed.

Every runtime quantity (sigma, scale, alpha, Adam constants, PRNG seed) is
passed as an *array* operand, not a static, so sweep lanes — which trace
per-lane sigma/scale — batch through ``jax.vmap``: the Pallas batching rule
folds the lane axis into the kernel grid, exactly one program for the whole
sweep partition.

CPU CI runs the same kernel body through the Pallas interpreter
(``interpret=None`` auto-selects it off-TPU); ``tests/test_kernels.py``
holds it to bitwise fp32 parity against ``kernels/ref.ota_fused_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams as _CompilerParams
from repro.utils.tree import ceil_div, next_pow2

LANES = 128

# consts vector layout (one f32 row, SMEM): indices into the (1, 8) operand
_SIGMA, _SCALE, _ALPHA, _B1, _B2, _C1, _C2, _EPS = range(8)
N_CONSTS = 8

# VMEM budget for the gradient-stack block when auto-sizing block_rows
_VMEM_BLOCK_BYTES = 4 * 1024 * 1024


def _mix(x: jax.Array, salt: jax.Array) -> jax.Array:
    """One murmur3-finalizer round over uint32 counters (same stream as
    ``kernels/ota_channel.py`` — statistically ample for AWGN)."""
    x = x ^ salt
    x = (x ^ (x >> 16)) * jnp.uint32(0x85EBCA6B)
    x = (x ^ (x >> 13)) * jnp.uint32(0xC2B2AE35)
    return x ^ (x >> 16)


def _counter_noise(seed: jax.Array, start: jax.Array, shape) -> jax.Array:
    """Standard-normal noise for ``shape`` elements at absolute flat offset
    ``start``: threefry-free counter PRNG -> Box-Muller, bitwise identical
    for any block partitioning of the same flat buffer."""
    pos = jax.lax.broadcasted_iota(jnp.uint32, shape, len(shape) - 1)
    counter = start.astype(jnp.uint32) + pos
    base = _mix(counter, seed.astype(jnp.uint32) * jnp.uint32(0x9E3779B9))
    u1 = _mix(base, jnp.uint32(0xA511E9B3))
    u2 = _mix(base, jnp.uint32(0x63D83595))
    # uniform in (0, 1]: (bits >> 8) * 2^-24, offset by 2^-25 to avoid 0
    f1 = (u1 >> 8).astype(jnp.float32) * (1.0 / (1 << 24)) + (1.0 / (1 << 25))
    f2 = (u2 >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    r = jnp.sqrt(-2.0 * jnp.log(f1))
    return r * jnp.cos(2.0 * jnp.pi * f2)


def _fused_kernel(consts_ref, seed_ref, h_ref, g_ref, *state_refs,
                  mode: str, with_noise: bool, per_block: int):
    """One (1, per_block) slice of the fused aggregation + update.

    ``state_refs`` by mode:
        "agg"  : (o_ref,)                      o = u
        "sgd"  : (p_ref, o_ref)                o = p - alpha * u
        "adam" : (p_ref, mu_ref, nu_ref, op_ref, omu_ref, onu_ref)
    """
    i = pl.program_id(0)
    h = h_ref[...].astype(jnp.float32)                     # (1, A)
    g = g_ref[...]                                         # (A, per_block)
    v = jax.lax.dot_general(
        h, g.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                      # (1, per_block)
    if with_noise:
        start = i.astype(jnp.uint32) * jnp.uint32(per_block)
        n = _counter_noise(seed_ref[0, 0], start, v.shape)
        v = v + consts_ref[0, _SIGMA] * n
    u = v * consts_ref[0, _SCALE]

    if mode == "agg":
        (o_ref,) = state_refs
        o_ref[...] = u
    elif mode == "sgd":
        p_ref, o_ref = state_refs
        a = consts_ref[0, _ALPHA]
        o_ref[...] = p_ref[...] - a * u
    else:  # adam
        p_ref, mu_ref, nu_ref, op_ref, omu_ref, onu_ref = state_refs
        a = consts_ref[0, _ALPHA]
        b1 = consts_ref[0, _B1]
        b2 = consts_ref[0, _B2]
        c1 = consts_ref[0, _C1]
        c2 = consts_ref[0, _C2]
        eps = consts_ref[0, _EPS]
        mu = b1 * mu_ref[...] + (1.0 - b1) * u
        nu = b2 * nu_ref[...] + (1.0 - b2) * jnp.square(u)
        step = -(a * (mu / c1) / (jnp.sqrt(nu / c2) + eps))
        op_ref[...] = p_ref[...] + step
        omu_ref[...] = mu
        onu_ref[...] = nu


def default_block_rows(n_agents: int, n_params: int,
                       wire_bytes: int = 4, cap: int = 256) -> int:
    """Largest power-of-two block_rows <= cap whose gradient-stack block fits
    the VMEM budget, shrunk further for short parameter vectors so padding
    stays bounded."""
    rows_needed = next_pow2(max(ceil_div(n_params, LANES), 1))
    br = min(cap, rows_needed)
    while br > 8 and n_agents * br * LANES * wire_bytes > _VMEM_BLOCK_BYTES:
        br //= 2
    return max(br, 1)


def _as_consts(sigma, scale, alpha=0.0, b1=0.0, b2=0.0, c1=1.0, c2=1.0,
               eps=0.0) -> jax.Array:
    vals = [sigma, scale, alpha, b1, b2, c1, c2, eps]
    return jnp.stack(
        [jnp.asarray(v, jnp.float32).reshape(()) for v in vals]
    ).reshape(1, N_CONSTS)


def _as_seed(seed) -> jax.Array:
    return jnp.asarray(seed, jnp.uint32).reshape(1, 1)


def _pad_flat(x: jax.Array, total: int) -> jax.Array:
    pad = total - x.shape[-1]
    if pad == 0:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths)


def _interpret_default(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _call(consts, seed, gains, grads, states, *, mode: str, with_noise: bool,
          block_rows: int, interpret: bool) -> Tuple[jax.Array, ...]:
    """Shared pallas_call builder over the padded flat layout.

    ``grads``: (A, total); ``states``: tuple of (1, total) f32 buffers
    (params / mu / nu as the mode requires).  Returns the mode's outputs,
    each (1, total) f32.
    """
    n_agents, total = grads.shape
    per_block = block_rows * LANES
    n_blocks = total // per_block
    smem = functools.partial(pl.BlockSpec, memory_space=pltpu.SMEM)

    flat_spec = pl.BlockSpec((1, per_block), lambda i: (0, i))
    in_specs = [
        smem((1, N_CONSTS), lambda i: (0, 0)),
        smem((1, 1), lambda i: (0, 0)),
        pl.BlockSpec((1, n_agents), lambda i: (0, 0)),
        pl.BlockSpec((n_agents, per_block), lambda i: (0, i)),
    ] + [flat_spec] * len(states)

    n_out = {"agg": 1, "sgd": 1, "adam": 3}[mode]
    out_specs = [flat_spec] * n_out
    out_shape = [jax.ShapeDtypeStruct((1, total), jnp.float32)] * n_out
    if n_out == 1:
        out_specs, out_shape = out_specs[0], out_shape[0]

    out = pl.pallas_call(
        functools.partial(_fused_kernel, mode=mode, with_noise=with_noise,
                          per_block=per_block),
        grid=(n_blocks,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        compiler_params=_CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(consts, seed, gains.reshape(1, n_agents), grads, *states)
    return out if isinstance(out, (tuple, list)) else (out,)


def _prep(grads: jax.Array, gains: jax.Array, block_rows: Optional[int],
          wire_dtype) -> Tuple[jax.Array, jax.Array, int, int, int]:
    """Pad the (A, P) gradient stack to the blocked flat layout."""
    if grads.ndim != 2:
        raise ValueError(f"grads must be (n_agents, n_params), got {grads.shape}")
    n_agents, n_params = grads.shape
    if wire_dtype is not None:
        grads = grads.astype(wire_dtype)
    wb = jnp.dtype(grads.dtype).itemsize
    br = block_rows or default_block_rows(n_agents, n_params, wb)
    per_block = br * LANES
    total = ceil_div(n_params, per_block) * per_block
    return _pad_flat(grads, total), gains, br, n_params, total


def fused_aggregate(
    grads: jax.Array,          # (n_agents, n_params) — stacked flat gradients
    gains: jax.Array,          # (n_agents,) f32 — this round's h_i
    *,
    sigma=0.0,                 # AWGN sigma on the summed signal (runtime ok)
    scale=1.0,                 # server normalisation 1/(N*m_eff) (runtime ok)
    seed=0,                    # uint32 counter-PRNG seed (runtime ok)
    with_noise: Optional[bool] = None,
    block_rows: Optional[int] = None,
    wire_dtype=None,           # e.g. jnp.bfloat16 — the uplink payload dtype
    interpret: Optional[bool] = None,
) -> jax.Array:
    """u = (sum_i h_i g_i + sigma*n) * scale, fused; returns (n_params,) f32."""
    grads, gains, br, n_params, _ = _prep(grads, gains, block_rows, wire_dtype)
    noise = with_noise if with_noise is not None else True
    (out,) = _call(
        _as_consts(sigma, scale), _as_seed(seed), gains, grads, (),
        mode="agg", with_noise=noise, block_rows=br,
        interpret=_interpret_default(interpret),
    )
    return out.reshape(-1)[:n_params]


def fused_aggregate_sgd(
    grads: jax.Array,          # (n_agents, n_params)
    gains: jax.Array,          # (n_agents,)
    params: jax.Array,         # (n_params,) f32 master copy
    *,
    alpha,                     # SGD step size (runtime ok)
    sigma=0.0,
    scale=1.0,
    seed=0,
    with_noise: Optional[bool] = None,
    block_rows: Optional[int] = None,
    wire_dtype=None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """p' = p - alpha * u with u the fused OTA update; (n_params,) f32."""
    grads, gains, br, n_params, total = _prep(grads, gains, block_rows,
                                              wire_dtype)
    p = _pad_flat(params.astype(jnp.float32).reshape(1, -1), total)
    noise = with_noise if with_noise is not None else True
    (out,) = _call(
        _as_consts(sigma, scale, alpha), _as_seed(seed), gains, grads, (p,),
        mode="sgd", with_noise=noise, block_rows=br,
        interpret=_interpret_default(interpret),
    )
    return out.reshape(-1)[:n_params]


def fused_server_pass(
    v: jax.Array,              # (n_params,) f32 — accumulated superposition
    *,
    sigma=0.0,
    scale=1.0,
    seed=0,
    with_noise: Optional[bool] = None,
    alpha=None,                # with params: fuse the SGD step too
    params: Optional[jax.Array] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """The server tail of a *streamed* (``agent_blocks``) uplink as one
    kernel pass: AWGN + debias/normalisation — and, when ``params`` (and
    ``alpha``) are given, the SGD update — over an already-accumulated
    superposition ``v = sum_i h_i g_i``.

    Reuses the aggregation kernel's block grid with ``v`` as a single
    unit-gain agent row and no wire-dtype hop (the blocked scan already
    applied the wire quantisation per agent row; re-narrowing the running
    sum would double-quantise).  The counter PRNG is keyed on the absolute
    flat element index, so the noise is bitwise-identical to the one-shot
    kernel's draw for the same seed — and invariant to the agent blocking.
    """
    flat = v.astype(jnp.float32).reshape(1, -1)
    ones = jnp.ones((1,), jnp.float32)
    if params is None:
        return fused_aggregate(
            flat, ones, sigma=sigma, scale=scale, seed=seed,
            with_noise=with_noise, interpret=interpret)
    if alpha is None:
        raise ValueError("fused_server_pass with params needs alpha")
    return fused_aggregate_sgd(
        flat, ones, params, alpha=alpha, sigma=sigma, scale=scale,
        seed=seed, with_noise=with_noise, interpret=interpret)


def fused_aggregate_adam(
    grads: jax.Array,          # (n_agents, n_params)
    gains: jax.Array,          # (n_agents,)
    params: jax.Array,         # (n_params,) f32 master copy
    mu: jax.Array,             # (n_params,) f32 first moment
    nu: jax.Array,             # (n_params,) f32 second moment
    *,
    alpha,                     # learning rate at this step (runtime ok)
    step,                      # 1-based step count t (runtime ok)
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    sigma=0.0,
    scale=1.0,
    seed=0,
    with_noise: Optional[bool] = None,
    block_rows: Optional[int] = None,
    wire_dtype=None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Aggregation + bias-corrected Adam, one pass: returns (p', mu', nu').

    Matches ``repro.optim.optimizers.adam`` (``_adam_core`` with
    weight_decay=0) applied to the fused update u, in fp32.
    """
    grads, gains, br, n_params, total = _prep(grads, gains, block_rows,
                                              wire_dtype)
    t = jnp.asarray(step, jnp.float32)
    c1 = 1.0 - jnp.asarray(b1, jnp.float32) ** t
    c2 = 1.0 - jnp.asarray(b2, jnp.float32) ** t
    states = tuple(
        _pad_flat(x.astype(jnp.float32).reshape(1, -1), total)
        for x in (params, mu, nu)
    )
    noise = with_noise if with_noise is not None else True
    outs = _call(
        _as_consts(sigma, scale, alpha, b1, b2, c1, c2, eps),
        _as_seed(seed), gains, grads, states,
        mode="adam", with_noise=noise, block_rows=br,
        interpret=_interpret_default(interpret),
    )
    return tuple(o.reshape(-1)[:n_params] for o in outs)
