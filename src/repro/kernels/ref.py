"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

These are *definitions*, optimised for clarity: full-score attention, the
chunked-but-vectorised SSD from models/ssm.py, and the direct OTA update.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_ref as _ssd_chunked


def flash_attention_ref(
    q: jax.Array,        # (B, H, Sq, Dh)
    k: jax.Array,        # (B, Hkv, Sk, Dh)
    v: jax.Array,        # (B, Hkv, Sk, Dh)
    *,
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    b, h, sq, dh = q.shape
    hkv = k.shape[1]
    g = h // hkv
    kf = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    qf = q.astype(jnp.float32) / jnp.sqrt(jnp.float32(dh))
    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    qp = jnp.arange(sq)
    kp = jnp.arange(k.shape[2])
    ok = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        ok &= kp[None, :] <= qp[:, None]
    if window is not None:
        ok &= kp[None, :] > qp[:, None] - window
    scores = jnp.where(ok[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return out.astype(q.dtype)


def ssd_ref(
    x: jax.Array,       # (B, S, H, P)
    dt: jax.Array,      # (B, S, H)
    A: jax.Array,       # (H,)
    B: jax.Array,       # (B, S, G, N)
    C: jax.Array,       # (B, S, G, N)
    chunk: int,
) -> jax.Array:
    """Delegates to the model's chunked SSD (itself equality-tested against
    the O(1)-state recurrent step in tests/test_models.py)."""
    return _ssd_chunked(x, dt, A, B, C, chunk)


def ssd_sequential_ref(x, dt, A, B, C):
    """Fully sequential SSD recurrence — the *definition* (slow, exact)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    f32 = jnp.float32

    def step(state, inp):
        xt, dtt, Bt, Ct = inp                       # (b,h,p),(b,h),(b,g,n),(b,g,n)
        decay = jnp.exp(dtt * A[None, :])           # (b,h)
        dg = decay.reshape(b, g, hg)
        dax = (xt * dtt[..., None]).reshape(b, g, hg, p)
        state = state * dg[..., None, None] + jnp.einsum("bgn,bghp->bghpn", Bt, dax)
        y = jnp.einsum("bgn,bghpn->bghp", Ct, state)
        return state, y.reshape(b, h, p)

    s0 = jnp.zeros((b, g, hg, p, n), f32)
    xs = (
        jnp.moveaxis(x.astype(f32), 1, 0),
        jnp.moveaxis(dt.astype(f32), 1, 0),
        jnp.moveaxis(B.astype(f32), 1, 0),
        jnp.moveaxis(C.astype(f32), 1, 0),
    )
    _, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1)                   # (b,s,h,p)


def ota_fused_ref(
    grads: jax.Array,     # (n_agents, n_params) — stacked flat gradients
    gains: jax.Array,     # (n_agents,)
    noise: Optional[jax.Array] = None,   # (n_params,) std normal, or None
    *,
    sigma=0.0,
    scale=1.0,
) -> jax.Array:
    """u = (sum_i h_i g_i + sigma*n) * scale — the fused-kernel definition.

    Op order mirrors ``ota_fused._fused_kernel`` exactly (f32 matvec, then
    noise FMA, then scale) so fp32 parity is bitwise in interpret mode; the
    caller supplies the kernel's own counter-PRNG ``noise`` realisation when
    checking the noisy path (tests extract it with the zero-gradient trick).
    """
    v = jax.lax.dot_general(
        gains.astype(jnp.float32).reshape(1, -1), grads.astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    ).reshape(-1)
    if noise is not None:
        v = v + jnp.asarray(sigma, jnp.float32) * noise.astype(jnp.float32)
    return v * jnp.asarray(scale, jnp.float32)


def ota_fused_sgd_ref(grads, gains, params, noise=None, *, alpha,
                      sigma=0.0, scale=1.0) -> jax.Array:
    """p' = p - alpha*u over :func:`ota_fused_ref` (same op order as the
    kernel's sgd mode; compare under jit — XLA contracts the multiply-
    subtract into one FMA exactly as the kernel body does)."""
    u = ota_fused_ref(grads, gains, noise, sigma=sigma, scale=scale)
    return params.astype(jnp.float32) - jnp.asarray(alpha, jnp.float32) * u


def ota_fused_adam_ref(grads, gains, params, mu, nu, noise=None, *, alpha,
                       step, b1=0.9, b2=0.999, eps=1e-8, sigma=0.0,
                       scale=1.0):
    """Aggregation + bias-corrected Adam on the fused update — mirrors
    ``ota_fused.fused_aggregate_adam`` (and ``optim.optimizers._adam_core``
    with weight_decay=0) op for op.  Returns (p', mu', nu')."""
    f32 = jnp.float32
    u = ota_fused_ref(grads, gains, noise, sigma=sigma, scale=scale)
    a, b1, b2, eps = (jnp.asarray(x, f32) for x in (alpha, b1, b2, eps))
    t = jnp.asarray(step, f32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t
    mu_n = b1 * mu.astype(f32) + (1.0 - b1) * u
    nu_n = b2 * nu.astype(f32) + (1.0 - b2) * jnp.square(u)
    delta = -(a * (mu_n / c1) / (jnp.sqrt(nu_n / c2) + eps))
    return params.astype(f32) + delta, mu_n, nu_n


def ota_channel_ref(
    v: jax.Array,         # aggregated sum_i h_i g_i (any shape)
    noise: jax.Array,     # standard normal, same shape
    *,
    sigma: float,
    n_agents: int,
    m_h: float,
    debias: bool = True,
) -> jax.Array:
    """(v + sigma * noise) / (N * m_h)  — Eq. (6)-(7) server-side update."""
    scale = 1.0 / (n_agents * (m_h if debias else 1.0))
    return ((v.astype(jnp.float32) + sigma * noise.astype(jnp.float32)) * scale).astype(
        v.dtype
    )
