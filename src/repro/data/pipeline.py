"""Synthetic-but-structured token pipeline.

Serves the role of a tokenised corpus loader: deterministic (step -> batch is
a pure function of the seed, so every data-parallel host materialises only
its shard), learnable (a mixture of k-order Markov chains with per-document
latent "topics", so models show decreasing loss), and shardable (batch dim is
sharded over ('pod','data')).

The memory stub for the audio/vlm families is generated here too: frame or
patch embeddings are produced from a fixed random projection of the token
prefix, standing in for the (out-of-scope, per the assignment) modality
frontends.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models.model import needs_memory
from repro.models.transformer import cross_len

PyTree = Any


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_topics: int = 8
    order: int = 2         # Markov order of the synthetic language
    seed: int = 0


class SyntheticLM:
    """step -> {tokens, labels} batches from a topic-mixture Markov chain."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        key = jax.random.key(cfg.seed)
        k_trans, k_topic = jax.random.split(key)
        # per-topic bigram transition logits over a hashed context bucket
        self.n_buckets = min(cfg.vocab, 4096)
        self.trans_logits = 2.0 * jax.random.normal(
            k_trans, (cfg.n_topics, self.n_buckets, min(cfg.vocab, 1024)),
            jnp.float32,
        )
        self.sub_vocab = self.trans_logits.shape[-1]

    def _hash_ctx(self, tok: jax.Array) -> jax.Array:
        h = tok.astype(jnp.uint32) * jnp.uint32(2654435761)
        return (h % jnp.uint32(self.n_buckets)).astype(jnp.int32)

    def batch(self, step: int) -> Dict[str, jax.Array]:
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.key(cfg.seed + 1), step)
        k_topic, k_start, k_scan = jax.random.split(key, 3)
        topics = jax.random.randint(
            k_topic, (cfg.global_batch,), 0, cfg.n_topics
        )
        start = jax.random.randint(
            k_start, (cfg.global_batch,), 0, self.sub_vocab
        )

        def gen_one(topic, tok0, k):
            def body(tok, kt):
                logits = self.trans_logits[topic, self._hash_ctx(tok)]
                nxt = jax.random.categorical(kt, logits)
                return nxt.astype(jnp.int32), nxt.astype(jnp.int32)

            keys = jax.random.split(k, cfg.seq_len + 1)
            _, toks = jax.lax.scan(body, tok0, keys)
            return toks

        keys = jax.random.split(k_scan, cfg.global_batch)
        seq = jax.vmap(gen_one)(topics, start, keys)      # (B, S+1)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


def memory_stub(
    cfg: ModelConfig, tokens: jax.Array, seq_len: int, seed: int = 7
) -> jax.Array:
    """Precomputed frontend embeddings (B, mem_len, d_model) — the assigned
    carve-out: a fixed random projection of token statistics stands in for
    the ViT / speech-codec output."""
    mem_len = cross_len(cfg, seq_len)
    b = tokens.shape[0]
    key = jax.random.key(seed)
    proj = jax.random.normal(key, (mem_len, cfg.d_model), jnp.float32) * 0.02
    phase = (tokens[:, :1].astype(jnp.float32) / max(cfg.vocab, 1))
    return (proj[None] * (1.0 + phase[..., None])).astype(jnp.dtype(cfg.dtype))


def make_batch(
    model_cfg: ModelConfig, shape: InputShape, step: int, seed: int = 0
) -> Dict[str, jax.Array]:
    """One training batch for (arch, shape), memory stub included."""
    dcfg = DataConfig(
        vocab=model_cfg.vocab,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        seed=seed,
    )
    ds = SyntheticLM(dcfg)
    batch = ds.batch(step)
    if needs_memory(model_cfg):
        batch["memory"] = memory_stub(model_cfg, batch["tokens"], shape.seq_len)
    return batch


def make_batch_specs(
    model_cfg: ModelConfig, shape: InputShape, mesh, batch_axes=("pod", "data")
):
    """NamedShardings for a batch dict: batch dim over the data axes."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = tuple(a for a in batch_axes if a in mesh.shape)
    bspec = axes if len(axes) > 1 else (axes[0] if axes else None)

    def spec(ndim):
        return NamedSharding(mesh, P(bspec, *([None] * (ndim - 1))))

    out = {"tokens": spec(2), "labels": spec(2)}
    if needs_memory(model_cfg):
        out["memory"] = spec(3)
    return out
