"""Bounded stale-gradient replay buffer, carried through the round scan.

When an agent misses a round (participation mask off), the server can
replay its **last contributed gradient** — kept in an ``(N, d)`` buffer
indexed by ABSOLUTE agent id, like ``HeterogeneousBudget`` — with an
age-decay weight ``decay ** (age - 1)`` as long as the copy is at most
``max_age`` rounds old.  Replayed terms are server-side memory: they
enter the update *after* the OTA uplink (no channel gain, no fresh
noise), normalised by the same total contribution weight ``W`` as the
fresh participants (see ``service.participation``).

Age convention: entering round ``k``, ``age[i]`` is the number of
rounds since agent ``i`` last contributed — ``1`` means "contributed
last round" (replay weight ``decay**0 = 1``), ``AGE_NEVER`` means never
(row is all zeros and must not replay).  After the round, participants
reset to ``1`` and everyone else ages by one (saturating).

All replay weights and age statistics are computed from the ``(N,)``
mask/age vectors *before* the block scan, and the buffer-sum fold uses
the same strict sequential ``ota.stream_fold_block`` as the uplink — so
the streamed (``agent_blocks``) form is bitwise invariant to the block
size, including padded non-dividing fleets (phantom rows carry weight
zero and fold exact zeros).  The O(N × d) buffer itself is inherent
carried state — the same asymmetry the event-triggered baseline
documents.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = ["AGE_NEVER", "StaleState", "StalenessConfig", "advance",
           "init_state", "normalize", "replay_sum_stacked",
           "replay_weights", "stats"]

# saturation value for "never contributed" (and the age cap): far above
# any usable max_age, small enough that age + 1 can never overflow int32
AGE_NEVER = jnp.int32(2 ** 30)


@dataclass(frozen=True)
class StalenessConfig:
    """Static (hashable) replay policy.  ``max_age=0`` disables replay
    entirely (normalises to None); ``decay`` may be a traced sweep-lane
    value."""

    max_age: int = 0         # replay copies at most this many rounds old
    decay: float = 1.0       # age-decay weight: w(age) = decay**(age - 1)

    def __post_init__(self):
        if self.max_age < 0:
            raise ValueError("max_age must be >= 0")
        if isinstance(self.decay, (int, float)) \
                and not 0.0 <= self.decay <= 1.0:
            raise ValueError("decay must be in [0, 1]")


def normalize(staleness: Optional[StalenessConfig],
              participation=None) -> Optional[StalenessConfig]:
    """``max_age=0`` is staleness-off; so is any staleness without active
    participation (no agent ever misses a round, the buffer would never
    replay) — the program must be byte-identical to ``staleness=None``."""
    if staleness is None or staleness.max_age < 1:
        return None
    if participation is None:
        return None
    return staleness


class StaleState(NamedTuple):
    """(N, d)-buffered last contributions + (N,) int32 ages."""

    grads: PyTree       # leading axis N, absolute agent order
    age: jax.Array      # (N,) int32; AGE_NEVER until first contribution


def init_state(scfg: StalenessConfig, theta: PyTree,
               n_agents: int) -> StaleState:
    grads = jax.vmap(
        lambda _: jax.tree.map(jnp.zeros_like, theta))(
            jnp.arange(n_agents))
    return StaleState(grads=grads,
                      age=jnp.full((n_agents,), AGE_NEVER, jnp.int32))


def replay_weights(scfg: StalenessConfig, mask: jax.Array,
                   age: jax.Array) -> jax.Array:
    """(N,) float32 replay weight per agent this round: exact zero for
    participants, too-old copies and never-contributed rows; otherwise
    ``decay ** (age - 1)``."""
    replay = jnp.logical_and(
        jnp.logical_not(mask),
        jnp.logical_and(age >= 1, age <= scfg.max_age))
    a = jnp.clip(age, 1, scfg.max_age).astype(jnp.float32)
    w = jnp.power(jnp.asarray(scfg.decay, jnp.float32), a - 1.0)
    return jnp.where(replay, w, 0.0)


def advance(scfg: StalenessConfig, state: StaleState, mask: jax.Array,
            fresh_grads: PyTree) -> StaleState:
    """Post-round buffer update (stacked form): participants' rows take
    their fresh gradient at age 1, everyone else ages by one round."""
    keep = jax.tree.map(
        lambda new, old: jnp.where(
            mask.reshape((-1,) + (1,) * (new.ndim - 1)), new, old),
        fresh_grads, state.grads)
    age = jnp.where(mask, jnp.int32(1),
                    jnp.minimum(state.age + 1, AGE_NEVER))
    return StaleState(grads=keep, age=age)


def replay_sum_stacked(state: StaleState, weights: jax.Array) -> PyTree:
    """``sum_i w_i * S_i`` over the stacked buffer (the batched-sum
    association, matching the stacked round's uplink combine)."""
    def _combine(s):
        wb = weights.reshape((-1,) + (1,) * (s.ndim - 1)).astype(s.dtype)
        return jnp.sum(wb * s, axis=0)

    return jax.tree.map(_combine, state.grads)


def stats(scfg: StalenessConfig, mask: jax.Array,
          age: jax.Array):
    """(total replay weight, replayed count, mean replayed age) scalars —
    all derived from the pre-scan (N,) vectors, so every round form
    (stacked, streamed, sharded) computes them identically."""
    w = replay_weights(scfg, mask, age)
    replayed = w > 0
    cnt = jnp.sum(replayed.astype(jnp.float32))
    from repro.service.participation import safe_inv

    mean_age = jnp.sum(jnp.where(replayed, age, 0).astype(jnp.float32)) \
        * safe_inv(cnt)
    return jnp.sum(w), cnt, mean_age
