"""Host-side continuous round service over the jitted service rounds.

:class:`RoundService` wraps the service round program built by
``fedpg.make_round_fn(participation=..., staleness=...)`` into a
long-running loop: rounds execute in jitted *commit segments* (a
``lax.scan`` of ``rounds_per_commit`` service rounds — one dispatch per
commit, any fleet size via ``agent_blocks`` streaming), the
:class:`~repro.service.participation.ServiceState` lives host-side
between commits, and each commit emits a ledger event with the round
service's telemetry (realised participation rate, realised-vs-expected
debias drift, staleness age histogram) plus a ``trace`` span.

Determinism and resume: per-round scan keys are derived by
``fold_in(round_key, absolute_round_index)`` — NOT by splitting a
carried key — so round k consumes the identical key stream whether it
runs in the first segment of a fresh service or the first segment after
a checkpoint restore.  Together with the counter-PRNG participation
masks (keyed on the checkpointed ``round_idx``) this makes a resumed
service bitwise-identical to an uninterrupted one.

Checkpoints go through :mod:`repro.checkpoint` (atomic ``.npz`` +
manifest); typed PRNG keys are stored as their ``key_data`` bits and
re-wrapped on restore.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fedpg
from repro.service import participation as svc_participation
from repro.service import staleness as svc_staleness
from repro.service.participation import ParticipationConfig, ServiceState
from repro.service.staleness import StalenessConfig, StaleState
from repro.telemetry import get_ledger, trace
from repro.telemetry.probes import TelemetryConfig, summarize

PyTree = Any

__all__ = ["RoundService", "ServiceConfig"]


@dataclass(frozen=True)
class ServiceConfig:
    """Host-side service loop policy (all static)."""

    rounds_per_commit: int = 8     # rounds per jitted segment / ledger event
    max_rounds: int = 64           # total rounds before the service stops
    round_deadline_s: Optional[float] = None  # wall-clock budget per round
    checkpoint_dir: str = ""       # "" disables checkpointing
    checkpoint_every: int = 1      # checkpoint every this many commits

    def __post_init__(self):
        if self.rounds_per_commit < 1:
            raise ValueError("rounds_per_commit must be >= 1")
        if self.max_rounds < 1:
            raise ValueError("max_rounds must be >= 1")
        if self.checkpoint_every < 1:
            raise ValueError("checkpoint_every must be >= 1")


def _key_data(key: jax.Array) -> jax.Array:
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        return jax.random.key_data(key)
    return key


def _wrap_key(data: jax.Array, like: jax.Array) -> jax.Array:
    if jnp.issubdtype(like.dtype, jax.dtypes.prng_key):
        return jax.random.wrap_key_data(jnp.asarray(data, jnp.uint32))
    return data


class RoundService:
    """A continuous federated round service with partial participation.

    ``participation`` must be *active* (one that can actually drop agents
    — see :func:`repro.service.participation.normalize`): a service whose
    config normalises away is just ``fedpg.run``, which already covers
    that case with a single dispatch.  All round-program options
    (``ota``, ``telemetry``, ``agent_blocks``, ``ota_backend``) carry the
    same semantics as :func:`repro.core.fedpg.run`.
    """

    def __init__(self, env, policy, cfg: fedpg.FedPGConfig, key: jax.Array,
                 *, participation: ParticipationConfig,
                 staleness: Optional[StalenessConfig] = None,
                 ota=None, telemetry: Optional[TelemetryConfig] = None,
                 agent_blocks: Optional[int] = None,
                 ota_backend: str = "auto",
                 service: ServiceConfig = ServiceConfig(),
                 theta0: Optional[PyTree] = None):
        part = svc_participation.normalize(participation, cfg.n_agents)
        if part is None:
            raise ValueError(
                "RoundService needs an active participation config (one "
                "that can drop agents); full participation is plain "
                "fedpg.run")
        stale = svc_staleness.normalize(staleness, part)
        self.cfg = cfg
        self.service = service
        self._part = part
        self._stale = stale
        round_fn = fedpg.make_round_fn(
            env, policy, cfg, ota, ota_backend=ota_backend,
            telemetry=telemetry, agent_blocks=agent_blocks,
            participation=part, staleness=stale)

        key_init, self._round_key, key_svc = jax.random.split(key, 3)
        theta = policy.init(key_init) if theta0 is None else theta0
        self.state: ServiceState = svc_participation.init_state(
            theta, key_svc, cfg.n_agents, stale)

        seg = service.rounds_per_commit

        def _segment(state: ServiceState, round_key, r0):
            keys = jax.vmap(
                lambda r: jax.random.fold_in(round_key, r))(
                    r0 + jnp.arange(seg, dtype=jnp.int32))
            return jax.lax.scan(round_fn, state, keys)

        self._segment = jax.jit(_segment)
        self._commits = 0

    # -- checkpointing -----------------------------------------------------

    def _ckpt_tree(self, state: ServiceState) -> Dict[str, Any]:
        tree = {
            "theta": state.theta,
            "round_idx": state.round_idx,
            "part_key": _key_data(state.part_key),
            "sched_key": _key_data(state.sched_key),
        }
        if state.stale is not None:
            tree["stale_grads"] = state.stale.grads
            tree["stale_age"] = state.stale.age
        return tree

    def checkpoint(self) -> Optional[str]:
        """Write the current service state; returns the path (or None when
        checkpointing is disabled)."""
        if not self.service.checkpoint_dir:
            return None
        from repro import checkpoint as ckpt

        step = int(self.state.round_idx)
        return ckpt.save(self.service.checkpoint_dir, step,
                         self._ckpt_tree(self.state))

    def resume(self) -> bool:
        """Restore the latest checkpoint, if any.  Returns True when a
        checkpoint was loaded; the next commit continues from its round
        (identical key and mask streams to the uninterrupted run)."""
        if not self.service.checkpoint_dir:
            return False
        from repro import checkpoint as ckpt

        step = ckpt.latest_step(self.service.checkpoint_dir)
        if step is None:
            return False
        tree = ckpt.restore(self.service.checkpoint_dir, step,
                            self._ckpt_tree(self.state))
        stale = None
        if self._stale is not None:
            stale = StaleState(grads=tree["stale_grads"],
                               age=jnp.asarray(tree["stale_age"], jnp.int32))
        self.state = ServiceState(
            theta=tree["theta"],
            round_idx=jnp.asarray(tree["round_idx"], jnp.int32),
            part_key=_wrap_key(tree["part_key"], self.state.part_key),
            sched_key=_wrap_key(tree["sched_key"], self.state.sched_key),
            stale=stale)
        return True

    # -- the service loop --------------------------------------------------

    def commit(self) -> Dict[str, Any]:
        """Run one commit segment (``rounds_per_commit`` service rounds);
        advances the host-side state and returns the commit record that was
        also written to the ambient ledger (if one is installed)."""
        svc = self.service
        r0 = int(self.state.round_idx)
        with trace.span("service_commit", round_start=r0,
                        rounds=svc.rounds_per_commit) as sp:
            state, metrics = self._segment(
                self.state, self._round_key, jnp.int32(r0))
            metrics = jax.tree.map(np.asarray, jax.device_get(metrics))
        self.state = state
        self._commits += 1

        rewards, grad_sq, gain_mean = metrics[:3]
        rec: Dict[str, Any] = {
            "round_start": r0,
            "round_end": r0 + svc.rounds_per_commit,
            "reward": float(np.mean(rewards)),
            "grad_sq": float(np.mean(grad_sq)),
            "gain_mean": float(np.mean(gain_mean)),
            "wall_us": sp.duration_us,
        }
        if len(metrics) == 4:
            tel = summarize(metrics[3])
            if tel is not None:
                rec.update({k: v for k, v in tel.items() if k in (
                    "participation_rate", "participation_drift",
                    "staleness_mean")})
        if self._stale is not None:
            # host-side staleness histogram over the live buffer ages:
            # bucket k = agents whose copy is k rounds old, last bucket =
            # too old / never contributed (AGE_NEVER saturates the clip)
            age = np.asarray(self.state.stale.age)
            hist = np.bincount(
                np.clip(age, 0, self._stale.max_age + 1),
                minlength=self._stale.max_age + 2)
            rec["staleness_hist"] = [int(c) for c in hist]
        per_round_s = sp.duration_us / 1e6 / svc.rounds_per_commit
        if svc.round_deadline_s is not None \
                and per_round_s > svc.round_deadline_s:
            rec["deadline_exceeded"] = True
            rec["per_round_s"] = per_round_s
        ledger = get_ledger()
        if ledger is not None:
            ledger.log_service(**rec)
        if svc.checkpoint_dir and self._commits % svc.checkpoint_every == 0:
            self.checkpoint()
        return rec

    def run(self) -> List[Dict[str, Any]]:
        """Run commits until ``max_rounds``; returns the commit records."""
        records = []
        while int(self.state.round_idx) < self.service.max_rounds:
            records.append(self.commit())
        return records
