"""repro.service — the asynchronous round service.

The paper's Algorithm 2 is fully synchronous: every agent broadcasts in
every round.  This package relaxes that into a *round service* with
partial, stale, and faulty agent participation, while preserving the
repo's core contracts (debias normalisation, bitwise block/shard
invariance, byte-identical programs when a feature is off):

* :mod:`repro.service.participation` — in-jit per-round participation
  masks (Bernoulli / deterministic round-robin subset), counter-PRNG
  keyed on ``(round, agent_id)`` so the realised mask is bitwise
  reproducible and invariant to ``agent_blocks``/``agent_mesh``
  partitioning, plus the realised/expected debias normalisers.
* :mod:`repro.service.staleness` — a bounded stale-gradient replay
  buffer carried through the round scan (absolute-agent-indexed,
  age-decay weighted).
* :mod:`repro.service.faults` — straggler delay distributions, a round
  deadline that closes the uplink, and crash/rejoin schedules; all
  declaratively configured and sweep-packable.
* :mod:`repro.service.driver` — the host-side continuous service
  (:class:`~repro.service.driver.RoundService`) wrapping the jitted
  service rounds from ``fedpg.make_round_fn``: segment commits,
  wall-clock round deadlines, checkpoint/resume, ledger telemetry.
  (``RoundService``/``ServiceConfig`` re-export lazily from here — the
  driver pulls in ``repro.core.fedpg``, which imports this package's
  config submodules, so an eager import would cycle.)

The in-jit pieces thread through ``fedpg.run(participation=...,
staleness=...)`` — see :func:`repro.core.fedpg.make_round_fn`.
"""
from repro.service.faults import (  # noqa: F401
    CrashSchedule, FaultConfig, StragglerModel,
)
from repro.service.participation import (  # noqa: F401
    ParticipationConfig, ServiceState,
)
from repro.service.staleness import StalenessConfig, StaleState  # noqa: F401

__all__ = [
    "CrashSchedule", "FaultConfig", "ParticipationConfig", "RoundService",
    "ServiceConfig", "ServiceState", "StalenessConfig", "StaleState",
    "StragglerModel",
]

_DRIVER_EXPORTS = ("RoundService", "ServiceConfig")


def __getattr__(name):
    if name in _DRIVER_EXPORTS:
        from repro.service import driver
        return getattr(driver, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
