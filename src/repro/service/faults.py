"""Declarative fault injection for the round service.

Three failure modes, composed into one per-round availability mask that
multiplies the participation mask (see ``service.participation``):

* **Stragglers** — each agent draws an upload delay from a configured
  distribution (exponential or Pareto/Lomax tail); the round's deadline
  closure commits with whoever made the deadline (``delay <= deadline``),
  the OTA analog of timeout/partial aggregation.
* **Crashes** — a configured fraction of agents follows a periodic
  crash/rejoin schedule (down for ``down`` out of every ``period``
  rounds, with a per-agent phase so outages are staggered).
* **Deadline** — ``math.inf`` disables closure (every straggler
  eventually makes it, i.e. stragglers alone change nothing).

Everything is a frozen, hashable dataclass so fault configs can join
compiled-program cache keys and sweep-lane structure keys, and every
random draw is a counter-PRNG ``fold_in`` on ``(round, agent_id)`` —
bitwise reproducible and invariant to agent blocking/sharding.  The
closed-form per-round availability probability (:meth:`FaultConfig.
availability`) feeds the ``expected_n`` debias normaliser.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["CrashSchedule", "FaultConfig", "StragglerModel"]


@dataclass(frozen=True)
class StragglerModel:
    """Per-(round, agent) upload-delay distribution.

    ``dist="exp"`` draws ``Exp(mean)``; ``dist="pareto"`` draws a
    Lomax(shape) tail scaled so the mean is ``mean`` (requires
    ``shape > 1``) — the heavy-tailed regime where a deadline actually
    bites.  Both are inverse-CDF transforms of one uniform draw, so the
    delay stream is pure counter-PRNG.
    """

    dist: str = "exp"        # "exp" | "pareto"
    mean: float = 1.0        # mean delay (same unit as the deadline)
    shape: float = 2.5       # Lomax tail index (pareto only)

    def __post_init__(self):
        if self.dist not in ("exp", "pareto"):
            raise ValueError(f"unknown straggler dist {self.dist!r}")
        if self.mean <= 0:
            raise ValueError("straggler mean delay must be > 0")
        if self.dist == "pareto" and self.shape <= 1:
            raise ValueError("pareto straggler needs shape > 1 for a "
                             "finite mean delay")

    def _scale(self) -> float:
        # Lomax mean = scale / (shape - 1)
        return self.mean * (self.shape - 1.0)

    def delays(self, u: jax.Array) -> jax.Array:
        """Inverse-CDF transform of uniform draws ``u`` in [0, 1)."""
        if self.dist == "exp":
            return -self.mean * jnp.log1p(-u)
        return self._scale() * (jnp.power(1.0 - u, -1.0 / self.shape) - 1.0)

    def prob_within(self, deadline: float) -> float:
        """Closed-form ``P(delay <= deadline)``."""
        if not math.isfinite(deadline):
            return 1.0
        if self.dist == "exp":
            return 1.0 - math.exp(-deadline / self.mean)
        return 1.0 - (1.0 + deadline / self._scale()) ** (-self.shape)


@dataclass(frozen=True)
class CrashSchedule:
    """Periodic crash/rejoin: a ``frac`` subset of agents is down for
    ``down`` out of every ``period`` rounds.  Which agents crash (one
    uniform per agent) and their outage phase (one ``fold_in`` per agent)
    are drawn from the round-independent schedule key, so an agent's
    crash windows are fixed for the whole run — crash, then rejoin."""

    frac: float = 0.1        # fraction of the fleet that ever crashes
    period: int = 10         # schedule period in rounds
    down: int = 1            # rounds spent down per period

    def __post_init__(self):
        if not 0.0 <= self.frac <= 1.0:
            raise ValueError("crash frac must be in [0, 1]")
        if self.period < 1 or not 0 <= self.down <= self.period:
            raise ValueError("need 0 <= down <= period and period >= 1")

    def up_mask(self, sched_key: jax.Array, round_idx: jax.Array,
                agent_ids: jax.Array) -> jax.Array:
        """(len(agent_ids),) bool — True where the agent is up this round."""
        def agent_up(i):
            k = jax.random.fold_in(sched_key, i)
            k_sel, k_phase = jax.random.split(k)
            crashes = jax.random.uniform(k_sel) < self.frac
            phase = jax.random.randint(k_phase, (), 0, self.period)
            in_outage = ((round_idx + phase) % self.period) < self.down
            return jnp.logical_not(jnp.logical_and(crashes, in_outage))

        return jax.vmap(agent_up)(agent_ids)

    def up_prob(self) -> float:
        """Closed-form per-round ``P(agent is up)``."""
        return 1.0 - self.frac * (self.down / self.period)


@dataclass(frozen=True)
class FaultConfig:
    """Composed fault model for one service run (hashable, declarative).

    ``deadline`` is the round-closure deadline applied to straggler
    delays; ``math.inf`` (the default) never closes a round early.
    """

    stragglers: Optional[StragglerModel] = None
    deadline: float = math.inf
    crashes: Optional[CrashSchedule] = None

    def __post_init__(self):
        if isinstance(self.deadline, (int, float)) and self.deadline < 0:
            raise ValueError("deadline must be >= 0")

    @property
    def active(self) -> bool:
        """Whether this config can ever drop an agent.  Stragglers without
        a finite deadline never do; a zero-fraction crash schedule never
        does.  An inactive config normalises the whole fault path away."""
        # a traced deadline (packed sweep lane) is not statically infinite:
        # keep the fault path active so the program shape matches the lane
        statically_inf = isinstance(self.deadline, (int, float)) \
            and math.isinf(self.deadline)
        straggle = self.stragglers is not None and not statically_inf
        crash = self.crashes is not None and self.crashes.frac > 0 \
            and self.crashes.down > 0
        return bool(straggle or crash)

    def availability(self) -> float:
        """Closed-form per-round ``P(agent contributes)`` under this fault
        model (delays and crash schedules are independent) — the factor the
        ``expected_n`` debias normaliser multiplies in."""
        p = 1.0
        if self.stragglers is not None:
            try:
                p *= self.stragglers.prob_within(float(self.deadline))
            except TypeError:  # traced deadline (sweep lane): no closed form
                pass
        if self.crashes is not None:
            p *= self.crashes.up_prob()
        return p

    def up_mask(self, delay_key: jax.Array, sched_key: jax.Array,
                round_idx: jax.Array, agent_ids: jax.Array) -> jax.Array:
        """(len(agent_ids),) bool availability this round: made the
        deadline AND not in a crash outage.  ``delay_key`` is the
        round-folded key (fresh delays each round); ``sched_key`` is the
        run-wide schedule key (fixed crash windows)."""
        up = jnp.ones(agent_ids.shape, bool)
        if self.stragglers is not None:
            def agent_delay(i):
                return jax.random.uniform(jax.random.fold_in(delay_key, i))

            u = jax.vmap(agent_delay)(agent_ids)
            up = jnp.logical_and(up,
                                 self.stragglers.delays(u) <= self.deadline)
        if self.crashes is not None:
            up = jnp.logical_and(
                up, self.crashes.up_mask(sched_key, round_idx, agent_ids))
        return up
