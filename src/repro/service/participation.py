"""In-jit participation semantics for the round service.

The paper's Algorithm 2 assumes every agent broadcasts in every round;
the service relaxes that to a *participation mask* drawn per round and
re-normalises the OTA update so the effective-moment contract
(``ota.effective_gain_mean``) is preserved:

* **Masks** are pure counter-PRNG: the run-wide ``part_key`` is
  ``fold_in``-ed with the round index, then per-agent draws ``fold_in``
  the ABSOLUTE agent id — so the mask for ``(round, agent)`` is bitwise
  reproducible and invariant to ``agent_blocks`` blocking and
  ``agent_mesh`` sharding (the same derivation scheme as
  ``ota.sharded_stream_gains``).  ``kind="bernoulli"`` draws each agent
  independently with probability ``rate``; ``kind="subset"`` is the
  deterministic round-robin window of ``subset`` agents (no PRNG at
  all); faults (:mod:`repro.service.faults`) AND into either.
* **Debias normalisers**: the full-fleet update is ``(sum_i h_i g_i +
  n) / (N * m_h)``; with ``W`` the round's total contribution weight
  (participating count plus any staleness replay weight), the service
  multiplies by ``N / W`` so the committed update is normalised by the
  *realised* participation (``debias="realized"``) — an exact-zero
  update when nobody makes the round, never an amplified noise draw —
  or by the closed-form ``E[W]`` (``debias="expected"``), the variant
  matching the paper-style analysis where the normaliser is a constant.

A config that can never drop an agent (``kind="full"``, or a static
Bernoulli ``rate >= 1`` with no active faults) normalises to ``None``
and the emitted program is byte-identical to the plain ``fedpg.run``
round — the same bitwise-off contract telemetry follows.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.service.faults import FaultConfig

PyTree = Any

__all__ = [
    "ParticipationConfig", "ServiceState", "expected_count", "init_state",
    "mask_agent_axis", "normalize", "participation_factor", "round_mask",
    "safe_inv", "scale_jaxpr",
]


@dataclass(frozen=True)
class ParticipationConfig:
    """Static (hashable) participation model; joins compiled-cache keys
    and sweep structure keys.  ``rate`` (bernoulli) may be a traced
    sweep-lane value; every other field is structural."""

    kind: str = "bernoulli"      # "bernoulli" | "subset" | "full"
    rate: float = 1.0            # Bernoulli participation probability
    subset: int = 0              # round-robin window size (kind="subset")
    debias: str = "realized"     # "realized" | "expected"
    faults: Optional[FaultConfig] = None

    def __post_init__(self):
        if self.kind not in ("bernoulli", "subset", "full"):
            raise ValueError(f"unknown participation kind {self.kind!r}")
        if self.debias not in ("realized", "expected"):
            raise ValueError(f"unknown debias mode {self.debias!r}")
        if self.kind == "subset" and self.subset < 1:
            raise ValueError("kind='subset' needs subset >= 1")
        if self.kind == "bernoulli" and isinstance(self.rate, (int, float)) \
                and not 0.0 < self.rate <= 1.0:
            raise ValueError("bernoulli rate must be in (0, 1]")


class ServiceState(NamedTuple):
    """The round-scan carry of a service run.  ``round_idx`` is the
    absolute round counter (checkpointable: a resumed service replays the
    identical mask stream); ``part_key`` seeds the per-round mask draws,
    ``sched_key`` the round-independent fault schedules; ``stale`` is the
    staleness buffer (:class:`repro.service.staleness.StaleState`) or
    None."""

    theta: PyTree
    round_idx: jax.Array               # () int32
    part_key: jax.Array
    sched_key: jax.Array
    stale: Optional[Any] = None


def normalize(participation: Optional[ParticipationConfig],
              n_agents: int) -> Optional[ParticipationConfig]:
    """Normalise: a config that can never drop an agent is
    participation-off (the emitted program must be byte-identical to
    ``participation=None``) — the telemetry ``_active_telemetry``
    contract, applied to participation."""
    p = participation
    if p is None:
        return None
    faulty = p.faults is not None and p.faults.active
    if faulty:
        return p
    if p.kind == "full":
        return None
    if p.kind == "bernoulli" and isinstance(p.rate, (int, float)) \
            and p.rate >= 1.0:
        return None
    if p.kind == "subset" and p.subset >= n_agents:
        return None
    return p


def init_state(theta: PyTree, key_svc: jax.Array, n_agents: int,
               staleness=None) -> ServiceState:
    """Fresh service state at round 0.  ``staleness`` is a normalised
    :class:`~repro.service.staleness.StalenessConfig` (or None)."""
    part_key, sched_key = jax.random.split(key_svc)
    stale = None
    if staleness is not None:
        from repro.service import staleness as _staleness

        stale = _staleness.init_state(staleness, theta, n_agents)
    return ServiceState(theta=theta,
                        round_idx=jnp.zeros((), jnp.int32),
                        part_key=part_key, sched_key=sched_key, stale=stale)


def round_mask(p: ParticipationConfig, part_key: jax.Array,
               sched_key: jax.Array, round_idx: jax.Array,
               agent_ids: jax.Array, n_agents: int) -> jax.Array:
    """(len(agent_ids),) bool participation mask for one round.

    ``agent_ids`` are ABSOLUTE agent indices — a shard or block passes
    its slice of ``arange(N)`` and gets exactly the rows of the full
    fleet's mask, which is what makes the mask block/shard invariant.
    """
    k_round = jax.random.fold_in(part_key, round_idx)
    k_bern, k_delay = jax.random.split(k_round)
    if p.kind == "bernoulli":
        def agent_draw(i):
            return jax.random.uniform(jax.random.fold_in(k_bern, i))

        mask = jax.vmap(agent_draw)(agent_ids) < p.rate
    elif p.kind == "subset":
        w = min(int(p.subset), n_agents)
        # round-robin window over absolute ids: exactly w participants,
        # rotating by w each round — deterministic, PRNG-free
        offset = (round_idx.astype(jnp.int32) * w) % n_agents
        mask = ((agent_ids.astype(jnp.int32) - offset) % n_agents) < w
    else:  # "full": only faults can drop agents
        mask = jnp.ones(agent_ids.shape, bool)
    if p.faults is not None and p.faults.active:
        mask = jnp.logical_and(
            mask, p.faults.up_mask(k_delay, sched_key, round_idx, agent_ids))
    return mask


def expected_count(p: ParticipationConfig, n_agents: int):
    """Closed-form ``E[participating count]`` — the ``expected_n`` debias
    normaliser.  Traced when ``rate`` is a packed sweep-lane value."""
    if p.kind == "bernoulli":
        base = p.rate * n_agents
    elif p.kind == "subset":
        base = float(min(int(p.subset), n_agents))
    else:
        base = float(n_agents)
    if p.faults is not None and p.faults.active:
        base = base * p.faults.availability()
    return base


def safe_inv(w):
    """``1/w`` with an exact-zero result at ``w == 0``: an empty round
    contributes an exact-zero term instead of NaN/inf."""
    w = jnp.asarray(w, jnp.float32)
    return jnp.where(w > 0, 1.0 / jnp.where(w > 0, w, 1.0), 0.0)


def participation_factor(n_agents: int, w_norm):
    """The ``N / W`` rescale that turns the full-fleet normaliser
    ``1/(N * m_h)`` into the participation normaliser ``1/(W * m_h)``;
    exact zero when ``W == 0`` so an empty round commits a zero update
    (the round's AWGN draw is discarded, never amplified)."""
    return n_agents * safe_inv(w_norm)


def mask_agent_axis(tree: PyTree, mask: jax.Array) -> PyTree:
    """Mask leading-axis rows to exact zeros (phantom-agent style)."""
    return jax.tree.map(
        lambda g: jnp.where(
            mask.reshape((-1,) + (1,) * (g.ndim - 1)),
            g, jnp.zeros_like(g)),
        tree)


def scale_jaxpr(p: ParticipationConfig, *, n_agents: int = 8):
    """Trace the round's debias normaliser for structural inspection.

    Returns the ClosedJaxpr of ``key -> N / W`` where ``W`` is the
    round's contribution weight under config ``p``.  This is the hook the
    ``participation-contract`` analyze check walks: with
    ``debias="realized"`` the key invar must be LIVE (the normaliser is
    data-dependent on the drawn mask — constant-folding it would silently
    revert to the expected-count analysis), with ``debias="expected"``
    the key invar must be DEAD (the normaliser is the closed form and
    must NOT consume the realisation).
    """
    def factor(key):
        if p.debias == "expected":
            return participation_factor(n_agents, expected_count(p, n_agents))
        part_key, sched_key = jax.random.split(key)
        ids = jnp.arange(n_agents, dtype=jnp.int32)
        mask = round_mask(p, part_key, sched_key,
                          jnp.zeros((), jnp.int32), ids, n_agents)
        return participation_factor(n_agents,
                                    jnp.sum(mask.astype(jnp.float32)))

    return jax.make_jaxpr(factor)(jax.random.key(0))
