"""Simple, dependency-free checkpointing.

Flattens a pytree with '/'-joined key paths into a single ``.npz`` per step
(atomic rename) plus a tiny JSON manifest recording the treedef, dtypes and
the step number.  Restore rebuilds the exact pytree structure; a target
"like" tree may be supplied to validate shapes/dtypes against.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

PyTree = Any

_STEP_RE = re.compile(r"^step_(\d+)\.npz$")

# numpy's npz format can't round-trip ml_dtypes (bf16/f8) natively; store the
# raw bits as a same-width integer and re-view on restore via the manifest.
_BITS_VIEW = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _to_storable(arr: np.ndarray):
    name = str(arr.dtype)
    if name in _BITS_VIEW:
        return arr.view(_BITS_VIEW[name]), name
    return arr, name


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _BITS_VIEW:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten(tree: PyTree):
    flat = {}

    def _fmt(path) -> str:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        return "/".join(parts)

    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        flat[_fmt(path)] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree: PyTree) -> str:
    """Write step_<step>.npz atomically; returns the path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    storable, dtypes = {}, {}
    for k, v in flat.items():
        storable[k], dtypes[k] = _to_storable(v)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "dtypes": dtypes,
        "shapes": {k: list(v.shape) for k, v in flat.items()},
    }
    path = os.path.join(ckpt_dir, f"step_{step}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **storable)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    with open(os.path.join(ckpt_dir, f"step_{step}.json"), "w") as f:
        json.dump(manifest, f)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for name in os.listdir(ckpt_dir)
        if (m := _STEP_RE.match(name))
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: PyTree) -> PyTree:
    """Load step_<step>.npz into the structure of ``like`` (shape-checked)."""
    path = os.path.join(ckpt_dir, f"step_{step}.npz")
    with open(os.path.join(ckpt_dir, f"step_{step}.json")) as f:
        manifest = json.load(f)
    with np.load(path) as data:
        flat = {
            k: _from_storable(data[k], manifest["dtypes"].get(k, str(data[k].dtype)))
            for k in data.files
        }

    ref = _flatten(like)
    missing = set(ref) - set(flat)
    extra = set(flat) - set(ref)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={missing} extra={extra}")
    for k, v in ref.items():
        if tuple(flat[k].shape) != tuple(v.shape):
            raise ValueError(
                f"shape mismatch for {k}: ckpt {flat[k].shape} vs model {v.shape}"
            )

    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)

    def _fmt(path) -> str:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        return "/".join(parts)

    new_leaves = [
        flat[_fmt(path)].astype(np.asarray(leaf).dtype)
        for path, leaf in leaves_paths
    ]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
