"""Grouped-query attention: full, sliding-window, cross, and cached decode.

Two numerics paths:

* ``attend`` — materialised-scores reference (differentiable; used for
  training at 4k and by smoke tests; also the oracle for the Pallas flash
  kernel).
* ``attend_blockwise`` — jnp online-softmax flash forward (lax.scan over KV
  blocks, O(S) memory) used for long prefill lowering where no gradient is
  required.  The Pallas kernel in ``kernels/flash_attention.py`` is the TPU
  version of the same schedule.

All shapes: q (B, Sq, H, Dh); k/v (B, Sk, Hkv, Dh); GQA via head grouping.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, rmsnorm_plan, rmsnorm
from repro.models.param import decl
from repro.utils import shard_hints as hints
from repro.utils import unroll as uscan

PyTree = Any
NEG_INF = -1e30


def attn_plan(cfg: ModelConfig) -> Dict:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "norm": rmsnorm_plan(d),
        "wq": decl((d, h, dh), ("d_model", "heads", None)),
        "wk": decl((d, hkv, dh), ("d_model", "kv_heads", None)),
        "wv": decl((d, hkv, dh), ("d_model", "kv_heads", None)),
        "wo": decl((h, dh, d), ("heads", None, "d_model"), fan_in_axes=(0, 1)),
    }


def _mask_bias(
    q_pos: jax.Array,
    k_pos: jax.Array,
    *,
    causal: bool,
    window: Optional[int],
    k_valid: Optional[jax.Array] = None,
) -> jax.Array:
    """(Sq, Sk) additive bias: 0 where visible, NEG_INF elsewhere."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    if k_valid is not None:
        ok &= k_valid[None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attend(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_pos: jax.Array,
    k_pos: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    k_valid: Optional[jax.Array] = None,
    expand_kv: bool = False,
) -> jax.Array:
    """Reference GQA attention with materialised (Sq, Sk) scores.

    ``expand_kv=True`` repeats KV heads up to the Q head count before the
    score matmul (the Megatron-TP convention): every einsum then carries a
    full 'heads' axis, so head sharding — or the context-parallel q_seq
    fallback (utils.shard_hints) — propagates cleanly.  Decode keeps the
    grouped form (expanding a 32k-slot cache per step would triple its
    footprint); its sharding comes from the cache specs instead.
    """
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    bias = _mask_bias(q_pos, k_pos, causal=causal, window=window, k_valid=k_valid)
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    if expand_kv:
        if g > 1:
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        q = hints.constrain(q, "batch", "q_seq", "heads", None)
        k = hints.constrain(k, "batch", None, "heads", None)
        v = hints.constrain(v, "batch", None, "heads", None)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
        scores = scores + bias
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
        return hints.constrain(out, "batch", "q_seq", "heads", None)
    qr = q.reshape(b, sq, hkv, g, dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k).astype(jnp.float32) * scale
    scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, h, dh)


def attend_blockwise(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_pos: jax.Array,
    k_pos: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    block_k: int = 1024,
) -> jax.Array:
    """Flash-style forward: scan KV blocks with online softmax (O(Sk) mem).

    Numerically matches ``attend`` (same f32 softmax); intended for prefill
    lowering where no backward pass is taken.  KV heads are expanded to the
    Q head count (see ``attend``) so head/context-parallel sharding holds.
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    block_k = min(block_k, sk)
    if sk % block_k != 0:
        # short/odd sequences (tests, tails): the materialised path is fine
        return attend(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal,
                      window=window, expand_kv=True)
    nblk = sk // block_k
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    q = hints.constrain(q, "batch", "q_seq", "heads", None)
    qr = q.astype(jnp.float32) / jnp.sqrt(jnp.float32(dh))

    kb = k.reshape(b, nblk, block_k, h, dh)
    vb = v.reshape(b, nblk, block_k, h, dh)
    kpb = k_pos.reshape(nblk, block_k)

    def body(carry, blk):
        m, l, acc = carry
        k_i, v_i, kp_i = blk
        k_i = hints.constrain(k_i, "batch", None, "heads", None)
        v_i = hints.constrain(v_i, "batch", None, "heads", None)
        s = jnp.einsum("bqhd,bkhd->bhqk", qr, k_i.astype(jnp.float32))
        s = s + _mask_bias(q_pos, kp_i, causal=causal, window=window)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, v_i.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    (m, l, acc), _ = uscan.scan(
        body,
        (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), kpb),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 1, 2)  # (b, sq, h, dh)
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# Module-level apply: projections + rope + attend (+cache handling)
# --------------------------------------------------------------------------

class KVCache(NamedTuple):
    """Fixed-capacity cache; ring-buffered when capacity < full context."""

    k: jax.Array          # (B, cap, Hkv, Dh) — rope already applied
    v: jax.Array          # (B, cap, Hkv, Dh)

    @property
    def capacity(self) -> int:
        return self.k.shape[1]


def init_cache(
    cfg: ModelConfig, batch: int, capacity: int, dtype
) -> KVCache:
    shape = (batch, capacity, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def _project_qkv(params: PyTree, x: jax.Array):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    return q, k, v


def _out_proj(params: PyTree, o: jax.Array) -> jax.Array:
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(o.dtype))
    return hints.constrain(out, "batch", "q_seq", None)


def self_attention(
    params: PyTree,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    blockwise: bool = False,
    positions: Optional[jax.Array] = None,
    return_kv: bool = False,
):
    """Full-sequence self attention (train / prefill / encoder)."""
    b, s, _ = x.shape
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    q, k, v = _project_qkv(params, h)
    pos = jnp.arange(s) if positions is None else positions
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    if blockwise:
        o = attend_blockwise(q, k, v, q_pos=pos, k_pos=pos, causal=causal,
                             window=window)
    else:
        o = attend(q, k, v, q_pos=pos, k_pos=pos, causal=causal,
                   window=window, expand_kv=True)
    out = _out_proj(params, o)
    if return_kv:
        return out, (k, v)
    return out


def cross_attention(
    params: PyTree,
    x: jax.Array,
    memory_kv: Tuple[jax.Array, jax.Array],
    cfg: ModelConfig,
) -> jax.Array:
    """Cross attention over precomputed memory K/V (no mask, no rope)."""
    b, sq, _ = x.shape
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, params["wq"].astype(x.dtype))
    k, v = memory_kv
    sk = k.shape[1]
    o = attend(
        q, k, v,
        q_pos=jnp.zeros((sq,), jnp.int32),
        k_pos=jnp.zeros((sk,), jnp.int32),
        causal=False,
        expand_kv=sq > 1,   # grouped path for 1-token decode
    )
    return _out_proj(params, o)


def project_memory(params: PyTree, memory: jax.Array):
    """Precompute cross-attention K/V from encoder/frontend output."""
    dt = memory.dtype
    k = jnp.einsum("bsd,dhk->bshk", memory, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", memory, params["wv"].astype(dt))
    return k, v


def decode_self_attention(
    params: PyTree,
    x: jax.Array,          # (B, 1, D) — the new token
    cache: KVCache,
    pos: jax.Array,        # scalar int32: absolute position of the new token
    cfg: ModelConfig,
    *,
    window: Optional[int] = None,
) -> Tuple[jax.Array, KVCache]:
    """One decode step against a (possibly ring-buffered) KV cache.

    Capacity == full context  -> plain causal cache (slot = pos).
    Capacity W < full context -> ring buffer (slot = pos mod W), giving
    sliding-window attention with O(W) memory — the sub-quadratic serving
    path used by long_500k.
    """
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    q, k, v = _project_qkv(params, h)
    q = apply_rope(q, pos[None], cfg.rope_theta)
    k = apply_rope(k, pos[None], cfg.rope_theta)

    cap = cache.capacity
    slot = jnp.mod(pos, cap)
    k_all = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
    v_all = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))

    # Absolute position stored in each slot s: the largest p <= pos with
    # p mod cap == s  ->  p = pos - ((pos - s) mod cap).
    slots = jnp.arange(cap)
    k_pos = pos - jnp.mod(pos - slots, cap)
    k_valid = k_pos >= 0
    eff_window = window if window is not None and window < cap else None
    o = attend(
        q, k_all, v_all,
        q_pos=pos[None],
        k_pos=k_pos,
        causal=True,
        window=eff_window,
        k_valid=k_valid,
    )
    return _out_proj(params, o), KVCache(k=k_all, v=v_all)


def decode_cross_attention(
    params: PyTree,
    x: jax.Array,
    memory_kv: Tuple[jax.Array, jax.Array],
    cfg: ModelConfig,
) -> jax.Array:
    """Cross attention during decode — the memory K/V are static."""
    return cross_attention(params, x, memory_kv, cfg)
