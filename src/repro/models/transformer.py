"""Family assemblies: dense / moe / ssm / hybrid / encdec / vlm.

Every family provides four pure functions over a parameter pytree built from
a single plan (``plan(cfg)``):

    forward(params, inputs)            -> logits (+ aux losses)
    loss(params, batch, weights)       -> scalar  (weights = OTA channel hook)
    prefill(params, inputs)            -> (last-position logits, cache)
    decode(params, cache, token, pos)  -> (logits, cache')

Layer stacks are ``lax.scan``-ed over stacked parameters (leading 'layers'
axis) to keep HLO size flat in depth — essential for compiling 95-layer
models against a 512-device mesh.  Heterogeneous-period families (VLM
cross-attn every k, zamba2 shared-attn every k) scan over *groups* with an
inner scan across the uniform sub-layers.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    chunked_lm_loss, embed, embed_plan, lm_loss, mlp, mlp_plan, rmsnorm,
    rmsnorm_plan, unembed,
)
from repro.models.param import stack_plan
from repro.utils import unroll as uscan

PyTree = Any


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def cross_len(cfg: ModelConfig, seq_len: int) -> int:
    """Length of the stub-frontend memory sequence."""
    if cfg.family == "vlm":
        return cfg.n_cross_tokens
    if cfg.family == "encdec":
        return max(seq_len // 4, 8)   # 4x-downsampled audio frames
    return 0


# ==========================================================================
# Plans
# ==========================================================================

def dense_layer_plan(cfg: ModelConfig) -> Dict:
    return {"attn": attn.attn_plan(cfg), "mlp": mlp_plan(cfg.d_model, cfg.d_ff)}


def moe_layer_plan(cfg: ModelConfig) -> Dict:
    return {"attn": attn.attn_plan(cfg), "moe": moe_mod.moe_plan(cfg)}


def cross_layer_plan(cfg: ModelConfig) -> Dict:
    return {
        "attn": attn.attn_plan(cfg),
        "cross": attn.attn_plan(cfg),
        "mlp": mlp_plan(cfg.d_model, cfg.d_ff),
    }


def plan(cfg: ModelConfig) -> Dict:
    p: Dict[str, Any] = {
        "embed": embed_plan(cfg),
        "final_norm": rmsnorm_plan(cfg.d_model),
    }
    fam = cfg.family
    if fam == "dense":
        p["layers"] = stack_plan(dense_layer_plan(cfg), cfg.n_layers)
    elif fam == "moe":
        p["layers"] = stack_plan(moe_layer_plan(cfg), cfg.n_layers)
    elif fam == "ssm":
        p["layers"] = stack_plan(ssm_mod.ssm_plan(cfg), cfg.n_layers)
    elif fam == "hybrid":
        per = cfg.shared_attn_every
        n_groups, tail = divmod(cfg.n_layers, per)
        p["mamba_groups"] = stack_plan(
            stack_plan(ssm_mod.ssm_plan(cfg), per, "sublayers"), n_groups
        )
        if tail:
            p["mamba_tail"] = stack_plan(ssm_mod.ssm_plan(cfg), tail)
        p["shared"] = dense_layer_plan(cfg)    # stored ONCE, applied n_groups x
    elif fam == "vlm":
        per = cfg.cross_attn_every
        assert cfg.n_layers % per == 0, (cfg.n_layers, per)
        n_groups = cfg.n_layers // per
        p["plain_groups"] = stack_plan(
            stack_plan(dense_layer_plan(cfg), per - 1, "sublayers"), n_groups
        )
        p["cross_layers"] = stack_plan(cross_layer_plan(cfg), n_groups)
    elif fam == "encdec":
        p["enc_layers"] = stack_plan(dense_layer_plan(cfg), cfg.encoder_layers)
        p["enc_norm"] = rmsnorm_plan(cfg.d_model)
        p["layers"] = stack_plan(cross_layer_plan(cfg), cfg.n_layers)
    else:
        raise ValueError(fam)
    return p


# ==========================================================================
# Layer bodies (shared by forward/prefill; decode variants below)
# ==========================================================================

def _dense_body(cfg, window, blockwise):
    def body(x, lp):
        x = x + attn.self_attention(
            lp["attn"], x, cfg, window=window, blockwise=blockwise
        )
        x = x + mlp(lp["mlp"], x, cfg.norm_eps)
        return x

    return body


def _moe_body(cfg, window, blockwise):
    def body(carry, lp):
        x, aux = carry
        x = x + attn.self_attention(
            lp["attn"], x, cfg, window=window, blockwise=blockwise
        )
        dx, a = moe_mod.moe_ffn(lp["moe"], x, cfg)
        return (x + dx, aux + a)

    return body


def _ssm_body(cfg):
    def body(x, lp):
        return x + ssm_mod.ssm_mixer(lp, x, cfg)

    return body


def _cross_body(cfg, memory_kv_fn, window, blockwise):
    """Self + cross + mlp; memory_kv_fn(lp) -> (k, v) for this layer."""

    def body(x, lp):
        x = x + attn.self_attention(
            lp["attn"], x, cfg, window=window, blockwise=blockwise
        )
        x = x + attn.cross_attention(lp["cross"], x, memory_kv_fn(lp), cfg)
        x = x + mlp(lp["mlp"], x, cfg.norm_eps)
        return x

    return body


def _scan(body, x0, stacked, cfg):
    def f(carry, lp):
        return body(carry, lp), None

    if cfg.remat:
        f = jax.checkpoint(f)
    carry, _ = uscan.scan(f, x0, stacked)
    return carry


# ==========================================================================
# Forward (training / full-sequence) per family
# ==========================================================================

def forward(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jax.Array,
    memory: Optional[jax.Array] = None,
    *,
    blockwise: bool = False,
    return_hidden: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits | final-norm hidden, aux)."""
    dt = _dtype(cfg)
    x = embed(params["embed"], tokens, dt)
    aux = jnp.zeros((), jnp.float32)
    fam = cfg.family
    win = cfg.window

    if fam == "dense":
        x = _scan(_dense_body(cfg, win, blockwise), x, params["layers"], cfg)
    elif fam == "moe":
        x, aux = _scan(
            _moe_body(cfg, win, blockwise), (x, aux), params["layers"], cfg,
        )
    elif fam == "ssm":
        x = _scan(_ssm_body(cfg), x, params["layers"], cfg)
    elif fam == "hybrid":
        shared = params["shared"]

        def group_body(x, gp):
            x = _scan(_ssm_body(cfg), x, gp, cfg)
            x = x + attn.self_attention(shared["attn"], x, cfg, window=win,
                                        blockwise=blockwise)
            x = x + mlp(shared["mlp"], x, cfg.norm_eps)
            return x

        x = _scan(group_body, x, params["mamba_groups"], cfg)
        if "mamba_tail" in params:
            x = _scan(_ssm_body(cfg), x, params["mamba_tail"], cfg)
    elif fam == "vlm":
        assert memory is not None, "vlm needs patch embeddings"
        mem = memory.astype(dt)

        def group_body(x, gp):
            x = _scan(_dense_body(cfg, win, blockwise), x, gp["plain"], cfg)
            cl = gp["cross"]
            kv = attn.project_memory(cl["cross"], mem)
            x = _cross_body(cfg, lambda _: kv, win, blockwise)(x, cl)
            return x

        stacked = {"plain": params["plain_groups"], "cross": params["cross_layers"]}
        x = _scan(group_body, x, stacked, cfg)
    elif fam == "encdec":
        assert memory is not None, "encdec needs frame embeddings"
        enc = encode(params, cfg, memory, blockwise=blockwise)

        def body(x, lp):
            kv = attn.project_memory(lp["cross"], enc)
            return _cross_body(cfg, lambda _: kv, win, blockwise)(x, lp)

        x = _scan(body, x, params["layers"], cfg)
    else:
        raise ValueError(fam)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, aux
    logits = unembed(params["embed"], x, cfg.tie_embeddings)
    return logits, aux


def encode(
    params: PyTree, cfg: ModelConfig, frames: jax.Array, *, blockwise: bool = False
) -> jax.Array:
    """Bidirectional encoder over stub frame embeddings (B, M, D)."""
    x = frames.astype(_dtype(cfg))

    def body(x, lp):
        x = x + attn.self_attention(lp["attn"], x, cfg, causal=False,
                                    blockwise=blockwise)
        x = x + mlp(lp["mlp"], x, cfg.norm_eps)
        return x

    x = _scan(body, x, params["enc_layers"], cfg)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def loss(
    params: PyTree,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    weights: Optional[jax.Array] = None,
    *,
    loss_chunk: int = 1024,
) -> jax.Array:
    """Next-token CE (+ MoE aux). ``weights``: per-sequence OTA gains.

    The CE is evaluated in rematerialised sequence chunks so the (B, S,
    vocab) f32 logits are never resident (big-vocab memory lever)."""
    hidden, aux = forward(
        params, cfg, batch["tokens"], batch.get("memory"), blockwise=False,
        return_hidden=True,
    )
    ce = chunked_lm_loss(
        params["embed"], hidden, batch["labels"], cfg.tie_embeddings,
        weights, chunk=loss_chunk,
    )
    return ce + aux


# ==========================================================================
# Caches
# ==========================================================================

class Cache(NamedTuple):
    """Decode-time state for every family (unused fields are None)."""

    kv: Any = None           # dense/moe: KVCache with leading (L,) axes
    ssm: Any = None          # ssm: SSMState with leading (L,)
    groups_kv: Any = None    # hybrid: shared-attn KVCache (G, ...); vlm plain (G, per-1, ...)
    groups_ssm: Any = None   # hybrid: SSMState (G, per, ...)
    tail_ssm: Any = None     # hybrid tail: SSMState (r, ...)
    cross_self_kv: Any = None  # vlm cross-layer self KV (G, ...)
    cross_kv: Any = None     # vlm/encdec: projected memory K/V
    pos: Any = None          # scalar int32 — next absolute position


def _stack_init(fn, n):
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), fn)


def init_cache(
    cfg: ModelConfig,
    batch: int,
    capacity: int,
    mem_len: int = 0,
    dtype=None,
) -> Cache:
    """Zero-initialised cache. ``capacity`` already reflects serve_window
    clamping (see server.cache_capacity)."""
    dt = dtype or _dtype(cfg)
    fam = cfg.family
    pos = jnp.zeros((), jnp.int32)

    def kv(n, cap=capacity):
        c = attn.init_cache(cfg, batch, cap, dt)
        return attn.KVCache(*(jnp.zeros((n,) + a.shape, a.dtype) for a in c))

    def sstate(n):
        s = ssm_mod.init_state(cfg, batch, dt)
        return ssm_mod.SSMState(*(jnp.zeros((n,) + a.shape, a.dtype) for a in s))

    def sstate2(n1, n2):
        s = ssm_mod.init_state(cfg, batch, dt)
        return ssm_mod.SSMState(
            *(jnp.zeros((n1, n2) + a.shape, a.dtype) for a in s)
        )

    def cross(n):
        shape = (n, batch, mem_len, cfg.n_kv_heads, cfg.head_dim)
        return (jnp.zeros(shape, dt), jnp.zeros(shape, dt))

    if fam in ("dense", "moe"):
        return Cache(kv=kv(cfg.n_layers), pos=pos)
    if fam == "ssm":
        return Cache(ssm=sstate(cfg.n_layers), pos=pos)
    if fam == "hybrid":
        per = cfg.shared_attn_every
        n_groups, tail = divmod(cfg.n_layers, per)
        return Cache(
            groups_ssm=sstate2(n_groups, per),
            groups_kv=kv(n_groups),
            tail_ssm=sstate(tail) if tail else None,
            pos=pos,
        )
    if fam == "vlm":
        per = cfg.cross_attn_every
        n_groups = cfg.n_layers // per
        plain = attn.init_cache(cfg, batch, capacity, dt)
        plain = attn.KVCache(
            *(jnp.zeros((n_groups, per - 1) + a.shape, a.dtype) for a in plain)
        )
        return Cache(
            groups_kv=plain,
            cross_self_kv=kv(n_groups),
            cross_kv=cross(n_groups),
            pos=pos,
        )
    if fam == "encdec":
        return Cache(kv=kv(cfg.n_layers), cross_kv=cross(cfg.n_layers), pos=pos)
    raise ValueError(fam)


# ==========================================================================
# Decode (one token against the cache) per family
# ==========================================================================

def decode(
    params: PyTree,
    cfg: ModelConfig,
    cache: Cache,
    token: jax.Array,       # (B, 1) int32
    *,
    window: Optional[int] = None,
) -> Tuple[jax.Array, Cache]:
    """serve_step: one new token per sequence. Returns (logits (B,1,V), cache')."""
    dt = _dtype(cfg)
    x = embed(params["embed"], token, dt)
    pos = cache.pos
    fam = cfg.family
    new = cache

    if fam in ("dense", "moe"):
        def body(x, xs):
            lp, c = xs
            dx, c2 = attn.decode_self_attention(
                lp["attn"], x, c, pos, cfg, window=window
            )
            x = x + dx
            if fam == "dense":
                x = x + mlp(lp["mlp"], x, cfg.norm_eps)
            else:
                dxm, _ = moe_mod.moe_ffn(lp["moe"], x, cfg)
                x = x + dxm
            return x, c2

        x, kv2 = uscan.scan(body, x, (params["layers"], cache.kv))
        new = cache._replace(kv=kv2)

    elif fam == "ssm":
        def body(x, xs):
            lp, s = xs
            dx, s2 = ssm_mod.ssm_step(lp, x, s, cfg)
            return x + dx, s2

        x, s2 = uscan.scan(body, x, (params["layers"], cache.ssm))
        new = cache._replace(ssm=s2)

    elif fam == "hybrid":
        shared = params["shared"]

        def group_body(x, xs):
            gp, gs, gkv = xs

            def inner(x, ys):
                lp, s = ys
                dx, s2 = ssm_mod.ssm_step(lp, x, s, cfg)
                return x + dx, s2

            x, gs2 = uscan.scan(inner, x, (gp, gs))
            dx, gkv2 = attn.decode_self_attention(
                shared["attn"], x, gkv, pos, cfg, window=window
            )
            x = x + dx
            x = x + mlp(shared["mlp"], x, cfg.norm_eps)
            return x, (gs2, gkv2)

        x, (gs2, gkv2) = uscan.scan(
            group_body, x, (params["mamba_groups"], cache.groups_ssm,
                            cache.groups_kv)
        )
        tail2 = cache.tail_ssm
        if "mamba_tail" in params:
            def inner(x, ys):
                lp, s = ys
                dx, s2 = ssm_mod.ssm_step(lp, x, s, cfg)
                return x + dx, s2

            x, tail2 = uscan.scan(
                inner, x, (params["mamba_tail"], cache.tail_ssm)
            )
        new = cache._replace(groups_ssm=gs2, groups_kv=gkv2, tail_ssm=tail2)

    elif fam == "vlm":
        def group_body(x, xs):
            gp, cl, pkv, skv, ckv = xs

            def inner(x, ys):
                lp, c = ys
                dx, c2 = attn.decode_self_attention(
                    lp["attn"], x, c, pos, cfg, window=window
                )
                x = x + dx
                x = x + mlp(lp["mlp"], x, cfg.norm_eps)
                return x, c2

            x, pkv2 = uscan.scan(inner, x, (gp, pkv))
            dx, skv2 = attn.decode_self_attention(
                cl["attn"], x, skv, pos, cfg, window=window
            )
            x = x + dx
            x = x + attn.decode_cross_attention(cl["cross"], x, ckv, cfg)
            x = x + mlp(cl["mlp"], x, cfg.norm_eps)
            return x, (pkv2, skv2)

        x, (pkv2, skv2) = uscan.scan(
            group_body,
            x,
            (params["plain_groups"], params["cross_layers"], cache.groups_kv,
             cache.cross_self_kv, cache.cross_kv),
        )
        new = cache._replace(groups_kv=pkv2, cross_self_kv=skv2)

    elif fam == "encdec":
        def body(x, xs):
            lp, c, ckv = xs
            dx, c2 = attn.decode_self_attention(
                lp["attn"], x, c, pos, cfg, window=window
            )
            x = x + dx
            x = x + attn.decode_cross_attention(lp["cross"], x, ckv, cfg)
            x = x + mlp(lp["mlp"], x, cfg.norm_eps)
            return x, c2

        x, kv2 = uscan.scan(body, x, (params["layers"], cache.kv,
                                        cache.cross_kv))
        new = cache._replace(kv=kv2)
    else:
        raise ValueError(fam)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg.tie_embeddings)
    return logits, new._replace(pos=pos + 1)


# ==========================================================================
# Prefill: full forward that also fills the cache (dense/moe/encdec only —
# SSM/hybrid prefill = chunked forward carrying state; provided for dense
# families where the assigned prefill_32k shape applies).
# ==========================================================================

def prefill(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jax.Array,
    memory: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Cache]:
    """Process the prompt, return (last-position logits, filled cache)."""
    dt = _dtype(cfg)
    b, s = tokens.shape
    x = embed(params["embed"], tokens, dt)
    win = cfg.window
    fam = cfg.family
    pos = jnp.asarray(s, jnp.int32)

    if fam in ("dense", "moe"):
        def body(x, lp):
            out, (k, v) = attn.self_attention(
                lp["attn"], x, cfg, window=win, blockwise=True, return_kv=True
            )
            x = x + out
            if fam == "dense":
                x = x + mlp(lp["mlp"], x, cfg.norm_eps)
            else:
                dxm, _ = moe_mod.moe_ffn(lp["moe"], x, cfg)
                x = x + dxm
            return x, attn.KVCache(k=k, v=v)

        x, kvs = uscan.scan(body, x, params["layers"])
        cache = Cache(kv=kvs, pos=pos)
    elif fam == "encdec":
        assert memory is not None
        enc = encode(params, cfg, memory, blockwise=True)

        def body(x, lp):
            out, (k, v) = attn.self_attention(
                lp["attn"], x, cfg, window=win, blockwise=True, return_kv=True
            )
            x = x + out
            ckv = attn.project_memory(lp["cross"], enc)
            x = x + attn.cross_attention(lp["cross"], x, ckv, cfg)
            x = x + mlp(lp["mlp"], x, cfg.norm_eps)
            return x, (attn.KVCache(k=k, v=v), ckv)

        x, (kvs, ckvs) = uscan.scan(body, x, params["layers"])
        cache = Cache(kv=kvs, cross_kv=ckvs, pos=pos)
    elif fam == "vlm":
        assert memory is not None
        mem = memory.astype(dt)

        def group_body(x, gp):
            def inner(x, lp):
                out, (k, v) = attn.self_attention(
                    lp["attn"], x, cfg, window=win, blockwise=True,
                    return_kv=True,
                )
                x = x + out
                x = x + mlp(lp["mlp"], x, cfg.norm_eps)
                return x, attn.KVCache(k=k, v=v)

            x, pkv = uscan.scan(inner, x, gp["plain"])
            cl = gp["cross"]
            out, (k, v) = attn.self_attention(
                cl["attn"], x, cfg, window=win, blockwise=True, return_kv=True
            )
            x = x + out
            ckv = attn.project_memory(cl["cross"], mem)
            x = x + attn.cross_attention(cl["cross"], x, ckv, cfg)
            x = x + mlp(cl["mlp"], x, cfg.norm_eps)
            return x, (pkv, attn.KVCache(k=k, v=v), ckv)

        stacked = {"plain": params["plain_groups"], "cross": params["cross_layers"]}
        x, (pkv, skv, ckv) = uscan.scan(group_body, x, stacked)
        cache = Cache(groups_kv=pkv, cross_self_kv=skv, cross_kv=ckv, pos=pos)
    elif fam in ("ssm", "hybrid"):
        # SSM prefill = forward; decode state would be carried by a chunked
        # scan — we expose forward-only prefill (logits) for these families.
        logits, _ = forward(params, cfg, tokens, memory, blockwise=False)
        return logits[:, -1:, :], init_cache(cfg, b, 1, 0)
    else:
        raise ValueError(fam)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x[:, -1:, :], cfg.tie_embeddings)
    return logits, cache
