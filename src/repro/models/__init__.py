"""Model substrate: composable transformer/SSM families as pure functions.

Every architecture is described by a *parameter plan* (a pytree of
``ParamDecl``) from which three consistent artifacts derive:
    - initialised parameters          (``param.init_params``)
    - ``jax.ShapeDtypeStruct`` stand-ins for dry-runs (no allocation)
    - ``PartitionSpec`` trees for pjit (``param.partition_specs``)

``model.build(config)`` returns a ``Model`` bundle of pure functions
(init/loss/prefill/decode) for any of the six assigned families.
"""
from repro.models import model  # noqa: F401
