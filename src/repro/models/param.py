"""Parameter plans: one declaration tree -> params, abstract shapes, specs.

A ``ParamDecl`` names every dimension of every weight with a *logical axis*
('d_model', 'd_ff', 'heads', 'experts', ...).  Sharding is then a pure
function of (plan, rules, mesh): each logical axis maps to zero or more mesh
axes, and any mapping whose product doesn't divide the dimension is dropped
(replicated) instead of failing — so the same plan serves the 1-device smoke
tests, the (16,16) pod and the (2,16,16) multi-pod mesh.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


@dataclass(frozen=True)
class ParamDecl:
    """Declaration of one weight tensor."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis per dim (None = never shard)
    init: str = "normal"                 # normal | zeros | ones | uniform | custom
    scale: Optional[float] = None        # stddev; None -> 1/sqrt(fan_in)
    fan_in_axes: Tuple[int, ...] = (0,)  # dims counted as fan-in
    dtype: Optional[str] = None          # override model dtype (e.g. fp32 norms)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def stddev(self) -> float:
        if self.scale is not None:
            return self.scale
        fan_in = 1
        for a in self.fan_in_axes:
            fan_in *= self.shape[a]
        return 1.0 / math.sqrt(max(fan_in, 1))


def decl(shape, axes, **kw) -> ParamDecl:
    return ParamDecl(tuple(shape), tuple(axes), **kw)


def stack_plan(plan: PyTree, n: int, axis_name: str = "layers") -> PyTree:
    """Prepend a stacked-layer dimension to every decl (for lax.scan bodies)."""

    def _stack(d: ParamDecl) -> ParamDecl:
        return ParamDecl(
            shape=(n,) + d.shape,
            axes=(axis_name,) + d.axes,
            init=d.init,
            scale=d.scale,
            fan_in_axes=tuple(a + 1 for a in d.fan_in_axes),
            dtype=d.dtype,
        )

    return jax.tree.map(_stack, plan, is_leaf=lambda x: isinstance(x, ParamDecl))


def _is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def init_params(key: jax.Array, plan: PyTree, dtype=jnp.float32) -> PyTree:
    """Materialise a plan into initialised parameters."""
    leaves, treedef = jax.tree.flatten(plan, is_leaf=_is_decl)
    keys = jax.random.split(key, len(leaves))

    def _one(k, d: ParamDecl):
        dt = jnp.dtype(d.dtype) if d.dtype else jnp.dtype(dtype)
        if d.init == "zeros":
            return jnp.zeros(d.shape, dt)
        if d.init == "ones":
            return jnp.ones(d.shape, dt)
        if d.init == "uniform":
            s = d.stddev()
            return jax.random.uniform(k, d.shape, jnp.float32, -s, s).astype(dt)
        if d.init == "dt_bias":
            # mamba2 dt bias: softplus^-1 of dt ~ U[dt_min, dt_max]
            u = jax.random.uniform(k, d.shape, jnp.float32, 1e-3, 1e-1)
            return jnp.log(jnp.expm1(u)).astype(dt)
        if d.init == "a_log":
            # mamba2 A_log: A ~ U[1, 16], stored as log
            u = jax.random.uniform(k, d.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(dt)
        return (jax.random.normal(k, d.shape, jnp.float32) * d.stddev()).astype(dt)

    return jax.tree.unflatten(treedef, [_one(k, d) for k, d in zip(keys, leaves)])


def abstract_params(plan: PyTree, dtype=jnp.float32) -> PyTree:
    """ShapeDtypeStruct stand-ins (for .lower() without allocation)."""

    def _one(d: ParamDecl):
        dt = jnp.dtype(d.dtype) if d.dtype else jnp.dtype(dtype)
        return jax.ShapeDtypeStruct(d.shape, dt)

    return jax.tree.map(_one, plan, is_leaf=_is_decl)


# --------------------------------------------------------------------------
# Sharding rules
# --------------------------------------------------------------------------

Rules = Mapping[str, Tuple[str, ...]]  # logical axis -> mesh axes


def _mesh_axis_size(mesh: Mesh, names: Sequence[str]) -> int:
    n = 1
    for name in names:
        n *= mesh.shape[name]
    return n


def spec_for(
    d: ParamDecl, rules: Rules, mesh: Mesh
) -> P:
    """PartitionSpec for one decl under the rules, replicating any dim whose
    size isn't divisible by its assigned mesh-axis product, and never
    assigning the same mesh axis twice in one spec."""
    used: set = set()
    parts = []
    for dim, axis in zip(d.shape, d.axes):
        entry = None
        if axis is not None and axis in rules:
            mesh_axes = tuple(a for a in rules[axis] if a in mesh.shape and a not in used)
            if mesh_axes and dim % _mesh_axis_size(mesh, mesh_axes) == 0:
                entry = mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
                used.update(mesh_axes)
        parts.append(entry)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def partition_specs(plan: PyTree, rules: Rules, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda d: spec_for(d, rules, mesh), plan, is_leaf=_is_decl)


def named_shardings(plan: PyTree, rules: Rules, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda d: NamedSharding(mesh, spec_for(d, rules, mesh)),
        plan,
        is_leaf=_is_decl,
    )


# Canonical rule-sets.  'data' axes shard FSDP-style (ZeRO-3) in training;
# serving keeps weights replicated across 'data' so decode needs no gathers.
def train_rules(fsdp: bool = True) -> Dict[str, Tuple[str, ...]]:
    r: Dict[str, Tuple[str, ...]] = {
        "d_ff": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "d_inner": ("model",),
        "ssm_heads": ("model",),
    }
    if fsdp:
        r["d_model"] = ("data",)
    return r


def serve_rules() -> Dict[str, Tuple[str, ...]]:
    return {
        "d_ff": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "d_inner": ("model",),
        "ssm_heads": ("model",),
    }
