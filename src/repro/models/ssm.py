"""Mamba2 / SSD (state-space duality) mixer [arXiv:2405.21060].

The SSD recurrence  s_t = exp(dt_t A) s_{t-1} + dt_t B_t x_t,  y_t = C_t s_t
is evaluated chunk-wise (chunk Q, MXU-aligned): a quadratic intra-chunk term
(the "duality" — an attention-like (Q,Q) matmul with a decay mask) plus an
inter-chunk state carry (lax.scan over chunks).  ``ssd_ref`` is the pure-jnp
oracle; ``kernels/ssd_scan.py`` is the Pallas TPU version of the same
schedule.  ``ssm_step`` is the O(1) recurrent decode form — equality between
``ssd_ref`` and repeated ``ssm_step`` is property-tested.

Projections are split per segment (z/x/B/C/dt) rather than fused, so the
'd_inner'/'ssm_heads' logical axes shard cleanly (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.layers import rmsnorm
from repro.models.param import decl
from repro.utils import shard_hints as hints
from repro.utils import unroll as uscan

PyTree = Any


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.headdim
    return d_inner, n_heads, s.n_groups, s.state


def ssm_plan(cfg: ModelConfig) -> Dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in, h, g, n = dims(cfg)
    return {
        "norm": {"scale": decl((d,), ("d_model",), init="ones", dtype="float32")},
        "w_z": decl((d, d_in), ("d_model", "d_inner")),
        "w_x": decl((d, d_in), ("d_model", "d_inner")),
        "w_B": decl((d, g * n), ("d_model", None)),
        "w_C": decl((d, g * n), ("d_model", None)),
        "w_dt": decl((d, h), ("d_model", "ssm_heads")),
        "conv_x": decl((s.conv_width, d_in), (None, "d_inner"), scale=0.5),
        "conv_B": decl((s.conv_width, g * n), (None, None), scale=0.5),
        "conv_C": decl((s.conv_width, g * n), (None, None), scale=0.5),
        "dt_bias": decl((h,), ("ssm_heads",), init="dt_bias", dtype="float32"),
        "A_log": decl((h,), ("ssm_heads",), init="a_log", dtype="float32"),
        "D": decl((h,), ("ssm_heads",), init="ones", dtype="float32"),
        "gate_norm": {
            "scale": decl((d_in,), ("d_inner",), init="ones", dtype="float32")
        },
        "w_out": decl((d_in, d), ("d_inner", "d_model")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv along time. x: (B,S,C); w: (W,C).

    Returns (y, new_state) where state keeps the last W-1 inputs for decode.
    """
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    new_state = xp[:, -(width - 1):, :] if width > 1 else pad
    return y, new_state


def ssd_ref(
    x: jax.Array,     # (B, S, H, P) — dt-weighted inputs applied inside
    dt: jax.Array,    # (B, S, H) — post-softplus
    A: jax.Array,     # (H,) — negative
    B: jax.Array,     # (B, S, G, N)
    C: jax.Array,     # (B, S, G, N)
    chunk: int,
) -> jax.Array:
    """Chunked SSD scan, f32 math. Returns y: (B, S, H, P).

    Sequences shorter than / not divisible by ``chunk`` are zero-padded on
    the right: dt=0 padding steps have decay exp(0)=1 and zero input, so
    they are exact no-ops on both the outputs and the carried state.
    """
    b, s_orig, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    chunk = min(chunk, s_orig) if s_orig < chunk else chunk
    pad = -s_orig % chunk
    if pad:
        zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, B, C = zp(x), zp(dt), zp(B), zp(C)
    s = s_orig + pad
    nc = s // chunk

    f32 = jnp.float32
    x = x.astype(f32)
    dt = dt.astype(f32)
    B = B.astype(f32)
    C = C.astype(f32)

    da = dt * A[None, None, :]                                  # (b,s,h) <= 0
    dax = x * dt[..., None]                                     # dt-weighted input

    xc = dax.reshape(b, nc, chunk, g, hg, p)
    dac = da.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)

    cum = jnp.cumsum(dac, axis=2)                               # (b,nc,Q,h)
    cum_g = cum.reshape(b, nc, chunk, g, hg)

    # ---- intra-chunk (quadratic, attention-like) -------------------------
    scores = jnp.einsum("bcqgn,bckgn->bcgqk", Cc, Bc)           # (b,nc,g,Q,Q)
    # seg[q, k] = cum[q] - cum[k] = sum_{tau in (k, q]} da_tau   (<= 0)
    seg = (
        cum_g[:, :, :, None, :, :] - cum_g[:, :, None, :, :, :]
    )                                                            # (b,nc,Q,K,g,hg)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(mask[None, None, :, :, None, None], jnp.exp(seg), 0.0)
    y_intra = jnp.einsum("bcgqk,bcqkgh,bckghp->bcqghp", scores, decay, xc)

    # ---- chunk states -----------------------------------------------------
    last = cum[:, :, -1:, :]                                    # (b,nc,1,h)
    decay_to_end = jnp.exp(last - cum).reshape(b, nc, chunk, g, hg)
    states = jnp.einsum("bcqgn,bcqgh,bcqghp->bcghpn", Bc, decay_to_end, xc)

    # ---- inter-chunk carry -------------------------------------------------
    chunk_decay = jnp.exp(last[:, :, 0, :]).reshape(b, nc, g, hg)

    def body(s_prev, inp):
        st, dec = inp                                           # (b,g,hg,p,n), (b,g,hg)
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    s0 = jnp.zeros((b, g, hg, p, n), f32)
    _, s_prevs = uscan.scan(
        body,
        s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                       # (b,nc,g,hg,p,n)

    y_inter = jnp.einsum(
        "bcqgn,bcghpn,bcqgh->bcqghp",
        Cc,
        s_prevs,
        jnp.exp(cum_g),
    )

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y[:, :s_orig]


class SSMState(NamedTuple):
    """Decode-time recurrent state for one SSM layer."""

    ssm: jax.Array      # (B, G, H/G, P, N) f32
    conv_x: jax.Array   # (B, W-1, d_inner)
    conv_B: jax.Array   # (B, W-1, G*N)
    conv_C: jax.Array   # (B, W-1, G*N)


def init_state(cfg: ModelConfig, batch: int, dtype) -> SSMState:
    s = cfg.ssm
    d_in, h, g, n = dims(cfg)
    w = s.conv_width
    return SSMState(
        ssm=jnp.zeros((batch, g, h // g, s.headdim, n), jnp.float32),
        conv_x=jnp.zeros((batch, w - 1, d_in), dtype),
        conv_B=jnp.zeros((batch, w - 1, g * n), dtype),
        conv_C=jnp.zeros((batch, w - 1, g * n), dtype),
    )


def _project(params: PyTree, h: jax.Array, cfg: ModelConfig):
    dt_ = h.dtype
    z = jnp.einsum("bsd,de->bse", h, params["w_z"].astype(dt_))
    xs = jnp.einsum("bsd,de->bse", h, params["w_x"].astype(dt_))
    Bp = jnp.einsum("bsd,de->bse", h, params["w_B"].astype(dt_))
    Cp = jnp.einsum("bsd,de->bse", h, params["w_C"].astype(dt_))
    dt = jnp.einsum("bsd,dh->bsh", h, params["w_dt"].astype(dt_))
    return z, xs, Bp, Cp, dt


def ssm_mixer(
    params: PyTree, x: jax.Array, cfg: ModelConfig
) -> jax.Array:
    """Full-sequence Mamba2 block body (pre-norm residual branch)."""
    b, s, d = x.shape
    scfg = cfg.ssm
    d_in, h_heads, g, n = dims(cfg)
    hid = rmsnorm(params["norm"], x, cfg.norm_eps)
    z, xs, Bp, Cp, dt = _project(params, hid, cfg)
    z = hints.constrain(z, "batch", None, "d_inner")
    xs = hints.constrain(xs, "batch", None, "d_inner")

    xs, _ = _causal_conv(xs, params["conv_x"].astype(x.dtype))
    Bp, _ = _causal_conv(Bp, params["conv_B"].astype(x.dtype))
    Cp, _ = _causal_conv(Cp, params["conv_C"].astype(x.dtype))
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)
    Bp = jax.nn.silu(Bp.astype(jnp.float32)).astype(x.dtype)
    Cp = jax.nn.silu(Cp.astype(jnp.float32)).astype(x.dtype)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    xh = xs.reshape(b, s, h_heads, scfg.headdim)
    xh = hints.constrain(xh, "batch", None, "ssm_heads", None)
    Bh = Bp.reshape(b, s, g, n)
    Ch = Cp.reshape(b, s, g, n)

    y = ssd_ref(xh, dt, A, Bh, Ch, scfg.chunk)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_in).astype(x.dtype)

    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm({"scale": params["gate_norm"]["scale"]}, y, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(x.dtype))
    return hints.constrain(out, "batch", None, None)


def ssm_step(
    params: PyTree, x: jax.Array, state: SSMState, cfg: ModelConfig
) -> Tuple[jax.Array, SSMState]:
    """One-token recurrent step: x (B, 1, D) -> (y (B, 1, D), state')."""
    b = x.shape[0]
    scfg = cfg.ssm
    d_in, h_heads, g, n = dims(cfg)
    hid = rmsnorm(params["norm"], x, cfg.norm_eps)
    z, xs, Bp, Cp, dt = _project(params, hid, cfg)

    xs, cx = _causal_conv(xs, params["conv_x"].astype(x.dtype), state.conv_x)
    Bp, cb = _causal_conv(Bp, params["conv_B"].astype(x.dtype), state.conv_B)
    Cp, cc = _causal_conv(Cp, params["conv_C"].astype(x.dtype), state.conv_C)
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)
    Bp = jax.nn.silu(Bp.astype(jnp.float32)).astype(x.dtype)
    Cp = jax.nn.silu(Cp.astype(jnp.float32)).astype(x.dtype)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]  # (b,h)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A[None, :])                            # (b,h)

    xh = xs.reshape(b, h_heads, scfg.headdim).astype(jnp.float32)
    Bh = Bp.reshape(b, g, n).astype(jnp.float32)
    Ch = Cp.reshape(b, g, n).astype(jnp.float32)
    hg = h_heads // g

    dax = xh * dt[..., None]                                    # (b,h,p)
    dax_g = dax.reshape(b, g, hg, scfg.headdim)
    decay_g = decay.reshape(b, g, hg)

    new_ssm = state.ssm * decay_g[..., None, None] + jnp.einsum(
        "bgn,bghp->bghpn", Bh, dax_g
    )
    y = jnp.einsum("bgn,bghpn->bghp", Ch, new_ssm)              # (b,g,hg,p)
    y = y + params["D"].reshape(1, g, hg)[..., None] * xh.reshape(
        b, g, hg, scfg.headdim
    )
    y = y.reshape(b, 1, d_in).astype(x.dtype)

    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm({"scale": params["gate_norm"]["scale"]}, y, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(x.dtype))
    return out, SSMState(ssm=new_ssm, conv_x=cx, conv_B=cb, conv_C=cc)
