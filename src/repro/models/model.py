"""Public model API: ``build(config)`` -> a bundle of pure functions.

Also provides ``abstract_inputs`` — the ShapeDtypeStruct stand-ins for every
(config x input-shape) combination, used by smoke tests, the data pipeline
contract, and the multi-pod dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer
from repro.models.param import (
    abstract_params, init_params, partition_specs, Rules,
)

PyTree = Any


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    plan: Any

    def init(self, key: jax.Array) -> PyTree:
        return init_params(key, self.plan, jnp.dtype(self.cfg.dtype))

    def abstract(self) -> PyTree:
        return abstract_params(self.plan, jnp.dtype(self.cfg.dtype))

    def specs(self, rules: Rules, mesh) -> PyTree:
        return partition_specs(self.plan, rules, mesh)

    # pure functions ------------------------------------------------------
    def loss(self, params, batch, weights=None):
        return transformer.loss(params, self.cfg, batch, weights)

    def forward(self, params, tokens, memory=None, *, blockwise=False):
        return transformer.forward(
            params, self.cfg, tokens, memory, blockwise=blockwise
        )

    def prefill(self, params, tokens, memory=None):
        return transformer.prefill(params, self.cfg, tokens, memory)

    def decode(self, params, cache, token, *, window=None):
        return transformer.decode(
            params, self.cfg, cache, token, window=window
        )

    def init_cache(self, batch, capacity, mem_len=0, dtype=None):
        return transformer.init_cache(
            self.cfg, batch, capacity, mem_len, dtype
        )


def build(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg, plan=transformer.plan(cfg))


# --------------------------------------------------------------------------
# Input contracts
# --------------------------------------------------------------------------

def serve_capacity(cfg: ModelConfig, seq_len: int) -> int:
    """KV-cache slots for decode: full context, or the SWA ring if the arch
    serves long contexts through a sliding window (DESIGN.md §4)."""
    win = cfg.window or cfg.serve_window
    if win is not None and win < seq_len:
        return win
    return seq_len


def needs_memory(cfg: ModelConfig) -> bool:
    return cfg.family in ("vlm", "encdec")


def abstract_inputs(
    cfg: ModelConfig, shape: InputShape, *, dtype: Optional[str] = None
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one step of the given kind.

    train:   {tokens, labels[, memory]}          (B, S) int32
    prefill: {tokens[, memory]}
    decode:  {token}  (B, 1) — cache/params come from their own specs
    """
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(dtype or cfg.dtype)
    i32 = jnp.int32
    mem_len = transformer.cross_len(cfg, s)

    def mem():
        return jax.ShapeDtypeStruct((b, mem_len, cfg.d_model), dt)

    if shape.kind == "train":
        out = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        if needs_memory(cfg):
            out["memory"] = mem()
        return out
    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if needs_memory(cfg):
            out["memory"] = mem()
        return out
    if shape.kind == "decode":
        return {"token": jax.ShapeDtypeStruct((b, 1), i32)}
    raise ValueError(shape.kind)


def abstract_cache(cfg: ModelConfig, shape: InputShape) -> PyTree:
    """ShapeDtypeStruct tree matching init_cache for the decode shapes."""
    cap = serve_capacity(cfg, shape.seq_len)
    mem_len = transformer.cross_len(cfg, shape.seq_len)
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, shape.global_batch, cap, mem_len)
    )
    return cache
