"""Shared neural building blocks (plan builders + pure apply functions)."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import decl
from repro.utils import shard_hints as hints

PyTree = Any


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------

def rmsnorm_plan(d: int) -> Dict:
    return {"scale": decl((d,), ("d_model",), init="ones", dtype="float32")}


def rmsnorm(params: PyTree, x: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(dtype)


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------

def embed_plan(cfg: ModelConfig) -> Dict:
    p = {"tok": decl((cfg.vocab, cfg.d_model), ("vocab", "d_model"), scale=0.02)}
    if not cfg.tie_embeddings:
        p["head"] = decl((cfg.d_model, cfg.vocab), ("d_model", "vocab"))
    return p


def embed(params: PyTree, tokens: jax.Array, dtype) -> jax.Array:
    x = params["tok"].astype(dtype)[tokens]
    return hints.constrain(x, "batch", "q_seq", None)


def unembed(params: PyTree, x: jax.Array, tie: bool) -> jax.Array:
    w = params["tok"].T if tie else params["head"]
    return jnp.einsum("...d,dv->...v", x, w.astype(x.dtype))


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                  # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., :, None, :]                     # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------

def mlp_plan(d_model: int, d_ff: int) -> Dict:
    return {
        "norm": rmsnorm_plan(d_model),
        "gate": decl((d_model, d_ff), ("d_model", "d_ff")),
        "up": decl((d_model, d_ff), ("d_model", "d_ff")),
        "down": decl((d_ff, d_model), ("d_ff", "d_model")),
    }


def mlp(params: PyTree, x: jax.Array, eps: float) -> jax.Array:
    h = rmsnorm(params["norm"], x, eps)
    g = jnp.einsum("...d,df->...f", h, params["gate"].astype(x.dtype))
    u = jnp.einsum("...d,df->...f", h, params["up"].astype(x.dtype))
    g = hints.constrain(g, "batch", "q_seq", "d_ff")
    u = hints.constrain(u, "batch", "q_seq", "d_ff")
    act = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out = jnp.einsum("...f,fd->...d", act, params["down"].astype(x.dtype))
    return hints.constrain(out, "batch", "q_seq", None)


# --------------------------------------------------------------------------
# Cross-entropy LM loss
# --------------------------------------------------------------------------

def lm_loss(
    logits: jax.Array,
    labels: jax.Array,
    weights: Optional[jax.Array] = None,
) -> jax.Array:
    """Mean next-token cross-entropy; ``weights`` optionally reweights each
    sequence (the OTA channel-weighted-loss hook: weight = h_{agent(seq)})."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold                                     # (batch, seq)
    per_seq = jnp.mean(nll, axis=-1)                      # (batch,)
    if weights is not None:
        per_seq = per_seq * weights
    return jnp.mean(per_seq)


def chunked_lm_loss(
    embed_params: PyTree,
    hidden: jax.Array,              # (B, S, D) — post-final-norm
    labels: jax.Array,              # (B, S)
    tie: bool,
    weights: Optional[jax.Array] = None,
    chunk: int = 1024,
) -> jax.Array:
    """CE without materialising the (B, S, vocab) f32 logits: scan over seq
    chunks with remat, so both fwd and bwd hold one (B, chunk, vocab) block
    (1.7 GB -> 0.2 GB/device on deepseek-67b train — EXPERIMENTS.md §Perf).
    """
    from repro.utils import unroll as uscan

    b, s, d = hidden.shape
    if s % chunk != 0:
        return lm_loss(
            unembed(embed_params, hidden, tie), labels, weights
        )
    nc = s // chunk
    hc = jnp.moveaxis(hidden.reshape(b, nc, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    def body(carry, blk):
        h, lab = blk
        logits = unembed(embed_params, h, tie).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(logz - gold, axis=-1), None

    body = jax.checkpoint(body)
    nll_sum, _ = uscan.scan(body, jnp.zeros((b,), jnp.float32), (hc, lc))
    per_seq = nll_sum / s
    if weights is not None:
        per_seq = per_seq * weights
    return jnp.mean(per_seq)
