"""Mixture-of-Experts FFN: top-k routing, capacity-based dropless-ish dispatch.

Dispatch uses sort-free gather/scatter (one-hot *cumsum* for intra-expert
ranks, then scatter into an (E, C, d) buffer), NOT one-hot matmuls — so the
compiled FLOPs scale with top_k like a real TPU MoE, and ``cost_analysis``
reflects the paper-relevant active-parameter compute.  Expert weights carry an
'experts' logical axis so expert parallelism is a sharding rule
('experts' -> 'model'), with XLA inserting the all-to-all.

OTA note (DESIGN.md §Arch-applicability): per-agent expert-gradient sparsity
makes MoE the worst-case family for OTA SNR — the dense channel noise hits
every expert's parameters while only top_k experts per token receive signal.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rmsnorm, rmsnorm_plan
from repro.models.param import decl
from repro.utils import shard_hints as hints
from repro.utils.tree import ceil_div

PyTree = Any


def moe_plan(cfg: ModelConfig) -> Dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    return {
        "norm": rmsnorm_plan(d),
        "router": decl((d, e), ("d_model", None), scale=0.02),
        "gate": decl((e, d, ff), ("experts", "d_model", "d_ff"), fan_in_axes=(1,)),
        "up": decl((e, d, ff), ("experts", "d_model", "d_ff"), fan_in_axes=(1,)),
        "down": decl((e, ff, d), ("experts", "d_ff", "d_model"), fan_in_axes=(1,)),
    }


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = ceil_div(n_tokens * m.top_k, m.num_experts)
    c = int(c * m.capacity_factor)
    return max(8, ((c + 7) // 8) * 8)  # pad to 8 for TPU-friendly layouts


def route(
    params: PyTree, x_flat: jax.Array, cfg: ModelConfig, key=None
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing. Returns (expert_idx (T,k), gates (T,k), aux_loss)."""
    m = cfg.moe
    logits = jnp.einsum(
        "td,de->te", x_flat.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    if key is not None and m.router_jitter > 0.0:
        logits = logits + m.router_jitter * jax.random.normal(key, logits.shape)
    gates_full = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(gates_full, m.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss.
    t = x_flat.shape[0]
    me = jnp.mean(gates_full, axis=0)                          # (E,)
    ce = jnp.zeros((m.num_experts,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (t * m.top_k)
    )
    aux = m.num_experts * jnp.sum(me * ce) * m.load_balance_coef
    return idx, gates.astype(x_flat.dtype), aux


def moe_ffn(
    params: PyTree, x: jax.Array, cfg: ModelConfig, key=None
) -> Tuple[jax.Array, jax.Array]:
    """MoE feed-forward over (B, S, D). Returns (out, aux_loss)."""
    b, s, d = x.shape
    m = cfg.moe
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    x_flat = h.reshape(b * s, d)
    t = b * s
    cap = _capacity(t, cfg)

    idx, gates, aux = route(params, x_flat, cfg, key)

    # intra-expert rank of each (token, slot) assignment, via a stable sort
    # by expert id + per-expert offsets (bincount).  NB: the one-hot-cumsum
    # formulation is O(T*k*E) *and* lowers through quadratic-cost
    # reduce-window prefix sums on some backends — see EXPERIMENTS.md §Perf
    # (granite-moe prefill hillclimb) for the measured 33x flops difference.
    flat_e = idx.reshape(-1)                                   # (T*k,)
    n_assign = t * m.top_k
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    counts = jnp.bincount(flat_e, length=m.num_experts)
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]]
    )
    rank_sorted = jnp.arange(n_assign, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    rank = jnp.zeros((n_assign,), jnp.int32).at[sort_idx].set(rank_sorted)
    keep = rank < cap
    dest = jnp.where(keep, flat_e * cap + rank, m.num_experts * cap)

    # scatter tokens into the (E*C, d) buffer (dropped tokens fall off the end)
    src = jnp.repeat(x_flat, m.top_k, axis=0)                  # (T*k, d)
    buf = jnp.zeros((m.num_experts * cap + 1, d), x.dtype).at[dest].set(src)
    buf = buf[:-1].reshape(m.num_experts, cap, d)

    # per-expert SwiGLU — batched matmul over the experts axis.  The
    # capacity dim shards over the data axes (each shard owns a slice of
    # every expert's token slots — the all-to-all dispatch pattern), and the
    # expert dim over 'model' where divisible; otherwise d_ff carries the
    # model axis.  Without the capacity constraint GSPMD replicated the
    # whole global-capacity buffer on every data shard (16x expert compute,
    # measured on mixtral prefill — EXPERIMENTS.md §Perf).
    dt = x.dtype
    serve = hints.has("moe_cap")   # serve-only: see utils/shard_hints notes
    if serve:
        buf = hints.constrain(buf, "experts", "moe_cap", None)
    g = jnp.einsum("ecd,edf->ecf", buf, params["gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, params["up"].astype(dt))
    if serve:
        g = hints.constrain(g, "experts", "moe_cap", "d_ff")
        u = hints.constrain(u, "experts", "moe_cap", "d_ff")
    act = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    y = jnp.einsum("ecf,efd->ecd", act, params["down"].astype(dt))
    if serve:
        y = hints.constrain(y, "experts", "moe_cap", None)

    # gather back and mix with gates (dropped assignments contribute zero)
    y_flat = y.reshape(m.num_experts * cap, d)
    safe = jnp.where(keep, dest, 0)
    picked = y_flat[safe] * keep[:, None].astype(dt)           # (T*k, d)
    picked = picked.reshape(t, m.top_k, d)
    out = jnp.sum(picked * gates[..., None], axis=1)
    out = hints.constrain(out.reshape(b, s, d), "batch", "q_seq", None)
    return out, aux
