"""Render a JSONL run ledger as a markdown report.

    python -m repro.telemetry.report LEDGER.jsonl [-o REPORT.md]

Sections (each only when the ledger carries matching events): platform,
compile counts, the per-scenario sweep table — measured ``avg_grad_sq``
against the Theorem-1/2 floors with the distance-to-floor and the in-jit
telemetry summaries (effective SNR, moment drift, grad-norm dispersion,
and — for service scenarios — the realised participation rate and mean
staleness) — the round-service commit log, and the benchmark rows.  This
is the human end of the observability pipeline: sweep/bench/service run
-> ``Ledger`` -> this report.
"""
from __future__ import annotations

import argparse
from typing import Any, Dict, List, Optional, Sequence

from repro.telemetry.ledger import read_ledger

__all__ = ["render"]


def _fmt(v: Any) -> str:
    if v is None or v == "":
        return "-"
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _table(cols: Sequence[str], rows: List[Dict[str, Any]]) -> List[str]:
    lines = ["| " + " | ".join(cols) + " |",
             "|" + "---|" * len(cols)]
    for row in rows:
        lines.append("| " + " | ".join(_fmt(row.get(c)) for c in cols) + " |")
    return lines


def _scenario_row(ev: Dict[str, Any]) -> Dict[str, Any]:
    tel = ev.get("telemetry") or {}
    return {
        "tag": ev.get("tag") or ev.get("index"),
        "env": ev.get("env"), "channel": ev.get("channel"),
        "noise_sigma": ev.get("noise_sigma"), "m_h_eff": ev.get("m_h_eff"),
        "final_reward": ev.get("final_reward"),
        "avg_grad_sq": ev.get("avg_grad_sq"),
        "floor": ev.get("floor"), "floor_which": ev.get("floor_which"),
        "dist_to_floor": ev.get("distance_to_floor"),
        "snr": tel.get("snr"), "drift": tel.get("moment_drift"),
        "dispersion": tel.get("dispersion"),
        # round-service probes: realised participation rate and mean
        # replayed age (present only for scenarios run with an active
        # ParticipationConfig / staleness replay)
        "part_rate": tel.get("participation_rate"),
        "staleness": tel.get("staleness_mean"),
    }


def render(events: List[Dict[str, Any]], title: str = "Run report") -> str:
    by_kind: Dict[str, List[Dict[str, Any]]] = {}
    for ev in events:
        by_kind.setdefault(ev.get("kind", "?"), []).append(ev)
    out: List[str] = [f"# {title}", ""]

    for ev in by_kind.get("platform", []):
        out += ["## Platform", ""]
        out += [f"- **{k}**: `{_fmt(v)}`" for k, v in sorted(ev.items())
                if k not in ("kind", "ts")]
        out.append("")

    if "compiles" in by_kind:
        out += ["## Compiled programs", ""]
        out += _table(["label", "count"], by_kind["compiles"])
        out.append("")

    sweeps = by_kind.get("sweep", [])
    scenarios = by_kind.get("scenario", [])
    if sweeps or scenarios:
        out += ["## Sweeps", ""]
        if sweeps:
            out += _table(["label", "n_scenarios", "n_partitions", "mc_runs",
                           "mode", "n_devices", "n_compiles"], sweeps)
            out.append("")
    if scenarios:
        out += ["### Scenarios: measured avg_grad_sq vs theory floors", ""]
        out += _table(
            ["tag", "env", "channel", "noise_sigma", "m_h_eff",
             "final_reward", "avg_grad_sq", "floor", "floor_which",
             "dist_to_floor", "snr", "drift", "dispersion",
             "part_rate", "staleness"],
            [_scenario_row(ev) for ev in scenarios])
        out.append("")

    if "service" in by_kind:
        out += ["## Round service", ""]
        out += _table(
            ["round_start", "round_end", "reward", "grad_sq", "gain_mean",
             "participation_rate", "participation_drift", "staleness_mean",
             "staleness_hist", "deadline_exceeded", "wall_us"],
            by_kind["service"])
        out.append("")

    if "bench_row" in by_kind:
        out += ["## Benchmark rows", ""]
        out += _table(["name", "us_per_call", "compile_us", "run_us",
                       "derived"], by_kind["bench_row"])
        out.append("")

    return "\n".join(out).rstrip() + "\n"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.report",
        description="render a JSONL run ledger as markdown")
    ap.add_argument("ledger", help="path to a LEDGER.jsonl file")
    ap.add_argument("-o", "--out", default="",
                    help="write the report here (default: stdout)")
    ap.add_argument("--title", default="Run report")
    args = ap.parse_args(argv)

    text = render(read_ledger(args.ledger), title=args.title)
    if args.out:
        with open(args.out, "w", encoding="utf-8") as f:
            f.write(text)
    else:
        print(text, end="")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
