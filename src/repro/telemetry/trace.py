"""Span tracer: the one timing mechanism for sweeps and benchmarks.

Replaces the ad-hoc ``time.perf_counter`` arithmetic that used to be
copy-pasted across ``sweep()`` and ``benchmarks/*.py`` with a span-tree
API::

    from repro.telemetry import trace

    with trace.span("compile", partition=3) as sp:
        compiled = jitted.lower(args).compile()
    print(sp.duration_us)

Spans nest (a ``with`` inside a ``with`` becomes a child span) and the
whole tree exports as Chrome trace-event JSON — ``trace.export(path)``
writes a ``{"traceEvents": [...]}`` document loadable in Perfetto or
``chrome://tracing``.  The process-global tracer is what the module-level
helpers operate on; ``Tracer`` instances can be used standalone (tests).

This module is the *owner* of raw-clock access: the ``raw-timing`` analyze
rule flags ``time.perf_counter()`` call sites anywhere outside
``src/repro/telemetry/``, so new timing code must come through here.

``jax_profile(logdir)`` optionally bridges a block to ``jax.profiler.trace``
for XLA-level timelines next to the host-side spans; it degrades to a
no-op when the profiler is unavailable.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional

__all__ = [
    "Span", "Timing", "Tracer", "export", "get_tracer", "jax_profile",
    "reset", "span", "spans", "timed_call", "to_chrome_trace",
]


@dataclass
class Span:
    """One timed interval.  ``duration_us`` is valid after the ``with``
    block exits; ``attrs`` may be extended inside the block (they export
    as the Chrome event's ``args``)."""

    name: str
    start_us: float
    duration_us: float = 0.0
    attrs: Dict[str, Any] = field(default_factory=dict)
    children: List["Span"] = field(default_factory=list)
    tid: int = 0


class Timing(float):
    """A median-microseconds float that also carries the compile/run split.

    ``float(t)`` (and all arithmetic) is the median run time per call in
    microseconds, so existing ``emit(name, time_call(...), ...)`` callers
    keep working; ``t.compile_us`` is the first-call (compile-inclusive)
    wall time and ``t.run_us`` the steady-state median.
    """

    compile_us: Optional[float]
    run_us: float

    def __new__(cls, run_us: float, compile_us: Optional[float] = None):
        self = float.__new__(cls, run_us)
        self.run_us = float(run_us)
        self.compile_us = None if compile_us is None else float(compile_us)
        return self


class Tracer:
    """A span tree with a per-thread open-span stack."""

    def __init__(self) -> None:
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._stacks: Dict[int, List[Span]] = {}
        self.roots: List[Span] = []

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    @contextmanager
    def span(self, name: str, **attrs: Any) -> Iterator[Span]:
        tid = threading.get_ident()
        sp = Span(name=name, start_us=self._now_us(), attrs=dict(attrs),
                  tid=tid & 0xFFFF)
        with self._lock:
            stack = self._stacks.setdefault(tid, [])
            (stack[-1].children if stack else self.roots).append(sp)
            stack.append(sp)
        try:
            yield sp
        finally:
            sp.duration_us = self._now_us() - sp.start_us
            with self._lock:
                self._stacks[tid].pop()

    def reset(self) -> None:
        self.__init__()

    def spans(self) -> List[Span]:
        return list(self.roots)

    def to_chrome_trace(self) -> Dict[str, Any]:
        """The span tree as a Chrome trace-event document (Perfetto-loadable):
        one ``ph="X"`` complete event per span, µs timestamps."""
        pid = os.getpid()
        events: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": "repro"},
        }]

        def visit(sp: Span) -> None:
            events.append({
                "name": sp.name, "cat": "repro", "ph": "X",
                "ts": sp.start_us, "dur": sp.duration_us,
                "pid": pid, "tid": sp.tid,
                "args": {k: _json_safe(v) for k, v in sp.attrs.items()},
            })
            for child in sp.children:
                visit(child)

        for root in self.roots:
            visit(root)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> Dict[str, Any]:
        doc = self.to_chrome_trace()
        with open(path, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=1)
        return doc


def _json_safe(v: Any) -> Any:
    """Chrome's ``args`` values must be JSON: numbers/strings/bools pass
    through (non-finite floats stringify), everything else reprs."""
    if isinstance(v, bool) or v is None or isinstance(v, (int, str)):
        return v
    if isinstance(v, float):
        return v if v == v and abs(v) != float("inf") else repr(v)
    return repr(v)


# ---------------------------------------------------------------------------
# The process-global tracer (what sweep/benchmarks record into).
# ---------------------------------------------------------------------------

_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def span(name: str, **attrs: Any):
    """``with trace.span("dispatch", partition=i) as sp: ...``"""
    return _TRACER.span(name, **attrs)


def reset() -> None:
    _TRACER.reset()


def spans() -> List[Span]:
    return _TRACER.spans()


def to_chrome_trace() -> Dict[str, Any]:
    return _TRACER.to_chrome_trace()


def export(path: str) -> Dict[str, Any]:
    """Write the global span tree as Chrome trace JSON; returns the doc."""
    return _TRACER.export(path)


def timed_call(
    fn: Callable,
    *args: Any,
    warmup: int = 1,
    iters: int = 5,
    block: Optional[Callable[[Any], Any]] = None,
    name: Optional[str] = None,
) -> Timing:
    """Median wall time per call, with the compile/run split as spans.

    The first warmup call runs inside a ``compile:<name>`` span (for jitted
    callables that is where compilation lands); the timed iterations run
    inside one ``run:<name>`` span.  ``block`` is applied to each result
    before the clock stops (pass ``jax.block_until_ready`` for jax work —
    this module deliberately does not import jax).
    """
    label = name or getattr(fn, "__name__", None) or "call"
    sink = block if block is not None else (lambda x: x)
    compile_us: Optional[float] = None
    if warmup > 0:
        with span(f"compile:{label}") as sp:
            sink(fn(*args))
        compile_us = sp.duration_us
        for _ in range(warmup - 1):
            sink(fn(*args))
    times = []
    with span(f"run:{label}", iters=iters) as sp:
        for _ in range(iters):
            t0 = time.perf_counter()
            sink(fn(*args))
            times.append(time.perf_counter() - t0)
    times.sort()
    run_us = times[len(times) // 2] * 1e6
    sp.attrs["median_us"] = run_us
    return Timing(run_us, compile_us=compile_us)


@contextmanager
def jax_profile(logdir: str) -> Iterator[None]:
    """Bridge a block to ``jax.profiler.trace(logdir)`` (XLA timeline next
    to the host spans); silently a no-op when jax or its profiler is
    unavailable."""
    try:
        from jax import profiler
        ctx = profiler.trace(str(logdir))
    except Exception:
        yield
        return
    with ctx:
        yield
