"""Structured run ledger: one JSONL event stream per sweep/bench run.

A :class:`Ledger` appends one JSON object per line — platform records
(``repro.utils.platform.describe``), compile counts (the existing
``repro.analyze.budget`` machinery), benchmark rows, and per-scenario sweep
records carrying the measured ``avg_grad_sq`` next to the Theorem-1/2
noise floors (``repro.core.theory.floor_report``) and the in-jit telemetry
summaries.  ``python -m repro.telemetry.report LEDGER.jsonl`` renders the
stream as a markdown report.

The *ambient* ledger (:func:`set_ledger` / :func:`get_ledger`) lets deep
call sites — ``benchmarks.common.emit`` / ``run_sweep`` — log without
threading a handle through every signature; ``benchmarks/run.py --ledger
LEDGER.jsonl`` installs one for the whole bench run.

Every value is sanitised to strict JSON (non-finite floats become the
strings ``"inf"``/``"nan"``) so artifacts survive any JSON parser.
"""
from __future__ import annotations

import json
import math
import time
import warnings
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional

__all__ = ["Ledger", "get_ledger", "read_ledger", "set_ledger",
           "using_ledger"]

_SCHEMA_VERSION = 1


def _json_safe(v: Any) -> Any:
    if isinstance(v, dict):
        return {str(k): _json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    if isinstance(v, bool) or v is None or isinstance(v, (int, str)):
        return v
    if isinstance(v, float):
        if math.isfinite(v):
            return v
        return "nan" if math.isnan(v) else ("inf" if v > 0 else "-inf")
    try:  # numpy scalars
        return _json_safe(float(v))
    except (TypeError, ValueError):
        return repr(v)


class Ledger:
    """Append-only JSONL event log.  Usable as a context manager."""

    def __init__(self, path: str, *, mode: str = "w") -> None:
        self.path = str(path)
        self._f = open(self.path, mode, encoding="utf-8")
        self.event("ledger_start", schema_version=_SCHEMA_VERSION)

    # -- core --------------------------------------------------------------

    def event(self, kind: str, **payload: Any) -> Dict[str, Any]:
        rec = {"ts": time.time(), "kind": kind, **payload}
        self._f.write(json.dumps(_json_safe(rec)) + "\n")
        self._f.flush()
        return rec

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "Ledger":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # -- canned records ----------------------------------------------------

    def log_platform(self) -> None:
        """One ``platform`` event from ``repro.utils.platform.describe()``."""
        from repro.utils import platform as rplat

        self.event("platform", **rplat.describe())

    @contextmanager
    def count_compiles(self, label: str = "") -> Iterator[None]:
        """Run a block under the analyze compile counter and log the count
        (the same ``jax.monitoring`` listener the budget contracts use)."""
        from repro.analyze.budget import CompileCounter

        with CompileCounter() as c:
            yield
        self.event("compiles", label=label, count=c.count)

    def log_service(self, **payload: Any) -> None:
        """One ``service`` event per round-service commit
        (``repro.service.driver.RoundService``): the commit's round range,
        mean reward / grad-sq / gain, the realised participation rate and
        debias drift, and — when staleness replay is on — the live buffer's
        age histogram."""
        self.event("service", **payload)

    def log_sweep(self, result, *, constants=None, V: Optional[float] = None,
                  label: str = "") -> None:
        """Per-scenario records for one ``SweepResult``.

        Each ``scenario`` event carries the flat descriptor
        (``Scenario.describe()``), the measured ``final_reward`` /
        ``avg_grad_sq`` / ``mean_gain``, the per-scenario wall-time share,
        and — when in-jit telemetry ran — the probe summary.  With ``V``
        (or ``constants``, an ``MDPConstants`` whose ``V()`` is used) the
        Theorem-1/2 floors and the measured distance-to-floor are attached
        via ``theory.floor_report``.
        """
        from repro.core import theory

        v_env = V if V is not None else (
            constants.V() if constants is not None else None)
        self.event("sweep", label=label, n_scenarios=len(result),
                   n_partitions=result.n_partitions, mc_runs=result.mc_runs,
                   mode=result.mode, n_devices=result.n_devices,
                   n_compiles=result.n_compiles)
        for i, s in enumerate(result.scenarios):
            rec: Dict[str, Any] = {"index": i, "label": label, **s.describe()}
            rec["final_reward"] = result.final_reward(i)
            rec["avg_grad_sq"] = result.avg_grad_sq(i)
            rec["scenario_time_us"] = result.scenario_time_us(i)
            tel = result.telemetry_summary(i)
            if tel is not None:
                rec["telemetry"] = tel
            if v_env is not None:
                m_h, sigma_h2 = s.effective_moments()
                fr = theory.floor_report(
                    n_agents=s.n_agents, batch_m=s.batch_m, m_h=m_h,
                    sigma_h2=sigma_h2, noise_sigma2=s.noise_sigma**2, V=v_env)
                rec.update(fr)
                rec["distance_to_floor"] = rec["avg_grad_sq"] - fr["floor"]
            self.event("scenario", **rec)


# ---------------------------------------------------------------------------
# Ambient ledger.
# ---------------------------------------------------------------------------

_AMBIENT: Optional[Ledger] = None


def set_ledger(ledger: Optional[Ledger]) -> None:
    global _AMBIENT
    _AMBIENT = ledger


def get_ledger() -> Optional[Ledger]:
    return _AMBIENT


@contextmanager
def using_ledger(ledger: Ledger) -> Iterator[Ledger]:
    """Install ``ledger`` as the ambient ledger for the block."""
    prev = get_ledger()
    set_ledger(ledger)
    try:
        yield ledger
    finally:
        set_ledger(prev)


# ---------------------------------------------------------------------------
# Reading.
# ---------------------------------------------------------------------------

def read_ledger(path: str) -> List[Dict[str, Any]]:
    """Parse a JSONL ledger, skipping malformed lines with a warning (a
    crashed run may truncate its last record — the rest stays usable)."""
    events: List[Dict[str, Any]] = []
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                warnings.warn(f"{path}:{lineno}: skipping malformed ledger "
                              "line", stacklevel=2)
                continue
            if not isinstance(rec, dict) or "kind" not in rec:
                warnings.warn(f"{path}:{lineno}: skipping non-event record",
                              stacklevel=2)
                continue
            events.append(rec)
    return events
