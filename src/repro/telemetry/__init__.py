"""Observability for the repro: in-jit probes, span traces, run ledgers.

Three layers, designed to compose:

* :mod:`repro.telemetry.probes` — a static :class:`TelemetryConfig` that,
  threaded through ``fedpg.run``/``monte_carlo``/``sweep``, makes every
  communication round emit a :class:`RoundTelemetry` pytree (effective
  SNR, pre/post-aggregation gradient norms, channel-moment drift, per-agent
  grad-norm dispersion) as extra scan outputs — computed *inside* the
  jitted program.  Telemetry off (the default) is bitwise identical to the
  pre-telemetry programs: the golden-trace suite pins this.
* :mod:`repro.telemetry.trace` — the span tracer (``with trace.span(...)``)
  that owns all wall-clock timing; exports Chrome trace-event JSON
  (Perfetto-loadable) of sweep partition compile/dispatch/materialize and
  benchmark phases.
* :mod:`repro.telemetry.ledger` — a JSONL event log per run (platform,
  compile counts, per-scenario results vs the Theorem-1/2 floors,
  telemetry summaries) rendered to markdown by
  ``python -m repro.telemetry.report``.

The ``trace`` and ``ledger`` modules are themselves jax-free (import them
directly in jax-less tooling); only ``probes`` — and this package init,
which re-exports it — pulls in jax.
"""
from repro.telemetry.ledger import (  # noqa: F401
    Ledger, get_ledger, read_ledger, set_ledger, using_ledger,
)
from repro.telemetry.probes import (  # noqa: F401
    RoundTelemetry, TelemetryConfig,
)
from repro.telemetry import trace  # noqa: F401

__all__ = [
    "Ledger", "RoundTelemetry", "TelemetryConfig", "get_ledger",
    "read_ledger", "set_ledger", "trace", "using_ledger",
]
