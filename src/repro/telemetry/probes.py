"""In-jit round probes: per-round diagnostics as extra scan outputs.

A :class:`TelemetryConfig` passed to ``fedpg.make_round_fn`` /
``fedpg.run`` / ``sweep()`` makes each communication round emit a
:class:`RoundTelemetry` pytree alongside the existing metrics — the
quantities the paper's analysis is stated in terms of but ``History``
never recorded:

=================  =========================================================
``snr``            effective receive SNR ``||sum_i h_i g_i||^2 / (d sigma_z^2)``
                   (scale-invariant: identical before/after the debias
                   normalisation; ``inf`` for noiseless/exact uplinks)
``grad_norm_pre``  mean per-agent local gradient norm (pre-aggregation)
``grad_norm_post`` norm of the applied server update ``u_k`` (post-aggregation)
``moment_drift``   realised ``mean(h)`` minus the closed-form effective
                   ``m_h`` (``ota.effective_gain_mean``) — the debias error
``dispersion``     per-agent grad-norm heterogeneity ``max_i||g_i|| / mean_i||g_i||``
=================  =========================================================

Everything is computed *inside* the jitted round (no extra dispatches);
disabled individual probes emit NaN constants so the pytree structure stays
static across configs.  With ``telemetry=None`` (the default) none of this
code reaches the trace: the telemetry-off jaxpr — and therefore every
golden trace — is bitwise identical to the pre-telemetry program.

Both round forms are covered: the stacked/vmap form
(:func:`stacked_round_probes`) and the ``agent_mesh`` shard_map form
(:func:`sharded_round_probes`, psum/pmax reductions over the agent axis).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.ota import OTAConfig

PyTree = Any


def _ota():
    # deferred: repro.core.fedpg imports this module at class-definition
    # time, so a top-level `from repro.core import ota` would be circular
    # when repro.telemetry is the entry point (e.g. the report CLI).
    from repro.core import ota
    return ota


__all__ = ["RoundTelemetry", "TelemetryConfig", "participation_probes",
           "sharded_round_probes", "sharded_streamed_round_probes",
           "stacked_round_probes", "streamed_round_probes", "summarize"]


@dataclass(frozen=True)
class TelemetryConfig:
    """Static (hashable) probe selection; all probes default on.

    Hashability matters: the config joins the compiled-callable cache keys
    in ``fedpg`` so telemetry-on and telemetry-off programs cache
    separately.  A config with every probe off is *inactive* and compiles
    the exact telemetry-off program (``active`` gates all emission).
    """

    snr: bool = True
    grad_norms: bool = True
    moment_drift: bool = True
    dispersion: bool = True
    participation: bool = True

    @property
    def active(self) -> bool:
        # deliberately excludes ``participation``: the service probes only
        # exist when a ParticipationConfig is active on the run, so the
        # flag alone must not activate telemetry (an all-base-off config
        # stays bitwise-off on every non-service run; ``fedpg`` treats the
        # flag as active exactly when a service round can feed it)
        return self.snr or self.grad_norms or self.moment_drift \
            or self.dispersion


class RoundTelemetry(NamedTuple):
    """Per-round probe outputs (float32 scalars inside the round; stacked
    to ``(K,)`` by the scan, ``(mc, K)`` by monte-carlo, ``(S, mc, K)`` by
    the sweep engine).  Disabled probes hold NaN.

    The three service probes (``participation_rate``,
    ``participation_drift``, ``staleness_mean``) default to ``None`` —
    an *absent* pytree node, not a NaN leaf — so every run without an
    active :class:`~repro.service.participation.ParticipationConfig`
    emits the exact pre-service telemetry pytree (golden traces and scan
    output structures are unchanged).  They hold arrays only when the
    round service attaches them via :func:`participation_probes`."""

    snr: jax.Array
    grad_norm_pre: jax.Array
    grad_norm_post: jax.Array
    moment_drift: jax.Array
    dispersion: jax.Array
    participation_rate: Optional[jax.Array] = None   # realised count / N
    participation_drift: Optional[jax.Array] = None  # realised - expected rate
    staleness_mean: Optional[jax.Array] = None       # mean replayed age


def _nan() -> jax.Array:
    return jnp.full((), jnp.nan, jnp.float32)


def _leaf_norms(g: jax.Array, n: int) -> jax.Array:
    return jnp.sum(jnp.square(g.astype(jnp.float32)).reshape(n, -1), axis=1)


def _per_agent_norms(grads_stacked: PyTree) -> jax.Array:
    """(N,) l2 norms of each agent's full gradient pytree."""
    leaves = jax.tree.leaves(grads_stacked)
    n = leaves[0].shape[0]
    sq = sum(_leaf_norms(g, n) for g in leaves)
    return jnp.sqrt(sq)


def _param_dim(grads_stacked: PyTree) -> int:
    """Static per-agent parameter count d (the AWGN dimension)."""
    leaves = jax.tree.leaves(grads_stacked)
    n = leaves[0].shape[0]
    return sum(int(leaf.size) // n for leaf in leaves)


def _snr_from(signal_sq: jax.Array, dim: int,
              ota_cfg: OTAConfig) -> jax.Array:
    sigma = jnp.asarray(ota_cfg.noise_sigma, jnp.float32)
    return (signal_sq.astype(jnp.float32)
            / (dim * jnp.square(sigma))).astype(jnp.float32)


def _drift_reference(ota_cfg: Optional[OTAConfig], n_agents: int):
    return _ota().effective_gain_mean(ota_cfg, n_agents)


def stacked_round_probes(
    config: TelemetryConfig,
    *,
    grads_stacked: PyTree,
    gains: jax.Array,
    ota_cfg: Optional[OTAConfig],
    n_agents: int,
    gain_mean: jax.Array,
    update_norm: jax.Array,
) -> RoundTelemetry:
    """Probes for the vmap round form (leading-N gradient stacks).

    ``gains`` is the sampled ``(N,)`` realisation (``1.0`` scalar when
    exact); ``update_norm`` is ``||u_k||`` as derived by the round body.
    """
    snr = grad_pre = grad_post = drift = disp = _nan()
    noisy = ota_cfg is not None and _ota()._noise_enabled(ota_cfg.noise_sigma)
    if config.snr:
        if not noisy:
            snr = jnp.full((), jnp.inf, jnp.float32)
        else:
            sig = _ota().signal_power_sq(grads_stacked, gains)
            snr = _snr_from(sig, _param_dim(grads_stacked), ota_cfg)
    if config.grad_norms or config.dispersion:
        norms = _per_agent_norms(grads_stacked)
        if config.grad_norms:
            grad_pre = jnp.mean(norms)
            grad_post = update_norm.astype(jnp.float32)
        if config.dispersion:
            disp = jnp.max(norms) / jnp.mean(norms)
    if config.moment_drift:
        ref = _drift_reference(ota_cfg, n_agents)
        drift = (gain_mean - ref).astype(jnp.float32)
    return RoundTelemetry(snr=snr, grad_norm_pre=grad_pre,
                          grad_norm_post=grad_post, moment_drift=drift,
                          dispersion=disp)


def sharded_round_probes(
    config: TelemetryConfig,
    *,
    local_grads: PyTree,
    local_gains: jax.Array,
    ota_cfg: Optional[OTAConfig],
    n_agents: int,
    axis_name: str,
    gain_mean: jax.Array,
    update_norm: jax.Array,
) -> RoundTelemetry:
    """Probes for the agent-mesh shard_map round form.

    ``local_grads`` leaves carry this shard's ``(n_local, ...)`` slice;
    cross-shard reductions are ``psum`` (sums/means) and ``pmax`` (the
    dispersion max), so every shard emits identical replicated values —
    matching how the round's metrics are already reduced.
    """
    snr = grad_pre = grad_post = drift = disp = _nan()
    noisy = ota_cfg is not None and _ota()._noise_enabled(ota_cfg.noise_sigma)
    leaves = jax.tree.leaves(local_grads)
    n_local = leaves[0].shape[0]
    if config.snr:
        if not noisy:
            snr = jnp.full((), jnp.inf, jnp.float32)
        else:
            # local combine, global psum — the same v the aggregate psums
            def _combine(g):
                hb = local_gains.reshape(
                    (n_local,) + (1,) * (g.ndim - 1)).astype(g.dtype)
                return jnp.sum(hb * g, axis=0)

            v = jax.lax.psum(jax.tree.map(_combine, local_grads), axis_name)
            sig = sum(jnp.sum(jnp.square(leaf))
                      for leaf in jax.tree.leaves(v))
            dim = sum(int(leaf.size) // n_local for leaf in leaves)
            snr = _snr_from(sig, dim, ota_cfg)
    if config.grad_norms or config.dispersion:
        local_sq = sum(_leaf_norms(g, n_local) for g in leaves)
        local_norms = jnp.sqrt(local_sq)
        mean_norm = jax.lax.psum(jnp.sum(local_norms), axis_name) / n_agents
        if config.grad_norms:
            grad_pre = mean_norm
            grad_post = update_norm.astype(jnp.float32)
        if config.dispersion:
            disp = jax.lax.pmax(jnp.max(local_norms), axis_name) / mean_norm
    if config.moment_drift:
        ref = _drift_reference(ota_cfg, n_agents)
        drift = (gain_mean - ref).astype(jnp.float32)
    return RoundTelemetry(snr=snr, grad_norm_pre=grad_pre,
                          grad_norm_post=grad_post, moment_drift=drift,
                          dispersion=disp)


def streamed_round_probes(
    config: TelemetryConfig,
    *,
    v: Optional[PyTree],
    norms_sq: Optional[jax.Array],
    ota_cfg: Optional[OTAConfig],
    n_agents: int,
    param_dim: int,
    gain_mean: jax.Array,
    update_norm: jax.Array,
) -> RoundTelemetry:
    """Probes for the blocked-scan (streamed) round form.

    Everything derives from the round's *running accumulators* instead of a
    materialised ``(N, d)`` gradient stack, so telemetry stays O(N) scalars
    at any fleet size: ``v`` is the accumulated channel superposition
    ``sum_i h_i g_i`` (None for exact uplinks — the SNR probe is ``inf``
    there anyway), ``norms_sq`` the ``(N,)`` per-agent squared gradient
    norms the scan emitted (None when both norm probes are off).  Values
    match :func:`stacked_round_probes` — bitwise for the norm statistics
    (identical per-agent values, identical final reductions), to
    reassociation tolerance for the SNR signal power.
    """
    snr = grad_pre = grad_post = drift = disp = _nan()
    noisy = ota_cfg is not None and _ota()._noise_enabled(ota_cfg.noise_sigma)
    if config.snr:
        if not noisy:
            snr = jnp.full((), jnp.inf, jnp.float32)
        else:
            sig = sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                      for leaf in jax.tree.leaves(v))
            snr = _snr_from(sig, param_dim, ota_cfg)
    if config.grad_norms or config.dispersion:
        norms = jnp.sqrt(norms_sq)
        if config.grad_norms:
            grad_pre = jnp.mean(norms)
            grad_post = update_norm.astype(jnp.float32)
        if config.dispersion:
            disp = jnp.max(norms) / jnp.mean(norms)
    if config.moment_drift:
        ref = _drift_reference(ota_cfg, n_agents)
        drift = (gain_mean - ref).astype(jnp.float32)
    return RoundTelemetry(snr=snr, grad_norm_pre=grad_pre,
                          grad_norm_post=grad_post, moment_drift=drift,
                          dispersion=disp)


def sharded_streamed_round_probes(
    config: TelemetryConfig,
    *,
    v: Optional[PyTree],
    local_norms_sq: Optional[jax.Array],
    valid_local: jax.Array,
    ota_cfg: Optional[OTAConfig],
    n_agents: int,
    axis_name: str,
    param_dim: int,
    gain_mean: jax.Array,
    update_norm: jax.Array,
) -> RoundTelemetry:
    """Streamed probes inside the agent-mesh shard_map round.

    ``v`` is already psummed (replicated) by the round body;
    ``local_norms_sq`` carries this shard's ``(n_local,)`` per-agent squared
    norms with phantom (padding) rows masked out via ``valid_local`` before
    the psum/pmax reductions, so padded fleets report statistics over the
    true ``n_agents`` only.

    Block-invariance caveat: under shard_map the SPMD partitioner fuses the
    per-agent norm reduction width-dependently, so the ``dispersion``
    probe's max-norm can move by a last mantissa bit across ``agent_blocks``
    choices (the summed ``grad_norm_pre`` over the *same* norms rounds
    identically).  Every other emitted quantity is bitwise block-invariant.
    """
    snr = grad_pre = grad_post = drift = disp = _nan()
    noisy = ota_cfg is not None and _ota()._noise_enabled(ota_cfg.noise_sigma)
    if config.snr:
        if not noisy:
            snr = jnp.full((), jnp.inf, jnp.float32)
        else:
            sig = sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                      for leaf in jax.tree.leaves(v))
            snr = _snr_from(sig, param_dim, ota_cfg)
    if config.grad_norms or config.dispersion:
        norms = jnp.where(valid_local, jnp.sqrt(local_norms_sq), 0.0)
        mean_norm = jax.lax.psum(jnp.sum(norms), axis_name) / n_agents
        if config.grad_norms:
            grad_pre = mean_norm
            grad_post = update_norm.astype(jnp.float32)
        if config.dispersion:
            # phantom rows hold 0.0, which can never win the max: every
            # shard owns at least one real agent (pad < n_local).
            disp = jax.lax.pmax(jnp.max(norms), axis_name) / mean_norm
    if config.moment_drift:
        ref = _drift_reference(ota_cfg, n_agents)
        drift = (gain_mean - ref).astype(jnp.float32)
    return RoundTelemetry(snr=snr, grad_norm_pre=grad_pre,
                          grad_norm_post=grad_post, moment_drift=drift,
                          dispersion=disp)


def participation_probes(
    config: TelemetryConfig,
    base: RoundTelemetry,
    *,
    rate_realized: jax.Array,
    rate_expected,
    staleness_mean: Optional[jax.Array] = None,
) -> RoundTelemetry:
    """Attach the round-service probes to a base :class:`RoundTelemetry`.

    Called only from service rounds (an active ``ParticipationConfig``):
    ``rate_realized`` is the realised participating fraction
    ``count / N``, ``rate_expected`` the closed-form expectation (possibly
    a traced sweep-lane value) — their difference is the realised-vs-
    expected debias drift.  ``staleness_mean`` is the mean age of the
    replayed stale contributions (None when staleness is off: the field
    stays an absent node).  With ``config.participation`` off the fields
    are NaN (present but disabled), keeping the service pytree static
    across probe selections.
    """
    if not config.participation:
        sm = None if staleness_mean is None else _nan()
        return base._replace(participation_rate=_nan(),
                             participation_drift=_nan(),
                             staleness_mean=sm)
    rate = rate_realized.astype(jnp.float32)
    drift = (rate - jnp.asarray(rate_expected, jnp.float32))
    sm = None if staleness_mean is None \
        else staleness_mean.astype(jnp.float32)
    return base._replace(participation_rate=rate,
                         participation_drift=drift.astype(jnp.float32),
                         staleness_mean=sm)


def summarize(telemetry) -> Optional[dict]:
    """NaN-aware scalar summary of stacked RoundTelemetry arrays (numpy
    side, for ledgers/tables): mean of each probe over every axis, with
    all-NaN (disabled) probes reported as None and absent (None-valued)
    service probes skipped."""
    if telemetry is None:
        return None
    import numpy as np

    out = {}
    for name, arr in zip(RoundTelemetry._fields, telemetry):
        if arr is None:
            continue
        a = np.asarray(arr, np.float64)
        finite = a[np.isfinite(a)]
        if finite.size:
            out[name] = float(np.mean(finite))
        elif np.isinf(a).any():
            out[name] = float("inf")
        else:
            out[name] = None
    return out
