"""Layer 2: trace-level contract checkers.

Where the AST rules (:mod:`repro.analyze.engine`) read source, these
checkers import the real registries, trace representative
(env x channel x uplink) programs through the hooks the core modules
expose (``sweep.lane_program``, ``ota.uplink_jaxpr``,
``envs.registered_envs``), and assert structural properties of the
resulting jaxprs / compiled artifacts:

``lane-contract``
    The sweep engine's bitwise-exactness invariant, checked structurally
    rather than via golden traces: for every registered env family, a
    two-lane partition must pack *exactly* the varying axes (set equality
    against an independent re-derivation from the scenario list), every
    packed leaf must actually differ across lanes (a constant promoted to
    a dynamic argument un-folds an XLA literal and can drift the last
    mantissa bit), every packed leaf must survive as a *consumed* input
    variable of the traced lane program (a packed-but-unread leaf means a
    lane silently runs the prototype's value), and a fully-constant
    partition must pack to ``{}`` (the replicate path).

``wire-dtype``
    No ``convert_element_type`` float narrowing anywhere in the uplink
    jaxpr, except the sanctioned ``OTAConfig.wire_dtype`` bf16 hop — and
    when ``wire_dtype="bfloat16"`` is requested, the hop must actually
    appear.

``compile-budget``
    A sweep compiles at most one program per structural partition (plus
    bounded slack), and repeated ``fedpg.monte_carlo`` calls with equal
    configs reuse the cached executable (zero recompiles on the second
    call).  Counting uses :mod:`repro.analyze.budget`.

``collective-audit``
    The ``agent_mesh`` shard_map path's compiled HLO contains only the
    expected collective kinds (psum -> all-reduce); an unexpected
    all-gather / all-to-all / reduce-scatter means a resharding snuck into
    the uplink.  The streamed (``agent_blocks``) form — including a
    non-dividing, phantom-padded fleet — is held to the same psum-only
    contract.  Skipped (with a report note) on single-device hosts.

``stream-contract``
    The streaming (``agent_blocks``) forms' memory invariant, checked
    structurally: every ``scan``/``while`` carry aval in the blocked
    uplink jaxpr and in the blocked round program must be *identical*
    across two fleet sizes at a fixed block size.  A carry that grows
    with ``n_agents`` means the streamed form secretly materialises the
    agent axis and the O(block × d) claim is false.

``participation-contract``
    The round service's normaliser liveness (realized debias must
    consume the PRNG key, expected debias must not), the sweep packing
    of the continuous service knobs (rate / deadline / decay as live
    lanes, structural knobs as partition splits, never-dropping configs
    folded into the plain partition), and a ``key-reuse`` hygiene scan
    of ``src/repro/service``.

Checkers emit the same :class:`~repro.analyze.findings.Finding` records as
the AST layer; source anchors point at the module that owns the violated
invariant.  jax is imported lazily so ``--ast-only`` runs never pay for it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

from repro.analyze.findings import Finding, Report

_CHECKS: Dict[str, Callable[[Report], None]] = {}


def register_check(name: str):
    def deco(fn):
        _CHECKS[name] = fn
        return fn
    return deco


def all_checks() -> Dict[str, Callable[[Report], None]]:
    return dict(_CHECKS)


def run_contracts(report: Report,
                  checks: Optional[Sequence[str]] = None) -> Report:
    """Run the named trace-level checks (default: all) into ``report``."""
    names = list(checks) if checks is not None else sorted(_CHECKS)
    for name in names:
        if name not in _CHECKS:
            raise KeyError(
                f"unknown contract check {name!r}; known: {sorted(_CHECKS)}")
        _CHECKS[name](report)
    return report


def _finding(rule: str, path: str, message: str,
             severity: str = "error") -> Finding:
    return Finding(rule=rule, severity=severity, path=path, line=0,
                   message=message)


# ---------------------------------------------------------------------------
# lane-contract
# ---------------------------------------------------------------------------

_SWEEP_PATH = "src/repro/core/sweep.py"
_OTA_PATH = "src/repro/core/ota.py"
_FEDPG_PATH = "src/repro/core/fedpg.py"

# Tiny-but-real run shape shared by every traced program below.
_TINY = dict(n_agents=2, batch_m=1, horizon=3, n_rounds=2)


def family_instances(name: str) -> Optional[list]:
    """Two same-kind instances of a registered family differing in a
    continuous parameter (``None`` when the family has no continuous axis).

    Default-packer families perturb their first declared-float field;
    array-parameter families (``tabular``, ``hetero``) get explicit
    constructions that exercise their custom packer hooks.
    """
    import jax

    from repro.rl.envs import is_float_field, make_env

    if name == "tabular":
        from repro.rl.envs.tabular import garnet
        return [garnet(jax.random.key(11)), garnet(jax.random.key(12))]
    if name == "hetero":
        from repro.rl.envs import WindyLandmarkNav, make_heterogeneous_env
        return [
            make_heterogeneous_env([WindyLandmarkNav(wind=0.0),
                                    WindyLandmarkNav(wind=0.1)]),
            make_heterogeneous_env([WindyLandmarkNav(wind=0.05),
                                    WindyLandmarkNav(wind=0.2)]),
        ]
    proto = make_env(name)
    ffields = [f for f in dataclasses.fields(proto) if is_float_field(f)]
    if not ffields:
        return None
    f = ffields[0]
    other = dataclasses.replace(
        proto, **{f.name: float(getattr(proto, f.name)) * 1.5 + 0.125})
    return [proto, other]


def _expected_packed_keys(part) -> set:
    """Independent re-derivation of which axes must be packed: exactly the
    axes whose values vary across the partition's scenarios (env only when
    the registry packer yields varying parameters)."""
    from repro.rl.envs import batched_env_arrays

    scens = part.scenarios
    proto = part.proto
    expected = set()
    if proto.env is not None and part.varying("env"):
        _, arrays = batched_env_arrays([s.env for s in scens])
        if arrays:
            expected.add("env")
    if part.varying("alpha"):
        expected.add("alpha")
    if proto.channel is not None:
        if part.varying("noise_sigma"):
            expected.add("noise_sigma")
        if part.varying("channel"):
            expected.add("channel")
        if proto.power_control is not None and part.varying("power_control"):
            expected.add("power_control")
        if proto.debias and ("channel" in expected
                             or "power_control" in expected):
            expected.add("update_scale")
    # round-service lane axes (see sweep._pack_partition): Bernoulli rate,
    # fault deadline (realized debias only) and staleness decay batch
    from repro.service import participation as svc_participation
    from repro.service import staleness as svc_staleness

    p0 = svc_participation.normalize(proto.participation, proto.n_agents)
    if p0 is not None:
        pn = [svc_participation.normalize(s.participation, s.n_agents)
              for s in scens]
        if p0.kind == "bernoulli" \
                and len({float(p.rate) for p in pn}) > 1:
            expected.add("participation_rate")
        if p0.debias == "realized" and p0.faults is not None \
                and p0.faults.active \
                and len({float(p.faults.deadline) for p in pn}) > 1:
            expected.add("participation_deadline")
        st0 = svc_staleness.normalize(proto.staleness, p0)
        if st0 is not None and len(
                {float(svc_staleness.normalize(s.staleness, q).decay)
                 for s, q in zip(scens, pn)}) > 1:
            expected.add("staleness_decay")
    return expected


def _check_one_partition(report: Report, scens, label: str) -> None:
    """The structural lane-contract assertions for one scenario list that
    must form a single partition."""
    import jax
    import numpy as np

    from repro.core.sweep import lane_program, partition_scenarios

    parts = partition_scenarios(scens)
    if len(parts) != 1:
        report.findings.append(_finding(
            "lane-contract", _SWEEP_PATH,
            f"{label}: continuous-axis grid split into {len(parts)} "
            "partitions (a continuous axis leaked into _structure_key)"))
        return
    part = parts[0]
    packed, fn, keys = lane_program(None, None, part)

    expected = _expected_packed_keys(part)
    if set(packed) != expected:
        report.findings.append(_finding(
            "lane-contract", _SWEEP_PATH,
            f"{label}: packed axes {sorted(packed)} != varying axes "
            f"{sorted(expected)} — constant axes must stay closed-over "
            "literals, varying axes must be packed"))
        return

    # trace the lane program once; a packed leaf is "live" when its input
    # variable is consumed by some equation (or returned)
    closed = jax.make_jaxpr(fn)(packed, keys)
    jaxpr = closed.jaxpr
    leaves = jax.tree_util.tree_flatten_with_path(packed)[0]
    invars = jaxpr.invars[:len(leaves)]
    used = set()
    for eqn in jaxpr.eqns:
        for v in eqn.invars:
            if not isinstance(v, jax.core.Literal):
                used.add(v)
    for v in jaxpr.outvars:
        if not isinstance(v, jax.core.Literal):
            used.add(v)

    # Channel / power-control objects pack WHOLESALE by design: all fields
    # of the varying dataclass (plus float64-precomputed derived constants
    # like BatchedChannel's _mean) travel as lane parameters, so individual
    # leaves may legitimately be constant or unused — but the object as a
    # whole must still vary and feed the trace.  Everything else packs
    # per-axis and is held to the strict leaf-level contract.
    wholesale = {"channel", "power_control"}
    n_lanes = len(part.scenarios)
    axis_varies: Dict[str, bool] = {}
    axis_live: Dict[str, bool] = {}
    for (path, leaf), var in zip(leaves, invars):
        axis = str(getattr(path[0], "key", path[0]))
        pstr = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        if arr.shape[0] != n_lanes:
            report.findings.append(_finding(
                "lane-contract", _SWEEP_PATH,
                f"{label}: packed leaf {pstr} lane axis {arr.shape[0]} != "
                f"{n_lanes} lanes"))
            continue
        varies = not all(np.array_equal(arr[0], arr[j])
                         for j in range(1, n_lanes))
        live = var in used
        axis_varies[axis] = axis_varies.get(axis, False) or varies
        axis_live[axis] = axis_live.get(axis, False) or live
        if axis in wholesale:
            continue
        if not varies:
            report.findings.append(_finding(
                "lane-contract", _SWEEP_PATH,
                f"{label}: packed leaf {pstr} is identical across lanes — "
                "a partition constant was promoted to a dynamic argument "
                "(un-folds the XLA literal the per-scenario path uses)"))
        if not live:
            report.findings.append(_finding(
                "lane-contract", _SWEEP_PATH,
                f"{label}: packed leaf {pstr} is a dead input of the lane "
                "program — its lanes silently run the prototype's folded "
                "value"))
    for axis in sorted(set(axis_varies) & wholesale):
        if not axis_varies[axis]:
            report.findings.append(_finding(
                "lane-contract", _SWEEP_PATH,
                f"{label}: packed object {axis!r} is identical across all "
                "lanes — a partition-constant object was promoted to "
                "dynamic arguments"))
        if not axis_live[axis]:
            report.findings.append(_finding(
                "lane-contract", _SWEEP_PATH,
                f"{label}: no leaf of packed object {axis!r} reaches the "
                "lane program — its lanes silently run the prototype"))


@register_check("lane-contract")
def check_lane_contract(report: Report,
                        families: Optional[Sequence[str]] = None) -> None:
    from repro.core.channel import NakagamiChannel, RayleighChannel
    from repro.core.power_control import TruncatedInversion
    from repro.core.sweep import Scenario, partition_scenarios
    from repro.rl.envs import make_env, registered_envs

    names = list(families) if families is not None else sorted(registered_envs())
    chan = RayleighChannel()
    for name in names:
        envs = family_instances(name)
        if envs is None:
            # no continuous env axis: alpha still varies, env stays constant
            report.skipped.append(
                f"lane-contract: env family {name!r} has no continuous "
                "parameter; alpha-axis coverage only")
            proto = make_env(name)
            envs = [proto, proto]
        scens = [
            Scenario(channel=chan, noise_sigma=1e-3, alpha=a, env=e, **_TINY)
            for a, e in zip((1e-3, 2e-3), envs)
        ]
        _check_one_partition(report, scens, f"family {name!r}")

    # the uplink axes: channel params + power control + noise + debias vary
    # together inside one landmark partition, so BatchedChannel packing and
    # the update_scale normaliser are exercised too
    env = make_env("landmark")
    scens = [
        Scenario(channel=NakagamiChannel(m=m, omega=om), noise_sigma=ns,
                 alpha=1e-3, env=env, debias=True,
                 power_control=TruncatedInversion(c_min=c), **_TINY)
        for m, om, ns, c in ((0.5, 1.0, 1e-3, 0.05), (1.5, 2.0, 1e-2, 0.1))
    ]
    _check_one_partition(report, scens, "uplink axes (channel/pc/noise)")

    # a fully-constant partition must take the replicate path: packed == {}
    from repro.core.sweep import _pack_partition
    const = [Scenario(channel=chan, noise_sigma=1e-3, alpha=1e-3, env=env,
                      **_TINY)] * 2
    part = partition_scenarios(const)[0]
    packed = _pack_partition(part)
    if packed:
        report.findings.append(_finding(
            "lane-contract", _SWEEP_PATH,
            f"identical-scenario partition packed {sorted(packed)}; "
            "constants must stay closed-over literals (replicate path)"))


# ---------------------------------------------------------------------------
# wire-dtype
# ---------------------------------------------------------------------------

def _iter_jaxprs(jaxpr):
    """Yield ``jaxpr`` and every sub-jaxpr reachable through eqn params
    (pjit, scan, cond, custom_jvp, ...)."""
    import jax

    yield jaxpr
    for eqn in jaxpr.eqns:
        for val in eqn.params.values():
            vals = val if isinstance(val, (tuple, list)) else (val,)
            for v in vals:
                if isinstance(v, jax.core.ClosedJaxpr):
                    yield from _iter_jaxprs(v.jaxpr)
                elif isinstance(v, jax.core.Jaxpr):
                    yield from _iter_jaxprs(v)


def narrowing_converts(closed_jaxpr) -> List[tuple]:
    """Every float->smaller-float ``convert_element_type`` in the jaxpr
    tree, as ``(src_dtype, dst_dtype)`` pairs."""
    import numpy as np
    import jax.numpy as jnp

    hits = []
    for jx in _iter_jaxprs(closed_jaxpr.jaxpr):
        for eqn in jx.eqns:
            if eqn.primitive.name != "convert_element_type":
                continue
            src = eqn.invars[0].aval.dtype
            dst = np.dtype(eqn.params["new_dtype"])
            if (jnp.issubdtype(src, jnp.floating)
                    and jnp.issubdtype(dst, jnp.floating)
                    and dst.itemsize < np.dtype(src).itemsize):
                hits.append((str(src), str(dst)))
    return hits


@register_check("wire-dtype")
def check_wire_dtype(report: Report) -> None:
    from repro.core.channel import RayleighChannel
    from repro.core.ota import OTAConfig, uplink_jaxpr

    native = OTAConfig(channel=RayleighChannel(), noise_sigma=1e-3,
                       debias=True)
    bf16 = dataclasses.replace(native, wire_dtype="bfloat16")

    for apply_form in (False, True):
        form = "aggregate_apply" if apply_form else "aggregate"
        for backend in ("xla", "pallas"):
            # no config may narrow floats without asking for it — and the
            # knob is documented pallas-only, so xla/bf16 must stay native
            for cfg, tag in ((None, "exact"), (native, "native"),
                             *(((bf16, "bf16"),) if backend == "xla" else ())):
                hits = narrowing_converts(
                    uplink_jaxpr(cfg, apply=apply_form, backend=backend))
                if hits:
                    report.findings.append(_finding(
                        "wire-dtype", _OTA_PATH,
                        f"{form}/{backend}/{tag}: unsanctioned float "
                        f"narrowing on the uplink: {hits} (only "
                        "OTAConfig.wire_dtype on the pallas backend may "
                        "narrow)"))
        # the sanctioned hop: pallas + wire_dtype="bfloat16" must narrow to
        # bf16, and to nothing else
        hits = narrowing_converts(
            uplink_jaxpr(bf16, apply=apply_form, backend="pallas"))
        bad = [h for h in hits if h[1] != "bfloat16"]
        if bad:
            report.findings.append(_finding(
                "wire-dtype", _OTA_PATH,
                f"{form}/pallas/bf16: narrowing beyond the sanctioned bf16 "
                f"hop: {bad}"))
        if not hits:
            report.findings.append(_finding(
                "wire-dtype", _OTA_PATH,
                f"{form}/pallas/bf16: wire_dtype='bfloat16' produced no "
                "bf16 hop — the wire-dtype knob is being ignored"))


# ---------------------------------------------------------------------------
# compile-budget
# ---------------------------------------------------------------------------

# One partition program per structural shape, plus this much slack for
# residual tiny dispatches the warm pass could not anticipate.
_COMPILE_SLACK = 1


@register_check("compile-budget")
def check_compile_budget(report: Report) -> None:
    import jax

    from repro.analyze import budget
    from repro.core import fedpg
    from repro.core.channel import RayleighChannel
    from repro.core.sweep import (
        grid, partition_scenarios, resolve_env_policy, sweep,
    )
    from repro.rl.envs import WindyLandmarkNav

    budget.warm_eager_helpers()
    fedpg.clear_compilation_cache()

    scens = grid(channel=[None, RayleighChannel()], noise_sigma=1e-3,
                 alpha=[1e-3, 2e-3],
                 env=[WindyLandmarkNav(wind=w) for w in (0.0, 0.2)],
                 **_TINY)
    n_parts = len(partition_scenarios(scens))
    key = jax.random.key(5)
    with budget.CompileCounter() as c:
        sweep(None, None, scens, key, 2)
    if c.count > n_parts + _COMPILE_SLACK:
        report.findings.append(_finding(
            "compile-budget", _SWEEP_PATH,
            f"sweep over {len(scens)} scenarios / {n_parts} partitions "
            f"compiled {c.count} programs (budget {n_parts} + "
            f"{_COMPILE_SLACK} slack) — a lane axis is splitting the "
            "partition program"))

    # repeated monte_carlo with equal configs must reuse the cached
    # executable: the recompile-per-call bug the _compiled_* caches fixed
    s = scens[-1]
    env, policy = resolve_env_policy(s)
    cfg, ota = s.fedpg_config(), s.ota_config()
    fedpg.monte_carlo(env, policy, cfg, key, 2, ota=ota)
    with budget.CompileCounter() as c2:
        fedpg.monte_carlo(env, policy, cfg, jax.random.key(6), 2, ota=ota)
    if c2.count != 0:
        report.findings.append(_finding(
            "compile-budget", _FEDPG_PATH,
            f"repeated monte_carlo with equal configs recompiled "
            f"{c2.count} program(s); the compiled-callable cache is not "
            "keying correctly"))


# ---------------------------------------------------------------------------
# collective-audit
# ---------------------------------------------------------------------------

# psum lowers to all-reduce; anything else on the agent-sharded uplink is a
# resharding that should not be there.
_EXPECTED_COLLECTIVES = frozenset({"all-reduce"})

# SPMD-partitioning jax.random.split across the mesh shuffles a few u32 key
# words between devices as tiny collective-permutes (threefry plumbing), and
# the phantom-agent key padding (gather + concatenate before shard_map)
# likewise lowers to a tiny all-gather of key words.  Tolerate those kinds up
# to this many wire bytes; a gradient-sized transfer (>= 4 bytes x param
# count x agents) still trips the audit.
_PERMUTE_BYTE_TOLERANCE = 1024
_TOLERATED_SMALL_KINDS = frozenset({"collective-permute", "all-gather"})


@register_check("collective-audit")
def check_collectives(report: Report) -> None:
    import jax

    if jax.device_count() < 2:
        report.skipped.append(
            "collective-audit: single-device host (set "
            "REPRO_EMULATED_DEVICES=8 to emulate a mesh)")
        return

    from repro.core import distribute, fedpg
    from repro.core.channel import RayleighChannel
    from repro.core.ota import OTAConfig
    from repro.rl.envs import make_env
    from repro.utils.hlo import parse_collective_bytes

    n_agents = jax.device_count()
    mesh = distribute.agent_mesh_for(n_agents)
    env = make_env("landmark")
    policy = env.default_policy()
    cfg = fedpg.FedPGConfig(n_agents=n_agents, batch_m=1, horizon=3,
                            n_rounds=2)
    ota = OTAConfig(channel=RayleighChannel(), noise_sigma=1e-3, debias=True)

    def audit(fn, label):
        hlo = fn.lower(jax.random.key(0)).compile().as_text()
        stats = parse_collective_bytes(hlo)
        unexpected_set = set(stats.count_by_kind) - _EXPECTED_COLLECTIVES
        for kind in _TOLERATED_SMALL_KINDS:
            if stats.bytes_by_kind.get(kind, 0.0) <= _PERMUTE_BYTE_TOLERANCE:
                unexpected_set.discard(kind)
        unexpected = sorted(unexpected_set)
        if unexpected:
            report.findings.append(_finding(
                "collective-audit", _FEDPG_PATH,
                f"{label} round program contains unexpected collectives "
                f"{unexpected} (expected only "
                f"{sorted(_EXPECTED_COLLECTIVES)}; stats: {stats.summary()})"
                " — a resharding snuck into the shard_map uplink"))
        if not stats.count_by_kind:
            report.findings.append(_finding(
                "collective-audit", _FEDPG_PATH,
                f"{label} round program contains no collectives at all — "
                "the psum aggregation is not crossing the mesh",
                severity="warning"))

    audit(jax.jit(lambda k: fedpg.run(env, policy, cfg, k, ota=ota,
                                      agent_mesh=mesh)),
          "agent-mesh")
    # the streamed form, on a fleet the mesh does NOT divide: the phantom
    # padding + blocked scan must still lower to psum-only collectives
    cfg_pad = fedpg.FedPGConfig(n_agents=n_agents + 1, batch_m=1, horizon=3,
                                n_rounds=2)
    audit(jax.jit(lambda k: fedpg.run(env, policy, cfg_pad, k, ota=ota,
                                      agent_mesh=mesh, agent_blocks=1)),
          "streamed agent-mesh (padded)")


# ---------------------------------------------------------------------------
# stream-contract
# ---------------------------------------------------------------------------

def _loop_carry_avals(closed_jaxpr) -> List[tuple]:
    """Every ``scan`` / ``while`` carry aval in the jaxpr tree, as sorted
    ``(primitive, shape, dtype)`` triples.

    The streamed forms' memory claim lives here: a blocked program's loop
    carries are the only state that survives across agent blocks, so their
    avals must be a function of ``(agent_blocks, d)`` alone — comparing the
    multiset across two fleet sizes at a fixed block is an exact structural
    test for "peak state independent of N".
    """
    avals = []
    for jx in _iter_jaxprs(closed_jaxpr.jaxpr):
        for eqn in jx.eqns:
            if eqn.primitive.name == "scan":
                lo = eqn.params["num_consts"]
                hi = lo + eqn.params["num_carry"]
                carry = eqn.invars[lo:hi]
            elif eqn.primitive.name == "while":
                lo = (eqn.params["cond_nconsts"]
                      + eqn.params["body_nconsts"])
                carry = eqn.invars[lo:]
            else:
                continue
            for v in carry:
                avals.append((eqn.primitive.name, str(v.aval.shape),
                              str(v.aval.dtype)))
    return sorted(avals)


@register_check("stream-contract")
def check_stream_contract(report: Report) -> None:
    import jax

    from repro.core import fedpg, ota
    from repro.core.channel import RayleighChannel
    from repro.core.ota import OTAConfig, uplink_jaxpr
    from repro.rl.envs import make_env

    block = 2
    small, large = 6, 24

    # 1) the aggregate level: the blocked uplink jaxpr's loop carries must
    #    not change when the fleet grows 4x at a fixed block size
    noisy = OTAConfig(channel=RayleighChannel(), noise_sigma=1e-3,
                      debias=True)
    for cfg, tag in ((None, "exact"), (noisy, "noisy")):
        for apply_form in (False, True):
            form = "aggregate_apply" if apply_form else "aggregate"
            carries = [
                _loop_carry_avals(uplink_jaxpr(
                    cfg, apply=apply_form, n_agents=n, agent_blocks=block))
                for n in (small, large)
            ]
            if carries[0] != carries[1]:
                report.findings.append(_finding(
                    "stream-contract", _OTA_PATH,
                    f"{form}/{tag}: blocked uplink loop carries differ "
                    f"between n_agents={small} and n_agents={large} at "
                    f"agent_blocks={block} — the scan carry grows with the "
                    f"fleet (got {carries[0]} vs {carries[1]})"))
            if not carries[0]:
                report.findings.append(_finding(
                    "stream-contract", _OTA_PATH,
                    f"{form}/{tag}: blocked uplink jaxpr contains no "
                    f"scan/while loops — agent_blocks={block} is not "
                    "streaming at all"))

    # 2) the round level: the full streamed round program (rollouts +
    #    uplink + server pass) must likewise keep all loop state O(block x d)
    env = make_env("landmark")
    policy = env.default_policy()

    def round_carries(n):
        cfg = fedpg.FedPGConfig(n_agents=n, batch_m=1, horizon=3, n_rounds=2)
        closed = jax.make_jaxpr(
            lambda k: fedpg.run(env, policy, cfg, k, ota=noisy,
                                agent_blocks=block))(jax.random.key(0))
        return _loop_carry_avals(closed)

    got = [round_carries(n) for n in (small, large)]
    if got[0] != got[1]:
        only_small = [a for a in got[0] if a not in got[1]]
        only_large = [a for a in got[1] if a not in got[0]]
        report.findings.append(_finding(
            "stream-contract", _FEDPG_PATH,
            f"streamed round program loop carries differ between "
            f"n_agents={small} and n_agents={large} at "
            f"agent_blocks={block} — some loop state scales with the fleet "
            f"(only at N={small}: {only_small}; only at N={large}: "
            f"{only_large})"))


# ---------------------------------------------------------------------------
# participation-contract
# ---------------------------------------------------------------------------

_PARTICIPATION_PATH = "src/repro/service/participation.py"


def _key_invar_live(closed_jaxpr) -> bool:
    """Whether any top-level input variable of the jaxpr is consumed by an
    equation (or returned)."""
    import jax

    jaxpr = closed_jaxpr.jaxpr
    used = set()
    for eqn in jaxpr.eqns:
        for v in eqn.invars:
            if not isinstance(v, jax.core.Literal):
                used.add(v)
    for v in jaxpr.outvars:
        if not isinstance(v, jax.core.Literal):
            used.add(v)
    return any(v in used for v in jaxpr.invars)


@register_check("participation-contract")
def check_participation_contract(report: Report) -> None:
    """The round service's debias-normaliser and lane-packing contracts.

    1. ``debias="realized"``: the traced ``key -> N/W`` normaliser
       (``participation.scale_jaxpr``) must CONSUME its key — the
       realised count is data-dependent on the drawn mask, and a dead key
       means the normaliser constant-folded back to the expected-count
       analysis.  ``debias="expected"``: the key must be DEAD — the
       closed-form normaliser must not touch the realisation.
    2. The sweep engine packs exactly the continuous service knobs
       (Bernoulli rate, fault deadline under realized debias, staleness
       decay) as live lane inputs, while the structural knobs (kind,
       debias mode) split partitions.
    3. The counter-PRNG hygiene of ``src/repro/service`` itself: the
       ``key-reuse`` AST rule over the whole package (mask and fault
       draws must stay pure fold_in counter-mode).
    """
    from repro.core.channel import RayleighChannel
    from repro.core.sweep import Scenario, partition_scenarios
    from repro.rl.envs import make_env
    from repro.service.faults import FaultConfig, StragglerModel
    from repro.service.participation import ParticipationConfig, scale_jaxpr
    from repro.service.staleness import StalenessConfig

    realized = [
        ParticipationConfig(rate=0.5),
        ParticipationConfig(kind="subset", subset=3),
        ParticipationConfig(kind="full", faults=FaultConfig(
            stragglers=StragglerModel(mean=1.0), deadline=1.0)),
    ]
    for p in realized:
        if not _key_invar_live(scale_jaxpr(p)):
            report.findings.append(_finding(
                "participation-contract", _PARTICIPATION_PATH,
                f"realized-debias normaliser for {p.kind!r} does not "
                "consume its PRNG key — N/W constant-folded back to the "
                "expected-count analysis"))
    expected = ParticipationConfig(rate=0.5, debias="expected")
    if _key_invar_live(scale_jaxpr(expected)):
        report.findings.append(_finding(
            "participation-contract", _PARTICIPATION_PATH,
            "expected-debias normaliser consumes the PRNG key — the "
            "closed-form E[W] must not depend on the realisation"))

    # 2) lane packing: each continuous service knob batches as a live lane
    #    input of a single partition program
    env = make_env("landmark")
    chan = RayleighChannel()

    def svc_scen(**kw):
        return Scenario(channel=chan, noise_sigma=1e-3, env=env,
                        debias=True, **_TINY, **kw)

    _check_one_partition(report, [
        svc_scen(participation=ParticipationConfig(rate=r))
        for r in (0.3, 0.7)
    ], "service rate axis")
    _check_one_partition(report, [
        svc_scen(participation=ParticipationConfig(kind="full", faults=FaultConfig(
            stragglers=StragglerModel(mean=1.0), deadline=d)))
        for d in (0.5, 2.0)
    ], "service deadline axis")
    _check_one_partition(report, [
        svc_scen(participation=ParticipationConfig(rate=0.5),
                 staleness=StalenessConfig(max_age=2, decay=dc))
        for dc in (0.5, 0.9)
    ], "service staleness-decay axis")

    # structural knobs must SPLIT: realized vs expected debias are
    # different programs (live vs dead key), never lanes of one
    split = partition_scenarios([
        svc_scen(participation=ParticipationConfig(rate=0.5, debias=d))
        for d in ("realized", "expected")
    ])
    if len(split) != 2:
        report.findings.append(_finding(
            "participation-contract", _SWEEP_PATH,
            "realized- and expected-debias scenarios merged into one "
            "partition — the debias mode is structural and must split"))
    # ...and a config that can never drop an agent must share the plain
    # partition (byte-identical programs)
    merged = partition_scenarios([
        svc_scen(participation=None),
        svc_scen(participation=ParticipationConfig(rate=1.0)),
    ])
    if len(merged) != 1:
        report.findings.append(_finding(
            "participation-contract", _SWEEP_PATH,
            "a full-participation config split from the plain partition — "
            "normalize() must fold it to participation=None"))

    # 3) PRNG hygiene of the service package itself
    from repro.analyze.engine import repo_root, scan
    from repro.analyze.rules import get_rules

    scan(repo_root(), ["src/repro/service"], rules=get_rules(["key-reuse"]),
         report=report)
