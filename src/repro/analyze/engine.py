"""Layer 1: the AST scan driver.

Parses every Python file under the configured roots (``src/``,
``benchmarks/``, ``examples/`` by default), hands each module to every
registered rule (:mod:`repro.analyze.rules`), and applies the inline
``# repro: noqa[rule-id]`` suppressions.  The shared AST analyses rules
build on live in :mod:`repro.analyze.astutils`; nothing in this layer
imports jax.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Iterator, List, Optional, Sequence, Tuple

from repro.analyze.astutils import ModuleContext, parse_module
from repro.analyze.findings import Finding, Report, is_suppressed
from repro.analyze.rules import Rule, all_rules

# Directories scanned by default, relative to the repo root.  ``tests/`` is
# deliberately absent: the suite keeps legacy-name and hazard coverage
# (deprecated wrappers must stay tested until they are removed).
DEFAULT_ROOTS = ("src", "benchmarks", "examples")

_SKIP_DIRS = {"__pycache__", ".git", ".ruff_cache", "node_modules"}


def repo_root() -> pathlib.Path:
    """The repository root (three levels above this file's package)."""
    return pathlib.Path(__file__).resolve().parents[3]


def iter_python_files(
    root: pathlib.Path, targets: Sequence[str],
) -> Iterator[Tuple[pathlib.Path, str]]:
    """Yield ``(abs_path, repo_relative_posix)`` for every .py under
    ``targets`` (files or directories, absolute or relative to ``root``)."""
    for target in targets:
        p = pathlib.Path(target)
        if not p.is_absolute():
            p = root / target
        if p.is_file() and p.suffix == ".py":
            yield p, _rel(p, root)
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not _SKIP_DIRS.intersection(f.parts):
                    yield f, _rel(f, root)


def _rel(p: pathlib.Path, root: pathlib.Path) -> str:
    try:
        return p.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        return p.as_posix()


def scan_module(ctx: ModuleContext, rules: Sequence[Rule],
                report: Report) -> None:
    for rule in rules:
        if ctx.relpath in rule.exclude:
            continue
        for f in rule.check(ctx):
            line = ""
            if 1 <= f.line <= len(ctx.source_lines):
                line = ctx.source_lines[f.line - 1]
            if is_suppressed(f, line):
                report.suppressed.append(f)
            else:
                report.findings.append(f)


def scan(root: pathlib.Path, targets: Sequence[str] = DEFAULT_ROOTS,
         rules: Optional[Sequence[Rule]] = None,
         report: Optional[Report] = None) -> Report:
    """Run the AST rules over every Python file under ``targets``."""
    report = report if report is not None else Report()
    rules = list(rules) if rules is not None else all_rules()
    for path, relpath in iter_python_files(root, targets):
        ctx = parse_module(path, relpath)
        if ctx is None:
            report.skipped.append(f"{relpath}: unparseable, not scanned")
            continue
        report.files_scanned += 1
        scan_module(ctx, rules, report)
    return report


def scan_source(source: str, relpath: str = "<string>",
                rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Scan one source string (the test-fixture entry point).

    Suppressions apply exactly as in file scans; returns the surviving
    findings.
    """
    report = Report()
    tree = ast.parse(source)
    ctx = ModuleContext(path=pathlib.Path(relpath), relpath=relpath,
                        tree=tree, source_lines=source.splitlines())
    scan_module(ctx, list(rules) if rules is not None else all_rules(),
                report)
    return report.findings
