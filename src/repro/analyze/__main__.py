"""CLI: ``python -m repro.analyze [--strict] [paths...]``.

Runs the AST rule engine over the default roots (or explicit paths) and,
unless ``--ast-only``, the trace-level contract checkers.  Always writes
the JSON report (``ANALYZE_report.json`` by default) next to the human
rendering on stdout.  Exit code: 1 on any error-severity finding, and on
*any* finding under ``--strict`` (the CI gate).
"""
from __future__ import annotations

import argparse
import sys

# Must run before anything imports jax: the contract checkers emulate a
# device mesh when REPRO_EMULATED_DEVICES is set (as in CI's analyze job).
from repro.utils import platform as rplat

rplat.apply_emulated_devices()

from repro.analyze import (  # noqa: E402
    DEFAULT_ROOTS, all_rules, get_rules, repo_root, scan,
)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analyze",
        description="static + trace-level contract checker for this repo",
    )
    parser.add_argument(
        "paths", nargs="*", default=None,
        help=f"files/dirs to scan (default: {', '.join(DEFAULT_ROOTS)})")
    parser.add_argument("--strict", action="store_true",
                        help="exit 1 on ANY finding (CI gate), not just errors")
    parser.add_argument("--ast-only", action="store_true",
                        help="skip the trace-level contract checkers (no jax)")
    parser.add_argument("--rules", default=None, metavar="ID[,ID...]",
                        help="run only these AST rule ids")
    parser.add_argument("--checks", default=None, metavar="NAME[,NAME...]",
                        help="run only these contract checks")
    parser.add_argument("--json", default="ANALYZE_report.json",
                        metavar="PATH",
                        help="JSON report path ('' to disable)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print every rule/check id and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.id:24s} [{rule.severity:7s}] {rule.description}")
        from repro.analyze.contracts import all_checks
        for name in sorted(all_checks()):
            print(f"{name:24s} [error  ] trace-level contract check")
        return 0

    rules = (get_rules(args.rules.split(",")) if args.rules else None)
    report = scan(repo_root(), args.paths or DEFAULT_ROOTS, rules=rules)

    if not args.ast_only:
        from repro.analyze.contracts import run_contracts
        checks = args.checks.split(",") if args.checks else None
        run_contracts(report, checks=checks)

    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            f.write(report.to_json() + "\n")
    print(report.render_text())
    return report.exit_code(strict=args.strict)


if __name__ == "__main__":
    sys.exit(main())
