"""XLA compile counting as an importable checker.

This generalises what used to be a test-only fixture in
``tests/conftest.py``: a process-wide listener on jax's
``backend_compile`` telemetry, a :class:`CompileCounter` context manager,
and :func:`warm_eager_helpers`, which compiles JAX's eager scaffolding
(key splits, float32 packing converts, effective-moment math,
``l_bar_for``, env-registry packers, History unstacking) once per process
so counts taken afterwards are partition/lane programs only.

``tests/conftest.py`` re-exports these for the ``compile_counter``
fixture; ``repro.analyze.contracts.check_compile_budget`` uses them to
machine-enforce the no-recompile-per-call invariant in CI.

The listener must be registered once per process; ``jax.monitoring``
offers no unregister, so the counter toggles an "active" flag instead.
"""
from __future__ import annotations

_COMPILE_COUNTER = {"active": False, "count": 0}
_LISTENER_REGISTERED = False
_EAGER_HELPERS_WARMED = False

_EVENT = "/jax/core/compile/backend_compile_duration"


def _on_event_duration(event: str, *args, **kwargs) -> None:
    if _COMPILE_COUNTER["active"] and event == _EVENT:
        _COMPILE_COUNTER["count"] += 1


def _ensure_listener() -> None:
    global _LISTENER_REGISTERED
    if _LISTENER_REGISTERED:
        return
    import jax

    jax.monitoring.register_event_duration_secs_listener(_on_event_duration)
    _LISTENER_REGISTERED = True


class CompileCounter:
    """Context manager counting XLA backend compilations while active.

    ``with CompileCounter() as c: ...; c.count`` — nesting is not
    supported (one process-wide flag).
    """

    def __init__(self):
        self.count = 0

    def __enter__(self):
        _ensure_listener()
        _COMPILE_COUNTER["count"] = 0
        _COMPILE_COUNTER["active"] = True
        return self

    def __exit__(self, *exc):
        _COMPILE_COUNTER["active"] = False
        self.count = _COMPILE_COUNTER["count"]
        return False


def warm_eager_helpers() -> None:
    """Compile JAX's eager scaffolding ONCE per process so compile counters
    compare partition programs, not cold-start helpers.

    A sweep's first run also compiles tiny eager dispatches — key
    splitting, float32 packing converts, effective-moment math,
    ``l_bar_for``, the env registry packer, History unstacking slices.
    Shapes here are deliberately distinct from any real test's so no
    *partition* program is pre-compiled on the caller's behalf.
    """
    global _EAGER_HELPERS_WARMED
    if _EAGER_HELPERS_WARMED:
        return
    import jax

    from repro.core import fedpg
    from repro.core.channel import RayleighChannel
    from repro.core.power_control import (
        TruncatedInversion, make_controlled_channel,
    )
    from repro.core.sweep import grid, resolve_env_policy, sweep
    from repro.rl.envs import WindyLandmarkNav

    tiny = dict(n_agents=2, batch_m=1, horizon=3, n_rounds=2, debias=True)
    chan = make_controlled_channel(RayleighChannel(), TruncatedInversion())
    scens = grid(env=[WindyLandmarkNav(wind=w) for w in (0.0, 0.31, 0.62)],
                 channel=[chan], noise_sigma=1e-3, **tiny)
    key = jax.random.key(99)
    # mc_runs=2 matches the sweep tests' Monte-Carlo width, so the tiny
    # split/convert programs they dispatch are all compiled here
    sweep(None, None, scens, key, 2)
    for s in scens[:1]:
        fedpg.monte_carlo(*resolve_env_policy(s), s.fedpg_config(), key, 2,
                          ota=s.ota_config())
    fedpg.clear_compilation_cache()
    _EAGER_HELPERS_WARMED = True
