"""``xla-flags``: ad-hoc ``XLA_FLAGS`` environment surgery.

``repro.utils.platform`` owns process-level XLA configuration
(``set_host_device_count`` merges flags instead of clobbering them, and
``REPRO_EMULATED_DEVICES`` replaces per-job flag strings).  Writing
``os.environ["XLA_FLAGS"]`` anywhere else silently discards whatever flags
the caller already set — the exact copy-paste drift PR 5 removed — so the
rule flags every direct mutation outside the owning module:

* ``os.environ["XLA_FLAGS"] = ...`` (and ``+=``)
* ``os.environ.setdefault("XLA_FLAGS", ...)``
* ``os.environ.update({... "XLA_FLAGS" ...})``
* ``os.putenv("XLA_FLAGS", ...)``

Reads (``os.environ.get("XLA_FLAGS")``) are fine — diagnostics report the
effective flags.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analyze.astutils import ModuleContext, dotted_name
from repro.analyze.findings import Finding
from repro.analyze.rules import Rule, register_rule

_VAR = "XLA_FLAGS"
_FIX = ("route XLA flag changes through repro.utils.platform "
        "(set_host_device_count / REPRO_EMULATED_DEVICES)")


def _is_environ_sub(node: ast.AST) -> bool:
    return (isinstance(node, ast.Subscript)
            and dotted_name(node.value).endswith("environ")
            and isinstance(node.slice, ast.Constant)
            and node.slice.value == _VAR)


def _mentions_var(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Constant) and n.value == _VAR
               for n in ast.walk(node))


@register_rule
class XlaFlagsRule(Rule):
    id = "xla-flags"
    severity = "error"
    description = "direct XLA_FLAGS mutation bypassing repro.utils.platform"
    exclude = ("src/repro/utils/platform.py",)

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                if any(_is_environ_sub(t) for t in targets):
                    yield ctx.finding(
                        self, node,
                        f"direct os.environ[{_VAR!r}] write; {_FIX}")
            elif isinstance(node, ast.Call):
                dotted = dotted_name(node.func)
                if (dotted.endswith("environ.setdefault")
                        or dotted.endswith("environ.update")
                        or dotted.endswith("putenv")):
                    if _mentions_var(node):
                        yield ctx.finding(
                            self, node,
                            f"{dotted.rpartition('.')[2]}() mutation of "
                            f"{_VAR}; {_FIX}")
