"""The pluggable AST rule registry.

A rule is a class with a unique ``id``, a ``severity``, a one-line
``description``, and a ``check(ctx)`` generator yielding
:class:`repro.analyze.findings.Finding` for one parsed module
(:class:`repro.analyze.engine.ModuleContext`).  Registration is by
decorator::

    from repro.analyze.rules import Rule, register_rule

    @register_rule
    class MyRule(Rule):
        id = "my-rule"
        severity = "warning"
        description = "what this catches"

        def check(self, ctx):
            yield ctx.finding(self, node, "message")

``exclude`` lists repo-relative paths a rule never applies to (e.g. the
module that *owns* the guarded invariant).  Importing this package loads
every built-in rule module so ``all_rules()`` is complete.
"""
from __future__ import annotations

from typing import Dict, Iterable, Iterator, List, Sequence, TYPE_CHECKING

from repro.analyze.findings import Finding

if TYPE_CHECKING:  # pragma: no cover
    from repro.analyze.engine import ModuleContext

_RULES: Dict[str, "Rule"] = {}


class Rule:
    """Base class for AST rules; subclasses override ``check``."""

    id: str = ""
    severity: str = "error"
    description: str = ""
    # repo-relative posix paths this rule never fires on (invariant owners)
    exclude: Sequence[str] = ()

    def check(self, ctx: "ModuleContext") -> Iterator[Finding]:
        raise NotImplementedError
        yield  # pragma: no cover


def register_rule(cls: type) -> type:
    """Class decorator adding a rule (by its ``id``) to the registry."""
    if not cls.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if cls.id in _RULES and type(_RULES[cls.id]) is not cls:
        raise ValueError(f"duplicate rule id {cls.id!r}")
    _RULES[cls.id] = cls()
    return cls


def all_rules() -> List[Rule]:
    return [_RULES[k] for k in sorted(_RULES)]


def get_rules(ids: Iterable[str]) -> List[Rule]:
    rules = []
    for rid in ids:
        if rid not in _RULES:
            raise KeyError(
                f"unknown rule {rid!r}; known: {sorted(_RULES)}")
        rules.append(_RULES[rid])
    return rules


# Built-in rule modules register themselves on import.
from repro.analyze.rules import deprecated_api  # noqa: E402,F401
from repro.analyze.rules import jit_pitfalls    # noqa: E402,F401
from repro.analyze.rules import platform        # noqa: E402,F401
from repro.analyze.rules import prng            # noqa: E402,F401
from repro.analyze.rules import timing          # noqa: E402,F401
