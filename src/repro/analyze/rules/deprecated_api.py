"""``deprecated-aggregation``: calls/imports of the legacy aggregation API.

The four legacy entry points (``aggregate_stacked``, ``exact_aggregate``,
``psum_aggregate``, ``psum_aggregate_stacked``) survive only as
DeprecationWarning shims in ``core/ota.py`` — every in-repo aggregation
call must go through ``ota.aggregate`` / ``ota.aggregate_apply``.

This rule absorbs the grep-based ``tools/lint_aggregation_api.py`` (which
now execs this rule as a thin shim): it flags call syntax on a legacy name
(bare or attribute) and ``from repro.core.ota import <legacy>`` imports,
anywhere outside ``core/ota.py`` itself.  Being AST-based, prose mentions
in strings/comments can no longer false-positive, and ``# repro:
noqa[deprecated-aggregation]`` marks sanctioned exceptions in-diff.
``tests/`` is outside the default scan roots on purpose: the suite keeps
legacy-name coverage so the deprecated wrappers stay correct until removal.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analyze.astutils import ModuleContext, dotted_name
from repro.analyze.findings import Finding
from repro.analyze.rules import Rule, register_rule

DEPRECATED = frozenset({
    "aggregate_stacked",
    "exact_aggregate",
    "psum_aggregate",
    "psum_aggregate_stacked",
})


@register_rule
class DeprecatedAggregationRule(Rule):
    id = "deprecated-aggregation"
    severity = "error"
    description = ("caller of a deprecated aggregation wrapper; use "
                   "ota.aggregate / ota.aggregate_apply")
    exclude = ("src/repro/core/ota.py",)

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                name = dotted_name(node.func).rpartition(".")[2]
                if name in DEPRECATED:
                    yield ctx.finding(
                        self, node,
                        f"call to deprecated ota.{name}; use ota.aggregate"
                        " / ota.aggregate_apply",
                    )
            elif isinstance(node, ast.ImportFrom):
                if node.module and node.module.endswith("ota"):
                    for alias in node.names:
                        if alias.name in DEPRECATED:
                            yield ctx.finding(
                                self, node,
                                f"import of deprecated ota.{alias.name}; "
                                "use ota.aggregate / ota.aggregate_apply",
                            )
