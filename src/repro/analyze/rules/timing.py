"""``raw-timing``: ad-hoc wall-clock timing bypassing the span tracer.

``repro.telemetry.trace`` owns wall-clock measurement: spans nest, carry
attributes, export to Chrome trace JSON, and keep ``wall_time_us``-style
bookkeeping consistent across the sweep engine and the bench suite.  A raw
``time.perf_counter()`` pair anywhere else produces a float that never
reaches trace exports or run ledgers — the pre-telemetry drift this PR
removed from ``benchmarks/common.py`` and ``et_baseline.py`` — so the rule
flags every direct monotonic-clock call outside the owning package:

* ``time.perf_counter()`` / ``time.perf_counter_ns()``
* ``time.monotonic()`` / ``time.monotonic_ns()``

(also through ``import time as t`` aliases and ``from time import
perf_counter`` names).  ``time.time()`` stays fine — it is a timestamp, not
an interval measurement.  The rare legitimate raw use (e.g. an interval
that must straddle asynchronous dispatch, or a micro-benchmark loop where
per-iteration span overhead would bias the medians) opts out per line with
``# repro: noqa[raw-timing]``.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analyze.astutils import ModuleContext, dotted_name
from repro.analyze.findings import Finding
from repro.analyze.rules import Rule, register_rule

_CLOCKS = frozenset(
    {"perf_counter", "perf_counter_ns", "monotonic", "monotonic_ns"})
_OWNER_PREFIX = "src/repro/telemetry/"
_FIX = ("wrap the timed region in repro.telemetry.trace.span() or use "
        "trace.timed_call() for call timing")


@register_rule
class RawTimingRule(Rule):
    id = "raw-timing"
    severity = "warning"
    description = "raw monotonic-clock timing bypassing repro.telemetry.trace"

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if ctx.relpath.startswith(_OWNER_PREFIX):
            return
        time_aliases = {"time"}
        clock_names = {}  # local name -> clock, from `from time import ...`
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "time":
                        time_aliases.add(a.asname or a.name)
            elif isinstance(node, ast.ImportFrom) and node.module == "time":
                for a in node.names:
                    if a.name in _CLOCKS:
                        clock_names[a.asname or a.name] = a.name
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = dotted_name(node.func)
            if "." in dotted:
                mod, _, attr = dotted.rpartition(".")
                if mod in time_aliases and attr in _CLOCKS:
                    yield ctx.finding(
                        self, node, f"raw {dotted}() timing; {_FIX}")
            elif dotted in clock_names:
                yield ctx.finding(
                    self, node,
                    f"raw {clock_names[dotted]}() timing; {_FIX}")
