"""``key-reuse``: a PRNG key consumed by two ``jax.random.*`` calls.

JAX keys are single-use: feeding the same key to two ``jax.random``
consumers (or using a key again after splitting it) silently correlates
the two draws — in this codebase that means correlated channel gains and
AWGN, a *wrong-science* bug the histories never reveal.  The rule runs a
linear abstract interpretation over each function body:

* a ``jax.random.<fn>(key, ...)`` call *consumes* ``key`` (``split`` and
  ``fold_in`` included — using the parent key after splitting it is the
  classic form of this bug);
* any assignment to the name *refreshes* it (``key, sub = split(key)``);
* ``if``/``else`` branches are analysed independently on copies of the
  state and merged by union, so exclusive-branch consumption does not
  false-positive;
* loop bodies are analysed twice, so a key consumed every iteration
  without a per-iteration ``fold_in``/``split`` refresh is caught
  (cross-iteration reuse).

Keys are tracked as names (``key``) and constant-subscript names
(``ks[0]``); anything fancier (attributes, dynamic subscripts) is out of
scope.  Nested function bodies are analysed as their own scopes.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analyze.astutils import FuncNode, ModuleContext, dotted_name
from repro.analyze.findings import Finding
from repro.analyze.rules import Rule, register_rule

# jax.random callables whose FIRST positional argument is a consumed key.
# (jax.random.key / PRNGKey are constructors, not consumers; wrappers like
# ota.aggregate take key= but route it to exactly one consumer themselves.)
CONSUMERS = frozenset({
    "split", "fold_in", "bits", "normal", "uniform", "randint", "choice",
    "permutation", "shuffle", "bernoulli", "categorical", "gumbel",
    "laplace", "logistic", "exponential", "gamma", "beta", "dirichlet",
    "poisson", "rademacher", "truncated_normal", "t", "cauchy", "ball",
    "orthogonal", "multivariate_normal", "loggamma", "binomial",
})

# dotted prefixes that denote the jax.random module
_RANDOM_PREFIXES = ("jax.random.", "random.", "jrandom.", "jr.")


def _consumer_key_expr(call: ast.Call) -> Optional[ast.AST]:
    """The consumed key expression of a jax.random consumer call, else None.

    Bare ``random.*`` only counts when the module was imported from jax
    (callers pass an alias map); to stay import-robust we accept the
    ``random.`` prefix but require the attribute to be a known consumer —
    stdlib ``random`` has none of these taking a key first.
    """
    dotted = dotted_name(call.func)
    if not dotted:
        return None
    head, _, attr = dotted.rpartition(".")
    if attr not in CONSUMERS:
        return None
    if not any((head + ".").startswith(p) or (head + ".") == p
               for p in _RANDOM_PREFIXES):
        return None
    if call.args:
        return call.args[0]
    for kw in call.keywords:
        if kw.arg == "key":
            return kw.value
    return None


def _key_id(expr: ast.AST) -> Optional[str]:
    """Canonical tracked id: ``key`` for Name, ``ks[0]`` for a
    constant-subscripted Name, None otherwise."""
    if isinstance(expr, ast.Name):
        return expr.id
    if (isinstance(expr, ast.Subscript)
            and isinstance(expr.value, ast.Name)
            and isinstance(expr.slice, ast.Constant)):
        return f"{expr.value.id}[{expr.slice.value!r}]"
    return None


def _assigned_names(node: ast.AST) -> Set[str]:
    """Names (re)bound by an assignment-like statement."""
    names: Set[str] = set()

    def collect(t: ast.AST):
        if isinstance(t, ast.Name):
            names.add(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                collect(e)
        elif isinstance(t, ast.Starred):
            collect(t.value)
        elif isinstance(t, ast.Subscript) and isinstance(t.value, ast.Name):
            kid = _key_id(t)
            names.add(kid if kid is not None else t.value.id)

    if isinstance(node, ast.Assign):
        for t in node.targets:
            collect(t)
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign, ast.NamedExpr)):
        collect(node.target)
    elif isinstance(node, ast.For):
        collect(node.target)
    elif isinstance(node, (ast.With, ast.AsyncWith)):
        for item in node.items:
            if item.optional_vars is not None:
                collect(item.optional_vars)
    return names


def _terminates(body: List[ast.stmt]) -> bool:
    """Whether a block ends by leaving the enclosing flow (guard-style
    ``if kind == ...: return consume(key)`` chains must not leak their
    branch's consumption into the fall-through path)."""
    return bool(body) and isinstance(
        body[-1], (ast.Return, ast.Raise, ast.Break, ast.Continue))


class _State:
    """name -> line of the consuming call (None = fresh)."""

    def __init__(self):
        self.consumed: Dict[str, int] = {}

    def copy(self) -> "_State":
        s = _State()
        s.consumed = dict(self.consumed)
        return s

    def merge(self, *others: "_State") -> None:
        for o in others:
            for k, v in o.consumed.items():
                self.consumed.setdefault(k, v)

    def refresh(self, names: Set[str]) -> None:
        for n in names:
            self.consumed.pop(n, None)
            # rebinding `ks` also refreshes every tracked `ks[...]`
            prefix = n + "["
            for tracked in [t for t in self.consumed if t.startswith(prefix)]:
                self.consumed.pop(tracked, None)


@register_rule
class KeyReuseRule(Rule):
    id = "key-reuse"
    severity = "error"
    description = ("a PRNG key is consumed by two jax.random calls "
                   "(or used again after being split)")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        findings: List[Finding] = []
        # module top level + every function body, each as its own scope
        scopes: List[List[ast.stmt]] = [ctx.tree.body]
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scopes.append(node.body)
        seen: Set[Tuple[int, str]] = set()
        for body in scopes:
            self._scan_block(body, _State(), findings, ctx)
        for f in findings:
            dedup = (f.line, f.message)
            if dedup not in seen:
                seen.add(dedup)
                yield f

    # -- the linear walk ---------------------------------------------------

    def _consume_in_stmt(self, stmt: ast.stmt, state: _State,
                         findings: List, ctx: ModuleContext) -> None:
        """Find consumer calls in ``stmt`` (excluding nested function
        bodies, which are separate scopes) and update/flag."""
        # nested defs/lambdas are their own scopes; ast.walk would still
        # yield their children, so collect and skip them explicitly
        nested: Set[ast.AST] = set()
        for node in ast.walk(stmt):
            if isinstance(node, FuncNode) and node is not stmt:
                nested.update(ast.walk(node))
        for node in ast.walk(stmt):
            if node in nested or not isinstance(node, ast.Call):
                continue
            key_expr = _consumer_key_expr(node)
            if key_expr is None:
                continue
            kid = _key_id(key_expr)
            if kid is None:
                continue
            prev = state.consumed.get(kid)
            if prev is not None:
                findings.append(ctx.finding(
                    self, node,
                    f"PRNG key {kid!r} already consumed on line {prev}; "
                    "split/fold_in a fresh subkey instead of reusing it",
                ))
            else:
                state.consumed[kid] = node.lineno

    def _scan_block(self, stmts: List[ast.stmt], state: _State,
                    findings: List, ctx: ModuleContext) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue  # separate scope (classes: methods scanned there)
            if isinstance(stmt, ast.If):
                self._consume_in_stmt(stmt.test, state, findings, ctx)
                s_then, s_else = state.copy(), state.copy()
                self._scan_block(stmt.body, s_then, findings, ctx)
                self._scan_block(stmt.orelse, s_else, findings, ctx)
                # post-if state is the union of the branch exits that FALL
                # THROUGH (each inherits the pre-state) — a branch ending in
                # return/raise/break/continue contributes nothing, so
                # guard-style dispatch chains don't cross-contaminate; and
                # because the pre-state is not unioned back in, a key
                # refreshed in both live branches reads as fresh afterwards
                exits = [s for s, body in ((s_then, stmt.body),
                                           (s_else, stmt.orelse))
                         if not _terminates(body)]
                if exits:
                    post = exits[0]
                    post.merge(*exits[1:])
                    state.consumed = post.consumed
                continue
            if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
                if isinstance(stmt, (ast.For, ast.AsyncFor)):
                    self._consume_in_stmt(stmt.iter, state, findings, ctx)
                    state.refresh(_assigned_names(stmt))
                else:
                    self._consume_in_stmt(stmt.test, state, findings, ctx)
                # two passes: the second catches cross-iteration reuse
                body_state = state.copy()
                self._scan_block(stmt.body, body_state, findings, ctx)
                self._scan_block(stmt.body, body_state, findings, ctx)
                self._scan_block(stmt.orelse, body_state, findings, ctx)
                state.merge(body_state)
                continue
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                self._consume_in_stmt(stmt, state, findings, ctx)
                state.refresh(_assigned_names(stmt))
                self._scan_block(stmt.body, state, findings, ctx)
                continue
            if isinstance(stmt, ast.Try):
                s_try = state.copy()
                self._scan_block(stmt.body, s_try, findings, ctx)
                for handler in stmt.handlers:
                    s_h = state.copy()
                    self._scan_block(handler.body, s_h, findings, ctx)
                    s_try.merge(s_h)
                self._scan_block(stmt.orelse, s_try, findings, ctx)
                self._scan_block(stmt.finalbody, s_try, findings, ctx)
                state.merge(s_try)
                continue
            # plain statement: consumers fire, then assignments refresh
            self._consume_in_stmt(stmt, state, findings, ctx)
            state.refresh(_assigned_names(stmt))
