"""Python-level in-jit hazards: the rules over ``ctx.traced_functions``.

Four rules share the heuristic traced-function analysis from
:mod:`repro.analyze.astutils` (functions decorated with ``jit``-likes,
passed to tracing entry points, or nested inside either):

``np-under-trace``
    A ``np.*`` / ``numpy.*`` call inside a traced function whose arguments
    touch traced data (a parameter of the traced function, or a ``jnp.*``
    expression).  numpy executes at trace time: on a tracer it raises, and
    on a value that *happens* to be concrete it silently constant-folds —
    a sweep-lane program that numpy-folds a packed parameter runs every
    lane at the prototype's value.  Static python-scalar numpy math
    (``np.sqrt(2.0)``, ``np.float32`` dtype mentions) is deliberately not
    flagged.

``tracer-leak``
    ``float()`` / ``int()`` / ``bool()`` on traced data inside a traced
    function — forces a concretization error (or, under AOT tracing, a
    baked-in constant).

``traced-branch``
    ``if`` / ``while`` / ``assert`` predicated on a ``jnp.*`` expression
    inside a traced function — Python control flow cannot branch on a
    tracer; use ``lax.cond`` / ``jnp.where``.

``jit-in-loop``
    ``jax.jit(...)`` constructed inside a ``for`` / ``while`` body (or a
    comprehension).  ``jit`` caches per function object, so a fresh
    closure each iteration recompiles each iteration — the exact
    recompile-per-call bug PR 2 fixed in ``run_jit`` / ``monte_carlo``.
    Benchmarks that *intend* one compile per structural size carry a
    ``# repro: noqa[jit-in-loop]`` so the exception is visible in-diff.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analyze.astutils import (
    FuncNode, ModuleContext, dotted_name, matches,
)
from repro.analyze.findings import Finding
from repro.analyze.rules import Rule, register_rule

_NP_ROOTS = ("np", "numpy", "onp")

# np attributes that are safe at trace time: dtype constructors on static
# values are idiomatic, and np.dtype/np.ndarray appear in isinstance checks
_NP_STATIC_OK = frozenset({
    "dtype", "ndarray", "generic", "isscalar", "ndim", "shape",
})

_JNP_PREFIXES = ("jnp.", "jax.numpy.", "jax.nn.", "jax.lax.", "lax.")


def _is_np_call(call: ast.Call) -> Optional[str]:
    dotted = dotted_name(call.func)
    root, _, rest = dotted.partition(".")
    if root in _NP_ROOTS and rest and rest not in _NP_STATIC_OK:
        return dotted
    return None


def _contains_jnp(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            dotted = dotted_name(sub.func)
            if any(dotted.startswith(p) for p in _JNP_PREFIXES):
                return True
    return False


def _touches_traced(ctx: ModuleContext, anchor: ast.AST,
                    expr: ast.AST) -> bool:
    """Whether ``expr`` plausibly evaluates traced data: it mentions a
    parameter of an enclosing traced function, or contains a jnp call."""
    if _contains_jnp(expr):
        return True
    params = ctx.traced_param_names(anchor)
    return any(isinstance(n, ast.Name) and n.id in params
               for n in ast.walk(expr))


@register_rule
class NpUnderTraceRule(Rule):
    id = "np-under-trace"
    severity = "error"
    description = ("numpy call on traced data inside a jitted/scanned/"
                   "vmapped function")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _is_np_call(node)
            if name is None or not ctx.in_traced_function(node):
                continue
            args = list(node.args) + [kw.value for kw in node.keywords]
            if any(_touches_traced(ctx, node, a) for a in args):
                yield ctx.finding(
                    self, node,
                    f"{name}(...) runs at trace time on traced data; "
                    "use jnp (or hoist the static math out of the traced "
                    "function)")


@register_rule
class TracerLeakRule(Rule):
    id = "tracer-leak"
    severity = "error"
    description = "float()/int()/bool() on traced data inside a traced function"

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in ("float", "int", "bool")
                    and len(node.args) == 1):
                continue
            if not ctx.in_traced_function(node):
                continue
            if _touches_traced(ctx, node, node.args[0]):
                yield ctx.finding(
                    self, node,
                    f"{node.func.id}() concretizes a tracer inside a "
                    "traced function; keep it an array (or compute the "
                    "scalar outside the trace)")


@register_rule
class TracedBranchRule(Rule):
    id = "traced-branch"
    severity = "error"
    description = "Python if/while/assert on a jnp expression under trace"

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.If, ast.While)):
                test = node.test
            elif isinstance(node, ast.Assert):
                test = node.test
            elif isinstance(node, ast.IfExp):
                test = node.test
            else:
                continue
            if not ctx.in_traced_function(node):
                continue
            if _contains_jnp(test):
                kind = type(node).__name__.lower()
                yield ctx.finding(
                    self, node,
                    f"python {kind} on a jnp expression under trace "
                    "(TracerBoolConversionError); use lax.cond / "
                    "lax.select / jnp.where")


@register_rule
class JitInLoopRule(Rule):
    id = "jit-in-loop"
    severity = "warning"
    description = "jax.jit constructed inside a loop (recompiles per iteration)"

    _LOOPS = (ast.For, ast.AsyncFor, ast.While,
              ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp)

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and matches(dotted_name(node.func),
                                frozenset({"jax.jit", "jit"}))):
                continue
            for anc in ctx.ancestors(node):
                if isinstance(anc, FuncNode):
                    break  # a def inside the loop is a fresh scope per
                           # call anyway; only flag jits directly in a loop
                if isinstance(anc, self._LOOPS):
                    yield ctx.finding(
                        self, node,
                        "jax.jit(...) inside a loop compiles a fresh "
                        "program per iteration; hoist it (or cache like "
                        "fedpg._compiled_run)")
                    break
