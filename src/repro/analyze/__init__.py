"""``repro.analyze``: the repo's static contract checker.

Two layers behind one CLI (``python -m repro.analyze``):

* **AST rules** (:mod:`repro.analyze.engine`, :mod:`repro.analyze.rules`)
  parse every Python file under ``src/``, ``benchmarks/``, ``examples/``
  and flag source-level hazards: PRNG key reuse, deprecated aggregation
  callers, numpy/``float()``/``if`` on traced values, ``jax.jit`` in
  loops, ad-hoc ``XLA_FLAGS`` surgery.  No jax import — pre-commit cheap.
* **Trace-level contracts** (:mod:`repro.analyze.contracts`) import the
  real registries and trace representative programs: the bitwise-lane
  packing contract, the sanctioned-narrowing wire-dtype rule, compile
  budgets (:mod:`repro.analyze.budget`), and the agent-mesh collective
  audit.

Both layers emit :class:`~repro.analyze.findings.Finding` records into one
:class:`~repro.analyze.findings.Report` (text + ``ANALYZE_report.json``);
``# repro: noqa[rule-id]`` suppresses AST findings inline.  CI runs
``python -m repro.analyze --strict`` and fails on any finding.
"""
from repro.analyze.engine import (  # noqa: F401
    DEFAULT_ROOTS, repo_root, scan, scan_source,
)
from repro.analyze.findings import Finding, Report  # noqa: F401
from repro.analyze.rules import (  # noqa: F401
    Rule, all_rules, get_rules, register_rule,
)

__all__ = [
    "DEFAULT_ROOTS", "Finding", "Report", "Rule", "all_rules", "get_rules",
    "register_rule", "repo_root", "run", "scan", "scan_source",
]


def run(targets=None, *, rules=None, ast_only: bool = False,
        checks=None) -> "Report":
    """One full analyzer pass: AST scan + (unless ``ast_only``) contracts.

    The importable equivalent of the CLI; ``repro.analyze.contracts`` is
    imported lazily so AST-only callers never touch jax.
    """
    report = scan(repo_root(), targets or DEFAULT_ROOTS, rules=rules)
    if not ast_only:
        from repro.analyze.contracts import run_contracts

        run_contracts(report, checks=checks)
    return report
