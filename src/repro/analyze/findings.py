"""Finding records, suppression syntax, and report rendering.

A :class:`Finding` is one analyzer hit: ``(rule, severity, file, line,
message)``.  Both analysis layers — the AST rule engine
(:mod:`repro.analyze.engine`) and the trace-level contract checkers
(:mod:`repro.analyze.contracts`) — emit the same record type, so one report
(text + ``ANALYZE_report.json``) covers the whole run.

Suppression is inline and therefore visible in-diff::

    os.environ["XLA_FLAGS"] = flags   # repro: noqa[xla-flags] bootstrap shim

``# repro: noqa[rule-a,rule-b]`` silences the named rules on that physical
line; a bare ``# repro: noqa`` silences every rule on the line.  Suppressed
findings are dropped from the exit-code accounting but still counted in the
JSON report (``counts.suppressed``) so exceptions never become invisible.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence

SEVERITIES = ("error", "warning", "info")

_NOQA_RE = re.compile(r"#\s*repro:\s*noqa(?:\[([A-Za-z0-9_\-, ]+)\])?")


@dataclass(frozen=True)
class Finding:
    """One analyzer hit (AST rule or trace-level contract violation)."""

    rule: str
    severity: str      # "error" | "warning" | "info"
    path: str          # repo-relative (or "<trace>" for contract checks)
    line: int          # 1-based; 0 when the finding has no source anchor
    message: str
    col: int = 0

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity {self.severity!r} not in {SEVERITIES}")

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.rule}] {self.severity}: {self.message}"


def noqa_rules(line: str) -> Optional[frozenset]:
    """The rule ids suppressed by ``line``'s trailing comment.

    Returns ``None`` when the line carries no ``repro: noqa`` marker, an
    empty frozenset for the bare blanket form (suppress everything), and a
    frozenset of rule ids for the bracketed form.
    """
    m = _NOQA_RE.search(line)
    if m is None:
        return None
    if m.group(1) is None:
        return frozenset()
    return frozenset(t.strip() for t in m.group(1).split(",") if t.strip())


def is_suppressed(finding: Finding, source_line: str) -> bool:
    rules = noqa_rules(source_line)
    if rules is None:
        return False
    return not rules or finding.rule in rules


@dataclass
class Report:
    """The full result of one analyzer run."""

    findings: List[Finding] = field(default_factory=list)
    suppressed: List[Finding] = field(default_factory=list)
    skipped: List[str] = field(default_factory=list)   # disabled checks + why
    files_scanned: int = 0

    def extend(self, findings: Sequence[Finding]) -> None:
        self.findings.extend(findings)

    @property
    def counts(self) -> Dict[str, int]:
        c = {s: 0 for s in SEVERITIES}
        for f in self.findings:
            c[f.severity] += 1
        c["suppressed"] = len(self.suppressed)
        return c

    def exit_code(self, strict: bool = False) -> int:
        """0 when clean; 1 on any error, or on any finding under --strict."""
        if strict:
            return 1 if self.findings else 0
        return 1 if self.counts["error"] else 0

    def to_json(self) -> str:
        return json.dumps(
            {
                "version": 1,
                "generated_by": "repro.analyze",
                "counts": self.counts,
                "files_scanned": self.files_scanned,
                "skipped": self.skipped,
                "findings": [asdict(f) for f in self.findings],
                "suppressed": [asdict(f) for f in self.suppressed],
            },
            indent=2, sort_keys=True,
        )

    def render_text(self) -> str:
        lines = []
        order = {s: i for i, s in enumerate(SEVERITIES)}
        for f in sorted(self.findings,
                        key=lambda f: (order[f.severity], f.path, f.line)):
            lines.append(f.render())
        for note in self.skipped:
            lines.append(f"skipped: {note}")
        c = self.counts
        lines.append(
            f"{len(self.findings)} finding(s) "
            f"({c['error']} error, {c['warning']} warning, {c['info']} info; "
            f"{c['suppressed']} suppressed) in {self.files_scanned} file(s)")
        return "\n".join(lines)
