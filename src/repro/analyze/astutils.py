"""Shared AST analyses for the rule engine.

Rules receive a :class:`ModuleContext` — one parsed module plus the
lazily-computed analyses every rule needs:

* ``dotted_name(node)`` — best-effort dotted name of a ``Name``/``Attribute``
  chain (``jax.lax.scan``), empty string otherwise;
* ``ctx.traced_functions`` — the set of function/lambda nodes that run under
  a JAX trace: decorated with ``jit``-likes, passed as callables to tracing
  entry points (``jit``/``vmap``/``scan``/``shard_map``/``pallas_call``/…),
  or lexically nested inside either;
* ``ctx.parents`` — child -> parent AST links, for ancestor queries.

The analysis is deliberately heuristic (no interprocedural dataflow): rules
built on it aim for high precision on this repo's idioms, with
``# repro: noqa[rule-id]`` as the escape hatch for deliberate exceptions.
No jax import happens anywhere in the AST layer — it must stay cheap enough
to run as a pre-commit-grade lint.
"""
from __future__ import annotations

import ast
import pathlib
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Set

FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)

# Call targets whose function-valued arguments run under a JAX trace.  A
# dotted name matches if it equals an entry or ends with "." + entry.
TRACE_ENTRIES = frozenset({
    "jax.jit", "jit", "jax.vmap", "vmap", "jax.pmap", "pmap",
    "jax.grad", "grad", "jax.value_and_grad", "value_and_grad",
    "jax.jacfwd", "jax.jacrev", "jax.hessian", "jax.linearize",
    "jax.make_jaxpr", "make_jaxpr", "jax.checkpoint", "jax.remat",
    "lax.scan", "lax.map", "lax.cond", "lax.while_loop", "lax.fori_loop",
    "lax.switch", "lax.associative_scan", "lax.custom_root",
    "shard_map", "pallas_call", "jax.eval_shape", "eval_shape",
})

# Decorators that make the decorated function a traced function.
TRACE_DECORATORS = frozenset({
    "jax.jit", "jit", "jax.checkpoint", "jax.remat", "jax.custom_jvp",
    "jax.custom_vjp", "jax.vmap", "jax.pmap",
})


def dotted_name(node: ast.AST) -> str:
    """``jax.lax.scan`` for a Name/Attribute chain; "" when not a chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def matches(dotted: str, entries: frozenset) -> bool:
    """Whether a dotted name is one of ``entries`` (exact or suffix)."""
    if not dotted:
        return False
    if dotted in entries:
        return True
    return any(dotted.endswith("." + e) for e in entries)


def _is_trace_decorator(dec: ast.AST) -> bool:
    if isinstance(dec, ast.Call):
        # @partial(jax.jit, ...) / @functools.partial(jax.jit, ...)
        if dotted_name(dec.func).rsplit(".", 1)[-1] == "partial" and dec.args:
            return matches(dotted_name(dec.args[0]), TRACE_DECORATORS)
        return matches(dotted_name(dec.func), TRACE_DECORATORS)
    return matches(dotted_name(dec), TRACE_DECORATORS)


@dataclass
class ModuleContext:
    """One parsed module plus the lazily-computed shared analyses."""

    path: pathlib.Path           # absolute
    relpath: str                 # repo-relative posix path
    tree: ast.Module
    source_lines: List[str]
    _parents: Optional[Dict[ast.AST, ast.AST]] = field(default=None,
                                                       repr=False)
    _traced: Optional[Set[ast.AST]] = field(default=None, repr=False)

    def finding(self, rule, node: ast.AST, message: str,
                severity: Optional[str] = None):
        from repro.analyze.findings import Finding

        return Finding(
            rule=rule.id, severity=severity or rule.severity,
            path=self.relpath, line=getattr(node, "lineno", 0),
            col=getattr(node, "col_offset", 0), message=message,
        )

    @property
    def parents(self) -> Dict[ast.AST, ast.AST]:
        if self._parents is None:
            self._parents = {
                child: parent
                for parent in ast.walk(self.tree)
                for child in ast.iter_child_nodes(parent)
            }
        return self._parents

    def ancestors(self, node: ast.AST) -> Iterator[ast.AST]:
        cur = self.parents.get(node)
        while cur is not None:
            yield cur
            cur = self.parents.get(cur)

    def enclosing_function(self, node: ast.AST):
        for anc in self.ancestors(node):
            if isinstance(anc, FuncNode):
                return anc
        return None

    @property
    def traced_functions(self) -> Set[ast.AST]:
        """Function/Lambda nodes that (heuristically) run under a trace."""
        if self._traced is not None:
            return self._traced

        by_name: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                by_name.setdefault(node.name, []).append(node)

        traced: Set[ast.AST] = set()
        # (a) trace-decorated defs
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(_is_trace_decorator(d) for d in node.decorator_list):
                    traced.add(node)
        # (b) function-valued arguments of tracing entry points
        for node in ast.walk(self.tree):
            if not (isinstance(node, ast.Call)
                    and matches(dotted_name(node.func), TRACE_ENTRIES)):
                continue
            args = list(node.args) + [kw.value for kw in node.keywords]
            for arg in args:
                if isinstance(arg, ast.Lambda):
                    traced.add(arg)
                elif isinstance(arg, ast.Name) and arg.id in by_name:
                    traced.update(by_name[arg.id])
        # (c) everything lexically nested inside a traced function
        frontier = list(traced)
        while frontier:
            fn = frontier.pop()
            for sub in ast.walk(fn):
                if isinstance(sub, FuncNode) and sub not in traced:
                    traced.add(sub)
                    frontier.append(sub)
        self._traced = traced
        return traced

    def in_traced_function(self, node: ast.AST) -> bool:
        fn = self.enclosing_function(node)
        while fn is not None:
            if fn in self.traced_functions:
                return True
            fn = self.enclosing_function(fn)
        return False

    def traced_param_names(self, node: ast.AST) -> Set[str]:
        """Parameter names of every traced function enclosing ``node`` —
        the names most likely bound to tracers at runtime."""
        names: Set[str] = set()
        fn = self.enclosing_function(node)
        while fn is not None:
            if fn in self.traced_functions:
                names |= param_names(fn)
            fn = self.enclosing_function(fn)
        return names


def param_names(fn: ast.AST) -> Set[str]:
    """Positional/keyword parameter names of a function/lambda node."""
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return set(names)


def parse_module(path: pathlib.Path, relpath: str) -> Optional[ModuleContext]:
    try:
        source = path.read_text(encoding="utf-8")
        tree = ast.parse(source, filename=str(path))
    except (SyntaxError, UnicodeDecodeError, OSError):
        return None
    return ModuleContext(path=path, relpath=relpath, tree=tree,
                         source_lines=source.splitlines())
