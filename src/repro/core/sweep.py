"""Batched scenario-sweep engine: the paper's figure grids as ONE program.

The paper's results (Figs. 1-5) — and the wider channel/power-control grids
of the related over-the-air FL literature — are grids of scenarios:
``(channel params, noise_sigma, alpha, n_agents, estimator, power control)``.
Running each grid point through its own ``fedpg.monte_carlo`` call re-traces
and re-compiles a fresh XLA program per point, so a benchmark suite spends
most of its wall time inside the compiler.

This module expresses the grid declaratively and compiles **one program per
structural partition**:

* **structural axes** change the trace shape or graph and force a partition
  split: ``n_agents``, ``batch_m``, ``horizon``, ``n_rounds``, ``gamma``,
  ``estimator``, ``debias``, the channel *family*, the power-control policy
  *type*, the environment *family* (registry kind tag, incl. structural
  sizes like grid dims), the policy, noise on/off, and exact-vs-OTA uplink;
* **continuous axes** (channel parameters, ``noise_sigma``, ``alpha``,
  power-control parameters, environment parameters — wind strengths, slip
  probabilities, Garnet P/l/rho tables) batch inside a single jitted
  program — mapped over scenarios, ``vmap``-ed over Monte-Carlo seeds —
  reusing the existing ``fedpg.run`` round body unchanged.

Exactness contract: a continuous axis that does **not** vary inside a
partition is closed over as the same Python-float literal the per-scenario
path uses, so those lanes are **bit-identical** to ``fedpg.monte_carlo``
under the same PRNG keys (XLA folds literals; re-materialising them as
runtime values can move a multiply and drift the last mantissa bit).  Axes
that do vary are fed as traced scalars via ``BatchedChannel`` /
``OTAConfig.update_scale``, whose float64-precomputed derived constants keep
the channel draws and updates bit-identical as well; likewise the env
registry packs only *varying* env parameters, so constant fields stay
folded literals.  Two exceptions: the debias normaliser when the axes it
depends on — channel parameters, or power-control parameters (effective
moments) — vary within a partition, where ``grad_sq`` may differ in the
final bit (documented in ``Scenario.debias``); and env families whose
dynamics run matvec/quadratic reductions over the traced parameters (LQR),
whose fusions may reassociate the final mantissa bit — elementwise-dynamics
families (particle, cliff-walk, tabular) stay bitwise.

Typical use::

    scenarios = grid(
        channel=[RayleighChannel(), NakagamiChannel(m=0.1, omega=1.0)],
        noise_sigma=[1e-3, 1e-2],
        alpha=[1e-3, 1e-4],
        n_agents=10, batch_m=10, n_rounds=200, debias=True,
    )
    result = sweep(env, policy, scenarios, jax.random.key(0), mc_runs=20)
    print(result.to_csv())
"""
from __future__ import annotations

import dataclasses
import io
import itertools
import math
import time
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fedpg
from repro.core.channel import (
    BatchedChannel, Channel, batched_channel_arrays, channel_kind,
)
from repro.core.fedpg import FedPGConfig, History
from repro.core.ota import OTAConfig
from repro.core.power_control import (
    PowerPolicy, check_agent_count, effective_moments,
)
from repro.rl.envs import (
    batched_env_arrays, build_lane_env, env_kind, robust_eq, values_vary,
)
from repro.rl.envs import check_agent_count as check_env_agent_count
from repro.rl.envs import default_policy as env_default_policy
from repro.service import participation as svc_participation
from repro.service import staleness as svc_staleness
from repro.service.participation import ParticipationConfig
from repro.service.staleness import StalenessConfig
from repro.telemetry import trace as rtrace
from repro.telemetry import probes as _probes
from repro.telemetry.probes import RoundTelemetry, TelemetryConfig

# Modes for laying scenarios into the partition program.  ``vmap`` (default)
# batches lanes into one vectorised computation — fastest on one device, and
# bit-identical to ``monte_carlo`` whenever the debias normaliser is
# partition-constant.  ``map`` runs the lanes through ``lax.map`` (sequential
# inside one program); every lane keeps the exact rank of the unbatched path,
# which is the conservative choice if a platform's batched reductions ever
# reassociate.  ``sharded`` is the vmap program with its lane/MC axes laid
# across a device mesh (``repro.core.distribute``): partitions dispatch
# asynchronously, uneven lane counts pad with masked replicate-lanes, and
# results stay bit-identical to ``vmap`` — sharding only moves data
# placement, never the per-lane jaxpr.
MODES = ("map", "vmap", "sharded")


@dataclass(frozen=True)
class Scenario:
    """One grid point: everything a single ``monte_carlo`` call would need.

    ``channel=None`` selects the exact Algorithm-1 uplink (``ota=None``).
    ``debias`` divides the update by the *effective* gain mean ``m_h``: the
    channel mean when ``power_control`` is None (the plain ``OTAConfig``
    convention), and the effective-gain mean ``E[c p(c)]`` — closed form
    where known, deterministic Monte Carlo otherwise — when a policy is set
    (threaded through ``OTAConfig.update_scale`` in float64, so batched
    lanes and the per-scenario path fold in the identical constant).

    ``env=None`` runs the environment ``sweep()`` was called with (the
    pre-env-zoo convention); an env instance makes the workload itself a
    grid axis — the env *family* (registry kind tag) is structural, its
    continuous parameters batch as lanes through the registry packer hooks.
    ``policy=None`` resolves to ``sweep()``'s policy for default-env
    scenarios and to the env family's ``default_policy()`` otherwise.
    """

    channel: Optional[Channel] = None
    noise_sigma: float = 0.0
    alpha: float = 1e-3
    n_agents: int = 10
    batch_m: int = 10
    horizon: int = 20
    gamma: float = 0.99
    n_rounds: int = 200
    estimator: str = "gpomdp"
    power_control: Optional[PowerPolicy] = None
    debias: bool = False
    # streaming round form: lax.scan over agent blocks (structural — it
    # changes the jaxpr, so it splits partitions; see fedpg.make_round_fn)
    agent_blocks: Optional[int] = None
    # round-service axes (fedpg.run(participation=..., staleness=...)):
    # the participation *kind*, debias mode, fault structure, and replay
    # depth are structural; the Bernoulli rate, the fault deadline (under
    # realized debias), and the age-decay batch as lanes
    participation: Optional[ParticipationConfig] = None
    staleness: Optional[StalenessConfig] = None
    env: Any = None
    policy: Any = None
    tag: str = ""  # free-form label carried into tables/CSV

    def fedpg_config(self) -> FedPGConfig:
        return FedPGConfig(
            n_agents=self.n_agents, batch_m=self.batch_m, horizon=self.horizon,
            gamma=self.gamma, alpha=self.alpha, n_rounds=self.n_rounds,
            estimator=self.estimator,
        )

    def effective_moments(self) -> Tuple[float, float]:
        """The effective-gain (m_h, sigma_h^2) this scenario realises —
        including power control — in float64.  This is the pair the
        Theorem-1/2 bounds must be evaluated with."""
        if self.channel is None:
            return 1.0, 0.0
        check_agent_count(self.channel, self.n_agents)
        if self.power_control is None:
            return float(self.channel.mean), float(self.channel.var)
        return effective_moments(self.channel, self.power_control,
                                 n_agents=self.n_agents)

    def ota_config(self) -> Optional[OTAConfig]:
        """The equivalent per-scenario OTAConfig (None for exact uplink)."""
        if self.channel is None:
            return None
        check_agent_count(self.channel, self.n_agents)
        update_scale = None
        if self.debias and self.power_control is not None:
            m_eff, _ = self.effective_moments()
            update_scale = 1.0 / (self.n_agents * m_eff)
        return OTAConfig(
            channel=self.channel, noise_sigma=self.noise_sigma,
            debias=self.debias, power_control=self.power_control,
            update_scale=update_scale,
        )

    def describe(self) -> Dict[str, Any]:
        """Flat, CSV-friendly view of the scenario."""
        chan = "exact" if self.channel is None else _channel_tag(self.channel)
        chan_params = "" if self.channel is None else ";".join(
            f"{f.name}={_fmt_param(getattr(self.channel, f.name))}"
            for f in dataclasses.fields(self.channel)
        )
        pc = "" if self.power_control is None else type(self.power_control).__name__
        pc_params = "" if self.power_control is None else ";".join(
            f"{f.name}={_fmt_param(getattr(self.power_control, f.name))}"
            for f in dataclasses.fields(self.power_control)
        )
        env_tag = "default" if self.env is None else _env_tag(self.env)
        env_params = ""
        if self.env is not None and dataclasses.is_dataclass(self.env):
            env_params = ";".join(
                f"{f.name}={_fmt_param(getattr(self.env, f.name))}"
                for f in dataclasses.fields(self.env)
            )
        pol = "" if self.policy is None else type(self.policy).__name__
        pp = self.participation
        part_kind = "" if pp is None else pp.kind
        part_rate: Any = ""
        if pp is not None:
            part_rate = pp.rate if pp.kind == "bernoulli" else (
                pp.subset if pp.kind == "subset" else "")
        part_debias = "" if pp is None else pp.debias
        faults = ""
        if pp is not None and pp.faults is not None:
            faults = "active" if pp.faults.active else "inactive"
        st = self.staleness
        m_eff, v_eff = self.effective_moments()
        return {
            "tag": self.tag, "channel": chan, "channel_params": chan_params,
            "noise_sigma": self.noise_sigma, "alpha": self.alpha,
            "n_agents": self.n_agents, "batch_m": self.batch_m,
            "horizon": self.horizon, "gamma": self.gamma,
            "n_rounds": self.n_rounds, "estimator": self.estimator,
            "power_control": pc, "power_control_params": pc_params,
            "debias": self.debias,
            "agent_blocks": "" if self.agent_blocks is None
            else self.agent_blocks,
            "participation": part_kind, "participation_rate": part_rate,
            "participation_debias": part_debias, "faults": faults,
            "staleness_max_age": "" if st is None else st.max_age,
            "staleness_decay": "" if st is None else st.decay,
            "env": env_tag, "env_params": env_params,
            "policy": pol, "m_h_eff": m_eff, "sigma_h2_eff": v_eff,
        }


def _fmt_param(v: Any) -> str:
    """Compact field rendering for describe(): numbers as %g, nested
    channel/policy objects (e.g. ControlledChannel.base) as their type,
    array-valued env parameters (TabularMDP tables, per-agent stacks) as
    their shape."""
    if isinstance(v, (int, float)):
        return f"{v:g}"
    if dataclasses.is_dataclass(v):
        return type(v).__name__
    if isinstance(v, (np.ndarray, jax.Array)):
        return f"array{tuple(v.shape)}"
    if isinstance(v, dict):
        return "{" + " ".join(sorted(v)) + "}"
    return str(v)


def _env_tag(env: Any) -> str:
    """Registry kind when available, else the concrete type name (custom
    envs outside the registry still sweep fine as partition constants)."""
    try:
        return env_kind(env)
    except ValueError:
        return type(env).__name__


def resolve_env_policy(scenario: Scenario, env: Any = None, policy: Any = None):
    """The (env, policy) a scenario actually runs: scenario fields override
    the sweep-level defaults; a scenario-specific env with no explicit
    policy resolves through the registry's ``default_policy`` hook (the
    sweep-level policy is for the sweep-level env and would generally
    mismatch the scenario env's observation/action spaces)."""
    e = scenario.env if scenario.env is not None else env
    if e is None:
        raise ValueError(
            "scenario has no env: set Scenario.env or pass sweep(env=...)"
        )
    if scenario.policy is not None:
        p = scenario.policy
    elif scenario.env is None and policy is not None:
        p = policy
    else:
        p = env_default_policy(e)
    check_env_agent_count(e, scenario.n_agents)
    return e, p


def grid(**axes) -> List[Scenario]:
    """Cartesian product of scenario axes.

    Each keyword is a ``Scenario`` field; a list/tuple value is an axis, a
    scalar is a fixed setting.  Axis order follows keyword order, last axis
    fastest — matching nested for-loops over the same lists.
    """
    valid = {f.name for f in dataclasses.fields(Scenario)}
    unknown = set(axes) - valid
    if unknown:
        raise ValueError(f"unknown scenario axes {sorted(unknown)}; "
                         f"choose from {sorted(valid)}")
    names = list(axes)
    values = [v if isinstance(v, (list, tuple)) else [v] for v in axes.values()]
    return [Scenario(**dict(zip(names, combo)))
            for combo in itertools.product(*values)]


# ---------------------------------------------------------------------------
# Partitioning by structural shape.
# ---------------------------------------------------------------------------

def _channel_tag(ch: Channel) -> str:
    """Registry kind when available, else the concrete type name (custom
    channels outside the registry still sweep fine as long as they don't
    vary within a partition)."""
    try:
        return channel_kind(ch)
    except ValueError:
        return type(ch).__name__


def _workload_key(s: Scenario) -> Tuple:
    """The (env, policy) part of the structure key.  The env *family* (kind
    tag, which encodes structural ints like grid sizes) splits partitions;
    same-family instances batch their continuous params as lanes.  The
    policy is structural outright (its params pytree shapes the trace)."""
    env_tag = None if s.env is None else _env_tag(s.env)
    if s.policy is None:
        pol_tag = None
    else:
        try:
            hash(s.policy)
            pol_tag = s.policy
        except TypeError:
            # unhashable policies (params-carrying dataclasses) split by
            # identity: merging distinct instances by type would silently
            # run the prototype's policy for every lane
            pol_tag = (type(s.policy).__name__, id(s.policy))
    return env_tag, pol_tag


def _service_key(s: Scenario) -> Tuple:
    """The round-service part of the structure key.  Normalised first, so
    a config that can never drop an agent shares its partition with plain
    scenarios (byte-identical programs).  The Bernoulli ``rate``, the
    fault ``deadline`` (realized debias only — the expected normaliser is
    a host-side closed form over the deadline, so a traced deadline can't
    feed it), and the staleness ``decay`` are continuous lane axes and
    are sentinel-zeroed out of the key; everything else is structural."""
    p = svc_participation.normalize(s.participation, s.n_agents)
    if p is None:
        return (None, None)
    f = p.faults if (p.faults is not None and p.faults.active) else None
    if f is None:
        f_tag = None
    else:
        dl_tag = -1.0 if p.debias == "realized" else f.deadline
        f_tag = (f.stragglers, dl_tag, f.crashes)
    rate_tag = -1.0 if p.kind == "bernoulli" else 0.0
    p_tag = (p.kind, rate_tag, p.subset, p.debias, f_tag)
    st = svc_staleness.normalize(s.staleness, p)
    st_tag = None if st is None else (st.max_age, -1.0)
    return (p_tag, st_tag)


def _structure_key(s: Scenario) -> Tuple:
    """Everything that changes the trace shape or the computation graph."""
    if s.channel is None:
        # exact uplink: the OTA-only axes don't reach the program — zero
        # them so equivalent exact scenarios share one partition/compile.
        return (s.n_agents, s.batch_m, s.horizon, s.gamma, s.n_rounds,
                s.estimator, False, None, None, False,
                s.agent_blocks) + _service_key(s) + _workload_key(s)
    pc = None if s.power_control is None else type(s.power_control).__name__
    return (s.n_agents, s.batch_m, s.horizon, s.gamma, s.n_rounds,
            s.estimator, s.debias, _channel_tag(s.channel), pc,
            s.noise_sigma > 0.0, s.agent_blocks) + _service_key(s) \
        + _workload_key(s)


@dataclass
class Partition:
    """A structurally-uniform slice of the grid, compiled as one program."""

    indices: List[int]           # positions in the original scenario list
    scenarios: List[Scenario]
    key: Tuple = ()
    wall_time_us: float = 0.0    # compile + execute, filled in by sweep()

    @property
    def proto(self) -> Scenario:
        return self.scenarios[0]

    def varying(self, name: str) -> bool:
        # unhashable values (envs carrying arrays: TabularMDP,
        # HeterogeneousEnv) fall back to identity — distinct instances
        # count as varying, so reuse ONE instance for a partition constant
        return values_vary([getattr(s, name) for s in self.scenarios])


def partition_scenarios(scenarios: Sequence[Scenario]) -> List[Partition]:
    groups: Dict[Tuple, Partition] = {}
    for i, s in enumerate(scenarios):
        k = _structure_key(s)
        part = groups.setdefault(k, Partition(indices=[], scenarios=[], key=k))
        part.indices.append(i)
        part.scenarios.append(s)
    return list(groups.values())


def _norm_const64(s: Scenario) -> float:
    """The per-scenario debias normaliser, in float64: the *effective* gain
    mean under power control, the raw channel mean otherwise (matching
    ``Scenario.ota_config``)."""
    if not s.debias:
        return 1.0
    return s.effective_moments()[0]


def _pack_partition(part: Partition) -> Dict[str, Any]:
    """Stack the axes that actually vary inside this partition.

    Returns a dict of (S,)-shaped float32 arrays (dtypes match what the
    unbatched path would have produced after weak-type promotion); constant
    axes are deliberately left out so the lane builder closes over the same
    Python literals the per-scenario program uses.
    """
    packed: Dict[str, Any] = {}

    def f32(vals64):
        return jnp.asarray(np.asarray(vals64, np.float64), jnp.float32)

    if part.proto.env is not None and part.varying("env"):
        _, env_arrays = batched_env_arrays([s.env for s in part.scenarios])
        # identity-distinct but parameter-identical envs (e.g. two all-equal
        # fleets) pack to nothing: leave them out so the partition takes the
        # replicate-one-lane path instead of vmapping a zero-leaf pytree
        if env_arrays:
            packed["env"] = {k: f32(v) for k, v in env_arrays.items()}
    if part.varying("alpha"):
        packed["alpha"] = f32([s.alpha for s in part.scenarios])
    if part.proto.channel is not None:
        if part.varying("noise_sigma"):
            packed["noise_sigma"] = f32([s.noise_sigma for s in part.scenarios])
        if part.varying("channel"):
            kind, arrays = batched_channel_arrays(
                [s.channel for s in part.scenarios])
            packed["channel"] = {k: f32(v) for k, v in arrays.items()}
        if part.proto.power_control is not None and part.varying("power_control"):
            fields = dataclasses.fields(part.proto.power_control)
            packed["power_control"] = {
                f.name: f32([float(getattr(s.power_control, f.name))
                             for s in part.scenarios])
                for f in fields
            }
        # the debias normaliser follows whichever axis moves the effective
        # moments — channel params or power-control params
        if part.proto.debias and (part.varying("channel")
                                  or "power_control" in packed):
            packed["update_scale"] = f32([
                1.0 / (s.n_agents * _norm_const64(s))
                for s in part.scenarios
            ])
    # round-service lane axes: structure keying guarantees every scenario
    # here normalises to the same shape as the prototype, so only the
    # continuous knobs can differ
    p0 = svc_participation.normalize(part.proto.participation,
                                     part.proto.n_agents)
    if p0 is not None:
        parts_n = [svc_participation.normalize(s.participation, s.n_agents)
                   for s in part.scenarios]
        if p0.kind == "bernoulli":
            rates = [float(p.rate) for p in parts_n]
            if values_vary(rates):
                packed["participation_rate"] = f32(rates)
        if p0.debias == "realized" and p0.faults is not None \
                and p0.faults.active:
            deadlines = [float(p.faults.deadline) for p in parts_n]
            if values_vary(deadlines):
                packed["participation_deadline"] = f32(deadlines)
        st0 = svc_staleness.normalize(part.proto.staleness, p0)
        if st0 is not None:
            decays = [float(svc_staleness.normalize(s.staleness, pn).decay)
                      for s, pn in zip(part.scenarios, parts_n)]
            if values_vary(decays):
                packed["staleness_decay"] = f32(decays)
    return packed


def _make_lane(env, policy, part: Partition,
               telemetry: Optional[TelemetryConfig] = None):
    """Build lane(packed_slice, keys) -> History(stacked over mc_runs).

    ``packed_slice`` holds only the *varying* axes (scalar tracers inside
    the partition program); everything constant is closed over exactly as
    the per-scenario path would.  ``keys`` stays a runtime argument — just
    like ``monte_carlo`` passes it — so XLA cannot constant-fold the PRNG
    chain differently than the unbatched program.
    """
    proto = part.proto
    base_cfg = proto.fedpg_config()
    # The scenario-resolved workload: proto env/policy override the sweep
    # defaults, same resolution the per-scenario reference path uses.
    lane_env, lane_policy = resolve_env_policy(proto, env, policy)
    # The per-scenario OTAConfig of the prototype: every constant axis —
    # including a power-control-derived update_scale literal — is closed
    # over exactly as the unbatched path would fold it in.
    proto_ota = proto.ota_config()
    # Registry kind, only needed when channel params vary (BatchedChannel);
    # constant non-registry channels are closed over like any other.
    chan_kind = (channel_kind(proto.channel)
                 if proto.channel is not None and part.varying("channel")
                 else None)
    # Likewise for env params: the registry builder reconstructs a lane env
    # from traced scalars; constant envs are closed over as-is.
    env_tag = (env_kind(proto.env)
               if proto.env is not None and part.varying("env")
               else None)
    pc_type = None if proto.power_control is None else type(proto.power_control)
    # normalised prototype service configs: constant partitions close over
    # them whole (same literals as the per-scenario path); varying knobs
    # are re-injected as traced lane scalars below
    proto_part = svc_participation.normalize(proto.participation,
                                             proto.n_agents)
    proto_stale = svc_staleness.normalize(proto.staleness, proto_part)

    def lane(packed: Dict[str, Any], keys: jax.Array) -> History:
        env_l = lane_env
        if "env" in packed:
            env_l = build_lane_env(env_tag, lane_env, packed["env"])
        cfg = base_cfg
        if "alpha" in packed:
            cfg = replace(cfg, alpha=packed["alpha"])
        ota = proto_ota
        if ota is not None:
            if "channel" in packed:
                channel: Channel = BatchedChannel(
                    kind=chan_kind, params=packed["channel"])
                ota = replace(ota, channel=channel)
            if "noise_sigma" in packed:
                ota = replace(ota, noise_sigma=packed["noise_sigma"])
            if "power_control" in packed:
                ota = replace(ota, power_control=pc_type(**packed["power_control"]))
            if "update_scale" in packed:
                ota = replace(ota, update_scale=packed["update_scale"])
        part_l = proto_part
        if "participation_rate" in packed:
            part_l = replace(part_l, rate=packed["participation_rate"])
        if "participation_deadline" in packed:
            part_l = replace(part_l, faults=replace(
                part_l.faults, deadline=packed["participation_deadline"]))
        stale_l = proto_stale
        if "staleness_decay" in packed:
            stale_l = replace(stale_l, decay=packed["staleness_decay"])
        return jax.vmap(
            lambda k: fedpg.run(env_l, lane_policy, cfg, k, ota=ota,
                                telemetry=telemetry,
                                agent_blocks=proto.agent_blocks,
                                participation=part_l,
                                staleness=stale_l)[1]
        )(keys)

    return lane


def lane_program(env, policy, part: Partition, mc_runs: int = 2,
                 telemetry: Optional[TelemetryConfig] = None):
    """The partition's program, exposed for structural inspection.

    Returns ``(packed, fn, keys)`` where ``fn(packed, keys)`` is exactly the
    callable ``sweep()`` would jit for this partition in ``mode="vmap"``
    (vmapped over lanes when anything varies, the single replicate lane
    otherwise) and ``keys`` is a ``split``-shaped example argument.  This is
    the hook ``repro.analyze.contracts.check_lane_contract`` traces: the
    bitwise-lane exactness contract says ``packed`` holds *only* the axes
    that vary inside the partition — every packed leaf must differ across
    lanes and must survive as a consumed dynamic input of the traced
    program, while constant axes stay closed-over Python literals.
    """
    packed = _pack_partition(part)
    lane = _make_lane(env, policy, part,
                      telemetry=fedpg._active_telemetry(telemetry))
    keys = jax.random.split(jax.random.key(0), mc_runs)
    fn = jax.vmap(lane, in_axes=(0, None)) if packed else lane
    return packed, fn, keys


# ---------------------------------------------------------------------------
# Results.
# ---------------------------------------------------------------------------

@dataclass
class SweepResult:
    """Histories for every scenario, plus grid/partition bookkeeping.

    ``history`` leaves have shape ``(n_scenarios, mc_runs, n_rounds)`` in
    the original scenario order (a 1-D object array of ``(mc_runs, K_i)``
    arrays when the grid varies ``n_rounds``).  ``mode``/``n_devices``
    record how the partitions executed (``n_devices > 1`` only for
    ``mode="sharded"``).
    """

    scenarios: List[Scenario]
    history: History
    partitions: List[Partition] = field(default_factory=list)
    mc_runs: int = 0
    mode: str = "vmap"
    n_devices: int = 1

    @property
    def n_partitions(self) -> int:
        return len(self.partitions)

    def scenario_time_us(self, i: int) -> float:
        """Per-(scenario, MC run) share of the owning partition's wall time
        — structurally different scenarios keep distinguishable timings.
        Synchronous modes charge compile + execute; ``sharded`` partitions
        dispatch asynchronously, so their wall time spans dispatch to
        results-ready (which for later partitions includes waiting on
        earlier ones still occupying the mesh)."""
        for part in self.partitions:
            if i in part.indices:
                return part.wall_time_us / (len(part.indices)
                                            * max(self.mc_runs, 1))
        raise IndexError(f"scenario {i} not in any partition")

    @property
    def n_compiles(self) -> int:
        """Compiled partition programs: one jit per structural shape."""
        return len(self.partitions)

    def __len__(self) -> int:
        return len(self.scenarios)

    def scenario_history(self, i: int) -> History:
        # tree.map (not a positional splat) so the optional telemetry
        # subtree — None when probes were off — passes through untouched.
        return jax.tree.map(lambda x: np.asarray(x[i]), self.history)

    def telemetry_summary(self, i: int) -> Optional[Dict[str, Any]]:
        """NaN/inf-aware mean of each in-jit probe for scenario ``i`` (see
        ``repro.telemetry.probes.summarize``); None when the sweep ran
        without telemetry."""
        if self.history.telemetry is None:
            return None
        tel = jax.tree.map(lambda x: np.asarray(x[i]),
                           self.history.telemetry)
        return _probes.summarize(tel)

    def final_reward(self, i: int, tail: int = 20) -> float:
        # jnp reductions, matching benchmarks.common exactly.
        return float(jnp.mean(jnp.asarray(self.history.rewards[i])[:, -tail:]))

    def avg_grad_sq(self, i: int) -> float:
        """(1/K) sum_k ||grad J||^2, averaged over MC runs (Fig. 2/5)."""
        return float(jnp.mean(jnp.asarray(self.history.grad_sq[i])))

    def index(self, **fields) -> int:
        """Position of the first scenario matching all given field values.

        ``env=`` matches by identity first, then equality — envs carrying
        arrays (TabularMDP, HeterogeneousEnv) compare ambiguously under
        ``==``, so pass the same instance the scenario was built with.
        """
        for i, s in enumerate(self.scenarios):
            if all(robust_eq(getattr(s, k), v) for k, v in fields.items()):
                return i
        raise KeyError(f"no scenario matches {fields}")

    def to_dicts(self, tail: int = 20) -> List[Dict[str, Any]]:
        rows = []
        for i, s in enumerate(self.scenarios):
            row = {"index": i, **s.describe()}
            row["final_reward"] = self.final_reward(i, tail)
            row["avg_grad_sq"] = self.avg_grad_sq(i)
            row["mean_gain"] = float(np.mean(np.asarray(self.history.gain_mean[i])))
            tel = self.telemetry_summary(i)
            if tel is not None:
                for k, v in tel.items():
                    row[f"telemetry_{k}"] = v
            rows.append(row)
        return rows

    def to_csv(self, path: Optional[str] = None, tail: int = 20) -> str:
        rows = self.to_dicts(tail)
        buf = io.StringIO()
        cols = list(rows[0]) if rows else []
        buf.write(",".join(cols) + "\n")
        for row in rows:
            buf.write(",".join(_csv_cell(row[c]) for c in cols) + "\n")
        text = buf.getvalue()
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text


def _csv_cell(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    s = str(v)
    if any(c in s for c in ',"\n\r'):  # RFC-4180 quoting
        return '"' + s.replace('"', '""') + '"'
    return s


def _stack_histories(arrs: List[np.ndarray]) -> np.ndarray:
    """Stack per-scenario arrays; ragged round counts (``n_rounds`` is a
    structural axis) fall back to a 1-D object array so ``history.x[i]``
    indexing keeps working."""
    if len({a.shape for a in arrs}) == 1:
        return np.stack(arrs)
    out = np.empty(len(arrs), dtype=object)
    for i, a in enumerate(arrs):
        out[i] = a
    return out


# ---------------------------------------------------------------------------
# The engine.
# ---------------------------------------------------------------------------

def sweep(
    env,
    policy,
    scenarios: Sequence[Scenario],
    key: jax.Array,
    mc_runs: int,
    *,
    mode: str = "vmap",
    mesh: Any = None,
    telemetry: Optional[TelemetryConfig] = None,
) -> SweepResult:
    """Run every scenario x mc_runs, one compiled program per partition.

    All scenarios share the same Monte-Carlo key set ``split(key, mc_runs)``
    — exactly what per-scenario ``fedpg.monte_carlo(..., key, mc_runs)``
    calls would use, so results are directly comparable across scenarios
    and against the unbatched path.

    ``env``/``policy`` are the defaults for scenarios that don't carry their
    own (see ``Scenario.env``); a grid where every scenario names an env may
    pass ``env=None, policy=None``.

    ``mode="sharded"`` lays each partition's (lanes x mc_runs) batch across
    a device mesh (``mesh=`` from ``launch.mesh.make_sweep_mesh``, default
    all devices on the lane axis), dispatches partitions asynchronously and
    defers ``block_until_ready`` to result materialisation; lanes stay
    bit-identical to ``mode="vmap"`` (see ``repro.core.distribute``).

    ``telemetry`` (a :class:`repro.telemetry.TelemetryConfig` with active
    probes) fills ``SweepResult.history.telemetry`` with ``(S, mc, K)``
    per-round probe stacks; telemetry off leaves every partition program
    bitwise identical to today's.  Partition execution is traced as
    ``repro.telemetry.trace`` spans either way.
    """
    telemetry = fedpg._active_telemetry(telemetry)
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    sharded = mode == "sharded"
    if mesh is not None and not sharded:
        raise ValueError("mesh= is only meaningful with mode='sharded'")
    scenarios = list(scenarios)
    if not scenarios:
        raise ValueError("empty scenario list")
    keys = jax.random.split(key, mc_runs)
    parts = partition_scenarios(scenarios)
    n_devices = 1
    if sharded:
        from repro.core import distribute
        if mesh is None:
            mesh = distribute.default_sweep_mesh()
        n_devices = mesh.size

    out_hist: List[Optional[History]] = [None] * len(scenarios)

    def collect(part: Partition, stacked: History, lanes: bool) -> None:
        """Materialise one partition: ONE device->host transfer per leaf,
        sliced on the host (no per-scenario eager gathers to dispatch or
        compile).  ``lanes=False`` is the replicate path — every scenario
        shares the single history; with lanes, trailing padded
        replicate-lanes (sharded mode) are masked off by the j < n slice."""
        s_np = jax.tree.map(np.asarray, stacked)
        for j, idx in enumerate(part.indices):
            out_hist[idx] = (jax.tree.map(lambda a: a[j], s_np)
                             if lanes else s_np)

    pending: List[Tuple[Partition, float, Any, Any]] = []
    for part in parts:
        packed = _pack_partition(part)
        lane = _make_lane(env, policy, part, telemetry=telemetry)
        if sharded:
            # async: launch and move on — drained after the loop.  A span
            # can't straddle the deferred materialisation, so the dispatch
            # -> ready wall time keeps a raw clock.
            t0 = time.perf_counter()  # repro: noqa[raw-timing]
            stacked, placement = distribute.dispatch_partition(
                lane, packed, keys, mesh)
            pending.append((part, t0, stacked, placement))
            continue
        # One jit per loop iteration is the design here, not the recompile
        # bug repro.analyze's jit-in-loop rule hunts: each partition is a
        # structurally distinct program and compiles exactly once.
        with rtrace.span("partition", mode=mode,
                         scenarios=len(part.indices)) as sp:
            if not packed:
                # Every scenario in the partition is identical: run one lane
                # and replicate its history.
                stacked, lanes = jax.jit(lane)({}, keys), False  # repro: noqa[jit-in-loop]
            elif mode == "vmap":
                stacked = jax.jit(jax.vmap(lane, in_axes=(0, None)))(  # repro: noqa[jit-in-loop]
                    packed, keys)
                lanes = True
            else:
                stacked = jax.jit(  # repro: noqa[jit-in-loop]
                    lambda pk, ks: jax.lax.map(lambda p: lane(p, ks), pk)
                )(packed, keys)
                lanes = True
            jax.block_until_ready(stacked)
        part.wall_time_us = sp.duration_us
        collect(part, stacked, lanes)

    # sharded drain: the deferred block_until_ready — results materialise
    # here, padded replicate-lanes are masked off, wall time spans
    # dispatch -> ready per partition
    for part, t0, stacked, placement in pending:
        with rtrace.span("materialize", scenarios=len(part.indices)):
            jax.block_until_ready(stacked)
        part.wall_time_us = (time.perf_counter() - t0) * 1e6  # repro: noqa[raw-timing]
        collect(part, stacked, placement.n_lanes > 0)

    history = History(
        rewards=_stack_histories([h.rewards for h in out_hist]),
        grad_sq=_stack_histories([h.grad_sq for h in out_hist]),
        gain_mean=_stack_histories([h.gain_mean for h in out_hist]),
        # per-field None guard: the service probe fields (participation
        # rate/drift, staleness age) exist only for service partitions —
        # a mixed sweep keeps the common probes stacked and drops a
        # service-only field unless every scenario carries it
        telemetry=None if out_hist[0].telemetry is None else RoundTelemetry(
            *((None if any(getattr(h.telemetry, f) is None
                           for h in out_hist)
               else _stack_histories([getattr(h.telemetry, f)
                                      for h in out_hist]))
              for f in RoundTelemetry._fields)),
    )
    return SweepResult(scenarios=scenarios, history=history, partitions=parts,
                       mc_runs=mc_runs, mode=mode, n_devices=n_devices)
