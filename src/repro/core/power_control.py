"""Transmit-power policies: h_{i,k} = c_{i,k} * p_{i,k}.

The paper folds the power coefficient p into the effective gain h and only
needs the pair (m_h, sigma_h^2) that Theorems 1/2 are stated in.  These
policies shape p as a function of the actual channel gain c — the main lever
on that pair in the OTA-FL literature (Cao et al., "Optimized Power Control
for Over-the-Air Federated Edge Learning"; Fan et al., "Joint Optimization
of Communications and Federated Learning Over the Air").

Policies
--------
* ``UnitPower``          — p = 1, the paper's default (h = c).
* ``TruncatedInversion`` — p = min(target/c, p_max) with outage below c_min.
* ``FullInversion``      — p = min(target/c, p_max), no outage region.
* ``ConstantReceived``   — phase-aware exact inversion, h = target a.s.
* ``HeterogeneousBudget``— per-agent constant budgets linspaced over agents.

Moments contract
----------------
The effective-gain channel ``ControlledChannel`` is registered in
``channel._REGISTRY`` (kind ``'controlled'``) and must carry *finite*
``(m_h, sigma_h^2)``; build it with :func:`make_controlled_channel`, which
prefers the closed forms below and falls back to Monte Carlo:

* ``TruncatedInversion``/``FullInversion`` over Rayleigh — exact via lower
  incomplete gamma functions (``gamma(3/2, .)`` and ``gamma(2, .)``, both
  elementary: erf/exp);
* ``ConstantReceived`` — (target, 0) for any base with P(c = 0) = 0;
* ``HeterogeneousBudget`` — exact mixture moments from the base moments
  (needs ``n_agents``);
* anything else — :func:`estimate_moments` Monte Carlo.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as _channel
from repro.core.channel import BatchedChannel, Channel, RayleighChannel

_POLICY_REGISTRY: Dict[str, type] = {}


def register_policy(cls: type) -> type:
    """Class decorator: make a policy reconstructable inside batched lanes."""
    _POLICY_REGISTRY[cls.__name__] = cls
    return cls


@dataclass(frozen=True)
class PowerPolicy:
    # True for policies whose p depends on the agent index (the gain vector's
    # last axis is then interpreted as the agent axis).
    per_agent = False

    def apply(self, c: jax.Array) -> jax.Array:
        """Map actual channel gains c to transmit power coefficients p."""
        raise NotImplementedError

    def apply_indexed(self, c: jax.Array, idx: jax.Array, n_agents) -> jax.Array:
        """Single-agent form for the shard_map/psum path: this shard's p
        given its scalar gain ``c``, agent index and total agent count."""
        del idx, n_agents
        return self.apply(c)

    def closed_form_moments(
        self, base: Channel, n_agents: Optional[int] = None
    ) -> Optional[Tuple[float, float]]:
        """Exact effective-gain (m_h, sigma_h^2) over ``base`` when known,
        else None (callers fall back to :func:`estimate_moments`)."""
        del base, n_agents
        return None


@register_policy
@dataclass(frozen=True)
class UnitPower(PowerPolicy):
    """p == 1: the paper's default (h = c)."""

    def apply(self, c: jax.Array) -> jax.Array:
        return jnp.ones_like(c)

    def closed_form_moments(self, base, n_agents=None):
        return float(base.mean), float(base.var)


# ---------------------------------------------------------------------------
# Channel-inversion policies.
# ---------------------------------------------------------------------------

def _rayleigh_partial_moments(scale: float, lo: float, hi: float) -> Tuple[float, float]:
    """(int_lo^hi c f(c) dc, int_lo^hi c^2 f(c) dc) for Rayleigh(scale).

    With u = c^2/(2 s^2) ~ Exp(1) these are lower-incomplete-gamma
    differences: gamma(3/2, u) = sqrt(pi)/2 erf(sqrt(u)) - sqrt(u) e^-u and
    gamma(2, u) = 1 - (1+u) e^-u.
    """
    s2 = scale * scale

    def u(c: float) -> float:
        return c * c / (2.0 * s2)

    def g32(x: float) -> float:
        return 0.5 * math.sqrt(math.pi) * math.erf(math.sqrt(x)) - math.sqrt(x) * math.exp(-x)

    def g2(x: float) -> float:
        return 1.0 - (1.0 + x) * math.exp(-x)

    i1 = scale * math.sqrt(2.0) * (g32(u(hi)) - g32(u(lo)))
    i2 = 2.0 * s2 * (g2(u(hi)) - g2(u(lo)))
    return i1, i2


def _rayleigh_inversion_moments(
    scale: float, target: float, p_max: float, c_min: float
) -> Tuple[float, float]:
    """Exact (m_h, sigma_h^2) of h = c * min(target/c, p_max) * 1{c >= c_min}
    over Rayleigh(scale): h = p_max c on [c_min, target/p_max), = target above.
    """
    t = target / p_max
    lo, hi = c_min, max(c_min, t)
    i1, i2 = _rayleigh_partial_moments(scale, lo, hi)
    surv = math.exp(-hi * hi / (2.0 * scale * scale))  # P(c >= hi)
    m = p_max * i1 + target * surv
    m2 = p_max * p_max * i2 + target * target * surv
    return m, max(m2 - m * m, 0.0)


@register_policy
@dataclass(frozen=True)
class TruncatedInversion(PowerPolicy):
    """p = min(target/c, p_max), with outage (p=0) below c_min.

    Classic OTA power control: agents invert their channel so the server
    sees ~equal gains, but deep fades are truncated to respect the power
    budget (otherwise E[p^2] diverges for Rayleigh).
    """

    target: float = 1.0
    p_max: float = 10.0
    c_min: float = 0.05

    def apply(self, c: jax.Array) -> jax.Array:
        p = jnp.minimum(self.target / jnp.maximum(c, 1e-12), self.p_max)
        return jnp.where(c >= self.c_min, p, 0.0)

    def closed_form_moments(self, base, n_agents=None):
        if type(base) is RayleighChannel:
            return _rayleigh_inversion_moments(
                float(base.scale), float(self.target), float(self.p_max),
                float(self.c_min))
        return None


@register_policy
@dataclass(frozen=True)
class FullInversion(PowerPolicy):
    """p = min(target/c, p_max): inversion with a power cap but no outage.

    Deep fades transmit at the cap instead of going silent, so weak agents
    still contribute (attenuated) signal rather than dropping out.
    """

    target: float = 1.0
    p_max: float = 10.0

    def apply(self, c: jax.Array) -> jax.Array:
        return jnp.minimum(self.target / jnp.maximum(c, 1e-12), self.p_max)

    def closed_form_moments(self, base, n_agents=None):
        if type(base) is RayleighChannel:
            return _rayleigh_inversion_moments(
                float(base.scale), float(self.target), float(self.p_max), 0.0)
        return None


@register_policy
@dataclass(frozen=True)
class ConstantReceived(PowerPolicy):
    """Phase-aware exact inversion: p = target/c, so h = target a.s.

    Models perfect channel-state pre-compensation (amplitude inversion with
    phase alignment, unbounded peak power): the server sees a deterministic
    gain, killing the channel-variance floor entirely — sigma_h^2 = 0, the
    best case of Theorems 1/2.
    """

    target: float = 1.0

    def apply(self, c: jax.Array) -> jax.Array:
        return self.target / jnp.maximum(c, 1e-12)

    def closed_form_moments(self, base, n_agents=None):
        # exact for any base with no atom at 0 (all continuous models here).
        return float(self.target), 0.0


@register_policy
@dataclass(frozen=True)
class HeterogeneousBudget(PowerPolicy):
    """Per-agent constant budgets: agent i transmits at b_i, with budgets
    linearly spaced from ``p_min`` (agent 0) to ``p_max`` (agent N-1).

    Models a fleet with heterogeneous power headroom; the effective gains
    stay independent but are no longer identically distributed, so the
    theory plugs in the *mixture* moments over a uniformly random agent.
    The gain vector's last axis is interpreted as the agent axis.
    """

    p_min: float = 0.5
    p_max: float = 1.5

    per_agent = True

    def _budgets(self, n: int, dtype) -> jax.Array:
        return jnp.linspace(self.p_min, self.p_max, n).astype(dtype)

    def apply(self, c: jax.Array) -> jax.Array:
        if jnp.ndim(c) == 0:
            raise ValueError(
                "HeterogeneousBudget.apply needs a trailing agent axis; "
                "single-agent (scalar) paths must use apply_indexed — the "
                "shard_map/psum form only supports per-agent policies via "
                "OTAConfig.power_control, not via ControlledChannel"
            )
        return jnp.broadcast_to(self._budgets(c.shape[-1], c.dtype), c.shape)

    def apply_indexed(self, c, idx, n_agents):
        if isinstance(n_agents, (int, np.integer)):
            # static count: fold the step in as a Python literal (matches
            # what the stacked form's linspace would produce)
            step = (self.p_max - self.p_min) / max(int(n_agents) - 1, 1)
        else:
            # traced count (old jax has no lax.axis_size): compute at runtime
            step = (self.p_max - self.p_min) / jnp.maximum(
                n_agents - 1, 1).astype(c.dtype)
        return (self.p_min + idx.astype(c.dtype) * step) * jnp.ones_like(c)

    def closed_form_moments(self, base, n_agents=None):
        if n_agents is None:
            raise ValueError(
                "HeterogeneousBudget moments depend on the agent count; "
                "pass n_agents (e.g. make_controlled_channel(..., n_agents=N))"
            )
        n = int(n_agents)
        mean_b = (self.p_min + self.p_max) / 2.0
        step = (self.p_max - self.p_min) / max(n - 1, 1)
        var_b = 0.0 if n == 1 else step * step * (n * n - 1) / 12.0
        m_c, v_c = float(base.mean), float(base.var)
        m = mean_b * m_c
        m2 = (var_b + mean_b * mean_b) * (v_c + m_c * m_c)
        return m, max(m2 - m * m, 0.0)


# ---------------------------------------------------------------------------
# The effective-gain channel, registered as a first-class channel family.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ControlledChannel(Channel):
    """Effective-gain channel h = c * policy(c) over a base channel.

    Registered in the channel registry as kind ``'controlled'`` with the
    composite structural tag ``controlled:<base_kind>:<PolicyType>``, so
    same-shaped instances batch into one sweep partition.  Construct with
    :func:`make_controlled_channel`, which fills the (m_h, sigma_h^2)
    moments (closed form where available, Monte Carlo otherwise) — the
    debiased update and the theory tables are poisoned by NaN moments, and
    ``OTAConfig``/``batched_channel_arrays`` reject them loudly.
    """

    base: Channel = None  # type: ignore[assignment]
    policy: PowerPolicy = UnitPower()
    # Effective moments; NaN until filled in (dataclass is frozen, so they
    # are passed explicitly by make_controlled_channel).
    _mean: float = float("nan")
    _var: float = float("nan")
    # For per-agent policies: the agent count the moments were baked for
    # (mixture moments depend on it); checked by check_agent_count.
    _n_agents: Optional[int] = None

    def __post_init__(self):
        if self.base is None:
            raise ValueError(
                "ControlledChannel needs a base channel; construct it with "
                "make_controlled_channel(base, policy, ...)"
            )

    def kind_tag(self) -> str:
        base_kind = _channel.channel_kind(self.base)
        if ":" in base_kind:
            raise ValueError("nested ControlledChannel is not supported")
        return f"controlled:{base_kind}:{type(self.policy).__name__}"

    def sample(self, key: jax.Array, shape) -> jax.Array:
        if (self.policy.per_agent and self._n_agents is not None
                and (not shape or shape[-1] != self._n_agents)):
            raise ValueError(
                f"ControlledChannel moments were baked for n_agents="
                f"{self._n_agents} but sample() was asked for agent axis "
                f"{shape[-1] if shape else '(scalar)'}; rebuild with "
                "make_controlled_channel(..., n_agents=<runtime count>)"
            )
        c = self.base.sample(key, shape)
        return c * self.policy.apply(c)

    @property
    def mean(self) -> float:
        return self._mean

    @property
    def var(self) -> float:
        return self._var


def _pack_controlled(channels):
    """Batched-array packer: base params under ``base.``, policy params under
    ``pc.`` (the common ``_mean``/``_var`` columns are added by the caller)."""
    _, base_params = _channel.batched_channel_arrays(
        [ch.base for ch in channels])
    params = {f"base.{k}": v for k, v in base_params.items()}
    for f in dataclasses.fields(channels[0].policy):
        params[f"pc.{f.name}"] = np.array(
            [float(getattr(ch.policy, f.name)) for ch in channels], np.float64
        )
    return params


def _sample_controlled(kind, params, key, shape):
    """Batched sampler: reconstruct base draw + policy from the lane's traced
    scalars — same ops as ControlledChannel.sample, so draws are bitwise
    identical to the concrete dataclass at equal parameter values."""
    _, base_kind, policy_name = kind.split(":")
    base_params = {k[len("base."):]: v for k, v in params.items()
                   if k.startswith("base.")}
    pol = _POLICY_REGISTRY[policy_name](
        **{k[len("pc."):]: v for k, v in params.items() if k.startswith("pc.")}
    )
    c = BatchedChannel(kind=base_kind, params=base_params).sample(key, shape)
    return c * pol.apply(c)


_channel.register_channel(
    "controlled", ControlledChannel,
    packer=_pack_controlled, sampler=_sample_controlled,
)


# ---------------------------------------------------------------------------
# Moments: closed form where known, Monte Carlo fallback.
# ---------------------------------------------------------------------------

def estimate_moments(
    base: Channel,
    policy: PowerPolicy,
    key: jax.Array,
    n: int = 200_000,
    *,
    n_agents: Optional[int] = None,
) -> Tuple[float, float]:
    """Monte Carlo (m_h, sigma_h^2) of the effective gain h = c * p(c).

    Per-agent policies need ``n_agents``: gains are drawn with an explicit
    trailing agent axis and the *mixture* moments over agents are returned.
    """
    if policy.per_agent:
        if not n_agents:
            raise ValueError("per-agent policy moments need n_agents")
        c = base.sample(key, (max(1, n // n_agents), n_agents))
    else:
        c = base.sample(key, (n,))
    h = c * policy.apply(c)
    return float(jnp.mean(h)), float(jnp.var(h))


def closed_form_moments(
    base: Channel, policy: PowerPolicy, *, n_agents: Optional[int] = None
) -> Optional[Tuple[float, float]]:
    """Exact effective moments when the (base, policy) pair has a closed
    form, else None."""
    return policy.closed_form_moments(base, n_agents)


@functools.lru_cache(maxsize=None)
def effective_moments(
    base: Channel,
    policy: PowerPolicy,
    *,
    n_agents: Optional[int] = None,
    n: int = 200_000,
) -> Tuple[float, float]:
    """Effective-gain (m_h, sigma_h^2): closed form if available, otherwise
    Monte Carlo with a fixed documented seed (jax.random.key(0)) so sweep
    packing and per-scenario configs agree deterministically."""
    closed = closed_form_moments(base, policy, n_agents=n_agents)
    if closed is not None:
        return closed
    return estimate_moments(base, policy, jax.random.key(0), n,
                            n_agents=n_agents)


def make_controlled_channel(
    base: Channel,
    policy: PowerPolicy,
    key: Optional[jax.Array] = None,
    n: int = 200_000,
    *,
    n_agents: Optional[int] = None,
) -> ControlledChannel:
    """The documented ControlledChannel constructor: fills the effective
    (m_h, sigma_h^2) via closed form when available, else Monte Carlo.

    ``key`` only matters for the Monte Carlo fallback (default
    jax.random.key(0)); ``n_agents`` is required by per-agent policies.
    """
    closed = closed_form_moments(base, policy, n_agents=n_agents)
    if closed is not None:
        m, v = closed
    else:
        if key is None:
            key = jax.random.key(0)
        m, v = estimate_moments(base, policy, key, n, n_agents=n_agents)
    return ControlledChannel(
        base=base, policy=policy, _mean=m, _var=v,
        _n_agents=n_agents if policy.per_agent else None,
    )


def check_agent_count(channel: Channel, n_agents: int) -> None:
    """Guard against using a ControlledChannel whose per-agent mixture
    moments were baked for a different agent count than it now runs with —
    the sampling would silently follow the runtime count while the debias
    normaliser and theory tables followed the baked one."""
    if (isinstance(channel, ControlledChannel)
            and channel._n_agents is not None
            and channel._n_agents != n_agents):
        raise ValueError(
            f"ControlledChannel moments were baked for n_agents="
            f"{channel._n_agents} but the scenario runs {n_agents} agents; "
            "rebuild it with make_controlled_channel(..., n_agents="
            f"{n_agents})"
        )
