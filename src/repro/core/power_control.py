"""Transmit-power policies: h_{i,k} = c_{i,k} * p_{i,k}.

The paper folds the power coefficient p into the effective gain h and only
needs (m_h, sigma_h^2).  These policies shape p as a function of the actual
channel gain c, producing effective-gain distributions whose moments we
estimate by Monte Carlo (no closed form for truncated inversion).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.channel import Channel


@dataclass(frozen=True)
class PowerPolicy:
    def apply(self, c: jax.Array) -> jax.Array:
        """Map actual channel gains c to transmit power coefficients p."""
        raise NotImplementedError


@dataclass(frozen=True)
class UnitPower(PowerPolicy):
    """p == 1: the paper's default (h = c)."""

    def apply(self, c: jax.Array) -> jax.Array:
        return jnp.ones_like(c)


@dataclass(frozen=True)
class TruncatedInversion(PowerPolicy):
    """p = min(target/c, p_max), with outage (p=0) below c_min.

    Classic OTA power control: agents invert their channel so the server
    sees ~equal gains, but deep fades are truncated to respect the power
    budget (otherwise E[p^2] diverges for Rayleigh).
    """

    target: float = 1.0
    p_max: float = 10.0
    c_min: float = 0.05

    def apply(self, c: jax.Array) -> jax.Array:
        p = jnp.minimum(self.target / jnp.maximum(c, 1e-12), self.p_max)
        return jnp.where(c >= self.c_min, p, 0.0)


@dataclass(frozen=True)
class ControlledChannel(Channel):
    """Effective-gain channel h = c * policy(c) over a base channel."""

    base: Channel = None  # type: ignore[assignment]
    policy: PowerPolicy = UnitPower()
    # Monte Carlo moment cache (filled by estimate_moments; dataclass frozen,
    # so moments are passed explicitly).
    _mean: float = float("nan")
    _var: float = float("nan")

    def sample(self, key: jax.Array, shape) -> jax.Array:
        c = self.base.sample(key, shape)
        return c * self.policy.apply(c)

    @property
    def mean(self) -> float:
        return self._mean

    @property
    def var(self) -> float:
        return self._var


def estimate_moments(
    base: Channel, policy: PowerPolicy, key: jax.Array, n: int = 200_000
) -> Tuple[float, float]:
    """Monte Carlo (m_h, sigma_h^2) of the effective gain h = c * p(c)."""
    c = base.sample(key, (n,))
    h = c * policy.apply(c)
    m = float(jnp.mean(h))
    v = float(jnp.var(h))
    return m, v


def make_controlled_channel(
    base: Channel, policy: PowerPolicy, key: jax.Array, n: int = 200_000
) -> ControlledChannel:
    m, v = estimate_moments(base, policy, key, n)
    return ControlledChannel(base=base, policy=policy, _mean=m, _var=v)
