"""Over-the-air aggregation (Eq. 6-7) — the paper's core primitive.

The physical channel computes ``v_k = sum_i h_{i,k} * g_i + n_k`` "for free"
by analog superposition; the server applies ``theta <- theta - alpha * v_k/N``.
On a TPU mesh the sum is a ``psum`` and the distortion/noise are explicit
tensor ops.  Three mathematically equivalent implementations are provided
(and tested equal against each other):

1. ``aggregate_stacked``  — literal Algorithm 2 over per-agent gradient
   pytrees stacked on a leading N axis.  Used by the RL loops where agents
   are vmapped workers.
2. ``psum_aggregate``     — ``shard_map`` form: each data-shard scales its
   local gradient by its own gain and ``psum``s across the agent axes; the
   AWGN is generated identically on every shard from a shared key (so no
   extra broadcast is needed).  Production form for the LLM trainer.
3. channel-weighted loss  — ``sample_gains`` + ``example_weights`` fold the
   gain into the per-example loss weight *before* autodiff, so a vanilla
   pjit gradient already equals ``sum_i h_i grad_i / N``; ``add_awgn`` then
   applies the server noise once.  Zero extra collectives vs. plain DP.

``exact_aggregate`` is the Algorithm-1 baseline (ideal per-agent uplink).
All forms return the *update direction* ``u_k = v_k / N`` so that
``theta^{k+1} = theta^k - alpha * u_k`` matches Eq. (7) exactly.  Setting
``debias=True`` additionally divides by ``m_h`` which makes the estimator
unbiased for ``grad J`` (the quantity the analysis controls, Lemma 3); the
paper's faithful update uses ``debias=False``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.channel import Channel, IdealChannel
from repro.core.power_control import PowerPolicy, effective_moments
from repro.utils.tree import tree_normal_like

PyTree = Any
Scalar = Union[float, jax.Array]  # python literal, or traced in a sweep lane


def _noise_enabled(sigma: Scalar) -> bool:
    """Whether to emit the AWGN ops.  Python literals keep the exact
    pre-existing behaviour (skip when 0); arrays/tracers always emit them
    (a runtime sigma of 0 then adds exact zeros)."""
    if isinstance(sigma, (int, float)):
        return sigma > 0.0
    return True


def _axis_size(name: str) -> Scalar:
    """Mesh-axis size inside shard_map.  ``jax.lax.axis_size`` only exists on
    newer jax; the pinned 0.4.x falls back to a psum of ones — a *traced*
    count, so callers that need a static agent count (per-agent power-control
    moments, float64-folded scales) must pass one explicitly (see the
    ``n_agents`` kwarg on :func:`psum_aggregate` /
    :func:`psum_aggregate_stacked`)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(jnp.ones((), jnp.int32), name)


@dataclass(frozen=True)
class OTAConfig:
    """Static configuration of the over-the-air uplink.

    ``noise_sigma`` may be a traced scalar (the sweep engine batches noise
    levels); ``power_control`` optionally shapes the transmit power so the
    effective gain becomes ``h = c * p(c)`` — with ``debias=True`` the
    update is then divided by the *effective* mean ``E[c p(c)]`` (see
    ``norm_const_for``), keeping the estimator unbiased under power
    control; ``update_scale`` overrides the full server normalisation
    ``1 / (N * norm_const)`` — the sweep engine precomputes it in float64
    per scenario so that batched lanes multiply by exactly the constant the
    unbatched program would have folded in.
    """

    channel: Channel
    noise_sigma: Scalar = 0.0  # sigma of the AWGN on the *sum* (Eq. 6)
    debias: bool = False       # divide by m_h (unbiased grad estimate)
    power_control: Optional[PowerPolicy] = None
    update_scale: Optional[Scalar] = None

    def __post_init__(self):
        # Fail at config-build time, not rounds later: a debiased update
        # divides by m_h, and a NaN mean (a ControlledChannel whose moments
        # were never estimated) would silently corrupt every update.
        if self.debias and self.update_scale is None:
            m = self.channel.mean
            if isinstance(m, (int, float)) and not math.isfinite(m):
                raise ValueError(
                    f"debias=True needs a finite channel mean, got m_h={m!r}; "
                    "build power-controlled channels with "
                    "make_controlled_channel so their effective moments are "
                    "estimated"
                )

    @property
    def norm_const(self) -> Scalar:
        """The raw-channel debias normaliser m_h (no power control folded
        in); the aggregation forms use :meth:`norm_const_for`, which
        accounts for ``power_control``."""
        if not self.debias:
            return 1.0
        m = self.channel.mean
        if isinstance(m, (int, float)) and not math.isfinite(m):
            raise ValueError(
                f"non-finite debias normaliser m_h={m!r}; build "
                "power-controlled channels with make_controlled_channel"
            )
        return m

    def norm_const_for(self, n_agents: Optional[int] = None) -> Scalar:
        """The debias normaliser the aggregation forms divide by: the
        *effective* gain mean E[c p(c)] when ``power_control`` is set
        (closed form or cached Monte Carlo — identical to what
        ``Scenario.ota_config`` folds into ``update_scale``), the channel
        mean otherwise.  ``n_agents`` is needed by per-agent policies."""
        if not self.debias or self.power_control is None:
            return self.norm_const
        try:
            return effective_moments(self.channel, self.power_control,
                                     n_agents=n_agents)[0]
        except TypeError as e:  # traced/unhashable channel or policy params
            raise ValueError(
                "debias needs hashable channel and power-control parameters "
                "to derive the effective mean; traced configs must carry an "
                "explicit update_scale (the sweep engine packs one per lane)"
            ) from e

    def ideal(self) -> "OTAConfig":
        """The matching noiseless/distortionless config (Algorithm 1)."""
        return replace(self, channel=IdealChannel(), noise_sigma=0.0,
                       power_control=None, update_scale=None)


# ---------------------------------------------------------------------------
# Form 1: stacked per-agent gradients (literal Algorithm 2).
# ---------------------------------------------------------------------------

def sample_gains(cfg: OTAConfig, key: jax.Array, n_agents: int) -> jax.Array:
    """Draw h_{i,k} for every agent for one round: shape (n_agents,).

    With power control, the effective gain is ``h = c * p(c)`` (Eq. 6's
    gain-times-power factorisation).
    """
    c = cfg.channel.sample(key, (n_agents,))
    if cfg.power_control is not None:
        c = c * cfg.power_control.apply(c)
    return c


def _server_epilogue(
    cfg: OTAConfig,
    key_n: jax.Array,
    v: PyTree,
    n_total: Scalar,
    n_agents: Optional[int],
) -> PyTree:
    """The shared server-side tail of every aggregation form: AWGN on the
    summed signal, then the update normalisation ``update_scale`` or
    ``1 / (n_total * norm_const)``.  One copy keeps the three
    equivalence-tested forms from drifting apart."""
    if _noise_enabled(cfg.noise_sigma):
        noise = tree_normal_like(key_n, v, cfg.noise_sigma)
        v = jax.tree.map(jnp.add, v, noise)
    scale = cfg.update_scale
    if scale is None:
        scale = 1.0 / (n_total * cfg.norm_const_for(n_agents))
    return jax.tree.map(lambda x: x * scale, v)


def aggregate_stacked(
    cfg: OTAConfig,
    key: jax.Array,
    grads_stacked: PyTree,
    *,
    gains: jax.Array | None = None,
) -> Tuple[PyTree, jax.Array]:
    """OTA-aggregate per-agent gradients stacked on a leading N axis.

    Returns ``(u_k, h)`` where ``u_k = (sum_i h_i g_i + n_k) / (N * c)``,
    ``c = m_h`` if debiasing else 1.
    """
    leading = jax.tree.leaves(grads_stacked)[0].shape[0]
    key_h, key_n = jax.random.split(key)
    h = sample_gains(cfg, key_h, leading) if gains is None else gains

    def _combine(g):
        hb = h.reshape((leading,) + (1,) * (g.ndim - 1)).astype(g.dtype)
        return jnp.sum(hb * g, axis=0)

    v = jax.tree.map(_combine, grads_stacked)
    return _server_epilogue(cfg, key_n, v, leading, leading), h


def exact_aggregate(grads_stacked: PyTree) -> PyTree:
    """Algorithm-1 baseline: exact mean of per-agent gradients (ideal uplink)."""
    return jax.tree.map(lambda g: jnp.mean(g, axis=0), grads_stacked)


# ---------------------------------------------------------------------------
# Form 2: shard_map / psum (production data-parallel form).
# ---------------------------------------------------------------------------

def _flat_axis_index(axis_names: Sequence[str]) -> Tuple[jax.Array, Scalar]:
    """(flattened shard index, total shard count) over the given mesh axes
    (row-major, matching the historical ``local_gain`` indexing).  The count
    is traced on jax versions without ``lax.axis_size``."""
    idx = jnp.zeros((), jnp.int32)
    stride: Scalar = 1
    for name in reversed(tuple(axis_names)):
        idx = idx + jax.lax.axis_index(name) * stride
        stride = stride * _axis_size(name)
    return idx, stride


def local_gain(
    cfg: OTAConfig,
    key: jax.Array,
    axis_names: Sequence[str],
    n_agents: Optional[int] = None,
) -> jax.Array:
    """Sample this shard's h_{i,k} inside shard_map.

    Every shard folds its own agent index into the shared round key, so the
    gains are independent across agents but reproducible.  ``n_agents`` is
    the static total agent count when the caller knows it (per-agent
    policies like ``HeterogeneousBudget`` prefer a static count).
    """
    idx, stride = _flat_axis_index(axis_names)
    c = cfg.channel.sample(jax.random.fold_in(key, idx), ())
    if cfg.power_control is not None:
        # per-agent policies key the budget on this shard's agent index
        n = stride if n_agents is None else n_agents
        c = c * cfg.power_control.apply_indexed(c, idx, n)
    return c


def psum_aggregate(
    cfg: OTAConfig,
    key: jax.Array,
    local_grad: PyTree,
    axis_names: Sequence[str],
    *,
    n_agents: Optional[int] = None,
) -> PyTree:
    """OTA aggregation across mesh axes, to be called inside shard_map.

    The per-agent gain scaling happens *before* the psum, so OTA adds zero
    communication volume over exact data-parallel aggregation — which is the
    paper's efficiency claim transplanted to the interconnect.  ``n_agents``
    is the static total agent count when known; without it the count is a
    traced psum of ones (old jax has no ``lax.axis_size``), which keeps the
    maths right but means debiased per-agent-policy configs must carry an
    explicit ``update_scale`` (a traced count cannot key the closed-form
    effective moments).
    """
    axis_names = tuple(axis_names)
    key_h, key_n = jax.random.split(key)
    h = local_gain(cfg, key_h, axis_names, n_agents)
    scaled = jax.tree.map(lambda g: g * h.astype(g.dtype), local_grad)
    v = jax.lax.psum(scaled, axis_names)
    # Same key_n on every shard => identical noise everywhere, i.e. the
    # server's single n_k draw without any broadcast collective.
    n = n_agents
    if n is None and cfg.update_scale is None:  # only then is the count used
        n = _flat_axis_index(axis_names)[1]
    return _server_epilogue(cfg, key_n, v, n, n_agents)


def psum_aggregate_stacked(
    cfg: OTAConfig,
    key: jax.Array,
    local_grads: PyTree,
    axis_names: Sequence[str],
    *,
    n_agents: Optional[int] = None,
) -> Tuple[PyTree, jax.Array]:
    """:func:`psum_aggregate` for shards that each carry a *stack* of agents.

    ``local_grads`` leaves have a leading ``n_local`` axis (this shard's
    slice of the agent axis).  Gains are drawn exactly like ``local_gain``
    but keyed on the *global* agent index ``shard_index * n_local + j`` —
    with one agent per shard the stream is identical to
    :func:`psum_aggregate`.  Each shard reduces its gain-weighted stack
    locally, ``psum``s across the mesh axes, and applies the shared AWGN +
    normalisation once.  This is the agent-axis sharding hook
    ``fedpg.make_round_fn`` uses, so ``HeterogeneousEnv`` fleets and
    per-agent power control (``HeterogeneousBudget``) run in their
    production shard_map form.

    Returns ``(update, h_local)``; ``h_local`` is this shard's (n_local,)
    gain slice (psum its sum for the global gain mean).
    """
    axis_names = tuple(axis_names)
    n_local = jax.tree.leaves(local_grads)[0].shape[0]
    key_h, key_n = jax.random.split(key)
    idx, stride = _flat_axis_index(axis_names)
    n_total: Scalar = n_agents if n_agents is not None else stride * n_local
    global_idx = idx * n_local + jnp.arange(n_local, dtype=jnp.int32)

    def gain_for(j):
        c = cfg.channel.sample(jax.random.fold_in(key_h, j), ())
        if cfg.power_control is not None:
            c = c * cfg.power_control.apply_indexed(c, j, n_total)
        return c

    h = jax.vmap(gain_for)(global_idx)

    def _combine(g):
        hb = h.reshape((n_local,) + (1,) * (g.ndim - 1)).astype(g.dtype)
        return jnp.sum(hb * g, axis=0)

    v = jax.lax.psum(jax.tree.map(_combine, local_grads), axis_names)
    return _server_epilogue(cfg, key_n, v, n_total, n_agents), h


# ---------------------------------------------------------------------------
# Form 3: channel-weighted loss (fold distortion into autodiff).
# ---------------------------------------------------------------------------

def example_weights(
    gains: jax.Array, global_batch: int, *, dtype=jnp.float32
) -> jax.Array:
    """Expand per-agent gains (N,) to per-example weights (global_batch,).

    Agent i owns the contiguous example slice [i*B/N, (i+1)*B/N).  With the
    per-example loss  L = (1/B) sum_e w_e l_e  and w_e = h_{agent(e)}, plain
    autodiff gives  grad L = (1/N) sum_i h_i grad J_i = v_k / N  (pre-noise).
    """
    n_agents = gains.shape[0]
    if global_batch % n_agents != 0:
        raise ValueError(
            f"global_batch={global_batch} not divisible by n_agents={n_agents}"
        )
    per = global_batch // n_agents
    return jnp.repeat(gains.astype(dtype), per)


def add_awgn(
    cfg: OTAConfig, key: jax.Array, grad: PyTree, n_agents: int
) -> PyTree:
    """Apply the server-side AWGN and normalisation to a weighted-loss grad.

    ``grad`` must already equal ``(1/N) sum_i h_i g_i`` (from the weighted
    loss); this adds ``n_k / N`` and optionally debiases by ``m_h``.  An
    ``update_scale`` override (``1 / (N * c)`` over the raw sum) is honoured
    here as the equivalent ``N * update_scale`` factor, keeping the three
    aggregation forms interchangeable for sweep-built configs.
    """
    if _noise_enabled(cfg.noise_sigma):
        noise = tree_normal_like(key, grad, cfg.noise_sigma / n_agents)
        grad = jax.tree.map(jnp.add, grad, noise)
    if cfg.update_scale is not None:
        scale = n_agents * cfg.update_scale
        grad = jax.tree.map(lambda x: x * scale, grad)
    elif cfg.debias:
        inv = 1.0 / cfg.norm_const_for(n_agents)
        grad = jax.tree.map(lambda x: x * inv, grad)
    return grad
