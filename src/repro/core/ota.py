"""Over-the-air aggregation (Eq. 6-7) — the paper's core primitive.

The physical channel computes ``v_k = sum_i h_{i,k} * g_i + n_k`` "for free"
by analog superposition; the server applies ``theta <- theta - alpha * v_k/N``.
On a TPU mesh the sum is a ``psum`` and the distortion/noise are explicit
tensor ops.

**Entry point:** :func:`aggregate` — one dispatcher over every mathematically
equivalent implementation form, described by an :class:`AggregateSpec`:

* form ``"stacked"``      — literal Algorithm 2 over per-agent gradient
  pytrees stacked on a leading N axis (the RL loops' vmapped workers).
* form ``"axis"``         — ``shard_map`` form: each data-shard scales its
  local gradient by its own gain and ``psum``s across the agent axes; the
  AWGN is generated identically on every shard from a shared key.
* form ``"axis_stacked"`` — the axis form for shards that each carry a
  *stack* of agents (the agent-mesh production path).
* ``exact=True``          — the Algorithm-1 baseline (ideal uplink) in any
  form: the plain mean.

Backends: ``"xla"`` executes the historical op chain (bit-identical to the
pre-dispatcher entry points); ``"pallas"`` routes the stacked form through
the fused kernel ``repro.kernels.ota_fused`` (gain matvec + counter-PRNG
AWGN + debias in ONE pass over the flattened parameter vector, bf16 wire
format via ``OTAConfig.wire_dtype``); ``"auto"`` picks pallas on TPU and
xla elsewhere.  The pallas backend draws its AWGN from the kernel's
counter PRNG — same distribution, different stream than the xla
threefry draw, so histories agree in distribution, not bitwise.

:func:`aggregate_apply` additionally fuses the server SGD update
``theta' = theta - alpha * u`` into the same kernel pass (the fedpg round
loop's uplink tail).

The legacy entry points (``aggregate_stacked``, ``exact_aggregate``,
``psum_aggregate``, ``psum_aggregate_stacked``) remain as thin deprecated
wrappers; new in-repo code must call :func:`aggregate` (enforced by
``tools/lint_aggregation_api.py`` in CI).

A third equivalent form needs no aggregation call at all: channel-weighted
loss — ``sample_gains`` + ``example_weights`` fold the gain into the
per-example loss weight *before* autodiff, so a vanilla pjit gradient
already equals ``sum_i h_i grad_i / N``; ``add_awgn`` then applies the
server noise once.  Zero extra collectives vs. plain DP.

All forms return the *update direction* ``u_k = v_k / N`` so that
``theta^{k+1} = theta^k - alpha * u_k`` matches Eq. (7) exactly.  Setting
``debias=True`` additionally divides by ``m_h`` which makes the estimator
unbiased for ``grad J`` (the quantity the analysis controls, Lemma 3); the
paper's faithful update uses ``debias=False``.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass, replace
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.channel import Channel, IdealChannel
from repro.core.power_control import PowerPolicy, effective_moments
from repro.utils.tree import tree_normal_like

PyTree = Any
Scalar = Union[float, jax.Array]  # python literal, or traced in a sweep lane


def _noise_enabled(sigma: Scalar) -> bool:
    """Whether to emit the AWGN ops.  Python literals keep the exact
    pre-existing behaviour (skip when 0); arrays/tracers always emit them
    (a runtime sigma of 0 then adds exact zeros)."""
    if isinstance(sigma, (int, float)):
        return sigma > 0.0
    return True


def _axis_size(name: str) -> Scalar:
    """Mesh-axis size inside shard_map.  ``jax.lax.axis_size`` only exists on
    newer jax; the pinned 0.4.x falls back to a psum of ones — a *traced*
    count, so callers that need a static agent count (per-agent power-control
    moments, float64-folded scales) must pass one explicitly (see the
    ``n_agents`` kwarg on :func:`aggregate`)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(jnp.ones((), jnp.int32), name)


@dataclass(frozen=True)
class OTAConfig:
    """Static configuration of the over-the-air uplink.

    ``noise_sigma`` may be a traced scalar (the sweep engine batches noise
    levels); ``power_control`` optionally shapes the transmit power so the
    effective gain becomes ``h = c * p(c)`` — with ``debias=True`` the
    update is then divided by the *effective* mean ``E[c p(c)]`` (see
    ``norm_const_for``), keeping the estimator unbiased under power
    control; ``update_scale`` overrides the full server normalisation
    ``1 / (N * norm_const)`` — the sweep engine precomputes it in float64
    per scenario so that batched lanes multiply by exactly the constant the
    unbatched program would have folded in.  ``wire_dtype`` narrows the
    uplink payload on the pallas backend (``"bfloat16"`` casts the stacked
    gradients before the fused gain matvec; compute and the parameter
    master copy stay float32); the default ``""`` keeps the native dtype.
    """

    channel: Channel
    noise_sigma: Scalar = 0.0  # sigma of the AWGN on the *sum* (Eq. 6)
    debias: bool = False       # divide by m_h (unbiased grad estimate)
    power_control: Optional[PowerPolicy] = None
    update_scale: Optional[Scalar] = None
    wire_dtype: str = ""       # "" (native) | "bfloat16" — pallas wire format

    def __post_init__(self):
        # Fail at config-build time, not rounds later: a debiased update
        # divides by m_h, and a NaN mean (a ControlledChannel whose moments
        # were never estimated) would silently corrupt every update.
        if self.debias and self.update_scale is None:
            m = self.channel.mean
            if isinstance(m, (int, float)) and not math.isfinite(m):
                raise ValueError(
                    f"debias=True needs a finite channel mean, got m_h={m!r}; "
                    "build power-controlled channels with "
                    "make_controlled_channel so their effective moments are "
                    "estimated"
                )

    @property
    def norm_const(self) -> Scalar:
        """The raw-channel debias normaliser m_h (no power control folded
        in); the aggregation forms use :meth:`norm_const_for`, which
        accounts for ``power_control``."""
        if not self.debias:
            return 1.0
        m = self.channel.mean
        if isinstance(m, (int, float)) and not math.isfinite(m):
            raise ValueError(
                f"non-finite debias normaliser m_h={m!r}; build "
                "power-controlled channels with make_controlled_channel"
            )
        return m

    def norm_const_for(self, n_agents: Optional[int] = None) -> Scalar:
        """The debias normaliser the aggregation forms divide by: the
        *effective* gain mean E[c p(c)] when ``power_control`` is set
        (closed form or cached Monte Carlo — identical to what
        ``Scenario.ota_config`` folds into ``update_scale``), the channel
        mean otherwise.  ``n_agents`` is needed by per-agent policies."""
        if not self.debias or self.power_control is None:
            return self.norm_const
        try:
            return effective_moments(self.channel, self.power_control,
                                     n_agents=n_agents)[0]
        except TypeError as e:  # traced/unhashable channel or policy params
            raise ValueError(
                "debias needs hashable channel and power-control parameters "
                "to derive the effective mean; traced configs must carry an "
                "explicit update_scale (the sweep engine packs one per lane)"
            ) from e

    def ideal(self) -> "OTAConfig":
        """The matching noiseless/distortionless config (Algorithm 1)."""
        return replace(self, channel=IdealChannel(), noise_sigma=0.0,
                       power_control=None, update_scale=None)


# ---------------------------------------------------------------------------
# The unified dispatcher.
# ---------------------------------------------------------------------------

_BACKENDS = ("auto", "xla", "pallas")
_FORMS = ("stacked", "axis", "axis_stacked")


@dataclass(frozen=True)
class AggregateSpec:
    """Fully resolved description of one aggregation call.

    ``form``    — ``"stacked"`` (leading-N pytree), ``"axis"`` (one agent
                  per shard inside shard_map), ``"axis_stacked"`` (a local
                  agent stack per shard inside shard_map).
    ``exact``   — ideal Algorithm-1 uplink (plain mean; no channel/noise).
    ``backend`` — ``"xla"`` | ``"pallas"`` | ``"auto"``.  The pallas fused
                  kernel implements the stacked form; axis forms always
                  lower to the xla psum chain (``"auto"`` resolves there,
                  an explicit ``"pallas"`` raises).
    """

    form: str = "stacked"
    exact: bool = False
    backend: str = "auto"

    def __post_init__(self):
        if self.form not in _FORMS:
            raise ValueError(f"unknown form {self.form!r}; one of {_FORMS}")
        if self.backend not in _BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; one of {_BACKENDS}")

    def resolved_backend(self) -> str:
        """The concrete backend this spec executes on, on this process."""
        if self.exact:
            return "xla"
        if self.backend == "auto":
            if self.form == "stacked" and jax.default_backend() == "tpu":
                return "pallas"
            return "xla"
        if self.backend == "pallas" and self.form != "stacked":
            raise ValueError(
                "backend='pallas' implements the stacked form only; axis "
                "forms run the psum chain (use backend='auto' or 'xla')")
        return self.backend


def _make_spec(cfg: Optional[OTAConfig], axis, local_stack: bool,
               backend: str) -> AggregateSpec:
    form = "stacked" if axis is None else (
        "axis_stacked" if local_stack else "axis")
    return AggregateSpec(form=form, exact=cfg is None, backend=backend)


def aggregate(
    grads: PyTree,
    cfg: Optional[OTAConfig],
    *,
    key: Optional[jax.Array] = None,
    axis: Optional[Sequence[str]] = None,
    n_agents: Optional[int] = None,
    backend: str = "auto",
    local_stack: bool = False,
    gains: Optional[jax.Array] = None,
    spec: Optional[AggregateSpec] = None,
    agent_blocks: Optional[int] = None,
) -> Tuple[PyTree, jax.Array]:
    """OTA-aggregate ``grads`` under ``cfg``; returns ``(u_k, h)``.

    ``cfg=None`` is the exact Algorithm-1 uplink (ideal mean; ``h == 1``).
    ``axis=None`` selects the stacked form (leaves carry a leading N axis);
    an axis-name tuple selects the shard_map/psum forms, ``local_stack=True``
    when each shard carries a stack of agents.  ``key`` is required for
    noisy forms; ``n_agents`` is the static global agent count when the
    caller knows it (needed by per-agent power policies and traced-count
    jax versions).  ``backend``/``spec`` pick the implementation —
    see :class:`AggregateSpec`.  ``gains`` overrides the channel draw
    (stacked form only, for equivalence tests).

    ``agent_blocks`` selects the *streaming* blocked-scan evaluation of the
    agent sum: the agent axis is consumed in ``lax.scan`` chunks of that
    many agents, each chunk folded into the running channel superposition
    by a strict sequential per-agent fold, with one AWGN draw + debias at
    the end.  The PRNG streams (gain draw, noise key / counter seed) are
    identical to the unblocked form, and the result is bitwise-invariant to
    the choice of block size — any partition of the agent axis, including a
    non-dividing one (the tail block is masked phantom agents), produces
    the identical update.  Relative to ``agent_blocks=None`` the only
    difference is the floating-point association of the cross-agent sum
    (XLA's batched reduce vs. the sequential fold), a last-mantissa-bit
    reassociation.  Needs an agent stack: the ``stacked`` and
    ``axis_stacked`` forms (in the latter, rows whose global agent index is
    ``>= n_agents`` are treated as phantom padding).  See
    ``fedpg.make_round_fn(agent_blocks=...)`` for the form that actually
    *produces* the gradients blockwise, which is where the O(B×d) peak
    memory comes from.

    ``h`` is the sampled gain realisation: shape ``(N,)`` for the stacked
    form, the local shard's gains for the axis forms (phantom entries
    zeroed under ``agent_blocks`` padding), ``1.0`` when exact.
    """
    sp = spec if spec is not None else _make_spec(cfg, axis, local_stack,
                                                  backend)
    if sp.form != "stacked" and axis is None:
        raise ValueError(f"form {sp.form!r} needs an axis-name tuple")
    if agent_blocks is not None and sp.form == "axis":
        raise ValueError(
            "agent_blocks streams an agent *stack*; the one-agent-per-shard "
            "'axis' form has nothing to block (use local_stack=True)")

    if sp.exact:
        if sp.form == "stacked":
            if agent_blocks is not None:
                return _exact_mean_streamed(grads, agent_blocks), jnp.ones(())
            return _exact_mean(grads), jnp.ones(())
        if sp.form == "axis":
            return jax.lax.pmean(grads, tuple(axis)), jnp.ones(())
        if agent_blocks is not None:
            return _exact_mean_axis_stacked_streamed(
                grads, tuple(axis), n_agents, agent_blocks), jnp.ones(())
        return _exact_mean_axis_stacked(grads, tuple(axis), n_agents), \
            jnp.ones(())

    if cfg is None:
        raise ValueError("noisy spec needs an OTAConfig")
    if key is None:
        raise ValueError("noisy aggregation needs a PRNG key")

    be = sp.resolved_backend()
    if sp.form == "stacked":
        if agent_blocks is not None:
            return _aggregate_stacked_streamed(
                cfg, key, grads, agent_blocks, gains=gains, backend=be)
        if be == "pallas":
            return _aggregate_stacked_pallas(cfg, key, grads, gains=gains)
        return _aggregate_stacked_xla(cfg, key, grads, gains=gains)
    if sp.form == "axis":
        u, h = _psum_axis(cfg, key, grads, tuple(axis), n_agents=n_agents)
        return u, h
    if agent_blocks is not None:
        return _psum_axis_stacked_streamed(cfg, key, grads, tuple(axis),
                                           n_agents=n_agents,
                                           agent_blocks=agent_blocks)
    return _psum_axis_stacked(cfg, key, grads, tuple(axis),
                              n_agents=n_agents)


def aggregate_apply(
    grads: PyTree,
    cfg: Optional[OTAConfig],
    params: PyTree,
    *,
    key: Optional[jax.Array] = None,
    alpha: Scalar,
    backend: str = "auto",
    gains: Optional[jax.Array] = None,
    agent_blocks: Optional[int] = None,
) -> Tuple[PyTree, jax.Array]:
    """Aggregate + server SGD step: ``theta' = theta - alpha * u_k``.

    Stacked form only (the fedpg round loop's uplink tail).  On the pallas
    backend the whole chain — gain matvec, AWGN, debias, parameter update —
    is ONE fused kernel pass (``ota_fused.fused_aggregate_sgd``); on xla it
    is the bit-exact historical two-step (aggregate, then tree-mapped
    update).  ``agent_blocks`` streams the agent axis in blocked-scan
    chunks (see :func:`aggregate`); on pallas the final noise + debias +
    SGD tail then still runs as one fused kernel pass over the accumulated
    superposition.  Returns ``(theta', h)``.
    """
    sp = _make_spec(cfg, None, False, backend)
    if agent_blocks is not None and not sp.exact \
            and sp.resolved_backend() == "pallas":
        return _aggregate_apply_streamed_pallas(
            cfg, key, grads, params, alpha, agent_blocks, gains=gains)
    if sp.exact or sp.resolved_backend() == "xla":
        u, h = aggregate(grads, cfg, key=key, gains=gains,
                         agent_blocks=agent_blocks,
                         spec=replace(sp, backend="xla"))
        return jax.tree.map(lambda p, x: p - alpha * x, params, u), h
    return _aggregate_apply_pallas(cfg, key, grads, params, alpha,
                                   gains=gains)


def uplink_jaxpr(cfg: Optional[OTAConfig], *, n_agents: int = 4,
                 dim: int = 8, apply: bool = False, alpha: Scalar = 1e-3,
                 backend: str = "xla", agent_blocks: Optional[int] = None):
    """Trace the stacked uplink for structural inspection.

    Returns the ClosedJaxpr of ``aggregate`` (or ``aggregate_apply`` with
    ``apply=True``) on a ``(n_agents, dim)`` gradient stack — no execution,
    no compile.  This is the hook ``repro.analyze.contracts``'s wire-dtype
    checker walks: the uplink may narrow floats *only* through the
    sanctioned ``OTAConfig.wire_dtype`` bf16 hop, so any other
    ``convert_element_type`` to a smaller float in this jaxpr is a
    precision bug.  ``agent_blocks`` traces the streaming blocked-scan
    form instead (the hook the stream-contract checker walks: the scan
    carry must stay O(block × d), independent of ``n_agents``).
    """
    grads = jnp.zeros((n_agents, dim), jnp.float32)
    key = jax.random.key(0)
    if apply:
        params = jnp.zeros((dim,), jnp.float32)
        return jax.make_jaxpr(
            lambda g, p, k: aggregate_apply(g, cfg, p, key=k, alpha=alpha,
                                            backend=backend,
                                            agent_blocks=agent_blocks)
        )(grads, params, key)
    return jax.make_jaxpr(
        lambda g, k: aggregate(g, cfg, key=k, backend=backend,
                               agent_blocks=agent_blocks)
    )(grads, key)


# ---------------------------------------------------------------------------
# Form 1 impl: stacked per-agent gradients (literal Algorithm 2).
# ---------------------------------------------------------------------------

def sample_gains(cfg: OTAConfig, key: jax.Array, n_agents: int) -> jax.Array:
    """Draw h_{i,k} for every agent for one round: shape (n_agents,).

    With power control, the effective gain is ``h = c * p(c)`` (Eq. 6's
    gain-times-power factorisation).
    """
    c = cfg.channel.sample(key, (n_agents,))
    if cfg.power_control is not None:
        c = c * cfg.power_control.apply(c)
    return c


def signal_power_sq(grads_stacked: PyTree, gains: jax.Array) -> jax.Array:
    """``||sum_i h_i g_i||^2`` — the received signal power of one uplink.

    Recomputes the combine of :func:`_aggregate_stacked_xla` on the same
    operands (identical op sequence, so XLA CSEs it against the aggregate
    when both appear in one program); the telemetry SNR probe divides this
    by the per-dimension noise power ``d * sigma_z^2``.
    """
    leading = jax.tree.leaves(grads_stacked)[0].shape[0]

    def _combine(g):
        hb = gains.reshape((leading,) + (1,) * (g.ndim - 1)).astype(g.dtype)
        return jnp.sum(hb * g, axis=0)

    v = jax.tree.map(_combine, grads_stacked)
    return sum(jnp.sum(jnp.square(leaf)) for leaf in jax.tree.leaves(v))


def effective_gain_mean(cfg: Optional[OTAConfig],
                        n_agents: Optional[int] = None) -> Scalar:
    """The closed-form effective gain mean ``m_h`` a config realises — the
    reference the telemetry moment-drift probe compares ``mean(h)`` against.

    Resolution order: exact uplink -> 1; a sweep-packed ``update_scale``
    (``1 / (N * m_eff)`` in float64) inverts back to the per-lane effective
    mean; otherwise the channel mean when no power control is set (possibly
    a traced ``BatchedChannel`` moment), else the closed-form/Monte-Carlo
    ``effective_moments``.  Falls back to the raw channel mean when traced
    power-control parameters make the closed form unavailable (the drift
    then includes the power-policy effect — documented approximation).
    """
    if cfg is None:
        return 1.0
    if cfg.debias and cfg.update_scale is not None and n_agents is not None:
        return 1.0 / (n_agents * cfg.update_scale)
    if cfg.power_control is None:
        return cfg.channel.mean
    try:
        return effective_moments(cfg.channel, cfg.power_control,
                                 n_agents=n_agents)[0]
    except TypeError:  # traced/unhashable channel or policy params
        return cfg.channel.mean


def _participation_rescale(n_total: Scalar, n_eff: Scalar) -> Scalar:
    """``n_total / n_eff`` — the round-service correction that retargets
    the full-fleet normaliser ``1/(n_total * m_h)`` at the round's
    effective contribution weight ``n_eff`` (realised participating count
    or its closed-form expectation, possibly fractional under staleness
    decay).  Exact zero at ``n_eff == 0``: an empty round must commit a
    zero update, never the amplified bare noise draw."""
    w = jnp.asarray(n_eff, jnp.float32)
    return jnp.where(w > 0, n_total / jnp.where(w > 0, w, 1.0), 0.0)


def _server_epilogue(
    cfg: OTAConfig,
    key_n: jax.Array,
    v: PyTree,
    n_total: Scalar,
    n_agents: Optional[int],
    n_eff: Optional[Scalar] = None,
) -> PyTree:
    """The shared server-side tail of every xla aggregation form: AWGN on
    the summed signal, then the update normalisation ``update_scale`` or
    ``1 / (n_total * norm_const)``.  One copy keeps the equivalence-tested
    forms from drifting apart.  ``n_eff`` (round service) renormalises by
    the effective contribution weight instead of the full fleet — see
    :func:`_participation_rescale`; ``None`` leaves the historical scale
    byte-identical."""
    if _noise_enabled(cfg.noise_sigma):
        noise = tree_normal_like(key_n, v, cfg.noise_sigma)
        v = jax.tree.map(jnp.add, v, noise)
    scale = cfg.update_scale
    if scale is None:
        scale = 1.0 / (n_total * cfg.norm_const_for(n_agents))
    if n_eff is not None:
        scale = scale * _participation_rescale(n_total, n_eff)
    return jax.tree.map(lambda x: x * scale, v)


def _server_scale(cfg: OTAConfig, n_total: Scalar,
                  n_agents: Optional[int],
                  n_eff: Optional[Scalar] = None) -> Scalar:
    """The epilogue's multiplicative constant, for backends that fuse it."""
    if cfg.update_scale is not None:
        scale = cfg.update_scale
    else:
        scale = 1.0 / (n_total * cfg.norm_const_for(n_agents))
    if n_eff is not None:
        scale = scale * _participation_rescale(n_total, n_eff)
    return scale


def _aggregate_stacked_xla(
    cfg: OTAConfig,
    key: jax.Array,
    grads_stacked: PyTree,
    *,
    gains: Optional[jax.Array] = None,
) -> Tuple[PyTree, jax.Array]:
    """u_k = (sum_i h_i g_i + n_k) / (N * c) as the historical XLA chain."""
    leading = jax.tree.leaves(grads_stacked)[0].shape[0]
    key_h, key_n = jax.random.split(key)
    h = sample_gains(cfg, key_h, leading) if gains is None else gains

    def _combine(g):
        hb = h.reshape((leading,) + (1,) * (g.ndim - 1)).astype(g.dtype)
        return jnp.sum(hb * g, axis=0)

    v = jax.tree.map(_combine, grads_stacked)
    return _server_epilogue(cfg, key_n, v, leading, leading), h


def _exact_mean(grads_stacked: PyTree) -> PyTree:
    """Algorithm-1 baseline: exact mean of per-agent gradients."""
    return jax.tree.map(lambda g: jnp.mean(g, axis=0), grads_stacked)


def _exact_mean_axis_stacked(
    local_grads: PyTree, axis_names: Tuple[str, ...],
    n_agents: Optional[int],
) -> PyTree:
    """Exact global mean over shard-local agent stacks (psum of local
    sums / N) — the op sequence the sharded fedpg round always used."""
    n_local = jax.tree.leaves(local_grads)[0].shape[0]
    if n_agents is None:
        idx_stride = 1
        for name in axis_names:
            idx_stride = idx_stride * _axis_size(name)
        n_total: Scalar = idx_stride * n_local
    else:
        n_total = n_agents
    local_sum = jax.tree.map(lambda g: jnp.sum(g, axis=0), local_grads)
    return jax.tree.map(
        lambda s: jax.lax.psum(s, axis_names) / n_total, local_sum)


# ---------------------------------------------------------------------------
# Pallas backend: the fused kernel over the flattened parameter axis.
# ---------------------------------------------------------------------------

def _wire_dtype(cfg: OTAConfig):
    if not cfg.wire_dtype:
        return None
    return jnp.dtype(cfg.wire_dtype)


def _flatten_agent_stack(grads_stacked: PyTree):
    """(pytree of (N, ...) leaves) -> ((N, P) f32, unflatten)."""
    leaves, treedef = jax.tree.flatten(grads_stacked)
    n = leaves[0].shape[0]
    sizes = [int(leaf.size) // n for leaf in leaves]
    flat = jnp.concatenate(
        [leaf.reshape(n, -1).astype(jnp.float32) for leaf in leaves], axis=1)

    def unflatten(vec: jax.Array) -> PyTree:
        parts = []
        off = 0
        for leaf, size in zip(leaves, sizes):
            parts.append(
                vec[off:off + size].reshape(leaf.shape[1:]).astype(leaf.dtype))
            off += size
        return jax.tree.unflatten(treedef, parts)

    return flat, n, unflatten


def _flatten_params(params: PyTree):
    leaves, treedef = jax.tree.flatten(params)
    sizes = [int(leaf.size) for leaf in leaves]
    flat = jnp.concatenate(
        [leaf.reshape(-1).astype(jnp.float32) for leaf in leaves])

    def unflatten(vec: jax.Array) -> PyTree:
        parts = []
        off = 0
        for leaf, size in zip(leaves, sizes):
            parts.append(
                vec[off:off + size].reshape(leaf.shape).astype(leaf.dtype))
            off += size
        return jax.tree.unflatten(treedef, parts)

    return flat, unflatten


def _kernel_seed(key_n: jax.Array) -> jax.Array:
    """A uint32 counter-PRNG seed derived from the server noise key."""
    return jax.random.bits(key_n, (), jnp.uint32)


def _aggregate_stacked_pallas(
    cfg: OTAConfig,
    key: jax.Array,
    grads_stacked: PyTree,
    *,
    gains: Optional[jax.Array] = None,
) -> Tuple[PyTree, jax.Array]:
    from repro.kernels import ota_fused

    flat, n, unflatten = _flatten_agent_stack(grads_stacked)
    key_h, key_n = jax.random.split(key)
    h = sample_gains(cfg, key_h, n) if gains is None else gains
    u = ota_fused.fused_aggregate(
        flat, h.astype(jnp.float32),
        sigma=cfg.noise_sigma,
        scale=_server_scale(cfg, n, n),
        seed=_kernel_seed(key_n),
        with_noise=_noise_enabled(cfg.noise_sigma),
        wire_dtype=_wire_dtype(cfg),
    )
    return unflatten(u), h


def _aggregate_apply_pallas(
    cfg: OTAConfig,
    key: jax.Array,
    grads_stacked: PyTree,
    params: PyTree,
    alpha: Scalar,
    *,
    gains: Optional[jax.Array] = None,
) -> Tuple[PyTree, jax.Array]:
    from repro.kernels import ota_fused

    flat, n, _ = _flatten_agent_stack(grads_stacked)
    pflat, punflatten = _flatten_params(params)
    key_h, key_n = jax.random.split(key)
    h = sample_gains(cfg, key_h, n) if gains is None else gains
    p_next = ota_fused.fused_aggregate_sgd(
        flat, h.astype(jnp.float32), pflat,
        alpha=alpha,
        sigma=cfg.noise_sigma,
        scale=_server_scale(cfg, n, n),
        seed=_kernel_seed(key_n),
        with_noise=_noise_enabled(cfg.noise_sigma),
        wire_dtype=_wire_dtype(cfg),
    )
    return punflatten(p_next), h


# ---------------------------------------------------------------------------
# Streaming (blocked-scan) evaluation of the agent sum: agent_blocks.
#
# The agent axis is consumed in scan chunks of `block` agents; each chunk is
# folded into the running channel superposition by a STRICT sequential
# per-agent fold.  The fold's association is therefore independent of where
# the block boundaries fall — any partition of the agent axis (including a
# masked phantom tail for non-dividing counts) yields a bitwise-identical
# sum, mirroring the `block_rows` invariance of the fused kernel.  Gains
# and AWGN come from the exact same PRNG streams as the unblocked forms;
# only the cross-agent summation association differs from XLA's batched
# reduce (a last-mantissa-bit reassociation, documented in README).
# ---------------------------------------------------------------------------

def blocked_layout(n_agents: int, agent_blocks: int) -> Tuple[int, int, int]:
    """Resolve a block partition: ``(n_blocks, block, pad)``.

    ``pad`` phantom agents fill the tail block when ``agent_blocks`` does
    not divide ``n_agents``; their contributions are masked to exact zeros,
    so the padded fold is bitwise-identical to the unpadded one.

    The block is capped at ``ceil(n_agents / 2)`` so the scan always runs
    at least two steps: XLA inlines a trip-count-1 loop, which changes how
    the block body fuses and would make ``agent_blocks >= n_agents`` a
    bitwise outlier among block sizes.  Capping only shrinks the block
    (peak memory stays within the requested O(agent_blocks × d)) and the
    strict sequential fold is invariant to where the boundaries fall, so
    every finite ``agent_blocks`` lands on the same history.
    """
    if agent_blocks < 1:
        raise ValueError(f"agent_blocks must be >= 1, got {agent_blocks}")
    block = min(int(agent_blocks), max(1, -(-n_agents // 2)))
    n_blocks = -(-n_agents // block)
    return n_blocks, block, n_blocks * block - n_agents


def pad_agent_axis(tree: PyTree, pad: int) -> PyTree:
    """Append ``pad`` phantom rows to every leading-axis leaf (row-0 copies;
    the values never contribute — every streamed consumer masks them).
    Works on PRNG key arrays too (gather + concatenate only)."""
    if pad == 0:
        return tree

    def _pad(a):
        filler = a[jnp.zeros((pad,), jnp.int32)]
        return jnp.concatenate([a, filler], axis=0)

    return jax.tree.map(_pad, tree)


def block_view(tree: PyTree, n_blocks: int, block: int) -> PyTree:
    """Reshape padded leading-axis leaves to ``(n_blocks, block, ...)`` —
    the xs layout the blocked scan consumes (absolute agent order is
    preserved: block b holds agents ``[b*block, (b+1)*block)``)."""
    return jax.tree.map(
        lambda a: a.reshape((n_blocks, block) + a.shape[1:]), tree)


def block_valid_mask(n_agents: int, n_blocks: int, block: int) -> jax.Array:
    """(n_blocks, block) bool — False on phantom (padding) rows."""
    return (jnp.arange(n_blocks * block) < n_agents).reshape(n_blocks, block)


def stream_fold_block(
    acc: PyTree,
    grads_block: PyTree,
    gains_block: Optional[jax.Array] = None,
    valid: Optional[jax.Array] = None,
    wire_dtype=None,
) -> PyTree:
    """Fold one agent block into the running sum, strictly sequentially.

    ``acc + h_0 g_0 + h_1 g_1 + ...`` as an explicit left fold (a
    ``fori_loop`` of per-agent adds), so the association never depends on
    the block size.  ``gains_block=None`` folds the unweighted gradients
    (the exact-uplink mean numerator).  ``valid`` masks phantom rows to
    exact zeros — IEEE-safe: the running value can never be ``-0.0`` (a sum
    starting from ``+0.0`` cannot produce it), so ``+ 0.0`` is a bitwise
    no-op and padding never perturbs the fold.  ``wire_dtype`` applies the
    pallas wire-format quantisation per agent row (cast down, compute in
    float32), matching the fused kernel's per-row math.
    """
    leaves = jax.tree.leaves(grads_block)
    block = leaves[0].shape[0]

    def step(i, acc):
        def add_row(a, g):
            row = g[i]
            if wire_dtype is not None:
                row = row.astype(wire_dtype).astype(jnp.float32)
            if gains_block is not None:
                row = gains_block[i].astype(row.dtype) * row
            if valid is not None:
                row = jnp.where(valid[i], row, jnp.zeros_like(row))
            return a + row.astype(a.dtype)
        return jax.tree.map(add_row, acc, grads_block)

    return jax.lax.fori_loop(0, block, step, acc)


def _stream_zero(grads_stacked: PyTree, as_f32: bool = False) -> PyTree:
    dt = (lambda a: jnp.float32) if as_f32 else (lambda a: a.dtype)
    return jax.tree.map(
        lambda a: jnp.zeros(a.shape[1:], dt(a)), grads_stacked)


def _stream_superpose(
    grads_stacked: PyTree,
    gains: Optional[jax.Array],
    agent_blocks: int,
    *,
    wire_dtype=None,
    as_f32: bool = False,
) -> PyTree:
    """scan-of-folds over an already-materialised agent stack; returns the
    running superposition ``sum_i h_i g_i`` (or ``sum_i g_i``)."""
    n = jax.tree.leaves(grads_stacked)[0].shape[0]
    n_blocks, block, pad = blocked_layout(n, agent_blocks)
    gp = block_view(pad_agent_axis(grads_stacked, pad), n_blocks, block)
    valid = block_valid_mask(n, n_blocks, block)
    xs = (gp, valid)
    if gains is not None:
        hp = jnp.concatenate([gains, jnp.zeros((pad,), gains.dtype)]) \
            if pad else gains
        xs = (gp, valid, hp.reshape(n_blocks, block))

    def body(acc, x):
        gb, vb = x[0], x[1]
        hb = x[2] if gains is not None else None
        if as_f32:
            gb = jax.tree.map(lambda a: a.astype(jnp.float32), gb)
        return stream_fold_block(acc, gb, hb, vb, wire_dtype=wire_dtype), None

    v, _ = jax.lax.scan(body, _stream_zero(grads_stacked, as_f32), xs)
    return v


def stream_finalize(
    cfg: OTAConfig,
    key_n: jax.Array,
    v: PyTree,
    n_agents: int,
    *,
    backend: str = "xla",
    n_eff: Optional[Scalar] = None,
) -> PyTree:
    """Server tail over a streamed superposition: ONE AWGN draw + the
    debias normalisation.  On xla this is the shared `_server_epilogue`
    (the noise tensor is bitwise-identical to the unblocked form's — same
    ``key_n``, same shapes); on pallas it is one fused kernel pass over the
    flattened ``v`` with the counter PRNG (noise indexed by absolute flat
    position, so it too is invariant to the agent blocking).  ``n_eff``
    retargets the normaliser at the round service's effective
    contribution weight (see :func:`_participation_rescale`)."""
    if backend == "pallas":
        from repro.kernels import ota_fused

        flat, unflatten = _flatten_params(v)
        u = ota_fused.fused_server_pass(
            flat,
            sigma=cfg.noise_sigma,
            scale=_server_scale(cfg, n_agents, n_agents, n_eff),
            seed=_kernel_seed(key_n),
            with_noise=_noise_enabled(cfg.noise_sigma),
        )
        return unflatten(u)
    return _server_epilogue(cfg, key_n, v, n_agents, n_agents, n_eff)


def stream_finalize_apply(
    cfg: OTAConfig,
    key_n: jax.Array,
    v: PyTree,
    params: PyTree,
    alpha: Scalar,
    n_agents: int,
    *,
    backend: str = "xla",
    n_eff: Optional[Scalar] = None,
) -> PyTree:
    """`stream_finalize` fused with the server SGD step
    ``theta' = theta - alpha * u`` (one kernel pass on pallas)."""
    if backend == "pallas":
        from repro.kernels import ota_fused

        flat, _ = _flatten_params(v)
        pflat, punflatten = _flatten_params(params)
        p_next = ota_fused.fused_server_pass(
            flat,
            sigma=cfg.noise_sigma,
            scale=_server_scale(cfg, n_agents, n_agents, n_eff),
            seed=_kernel_seed(key_n),
            with_noise=_noise_enabled(cfg.noise_sigma),
            alpha=alpha,
            params=pflat,
        )
        return punflatten(p_next)
    u = _server_epilogue(cfg, key_n, v, n_agents, n_agents, n_eff)
    return jax.tree.map(lambda p, x: p - alpha * x, params, u)


def _aggregate_stacked_streamed(
    cfg: OTAConfig,
    key: jax.Array,
    grads_stacked: PyTree,
    agent_blocks: int,
    *,
    gains: Optional[jax.Array] = None,
    backend: str = "xla",
) -> Tuple[PyTree, jax.Array]:
    """The stacked form evaluated as a blocked scan.  Same key split, same
    full-N gain draw, same noise stream as the unblocked stacked form of
    the matching backend — only the agent-sum association differs."""
    n = jax.tree.leaves(grads_stacked)[0].shape[0]
    key_h, key_n = jax.random.split(key)
    h = sample_gains(cfg, key_h, n) if gains is None else gains
    pallas = backend == "pallas"
    v = _stream_superpose(
        grads_stacked, h.astype(jnp.float32) if pallas else h, agent_blocks,
        wire_dtype=_wire_dtype(cfg) if pallas else None, as_f32=pallas)
    if pallas:
        # match the kernel's output contract: float32 update leaves cast
        # back to the native parameter dtypes by the unflatten
        u = stream_finalize(cfg, key_n, v, n, backend="pallas")
        u = jax.tree.map(lambda x, g: x.astype(g.dtype), u,
                         jax.tree.map(lambda a: a[0], grads_stacked))
        return u, h
    return stream_finalize(cfg, key_n, v, n), h


def _aggregate_apply_streamed_pallas(
    cfg: OTAConfig,
    key: jax.Array,
    grads_stacked: PyTree,
    params: PyTree,
    alpha: Scalar,
    agent_blocks: int,
    *,
    gains: Optional[jax.Array] = None,
) -> Tuple[PyTree, jax.Array]:
    n = jax.tree.leaves(grads_stacked)[0].shape[0]
    key_h, key_n = jax.random.split(key)
    h = sample_gains(cfg, key_h, n) if gains is None else gains
    v = _stream_superpose(grads_stacked, h.astype(jnp.float32), agent_blocks,
                          wire_dtype=_wire_dtype(cfg), as_f32=True)
    return stream_finalize_apply(cfg, key_n, v, params, alpha, n,
                                 backend="pallas"), h


def _exact_mean_streamed(grads_stacked: PyTree, agent_blocks: int) -> PyTree:
    """Algorithm-1 mean as a blocked fold: ``(fold_i g_i) / N``."""
    n = jax.tree.leaves(grads_stacked)[0].shape[0]
    v = _stream_superpose(grads_stacked, None, agent_blocks)
    return jax.tree.map(lambda s: s / n, v)


def _exact_mean_axis_stacked_streamed(
    local_grads: PyTree, axis_names: Tuple[str, ...],
    n_agents: Optional[int], agent_blocks: int,
) -> PyTree:
    """Exact global mean with shard-local blocked folds (psum of local
    folds / N).  Rows whose global agent index is >= ``n_agents`` are
    phantom padding and fold exact zeros."""
    n_local = jax.tree.leaves(local_grads)[0].shape[0]
    n_total, valid_local = _sharded_stream_meta(axis_names, n_local, n_agents)
    v_local = _stream_superpose_masked(local_grads, None, agent_blocks,
                                       valid_local)
    return jax.tree.map(
        lambda s: jax.lax.psum(s, axis_names) / n_total, v_local)


def _sharded_stream_meta(axis_names, n_local: int,
                         n_agents: Optional[int]):
    """(true agent count, per-local-row validity) for a possibly padded
    shard-local stack: row j is global agent ``shard_index * n_local + j``,
    valid while that index is < n_agents."""
    idx, stride = _flat_axis_index(axis_names)
    if n_agents is None:
        return stride * n_local, jnp.ones((n_local,), bool)
    global_idx = idx * n_local + jnp.arange(n_local, dtype=jnp.int32)
    return n_agents, global_idx < n_agents


def _stream_superpose_masked(
    local_grads: PyTree,
    gains: Optional[jax.Array],
    agent_blocks: int,
    valid_local: jax.Array,
) -> PyTree:
    """`_stream_superpose` over a shard-local stack whose rows carry their
    own validity (shard-level phantom padding composed with the tail-block
    padding of the scan itself)."""
    n_local = jax.tree.leaves(local_grads)[0].shape[0]
    n_blocks, block, pad = blocked_layout(n_local, agent_blocks)
    gp = block_view(pad_agent_axis(local_grads, pad), n_blocks, block)
    vp = jnp.concatenate([valid_local, jnp.zeros((pad,), bool)]) \
        if pad else valid_local
    valid = vp.reshape(n_blocks, block)
    xs = (gp, valid)
    if gains is not None:
        hp = jnp.concatenate([gains, jnp.zeros((pad,), gains.dtype)]) \
            if pad else gains
        xs = (gp, valid, hp.reshape(n_blocks, block))

    def body(acc, x):
        gb, vb = x[0], x[1]
        hb = x[2] if gains is not None else None
        return stream_fold_block(acc, gb, hb, vb), None

    v, _ = jax.lax.scan(body, _stream_zero(local_grads), xs)
    return v


def sharded_stream_gains(
    cfg: OTAConfig,
    key_h: jax.Array,
    axis_names: Tuple[str, ...],
    n_local: int,
    n_agents: Optional[int],
) -> Tuple[jax.Array, jax.Array]:
    """This shard's ``(h_local, valid_local)`` for a streamed axis-stacked
    uplink: the same global-agent-index ``fold_in`` gain stream as the
    unblocked `_psum_axis_stacked` (so gains are invariant to both the mesh
    layout and the blocking), with phantom rows — global index >=
    ``n_agents`` under padding — zeroed so a ``psum(sum(h)) / N`` gain mean
    stays correct."""
    n_total, valid_local = _sharded_stream_meta(axis_names, n_local, n_agents)
    idx, _ = _flat_axis_index(axis_names)
    global_idx = idx * n_local + jnp.arange(n_local, dtype=jnp.int32)

    def gain_for(j):
        c = cfg.channel.sample(jax.random.fold_in(key_h, j), ())
        if cfg.power_control is not None:
            c = c * cfg.power_control.apply_indexed(c, j, n_total)
        return c

    h = jax.vmap(gain_for)(global_idx)
    return jnp.where(valid_local, h, jnp.zeros_like(h)), valid_local


def _psum_axis_stacked_streamed(
    cfg: OTAConfig,
    key: jax.Array,
    local_grads: PyTree,
    axis_names: Tuple[str, ...],
    *,
    n_agents: Optional[int] = None,
    agent_blocks: int,
) -> Tuple[PyTree, jax.Array]:
    """The axis-stacked form with shard-local blocked folds.

    Gains come from :func:`sharded_stream_gains` (the unblocked form's
    stream); phantom rows fold exact zeros.  Local folds are psummed once,
    then the shared server epilogue runs with the TRUE agent count — the
    reward/update normalisers never see the padding.
    """
    n_local = jax.tree.leaves(local_grads)[0].shape[0]
    key_h, key_n = jax.random.split(key)
    n_total, _ = _sharded_stream_meta(axis_names, n_local, n_agents)
    h, valid_local = sharded_stream_gains(cfg, key_h, axis_names, n_local,
                                          n_agents)
    v_local = _stream_superpose_masked(local_grads, h, agent_blocks,
                                       valid_local)
    v = jax.tree.map(lambda s: jax.lax.psum(s, axis_names), v_local)
    return _server_epilogue(cfg, key_n, v, n_total, n_agents), h


# ---------------------------------------------------------------------------
# Form 2 impl: shard_map / psum (production data-parallel form).
# ---------------------------------------------------------------------------

def _flat_axis_index(axis_names: Sequence[str]) -> Tuple[jax.Array, Scalar]:
    """(flattened shard index, total shard count) over the given mesh axes
    (row-major, matching the historical ``local_gain`` indexing).  The count
    is traced on jax versions without ``lax.axis_size``."""
    idx = jnp.zeros((), jnp.int32)
    stride: Scalar = 1
    for name in reversed(tuple(axis_names)):
        idx = idx + jax.lax.axis_index(name) * stride
        stride = stride * _axis_size(name)
    return idx, stride


def local_gain(
    cfg: OTAConfig,
    key: jax.Array,
    axis_names: Sequence[str],
    n_agents: Optional[int] = None,
) -> jax.Array:
    """Sample this shard's h_{i,k} inside shard_map.

    Every shard folds its own agent index into the shared round key, so the
    gains are independent across agents but reproducible.  ``n_agents`` is
    the static total agent count when the caller knows it (per-agent
    policies like ``HeterogeneousBudget`` prefer a static count).
    """
    idx, stride = _flat_axis_index(axis_names)
    c = cfg.channel.sample(jax.random.fold_in(key, idx), ())
    if cfg.power_control is not None:
        # per-agent policies key the budget on this shard's agent index
        n = stride if n_agents is None else n_agents
        c = c * cfg.power_control.apply_indexed(c, idx, n)
    return c


def _psum_axis(
    cfg: OTAConfig,
    key: jax.Array,
    local_grad: PyTree,
    axis_names: Tuple[str, ...],
    *,
    n_agents: Optional[int] = None,
) -> Tuple[PyTree, jax.Array]:
    """OTA aggregation across mesh axes, called inside shard_map.

    The per-agent gain scaling happens *before* the psum, so OTA adds zero
    communication volume over exact data-parallel aggregation — which is the
    paper's efficiency claim transplanted to the interconnect.  ``n_agents``
    is the static total agent count when known; without it the count is a
    traced psum of ones (old jax has no ``lax.axis_size``), which keeps the
    maths right but means debiased per-agent-policy configs must carry an
    explicit ``update_scale`` (a traced count cannot key the closed-form
    effective moments).
    """
    key_h, key_n = jax.random.split(key)
    h = local_gain(cfg, key_h, axis_names, n_agents)
    scaled = jax.tree.map(lambda g: g * h.astype(g.dtype), local_grad)
    v = jax.lax.psum(scaled, axis_names)
    # Same key_n on every shard => identical noise everywhere, i.e. the
    # server's single n_k draw without any broadcast collective.
    n = n_agents
    if n is None and cfg.update_scale is None:  # only then is the count used
        n = _flat_axis_index(axis_names)[1]
    return _server_epilogue(cfg, key_n, v, n, n_agents), h


def _psum_axis_stacked(
    cfg: OTAConfig,
    key: jax.Array,
    local_grads: PyTree,
    axis_names: Tuple[str, ...],
    *,
    n_agents: Optional[int] = None,
) -> Tuple[PyTree, jax.Array]:
    """The axis form for shards that each carry a *stack* of agents.

    ``local_grads`` leaves have a leading ``n_local`` axis (this shard's
    slice of the agent axis).  Gains are drawn exactly like ``local_gain``
    but keyed on the *global* agent index ``shard_index * n_local + j`` —
    with one agent per shard the stream is identical to the plain axis
    form.  Each shard reduces its gain-weighted stack locally, ``psum``s
    across the mesh axes, and applies the shared AWGN + normalisation once.
    This is the agent-axis sharding hook ``fedpg.make_round_fn`` uses, so
    ``HeterogeneousEnv`` fleets and per-agent power control
    (``HeterogeneousBudget``) run in their production shard_map form.

    Returns ``(update, h_local)``; ``h_local`` is this shard's (n_local,)
    gain slice (psum its sum for the global gain mean).
    """
    n_local = jax.tree.leaves(local_grads)[0].shape[0]
    key_h, key_n = jax.random.split(key)
    idx, stride = _flat_axis_index(axis_names)
    n_total: Scalar = n_agents if n_agents is not None else stride * n_local
    global_idx = idx * n_local + jnp.arange(n_local, dtype=jnp.int32)

    def gain_for(j):
        c = cfg.channel.sample(jax.random.fold_in(key_h, j), ())
        if cfg.power_control is not None:
            c = c * cfg.power_control.apply_indexed(c, j, n_total)
        return c

    h = jax.vmap(gain_for)(global_idx)

    def _combine(g):
        hb = h.reshape((n_local,) + (1,) * (g.ndim - 1)).astype(g.dtype)
        return jnp.sum(hb * g, axis=0)

    v = jax.lax.psum(jax.tree.map(_combine, local_grads), axis_names)
    return _server_epilogue(cfg, key_n, v, n_total, n_agents), h


# ---------------------------------------------------------------------------
# Deprecated entry points — thin wrappers over the dispatcher-era impls.
# New in-repo code must use :func:`aggregate`; CI lints for fresh callers
# (tools/lint_aggregation_api.py).
# ---------------------------------------------------------------------------

def _warn_deprecated(name: str, repl: str) -> None:
    warnings.warn(
        f"ota.{name} is deprecated; use ota.aggregate({repl})",
        DeprecationWarning, stacklevel=3,
    )


def aggregate_stacked(
    cfg: OTAConfig,
    key: jax.Array,
    grads_stacked: PyTree,
    *,
    gains: Optional[jax.Array] = None,
) -> Tuple[PyTree, jax.Array]:
    """Deprecated: ``aggregate(grads, cfg, key=key, backend="xla")``."""
    _warn_deprecated("aggregate_stacked", "grads, cfg, key=key")
    return _aggregate_stacked_xla(cfg, key, grads_stacked, gains=gains)


def exact_aggregate(grads_stacked: PyTree) -> PyTree:
    """Deprecated: ``aggregate(grads, None)[0]``."""
    _warn_deprecated("exact_aggregate", "grads, None")
    return _exact_mean(grads_stacked)


def psum_aggregate(
    cfg: OTAConfig,
    key: jax.Array,
    local_grad: PyTree,
    axis_names: Sequence[str],
    *,
    n_agents: Optional[int] = None,
) -> PyTree:
    """Deprecated: ``aggregate(grads, cfg, key=key, axis=axis_names)[0]``."""
    _warn_deprecated("psum_aggregate", "grads, cfg, key=key, axis=...")
    return _psum_axis(cfg, key, local_grad, tuple(axis_names),
                      n_agents=n_agents)[0]


def psum_aggregate_stacked(
    cfg: OTAConfig,
    key: jax.Array,
    local_grads: PyTree,
    axis_names: Sequence[str],
    *,
    n_agents: Optional[int] = None,
) -> Tuple[PyTree, jax.Array]:
    """Deprecated: ``aggregate(..., axis=..., local_stack=True)``."""
    _warn_deprecated("psum_aggregate_stacked",
                     "grads, cfg, key=key, axis=..., local_stack=True")
    return _psum_axis_stacked(cfg, key, local_grads, tuple(axis_names),
                              n_agents=n_agents)


# ---------------------------------------------------------------------------
# Form 3: channel-weighted loss (fold distortion into autodiff).
# ---------------------------------------------------------------------------

def example_weights(
    gains: jax.Array, global_batch: int, *, dtype=jnp.float32
) -> jax.Array:
    """Expand per-agent gains (N,) to per-example weights (global_batch,).

    Agent i owns the contiguous example slice [i*B/N, (i+1)*B/N).  With the
    per-example loss  L = (1/B) sum_e w_e l_e  and w_e = h_{agent(e)}, plain
    autodiff gives  grad L = (1/N) sum_i h_i grad J_i = v_k / N  (pre-noise).
    """
    n_agents = gains.shape[0]
    if global_batch % n_agents != 0:
        raise ValueError(
            f"global_batch={global_batch} not divisible by n_agents={n_agents}"
        )
    per = global_batch // n_agents
    return jnp.repeat(gains.astype(dtype), per)


def add_awgn(
    cfg: OTAConfig, key: jax.Array, grad: PyTree, n_agents: int,
    *, backend: str = "xla",
) -> PyTree:
    """Apply the server-side AWGN and normalisation to a weighted-loss grad.

    ``grad`` must already equal ``(1/N) sum_i h_i g_i`` (from the weighted
    loss); this adds ``n_k / N`` and optionally debiases by ``m_h``.  An
    ``update_scale`` override (``1 / (N * c)`` over the raw sum) is honoured
    here as the equivalent ``N * update_scale`` factor, keeping the
    aggregation forms interchangeable for sweep-built configs.

    ``backend="pallas"`` (or ``"auto"`` on TPU) runs the whole epilogue as
    one fused-kernel pass over the flattened gradient — the LLM trainer's
    server tail at transformer scale; the noise then comes from the kernel's
    counter PRNG (same distribution, different stream than xla threefry).
    """
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {_BACKENDS}")
    be = backend
    if be == "auto":
        be = "pallas" if jax.default_backend() == "tpu" else "xla"
    if be == "pallas":
        return _add_awgn_pallas(cfg, key, grad, n_agents)
    if _noise_enabled(cfg.noise_sigma):
        noise = tree_normal_like(key, grad, cfg.noise_sigma / n_agents)
        grad = jax.tree.map(jnp.add, grad, noise)
    if cfg.update_scale is not None:
        scale = n_agents * cfg.update_scale
        grad = jax.tree.map(lambda x: x * scale, grad)
    elif cfg.debias:
        inv = 1.0 / cfg.norm_const_for(n_agents)
        grad = jax.tree.map(lambda x: x * inv, grad)
    return grad


def _add_awgn_pallas(
    cfg: OTAConfig, key: jax.Array, grad: PyTree, n_agents: int
) -> PyTree:
    """The weighted-loss server epilogue as one fused kernel pass: the
    already-averaged gradient enters as a single-"agent" stack with unit
    gain, sigma/N noise, and the Form-3 normalisation."""
    from repro.kernels import ota_fused

    flat, unflatten = _flatten_params(grad)
    if cfg.update_scale is not None:
        scale: Scalar = n_agents * cfg.update_scale
    elif cfg.debias:
        scale = 1.0 / cfg.norm_const_for(n_agents)
    else:
        scale = 1.0
    u = ota_fused.fused_aggregate(
        flat.reshape(1, -1), jnp.ones((1,), jnp.float32),
        sigma=jnp.asarray(cfg.noise_sigma, jnp.float32) / n_agents,
        scale=scale,
        seed=_kernel_seed(key),
        with_noise=_noise_enabled(cfg.noise_sigma),
        wire_dtype=_wire_dtype(cfg),
    )
    return unflatten(u)
