"""Over-the-air aggregation (Eq. 6-7) — the paper's core primitive.

The physical channel computes ``v_k = sum_i h_{i,k} * g_i + n_k`` "for free"
by analog superposition; the server applies ``theta <- theta - alpha * v_k/N``.
On a TPU mesh the sum is a ``psum`` and the distortion/noise are explicit
tensor ops.

**Entry point:** :func:`aggregate` — one dispatcher over every mathematically
equivalent implementation form, described by an :class:`AggregateSpec`:

* form ``"stacked"``      — literal Algorithm 2 over per-agent gradient
  pytrees stacked on a leading N axis (the RL loops' vmapped workers).
* form ``"axis"``         — ``shard_map`` form: each data-shard scales its
  local gradient by its own gain and ``psum``s across the agent axes; the
  AWGN is generated identically on every shard from a shared key.
* form ``"axis_stacked"`` — the axis form for shards that each carry a
  *stack* of agents (the agent-mesh production path).
* ``exact=True``          — the Algorithm-1 baseline (ideal uplink) in any
  form: the plain mean.

Backends: ``"xla"`` executes the historical op chain (bit-identical to the
pre-dispatcher entry points); ``"pallas"`` routes the stacked form through
the fused kernel ``repro.kernels.ota_fused`` (gain matvec + counter-PRNG
AWGN + debias in ONE pass over the flattened parameter vector, bf16 wire
format via ``OTAConfig.wire_dtype``); ``"auto"`` picks pallas on TPU and
xla elsewhere.  The pallas backend draws its AWGN from the kernel's
counter PRNG — same distribution, different stream than the xla
threefry draw, so histories agree in distribution, not bitwise.

:func:`aggregate_apply` additionally fuses the server SGD update
``theta' = theta - alpha * u`` into the same kernel pass (the fedpg round
loop's uplink tail).

The legacy entry points (``aggregate_stacked``, ``exact_aggregate``,
``psum_aggregate``, ``psum_aggregate_stacked``) remain as thin deprecated
wrappers; new in-repo code must call :func:`aggregate` (enforced by
``tools/lint_aggregation_api.py`` in CI).

A third equivalent form needs no aggregation call at all: channel-weighted
loss — ``sample_gains`` + ``example_weights`` fold the gain into the
per-example loss weight *before* autodiff, so a vanilla pjit gradient
already equals ``sum_i h_i grad_i / N``; ``add_awgn`` then applies the
server noise once.  Zero extra collectives vs. plain DP.

All forms return the *update direction* ``u_k = v_k / N`` so that
``theta^{k+1} = theta^k - alpha * u_k`` matches Eq. (7) exactly.  Setting
``debias=True`` additionally divides by ``m_h`` which makes the estimator
unbiased for ``grad J`` (the quantity the analysis controls, Lemma 3); the
paper's faithful update uses ``debias=False``.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass, replace
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.channel import Channel, IdealChannel
from repro.core.power_control import PowerPolicy, effective_moments
from repro.utils.tree import tree_normal_like

PyTree = Any
Scalar = Union[float, jax.Array]  # python literal, or traced in a sweep lane


def _noise_enabled(sigma: Scalar) -> bool:
    """Whether to emit the AWGN ops.  Python literals keep the exact
    pre-existing behaviour (skip when 0); arrays/tracers always emit them
    (a runtime sigma of 0 then adds exact zeros)."""
    if isinstance(sigma, (int, float)):
        return sigma > 0.0
    return True


def _axis_size(name: str) -> Scalar:
    """Mesh-axis size inside shard_map.  ``jax.lax.axis_size`` only exists on
    newer jax; the pinned 0.4.x falls back to a psum of ones — a *traced*
    count, so callers that need a static agent count (per-agent power-control
    moments, float64-folded scales) must pass one explicitly (see the
    ``n_agents`` kwarg on :func:`aggregate`)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(jnp.ones((), jnp.int32), name)


@dataclass(frozen=True)
class OTAConfig:
    """Static configuration of the over-the-air uplink.

    ``noise_sigma`` may be a traced scalar (the sweep engine batches noise
    levels); ``power_control`` optionally shapes the transmit power so the
    effective gain becomes ``h = c * p(c)`` — with ``debias=True`` the
    update is then divided by the *effective* mean ``E[c p(c)]`` (see
    ``norm_const_for``), keeping the estimator unbiased under power
    control; ``update_scale`` overrides the full server normalisation
    ``1 / (N * norm_const)`` — the sweep engine precomputes it in float64
    per scenario so that batched lanes multiply by exactly the constant the
    unbatched program would have folded in.  ``wire_dtype`` narrows the
    uplink payload on the pallas backend (``"bfloat16"`` casts the stacked
    gradients before the fused gain matvec; compute and the parameter
    master copy stay float32); the default ``""`` keeps the native dtype.
    """

    channel: Channel
    noise_sigma: Scalar = 0.0  # sigma of the AWGN on the *sum* (Eq. 6)
    debias: bool = False       # divide by m_h (unbiased grad estimate)
    power_control: Optional[PowerPolicy] = None
    update_scale: Optional[Scalar] = None
    wire_dtype: str = ""       # "" (native) | "bfloat16" — pallas wire format

    def __post_init__(self):
        # Fail at config-build time, not rounds later: a debiased update
        # divides by m_h, and a NaN mean (a ControlledChannel whose moments
        # were never estimated) would silently corrupt every update.
        if self.debias and self.update_scale is None:
            m = self.channel.mean
            if isinstance(m, (int, float)) and not math.isfinite(m):
                raise ValueError(
                    f"debias=True needs a finite channel mean, got m_h={m!r}; "
                    "build power-controlled channels with "
                    "make_controlled_channel so their effective moments are "
                    "estimated"
                )

    @property
    def norm_const(self) -> Scalar:
        """The raw-channel debias normaliser m_h (no power control folded
        in); the aggregation forms use :meth:`norm_const_for`, which
        accounts for ``power_control``."""
        if not self.debias:
            return 1.0
        m = self.channel.mean
        if isinstance(m, (int, float)) and not math.isfinite(m):
            raise ValueError(
                f"non-finite debias normaliser m_h={m!r}; build "
                "power-controlled channels with make_controlled_channel"
            )
        return m

    def norm_const_for(self, n_agents: Optional[int] = None) -> Scalar:
        """The debias normaliser the aggregation forms divide by: the
        *effective* gain mean E[c p(c)] when ``power_control`` is set
        (closed form or cached Monte Carlo — identical to what
        ``Scenario.ota_config`` folds into ``update_scale``), the channel
        mean otherwise.  ``n_agents`` is needed by per-agent policies."""
        if not self.debias or self.power_control is None:
            return self.norm_const
        try:
            return effective_moments(self.channel, self.power_control,
                                     n_agents=n_agents)[0]
        except TypeError as e:  # traced/unhashable channel or policy params
            raise ValueError(
                "debias needs hashable channel and power-control parameters "
                "to derive the effective mean; traced configs must carry an "
                "explicit update_scale (the sweep engine packs one per lane)"
            ) from e

    def ideal(self) -> "OTAConfig":
        """The matching noiseless/distortionless config (Algorithm 1)."""
        return replace(self, channel=IdealChannel(), noise_sigma=0.0,
                       power_control=None, update_scale=None)


# ---------------------------------------------------------------------------
# The unified dispatcher.
# ---------------------------------------------------------------------------

_BACKENDS = ("auto", "xla", "pallas")
_FORMS = ("stacked", "axis", "axis_stacked")


@dataclass(frozen=True)
class AggregateSpec:
    """Fully resolved description of one aggregation call.

    ``form``    — ``"stacked"`` (leading-N pytree), ``"axis"`` (one agent
                  per shard inside shard_map), ``"axis_stacked"`` (a local
                  agent stack per shard inside shard_map).
    ``exact``   — ideal Algorithm-1 uplink (plain mean; no channel/noise).
    ``backend`` — ``"xla"`` | ``"pallas"`` | ``"auto"``.  The pallas fused
                  kernel implements the stacked form; axis forms always
                  lower to the xla psum chain (``"auto"`` resolves there,
                  an explicit ``"pallas"`` raises).
    """

    form: str = "stacked"
    exact: bool = False
    backend: str = "auto"

    def __post_init__(self):
        if self.form not in _FORMS:
            raise ValueError(f"unknown form {self.form!r}; one of {_FORMS}")
        if self.backend not in _BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; one of {_BACKENDS}")

    def resolved_backend(self) -> str:
        """The concrete backend this spec executes on, on this process."""
        if self.exact:
            return "xla"
        if self.backend == "auto":
            if self.form == "stacked" and jax.default_backend() == "tpu":
                return "pallas"
            return "xla"
        if self.backend == "pallas" and self.form != "stacked":
            raise ValueError(
                "backend='pallas' implements the stacked form only; axis "
                "forms run the psum chain (use backend='auto' or 'xla')")
        return self.backend


def _make_spec(cfg: Optional[OTAConfig], axis, local_stack: bool,
               backend: str) -> AggregateSpec:
    form = "stacked" if axis is None else (
        "axis_stacked" if local_stack else "axis")
    return AggregateSpec(form=form, exact=cfg is None, backend=backend)


def aggregate(
    grads: PyTree,
    cfg: Optional[OTAConfig],
    *,
    key: Optional[jax.Array] = None,
    axis: Optional[Sequence[str]] = None,
    n_agents: Optional[int] = None,
    backend: str = "auto",
    local_stack: bool = False,
    gains: Optional[jax.Array] = None,
    spec: Optional[AggregateSpec] = None,
) -> Tuple[PyTree, jax.Array]:
    """OTA-aggregate ``grads`` under ``cfg``; returns ``(u_k, h)``.

    ``cfg=None`` is the exact Algorithm-1 uplink (ideal mean; ``h == 1``).
    ``axis=None`` selects the stacked form (leaves carry a leading N axis);
    an axis-name tuple selects the shard_map/psum forms, ``local_stack=True``
    when each shard carries a stack of agents.  ``key`` is required for
    noisy forms; ``n_agents`` is the static global agent count when the
    caller knows it (needed by per-agent power policies and traced-count
    jax versions).  ``backend``/``spec`` pick the implementation —
    see :class:`AggregateSpec`.  ``gains`` overrides the channel draw
    (stacked form only, for equivalence tests).

    ``h`` is the sampled gain realisation: shape ``(N,)`` for the stacked
    form, the local shard's gains for the axis forms, ``1.0`` when exact.
    """
    sp = spec if spec is not None else _make_spec(cfg, axis, local_stack,
                                                  backend)
    if sp.form != "stacked" and axis is None:
        raise ValueError(f"form {sp.form!r} needs an axis-name tuple")

    if sp.exact:
        if sp.form == "stacked":
            return _exact_mean(grads), jnp.ones(())
        if sp.form == "axis":
            return jax.lax.pmean(grads, tuple(axis)), jnp.ones(())
        return _exact_mean_axis_stacked(grads, tuple(axis), n_agents), \
            jnp.ones(())

    if cfg is None:
        raise ValueError("noisy spec needs an OTAConfig")
    if key is None:
        raise ValueError("noisy aggregation needs a PRNG key")

    be = sp.resolved_backend()
    if sp.form == "stacked":
        if be == "pallas":
            return _aggregate_stacked_pallas(cfg, key, grads, gains=gains)
        return _aggregate_stacked_xla(cfg, key, grads, gains=gains)
    if sp.form == "axis":
        u, h = _psum_axis(cfg, key, grads, tuple(axis), n_agents=n_agents)
        return u, h
    return _psum_axis_stacked(cfg, key, grads, tuple(axis),
                              n_agents=n_agents)


def aggregate_apply(
    grads: PyTree,
    cfg: Optional[OTAConfig],
    params: PyTree,
    *,
    key: Optional[jax.Array] = None,
    alpha: Scalar,
    backend: str = "auto",
    gains: Optional[jax.Array] = None,
) -> Tuple[PyTree, jax.Array]:
    """Aggregate + server SGD step: ``theta' = theta - alpha * u_k``.

    Stacked form only (the fedpg round loop's uplink tail).  On the pallas
    backend the whole chain — gain matvec, AWGN, debias, parameter update —
    is ONE fused kernel pass (``ota_fused.fused_aggregate_sgd``); on xla it
    is the bit-exact historical two-step (aggregate, then tree-mapped
    update).  Returns ``(theta', h)``.
    """
    sp = _make_spec(cfg, None, False, backend)
    if sp.exact or sp.resolved_backend() == "xla":
        u, h = aggregate(grads, cfg, key=key, gains=gains,
                         spec=replace(sp, backend="xla"))
        return jax.tree.map(lambda p, x: p - alpha * x, params, u), h
    return _aggregate_apply_pallas(cfg, key, grads, params, alpha,
                                   gains=gains)


def uplink_jaxpr(cfg: Optional[OTAConfig], *, n_agents: int = 4,
                 dim: int = 8, apply: bool = False, alpha: Scalar = 1e-3,
                 backend: str = "xla"):
    """Trace the stacked uplink for structural inspection.

    Returns the ClosedJaxpr of ``aggregate`` (or ``aggregate_apply`` with
    ``apply=True``) on a ``(n_agents, dim)`` gradient stack — no execution,
    no compile.  This is the hook ``repro.analyze.contracts``'s wire-dtype
    checker walks: the uplink may narrow floats *only* through the
    sanctioned ``OTAConfig.wire_dtype`` bf16 hop, so any other
    ``convert_element_type`` to a smaller float in this jaxpr is a
    precision bug.
    """
    grads = jnp.zeros((n_agents, dim), jnp.float32)
    key = jax.random.key(0)
    if apply:
        params = jnp.zeros((dim,), jnp.float32)
        return jax.make_jaxpr(
            lambda g, p, k: aggregate_apply(g, cfg, p, key=k, alpha=alpha,
                                            backend=backend)
        )(grads, params, key)
    return jax.make_jaxpr(
        lambda g, k: aggregate(g, cfg, key=k, backend=backend)
    )(grads, key)


# ---------------------------------------------------------------------------
# Form 1 impl: stacked per-agent gradients (literal Algorithm 2).
# ---------------------------------------------------------------------------

def sample_gains(cfg: OTAConfig, key: jax.Array, n_agents: int) -> jax.Array:
    """Draw h_{i,k} for every agent for one round: shape (n_agents,).

    With power control, the effective gain is ``h = c * p(c)`` (Eq. 6's
    gain-times-power factorisation).
    """
    c = cfg.channel.sample(key, (n_agents,))
    if cfg.power_control is not None:
        c = c * cfg.power_control.apply(c)
    return c


def signal_power_sq(grads_stacked: PyTree, gains: jax.Array) -> jax.Array:
    """``||sum_i h_i g_i||^2`` — the received signal power of one uplink.

    Recomputes the combine of :func:`_aggregate_stacked_xla` on the same
    operands (identical op sequence, so XLA CSEs it against the aggregate
    when both appear in one program); the telemetry SNR probe divides this
    by the per-dimension noise power ``d * sigma_z^2``.
    """
    leading = jax.tree.leaves(grads_stacked)[0].shape[0]

    def _combine(g):
        hb = gains.reshape((leading,) + (1,) * (g.ndim - 1)).astype(g.dtype)
        return jnp.sum(hb * g, axis=0)

    v = jax.tree.map(_combine, grads_stacked)
    return sum(jnp.sum(jnp.square(leaf)) for leaf in jax.tree.leaves(v))


def effective_gain_mean(cfg: Optional[OTAConfig],
                        n_agents: Optional[int] = None) -> Scalar:
    """The closed-form effective gain mean ``m_h`` a config realises — the
    reference the telemetry moment-drift probe compares ``mean(h)`` against.

    Resolution order: exact uplink -> 1; a sweep-packed ``update_scale``
    (``1 / (N * m_eff)`` in float64) inverts back to the per-lane effective
    mean; otherwise the channel mean when no power control is set (possibly
    a traced ``BatchedChannel`` moment), else the closed-form/Monte-Carlo
    ``effective_moments``.  Falls back to the raw channel mean when traced
    power-control parameters make the closed form unavailable (the drift
    then includes the power-policy effect — documented approximation).
    """
    if cfg is None:
        return 1.0
    if cfg.debias and cfg.update_scale is not None and n_agents is not None:
        return 1.0 / (n_agents * cfg.update_scale)
    if cfg.power_control is None:
        return cfg.channel.mean
    try:
        return effective_moments(cfg.channel, cfg.power_control,
                                 n_agents=n_agents)[0]
    except TypeError:  # traced/unhashable channel or policy params
        return cfg.channel.mean


def _server_epilogue(
    cfg: OTAConfig,
    key_n: jax.Array,
    v: PyTree,
    n_total: Scalar,
    n_agents: Optional[int],
) -> PyTree:
    """The shared server-side tail of every xla aggregation form: AWGN on
    the summed signal, then the update normalisation ``update_scale`` or
    ``1 / (n_total * norm_const)``.  One copy keeps the equivalence-tested
    forms from drifting apart."""
    if _noise_enabled(cfg.noise_sigma):
        noise = tree_normal_like(key_n, v, cfg.noise_sigma)
        v = jax.tree.map(jnp.add, v, noise)
    scale = cfg.update_scale
    if scale is None:
        scale = 1.0 / (n_total * cfg.norm_const_for(n_agents))
    return jax.tree.map(lambda x: x * scale, v)


def _server_scale(cfg: OTAConfig, n_total: Scalar,
                  n_agents: Optional[int]) -> Scalar:
    """The epilogue's multiplicative constant, for backends that fuse it."""
    if cfg.update_scale is not None:
        return cfg.update_scale
    return 1.0 / (n_total * cfg.norm_const_for(n_agents))


def _aggregate_stacked_xla(
    cfg: OTAConfig,
    key: jax.Array,
    grads_stacked: PyTree,
    *,
    gains: Optional[jax.Array] = None,
) -> Tuple[PyTree, jax.Array]:
    """u_k = (sum_i h_i g_i + n_k) / (N * c) as the historical XLA chain."""
    leading = jax.tree.leaves(grads_stacked)[0].shape[0]
    key_h, key_n = jax.random.split(key)
    h = sample_gains(cfg, key_h, leading) if gains is None else gains

    def _combine(g):
        hb = h.reshape((leading,) + (1,) * (g.ndim - 1)).astype(g.dtype)
        return jnp.sum(hb * g, axis=0)

    v = jax.tree.map(_combine, grads_stacked)
    return _server_epilogue(cfg, key_n, v, leading, leading), h


def _exact_mean(grads_stacked: PyTree) -> PyTree:
    """Algorithm-1 baseline: exact mean of per-agent gradients."""
    return jax.tree.map(lambda g: jnp.mean(g, axis=0), grads_stacked)


def _exact_mean_axis_stacked(
    local_grads: PyTree, axis_names: Tuple[str, ...],
    n_agents: Optional[int],
) -> PyTree:
    """Exact global mean over shard-local agent stacks (psum of local
    sums / N) — the op sequence the sharded fedpg round always used."""
    n_local = jax.tree.leaves(local_grads)[0].shape[0]
    if n_agents is None:
        idx_stride = 1
        for name in axis_names:
            idx_stride = idx_stride * _axis_size(name)
        n_total: Scalar = idx_stride * n_local
    else:
        n_total = n_agents
    local_sum = jax.tree.map(lambda g: jnp.sum(g, axis=0), local_grads)
    return jax.tree.map(
        lambda s: jax.lax.psum(s, axis_names) / n_total, local_sum)


# ---------------------------------------------------------------------------
# Pallas backend: the fused kernel over the flattened parameter axis.
# ---------------------------------------------------------------------------

def _wire_dtype(cfg: OTAConfig):
    if not cfg.wire_dtype:
        return None
    return jnp.dtype(cfg.wire_dtype)


def _flatten_agent_stack(grads_stacked: PyTree):
    """(pytree of (N, ...) leaves) -> ((N, P) f32, unflatten)."""
    leaves, treedef = jax.tree.flatten(grads_stacked)
    n = leaves[0].shape[0]
    sizes = [int(leaf.size) // n for leaf in leaves]
    flat = jnp.concatenate(
        [leaf.reshape(n, -1).astype(jnp.float32) for leaf in leaves], axis=1)

    def unflatten(vec: jax.Array) -> PyTree:
        parts = []
        off = 0
        for leaf, size in zip(leaves, sizes):
            parts.append(
                vec[off:off + size].reshape(leaf.shape[1:]).astype(leaf.dtype))
            off += size
        return jax.tree.unflatten(treedef, parts)

    return flat, n, unflatten


def _flatten_params(params: PyTree):
    leaves, treedef = jax.tree.flatten(params)
    sizes = [int(leaf.size) for leaf in leaves]
    flat = jnp.concatenate(
        [leaf.reshape(-1).astype(jnp.float32) for leaf in leaves])

    def unflatten(vec: jax.Array) -> PyTree:
        parts = []
        off = 0
        for leaf, size in zip(leaves, sizes):
            parts.append(
                vec[off:off + size].reshape(leaf.shape).astype(leaf.dtype))
            off += size
        return jax.tree.unflatten(treedef, parts)

    return flat, unflatten


def _kernel_seed(key_n: jax.Array) -> jax.Array:
    """A uint32 counter-PRNG seed derived from the server noise key."""
    return jax.random.bits(key_n, (), jnp.uint32)


def _aggregate_stacked_pallas(
    cfg: OTAConfig,
    key: jax.Array,
    grads_stacked: PyTree,
    *,
    gains: Optional[jax.Array] = None,
) -> Tuple[PyTree, jax.Array]:
    from repro.kernels import ota_fused

    flat, n, unflatten = _flatten_agent_stack(grads_stacked)
    key_h, key_n = jax.random.split(key)
    h = sample_gains(cfg, key_h, n) if gains is None else gains
    u = ota_fused.fused_aggregate(
        flat, h.astype(jnp.float32),
        sigma=cfg.noise_sigma,
        scale=_server_scale(cfg, n, n),
        seed=_kernel_seed(key_n),
        with_noise=_noise_enabled(cfg.noise_sigma),
        wire_dtype=_wire_dtype(cfg),
    )
    return unflatten(u), h


def _aggregate_apply_pallas(
    cfg: OTAConfig,
    key: jax.Array,
    grads_stacked: PyTree,
    params: PyTree,
    alpha: Scalar,
    *,
    gains: Optional[jax.Array] = None,
) -> Tuple[PyTree, jax.Array]:
    from repro.kernels import ota_fused

    flat, n, _ = _flatten_agent_stack(grads_stacked)
    pflat, punflatten = _flatten_params(params)
    key_h, key_n = jax.random.split(key)
    h = sample_gains(cfg, key_h, n) if gains is None else gains
    p_next = ota_fused.fused_aggregate_sgd(
        flat, h.astype(jnp.float32), pflat,
        alpha=alpha,
        sigma=cfg.noise_sigma,
        scale=_server_scale(cfg, n, n),
        seed=_kernel_seed(key_n),
        with_noise=_noise_enabled(cfg.noise_sigma),
        wire_dtype=_wire_dtype(cfg),
    )
    return punflatten(p_next), h


# ---------------------------------------------------------------------------
# Form 2 impl: shard_map / psum (production data-parallel form).
# ---------------------------------------------------------------------------

def _flat_axis_index(axis_names: Sequence[str]) -> Tuple[jax.Array, Scalar]:
    """(flattened shard index, total shard count) over the given mesh axes
    (row-major, matching the historical ``local_gain`` indexing).  The count
    is traced on jax versions without ``lax.axis_size``."""
    idx = jnp.zeros((), jnp.int32)
    stride: Scalar = 1
    for name in reversed(tuple(axis_names)):
        idx = idx + jax.lax.axis_index(name) * stride
        stride = stride * _axis_size(name)
    return idx, stride


def local_gain(
    cfg: OTAConfig,
    key: jax.Array,
    axis_names: Sequence[str],
    n_agents: Optional[int] = None,
) -> jax.Array:
    """Sample this shard's h_{i,k} inside shard_map.

    Every shard folds its own agent index into the shared round key, so the
    gains are independent across agents but reproducible.  ``n_agents`` is
    the static total agent count when the caller knows it (per-agent
    policies like ``HeterogeneousBudget`` prefer a static count).
    """
    idx, stride = _flat_axis_index(axis_names)
    c = cfg.channel.sample(jax.random.fold_in(key, idx), ())
    if cfg.power_control is not None:
        # per-agent policies key the budget on this shard's agent index
        n = stride if n_agents is None else n_agents
        c = c * cfg.power_control.apply_indexed(c, idx, n)
    return c


def _psum_axis(
    cfg: OTAConfig,
    key: jax.Array,
    local_grad: PyTree,
    axis_names: Tuple[str, ...],
    *,
    n_agents: Optional[int] = None,
) -> Tuple[PyTree, jax.Array]:
    """OTA aggregation across mesh axes, called inside shard_map.

    The per-agent gain scaling happens *before* the psum, so OTA adds zero
    communication volume over exact data-parallel aggregation — which is the
    paper's efficiency claim transplanted to the interconnect.  ``n_agents``
    is the static total agent count when known; without it the count is a
    traced psum of ones (old jax has no ``lax.axis_size``), which keeps the
    maths right but means debiased per-agent-policy configs must carry an
    explicit ``update_scale`` (a traced count cannot key the closed-form
    effective moments).
    """
    key_h, key_n = jax.random.split(key)
    h = local_gain(cfg, key_h, axis_names, n_agents)
    scaled = jax.tree.map(lambda g: g * h.astype(g.dtype), local_grad)
    v = jax.lax.psum(scaled, axis_names)
    # Same key_n on every shard => identical noise everywhere, i.e. the
    # server's single n_k draw without any broadcast collective.
    n = n_agents
    if n is None and cfg.update_scale is None:  # only then is the count used
        n = _flat_axis_index(axis_names)[1]
    return _server_epilogue(cfg, key_n, v, n, n_agents), h


def _psum_axis_stacked(
    cfg: OTAConfig,
    key: jax.Array,
    local_grads: PyTree,
    axis_names: Tuple[str, ...],
    *,
    n_agents: Optional[int] = None,
) -> Tuple[PyTree, jax.Array]:
    """The axis form for shards that each carry a *stack* of agents.

    ``local_grads`` leaves have a leading ``n_local`` axis (this shard's
    slice of the agent axis).  Gains are drawn exactly like ``local_gain``
    but keyed on the *global* agent index ``shard_index * n_local + j`` —
    with one agent per shard the stream is identical to the plain axis
    form.  Each shard reduces its gain-weighted stack locally, ``psum``s
    across the mesh axes, and applies the shared AWGN + normalisation once.
    This is the agent-axis sharding hook ``fedpg.make_round_fn`` uses, so
    ``HeterogeneousEnv`` fleets and per-agent power control
    (``HeterogeneousBudget``) run in their production shard_map form.

    Returns ``(update, h_local)``; ``h_local`` is this shard's (n_local,)
    gain slice (psum its sum for the global gain mean).
    """
    n_local = jax.tree.leaves(local_grads)[0].shape[0]
    key_h, key_n = jax.random.split(key)
    idx, stride = _flat_axis_index(axis_names)
    n_total: Scalar = n_agents if n_agents is not None else stride * n_local
    global_idx = idx * n_local + jnp.arange(n_local, dtype=jnp.int32)

    def gain_for(j):
        c = cfg.channel.sample(jax.random.fold_in(key_h, j), ())
        if cfg.power_control is not None:
            c = c * cfg.power_control.apply_indexed(c, j, n_total)
        return c

    h = jax.vmap(gain_for)(global_idx)

    def _combine(g):
        hb = h.reshape((n_local,) + (1,) * (g.ndim - 1)).astype(g.dtype)
        return jnp.sum(hb * g, axis=0)

    v = jax.lax.psum(jax.tree.map(_combine, local_grads), axis_names)
    return _server_epilogue(cfg, key_n, v, n_total, n_agents), h


# ---------------------------------------------------------------------------
# Deprecated entry points — thin wrappers over the dispatcher-era impls.
# New in-repo code must use :func:`aggregate`; CI lints for fresh callers
# (tools/lint_aggregation_api.py).
# ---------------------------------------------------------------------------

def _warn_deprecated(name: str, repl: str) -> None:
    warnings.warn(
        f"ota.{name} is deprecated; use ota.aggregate({repl})",
        DeprecationWarning, stacklevel=3,
    )


def aggregate_stacked(
    cfg: OTAConfig,
    key: jax.Array,
    grads_stacked: PyTree,
    *,
    gains: Optional[jax.Array] = None,
) -> Tuple[PyTree, jax.Array]:
    """Deprecated: ``aggregate(grads, cfg, key=key, backend="xla")``."""
    _warn_deprecated("aggregate_stacked", "grads, cfg, key=key")
    return _aggregate_stacked_xla(cfg, key, grads_stacked, gains=gains)


def exact_aggregate(grads_stacked: PyTree) -> PyTree:
    """Deprecated: ``aggregate(grads, None)[0]``."""
    _warn_deprecated("exact_aggregate", "grads, None")
    return _exact_mean(grads_stacked)


def psum_aggregate(
    cfg: OTAConfig,
    key: jax.Array,
    local_grad: PyTree,
    axis_names: Sequence[str],
    *,
    n_agents: Optional[int] = None,
) -> PyTree:
    """Deprecated: ``aggregate(grads, cfg, key=key, axis=axis_names)[0]``."""
    _warn_deprecated("psum_aggregate", "grads, cfg, key=key, axis=...")
    return _psum_axis(cfg, key, local_grad, tuple(axis_names),
                      n_agents=n_agents)[0]


def psum_aggregate_stacked(
    cfg: OTAConfig,
    key: jax.Array,
    local_grads: PyTree,
    axis_names: Sequence[str],
    *,
    n_agents: Optional[int] = None,
) -> Tuple[PyTree, jax.Array]:
    """Deprecated: ``aggregate(..., axis=..., local_stack=True)``."""
    _warn_deprecated("psum_aggregate_stacked",
                     "grads, cfg, key=key, axis=..., local_stack=True")
    return _psum_axis_stacked(cfg, key, local_grads, tuple(axis_names),
                              n_agents=n_agents)


# ---------------------------------------------------------------------------
# Form 3: channel-weighted loss (fold distortion into autodiff).
# ---------------------------------------------------------------------------

def example_weights(
    gains: jax.Array, global_batch: int, *, dtype=jnp.float32
) -> jax.Array:
    """Expand per-agent gains (N,) to per-example weights (global_batch,).

    Agent i owns the contiguous example slice [i*B/N, (i+1)*B/N).  With the
    per-example loss  L = (1/B) sum_e w_e l_e  and w_e = h_{agent(e)}, plain
    autodiff gives  grad L = (1/N) sum_i h_i grad J_i = v_k / N  (pre-noise).
    """
    n_agents = gains.shape[0]
    if global_batch % n_agents != 0:
        raise ValueError(
            f"global_batch={global_batch} not divisible by n_agents={n_agents}"
        )
    per = global_batch // n_agents
    return jnp.repeat(gains.astype(dtype), per)


def add_awgn(
    cfg: OTAConfig, key: jax.Array, grad: PyTree, n_agents: int,
    *, backend: str = "xla",
) -> PyTree:
    """Apply the server-side AWGN and normalisation to a weighted-loss grad.

    ``grad`` must already equal ``(1/N) sum_i h_i g_i`` (from the weighted
    loss); this adds ``n_k / N`` and optionally debiases by ``m_h``.  An
    ``update_scale`` override (``1 / (N * c)`` over the raw sum) is honoured
    here as the equivalent ``N * update_scale`` factor, keeping the
    aggregation forms interchangeable for sweep-built configs.

    ``backend="pallas"`` (or ``"auto"`` on TPU) runs the whole epilogue as
    one fused-kernel pass over the flattened gradient — the LLM trainer's
    server tail at transformer scale; the noise then comes from the kernel's
    counter PRNG (same distribution, different stream than xla threefry).
    """
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {_BACKENDS}")
    be = backend
    if be == "auto":
        be = "pallas" if jax.default_backend() == "tpu" else "xla"
    if be == "pallas":
        return _add_awgn_pallas(cfg, key, grad, n_agents)
    if _noise_enabled(cfg.noise_sigma):
        noise = tree_normal_like(key, grad, cfg.noise_sigma / n_agents)
        grad = jax.tree.map(jnp.add, grad, noise)
    if cfg.update_scale is not None:
        scale = n_agents * cfg.update_scale
        grad = jax.tree.map(lambda x: x * scale, grad)
    elif cfg.debias:
        inv = 1.0 / cfg.norm_const_for(n_agents)
        grad = jax.tree.map(lambda x: x * inv, grad)
    return grad


def _add_awgn_pallas(
    cfg: OTAConfig, key: jax.Array, grad: PyTree, n_agents: int
) -> PyTree:
    """The weighted-loss server epilogue as one fused kernel pass: the
    already-averaged gradient enters as a single-"agent" stack with unit
    gain, sigma/N noise, and the Form-3 normalisation."""
    from repro.kernels import ota_fused

    flat, unflatten = _flatten_params(grad)
    if cfg.update_scale is not None:
        scale: Scalar = n_agents * cfg.update_scale
    elif cfg.debias:
        scale = 1.0 / cfg.norm_const_for(n_agents)
    else:
        scale = 1.0
    u = ota_fused.fused_aggregate(
        flat.reshape(1, -1), jnp.ones((1,), jnp.float32),
        sigma=jnp.asarray(cfg.noise_sigma, jnp.float32) / n_agents,
        scale=scale,
        seed=_kernel_seed(key),
        with_noise=_noise_enabled(cfg.noise_sigma),
        wire_dtype=_wire_dtype(cfg),
    )
    return unflatten(u)
