"""Algorithm 1 (federated PG) and Algorithm 2 (over-the-air federated PG).

Fully-jitted loops: each communication round samples N agents x M
trajectories (vmap x vmap over independent PRNG streams), forms per-agent
mini-batch G(PO)MDP estimates (Eq. 4), aggregates — exactly (Algorithm 1) or
through the simulated fading channel (Algorithm 2, Eq. 6-7) — and applies the
server update.  ``lax.scan`` carries theta across the K rounds so a whole
training run is a single XLA program.

Per-round metrics (the paper's Figs. 1-5):
    reward   — empirical cumulative (discounted) reward, averaged over all
               N*M freshly-sampled trajectories;
    grad_sq  — ||(1/N) sum_i grad_hat J_i||^2, the best available estimate of
               ||grad J(theta^k)||^2 (Fig. 2/5's y-axis before K-averaging).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import gpomdp
from repro.core import ota
from repro.core.ota import OTAConfig
from repro.rl.envs.heterogeneous import HeterogeneousEnv, check_agent_count
from repro.rl.sampler import empirical_reward, rollout_batch
from repro.telemetry.probes import RoundTelemetry, TelemetryConfig
from repro.telemetry import probes as _probes
from repro.utils.tree import tree_global_norm_sq

PyTree = Any


@dataclass(frozen=True)
class FedPGConfig:
    n_agents: int = 10           # N
    batch_m: int = 10            # M (trajectories per agent per round)
    horizon: int = 20            # T
    gamma: float = 0.99
    alpha: float = 1e-4          # step size
    n_rounds: int = 200          # K
    estimator: str = "gpomdp"    # or "reinforce"


class History(NamedTuple):
    """Per-round training metrics; prefix-compatible with its 3-field
    predecessor — ``telemetry`` defaults to None (an empty pytree subtree)
    and only holds a ``RoundTelemetry`` stack when a ``TelemetryConfig``
    with active probes was passed to the run."""

    rewards: jax.Array    # (K,)
    grad_sq: jax.Array    # (K,)
    gain_mean: jax.Array  # (K,) mean sampled h per round (1.0 for exact)
    telemetry: Optional[RoundTelemetry] = None  # (K,)-leaved probes, or None


def _active_telemetry(
    telemetry: Optional[TelemetryConfig],
) -> Optional[TelemetryConfig]:
    """Normalise: a config with every probe off is telemetry-off (the
    emitted program must be byte-identical to ``telemetry=None``)."""
    if telemetry is not None and telemetry.active:
        return telemetry
    return None


def _estimator_grad(cfg: FedPGConfig):
    if cfg.estimator == "gpomdp":
        return gpomdp.gpomdp_gradient
    if cfg.estimator == "reinforce":
        return gpomdp.reinforce_gradient
    raise ValueError(f"unknown estimator {cfg.estimator!r}")


def make_round_fn(
    env,
    policy,
    cfg: FedPGConfig,
    ota_cfg: Optional[OTAConfig],
    *,
    agent_mesh=None,
    agent_axis: str = "agents",
    ota_backend: str = "auto",
    telemetry: Optional[TelemetryConfig] = None,
):
    """One communication round: (theta, key) -> (theta', metrics).

    A ``HeterogeneousEnv`` is unrolled per agent: the agent vmap additionally
    maps over the wrapper's per-agent field stacks, so agent i samples from
    its own dynamics inside the same jitted program.

    ``agent_mesh`` shards the agent axis across a device mesh instead: each
    shard rolls out its slice of the fleet (``n_agents / axis_size`` agents,
    per-agent env stacks sliced by ``shard_map``) and the uplink runs through
    :func:`repro.core.ota.aggregate` in its axis-stacked form — the
    production shard_map/psum form, with per-agent power control keyed on global agent
    indices.  Numerical relationship to the vmapped form: rollouts are
    identical (same per-agent keys); cross-agent reductions psum in mesh
    order, so exact-uplink runs and *deterministic* channels (FixedGain,
    per-agent budgets over it) match to reduction tolerance — but gains of
    a *stochastic* channel come from the indexed fold_in stream rather than
    the stacked batched draw, a different random realisation entirely:
    those histories agree in distribution, not numerically.

    ``ota_backend`` selects the aggregation implementation ("xla",
    "pallas", or "auto" — see :class:`repro.core.ota.AggregateSpec`); on
    the pallas backend the uplink *and* the server SGD step run as one
    fused kernel pass (:func:`repro.core.ota.aggregate_apply`).

    ``telemetry`` (a :class:`repro.telemetry.TelemetryConfig` with at least
    one probe on) appends a :class:`RoundTelemetry` pytree to the metrics
    tuple — in-jit per-round diagnostics, see ``repro.telemetry.probes``.
    With ``telemetry=None`` (or all probes off) the emitted program is
    bitwise identical to the pre-telemetry round.
    """
    telem = _active_telemetry(telemetry)

    if agent_mesh is not None:
        return _make_agent_sharded_round_fn(
            env, policy, cfg, ota_cfg, agent_mesh, agent_axis, ota_backend,
            telemetry=telem)

    grad_fn = _estimator_grad(cfg)
    hetero = isinstance(env, HeterogeneousEnv)
    if hetero:
        check_agent_count(env, cfg.n_agents)

    def round_fn(theta: PyTree, key: jax.Array):
        key_samp, key_chan = jax.random.split(key)
        agent_keys = jax.random.split(key_samp, cfg.n_agents)

        # --- local sampling + estimation (parallel across agents) --------
        def agent_grad(k, lane_params):
            e = env.lane(lane_params) if hetero else env
            traj = rollout_batch(e, policy, theta, k, cfg.horizon, cfg.batch_m)
            return grad_fn(policy, theta, traj, cfg.gamma), traj

        lane_stacks = dict(env.params) if hetero else {}
        grads, trajs = jax.vmap(agent_grad)(agent_keys, lane_stacks)  # N axis

        # --- uplink + server update --------------------------------------
        mean_grad = ota.aggregate(grads, None)[0]  # also the grad_sq metric
        if ota_cfg is None:
            gain_mean = jnp.ones(())
            theta_next = jax.tree.map(
                lambda p, u: p - cfg.alpha * u, theta, mean_grad)
        else:
            theta_next, h = ota.aggregate_apply(
                grads, ota_cfg, theta, key=key_chan, alpha=cfg.alpha,
                backend=ota_backend)
            gain_mean = jnp.mean(h)

        # --- metrics ------------------------------------------------------
        reward = empirical_reward(trajs, cfg.gamma)
        grad_sq = tree_global_norm_sq(mean_grad)
        if telem is None:
            return theta_next, (reward, grad_sq, gain_mean)

        # --- telemetry probes (in-jit, only when requested) ---------------
        if ota_cfg is None:
            gains = jnp.ones((cfg.n_agents,))
            update_norm = jnp.sqrt(grad_sq)
        else:
            gains = h
            update_norm = jnp.sqrt(tree_global_norm_sq(jax.tree.map(
                jnp.subtract, theta, theta_next))) / cfg.alpha
        probes = _probes.stacked_round_probes(
            telem, grads_stacked=grads, gains=gains, ota_cfg=ota_cfg,
            n_agents=cfg.n_agents, gain_mean=gain_mean,
            update_norm=update_norm)
        return theta_next, (reward, grad_sq, gain_mean, probes)

    return round_fn


def _make_agent_sharded_round_fn(
    env, policy, cfg: FedPGConfig, ota_cfg: Optional[OTAConfig],
    mesh, axis_name: str, ota_backend: str = "auto",
    telemetry: Optional[TelemetryConfig] = None,
):
    """The agent axis laid across ``mesh[axis_name]`` via shard_map.

    Each shard vmaps over its ``n_local = n_agents / axis_size`` agents;
    per-agent env stacks and sampling keys enter with ``P(axis_name)`` specs
    so shard_map hands every shard exactly its fleet slice.  The uplink is
    the psum form (``ota.aggregate`` with ``local_stack=True``); metrics
    psum local partial sums, so every shard ends the round with identical
    (replicated) theta and metrics.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.rl.sampler import discounted_return

    grad_fn = _estimator_grad(cfg)
    hetero = isinstance(env, HeterogeneousEnv)
    if hetero:
        check_agent_count(env, cfg.n_agents)
    if axis_name not in mesh.shape:
        raise ValueError(
            f"agent mesh has no axis {axis_name!r}; axes are "
            f"{tuple(mesh.axis_names)}")
    n_shards = mesh.shape[axis_name]
    if cfg.n_agents % n_shards != 0:
        raise ValueError(
            f"n_agents={cfg.n_agents} does not divide across the "
            f"{axis_name!r} mesh axis of size {n_shards}")

    def local_round(theta, agent_keys, lane_stacks, key_chan):
        # agent_keys/lane_stacks are this shard's (n_local,)-leading slices
        def agent_grad(k, lane_params):
            e = env.lane(lane_params) if hetero else env
            traj = rollout_batch(e, policy, theta, k, cfg.horizon, cfg.batch_m)
            return grad_fn(policy, theta, traj, cfg.gamma), traj

        grads, trajs = jax.vmap(agent_grad)(agent_keys, lane_stacks)
        mean_grad = ota.aggregate(
            grads, None, axis=(axis_name,), n_agents=cfg.n_agents,
            local_stack=True)[0]

        if ota_cfg is None:
            update = mean_grad
            gain_mean = jnp.ones(())
        else:
            update, h = ota.aggregate(
                grads, ota_cfg, key=key_chan, axis=(axis_name,),
                n_agents=cfg.n_agents, local_stack=True,
                backend=ota_backend)
            gain_mean = jax.lax.psum(jnp.sum(h), axis_name) / cfg.n_agents
        theta_next = jax.tree.map(lambda p, u: p - cfg.alpha * u, theta, update)

        # metrics: psum of local partial sums == the global means
        r_local = -jnp.sum(discounted_return(trajs.losses, cfg.gamma))
        reward = jax.lax.psum(r_local, axis_name) / (cfg.n_agents * cfg.batch_m)
        grad_sq = tree_global_norm_sq(mean_grad)
        if telemetry is None:
            return theta_next, (reward, grad_sq, gain_mean)

        # telemetry probes: psum/pmax reductions, replicated outputs
        n_local = jax.tree.leaves(grads)[0].shape[0]
        local_gains = h if ota_cfg is not None else jnp.ones((n_local,))
        probes = _probes.sharded_round_probes(
            telemetry, local_grads=grads, local_gains=local_gains,
            ota_cfg=ota_cfg, n_agents=cfg.n_agents, axis_name=axis_name,
            gain_mean=gain_mean,
            update_norm=jnp.sqrt(tree_global_norm_sq(update)))
        return theta_next, (reward, grad_sq, gain_mean, probes)

    def round_fn(theta: PyTree, key: jax.Array):
        key_samp, key_chan = jax.random.split(key)
        agent_keys = jax.random.split(key_samp, cfg.n_agents)
        lane_stacks = dict(env.params) if hetero else {}
        stack_specs = jax.tree.map(lambda _: P(axis_name), lane_stacks)
        metric_specs = (P(), P(), P())
        if telemetry is not None:
            metric_specs += (RoundTelemetry(P(), P(), P(), P(), P()),)
        return shard_map(
            local_round, mesh=mesh,
            in_specs=(P(), P(axis_name), stack_specs, P()),
            out_specs=(P(), metric_specs),
            check_rep=False,
        )(theta, agent_keys, lane_stacks, key_chan)

    return round_fn


def run(
    env,
    policy,
    cfg: FedPGConfig,
    key: jax.Array,
    *,
    ota: Optional[OTAConfig] = None,
    theta0: Optional[PyTree] = None,
    agent_mesh=None,
    agent_axis: str = "agents",
    ota_backend: str = "auto",
    telemetry: Optional[TelemetryConfig] = None,
):
    """Run K rounds; returns (theta_K, History).

    ``ota=None`` is Algorithm 1 (exact aggregation); an ``OTAConfig`` is
    Algorithm 2 over the configured channel.  ``agent_mesh`` shards the
    agent axis across a device mesh (see :func:`make_round_fn`) — use
    ``repro.core.distribute.agent_mesh_for`` to build one.  ``ota_backend``
    routes the uplink ("xla" | "pallas" | "auto").  ``telemetry`` (active
    probes) fills ``History.telemetry`` with ``(K,)``-leaved round probes.
    """
    key_init, key_scan = jax.random.split(key)
    theta = policy.init(key_init) if theta0 is None else theta0
    round_fn = make_round_fn(env, policy, cfg, ota,
                             agent_mesh=agent_mesh, agent_axis=agent_axis,
                             ota_backend=ota_backend, telemetry=telemetry)

    def body(carry, key_k):
        theta = carry
        theta, metrics = round_fn(theta, key_k)
        return theta, metrics

    keys = jax.random.split(key_scan, cfg.n_rounds)
    theta, metrics = jax.lax.scan(body, theta, keys)
    if len(metrics) == 4:
        rewards, grad_sq, gain_mean, probes = metrics
        return theta, History(rewards=rewards, grad_sq=grad_sq,
                              gain_mean=gain_mean, telemetry=probes)
    rewards, grad_sq, gain_mean = metrics
    return theta, History(rewards=rewards, grad_sq=grad_sq, gain_mean=gain_mean)


# ---------------------------------------------------------------------------
# Compiled-callable cache.  ``jax.jit`` caches per function object, so
# wrapping a fresh lambda on every run_jit/monte_carlo call used to recompile
# the whole training program from scratch each time.  The jitted closures are
# instead cached on the (hashable) argument tuple; configs with traced or
# otherwise unhashable fields fall back to a fresh closure.
# ---------------------------------------------------------------------------

# Bounded: each entry pins its compiled executable (and the captured
# env/policy) alive, so an unbounded cache would leak across a long
# hand-rolled parameter grid that bypasses the sweep engine.
_CACHE_SIZE = 64


@functools.lru_cache(maxsize=_CACHE_SIZE)
def _compiled_run(env, policy, cfg: FedPGConfig, ota_cfg, backend: str,
                  telemetry=None):
    return jax.jit(
        lambda k: run(env, policy, cfg, k, ota=ota_cfg, ota_backend=backend,
                      telemetry=telemetry))


@functools.lru_cache(maxsize=_CACHE_SIZE)
def _compiled_monte_carlo(env, policy, cfg: FedPGConfig, ota_cfg,
                          n_runs: int, backend: str, telemetry=None):
    return jax.jit(jax.vmap(
        lambda k: run(env, policy, cfg, k, ota=ota_cfg,
                      ota_backend=backend, telemetry=telemetry)[1]))


# every compiled-program cache in the package; other modules (e.g.
# event_triggered) register theirs so one reset call clears them all
_COMPILED_CACHES = [_compiled_run, _compiled_monte_carlo]


def register_compiled_cache(cache) -> None:
    _COMPILED_CACHES.append(cache)


def clear_compilation_cache() -> None:
    """Drop every cached compiled program (mainly for tests) — including
    caches other modules registered via ``register_compiled_cache``."""
    for cache in _COMPILED_CACHES:
        cache.cache_clear()


def _hashable(*objs) -> bool:
    try:
        hash(objs)
        return True
    except TypeError:
        return False


def run_jit(env, policy, cfg: FedPGConfig, key, *, ota=None, theta0=None,
            ota_backend: str = "auto",
            telemetry: Optional[TelemetryConfig] = None):
    """jit-compiled entry point (env/policy/cfgs are closure constants).

    Repeated calls with the same ``(env, policy, cfg, ota, ota_backend,
    telemetry)`` reuse the compiled program (``theta0`` is a pytree and
    cannot key a cache, so passing one compiles fresh).  Caching needs
    every argument hashable: envs holding jax arrays (e.g. ``TabularMDP``)
    take the uncached path.
    """
    telemetry = _active_telemetry(telemetry)
    if theta0 is None and _hashable(env, policy, cfg, ota, telemetry):
        return _compiled_run(env, policy, cfg, ota, ota_backend,
                             telemetry)(key)
    fn = jax.jit(lambda k: run(env, policy, cfg, k, ota=ota, theta0=theta0,
                               ota_backend=ota_backend, telemetry=telemetry))
    return fn(key)


def avg_grad_sq(history: History) -> jax.Array:
    """The paper's reported quantity: (1/K) sum_k ||grad J(theta^k)||^2."""
    return jnp.mean(history.grad_sq)


def monte_carlo(
    env, policy, cfg: FedPGConfig, key: jax.Array, n_runs: int, *, ota=None,
    ota_backend: str = "auto",
    telemetry: Optional[TelemetryConfig] = None,
):
    """n_runs independent repetitions (the paper uses 20): vmapped.

    Repeated calls with the same ``(env, policy, cfg, ota, n_runs,
    telemetry)`` reuse the compiled program; only the PRNG keys change
    between calls.  Caching needs every argument hashable: envs holding
    jax arrays (e.g. ``TabularMDP``) take the uncached path.
    """
    telemetry = _active_telemetry(telemetry)
    keys = jax.random.split(key, n_runs)
    if _hashable(env, policy, cfg, ota, telemetry):
        return _compiled_monte_carlo(env, policy, cfg, ota, n_runs,
                                     ota_backend, telemetry)(keys)
    fn = jax.jit(jax.vmap(
        lambda k: run(env, policy, cfg, k, ota=ota,
                      ota_backend=ota_backend, telemetry=telemetry)[1]))
    return fn(keys)
