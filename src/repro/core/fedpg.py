"""Algorithm 1 (federated PG) and Algorithm 2 (over-the-air federated PG).

Fully-jitted loops: each communication round samples N agents x M
trajectories (vmap x vmap over independent PRNG streams), forms per-agent
mini-batch G(PO)MDP estimates (Eq. 4), aggregates — exactly (Algorithm 1) or
through the simulated fading channel (Algorithm 2, Eq. 6-7) — and applies the
server update.  ``lax.scan`` carries theta across the K rounds so a whole
training run is a single XLA program.

Per-round metrics (the paper's Figs. 1-5):
    reward   — empirical cumulative (discounted) reward, averaged over all
               N*M freshly-sampled trajectories;
    grad_sq  — ||(1/N) sum_i grad_hat J_i||^2, the best available estimate of
               ||grad J(theta^k)||^2 (Fig. 2/5's y-axis before K-averaging).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import gpomdp
from repro.core import ota
from repro.core.ota import OTAConfig
from repro.rl.envs.heterogeneous import HeterogeneousEnv, check_agent_count
from repro.rl.sampler import empirical_reward, rollout_batch
from repro.service import participation as svc_participation
from repro.service import staleness as svc_staleness
from repro.service.participation import ParticipationConfig, ServiceState
from repro.service.staleness import StalenessConfig
from repro.telemetry.probes import RoundTelemetry, TelemetryConfig
from repro.telemetry import probes as _probes
from repro.utils.tree import tree_global_norm_sq

PyTree = Any


@dataclass(frozen=True)
class FedPGConfig:
    n_agents: int = 10           # N
    batch_m: int = 10            # M (trajectories per agent per round)
    horizon: int = 20            # T
    gamma: float = 0.99
    alpha: float = 1e-4          # step size
    n_rounds: int = 200          # K
    estimator: str = "gpomdp"    # or "reinforce"


class History(NamedTuple):
    """Per-round training metrics; prefix-compatible with its 3-field
    predecessor — ``telemetry`` defaults to None (an empty pytree subtree)
    and only holds a ``RoundTelemetry`` stack when a ``TelemetryConfig``
    with active probes was passed to the run."""

    rewards: jax.Array    # (K,)
    grad_sq: jax.Array    # (K,)
    gain_mean: jax.Array  # (K,) mean sampled h per round (1.0 for exact)
    telemetry: Optional[RoundTelemetry] = None  # (K,)-leaved probes, or None


def _active_telemetry(
    telemetry: Optional[TelemetryConfig],
    participation: Optional[ParticipationConfig] = None,
) -> Optional[TelemetryConfig]:
    """Normalise: a config with every probe off is telemetry-off (the
    emitted program must be byte-identical to ``telemetry=None``).  The
    ``participation`` probe flag only counts when an active (normalised)
    participation config makes a service round — on plain runs it has
    nothing to observe and must not activate telemetry."""
    if telemetry is None:
        return None
    if telemetry.active or (participation is not None
                            and telemetry.participation):
        return telemetry
    return None


def _estimator_grad(cfg: FedPGConfig):
    if cfg.estimator == "gpomdp":
        return gpomdp.gpomdp_gradient
    if cfg.estimator == "reinforce":
        return gpomdp.reinforce_gradient
    raise ValueError(f"unknown estimator {cfg.estimator!r}")


def make_round_fn(
    env,
    policy,
    cfg: FedPGConfig,
    ota_cfg: Optional[OTAConfig],
    *,
    agent_mesh=None,
    agent_axis: str = "agents",
    ota_backend: str = "auto",
    telemetry: Optional[TelemetryConfig] = None,
    agent_blocks: Optional[int] = None,
    participation: Optional[ParticipationConfig] = None,
    staleness: Optional[StalenessConfig] = None,
):
    """One communication round: (theta, key) -> (theta', metrics).

    With an *active* ``participation`` config (one that can actually drop
    agents — see :func:`repro.service.participation.normalize`) the round
    becomes a service round ``(ServiceState, key) -> (ServiceState',
    metrics)``: a per-round participation mask (counter-PRNG on ``(round,
    agent_id)``, block/shard invariant) selects the contributing agents,
    non-contributors are masked to exact zeros phantom-agent style, and
    the update is renormalised by the realised (or closed-form expected)
    contribution weight.  ``staleness`` additionally replays
    non-participants' last contributed gradients with age-decay weights
    (stacked and ``agent_blocks`` forms; not composed with
    ``agent_mesh``).  A config that normalises away — ``kind="full"``, a
    static Bernoulli ``rate >= 1`` with no active faults — emits the
    byte-identical plain round.

    A ``HeterogeneousEnv`` is unrolled per agent: the agent vmap additionally
    maps over the wrapper's per-agent field stacks, so agent i samples from
    its own dynamics inside the same jitted program.

    ``agent_mesh`` shards the agent axis across a device mesh instead: each
    shard rolls out its slice of the fleet (``n_agents / axis_size`` agents,
    per-agent env stacks sliced by ``shard_map``) and the uplink runs through
    :func:`repro.core.ota.aggregate` in its axis-stacked form — the
    production shard_map/psum form, with per-agent power control keyed on global agent
    indices.  Numerical relationship to the vmapped form: rollouts are
    identical (same per-agent keys); cross-agent reductions psum in mesh
    order, so exact-uplink runs and *deterministic* channels (FixedGain,
    per-agent budgets over it) match to reduction tolerance — but gains of
    a *stochastic* channel come from the indexed fold_in stream rather than
    the stacked batched draw, a different random realisation entirely:
    those histories agree in distribution, not numerically.

    ``ota_backend`` selects the aggregation implementation ("xla",
    "pallas", or "auto" — see :class:`repro.core.ota.AggregateSpec`); on
    the pallas backend the uplink *and* the server SGD step run as one
    fused kernel pass (:func:`repro.core.ota.aggregate_apply`).

    ``telemetry`` (a :class:`repro.telemetry.TelemetryConfig` with at least
    one probe on) appends a :class:`RoundTelemetry` pytree to the metrics
    tuple — in-jit per-round diagnostics, see ``repro.telemetry.probes``.
    With ``telemetry=None`` (or all probes off) the emitted program is
    bitwise identical to the pre-telemetry round.

    ``agent_blocks`` streams the agent axis: rollouts, gradient estimation
    and the channel superposition run in a ``lax.scan`` over blocks of that
    many agents, so peak memory is O(agent_blocks × d) in the fleet size
    (the scan carry holds one block of trajectories/gradients plus the
    d-sized running sums; only O(N) per-agent *scalars* — gains, returns,
    probe norms — are ever materialised).  Per-agent sampling keys and
    channel gains are indexed by ABSOLUTE agent index, identically to the
    unblocked round, and the cross-agent sums are strict sequential folds:
    histories are bitwise-invariant to the choice of block size (any
    partition of the agent axis, dividing or not — the tail block pads
    masked phantom agents).  Relative to ``agent_blocks=None`` the gain
    means are bitwise-identical and rewards/updates differ only at
    floating-point reassociation level (XLA fuses the blocked rollouts and
    the agent sum differently — last-mantissa-bit effects, ~1e-7
    relative).  Composes with ``agent_mesh``:
    each shard scans its local slice in blocks and the partial sums psum
    across the mesh; a non-dividing ``n_agents`` is then padded with
    masked phantom agents instead of raising.
    """
    part = svc_participation.normalize(participation, cfg.n_agents)
    stale_cfg = svc_staleness.normalize(staleness, part)
    telem = _active_telemetry(telemetry, part)

    if agent_mesh is not None:
        return _make_agent_sharded_round_fn(
            env, policy, cfg, ota_cfg, agent_mesh, agent_axis, ota_backend,
            telemetry=telem, agent_blocks=agent_blocks,
            participation=part, staleness=stale_cfg)
    if agent_blocks is not None:
        return _make_streamed_round_fn(
            env, policy, cfg, ota_cfg, agent_blocks, ota_backend,
            telemetry=telem, participation=part, staleness=stale_cfg)

    grad_fn = _estimator_grad(cfg)
    hetero = isinstance(env, HeterogeneousEnv)
    if hetero:
        check_agent_count(env, cfg.n_agents)

    def round_fn(theta: PyTree, key: jax.Array):
        key_samp, key_chan = jax.random.split(key)
        agent_keys = jax.random.split(key_samp, cfg.n_agents)

        # --- local sampling + estimation (parallel across agents) --------
        def agent_grad(k, lane_params):
            e = env.lane(lane_params) if hetero else env
            traj = rollout_batch(e, policy, theta, k, cfg.horizon, cfg.batch_m)
            return grad_fn(policy, theta, traj, cfg.gamma), traj

        lane_stacks = dict(env.params) if hetero else {}
        grads, trajs = jax.vmap(agent_grad)(agent_keys, lane_stacks)  # N axis

        # --- uplink + server update --------------------------------------
        mean_grad = ota.aggregate(grads, None)[0]  # also the grad_sq metric
        if ota_cfg is None:
            gain_mean = jnp.ones(())
            theta_next = jax.tree.map(
                lambda p, u: p - cfg.alpha * u, theta, mean_grad)
        else:
            theta_next, h = ota.aggregate_apply(
                grads, ota_cfg, theta, key=key_chan, alpha=cfg.alpha,
                backend=ota_backend)
            gain_mean = jnp.mean(h)

        # --- metrics ------------------------------------------------------
        reward = empirical_reward(trajs, cfg.gamma)
        grad_sq = tree_global_norm_sq(mean_grad)
        if telem is None:
            return theta_next, (reward, grad_sq, gain_mean)

        # --- telemetry probes (in-jit, only when requested) ---------------
        if ota_cfg is None:
            gains = jnp.ones((cfg.n_agents,))
            update_norm = jnp.sqrt(grad_sq)
        else:
            gains = h
            update_norm = jnp.sqrt(tree_global_norm_sq(jax.tree.map(
                jnp.subtract, theta, theta_next))) / cfg.alpha
        probes = _probes.stacked_round_probes(
            telem, grads_stacked=grads, gains=gains, ota_cfg=ota_cfg,
            n_agents=cfg.n_agents, gain_mean=gain_mean,
            update_norm=update_norm)
        return theta_next, (reward, grad_sq, gain_mean, probes)

    if part is None:
        return round_fn

    from repro.rl.sampler import discounted_return

    def service_round(state: ServiceState, key: jax.Array):
        theta = state.theta
        key_samp, key_chan = jax.random.split(key)
        agent_keys = jax.random.split(key_samp, cfg.n_agents)
        ids = jnp.arange(cfg.n_agents, dtype=jnp.int32)
        mask = svc_participation.round_mask(
            part, state.part_key, state.sched_key, state.round_idx, ids,
            cfg.n_agents)
        mf = mask.astype(jnp.float32)
        count_p = jnp.sum(mf)

        # rollouts run for every agent (same per-agent keys as the plain
        # round: the realised trajectories of a participant are identical
        # whether or not its peers made the round); non-participants are
        # masked to exact-zero rows before any cross-agent reduction.
        def agent_grad(k, lane_params):
            e = env.lane(lane_params) if hetero else env
            traj = rollout_batch(e, policy, theta, k, cfg.horizon, cfg.batch_m)
            return grad_fn(policy, theta, traj, cfg.gamma), traj

        lane_stacks = dict(env.params) if hetero else {}
        grads, trajs = jax.vmap(agent_grad)(agent_keys, lane_stacks)
        gm = svc_participation.mask_agent_axis(grads, mask)

        if stale_cfg is not None:
            rw = svc_staleness.replay_weights(stale_cfg, mask, state.stale.age)
            w_replay, _, stale_age = svc_staleness.stats(
                stale_cfg, mask, state.stale.age)
            ssum = svc_staleness.replay_sum_stacked(state.stale, rw)
            stale_next = svc_staleness.advance(
                stale_cfg, state.stale, mask, grads)
        else:
            w_replay = jnp.zeros((), jnp.float32)
            ssum = stale_next = stale_age = None

        w_real = count_p + w_replay
        w_norm = w_real if part.debias == "realized" else jnp.asarray(
            svc_participation.expected_count(part, cfg.n_agents), jnp.float32)
        inv_w = svc_participation.safe_inv(w_norm)
        pf = svc_participation.participation_factor(cfg.n_agents, w_norm)

        gsum = jax.tree.map(lambda g: jnp.sum(g, axis=0), gm)
        if ssum is not None:
            gsum = jax.tree.map(jnp.add, gsum, ssum)
        mean_grad = jax.tree.map(lambda s: s * inv_w, gsum)

        if ota_cfg is None:
            gain_mean = jnp.ones(())
            update = mean_grad
        else:
            key_h, _ = jax.random.split(key_chan)
            h = ota.sample_gains(ota_cfg, key_h, cfg.n_agents)
            hm = jnp.where(mask, h, jnp.zeros_like(h))
            # passing key_chan reproduces the plain round's AWGN stream:
            # aggregate re-splits it to the same key_n internally
            u_fresh = ota.aggregate(gm, ota_cfg, key=key_chan, gains=hm,
                                    backend=ota_backend)[0]
            update = jax.tree.map(lambda u: u * pf, u_fresh)
            if ssum is not None:
                update = jax.tree.map(
                    lambda u, s: u + s * inv_w, update, ssum)
            gain_mean = jnp.sum(hm) * svc_participation.safe_inv(count_p)
        theta_next = jax.tree.map(
            lambda p, u: p - cfg.alpha * u, theta, update)

        # metrics over the agents that actually made the round
        returns = discounted_return(trajs.losses, cfg.gamma)
        reward = -jnp.sum(jnp.where(mask[:, None], returns, 0.0)) \
            * svc_participation.safe_inv(count_p) / cfg.batch_m
        grad_sq = tree_global_norm_sq(mean_grad)

        state_next = state._replace(theta=theta_next,
                                    round_idx=state.round_idx + 1,
                                    stale=stale_next)
        if telem is None:
            return state_next, (reward, grad_sq, gain_mean)

        probes = _probes.stacked_round_probes(
            telem, grads_stacked=gm, gains=hm if ota_cfg is not None else mf,
            ota_cfg=ota_cfg, n_agents=cfg.n_agents, gain_mean=gain_mean,
            update_norm=jnp.sqrt(tree_global_norm_sq(update)))
        probes = _probes.participation_probes(
            telem, probes, rate_realized=count_p / cfg.n_agents,
            rate_expected=svc_participation.expected_count(
                part, cfg.n_agents) / cfg.n_agents,
            staleness_mean=stale_age)
        return state_next, (reward, grad_sq, gain_mean, probes)

    return service_round


def _make_streamed_round_fn(
    env, policy, cfg: FedPGConfig, ota_cfg: Optional[OTAConfig],
    agent_blocks: int, ota_backend: str = "auto",
    telemetry: Optional[TelemetryConfig] = None,
    participation: Optional[ParticipationConfig] = None,
    staleness: Optional[StalenessConfig] = None,
):
    """The vmap round evaluated as a blocked scan over the agent axis.

    Each scan step rolls out one block of ``agent_blocks`` agents (a vmap
    *within* the block), folds their gradients into the running exact-mean
    and channel-superposition accumulators (strict sequential folds — see
    :func:`repro.core.ota.stream_fold_block`) and emits only O(block)
    per-agent scalars (returns, probe norms) as scan outputs.  Peak memory
    is therefore O(agent_blocks × d) in the fleet size.  Sampling keys and
    channel gains are indexed by absolute agent index — the same
    ``split(key_samp, N)`` / ``sample_gains(key_h, N)`` streams as the
    unblocked round — so the emitted history is bitwise-invariant to the
    choice of block size.
    """
    from repro.rl.sampler import discounted_return

    grad_fn = _estimator_grad(cfg)
    hetero = isinstance(env, HeterogeneousEnv)
    if hetero:
        check_agent_count(env, cfg.n_agents)
    n_blocks, block, pad = ota.blocked_layout(cfg.n_agents, agent_blocks)
    noisy = ota_cfg is not None
    spec = ota._make_spec(ota_cfg, None, False, ota_backend)
    pallas = not spec.exact and spec.resolved_backend() == "pallas"
    wire_dt = ota._wire_dtype(ota_cfg) if pallas else None
    want_norms = telemetry is not None and (
        telemetry.grad_norms or telemetry.dispersion)

    def round_fn(theta: PyTree, key: jax.Array):
        key_samp, key_chan = jax.random.split(key)
        agent_keys = jax.random.split(key_samp, cfg.n_agents)
        lane_stacks = dict(env.params) if hetero else {}
        xs = {
            "keys": ota.block_view(
                ota.pad_agent_axis(agent_keys, pad), n_blocks, block),
            "stacks": ota.block_view(
                ota.pad_agent_axis(lane_stacks, pad), n_blocks, block),
            "valid": ota.block_valid_mask(cfg.n_agents, n_blocks, block),
        }
        if noisy:
            key_h, key_n = jax.random.split(key_chan)
            h = ota.sample_gains(ota_cfg, key_h, cfg.n_agents)
            hp = jnp.concatenate([h, jnp.zeros((pad,), h.dtype)]) \
                if pad else h
            xs["gains"] = hp.reshape(n_blocks, block)

        def agent_grad(k, lane_params):
            e = env.lane(lane_params) if hetero else env
            traj = rollout_batch(e, policy, theta, k, cfg.horizon, cfg.batch_m)
            return grad_fn(policy, theta, traj, cfg.gamma), traj

        def block_body(carry, x):
            grads_b, trajs_b = jax.vmap(agent_grad)(x["keys"], x["stacks"])
            gsum = ota.stream_fold_block(carry[0], grads_b, None, x["valid"])
            ys = {"returns": discounted_return(trajs_b.losses, cfg.gamma)}
            if want_norms:
                ys["norms_sq"] = sum(
                    _probes._leaf_norms(g, block)
                    for g in jax.tree.leaves(grads_b))
            if not noisy:
                return (gsum,), ys
            gb = jax.tree.map(lambda a: a.astype(jnp.float32), grads_b) \
                if pallas else grads_b
            v = ota.stream_fold_block(carry[1], gb, x["gains"], x["valid"],
                                      wire_dtype=wire_dt)
            return (gsum, v), ys

        carry0 = (jax.tree.map(jnp.zeros_like, theta),)
        if noisy:
            vdt = (lambda p: jnp.float32) if pallas else (lambda p: p.dtype)
            carry0 += (jax.tree.map(
                lambda p: jnp.zeros(p.shape, vdt(p)), theta),)
        carry, ys = jax.lax.scan(block_body, carry0, xs)

        # per-agent scalars come back (n_blocks, block, ...); restore the
        # absolute agent order and drop the phantom tail before reducing
        # with the exact ops the unblocked round uses.
        returns = ys["returns"].reshape(
            (n_blocks * block,) + ys["returns"].shape[2:])[:cfg.n_agents]
        reward = -jnp.mean(returns)
        mean_grad = jax.tree.map(lambda s: s / cfg.n_agents, carry[0])
        grad_sq = tree_global_norm_sq(mean_grad)

        if not noisy:
            gain_mean = jnp.ones(())
            theta_next = jax.tree.map(
                lambda p, u: p - cfg.alpha * u, theta, mean_grad)
        else:
            theta_next = ota.stream_finalize_apply(
                ota_cfg, key_n, carry[1], theta, cfg.alpha, cfg.n_agents,
                backend="pallas" if pallas else "xla")
            gain_mean = jnp.mean(h)

        if telemetry is None:
            return theta_next, (reward, grad_sq, gain_mean)

        if not noisy:
            update_norm = jnp.sqrt(grad_sq)
        else:
            update_norm = jnp.sqrt(tree_global_norm_sq(jax.tree.map(
                jnp.subtract, theta, theta_next))) / cfg.alpha
        norms_sq = ys["norms_sq"].reshape(-1)[:cfg.n_agents] \
            if want_norms else None
        probes = _probes.streamed_round_probes(
            telemetry, v=carry[1] if noisy else None, norms_sq=norms_sq,
            ota_cfg=ota_cfg, n_agents=cfg.n_agents,
            param_dim=sum(int(p.size) for p in jax.tree.leaves(theta)),
            gain_mean=gain_mean, update_norm=update_norm)
        return theta_next, (reward, grad_sq, gain_mean, probes)

    part, stale_cfg = participation, staleness
    if part is None:
        return round_fn

    def service_round(state: ServiceState, key: jax.Array):
        # the mask, replay weights and every normaliser scalar derive from
        # (N,) vectors computed BEFORE the block scan — identical across
        # block sizes, so the streamed service round inherits the blocked
        # round's bitwise block-invariance.
        theta = state.theta
        key_samp, key_chan = jax.random.split(key)
        agent_keys = jax.random.split(key_samp, cfg.n_agents)
        lane_stacks = dict(env.params) if hetero else {}
        ids = jnp.arange(cfg.n_agents, dtype=jnp.int32)
        mask = svc_participation.round_mask(
            part, state.part_key, state.sched_key, state.round_idx, ids,
            cfg.n_agents)
        mf = mask.astype(jnp.float32)
        count_p = jnp.sum(mf)

        if stale_cfg is not None:
            rw = svc_staleness.replay_weights(stale_cfg, mask, state.stale.age)
            w_replay, _, stale_age = svc_staleness.stats(
                stale_cfg, mask, state.stale.age)
        else:
            rw = None
            w_replay = jnp.zeros((), jnp.float32)
            stale_age = None
        w_real = count_p + w_replay
        w_norm = w_real if part.debias == "realized" else jnp.asarray(
            svc_participation.expected_count(part, cfg.n_agents), jnp.float32)
        inv_w = svc_participation.safe_inv(w_norm)

        def _pad_row(a):
            return jnp.concatenate([a, jnp.zeros((pad,), a.dtype)]) \
                if pad else a

        xs = {
            "keys": ota.block_view(
                ota.pad_agent_axis(agent_keys, pad), n_blocks, block),
            "stacks": ota.block_view(
                ota.pad_agent_axis(lane_stacks, pad), n_blocks, block),
            "valid": ota.block_valid_mask(cfg.n_agents, n_blocks, block),
            "pmask": _pad_row(mf).reshape(n_blocks, block),
        }
        if noisy:
            key_h, key_n = jax.random.split(key_chan)
            h = ota.sample_gains(ota_cfg, key_h, cfg.n_agents)
            hm = jnp.where(mask, h, jnp.zeros_like(h))
            xs["gains"] = _pad_row(hm).reshape(n_blocks, block)
        if stale_cfg is not None:
            xs["stale"] = ota.block_view(
                ota.pad_agent_axis(state.stale.grads, pad), n_blocks, block)
            xs["rw"] = _pad_row(rw).reshape(n_blocks, block)

        def agent_grad(k, lane_params):
            e = env.lane(lane_params) if hetero else env
            traj = rollout_batch(e, policy, theta, k, cfg.horizon, cfg.batch_m)
            return grad_fn(policy, theta, traj, cfg.gamma), traj

        def block_body(carry, x):
            grads_b, trajs_b = jax.vmap(agent_grad)(x["keys"], x["stacks"])
            out = {"gsum": ota.stream_fold_block(
                carry["gsum"], grads_b, x["pmask"], x["valid"])}
            ys = {"returns": discounted_return(trajs_b.losses, cfg.gamma)}
            if want_norms:
                ys["norms_sq"] = sum(
                    _probes._leaf_norms(g, block)
                    for g in jax.tree.leaves(grads_b))
            if stale_cfg is not None:
                out["ssum"] = ota.stream_fold_block(
                    carry["ssum"], x["stale"], x["rw"], x["valid"])
                pm = x["pmask"] > 0
                ys["stale_new"] = jax.tree.map(
                    lambda fresh, old: jnp.where(
                        pm.reshape((-1,) + (1,) * (fresh.ndim - 1)),
                        fresh, old),
                    grads_b, x["stale"])
            if noisy:
                gb = jax.tree.map(
                    lambda a: a.astype(jnp.float32), grads_b) \
                    if pallas else grads_b
                out["v"] = ota.stream_fold_block(
                    carry["v"], gb, x["gains"], x["valid"],
                    wire_dtype=wire_dt)
            return out, ys

        carry0 = {"gsum": jax.tree.map(jnp.zeros_like, theta)}
        if stale_cfg is not None:
            carry0["ssum"] = jax.tree.map(jnp.zeros_like, theta)
        if noisy:
            vdt = (lambda p: jnp.float32) if pallas else (lambda p: p.dtype)
            carry0["v"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, vdt(p)), theta)
        carry, ys = jax.lax.scan(block_body, carry0, xs)

        gsum = carry["gsum"]
        if stale_cfg is not None:
            gsum = jax.tree.map(jnp.add, gsum, carry["ssum"])
        mean_grad = jax.tree.map(lambda s: s * inv_w, gsum)
        grad_sq = tree_global_norm_sq(mean_grad)

        if not noisy:
            gain_mean = jnp.ones(())
            update = mean_grad
        else:
            update = ota.stream_finalize(
                ota_cfg, key_n, carry["v"], cfg.n_agents,
                backend="pallas" if pallas else "xla", n_eff=w_norm)
            if stale_cfg is not None:
                update = jax.tree.map(
                    lambda u, s: u + s * inv_w, update, carry["ssum"])
            gain_mean = jnp.sum(hm) * svc_participation.safe_inv(count_p)
        theta_next = jax.tree.map(
            lambda p, u: p - cfg.alpha * u, theta, update)

        returns = ys["returns"].reshape(
            (n_blocks * block,) + ys["returns"].shape[2:])[:cfg.n_agents]
        reward = -jnp.sum(jnp.where(mask[:, None], returns, 0.0)) \
            * svc_participation.safe_inv(count_p) / cfg.batch_m

        if stale_cfg is not None:
            buf = jax.tree.map(
                lambda s: s.reshape(
                    (n_blocks * block,) + s.shape[2:])[:cfg.n_agents],
                ys["stale_new"])
            age = jnp.where(mask, jnp.int32(1),
                            jnp.minimum(state.stale.age + 1,
                                        svc_staleness.AGE_NEVER))
            stale_next = svc_staleness.StaleState(grads=buf, age=age)
        else:
            stale_next = None
        state_next = state._replace(theta=theta_next,
                                    round_idx=state.round_idx + 1,
                                    stale=stale_next)
        if telemetry is None:
            return state_next, (reward, grad_sq, gain_mean)

        norms_sq = jnp.where(
            mask, ys["norms_sq"].reshape(-1)[:cfg.n_agents], 0.0) \
            if want_norms else None
        probes = _probes.streamed_round_probes(
            telemetry, v=carry["v"] if noisy else None, norms_sq=norms_sq,
            ota_cfg=ota_cfg, n_agents=cfg.n_agents,
            param_dim=sum(int(p.size) for p in jax.tree.leaves(theta)),
            gain_mean=gain_mean,
            update_norm=jnp.sqrt(tree_global_norm_sq(update)))
        probes = _probes.participation_probes(
            telemetry, probes, rate_realized=count_p / cfg.n_agents,
            rate_expected=svc_participation.expected_count(
                part, cfg.n_agents) / cfg.n_agents,
            staleness_mean=stale_age)
        return state_next, (reward, grad_sq, gain_mean, probes)

    return service_round


def _make_agent_sharded_round_fn(
    env, policy, cfg: FedPGConfig, ota_cfg: Optional[OTAConfig],
    mesh, axis_name: str, ota_backend: str = "auto",
    telemetry: Optional[TelemetryConfig] = None,
    agent_blocks: Optional[int] = None,
    participation: Optional[ParticipationConfig] = None,
    staleness: Optional[StalenessConfig] = None,
):
    """The agent axis laid across ``mesh[axis_name]`` via shard_map.

    Each shard vmaps over its ``n_local = n_agents / axis_size`` agents;
    per-agent env stacks and sampling keys enter with ``P(axis_name)`` specs
    so shard_map hands every shard exactly its fleet slice.  The uplink is
    the psum form (``ota.aggregate`` with ``local_stack=True``); metrics
    psum local partial sums, so every shard ends the round with identical
    (replicated) theta and metrics.

    With ``agent_blocks`` each shard consumes its local slice as a blocked
    scan (strict sequential folds, O(agent_blocks × d) peak memory per
    shard) and the partial sums psum across the mesh.  A non-dividing
    ``n_agents`` is then handled by padding the global stacks to
    ``ceil(N / n_shards) * n_shards`` with masked phantom agents — their
    gains and gradients fold exact zeros and every normaliser (reward,
    gain mean, debias) uses the true agent count.  Without ``agent_blocks``
    a non-dividing fleet still raises.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.rl.sampler import discounted_return

    grad_fn = _estimator_grad(cfg)
    hetero = isinstance(env, HeterogeneousEnv)
    if hetero:
        check_agent_count(env, cfg.n_agents)
    if axis_name not in mesh.shape:
        raise ValueError(
            f"agent mesh has no axis {axis_name!r}; axes are "
            f"{tuple(mesh.axis_names)}")
    part, stale_cfg = participation, staleness
    if stale_cfg is not None:
        raise ValueError(
            "staleness replay does not compose with agent_mesh: the stale "
            "buffer is absolute-agent-indexed carried state and the mesh "
            "round carries only replicated theta (use agent_blocks without "
            "a mesh, or staleness=None)")
    if part is not None and agent_blocks is None:
        raise ValueError(
            "participation under agent_mesh needs agent_blocks: the "
            "service round reuses the streamed shard path's phantom-agent "
            "masking (any block size works, e.g. agent_blocks=n_local)")
    n_shards = mesh.shape[axis_name]
    if cfg.n_agents % n_shards != 0 and agent_blocks is None:
        raise ValueError(
            f"n_agents={cfg.n_agents} does not divide across the "
            f"{axis_name!r} mesh axis of size {n_shards}; pass agent_blocks "
            "to run with a masked phantom-agent tail instead")
    n_local = -(-cfg.n_agents // n_shards)
    pad_total = n_local * n_shards - cfg.n_agents

    def local_round(theta, agent_keys, lane_stacks, key_chan):
        # agent_keys/lane_stacks are this shard's (n_local,)-leading slices
        def agent_grad(k, lane_params):
            e = env.lane(lane_params) if hetero else env
            traj = rollout_batch(e, policy, theta, k, cfg.horizon, cfg.batch_m)
            return grad_fn(policy, theta, traj, cfg.gamma), traj

        grads, trajs = jax.vmap(agent_grad)(agent_keys, lane_stacks)
        mean_grad = ota.aggregate(
            grads, None, axis=(axis_name,), n_agents=cfg.n_agents,
            local_stack=True)[0]

        if ota_cfg is None:
            update = mean_grad
            gain_mean = jnp.ones(())
        else:
            update, h = ota.aggregate(
                grads, ota_cfg, key=key_chan, axis=(axis_name,),
                n_agents=cfg.n_agents, local_stack=True,
                backend=ota_backend)
            gain_mean = jax.lax.psum(jnp.sum(h), axis_name) / cfg.n_agents
        theta_next = jax.tree.map(lambda p, u: p - cfg.alpha * u, theta, update)

        # metrics: psum of local partial sums == the global means
        r_local = -jnp.sum(discounted_return(trajs.losses, cfg.gamma))
        reward = jax.lax.psum(r_local, axis_name) / (cfg.n_agents * cfg.batch_m)
        grad_sq = tree_global_norm_sq(mean_grad)
        if telemetry is None:
            return theta_next, (reward, grad_sq, gain_mean)

        # telemetry probes: psum/pmax reductions, replicated outputs
        n_local = jax.tree.leaves(grads)[0].shape[0]
        local_gains = h if ota_cfg is not None else jnp.ones((n_local,))
        probes = _probes.sharded_round_probes(
            telemetry, local_grads=grads, local_gains=local_gains,
            ota_cfg=ota_cfg, n_agents=cfg.n_agents, axis_name=axis_name,
            gain_mean=gain_mean,
            update_norm=jnp.sqrt(tree_global_norm_sq(update)))
        return theta_next, (reward, grad_sq, gain_mean, probes)

    if agent_blocks is not None:
        nb, blk, bpad = ota.blocked_layout(n_local, agent_blocks)
    want_norms = telemetry is not None and (
        telemetry.grad_norms or telemetry.dispersion)

    def local_round_streamed(theta, agent_keys, lane_stacks, key_chan):
        # agent_keys/lane_stacks are this shard's (n_local,)-leading slices
        # of the globally padded stacks; rows whose global agent index is
        # >= n_agents are masked phantoms.
        def agent_grad(k, lane_params):
            e = env.lane(lane_params) if hetero else env
            traj = rollout_batch(e, policy, theta, k, cfg.horizon, cfg.batch_m)
            return grad_fn(policy, theta, traj, cfg.gamma), traj

        _, valid_local = ota._sharded_stream_meta(
            (axis_name,), n_local, cfg.n_agents)
        if ota_cfg is not None:
            key_h, key_n = jax.random.split(key_chan)
            h, valid_local = ota.sharded_stream_gains(
                ota_cfg, key_h, (axis_name,), n_local, cfg.n_agents)

        vp = jnp.concatenate([valid_local, jnp.zeros((bpad,), bool)]) \
            if bpad else valid_local
        xs = {
            "keys": ota.block_view(
                ota.pad_agent_axis(agent_keys, bpad), nb, blk),
            "stacks": ota.block_view(
                ota.pad_agent_axis(lane_stacks, bpad), nb, blk),
            "valid": vp.reshape(nb, blk),
        }
        if ota_cfg is not None:
            hp = jnp.concatenate([h, jnp.zeros((bpad,), h.dtype)]) \
                if bpad else h
            xs["gains"] = hp.reshape(nb, blk)

        def block_body(carry, x):
            grads_b, trajs_b = jax.vmap(agent_grad)(x["keys"], x["stacks"])
            gsum = ota.stream_fold_block(carry[0], grads_b, None, x["valid"])
            ys = {"returns": discounted_return(trajs_b.losses, cfg.gamma)}
            if want_norms:
                ys["norms_sq"] = sum(
                    _probes._leaf_norms(g, blk)
                    for g in jax.tree.leaves(grads_b))
            if ota_cfg is None:
                return (gsum,), ys
            v = ota.stream_fold_block(carry[1], grads_b, x["gains"],
                                      x["valid"])
            return (gsum, v), ys

        carry0 = (jax.tree.map(jnp.zeros_like, theta),)
        if ota_cfg is not None:
            carry0 += (jax.tree.map(jnp.zeros_like, theta),)
        carry, ys = jax.lax.scan(block_body, carry0, xs)

        mean_grad = jax.tree.map(
            lambda s: jax.lax.psum(s, axis_name) / cfg.n_agents, carry[0])
        v_global = None
        if ota_cfg is None:
            update = mean_grad
            gain_mean = jnp.ones(())
        else:
            v_global = jax.tree.map(
                lambda s: jax.lax.psum(s, axis_name), carry[1])
            update = ota.stream_finalize(ota_cfg, key_n, v_global,
                                         cfg.n_agents)
            gain_mean = jax.lax.psum(jnp.sum(h), axis_name) / cfg.n_agents
        theta_next = jax.tree.map(
            lambda p, u: p - cfg.alpha * u, theta, update)

        # metrics: restore absolute local order, mask phantoms, psum
        returns = ys["returns"].reshape(
            (nb * blk,) + ys["returns"].shape[2:])[:n_local]
        r_local = -jnp.sum(jnp.where(valid_local[:, None], returns, 0.0))
        reward = jax.lax.psum(r_local, axis_name) / (cfg.n_agents * cfg.batch_m)
        grad_sq = tree_global_norm_sq(mean_grad)
        if telemetry is None:
            return theta_next, (reward, grad_sq, gain_mean)

        norms_sq = ys["norms_sq"].reshape(-1)[:n_local] if want_norms \
            else None
        probes = _probes.sharded_streamed_round_probes(
            telemetry, v=v_global, local_norms_sq=norms_sq,
            valid_local=valid_local, ota_cfg=ota_cfg, n_agents=cfg.n_agents,
            axis_name=axis_name,
            param_dim=sum(int(p.size) for p in jax.tree.leaves(theta)),
            gain_mean=gain_mean,
            update_norm=jnp.sqrt(tree_global_norm_sq(update)))
        return theta_next, (reward, grad_sq, gain_mean, probes)

    def local_round_streamed_svc(theta, agent_keys, lane_stacks, key_chan,
                                 round_idx, part_key, sched_key):
        # the streamed shard body with a participation mask: each shard
        # derives its rows of the GLOBAL mask from absolute agent indices
        # (the same counter-PRNG scheme as ``sharded_stream_gains``), so
        # the realised mask is invariant to the mesh layout and blocking.
        def agent_grad(k, lane_params):
            e = env.lane(lane_params) if hetero else env
            traj = rollout_batch(e, policy, theta, k, cfg.horizon, cfg.batch_m)
            return grad_fn(policy, theta, traj, cfg.gamma), traj

        _, valid_local = ota._sharded_stream_meta(
            (axis_name,), n_local, cfg.n_agents)
        idx, _ = ota._flat_axis_index((axis_name,))
        gids = idx * n_local + jnp.arange(n_local, dtype=jnp.int32)
        mask_local = jnp.logical_and(
            svc_participation.round_mask(part, part_key, sched_key,
                                         round_idx, gids, cfg.n_agents),
            valid_local)
        mf_local = mask_local.astype(jnp.float32)
        count_p = jax.lax.psum(jnp.sum(mf_local), axis_name)
        w_norm = count_p if part.debias == "realized" else jnp.asarray(
            svc_participation.expected_count(part, cfg.n_agents), jnp.float32)
        inv_w = svc_participation.safe_inv(w_norm)

        if ota_cfg is not None:
            key_h, key_n = jax.random.split(key_chan)
            h, valid_local = ota.sharded_stream_gains(
                ota_cfg, key_h, (axis_name,), n_local, cfg.n_agents)
            hm = jnp.where(mask_local, h, jnp.zeros_like(h))

        def _pad_row(a):
            return jnp.concatenate(
                [a, jnp.zeros((bpad,), a.dtype)]) if bpad else a

        vp = _pad_row(valid_local)
        xs = {
            "keys": ota.block_view(
                ota.pad_agent_axis(agent_keys, bpad), nb, blk),
            "stacks": ota.block_view(
                ota.pad_agent_axis(lane_stacks, bpad), nb, blk),
            "valid": vp.reshape(nb, blk),
            "pmask": _pad_row(mf_local).reshape(nb, blk),
        }
        if ota_cfg is not None:
            xs["gains"] = _pad_row(hm).reshape(nb, blk)

        def block_body(carry, x):
            grads_b, trajs_b = jax.vmap(agent_grad)(x["keys"], x["stacks"])
            gsum = ota.stream_fold_block(carry[0], grads_b, x["pmask"],
                                         x["valid"])
            ys = {"returns": discounted_return(trajs_b.losses, cfg.gamma)}
            if want_norms:
                ys["norms_sq"] = sum(
                    _probes._leaf_norms(g, blk)
                    for g in jax.tree.leaves(grads_b))
            if ota_cfg is None:
                return (gsum,), ys
            v = ota.stream_fold_block(carry[1], grads_b, x["gains"],
                                      x["valid"])
            return (gsum, v), ys

        carry0 = (jax.tree.map(jnp.zeros_like, theta),)
        if ota_cfg is not None:
            carry0 += (jax.tree.map(jnp.zeros_like, theta),)
        carry, ys = jax.lax.scan(block_body, carry0, xs)

        mean_grad = jax.tree.map(
            lambda s: jax.lax.psum(s, axis_name) * inv_w, carry[0])
        v_global = None
        if ota_cfg is None:
            update = mean_grad
            gain_mean = jnp.ones(())
        else:
            v_global = jax.tree.map(
                lambda s: jax.lax.psum(s, axis_name), carry[1])
            update = ota.stream_finalize(ota_cfg, key_n, v_global,
                                         cfg.n_agents, n_eff=w_norm)
            gain_mean = jax.lax.psum(jnp.sum(hm), axis_name) \
                * svc_participation.safe_inv(count_p)
        theta_next = jax.tree.map(
            lambda p, u: p - cfg.alpha * u, theta, update)

        returns = ys["returns"].reshape(
            (nb * blk,) + ys["returns"].shape[2:])[:n_local]
        r_local = -jnp.sum(jnp.where(mask_local[:, None], returns, 0.0))
        reward = jax.lax.psum(r_local, axis_name) \
            * svc_participation.safe_inv(count_p) / cfg.batch_m
        grad_sq = tree_global_norm_sq(mean_grad)
        if telemetry is None:
            return theta_next, (reward, grad_sq, gain_mean)

        norms_sq = ys["norms_sq"].reshape(-1)[:n_local] if want_norms \
            else None
        probes = _probes.sharded_streamed_round_probes(
            telemetry, v=v_global, local_norms_sq=norms_sq,
            valid_local=mask_local, ota_cfg=ota_cfg, n_agents=cfg.n_agents,
            axis_name=axis_name,
            param_dim=sum(int(p.size) for p in jax.tree.leaves(theta)),
            gain_mean=gain_mean,
            update_norm=jnp.sqrt(tree_global_norm_sq(update)))
        probes = _probes.participation_probes(
            telemetry, probes, rate_realized=count_p / cfg.n_agents,
            rate_expected=svc_participation.expected_count(
                part, cfg.n_agents) / cfg.n_agents)
        return theta_next, (reward, grad_sq, gain_mean, probes)

    def round_fn(theta: PyTree, key: jax.Array):
        key_samp, key_chan = jax.random.split(key)
        agent_keys = jax.random.split(key_samp, cfg.n_agents)
        lane_stacks = dict(env.params) if hetero else {}
        if agent_blocks is not None and pad_total:
            agent_keys = ota.pad_agent_axis(agent_keys, pad_total)
            lane_stacks = ota.pad_agent_axis(lane_stacks, pad_total)
        stack_specs = jax.tree.map(lambda _: P(axis_name), lane_stacks)
        metric_specs = (P(), P(), P())
        if telemetry is not None:
            metric_specs += (RoundTelemetry(P(), P(), P(), P(), P()),)
        body = local_round_streamed if agent_blocks is not None \
            else local_round
        return shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(axis_name), stack_specs, P()),
            out_specs=(P(), metric_specs),
            check_rep=False,
        )(theta, agent_keys, lane_stacks, key_chan)

    if part is None:
        return round_fn

    def service_round(state: ServiceState, key: jax.Array):
        theta = state.theta
        key_samp, key_chan = jax.random.split(key)
        agent_keys = jax.random.split(key_samp, cfg.n_agents)
        lane_stacks = dict(env.params) if hetero else {}
        if pad_total:
            agent_keys = ota.pad_agent_axis(agent_keys, pad_total)
            lane_stacks = ota.pad_agent_axis(lane_stacks, pad_total)
        stack_specs = jax.tree.map(lambda _: P(axis_name), lane_stacks)
        metric_specs = (P(), P(), P())
        if telemetry is not None:
            metric_specs += (RoundTelemetry(P(), P(), P(), P(), P())._replace(
                participation_rate=P(), participation_drift=P()),)
        theta_next, metrics = shard_map(
            local_round_streamed_svc, mesh=mesh,
            in_specs=(P(), P(axis_name), stack_specs, P(), P(), P(), P()),
            out_specs=(P(), metric_specs),
            check_rep=False,
        )(theta, agent_keys, lane_stacks, key_chan, state.round_idx,
          state.part_key, state.sched_key)
        state_next = state._replace(theta=theta_next,
                                    round_idx=state.round_idx + 1)
        return state_next, metrics

    return service_round


def run(
    env,
    policy,
    cfg: FedPGConfig,
    key: jax.Array,
    *,
    ota: Optional[OTAConfig] = None,
    theta0: Optional[PyTree] = None,
    agent_mesh=None,
    agent_axis: str = "agents",
    ota_backend: str = "auto",
    telemetry: Optional[TelemetryConfig] = None,
    agent_blocks: Optional[int] = None,
    participation: Optional[ParticipationConfig] = None,
    staleness: Optional[StalenessConfig] = None,
):
    """Run K rounds; returns (theta_K, History).

    ``ota=None`` is Algorithm 1 (exact aggregation); an ``OTAConfig`` is
    Algorithm 2 over the configured channel.  ``agent_mesh`` shards the
    agent axis across a device mesh (see :func:`make_round_fn`) — use
    ``repro.core.distribute.agent_mesh_for`` to build one.  ``ota_backend``
    routes the uplink ("xla" | "pallas" | "auto").  ``telemetry`` (active
    probes) fills ``History.telemetry`` with ``(K,)``-leaved round probes.
    ``agent_blocks`` streams the agent axis in blocked-scan chunks of that
    many agents — O(agent_blocks × d) peak memory, history bitwise-invariant
    to the block size (see :func:`make_round_fn`).  ``participation`` /
    ``staleness`` run the rounds as *service* rounds (partial agent
    participation, stale-gradient replay — see :mod:`repro.service`); a
    config that normalises away (full participation, ``max_age=0``) emits
    the byte-identical plain program.
    """
    part = svc_participation.normalize(participation, cfg.n_agents)
    stale_cfg = svc_staleness.normalize(staleness, part)
    round_fn = make_round_fn(env, policy, cfg, ota,
                             agent_mesh=agent_mesh, agent_axis=agent_axis,
                             ota_backend=ota_backend, telemetry=telemetry,
                             agent_blocks=agent_blocks,
                             participation=part, staleness=stale_cfg)
    if part is not None:
        key_init, key_scan, key_svc = jax.random.split(key, 3)
        theta = policy.init(key_init) if theta0 is None else theta0
        state0 = svc_participation.init_state(theta, key_svc, cfg.n_agents,
                                              stale_cfg)
        keys = jax.random.split(key_scan, cfg.n_rounds)
        state, metrics = jax.lax.scan(round_fn, state0, keys)
        theta = state.theta
    else:
        key_init, key_scan = jax.random.split(key)
        theta = policy.init(key_init) if theta0 is None else theta0

        def body(carry, key_k):
            theta = carry
            theta, metrics = round_fn(theta, key_k)
            return theta, metrics

        keys = jax.random.split(key_scan, cfg.n_rounds)
        theta, metrics = jax.lax.scan(body, theta, keys)
    if len(metrics) == 4:
        rewards, grad_sq, gain_mean, probes = metrics
        return theta, History(rewards=rewards, grad_sq=grad_sq,
                              gain_mean=gain_mean, telemetry=probes)
    rewards, grad_sq, gain_mean = metrics
    return theta, History(rewards=rewards, grad_sq=grad_sq, gain_mean=gain_mean)


# ---------------------------------------------------------------------------
# Compiled-callable cache.  ``jax.jit`` caches per function object, so
# wrapping a fresh lambda on every run_jit/monte_carlo call used to recompile
# the whole training program from scratch each time.  The jitted closures are
# instead cached on the (hashable) argument tuple; configs with traced or
# otherwise unhashable fields fall back to a fresh closure.
# ---------------------------------------------------------------------------

# Bounded: each entry pins its compiled executable (and the captured
# env/policy) alive, so an unbounded cache would leak across a long
# hand-rolled parameter grid that bypasses the sweep engine.
_CACHE_SIZE = 64


# NOTE: the cache keys must include EVERY program-shaping argument of
# `run` — a key that omits one silently returns a stale compiled program
# for the other value.  Keep these signatures in lockstep with `run`.

@functools.lru_cache(maxsize=_CACHE_SIZE)
def _compiled_run(env, policy, cfg: FedPGConfig, ota_cfg, backend: str,
                  telemetry=None, agent_mesh=None, agent_axis: str = "agents",
                  agent_blocks=None, participation=None, staleness=None):
    return jax.jit(
        lambda k: run(env, policy, cfg, k, ota=ota_cfg, ota_backend=backend,
                      telemetry=telemetry, agent_mesh=agent_mesh,
                      agent_axis=agent_axis, agent_blocks=agent_blocks,
                      participation=participation, staleness=staleness))


@functools.lru_cache(maxsize=_CACHE_SIZE)
def _compiled_monte_carlo(env, policy, cfg: FedPGConfig, ota_cfg,
                          n_runs: int, backend: str, telemetry=None,
                          agent_mesh=None, agent_axis: str = "agents",
                          agent_blocks=None, participation=None,
                          staleness=None):
    return jax.jit(jax.vmap(
        lambda k: run(env, policy, cfg, k, ota=ota_cfg,
                      ota_backend=backend, telemetry=telemetry,
                      agent_mesh=agent_mesh, agent_axis=agent_axis,
                      agent_blocks=agent_blocks,
                      participation=participation, staleness=staleness)[1]))


# every compiled-program cache in the package; other modules (e.g.
# event_triggered) register theirs so one reset call clears them all
_COMPILED_CACHES = [_compiled_run, _compiled_monte_carlo]


def register_compiled_cache(cache) -> None:
    _COMPILED_CACHES.append(cache)


def clear_compilation_cache() -> None:
    """Drop every cached compiled program (mainly for tests) — including
    caches other modules registered via ``register_compiled_cache``."""
    for cache in _COMPILED_CACHES:
        cache.cache_clear()


def _hashable(*objs) -> bool:
    try:
        hash(objs)
        return True
    except TypeError:
        return False


def run_jit(env, policy, cfg: FedPGConfig, key, *, ota=None, theta0=None,
            ota_backend: str = "auto",
            telemetry: Optional[TelemetryConfig] = None,
            agent_mesh=None, agent_axis: str = "agents",
            agent_blocks: Optional[int] = None,
            participation: Optional[ParticipationConfig] = None,
            staleness: Optional[StalenessConfig] = None):
    """jit-compiled entry point (env/policy/cfgs are closure constants).

    Repeated calls with the same ``(env, policy, cfg, ota, ota_backend,
    telemetry, agent_mesh, agent_axis, agent_blocks, participation,
    staleness)`` reuse the compiled program (``theta0`` is a pytree and
    cannot key a cache, so passing one compiles fresh).  Caching needs
    every argument hashable: envs holding jax arrays (e.g. ``TabularMDP``)
    take the uncached path.  Participation/staleness configs are
    *normalised* before keying, so a full-participation config hits the
    same cache entry as ``None``.
    """
    participation = svc_participation.normalize(participation, cfg.n_agents)
    staleness = svc_staleness.normalize(staleness, participation)
    telemetry = _active_telemetry(telemetry, participation)
    if theta0 is None and _hashable(env, policy, cfg, ota, telemetry,
                                    agent_mesh, agent_axis, agent_blocks,
                                    participation, staleness):
        return _compiled_run(env, policy, cfg, ota, ota_backend, telemetry,
                             agent_mesh, agent_axis, agent_blocks,
                             participation, staleness)(key)
    fn = jax.jit(lambda k: run(env, policy, cfg, k, ota=ota, theta0=theta0,
                               ota_backend=ota_backend, telemetry=telemetry,
                               agent_mesh=agent_mesh, agent_axis=agent_axis,
                               agent_blocks=agent_blocks,
                               participation=participation,
                               staleness=staleness))
    return fn(key)


def avg_grad_sq(history: History) -> jax.Array:
    """The paper's reported quantity: (1/K) sum_k ||grad J(theta^k)||^2."""
    return jnp.mean(history.grad_sq)


def monte_carlo(
    env, policy, cfg: FedPGConfig, key: jax.Array, n_runs: int, *, ota=None,
    ota_backend: str = "auto",
    telemetry: Optional[TelemetryConfig] = None,
    agent_mesh=None, agent_axis: str = "agents",
    agent_blocks: Optional[int] = None,
    participation: Optional[ParticipationConfig] = None,
    staleness: Optional[StalenessConfig] = None,
):
    """n_runs independent repetitions (the paper uses 20): vmapped.

    Repeated calls with the same ``(env, policy, cfg, ota, n_runs,
    ota_backend, telemetry, agent_mesh, agent_axis, agent_blocks,
    participation, staleness)`` reuse the compiled program; only the PRNG
    keys change between calls.  Caching needs every argument hashable:
    envs holding jax arrays (e.g. ``TabularMDP``) take the uncached path.
    """
    participation = svc_participation.normalize(participation, cfg.n_agents)
    staleness = svc_staleness.normalize(staleness, participation)
    telemetry = _active_telemetry(telemetry, participation)
    keys = jax.random.split(key, n_runs)
    if _hashable(env, policy, cfg, ota, telemetry, agent_mesh, agent_axis,
                 agent_blocks, participation, staleness):
        return _compiled_monte_carlo(env, policy, cfg, ota, n_runs,
                                     ota_backend, telemetry, agent_mesh,
                                     agent_axis, agent_blocks,
                                     participation, staleness)(keys)
    fn = jax.jit(jax.vmap(
        lambda k: run(env, policy, cfg, k, ota=ota,
                      ota_backend=ota_backend, telemetry=telemetry,
                      agent_mesh=agent_mesh, agent_axis=agent_axis,
                      agent_blocks=agent_blocks,
                      participation=participation,
                      staleness=staleness)[1]))
    return fn(keys)
