"""Policy-gradient estimators: REINFORCE and mini-batch G(PO)MDP (Eq. 4).

G(PO)MDP [Baxter & Bartlett '01] weights each log-prob by the *discounted
loss-to-go* rather than the full return — the "causality trick":

    sum_t phi(t) gamma^t l_t  ==  sum_tau (grad log pi_tau) * sum_{t>=tau} gamma^t l_t

(phi(t) = sum_{tau<=t} grad log pi_tau), which is exactly Eq. (4) and has
strictly lower variance than REINFORCE.  Both estimators are implemented as
*surrogate losses* whose autodiff gradient equals the estimator, so they
compose with jax.grad / jax.vmap / shard_map and with the channel-weighted
OTA form.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.rl.sampler import Trajectory

PyTree = Any


def discounted_to_go(losses: jax.Array, gamma: float) -> jax.Array:
    """w_tau = sum_{t>=tau} gamma^t l_t (note: gamma^t, NOT gamma^{t-tau} —
    the paper's Eq. (4) keeps the absolute discounting).

    Works on the last axis; implemented as a reverse cumulative sum.
    """
    t = jnp.arange(losses.shape[-1], dtype=jnp.float32)
    disc = losses * gamma**t
    return jnp.flip(jnp.cumsum(jnp.flip(disc, -1), -1), -1)


def total_discounted(losses: jax.Array, gamma: float) -> jax.Array:
    t = jnp.arange(losses.shape[-1], dtype=jnp.float32)
    return jnp.sum(losses * gamma**t, axis=-1)


def _traj_logps(policy, params: PyTree, traj: Trajectory) -> jax.Array:
    """log pi(a_t | s_t; theta) along time (and any leading batch dims).

    Discrete actions are scalar per step; continuous policies (e.g.
    ``GaussianPolicy``) carry a trailing action-dim axis, which is flattened
    alongside the observation one.  ``traj.losses`` always has exactly the
    (batch..., time) shape, so it anchors both cases.
    """
    batch_time = traj.losses.shape
    flat_obs = traj.obs.reshape((-1, traj.obs.shape[-1]))
    if traj.actions.ndim > len(batch_time):  # vector (continuous) actions
        flat_act = traj.actions.reshape((-1, traj.actions.shape[-1]))
    else:
        flat_act = traj.actions.reshape((-1,))
    logps = jax.vmap(lambda o, a: policy.log_prob(params, o, a))(flat_obs, flat_act)
    return logps.reshape(batch_time)


def gpomdp_surrogate(
    policy, params: PyTree, traj: Trajectory, gamma: float,
    weights: jax.Array | None = None,
) -> jax.Array:
    """Scalar whose gradient is the mini-batch G(PO)MDP estimate (Eq. 4).

    ``traj`` may have arbitrary leading batch dims; the surrogate averages
    over them (the 1/M of Eq. 4).  ``weights`` (matching the leading batch
    dims) optionally reweights trajectories — this is the hook the
    channel-weighted OTA form uses (weight = h_{agent(m)}).
    """
    logps = _traj_logps(policy, params, traj)
    to_go = jax.lax.stop_gradient(discounted_to_go(traj.losses, gamma))
    per_traj = jnp.sum(logps * to_go, axis=-1)
    if weights is not None:
        per_traj = per_traj * weights
    return jnp.mean(per_traj)


def reinforce_surrogate(
    policy, params: PyTree, traj: Trajectory, gamma: float,
    weights: jax.Array | None = None,
) -> jax.Array:
    """REINFORCE surrogate: every log-prob weighted by the full return."""
    logps = _traj_logps(policy, params, traj)
    ret = jax.lax.stop_gradient(total_discounted(traj.losses, gamma))
    per_traj = jnp.sum(logps, axis=-1) * ret
    if weights is not None:
        per_traj = per_traj * weights
    return jnp.mean(per_traj)


def gpomdp_gradient(
    policy, params: PyTree, traj: Trajectory, gamma: float,
    weights: jax.Array | None = None,
) -> PyTree:
    """The estimator itself: grad_theta of the G(PO)MDP surrogate."""
    return jax.grad(
        lambda p: gpomdp_surrogate(policy, p, traj, gamma, weights)
    )(params)


def reinforce_gradient(
    policy, params: PyTree, traj: Trajectory, gamma: float,
    weights: jax.Array | None = None,
) -> PyTree:
    return jax.grad(
        lambda p: reinforce_surrogate(policy, p, traj, gamma, weights)
    )(params)
