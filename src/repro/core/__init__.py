"""Core contribution of the paper: over-the-air federated policy gradient.

Public API:
    channel   — fading-channel models (Rayleigh, Nakagami-m, ...) with exact
                (m_h, sigma_h^2) statistics used by the theory.
    ota       — the over-the-air aggregation primitive (Eq. 6-7), in three
                mathematically equivalent forms (stacked / shard_map-psum /
                channel-weighted-loss) plus the exact Algorithm-1 baseline.
    gpomdp    — REINFORCE and mini-batch G(PO)MDP gradient estimators (Eq. 4).
    theory    — smoothness constant L, bound constant V, Theorem 1/2 right-
                hand sides and Corollary 1 complexity calculators.
    fedpg     — Algorithm 1 (federated PG) and Algorithm 2 (OTA federated PG)
                training loops.
    power_control — transmit-power policies (truncated channel inversion).
    sweep     — batched scenario-sweep engine: a grid of (channel, noise,
                step-size, N, estimator, power-control) scenarios partitioned
                by structural shape and run as one jitted program each.
"""
from repro.core import (  # noqa: F401
    channel, fedpg, gpomdp, ota, power_control, sweep, theory,
)
