"""Core contribution of the paper: over-the-air federated policy gradient.

Public API:
    channel   — fading-channel models (Rayleigh, Nakagami-m, ...) with exact
                (m_h, sigma_h^2) statistics used by the theory.
    ota       — the over-the-air aggregation primitive (Eq. 6-7) behind one
                dispatcher: ``aggregate(grads, cfg, key=..., axis=...,
                backend=...)`` covers the stacked, shard_map-psum and exact
                (Algorithm-1, ``cfg=None``) forms, and ``aggregate_apply``
                fuses the server SGD step.  ``backend="pallas"`` routes the
                stacked form through the fused uplink kernel in
                ``repro.kernels.ota_fused`` (auto-selected on TPU); the
                legacy entry points survive as DeprecationWarning shims.
    gpomdp    — REINFORCE and mini-batch G(PO)MDP gradient estimators (Eq. 4).
    theory    — smoothness constant L, bound constant V, Theorem 1/2 right-
                hand sides and Corollary 1 complexity calculators.
    fedpg     — Algorithm 1 (federated PG) and Algorithm 2 (OTA federated PG)
                training loops; run_jit/monte_carlo cache their compiled
                programs keyed on (env, policy, cfg, ota, n_runs).
    power_control — transmit-power policies shaping the effective gain
                h = c * p(c): UnitPower, TruncatedInversion, FullInversion,
                ConstantReceived (phase-aware exact inversion), and
                HeterogeneousBudget (per-agent power budgets).  The
                effective-gain channel ControlledChannel is a first-class
                registry family ('controlled'); build it with
                make_controlled_channel, which fills the (m_h, sigma_h^2)
                moments — closed form for the inversion policies over
                Rayleigh (incomplete-gamma expressions), mixture moments for
                heterogeneous budgets, Monte Carlo fallback otherwise.
                Non-finite moments are rejected at OTAConfig/pack time.
    sweep     — batched scenario-sweep engine: a grid of (env, channel,
                noise, step-size, N, estimator, power-control) scenarios
                partitioned by structural shape and run as one jitted
                program each.  Power-control policy *type* is structural;
                its parameters (and ControlledChannel parameters) batch
                in-program, with per-lane debias normalisation from the
                *effective* moments.  The environment is a first-class axis
                too: the env *family* (registry kind tag from
                repro.rl.envs) is structural, continuous env parameters
                (wind, slip, Garnet P/l/rho tables) batch as lanes through
                the registry packer/builder hooks, and HeterogeneousEnv
                fleets give each federated agent its own dynamics inside
                one program (fedpg/event_triggered vmap the per-agent
                stacks).
    theory    — also: env_l_bar/constants_for_env derive the Assumption-1
                loss envelope from the env at the *actual* horizon
                (l_bar_for), so bound tables track the configured T.
    distribute — device-mesh execution layer under sweep(..., mode="sharded"):
                partition lane/MC axes lay across a ("lane", "mc") mesh via
                NamedSharding (uneven lane counts padded with masked
                replicate-lanes), partitions dispatch asynchronously with
                block_until_ready deferred to SweepResult materialisation,
                and results stay bit-identical to mode="vmap" (golden-trace
                + test_distribute harness).  agent_mesh_for builds the
                ("agents",) mesh for fedpg.run(..., agent_mesh=...), which
                runs each round's fleet in the production shard_map form
                (ota.aggregate with axis names) — HeterogeneousEnv stacks
                and per-agent power control shard with it.

The environment zoo itself (LandmarkNav variants, CliffWalk, LQR, Garnet
tabular MDPs, HeterogeneousEnv, register_env) lives in ``repro.rl.envs``.
Observability — in-jit round probes (``fedpg.run(...,
telemetry=TelemetryConfig())``), the span tracer behind sweep partition
timing, and the run ledger — lives in ``repro.telemetry``; telemetry off
emits programs bitwise identical to the pre-telemetry ones.
"""
from repro.core import (  # noqa: F401
    channel, distribute, event_triggered, fedpg, gpomdp, ota, power_control,
    sweep, theory,
)
