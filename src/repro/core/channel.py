"""Fading-channel models for over-the-air aggregation.

Each model samples the per-agent, per-round channel gain ``h_{i,k}`` of
Eq. (6) and exposes the exact first/second moments ``(m_h, sigma_h^2)`` the
convergence theory (Theorems 1 and 2) is stated in terms of.

The paper's two simulation settings are provided verbatim:

* ``RayleighChannel(scale=1)`` — m_h = sqrt(pi/2), sigma_h^2 = (4-pi)/2,
  which satisfies the Theorem-1 condition sigma_h^2 <= (N+1) m_h^2 for all N.
* ``NakagamiChannel(m=0.1, omega=1)`` — sigma_h^2 = 10 m_h^2, violating the
  Theorem-1 condition for small N; Theorem 2 applies.

``h_{i,k} = c_{i,k} * p_{i,k}`` (actual gain x transmit-power coefficient);
power control policies that shape p live in ``power_control.py``.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Channel:
    """Base class: a distribution over non-negative gains h."""

    def sample(self, key: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
        raise NotImplementedError

    @property
    def mean(self) -> float:  # m_h
        raise NotImplementedError

    @property
    def var(self) -> float:  # sigma_h^2
        raise NotImplementedError

    @property
    def second_moment(self) -> float:
        return self.var + self.mean**2

    def satisfies_theorem1(self, n_agents: int) -> bool:
        """The Theorem-1 channel condition sigma_h^2 <= (N+1) m_h^2."""
        return self.var <= (n_agents + 1) * self.mean**2


@dataclass(frozen=True)
class IdealChannel(Channel):
    """h == 1 deterministically: recovers exact (TDMA/FDMA) aggregation."""

    def sample(self, key: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
        return jnp.ones(shape, jnp.float32)

    @property
    def mean(self) -> float:
        return 1.0

    @property
    def var(self) -> float:
        return 0.0


@dataclass(frozen=True)
class FixedGainChannel(Channel):
    """h == gain deterministically (distortion without randomness)."""

    gain: float = 1.0

    def sample(self, key: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
        return jnp.full(shape, self.gain, jnp.float32)

    @property
    def mean(self) -> float:
        return self.gain

    @property
    def var(self) -> float:
        return 0.0


@dataclass(frozen=True)
class RayleighChannel(Channel):
    """Rayleigh(scale): pdf h/s^2 exp(-h^2/(2 s^2)).

    mean = s*sqrt(pi/2); var = (4-pi)/2 * s^2.  The paper uses s=1.
    """

    scale: float = 1.0

    def sample(self, key: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
        # If X, Y ~ N(0, s^2) iid then ||(X, Y)|| ~ Rayleigh(s).
        z = jax.random.normal(key, shape + (2,), jnp.float32)
        return self.scale * jnp.sqrt(jnp.sum(z * z, axis=-1))

    @property
    def mean(self) -> float:
        return self.scale * math.sqrt(math.pi / 2.0)

    @property
    def var(self) -> float:
        return (4.0 - math.pi) / 2.0 * self.scale**2


@dataclass(frozen=True)
class NakagamiChannel(Channel):
    """Nakagami-m *power* gain: h ~ Gamma(shape=m, scale=omega/m).

    The paper states "Nakagami-m channel with m=0.1 and Omega=1, which
    satisfies sigma_h^2 = 10 m_h^2" — that identity holds exactly for the
    squared-envelope (power) gain, h = |amplitude|^2 ~ Gamma(m, Omega/m):
    mean = Omega, var = Omega^2/m.  (The amplitude convention would give
    sigma_h^2 ~= 3.1 m_h^2 instead, contradicting the paper's Section IV.)
    """

    m: float = 0.1
    omega: float = 1.0

    def sample(self, key: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
        return jax.random.gamma(key, self.m, shape, jnp.float32) * (
            self.omega / self.m
        )

    @property
    def mean(self) -> float:
        return self.omega

    @property
    def var(self) -> float:
        return self.omega**2 / self.m


@dataclass(frozen=True)
class LogNormalChannel(Channel):
    """Log-normal shadowing: h = exp(mu + sigma Z). Beyond-paper extra."""

    mu: float = 0.0
    sigma: float = 0.25

    def sample(self, key: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
        z = jax.random.normal(key, shape, jnp.float32)
        return jnp.exp(self.mu + self.sigma * z)

    @property
    def mean(self) -> float:
        return math.exp(self.mu + self.sigma**2 / 2.0)

    @property
    def var(self) -> float:
        return (math.exp(self.sigma**2) - 1.0) * math.exp(2 * self.mu + self.sigma**2)


_REGISTRY: Dict[str, type] = {
    "ideal": IdealChannel,
    "fixed": FixedGainChannel,
    "rayleigh": RayleighChannel,
    "nakagami": NakagamiChannel,
    "lognormal": LogNormalChannel,
}

# Extension hooks for channel families whose parameters are not a flat tuple
# of floats (e.g. power-controlled effective-gain channels, whose dataclass
# nests a base channel and a policy).  Keyed by the *root* of the kind tag
# (the part before the first ':'):
#   packer(channels)                 -> Dict[str, np.ndarray]  (float64)
#   sampler(kind, params, key, shape)-> jax.Array
_BATCHED_PACKERS: Dict[str, Callable[..., Dict[str, np.ndarray]]] = {}
_BATCHED_SAMPLERS: Dict[str, Callable[..., jax.Array]] = {}


def register_channel(
    name: str,
    cls: type,
    *,
    packer: Callable[..., Dict[str, np.ndarray]] | None = None,
    sampler: Callable[..., jax.Array] | None = None,
) -> None:
    """Add a channel family to the registry (and the batched-sweep engine).

    ``packer``/``sampler`` are only needed when the dataclass fields are not
    all plain floats; a class may also define ``kind_tag()`` returning a
    refined structural tag (``'<name>:<...>'``) so that structurally
    incompatible members of the family land in separate sweep partitions.
    """
    _REGISTRY[name] = cls
    if packer is not None:
        _BATCHED_PACKERS[name] = packer
    if sampler is not None:
        _BATCHED_SAMPLERS[name] = sampler


# ---------------------------------------------------------------------------
# Batched adapter: channel parameters as (possibly traced) arrays.
# ---------------------------------------------------------------------------

def channel_kind(ch: Channel) -> str:
    """Reverse registry lookup: RayleighChannel() -> 'rayleigh'.

    Registered classes may refine their tag via ``kind_tag()`` (e.g.
    ``ControlledChannel`` -> ``'controlled:rayleigh:TruncatedInversion'``) so
    partitioning distinguishes structurally different members of one family.
    """
    for name, cls in _REGISTRY.items():
        if type(ch) is cls:
            tag = getattr(ch, "kind_tag", None)
            return tag() if callable(tag) else name
    raise ValueError(f"channel {type(ch).__name__} is not in the registry")


def batched_channel_arrays(
    channels: Sequence[Channel],
) -> Tuple[str, Dict[str, np.ndarray]]:
    """Stack a same-kind channel list into per-parameter float64 arrays.

    Returns ``(kind, params)`` where each ``params[name]`` has shape
    ``(len(channels),)``.  Besides the raw dataclass fields, derived scalars
    the sampler / theory need are precomputed here in float64 — so a
    ``BatchedChannel`` lane reproduces the concrete dataclass bit-for-bit
    instead of re-deriving them in float32 inside the trace:

    * ``_mean`` / ``_var``   — the exact moments (m_h, sigma_h^2);
    * ``_omega_over_m``      — the Nakagami Gamma scale Omega/m.

    Families with nested parameters (registered with a ``packer``) stack
    through their hook; for them the returned kind is the full composite tag.
    """
    kinds = {channel_kind(ch) for ch in channels}
    if len(kinds) != 1:
        raise ValueError(f"cannot batch across channel kinds {sorted(kinds)}")
    kind = kinds.pop()
    root = kind.split(":", 1)[0]
    if root in _BATCHED_PACKERS:
        params = _BATCHED_PACKERS[root](channels)
    else:
        names = [f.name for f in dataclasses.fields(channels[0])]
        params = {
            name: np.array([float(getattr(ch, name)) for ch in channels],
                           np.float64)
            for name in names
        }
        if kind == "nakagami":
            params["_omega_over_m"] = np.array(
                [float(ch.omega) / float(ch.m) for ch in channels], np.float64
            )
    params["_mean"] = np.array([float(ch.mean) for ch in channels], np.float64)
    params["_var"] = np.array([float(ch.var) for ch in channels], np.float64)
    if not (np.isfinite(params["_mean"]).all()
            and np.isfinite(params["_var"]).all()):
        raise ValueError(
            f"channel kind {kind!r} has non-finite (m_h, sigma_h^2) moments; "
            "power-controlled channels must be built with "
            "make_controlled_channel so their effective moments are known"
        )
    return kind, params


@dataclass(frozen=True)
class BatchedChannel(Channel):
    """A channel family whose parameters are (possibly traced) array scalars.

    The scenario-sweep engine vmaps/maps over stacked channel parameters; a
    lane of that batch sees scalar tracers, which the frozen float-field
    dataclasses above cannot hold without retracing per value.  This adapter
    keeps their exact sampling computations (same ops, same PRNG layout, so
    the draws are bit-identical to the concrete classes at equal parameter
    values) while accepting traced ``params``.

    ``params`` is the per-lane slice of ``batched_channel_arrays`` output.
    """

    kind: str = ""
    params: Any = None  # Mapping[str, jax.Array], each shape ()

    def sample(self, key: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
        p = self.params
        root = self.kind.split(":", 1)[0]
        if root in _BATCHED_SAMPLERS:
            return _BATCHED_SAMPLERS[root](self.kind, p, key, shape)
        if self.kind == "ideal":
            return jnp.ones(shape, jnp.float32)
        if self.kind == "fixed":
            return jnp.broadcast_to(
                jnp.asarray(p["gain"], jnp.float32), shape
            )
        if self.kind == "rayleigh":
            z = jax.random.normal(key, shape + (2,), jnp.float32)
            return p["scale"] * jnp.sqrt(jnp.sum(z * z, axis=-1))
        if self.kind == "nakagami":
            return jax.random.gamma(key, p["m"], shape, jnp.float32) * (
                p["_omega_over_m"]
            )
        if self.kind == "lognormal":
            z = jax.random.normal(key, shape, jnp.float32)
            return jnp.exp(p["mu"] + p["sigma"] * z)
        raise ValueError(f"unknown batched channel kind {self.kind!r}")

    @property
    def mean(self):  # traced m_h
        return self.params["_mean"]

    @property
    def var(self):  # traced sigma_h^2
        return self.params["_var"]


def make_channel(name: str, **kwargs) -> Channel:
    """Factory: make_channel('rayleigh'), make_channel('nakagami', m=0.1)."""
    try:
        return _REGISTRY[name](**kwargs)
    except KeyError as e:
        raise ValueError(
            f"unknown channel {name!r}; choose from {sorted(_REGISTRY)}"
        ) from e


def noise_sigma_from_db(db: float) -> float:
    """sigma for AWGN given noise power in dB: sigma^2 = 10^(db/10).

    The paper sets sigma^2 = -60 dB => sigma^2 = 1e-6.
    """
    return math.sqrt(10.0 ** (db / 10.0))
