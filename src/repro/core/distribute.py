"""Device-mesh execution for the sweep engine: lanes x MC seeds on a mesh.

``sweep(..., mode="sharded")`` routes every partition program through this
module (the ROADMAP's sharding/async north-star item wired into the hot
path):

* the packed lane axis lays across the mesh's ``"lane"`` axis via
  ``NamedSharding`` — uneven lane counts are padded with *replicate-lanes*
  (copies of the last real lane) that are masked off when results
  materialise;
* Monte-Carlo keys lay across the ``"mc"`` axis whenever ``mc_runs``
  divides it (otherwise they replicate across that axis);
* single-lane partitions (nothing packed — the replicate path) shard the
  MC axis across the *whole* mesh instead, so a lone scenario still uses
  every device;
* dispatch is asynchronous: partition programs launch back-to-back and
  ``block_until_ready`` is deferred until ``SweepResult`` materialisation,
  so device execution overlaps host-side packing/compilation of later
  partitions;
* packed lane arrays are donated to their partition program (they are
  rebuilt per partition, so the buffers are dead after dispatch) — on
  accelerator meshes; the CPU backend cannot reuse donated buffers, so
  donation is skipped there rather than tripping jax's warning.

Exactness contract: sharding only changes data *placement* — a sharded
partition runs the identical ``vmap`` jaxpr that ``mode="vmap"`` jits, so
lanes are bit-identical to the default mode (and hence to per-scenario
``fedpg.monte_carlo``) wherever that mode is bitwise; the padded lanes
recompute the last real lane and never reach the result.
``tests/test_distribute.py`` plus the golden-trace suite enforce this on an
8-device emulated CPU mesh (``REPRO_EMULATED_DEVICES=8``, applied by
``repro.utils.platform`` before JAX initialises).

The *agent* axis inside a round is the other shardable dimension: build a
mesh with :func:`agent_mesh_for` and pass it to
``fedpg.run(..., agent_mesh=...)`` to run the per-round fleet in its
production ``shard_map`` form — ``ota.aggregate(..., axis=...,
local_stack=True)``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_agent_mesh, make_sweep_mesh

LANE_AXIS = "lane"
MC_AXIS = "mc"

__all__ = [
    "LANE_AXIS", "MC_AXIS", "Placement", "agent_mesh_for",
    "default_sweep_mesh", "dispatch_partition", "pad_lanes",
    "place_partition", "plan_placement",
]


def default_sweep_mesh() -> Mesh:
    """All available devices on the lane axis (``("lane", "mc")`` shaped)."""
    return make_sweep_mesh()


def agent_mesh_for(n_agents: int, devices=None) -> Mesh:
    """An ``("agents",)`` mesh over the largest device count dividing
    ``n_agents`` — the mesh ``fedpg.run(..., agent_mesh=...)`` wants."""
    devices = list(jax.devices()) if devices is None else list(devices)
    n = len(devices)
    while n_agents % n:
        n -= 1
    return make_agent_mesh(n, devices)


@dataclass(frozen=True)
class Placement:
    """How one partition's (lanes x mc_runs) batch lands on the mesh.

    ``n_lanes == 0`` marks the replicate path (no packed arrays: the lane
    function runs once and the engine replicates its history); ``n_pad``
    is the number of masked replicate-lanes appended so the lane axis
    divides the mesh's lane dimension.
    """

    mesh: Mesh
    n_lanes: int
    n_pad: int
    lane_spec: P
    key_spec: P
    out_spec: P

    @property
    def n_devices(self) -> int:
        return self.mesh.size


def plan_placement(mesh: Mesh, n_lanes: int, mc_runs: int) -> Placement:
    """Choose shardings for one partition.

    Lanes shard over ``"lane"``; keys shard over ``"mc"`` when ``mc_runs``
    divides that axis.  With nothing packed the keys shard over the whole
    mesh when ``mc_runs`` divides ``mesh.size`` (else everything
    replicates — a 1-device degenerate placement that still runs).
    """
    if LANE_AXIS not in mesh.shape:
        raise ValueError(
            f"sweep mesh needs a {LANE_AXIS!r} axis; got {tuple(mesh.axis_names)} "
            "(build one with launch.mesh.make_sweep_mesh)")
    lane_d = mesh.shape[LANE_AXIS]
    mc_d = mesh.shape.get(MC_AXIS, 1)
    if n_lanes == 0:
        axes = tuple(mesh.axis_names)
        key_spec = P(axes) if mesh.size > 1 and mc_runs % mesh.size == 0 else P()
        return Placement(mesh=mesh, n_lanes=0, n_pad=0, lane_spec=P(),
                         key_spec=key_spec, out_spec=key_spec)
    n_pad = -n_lanes % lane_d
    mc_sharded = mc_d > 1 and mc_runs % mc_d == 0
    key_spec = P(MC_AXIS) if mc_sharded else P()
    out_spec = P(LANE_AXIS, MC_AXIS) if mc_sharded else P(LANE_AXIS)
    return Placement(mesh=mesh, n_lanes=n_lanes, n_pad=n_pad,
                     lane_spec=P(LANE_AXIS), key_spec=key_spec,
                     out_spec=out_spec)


def pad_lanes(packed: Dict[str, Any], n_pad: int) -> Dict[str, Any]:
    """Append ``n_pad`` copies of the last lane to every packed leaf.

    Replicate-lanes keep every value finite and every program branch
    identical to a real lane; the engine masks them off at collection, so
    they cost device FLOPs but never touch results.
    """
    if n_pad == 0:
        return packed
    return jax.tree.map(
        lambda x: jnp.concatenate([x] + [x[-1:]] * n_pad, axis=0), packed)


def place_partition(
    lane_fn,
    packed: Dict[str, Any],
    keys: jax.Array,
    mesh: Mesh,
    *,
    donate: bool = True,
) -> Tuple[Any, Dict[str, Any], jax.Array, Placement]:
    """Pad, place, and jit one partition program for the mesh.

    Returns ``(jitted, placed_packed, placed_keys, placement)`` without
    executing — benchmarks warm and time the call themselves (pass
    ``donate=False`` to re-invoke on the same buffers).
    """
    n_lanes = 0
    leaves = jax.tree.leaves(packed)
    if leaves:
        n_lanes = leaves[0].shape[0]
    # the CPU backend cannot reuse donated buffers (jax warns and ignores
    # them) — donation only pays on accelerator meshes
    donate = donate and mesh.devices.flat[0].platform != "cpu"
    placement = plan_placement(mesh, n_lanes, keys.shape[0])
    key_sh = NamedSharding(mesh, placement.key_spec)
    out_sh = NamedSharding(mesh, placement.out_spec)
    keys_placed = jax.device_put(keys, key_sh)
    if placement.n_lanes == 0:
        jitted = jax.jit(lane_fn, in_shardings=(key_sh, key_sh),
                         out_shardings=out_sh)
        return jitted, packed, keys_placed, placement
    lane_sh = NamedSharding(mesh, placement.lane_spec)
    placed = jax.device_put(pad_lanes(packed, placement.n_pad), lane_sh)
    jitted = jax.jit(
        jax.vmap(lane_fn, in_axes=(0, None)),
        in_shardings=(lane_sh, key_sh),
        out_shardings=out_sh,
        donate_argnums=(0,) if donate else (),
    )
    return jitted, placed, keys_placed, placement


def dispatch_partition(
    lane_fn,
    packed: Dict[str, Any],
    keys: jax.Array,
    mesh: Mesh,
    *,
    donate: bool = True,
) -> Tuple[Any, Placement]:
    """Launch one partition on the mesh and return WITHOUT blocking.

    The result's leaves carry a (padded) leading lane axis when
    ``placement.n_lanes > 0``; the replicate path returns unstacked
    ``(mc_runs, ...)`` leaves.  Callers slice real lanes / replicate and
    defer ``block_until_ready`` until they materialise results.

    Compilation is split out ahead-of-time (``lower().compile()`` — still
    exactly one XLA compile per partition, the compile-budget contract's
    invariant) so the ``compile`` and ``dispatch`` phases land as separate
    ``repro.telemetry.trace`` spans in sweep trace exports.
    """
    from repro.telemetry import trace as rtrace

    jitted, placed, keys_placed, placement = place_partition(
        lane_fn, packed, keys, mesh, donate=donate)
    with rtrace.span("compile", lanes=placement.n_lanes,
                     pad=placement.n_pad, devices=mesh.size):
        compiled = jitted.lower(placed, keys_placed).compile()
    with rtrace.span("dispatch", lanes=placement.n_lanes,
                     devices=mesh.size):
        out = compiled(placed, keys_placed)
    return out, placement
