"""Event-triggered (LAPG-style) federated PG — the communication-efficient
baseline the paper positions itself against (Chen et al. [16], discussed in
Section I: "with a huge number of agents, the event-triggered mechanism
still fails due to communication bottleneck").

Each round, agent i uploads its fresh gradient only if it moved enough since
its last upload:

    upload_i  iff  ||ghat_i^k - ghat_i^{last}||^2 >= tau * ||ghat_i^k||^2

otherwise the server reuses the stale copy.  Channel-use accounting: the
event-triggered scheme still needs ONE ORTHOGONAL channel use PER UPLOADING
AGENT (TDMA/FDMA), so its per-round communication is E[#triggers] in [0, N]
— whereas OTA is exactly 1 regardless of N.  That asymmetry is the paper's
motivation and what `benchmarks/et_baseline.py` measures.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import ota
from repro.core.fedpg import (
    FedPGConfig, _estimator_grad, _hashable, register_compiled_cache,
)
from repro.rl.envs.heterogeneous import HeterogeneousEnv, check_agent_count
from repro.rl.sampler import discounted_return, empirical_reward, rollout_batch
from repro.service import participation as svc_participation
from repro.utils.tree import (
    tree_global_norm_sq, tree_sub, tree_zeros_like,
)

PyTree = Any


@dataclass(frozen=True)
class ETConfig:
    tau: float = 0.05     # trigger threshold (relative squared change)


class ETHistory(NamedTuple):
    rewards: jax.Array       # (K,)
    grad_sq: jax.Array       # (K,)
    uploads: jax.Array       # (K,) — channel uses this round (0..N)


def run(env, policy, cfg: FedPGConfig, et: ETConfig, key: jax.Array,
        *, agent_blocks=None, participation=None):
    """K rounds of event-triggered federated PG. Returns (theta, ETHistory).

    ``agent_blocks`` rolls the fleet out in blocked-scan chunks of that
    many agents (same absolute-index key stream as the unblocked loop) —
    the trajectory memory drops to O(agent_blocks), though the stale-
    gradient state this baseline must carry is inherently O(N × d): that
    asymmetry vs. the streamed OTA round is exactly the scaling gap the
    paper argues.  The full (N,)-stacked gradients are re-materialised from
    the scan outputs, so the trigger/aggregate tail — and the emitted
    history — is identical to the unblocked program's.

    ``participation`` (an active
    :class:`~repro.service.participation.ParticipationConfig`) gates the
    trigger with the same per-round mask the OTA service rounds draw: an
    agent uploads iff it *participates* AND its gradient moved enough, so
    a non-participant's trigger state does not advance (the server keeps
    its last uploaded copy and its reference gradient stays put — exactly
    LAPG semantics under intermittent availability).  The server mean
    still runs over all N stale copies; the reward averages the
    participants' fresh trajectories.  A config that normalises away
    emits the byte-identical plain program.
    """
    part = svc_participation.normalize(participation, cfg.n_agents)
    if part is None:
        key_init, key_scan = jax.random.split(key)
    else:
        key_init, key_scan, key_svc = jax.random.split(key, 3)
        part_key, sched_key = jax.random.split(key_svc)
        agent_ids = jnp.arange(cfg.n_agents, dtype=jnp.int32)
    theta = policy.init(key_init)
    # honour cfg.estimator exactly like fedpg.make_round_fn does
    grad_fn = _estimator_grad(cfg)
    # per-agent heterogeneous dynamics vmap exactly like fedpg.make_round_fn
    hetero = isinstance(env, HeterogeneousEnv)
    if hetero:
        check_agent_count(env, cfg.n_agents)
    stale0 = jax.vmap(lambda _: tree_zeros_like(theta))(
        jnp.arange(cfg.n_agents)
    )
    if agent_blocks is not None:
        n_blocks, block, pad = ota.blocked_layout(cfg.n_agents, agent_blocks)

    def round_fn(carry, key_k):
        if part is None:
            theta, stale = carry
        else:
            theta, stale, round_idx = carry
        agent_keys = jax.random.split(key_k, cfg.n_agents)
        lane_stacks = dict(env.params) if hetero else {}

        def agent_grad(k, lane_params):
            e = env.lane(lane_params) if hetero else env
            traj = rollout_batch(e, policy, theta, k, cfg.horizon,
                                 cfg.batch_m)
            return grad_fn(policy, theta, traj, cfg.gamma), traj

        if agent_blocks is None:
            grads, trajs = jax.vmap(agent_grad)(agent_keys, lane_stacks)
            if part is None:
                reward = empirical_reward(trajs, cfg.gamma)
            else:
                returns_pa = discounted_return(trajs.losses, cfg.gamma)
        else:
            xs = (ota.block_view(ota.pad_agent_axis(agent_keys, pad),
                                 n_blocks, block),
                  ota.block_view(ota.pad_agent_axis(lane_stacks, pad),
                                 n_blocks, block))

            def block_body(c, x):
                g_b, t_b = jax.vmap(agent_grad)(*x)
                return c, (g_b, discounted_return(t_b.losses, cfg.gamma))

            _, (g_blocks, returns) = jax.lax.scan(block_body, 0, xs)
            grads = jax.tree.map(
                lambda a: a.reshape((n_blocks * block,) + a.shape[2:])
                [:cfg.n_agents], g_blocks)
            if part is None:
                reward = -jnp.mean(returns.reshape(
                    (n_blocks * block,) + returns.shape[2:])[:cfg.n_agents])
            else:
                returns_pa = returns.reshape(
                    (n_blocks * block,) + returns.shape[2:])[:cfg.n_agents]

        # trigger test per agent
        def trig(g_new, g_old):
            diff = tree_global_norm_sq(tree_sub(g_new, g_old))
            return diff >= et.tau * tree_global_norm_sq(g_new)

        fire = jax.vmap(trig)(grads, stale)                   # (N,) bool
        if part is not None:
            # an agent uploads iff it participates AND triggers — a
            # non-participant's server copy and trigger reference both
            # stay put (the `used` carry below keeps its stale row)
            mask = svc_participation.round_mask(
                part, part_key, sched_key, round_idx, agent_ids,
                cfg.n_agents)
            fire = jnp.logical_and(mask, fire)
            count_p = jnp.sum(mask.astype(jnp.float32))
            reward = -jnp.sum(jnp.where(mask[:, None], returns_pa, 0.0)) \
                * svc_participation.safe_inv(count_p) / cfg.batch_m

        # server-side view: fresh where fired, stale otherwise
        used = jax.tree.map(
            lambda gn, go: jnp.where(
                fire.reshape((-1,) + (1,) * (gn.ndim - 1)), gn, go
            ),
            grads, stale,
        )
        update = ota.aggregate(used, None)[0]  # exact uplink (ideal mean)
        theta = jax.tree.map(lambda p, u: p - cfg.alpha * u, theta, update)

        gsq = tree_global_norm_sq(update)
        metrics = (reward, gsq, jnp.sum(fire))
        if part is None:
            return (theta, used), metrics
        return (theta, used, round_idx + 1), metrics

    keys = jax.random.split(key_scan, cfg.n_rounds)
    carry0 = (theta, stale0) if part is None \
        else (theta, stale0, jnp.zeros((), jnp.int32))
    carry, (rewards, gsq, ups) = jax.lax.scan(round_fn, carry0, keys)
    theta = carry[0]
    return theta, ETHistory(rewards=rewards, grad_sq=gsq,
                            uploads=ups.astype(jnp.float32))


@functools.lru_cache(maxsize=64)
def _compiled_run(env, policy, cfg: FedPGConfig, et: ETConfig,
                  agent_blocks=None, participation=None):
    return jax.jit(
        lambda k: run(env, policy, cfg, et, k, agent_blocks=agent_blocks,
                      participation=participation))


register_compiled_cache(_compiled_run)


def run_jit(env, policy, cfg: FedPGConfig, et: ETConfig, key,
            *, agent_blocks=None, participation=None):
    """Compiled entry point; reuses the program across calls with the same
    (hashable) ``(env, policy, cfg, et, agent_blocks, participation)``,
    like ``fedpg.run_jit`` (the participation config is normalised before
    keying, so full participation hits the same entry as ``None``)."""
    participation = svc_participation.normalize(participation, cfg.n_agents)
    if _hashable(env, policy, cfg, et, agent_blocks, participation):
        return _compiled_run(env, policy, cfg, et, agent_blocks,
                             participation)(key)
    return jax.jit(
        lambda k: run(env, policy, cfg, et, k,
                      agent_blocks=agent_blocks,
                      participation=participation))(key)
