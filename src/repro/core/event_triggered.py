"""Event-triggered (LAPG-style) federated PG — the communication-efficient
baseline the paper positions itself against (Chen et al. [16], discussed in
Section I: "with a huge number of agents, the event-triggered mechanism
still fails due to communication bottleneck").

Each round, agent i uploads its fresh gradient only if it moved enough since
its last upload:

    upload_i  iff  ||ghat_i^k - ghat_i^{last}||^2 >= tau * ||ghat_i^k||^2

otherwise the server reuses the stale copy.  Channel-use accounting: the
event-triggered scheme still needs ONE ORTHOGONAL channel use PER UPLOADING
AGENT (TDMA/FDMA), so its per-round communication is E[#triggers] in [0, N]
— whereas OTA is exactly 1 regardless of N.  That asymmetry is the paper's
motivation and what `benchmarks/et_baseline.py` measures.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import ota
from repro.core.fedpg import (
    FedPGConfig, _estimator_grad, _hashable, register_compiled_cache,
)
from repro.rl.envs.heterogeneous import HeterogeneousEnv, check_agent_count
from repro.rl.sampler import discounted_return, empirical_reward, rollout_batch
from repro.utils.tree import (
    tree_global_norm_sq, tree_sub, tree_zeros_like,
)

PyTree = Any


@dataclass(frozen=True)
class ETConfig:
    tau: float = 0.05     # trigger threshold (relative squared change)


class ETHistory(NamedTuple):
    rewards: jax.Array       # (K,)
    grad_sq: jax.Array       # (K,)
    uploads: jax.Array       # (K,) — channel uses this round (0..N)


def run(env, policy, cfg: FedPGConfig, et: ETConfig, key: jax.Array,
        *, agent_blocks=None):
    """K rounds of event-triggered federated PG. Returns (theta, ETHistory).

    ``agent_blocks`` rolls the fleet out in blocked-scan chunks of that
    many agents (same absolute-index key stream as the unblocked loop) —
    the trajectory memory drops to O(agent_blocks), though the stale-
    gradient state this baseline must carry is inherently O(N × d): that
    asymmetry vs. the streamed OTA round is exactly the scaling gap the
    paper argues.  The full (N,)-stacked gradients are re-materialised from
    the scan outputs, so the trigger/aggregate tail — and the emitted
    history — is identical to the unblocked program's.
    """
    key_init, key_scan = jax.random.split(key)
    theta = policy.init(key_init)
    # honour cfg.estimator exactly like fedpg.make_round_fn does
    grad_fn = _estimator_grad(cfg)
    # per-agent heterogeneous dynamics vmap exactly like fedpg.make_round_fn
    hetero = isinstance(env, HeterogeneousEnv)
    if hetero:
        check_agent_count(env, cfg.n_agents)
    stale0 = jax.vmap(lambda _: tree_zeros_like(theta))(
        jnp.arange(cfg.n_agents)
    )
    if agent_blocks is not None:
        n_blocks, block, pad = ota.blocked_layout(cfg.n_agents, agent_blocks)

    def round_fn(carry, key_k):
        theta, stale = carry
        agent_keys = jax.random.split(key_k, cfg.n_agents)
        lane_stacks = dict(env.params) if hetero else {}

        def agent_grad(k, lane_params):
            e = env.lane(lane_params) if hetero else env
            traj = rollout_batch(e, policy, theta, k, cfg.horizon,
                                 cfg.batch_m)
            return grad_fn(policy, theta, traj, cfg.gamma), traj

        if agent_blocks is None:
            grads, trajs = jax.vmap(agent_grad)(agent_keys, lane_stacks)
            reward = empirical_reward(trajs, cfg.gamma)
        else:
            xs = (ota.block_view(ota.pad_agent_axis(agent_keys, pad),
                                 n_blocks, block),
                  ota.block_view(ota.pad_agent_axis(lane_stacks, pad),
                                 n_blocks, block))

            def block_body(c, x):
                g_b, t_b = jax.vmap(agent_grad)(*x)
                return c, (g_b, discounted_return(t_b.losses, cfg.gamma))

            _, (g_blocks, returns) = jax.lax.scan(block_body, 0, xs)
            grads = jax.tree.map(
                lambda a: a.reshape((n_blocks * block,) + a.shape[2:])
                [:cfg.n_agents], g_blocks)
            reward = -jnp.mean(returns.reshape(
                (n_blocks * block,) + returns.shape[2:])[:cfg.n_agents])

        # trigger test per agent
        def trig(g_new, g_old):
            diff = tree_global_norm_sq(tree_sub(g_new, g_old))
            return diff >= et.tau * tree_global_norm_sq(g_new)

        fire = jax.vmap(trig)(grads, stale)                   # (N,) bool

        # server-side view: fresh where fired, stale otherwise
        used = jax.tree.map(
            lambda gn, go: jnp.where(
                fire.reshape((-1,) + (1,) * (gn.ndim - 1)), gn, go
            ),
            grads, stale,
        )
        update = ota.aggregate(used, None)[0]  # exact uplink (ideal mean)
        theta = jax.tree.map(lambda p, u: p - cfg.alpha * u, theta, update)

        gsq = tree_global_norm_sq(update)
        return (theta, used), (reward, gsq, jnp.sum(fire))

    keys = jax.random.split(key_scan, cfg.n_rounds)
    (theta, _), (rewards, gsq, ups) = jax.lax.scan(
        round_fn, (theta, stale0), keys
    )
    return theta, ETHistory(rewards=rewards, grad_sq=gsq,
                            uploads=ups.astype(jnp.float32))


@functools.lru_cache(maxsize=64)
def _compiled_run(env, policy, cfg: FedPGConfig, et: ETConfig,
                  agent_blocks=None):
    return jax.jit(
        lambda k: run(env, policy, cfg, et, k, agent_blocks=agent_blocks))


register_compiled_cache(_compiled_run)


def run_jit(env, policy, cfg: FedPGConfig, et: ETConfig, key,
            *, agent_blocks=None):
    """Compiled entry point; reuses the program across calls with the same
    (hashable) ``(env, policy, cfg, et, agent_blocks)``, like
    ``fedpg.run_jit``."""
    if _hashable(env, policy, cfg, et, agent_blocks):
        return _compiled_run(env, policy, cfg, et, agent_blocks)(key)
    return jax.jit(
        lambda k: run(env, policy, cfg, et, k,
                      agent_blocks=agent_blocks))(key)
