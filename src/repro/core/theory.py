"""Closed-form constants and bounds from the paper's analysis.

Everything here is pure float math so the benchmark tables and the property
tests can evaluate the theory against the simulated algorithm:

* Lemma 1   — smoothness constant  L = (F + G^2 + 2 gamma G^2/(1-gamma))
              * gamma * l_bar / (1-gamma)^2.
* Lemma 3   — gradient-estimate distortion bound, with
              V = G * l_bar * gamma / (1-gamma)^2.
* Theorem 1 — average squared-gradient-norm bound under the channel
              condition sigma_h^2 <= (N+1) m_h^2 (Eq. 10), with
              Lambda = M (N+1) m_h^2 - (M-1) sigma_h^2.
* Theorem 2 — unconditional bound (Eq. 11) with the O(1/N) channel floor.
* Corollary 1 — communication/sampling complexity schedules.
* ``theorem1_floor``/``theorem2_floor``/``applicable_bound`` — the K -> inf
  variance floors and the tightest-applicable-bound dispatcher; evaluate
  them with a channel's *effective* (m_h, sigma_h^2) (power control folded
  in, see ``power_control.effective_moments``) to read off how a transmit
  power policy moves the channel-variance floor.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class MDPConstants:
    """Problem constants the assumptions are stated in terms of."""

    G: float        # sup ||grad log pi||            (Assumption 2)
    F: float        # sup |d^2/dtheta^2 log pi|      (Assumption 2)
    l_bar: float    # sup loss                        (Assumption 1)
    gamma: float    # discount factor

    def smoothness_L(self) -> float:
        """Lemma 1: J is L-smooth."""
        g, f, lb, gam = self.G, self.F, self.l_bar, self.gamma
        return (f + g * g + 2.0 * gam * g * g / (1.0 - gam)) * (
            gam * lb / (1.0 - gam) ** 2
        )

    def V(self) -> float:
        """Lemma 3's gradient-norm envelope: V = G l_bar gamma/(1-gamma)^2.

        (= G * l_bar * sum_{t>=0} t gamma^t, the sup of any G(PO)MDP
        single-trajectory estimate's norm.)
        """
        return self.G * self.l_bar * self.gamma / (1.0 - self.gamma) ** 2

    def max_stepsize(self, m_h: float) -> float:
        """Theorem 1/2 step-size condition alpha <= 1/(m_h L)."""
        return 1.0 / (m_h * self.smoothness_L())


def Lambda(n_agents: int, batch_m: int, m_h: float, sigma_h2: float) -> float:
    """Lambda_{N,M}^{sigma_h, m_h} = M (N+1) m_h^2 - (M-1) sigma_h^2."""
    return batch_m * (n_agents + 1) * m_h**2 - (batch_m - 1) * sigma_h2


def channel_condition_ok(n_agents: int, m_h: float, sigma_h2: float) -> bool:
    """Theorem 1's channel condition sigma_h^2 <= (N+1) m_h^2."""
    return sigma_h2 <= (n_agents + 1) * m_h**2


def lemma3_bound(
    *,
    n_agents: int,
    batch_m: int,
    m_h: float,
    sigma_h2: float,
    noise_sigma2: float,
    V: float,
    grad_sq: float,
) -> float:
    """Eq. (9): bound on E|| v_k/(m_h N) - grad J ||^2 given ||grad J||^2."""
    n, m = n_agents, batch_m
    return (
        noise_sigma2 / n**2 / m_h**2
        + sigma_h2 * V**2 / (m * n * m_h**2)
        + (m * (sigma_h2 - m_h**2) - sigma_h2) / (m * n * m_h**2) * grad_sq
    )


def theorem1_bound(
    *,
    K: int,
    n_agents: int,
    batch_m: int,
    alpha: float,
    m_h: float,
    sigma_h2: float,
    noise_sigma2: float,
    delta_J: float,   # J(theta^0) - J(theta^*)
    V: float,
) -> float:
    """Eq. (10): bound on (1/K) sum_k E ||grad J(theta^k)||^2."""
    n, m = n_agents, batch_m
    lam = Lambda(n, m, m_h, sigma_h2)
    if lam <= 0:
        return math.inf
    return (
        2.0 * m * n * m_h * delta_J / (alpha * lam * K)
        + m * m_h**2 * noise_sigma2 / (n * lam)
        + sigma_h2 * V**2 / lam
    )


def theorem2_bound(
    *,
    K: int,
    n_agents: int,
    batch_m: int,
    alpha: float,
    m_h: float,
    sigma_h2: float,
    noise_sigma2: float,
    delta_J: float,
    V: float,
) -> float:
    """Eq. (11): unconditional bound; note the O(1/N) channel-variance floor
    (second term) that neither K nor M can reduce (Remark 3)."""
    n, m = n_agents, batch_m
    denom = m * (n + 1) * m_h**2 + sigma_h2
    return (
        2.0 * m * n * m_h * delta_J / (alpha * K * denom)
        + m * sigma_h2 * V**2 / denom
        + sigma_h2 * V**2 / denom
        + m * m_h**2 * noise_sigma2 / (n * denom)
    )


def theorem1_floor(
    *,
    n_agents: int,
    batch_m: int,
    m_h: float,
    sigma_h2: float,
    noise_sigma2: float,
    V: float,
) -> float:
    """Theorem 1's K -> inf limit: the variance floor no round count can
    beat.  This is the quantity transmit-power control moves — it is
    monotone in ``sigma_h2 / m_h^2``, the normalised channel variance."""
    lam = Lambda(n_agents, batch_m, m_h, sigma_h2)
    if lam <= 0:
        return math.inf
    return (
        batch_m * m_h**2 * noise_sigma2 / (n_agents * lam)
        + sigma_h2 * V**2 / lam
    )


def theorem2_floor(
    *,
    n_agents: int,
    batch_m: int,
    m_h: float,
    sigma_h2: float,
    noise_sigma2: float,
    V: float,
) -> float:
    """Theorem 2's K -> inf limit (Remark 3's O(1/N) channel floor)."""
    n, m = n_agents, batch_m
    denom = m * (n + 1) * m_h**2 + sigma_h2
    return (
        m * sigma_h2 * V**2 / denom
        + sigma_h2 * V**2 / denom
        + m * m_h**2 * noise_sigma2 / (n * denom)
    )


def floor_report(
    *,
    n_agents: int,
    batch_m: int,
    m_h: float,
    sigma_h2: float,
    noise_sigma2: float,
    V: float,
) -> dict:
    """Both K -> inf floors plus which one applies — the flat record run
    ledgers attach to every measured scenario (``floor`` is the applicable
    one: Theorem 1 when its channel condition holds and the floor is
    finite, Theorem 2 otherwise)."""
    kw = dict(n_agents=n_agents, batch_m=batch_m, m_h=m_h,
              sigma_h2=sigma_h2, noise_sigma2=noise_sigma2, V=V)
    f1 = theorem1_floor(**kw)
    f2 = theorem2_floor(**kw)
    ok = channel_condition_ok(n_agents, m_h, sigma_h2)
    which = "theorem1" if ok and math.isfinite(f1) else "theorem2"
    return {
        "floor_theorem1": f1,
        "floor_theorem2": f2,
        "channel_condition_ok": ok,
        "floor_which": which,
        "floor": f1 if which == "theorem1" else f2,
    }


def applicable_bound(
    *,
    K: int,
    n_agents: int,
    batch_m: int,
    alpha: float,
    m_h: float,
    sigma_h2: float,
    noise_sigma2: float,
    delta_J: float,
    V: float,
) -> Tuple[str, float]:
    """The tightest applicable bound for a channel's *effective*
    (m_h, sigma_h^2): Theorem 1 when its channel condition (Eq. 10's
    premise) holds, Theorem 2 otherwise.  Returns (which, value)."""
    kw = dict(K=K, n_agents=n_agents, batch_m=batch_m, alpha=alpha, m_h=m_h,
              sigma_h2=sigma_h2, noise_sigma2=noise_sigma2, delta_J=delta_J,
              V=V)
    if channel_condition_ok(n_agents, m_h, sigma_h2):
        b = theorem1_bound(**kw)
        if math.isfinite(b):
            return "theorem1", b
    return "theorem2", theorem2_bound(**kw)


@dataclass(frozen=True)
class ComplexitySchedule:
    """Corollary 1: (K, N, M) achieving an eps-approximate stationary point."""

    epsilon: float
    K: int            # communication rounds,   O(1/eps)
    n_agents: int     # agents,                 O(1/sqrt(eps))
    batch_m: int      # per-agent batch,        O(1/(N eps))

    @property
    def total_trajectories(self) -> int:
        """Per-agent sampling complexity K*M = O(1/(N eps^2))... the paper
        reports the *per-round per-agent* sampling complexity M = O(1/(N eps))."""
        return self.K * self.batch_m


def corollary1_schedule(epsilon: float, *, c_k: float = 1.0, c_n: float = 1.0,
                        c_m: float = 1.0) -> ComplexitySchedule:
    """Instantiate Corollary 1's asymptotic schedule with unit constants:
    K = ceil(c_k/eps), N = ceil(c_n/sqrt(eps)), M = ceil(c_m/(N eps))."""
    K = max(1, math.ceil(c_k / epsilon))
    N = max(1, math.ceil(c_n / math.sqrt(epsilon)))
    M = max(1, math.ceil(c_m / (N * epsilon)))
    return ComplexitySchedule(epsilon=epsilon, K=K, n_agents=N, batch_m=M)


def env_l_bar(env, horizon: int) -> float:
    """The Assumption-1 loss envelope for ``env`` at the *actual* horizon.

    Prefers the env's ``l_bar_for(horizon)`` hook (horizon-dependent
    envelopes: the landmark tasks drift ``step_size * T`` from the arena,
    so a fixed-``T`` constant silently under-states l_bar for longer runs);
    falls back to a static ``l_bar`` attribute.
    """
    fn = getattr(env, "l_bar_for", None)
    if callable(fn):
        return float(fn(horizon))
    lb = getattr(env, "l_bar", None)
    if lb is not None:
        return float(lb)
    raise ValueError(
        f"environment {type(env).__name__} exposes neither l_bar_for() nor "
        "l_bar; pass MDPConstants explicitly"
    )


def constants_for_env(
    env, *, horizon: int, gamma: float, G: float, F: float
) -> MDPConstants:
    """``MDPConstants`` with ``l_bar`` derived from the env at the configured
    horizon — the safe way to build theory tables (Theorem 1/2 bounds scale
    with ``l_bar^2`` through V, so a stale fixed-horizon envelope corrupts
    every bound)."""
    return MDPConstants(G=G, F=F, l_bar=env_l_bar(env, horizon), gamma=gamma)


def mlp_policy_constants(
    *, weight_bound: float, input_bound: float, hidden: int, n_actions: int,
    l_bar: float, gamma: float,
) -> MDPConstants:
    """Conservative (G, F) envelopes for a 2-layer ReLU-softmax policy.

    For softmax output, ||grad_logits log pi|| <= sqrt(2); back-propagating
    through a ReLU layer with bounded weights/inputs gives the crude Lipschitz
    products below.  These are *envelopes* for plugging into the bounds, not
    tight constants.
    """
    # d log pi / d logits is bounded by sqrt(2) in l2 for categorical softmax.
    lip_logits = math.sqrt(2.0)
    # gradient wrt last-layer weights: |hidden activation| * lip_logits
    g_w2 = lip_logits * weight_bound * input_bound * math.sqrt(hidden)
    # gradient wrt first-layer weights: lip through W2 (bounded) * input
    g_w1 = lip_logits * weight_bound * input_bound * math.sqrt(hidden)
    G = math.sqrt(g_w1**2 + g_w2**2)
    F = 2.0 * (weight_bound * input_bound) ** 2 * (1.0 + hidden)
    return MDPConstants(G=G, F=F, l_bar=l_bar, gamma=gamma)
