"""Mesh construction (functions only — importing this module never touches
jax device state until a constructor is called).

Two families:

* production LLM meshes (``data``/``model`` axes) for the trainer/server;
* sweep-shaped meshes (``lane``/``mc``/``agents`` axes) for
  ``repro.core.distribute`` — the device layer under
  ``sweep(..., mode="sharded")`` and the agent-sharded round functions.
  Develop on CPU with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e pod mesh: (16,16) = 256 chips; multi-pod: (2,16,16) = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_tiny_mesh(data: int = 2, model: int = 2):
    """Small host-device mesh for CI tests (requires the XLA host-device
    flag to be set before jax initialises)."""
    return jax.make_mesh((data, model), ("data", "model"))


def n_data_shards(mesh) -> int:
    """Number of OTA 'agents' = data-parallel replica groups."""
    n = 1
    for axis in ("pod", "data"):
        if axis in mesh.shape:
            n *= mesh.shape[axis]
    return n


# ---------------------------------------------------------------------------
# Sweep-shaped meshes (repro.core.distribute).
# ---------------------------------------------------------------------------

def make_sweep_mesh(
    lane_shards: Optional[int] = None,
    mc_shards: int = 1,
    devices: Optional[Sequence] = None,
) -> Mesh:
    """A ``("lane", "mc")`` mesh for sharded sweep execution.

    Lanes (scenario axis inside one partition) lay across ``lane``; Monte
    Carlo seeds across ``mc``.  Defaults to every available device on the
    lane axis — the right shape whenever partitions carry at least as many
    lanes as devices.  Pass an explicit ``devices`` subset (e.g.
    ``jax.devices()[:4]``) for scaling studies.
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    if mc_shards < 1:
        raise ValueError(f"mc_shards must be >= 1, got {mc_shards}")
    if lane_shards is not None and lane_shards < 1:
        raise ValueError(f"lane_shards must be >= 1, got {lane_shards}")
    if lane_shards is None:
        lane_shards = max(len(devices) // mc_shards, 1)
    n = lane_shards * mc_shards
    if n > len(devices):
        raise ValueError(
            f"mesh wants {lane_shards}x{mc_shards}={n} devices but only "
            f"{len(devices)} are available")
    grid = np.asarray(devices[:n]).reshape(lane_shards, mc_shards)
    return Mesh(grid, ("lane", "mc"))


def make_agent_mesh(n_shards: Optional[int] = None,
                    devices: Optional[Sequence] = None) -> Mesh:
    """A 1-D ``("agents",)`` mesh: the production shard_map form of the
    per-round agent axis (``fedpg.make_round_fn(..., agent_mesh=...)``).
    ``n_shards`` must divide the round's ``n_agents``."""
    devices = list(jax.devices()) if devices is None else list(devices)
    if n_shards is None:
        n_shards = len(devices)
    if n_shards < 1 or n_shards > len(devices):
        raise ValueError(
            f"n_shards={n_shards} out of range for {len(devices)} devices")
    return Mesh(np.asarray(devices[:n_shards]), ("agents",))
