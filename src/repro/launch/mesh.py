"""Production mesh construction (functions only — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e pod mesh: (16,16) = 256 chips; multi-pod: (2,16,16) = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_tiny_mesh(data: int = 2, model: int = 2):
    """Small host-device mesh for CI tests (requires the XLA host-device
    flag to be set before jax initialises)."""
    return jax.make_mesh((data, model), ("data", "model"))


def n_data_shards(mesh) -> int:
    """Number of OTA 'agents' = data-parallel replica groups."""
    n = 1
    for axis in ("pod", "data"):
        if axis in mesh.shape:
            n *= mesh.shape[axis]
    return n
