"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run JSONs.

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import warnings
from typing import Dict, List

from repro.configs import ARCH_IDS
from repro.configs.shapes import SHAPES
from repro.utils.tree import human_bytes

MESHES = ("pod16x16", "pod2x16x16")


def load(dryrun_dir: str) -> Dict:
    """Index dry-run records by (arch, shape, mesh, filename stem).

    Malformed files — unparseable JSON, or records missing any of the
    identifying keys — are skipped with a warning rather than crashing the
    whole report: one bad artifact should not hide the rest."""
    recs = {}
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        try:
            with open(path) as f:
                r = json.load(f)
        except (json.JSONDecodeError, OSError) as e:
            warnings.warn(f"skipping unreadable dry-run record {path}: {e}",
                          stacklevel=2)
            continue
        if not isinstance(r, dict) or not all(
                k in r for k in ("arch", "shape", "mesh")):
            warnings.warn(f"skipping malformed dry-run record {path}: "
                          "missing arch/shape/mesh", stacklevel=2)
            continue
        base = os.path.basename(path)[:-5]
        recs[(r["arch"], r["shape"], r["mesh"], base)] = r
    return recs


def _mem_gb(rec) -> str:
    mem = rec.get("memory", {})
    tot = sum(mem.get(k, 0) for k in
              ("argument_size_in_bytes", "temp_size_in_bytes"))
    if not tot:
        return "?"
    flag = "" if tot <= 16e9 else " (!)"
    return f"{tot/1e9:.2f}{flag}"


def dryrun_table(recs) -> List[str]:
    lines = [
        "| arch | shape | mesh | status | bytes/device (args+temp, GB) |"
        " HLO FLOPs/dev | collectives (per-device wire bytes) |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in MESHES:
                match = [r for (a, s, m, _), r in recs.items()
                         if a == arch and s == shape and m == mesh]
                if not match:
                    lines.append(
                        f"| {arch} | {shape} | {mesh} | MISSING | | | |")
                    continue
                r = match[-1]
                coll = r.get("collectives_rolled", {})
                kinds = ",".join(
                    f"{k}:{int(v):,}" for k, v in
                    sorted(coll.get("bytes_by_kind", {}).items()))
                flops = r["roofline"]["hlo_flops"]
                lines.append(
                    f"| {arch} | {shape} | {mesh} | compiled "
                    f"({r['t_compile_s']}s) | {_mem_gb(r)} | {flops:.3g} | "
                    f"{kinds or 'none'} |"
                )
    return lines


def roofline_table(recs) -> List[str]:
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) |"
        " dominant | MODEL/HLO FLOPs | MFU bound | one-line lever |",
        "|---|---|---|---|---|---|---|---|---|"[:-2],
    ]
    levers = {
        "collective": "reduce-scatter grads in bf16 / overlap FSDP gathers",
        "memory": "cut cache copies (donate/alias), flash-attn bwd, fp8 cache",
        "compute": "already compute-bound: raise per-chip batch or quantise",
    }
    for arch in ARCH_IDS:
        for shape in SHAPES:
            match = [r for (a, s, m, _), r in recs.items()
                     if a == arch and s == shape and m == "pod16x16"
                     and r.get("calibrated")]
            if not match:
                continue
            r = match[-1]["roofline"]
            lines.append(
                f"| {arch} | {shape} | {r['compute_s']*1e3:.2f} | "
                f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
                f"**{r['dominant']}** | {r['useful_flop_ratio']:.3f} | "
                f"{r['mfu']:.4f} | {levers[r['dominant']]} |"
            )
    return lines


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--section", default="both",
                    choices=("dryrun", "roofline", "both"))
    args = ap.parse_args()
    recs = load(args.dir)
    if args.section in ("dryrun", "both"):
        print("\n".join(dryrun_table(recs)))
        print()
    if args.section in ("roofline", "both"):
        print("\n".join(roofline_table(recs)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
