"""Training driver: end-to-end loop over the synthetic pipeline with OTA (or
exact) gradient aggregation, periodic eval + checkpointing.

On this CPU container it drives the reduced smoke configs (the full configs
are exercised via the dry-run); on a real TPU slice the same entry point runs
the production mesh by passing --mesh pod.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
        --steps 200 --aggregator ota --channel rayleigh
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import InputShape
from repro.data.pipeline import make_batch
from repro.models import model as model_lib
from repro.train import trainer


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--n-agents", type=int, default=4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--aggregator", default="ota", choices=("ota", "exact"))
    ap.add_argument("--channel", default="rayleigh",
                    choices=("rayleigh", "nakagami", "lognormal", "fixed", "ideal"))
    ap.add_argument("--noise-db", type=float, default=-60.0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = model_lib.build(cfg)
    shape = InputShape("cli", seq_len=args.seq_len,
                       global_batch=args.global_batch, kind="train")
    tcfg = trainer.TrainConfig(
        aggregator=args.aggregator,
        channel=args.channel,
        noise_db=args.noise_db,
        n_agents=args.n_agents,
        microbatch=args.microbatch,
        lr=args.lr,
        warmup=min(50, args.steps // 10 + 1),
        total_steps=args.steps,
        seed=args.seed,
    )
    state = trainer.init_state(model, tcfg, jax.random.key(args.seed))
    if args.ckpt_dir:
        last = checkpoint.latest_step(args.ckpt_dir)
        if last is not None:
            state = checkpoint.restore(args.ckpt_dir, last, state)
            print(f"restored step {int(state.step)} from {args.ckpt_dir}")

    step_fn = jax.jit(trainer.make_train_step(model, tcfg))
    key = jax.random.key(args.seed + 1)
    history = []
    t0 = time.time()
    start = int(state.step)
    for i in range(start, args.steps):
        batch = make_batch(cfg, shape, i, seed=args.seed)
        state, metrics = step_fn(state, batch, key)
        if i % args.log_every == 0 or i == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["wall_s"] = round(time.time() - t0, 2)
            history.append(m)
            print(
                f"step {i:5d} loss {m['loss']:.4f} |g| {m['grad_norm']:.3f} "
                f"gain {m['gain_mean']:.3f} ({m['wall_s']:.1f}s)"
            )
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt_dir, i + 1, state)

    if args.ckpt_dir:
        checkpoint.save(args.ckpt_dir, args.steps, state)
    if args.metrics_out:
        os.makedirs(os.path.dirname(args.metrics_out) or ".", exist_ok=True)
        with open(args.metrics_out, "w") as f:
            json.dump(history, f, indent=1)
    first, last_l = history[0]["loss"], history[-1]["loss"]
    print(f"loss {first:.4f} -> {last_l:.4f} over {args.steps} steps")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
