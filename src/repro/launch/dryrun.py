import os
from repro.utils import platform as rplat
rplat.set_host_device_count(512)

# NOTE: the lines above MUST be the first statements in this module — jax
# locks the device count on first init (see module docstring below);
# repro.utils.platform is import-light (no jax at module scope).

_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step, in_shardings=..., out_shardings=...).lower(...)
.compile()`` must succeed on the single-pod (16,16) mesh AND the 2-pod
(2,16,16) mesh for every assigned architecture and input shape, using
ShapeDtypeStruct stand-ins (zero allocation).

The first two lines of this file MUST stay first: jax locks the device count
on first init, and only the dry-run should see 512 host devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
    PYTHONPATH=src python -m repro.launch.dryrun --arch X --shape Y --tiny 4
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, get_shape
from repro.data.pipeline import make_batch_specs
from repro.launch.mesh import make_production_mesh, n_data_shards
from repro.models import model as model_lib
from repro.models.param import serve_rules, train_rules
from repro.utils import shard_hints
from repro.optim.optimizers import OptState
from repro.train import server, trainer
from repro.utils import hlo as hlo_lib
from repro.utils.roofline import (
    RooflineReport, model_flops_per_step, ota_fused_cost,
)
from repro.utils.tree import tree_bytes


def _ns(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def input_specs(cfg, shape) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    return model_lib.abstract_inputs(cfg, shape)


def _default_microbatch(cfg, shape, n_agents: int) -> int:
    """1 sequence per agent per microbatch for big models (keeps the scanned
    remat carries bounded); single-shot for small ones."""
    per_agent = max(shape.global_batch // n_agents, 1)
    if cfg.d_model >= 3072 or shape.seq_len > 8192:
        return per_agent
    return 1


def build_train_lowering(cfg, shape, mesh: Mesh, *, aggregator: str = "ota",
                         microbatch: Optional[int] = None, fsdp: bool = True,
                         remat: Optional[bool] = None):
    model = model_lib.build(cfg if remat is None else cfg.with_(remat=remat))
    n_agents = n_data_shards(mesh)
    mb = microbatch or _default_microbatch(cfg, shape, n_agents)
    tcfg = trainer.TrainConfig(
        aggregator=aggregator, n_agents=n_agents, microbatch=mb,
        total_steps=10_000,
    )
    step = trainer.make_train_step(model, tcfg)

    rules = train_rules(fsdp=fsdp)
    pspecs = model.specs(rules, mesh)
    state_specs = trainer.TrainState(
        params=pspecs,
        opt_state=OptState(step=P(), mu=pspecs, nu=pspecs),
        step=P(),
    )
    batch_sh = make_batch_specs(cfg, shape, mesh)
    metric_specs = {k: P() for k in ("loss", "grad_norm", "gain_mean", "update_norm")}

    state_abs = jax.eval_shape(
        lambda k: trainer.init_state(model, tcfg, k), jax.eval_shape(lambda: jax.random.key(0))
    )
    batch_abs = input_specs(cfg, shape)
    key_abs = jax.eval_shape(lambda: jax.random.key(0))

    jitted = jax.jit(
        step,
        in_shardings=(_ns(mesh, state_specs), batch_sh, NamedSharding(mesh, P())),
        out_shardings=(_ns(mesh, state_specs), _ns(mesh, metric_specs)),
        donate_argnums=(0,),
    )
    with shard_hints.hints(mesh, **shard_hints.attn_hints(cfg, mesh, "train")):
        lowered = jitted.lower(state_abs, batch_abs, key_abs)
    return lowered, {"microbatch": mb, "n_agents": n_agents}


def build_prefill_lowering(cfg, shape, mesh: Mesh):
    model = model_lib.build(cfg.with_(remat=False))
    rules = serve_rules()
    pspecs = model.specs(rules, mesh)
    params_abs = model.abstract()
    batch_abs = input_specs(cfg, shape)
    batch_sh = make_batch_specs(cfg, shape, mesh)
    in_sh = [_ns(mesh, pspecs), batch_sh["tokens"]]
    args = [params_abs, batch_abs["tokens"]]
    if model_lib.needs_memory(cfg):
        in_sh.append(batch_sh["memory"])
        args.append(batch_abs["memory"])

    def prefill_step(params, tokens, memory=None):
        return model.prefill(params, tokens, memory)

    jitted = jax.jit(prefill_step, in_shardings=tuple(in_sh))
    with shard_hints.hints(mesh, **shard_hints.attn_hints(cfg, mesh, "prefill")):
        lowered = jitted.lower(*args)
    return lowered, {}


def build_decode_lowering(cfg, shape, mesh: Mesh):
    model = model_lib.build(cfg.with_(remat=False))
    rules = serve_rules()
    pspecs = model.specs(rules, mesh)
    params_abs = model.abstract()
    cache_abs = server.abstract_cache_for_shape(model, shape)
    cache_sp = server.cache_specs(cfg, shape, mesh)
    token_abs = input_specs(cfg, shape)["token"]
    b_entry = server._batch_entry(mesh, shape.global_batch)
    token_sh = NamedSharding(mesh, P(b_entry, None))

    step = server.make_serve_step(model, shape)
    jitted = jax.jit(
        step,
        in_shardings=(_ns(mesh, pspecs), _ns(mesh, cache_sp), token_sh),
        out_shardings=(token_sh, None, _ns(mesh, cache_sp)),
        donate_argnums=(1,),
    )
    return jitted.lower(params_abs, cache_abs, token_abs), {}


# ===========================================================================
# Cost calibration.
#
# XLA's cost_analysis counts a `while` body ONCE regardless of trip count, so
# the scanned layer stacks (and the microbatch accumulation loop) are
# undercounted.  We therefore lower shallow FULLY-UNROLLED variants with
# identical per-layer shapes, measure (flops, hbm_bytes, collective_bytes)
# vectors, solve the linear cost model
#
#     true = fixed + M * (micro_overhead + depth_terms(production depth))
#
# and extrapolate.  Depth knobs per family: plain layer count (dense/moe/
# ssm), (groups, period) for hybrid/vlm, (enc_layers, dec_layers) for encdec.
# ===========================================================================

from repro.utils import unroll as uscan


def _cost_dict(compiled) -> Dict[str, float]:
    """compiled.cost_analysis() normalised to one dict: some jax versions
    return a per-device list (identical SPMD programs — take the first)."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _cost_vec(compiled) -> np.ndarray:
    cost = _cost_dict(compiled)
    coll = hlo_lib.parse_collective_bytes(compiled.as_text())
    return np.array(
        [
            float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll.total_bytes),
        ]
    )


def _calib_shape(shape, global_batch: int):
    return dataclasses.replace(shape, global_batch=global_batch)


def _depth_points(cfg):
    """Calibration points + solver for the depth-linear cost model.

    Points avoid depth 1 — XLA makes pathologically different global
    optimisation choices for single-layer programs (verified empirically),
    so all measurements sit in the linear region (depths 2-4) and per-body
    costs come from finite differences there.  Returns (points, solve) where
    ``solve(U)`` yields {'D_a': depth cost at point a, 'D_prod': depth cost
    at production depth}.
    """
    fam = cfg.family
    if fam in ("dense", "moe", "ssm"):
        pts = {"a": cfg.with_(n_layers=2), "b": cfg.with_(n_layers=3)}

        def solve(U):
            pl = U["b"] - U["a"]
            return {"pl": pl, "D_a": 2 * pl, "D_prod": cfg.n_layers * pl}

        return pts, solve
    if fam == "hybrid":
        # group = P mamba sublayers + 1 shared attn block; D = G*(go + P*pl)
        pts = {
            "a": cfg.with_(n_layers=2, shared_attn_every=2),   # G1 P2
            "b": cfg.with_(n_layers=3, shared_attn_every=3),   # G1 P3
            "c": cfg.with_(n_layers=4, shared_attn_every=2),   # G2 P2
        }

        def solve(U):
            pl = U["b"] - U["a"]              # one extra mamba sublayer
            go = U["c"] - U["a"] - 2 * pl     # one extra group (shared block)
            g, t = divmod(cfg.n_layers, cfg.shared_attn_every)
            d = g * (go + cfg.shared_attn_every * pl) + t * pl
            return {"pl": pl, "go": go, "D_a": go + 2 * pl, "D_prod": d}

        return pts, solve
    if fam == "vlm":
        # group = (P-1) plain layers + 1 cross layer; D = G*(go + (P-1)*pl)
        pts = {
            "a": cfg.with_(n_layers=2, cross_attn_every=2),    # G1 P2
            "b": cfg.with_(n_layers=3, cross_attn_every=3),    # G1 P3
            "c": cfg.with_(n_layers=4, cross_attn_every=2),    # G2 P2
        }

        def solve(U):
            pl = U["b"] - U["a"]              # one extra plain sublayer
            go = U["c"] - U["a"] - pl         # one extra group (cross layer)
            g = cfg.n_layers // cfg.cross_attn_every
            d = g * (go + (cfg.cross_attn_every - 1) * pl)
            return {"pl": pl, "go": go, "D_a": go + pl, "D_prod": d}

        return pts, solve
    if fam == "encdec":
        pts = {
            "a": cfg.with_(encoder_layers=2, n_layers=2),
            "b": cfg.with_(encoder_layers=3, n_layers=2),
            "c": cfg.with_(encoder_layers=2, n_layers=3),
        }

        def solve(U):
            pe = U["b"] - U["a"]
            pd = U["c"] - U["a"]
            return {
                "pe": pe, "pd": pd, "D_a": 2 * pe + 2 * pd,
                "D_prod": cfg.encoder_layers * pe + cfg.n_layers * pd,
            }

        return pts, solve
    raise ValueError(fam)


def _depth_points_decode(cfg):
    """Decode runs no encoder, so encdec decode is depth-linear in n_layers."""
    if cfg.family == "encdec":
        pts = {"a": cfg.with_(n_layers=2), "b": cfg.with_(n_layers=3)}

        def solve(U):
            pl = U["b"] - U["a"]
            return {"pl": pl, "D_a": 2 * pl, "D_prod": cfg.n_layers * pl}

        return pts, solve
    return _depth_points(cfg)


def calibrated_costs(cfg, shape, mesh, *, aggregator: str = "ota",
                     microbatch: int = 1, fsdp: bool = True,
                     verbose: bool = False) -> Dict[str, float]:
    """Trip-count-corrected (flops, hbm bytes, collective bytes), per chip."""
    kind = shape.kind

    def measure(point_cfg, point_shape, mb):
        if kind == "train":
            lowered, _ = build_train_lowering(
                point_cfg, point_shape, mesh, aggregator=aggregator,
                microbatch=mb, fsdp=fsdp,
            )
        elif kind == "prefill":
            lowered, _ = build_prefill_lowering(point_cfg, point_shape, mesh)
        else:
            lowered, _ = build_decode_lowering(point_cfg, point_shape, mesh)
        return _cost_vec(lowered.compile())

    with uscan.unrolled():
        if kind == "train":
            # Measure with remat OFF (jax.checkpoint's recompute destabilises
            # XLA cost analysis); the production program's remat recompute is
            # one extra per-layer forward, approximated by scaling depth
            # terms by 4/3 (fwd:bwd = 2:4, +fwd recompute => 8/6).
            base_cfg = cfg.with_(remat=False)
            pts, solve = _depth_points(base_cfg)
            pb = shape.global_batch // microbatch   # sequences per microbatch
            sh1 = _calib_shape(shape, pb)
            U = {k: measure(c, sh1, 1) for k, c in pts.items()}
            comp = solve(U)
            base_a = U["a"] - comp["D_a"]            # fixed + micro_overhead
            if microbatch > 1:
                u_m2 = measure(pts["a"], _calib_shape(shape, 2 * pb), 2)
                mo = (u_m2 - U["a"]) - comp["D_a"]   # one more micro body
                fixed = base_a - mo
            else:
                mo, fixed = base_a, np.zeros(3)
            remat_scale = 4.0 / 3.0 if cfg.remat else 1.0
            true = fixed + microbatch * (mo + remat_scale * comp["D_prod"])
        else:
            pts, solve = (
                _depth_points_decode(cfg) if kind == "decode" else _depth_points(cfg)
            )
            U = {k: measure(c, shape, 1) for k, c in pts.items()}
            comp = solve(U)
            true = (U["a"] - comp["D_a"]) + comp["D_prod"]

    true = np.maximum(true, 0.0)
    out = {
        "flops": float(true[0]),
        "hbm_bytes": float(true[1]),
        "collective_bytes": float(true[2]),
    }
    if verbose:
        print(f"  calibrated: {out}")
    return out


def analyze(lowered, compiled, cfg, shape, mesh_name: str, n_chips: int,
            extra: Dict[str, Any],
            calibrated: Optional[Dict[str, float]] = None) -> Dict[str, Any]:
    cost = _cost_dict(compiled)
    try:
        mem = compiled.memory_analysis()
        mem_str = str(mem)
    except Exception as e:  # pragma: no cover - backend-dependent
        mem, mem_str = None, f"unavailable: {e}"
    coll = hlo_lib.parse_collective_bytes(compiled.as_text())
    if calibrated is not None:
        flops = calibrated["flops"]
        hbm_bytes = calibrated["hbm_bytes"]
        coll_bytes = calibrated["collective_bytes"]
    else:
        flops = float(cost.get("flops", 0.0))
        hbm_bytes = float(cost.get("bytes accessed", 0.0))
        coll_bytes = float(coll.total_bytes)

    total, active = cfg.param_counts()
    mf_total = model_flops_per_step(
        n_params_active=active,
        tokens=shape.tokens_per_step,
        training=shape.kind == "train",
    )
    report = RooflineReport(
        arch=cfg.arch_id,
        shape=shape.name,
        mesh=mesh_name,
        n_chips=n_chips,
        hlo_flops=flops,
        hlo_bytes=hbm_bytes,
        collective_bytes=coll_bytes,
        model_flops=mf_total / n_chips,
    ).finalize()

    # the uplink's own roofline: what the fused OTA kernel should cost on
    # this model vs the unfused XLA chain (benchmarks/ota_kernel.py measures
    # the same pair, so dry-run estimates and bench numbers line up)
    ota_est = None
    if shape.kind == "train":
        ota_est = ota_fused_cost(
            total, int(extra.get("n_agents", 1)), mode="adam")

    record = {
        "arch": cfg.arch_id,
        "shape": shape.name,
        "mesh": mesh_name,
        "kind": shape.kind,
        "n_chips": n_chips,
        "params_total": total,
        "params_active": active,
        "ota_fused_roofline": ota_est,
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "collectives_rolled": {
            "bytes_by_kind": coll.bytes_by_kind,
            "count_by_kind": coll.count_by_kind,
            "total_bytes": coll.total_bytes,
        },
        "calibrated": calibrated,
        "memory_analysis": mem_str,
        "roofline": report.row(),
        **extra,
    }
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            try:
                record.setdefault("memory", {})[attr] = int(getattr(mem, attr))
            except Exception:
                pass
    return record


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            tiny: int = 0, out_dir: str = "experiments/dryrun",
            aggregator: str = "ota", microbatch: Optional[int] = None,
            fsdp: bool = True, verbose: bool = True, calibrate: bool = True,
            mesh_shape: str = "", tag: str = "") -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if tiny:
        mesh = jax.make_mesh((tiny, tiny), ("data", "model"))
        mesh_name = f"tiny{tiny}x{tiny}"
    elif mesh_shape:
        # arch-adapted (data, model) factorisation of the same 256-chip pod
        # (beyond-paper perf lever — see EXPERIMENTS.md §Perf)
        d, m = (int(x) for x in mesh_shape.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        mesh_name = f"pod{d}x{m}"
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    n_chips = mesh.size

    t0 = time.time()
    if shape.kind == "train":
        mb = microbatch or _default_microbatch(cfg, shape, n_data_shards(mesh))
        lowered, extra = build_train_lowering(
            cfg, shape, mesh, aggregator=aggregator, microbatch=mb,
            fsdp=fsdp,
        )
    elif shape.kind == "prefill":
        mb = 1
        lowered, extra = build_prefill_lowering(cfg, shape, mesh)
    else:
        mb = 1
        lowered, extra = build_decode_lowering(cfg, shape, mesh)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    calib = None
    t_calib = 0.0
    if calibrate:
        t0 = time.time()
        calib = calibrated_costs(
            cfg, shape, mesh, aggregator=aggregator, microbatch=mb, fsdp=fsdp,
        )
        t_calib = time.time() - t0

    record = analyze(lowered, compiled, cfg, shape, mesh_name, n_chips, extra,
                     calibrated=calib)
    record["t_lower_s"] = round(t_lower, 2)
    record["t_compile_s"] = round(t_compile, 2)
    record["t_calibrate_s"] = round(t_calib, 2)
    record["aggregator"] = aggregator if shape.kind == "train" else None

    if verbose:
        print(record["memory_analysis"])
        print({k: v for k, v in record["cost_analysis"].items()})
        r = record["roofline"]
        print(
            f"[{arch} x {shape_name} x {mesh_name}] lower={t_lower:.1f}s "
            f"compile={t_compile:.1f}s compute={r['compute_s']*1e3:.3f}ms "
            f"memory={r['memory_s']*1e3:.3f}ms coll={r['collective_s']*1e3:.3f}ms "
            f"dominant={r['dominant']} useful={r['useful_flop_ratio']:.3f}"
        )

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fn = f"{arch}_{shape_name}_{mesh_name}{suffix}.json".replace("/", "_")
        with open(os.path.join(out_dir, fn), "w") as f:
            json.dump(record, f, indent=1)
    return record


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tiny", type=int, default=0,
                    help="use a (tiny x tiny) mesh instead of production")
    ap.add_argument("--mesh-shape", default="",
                    help="arch-adapted (data x model) pod factorisation, "
                         "e.g. 32x8 (same 256 chips)")
    ap.add_argument("--aggregator", default="ota", choices=("ota", "exact"))
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip the trip-count cost calibration (multi-pod "
                         "compile-proof runs don't need rooflines)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                combos.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape)]

    failures = []
    for arch, shape in combos:
        try:
            run_one(
                arch, shape, multi_pod=args.multi_pod, tiny=args.tiny,
                out_dir=args.out, aggregator=args.aggregator,
                microbatch=args.microbatch, fsdp=not args.no_fsdp,
                calibrate=not (args.no_calibrate or args.multi_pod),
                mesh_shape=args.mesh_shape, tag=args.tag,
            )
        except Exception:
            print(f"FAILED: {arch} x {shape}")
            traceback.print_exc()
            failures.append((arch, shape))
    if failures:
        print("failures:", failures)
        return 1
    print(f"all {len(combos)} combination(s) lowered + compiled OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
