"""Paper Figs. 1-2: effect of batch size M and agent count N under the
Rayleigh channel (alpha = 1e-4 in the paper; we use a slightly larger step
and fewer MC runs to fit the CPU budget — trends, not absolute values, are
the claim)."""
from __future__ import annotations

import time

from repro.configs.ota_pg_particle import RAYLEIGH
from repro.core.channel import make_channel
from repro.core.ota import OTAConfig
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy

from benchmarks.common import avg_grad_sq, emit, final_reward, run_setting

SETTINGS = [  # (N, M)
    (1, 10), (5, 10), (10, 10),   # N sweep at M=10  (Fig. 2 linear speedup)
    (10, 1), (10, 5),             # M sweep at N=10  (Fig. 1)
]


def run(mc_runs: int = 5, n_rounds: int = 250, alpha: float = 1e-3):
    env, pol = LandmarkNav(), MLPPolicy()
    ota = OTAConfig(
        channel=make_channel(RAYLEIGH.channel, **dict(RAYLEIGH.channel_kwargs)),
        noise_sigma=RAYLEIGH.noise_sigma,
        debias=True,
    )
    results = {}
    for n, m in SETTINGS:
        cfg = RAYLEIGH.fedpg(n_agents=n, batch_m=m, n_rounds=n_rounds)
        cfg = type(cfg)(**{**cfg.__dict__, "alpha": alpha})
        t0 = time.perf_counter()
        rewards, grad_sq = run_setting(env, pol, cfg, ota, mc_runs)
        dt = (time.perf_counter() - t0) * 1e6
        results[(n, m)] = (final_reward(rewards), avg_grad_sq(grad_sq))
        emit(
            f"fig12_rayleigh_N{n}_M{m}", dt / mc_runs,
            f"reward={results[(n, m)][0]:.3f};avg_grad_sq={results[(n, m)][1]:.4f}",
        )

    # derived claims
    g = {k: v[1] for k, v in results.items()}
    n_speedup = g[(1, 10)] / max(g[(10, 10)], 1e-9)
    m_effect = g[(10, 1)] / max(g[(10, 10)], 1e-9)
    emit(
        "fig2_linear_speedup_N1_over_N10", 0.0,
        f"ratio={n_speedup:.2f};claim=decreases_in_N;"
        f"pass={g[(1,10)] > g[(5,10)] > g[(10,10)]}",
    )
    emit(
        "fig1_batch_effect_M1_over_M10", 0.0,
        f"ratio={m_effect:.2f};claim=decreases_in_M;"
        f"pass={g[(10,1)] > g[(10,10)]}",
    )
    return g
