"""Paper Figs. 1-2: effect of batch size M and agent count N under the
Rayleigh channel (alpha = 1e-4 in the paper; we use a slightly larger step
and fewer MC runs to fit the CPU budget — trends, not absolute values, are
the claim).

Declarative grid + post-processing table over the scenario-sweep engine:
each (N, M) point is its own structural shape, so the engine compiles one
program per point and reproduces the per-scenario path bit-for-bit.
"""
from __future__ import annotations

from repro.configs.ota_pg_particle import RAYLEIGH
from repro.core.channel import make_channel
from repro.core.sweep import Scenario
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy

from benchmarks.common import emit, run_sweep

SETTINGS = [  # (N, M)
    (1, 10), (5, 10), (10, 10),   # N sweep at M=10  (Fig. 2 linear speedup)
    (10, 1), (10, 5),             # M sweep at N=10  (Fig. 1)
]


def scenarios(n_rounds: int, alpha: float):
    channel = make_channel(RAYLEIGH.channel, **dict(RAYLEIGH.channel_kwargs))
    return [
        Scenario(
            channel=channel, noise_sigma=RAYLEIGH.noise_sigma, alpha=alpha,
            n_agents=n, batch_m=m, horizon=RAYLEIGH.horizon,
            gamma=RAYLEIGH.gamma, n_rounds=n_rounds, debias=True,
            tag=f"N{n}_M{m}",
        )
        for n, m in SETTINGS
    ]


def run(mc_runs: int = 5, n_rounds: int = 250, alpha: float = 1e-3):
    env, pol = LandmarkNav(), MLPPolicy()
    scens = scenarios(n_rounds, alpha)
    res = run_sweep(env, pol, scens, mc_runs)

    results = {}
    for i, (n, m) in enumerate(SETTINGS):
        results[(n, m)] = (res.final_reward(i), res.avg_grad_sq(i))
        emit(
            f"fig12_rayleigh_N{n}_M{m}", res.scenario_time_us(i),
            f"reward={results[(n, m)][0]:.3f};avg_grad_sq={results[(n, m)][1]:.4f}",
        )

    # derived claims
    g = {k: v[1] for k, v in results.items()}
    n_speedup = g[(1, 10)] / max(g[(10, 10)], 1e-9)
    m_effect = g[(10, 1)] / max(g[(10, 10)], 1e-9)
    emit(
        "fig2_linear_speedup_N1_over_N10", 0.0,
        f"ratio={n_speedup:.2f};claim=decreases_in_N;"
        f"pass={g[(1,10)] > g[(5,10)] > g[(10,10)]}",
    )
    emit(
        "fig1_batch_effect_M1_over_M10", 0.0,
        f"ratio={m_effect:.2f};claim=decreases_in_M;"
        f"pass={g[(10,1)] > g[(10,10)]}",
    )
    emit("fig12_sweep_compiles", 0.0,
         f"partitions={res.n_partitions};scenarios={len(scens)}")
    return g
