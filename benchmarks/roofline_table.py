"""Render the roofline table from the dry-run JSON records (§Roofline).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and emits
one row per (arch x shape x mesh) with the three terms, the dominant
bottleneck, and the useful-FLOP ratio.  No compilation happens here."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def run(dryrun_dir: str = "experiments/dryrun"):
    files = sorted(glob.glob(os.path.join(dryrun_dir, "*.json")))
    if not files:
        emit("roofline_table", 0.0, "status=no_dryrun_records_found")
        return
    n = 0
    for path in files:
        rec = json.load(open(path))
        r = rec["roofline"]
        if rec.get("calibrated") is None and rec["mesh"].startswith("pod2x"):
            # multi-pod records are compile-proof only
            emit(
                f"dryrun_{rec['arch']}_{rec['shape']}_{rec['mesh']}",
                0.0,
                f"status=compiled;chips={rec['n_chips']}",
            )
            continue
        emit(
            f"roofline_{rec['arch']}_{rec['shape']}_{rec['mesh']}",
            r["step_time_s"] * 1e6,
            f"dominant={r['dominant']};compute_ms={r['compute_s']*1e3:.2f};"
            f"memory_ms={r['memory_s']*1e3:.2f};"
            f"collective_ms={r['collective_s']*1e3:.2f};"
            f"useful_ratio={r['useful_flop_ratio']:.3f};mfu={r['mfu']:.4f}",
        )
        ota = rec.get("ota_fused_roofline")
        if ota:
            emit(
                f"ota_fused_{rec['arch']}_{rec['shape']}_{rec['mesh']}",
                ota["fused_s"] * 1e6,
                f"xla_us={ota['xla_s']*1e6:.1f};"
                f"speedup_est={ota['speedup_est']:.2f};"
                f"agents={ota['n_agents']};mode={ota['mode']}",
            )
        n += 1
    emit("roofline_table_rows", 0.0, f"count={n}")
