"""Shared helpers for the benchmark harness.

Importing this module applies the process platform config (see
``repro.utils.platform``): ``REPRO_EMULATED_DEVICES=8`` runs the same
benches on an emulated 8-device CPU mesh that a real accelerator job runs
on hardware — no per-job ``XLA_FLAGS`` surgery.

Timing runs through ``repro.telemetry.trace``: ``time_call`` returns a
:class:`~repro.telemetry.trace.Timing` (a float carrying ``compile_us`` /
``run_us``) and every call lands as ``compile:<name>`` / ``run:<name>``
spans in the process trace, exportable with ``benchmarks.run --trace``.
``emit`` rows are dicts with those fields and mirror to the ambient run
ledger when ``--ledger`` installed one.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

from repro.utils import platform as rplat  # pre-jax: may set device flags

rplat.apply_emulated_devices()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.telemetry import get_ledger  # noqa: E402
from repro.telemetry import trace as rtrace  # noqa: E402

# structured row records; formatted only at print time so consumers (the
# --json export, the run ledger) never re-parse CSV strings
ROWS: List[Dict[str, Any]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    row: Dict[str, Any] = {"name": name, "us_per_call": float(us_per_call),
                           "derived": derived}
    # Timing (from time_call) carries the compile/run split; a bare float
    # (derived rates, totals) leaves the fields absent.
    if isinstance(us_per_call, rtrace.Timing):
        row["run_us"] = us_per_call.run_us
        if us_per_call.compile_us is not None:
            row["compile_us"] = us_per_call.compile_us
    ROWS.append(row)
    led = get_ledger()
    if led is not None:
        led.event("bench_row", **row)
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 5,
              name: Optional[str] = None) -> rtrace.Timing:
    """Median wall time per call in microseconds (blocks on jax arrays).

    Returns a :class:`~repro.telemetry.trace.Timing`: the median run time
    as a plain float, with the first-warmup (compile) time on
    ``.compile_us``.  Both phases land as spans named after ``fn`` (or
    ``name=``).
    """
    return rtrace.timed_call(
        fn, *args, warmup=warmup, iters=iters,
        block=jax.block_until_ready, name=name)


def run_setting(env, pol, cfg, ota, mc_runs: int, seed: int = 0):
    """Monte Carlo fedpg histories (vmapped); returns (rewards, grad_sq).

    The naive per-scenario path — one fresh XLA program per call.  Kept as
    the reference the sweep engine is tested bit-identical against; new
    benchmarks should declare a scenario grid and use ``run_sweep``.
    """
    from repro.core import fedpg

    hist = fedpg.monte_carlo(env, pol, cfg, jax.random.key(seed), mc_runs,
                             ota=ota)
    return hist.rewards, hist.grad_sq


def run_sweep(env, pol, scenarios, mc_runs: int, seed: int = 0):
    """Run a declarative scenario list through the batched sweep engine.

    One compiled program per structural partition; every scenario shares the
    Monte-Carlo key set of ``jax.random.key(seed)`` — the same keys the
    per-scenario ``run_setting(..., seed=seed)`` calls would use.  The
    result is mirrored to the ambient run ledger when one is installed.
    """
    from repro.core.sweep import sweep

    res = sweep(env, pol, scenarios, jax.random.key(seed), mc_runs)
    led = get_ledger()
    if led is not None:
        led.log_sweep(res)
    return res


def final_reward(rewards: jnp.ndarray, tail: int = 20) -> float:
    return float(jnp.mean(rewards[:, -tail:]))


def avg_grad_sq(grad_sq: jnp.ndarray) -> float:
    """(1/K) sum_k E||grad J||^2, averaged over MC runs (paper Fig. 2/5)."""
    return float(jnp.mean(grad_sq))
