"""Shared helpers for the benchmark harness.

Importing this module applies the process platform config (see
``repro.utils.platform``): ``REPRO_EMULATED_DEVICES=8`` runs the same
benches on an emulated 8-device CPU mesh that a real accelerator job runs
on hardware — no per-job ``XLA_FLAGS`` surgery.
"""
from __future__ import annotations

import time
from typing import Callable, List

from repro.utils import platform as rplat  # pre-jax: may set device flags

rplat.apply_emulated_devices()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

# structured (name, us_per_call, derived) records; formatted only at print
# time so consumers (e.g. the --json export) never re-parse CSV strings
ROWS: List[tuple] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time per call in microseconds (blocks on jax arrays)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def run_setting(env, pol, cfg, ota, mc_runs: int, seed: int = 0):
    """Monte Carlo fedpg histories (vmapped); returns (rewards, grad_sq).

    The naive per-scenario path — one fresh XLA program per call.  Kept as
    the reference the sweep engine is tested bit-identical against; new
    benchmarks should declare a scenario grid and use ``run_sweep``.
    """
    from repro.core import fedpg

    hist = fedpg.monte_carlo(env, pol, cfg, jax.random.key(seed), mc_runs,
                             ota=ota)
    return hist.rewards, hist.grad_sq


def run_sweep(env, pol, scenarios, mc_runs: int, seed: int = 0):
    """Run a declarative scenario list through the batched sweep engine.

    One compiled program per structural partition; every scenario shares the
    Monte-Carlo key set of ``jax.random.key(seed)`` — the same keys the
    per-scenario ``run_setting(..., seed=seed)`` calls would use.
    """
    from repro.core.sweep import sweep

    return sweep(env, pol, scenarios, jax.random.key(seed), mc_runs)


def final_reward(rewards: jnp.ndarray, tail: int = 20) -> float:
    return float(jnp.mean(rewards[:, -tail:]))


def avg_grad_sq(grad_sq: jnp.ndarray) -> float:
    """(1/K) sum_k E||grad J||^2, averaged over MC runs (paper Fig. 2/5)."""
    return float(jnp.mean(grad_sq))
