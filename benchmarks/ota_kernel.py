"""Fused OTA kernel vs XLA fusion: the tentpole's honest benchmark.

For each parameter size, times the full uplink — gain matvec over the
(N, P) gradient stack, AWGN, debias scale, SGD parameter update — three
ways:

* ``xla``           — the dispatcher's XLA op chain (what golden traces pin),
* ``pallas``        — the fused kernel (compiled on TPU; interpret mode on
  CPU, where the timing is a correctness harness, not a speed claim),
* ``pallas_bf16``   — the fused kernel with the bf16 wire format.

Each row carries the analytic roofline expectation from
``utils.roofline.ota_fused_cost`` so the measured CPU numbers ship next to
the modelled TPU numbers the dry-run reports.  Emits rows consumed by
``benchmarks/run.py --json`` → ``BENCH_ota_kernel.json`` in CI.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ota
from repro.core.channel import RayleighChannel
from repro.utils.roofline import ota_fused_cost

from benchmarks.common import emit, time_call

# (name, n_params) — ≥3 sizes so the crossover (if any) is visible
SIZES = (
    ("64k", 1 << 16),
    ("512k", 1 << 19),
    ("2M", 1 << 21),
)
QUICK_SIZES = SIZES[:3]  # quick mode trims iterations, not coverage


def _setup(n_params: int, n_agents: int):
    g = {"w": jax.random.normal(jax.random.key(0), (n_agents, n_params),
                                jnp.float32) * 1e-2}
    p = {"w": jnp.zeros((n_params,), jnp.float32)}
    return g, p


def run(quick: bool = False, n_agents: int = 8):
    on_tpu = jax.default_backend() == "tpu"
    iters = 2 if quick else 5
    cfg = ota.OTAConfig(channel=RayleighChannel(), noise_sigma=1e-2,
                        debias=True)
    cfg_bf16 = ota.OTAConfig(channel=RayleighChannel(), noise_sigma=1e-2,
                             debias=True, wire_dtype="bfloat16")
    key = jax.random.key(7)

    for name, n_params in (QUICK_SIZES if quick else SIZES):
        grads, params = _setup(n_params, n_agents)
        est = ota_fused_cost(n_params, n_agents, mode="sgd")
        est_bf16 = ota_fused_cost(n_params, n_agents, wire_bytes=2,
                                  mode="sgd")

        def bench(backend, c, tag, est_row):
            fn = jax.jit(lambda k: ota.aggregate_apply(
                grads, c, params, key=k, alpha=1e-3, backend=backend)[0])
            us = time_call(fn, key, iters=iters)
            n_bytes = n_agents * n_params * 4
            emit(
                f"ota_uplink_{tag}_{name}",
                us,
                f"agents={n_agents};params={n_params};bytes={n_bytes};"
                f"backend={backend};compiled={on_tpu or backend == 'xla'};"
                f"tpu_roofline_us={est_row['fused_s'] * 1e6:.2f};"
                f"tpu_xla_roofline_us={est_row['xla_s'] * 1e6:.2f};"
                f"tpu_speedup_est={est_row['speedup_est']:.2f}",
            )
            return us

        us_xla = bench("xla", cfg, "xla", est)
        # interpret mode on CPU: correctness-harness timing only
        us_pl = bench("pallas", cfg, "pallas", est)
        bench("pallas", cfg_bf16, "pallas_bf16", est_bf16)
        emit(
            f"ota_uplink_ratio_{name}",
            0.0,
            f"measured_xla_over_pallas={us_xla / us_pl:.3f};"
            f"modelled_tpu_speedup={est['speedup_est']:.2f};"
            f"note={'compiled' if on_tpu else 'pallas_is_interpret_mode'}",
        )
