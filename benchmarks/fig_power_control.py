"""Beyond-figure grid: transmit-power control as a first-class sweep axis.

The paper's Theorems 1/2 are stated in terms of the effective-gain pair
(m_h, sigma_h^2); the OTA-FL literature (Cao et al., Fan et al.) shows the
transmit-power policy is the main lever on that pair.  This suite sweeps a
policy grid over the Rayleigh base channel on a tabular MDP with computable
constants and emits, per scenario:

* the simulated average squared gradient norm (the paper's Fig. 2/5 metric),
* the tightest applicable Theorem-1/2 bound evaluated with the *effective*
  moments, and
* the K -> inf variance floor — the "power control moves the
  channel-variance floor" story in one table: inversion policies shrink
  sigma_h^2/m_h^2 and with it the floor; phase-aware constant-received
  power kills the channel term entirely.

Policy-parameter lanes (the TruncatedInversion target axis) batch into one
compiled program via the sweep engine's ControlledChannel packing.
"""
from __future__ import annotations

import math

import jax

from repro.core import theory
from repro.core.channel import RayleighChannel
from repro.core.power_control import (
    ConstantReceived, FullInversion, HeterogeneousBudget, TruncatedInversion,
    make_controlled_channel,
)
from repro.core.sweep import Scenario
from repro.rl.env import TabularMDP
from repro.rl.policy import TabularSoftmaxPolicy

from benchmarks.common import emit, run_sweep

N_AGENTS, BATCH_M = 8, 4


def _policies():
    """(tag, policy-or-None) grid; None = no power control (h = c)."""
    return [
        ("unit", None),
        ("trunc_inv_t0.8", TruncatedInversion(target=0.8)),
        ("trunc_inv_t1.0", TruncatedInversion(target=1.0)),
        ("trunc_inv_t1.2", TruncatedInversion(target=1.2)),
        ("full_inv", FullInversion(target=1.0)),
        ("const_recv", ConstantReceived(target=1.0)),
        ("hetero_budget", HeterogeneousBudget(p_min=0.5, p_max=1.5)),
    ]


def scenarios(n_rounds: int, mdp, consts):
    base = RayleighChannel()
    out = []
    for tag, pol in _policies():
        ch = base if pol is None else make_controlled_channel(
            base, pol, n_agents=N_AGENTS)
        alpha = min(1e-2, consts.max_stepsize(float(ch.mean)))
        out.append(Scenario(
            channel=ch, noise_sigma=1e-3, alpha=alpha, n_agents=N_AGENTS,
            batch_m=BATCH_M, horizon=mdp.horizon, gamma=mdp.gamma,
            n_rounds=n_rounds, debias=True, tag=tag,
        ))
    return out


def run(n_rounds: int = 120, mc_runs: int = 3):
    mdp = TabularMDP.random(jax.random.key(0), n_states=3, n_actions=2,
                            gamma=0.9, horizon=3)
    pol = TabularSoftmaxPolicy(3, 2)
    consts = theory.MDPConstants(G=math.sqrt(2.0), F=0.5, l_bar=1.0, gamma=0.9)
    V = consts.V()
    delta_j = 1.0 / (1 - 0.9)

    scens = scenarios(n_rounds, mdp, consts)
    res = run_sweep(mdp, pol, scens, mc_runs, seed=1)

    floors = {}
    for i, s in enumerate(scens):
        m_h, v_h = s.effective_moments()
        which, bound = theory.applicable_bound(
            K=n_rounds, n_agents=N_AGENTS, batch_m=BATCH_M, alpha=s.alpha,
            m_h=m_h, sigma_h2=v_h, noise_sigma2=1e-6, delta_J=delta_j, V=V,
        )
        floor = (theory.theorem1_floor if which == "theorem1"
                 else theory.theorem2_floor)(
            n_agents=N_AGENTS, batch_m=BATCH_M, m_h=m_h, sigma_h2=v_h,
            noise_sigma2=1e-6, V=V,
        )
        floors[s.tag] = floor
        empirical = res.avg_grad_sq(i)
        emit(
            f"fig_pc_{s.tag}", res.scenario_time_us(i),
            f"avg_grad_sq={empirical:.4f};bound={bound:.4f};which={which};"
            f"m_h_eff={m_h:.4f};sigma_h2_eff={v_h:.5f};floor={floor:.5f};"
            f"holds={bool(empirical <= bound)}",
        )

    # the story: channel inversion shrinks the variance floor, exact
    # phase-aware inversion (sigma_h^2 = 0) leaves only the noise term
    emit(
        "fig_pc_floor_moves", 0.0,
        f"unit={floors['unit']:.5f};trunc={floors['trunc_inv_t1.0']:.5f};"
        f"const={floors['const_recv']:.6f};"
        f"pass={bool(floors['const_recv'] < floors['trunc_inv_t1.0'] < floors['unit'])}",
    )
    emit("fig_pc_sweep_compiles", 0.0,
         f"partitions={res.n_partitions};scenarios={len(scens)}")
    return floors
