"""Scaling in N: streamed (``agent_blocks``) vs stacked round memory.

The paper's regime is a *massive* fleet (Section I: the motivation is
"a huge number of agents"); the stacked round materialises the full
(N, M, H, ...) trajectory batch and the (N, d) gradient stack, so its
peak temp memory grows with N x d and a 10^5-agent fleet blows past any
accelerator's HBM.  The blocked-scan streamed form keeps only one
O(agent_blocks x d) block live at a time; the only O(N) state left is
the per-agent PRNG key material (8 B/agent plus padding copies — the
price of keeping the key streams bitwise-identical to the stacked form).

For each fleet size this bench compiles both forms and reads the XLA
``memory_analysis`` (no execution needed for the memory claim — the
stacked 10^5 program is compiled but only *executed* where it is cheap),
then times the streamed form for throughput.  Emits rows consumed by
``benchmarks/run.py --json`` → ``BENCH_large_n.json`` in CI:

* ``large_n_streamed_{N}`` — measured wall time, temp/arg/out bytes,
  rounds/s and agent-rounds/s,
* ``large_n_stacked_{N}``  — temp bytes (executed only when cheap),
* ``large_n_summary``      — the per-agent temp-byte comparison at the
  largest N and the streamed temp ratio across the N range.

On a multi-device host (``REPRO_EMULATED_DEVICES=8``) one extra row runs
the composed shard_map + streaming path at the smoke fleet size.
"""
from __future__ import annotations

import jax

from repro.core import fedpg, ota
from repro.core.channel import RayleighChannel
from repro.rl.envs import make_env

from benchmarks.common import emit, time_call

# full tier covers the paper-motivating 10^5 fleet; quick (CI smoke) stops
# at 10^4 — coverage of the scaling trend, not the headline point
SIZES = (100, 1_000, 10_000, 100_000)
QUICK_SIZES = SIZES[:3]

AGENT_BLOCKS = 32
# executing the stacked form past this N costs real time/memory without
# adding information: memory_analysis comes from the compile alone
STACKED_EXEC_LIMIT = 1_000


def _mem(compiled):
    ma = compiled.memory_analysis()
    return (int(ma.temp_size_in_bytes), int(ma.argument_size_in_bytes),
            int(ma.output_size_in_bytes))


def run(quick: bool = False):
    env = make_env("landmark")
    policy = env.default_policy()
    ota_cfg = ota.OTAConfig(channel=RayleighChannel(), noise_sigma=1e-3,
                            debias=True)
    key = jax.random.key(3)
    sizes = QUICK_SIZES if quick else SIZES
    # quick stops at 10^4 agents, so 2 rounds stays cheap there; the full
    # tier runs a single round to keep the 10^5 execution bounded
    n_rounds = 2 if quick else 1

    temps = {}
    for n in sizes:
        cfg = fedpg.FedPGConfig(n_agents=n, batch_m=1, horizon=3,
                                n_rounds=n_rounds)

        # one fresh program per fleet size IS the experiment (its compile is
        # excluded from the timing; memory_analysis needs the executable)
        streamed = jax.jit(lambda k, c=cfg: fedpg.run(  # repro: noqa[jit-in-loop]
            env, policy, c, k, ota=ota_cfg, agent_blocks=AGENT_BLOCKS))
        comp = streamed.lower(key).compile()
        temp, arg, out = _mem(comp)
        temps[("streamed", n)] = temp
        us = time_call(comp, key, iters=1 if n >= 10_000 else 3)
        rounds_per_s = n_rounds / (float(us) * 1e-6)
        emit(
            f"large_n_streamed_{n}",
            us,
            f"agents={n};agent_blocks={AGENT_BLOCKS};rounds={n_rounds};"
            f"temp_bytes={temp};arg_bytes={arg};out_bytes={out};"
            f"temp_bytes_per_agent={temp / n:.1f};"
            f"rounds_per_s={rounds_per_s:.2f};"
            f"agent_rounds_per_s={rounds_per_s * n:.0f}",
        )

        stacked = jax.jit(lambda k, c=cfg: fedpg.run(  # repro: noqa[jit-in-loop]
            env, policy, c, k, ota=ota_cfg))
        comp_s = stacked.lower(key).compile()
        temp_s, _, _ = _mem(comp_s)
        temps[("stacked", n)] = temp_s
        executed = n <= STACKED_EXEC_LIMIT
        us_s = time_call(comp_s, key, iters=3) if executed else 0.0
        emit(
            f"large_n_stacked_{n}",
            us_s,
            f"agents={n};rounds={n_rounds};temp_bytes={temp_s};"
            f"temp_bytes_per_agent={temp_s / n:.1f};"
            f"executed={executed};"
            f"note={'timed' if executed else 'memory_analysis_only'}",
        )

    n_max, n_min = max(sizes), min(sizes)
    emit(
        "large_n_summary",
        0.0,
        f"n_max={n_max};"
        f"stacked_over_streamed_temp_at_n_max="
        f"{temps[('stacked', n_max)] / temps[('streamed', n_max)]:.1f};"
        f"streamed_temp_growth_{n_min}_to_{n_max}="
        f"{temps[('streamed', n_max)] / temps[('streamed', n_min)]:.1f};"
        f"stacked_temp_growth_{n_min}_to_{n_max}="
        f"{temps[('stacked', n_max)] / temps[('stacked', n_min)]:.1f};"
        f"note=streamed_growth_is_per_agent_key_material_only",
    )

    if jax.device_count() >= 2:
        from repro.core import distribute

        n = 10_000
        mesh = distribute.agent_mesh_for(jax.device_count())
        cfg = fedpg.FedPGConfig(n_agents=n + 1, batch_m=1, horizon=3,
                                n_rounds=n_rounds)  # non-dividing: padded
        fn = jax.jit(lambda k: fedpg.run(
            env, policy, cfg, k, ota=ota_cfg, agent_mesh=mesh,
            agent_blocks=AGENT_BLOCKS))
        comp = fn.lower(key).compile()
        temp, _, _ = _mem(comp)
        us = time_call(comp, key, iters=1)
        emit(
            f"large_n_sharded_streamed_{n + 1}",
            us,
            f"agents={n + 1};shards={jax.device_count()};"
            f"agent_blocks={AGENT_BLOCKS};temp_bytes={temp};"
            f"padded=True",
        )
