"""Microbenchmarks: OTA aggregation forms and kernel-vs-ref timings (CPU
wall time; kernel interpret mode is a correctness harness, not a speed
claim — the derived column carries the analytic TPU expectation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ota
from repro.core.channel import RayleighChannel
from repro.kernels import ops, ref
from repro.utils.roofline import HBM_BW

from benchmarks.common import emit, time_call


def run():
    # --- OTA aggregation over a 1M-param gradient set --------------------
    n_agents = 16
    grads = {
        "w1": jnp.ones((n_agents, 512, 512), jnp.float32),
        "w2": jnp.ones((n_agents, 512, 1488), jnp.float32),
    }
    cfg = ota.OTAConfig(channel=RayleighChannel(), noise_sigma=1e-3,
                        debias=True)

    agg = jax.jit(
        lambda k: ota.aggregate(grads, cfg, key=k, backend="xla")[0])
    us = time_call(agg, jax.random.key(0))
    n_bytes = sum(x.size * 4 for x in grads.values())
    emit("ota_aggregate_stacked_1M", us,
         f"agents={n_agents};bytes={n_bytes};"
         f"tpu_mem_bound_est_us={n_bytes / HBM_BW * 1e6:.1f}")

    exact = jax.jit(lambda: ota.aggregate(grads, None)[0])
    emit("exact_aggregate_1M", time_call(exact),
         "baseline=algorithm1_mean")

    # --- fused OTA server update (Pallas) vs unfused jnp ------------------
    v = jnp.ones((4096, 1024), jnp.float32)
    fused = lambda: ops.ota_update(v, sigma=1e-3, n_agents=16, m_h=1.25,
                                   use_pallas=True)
    unfused = lambda: ops.ota_update(v, sigma=1e-3, n_agents=16, m_h=1.25,
                                     use_pallas=False)
    emit("ota_update_pallas_interpret_16MB", time_call(fused, iters=3),
         "hbm_passes=2(fused)")
    emit("ota_update_jnp_ref_16MB", time_call(unfused, iters=3),
         "hbm_passes=4(noise_materialised)")

    # --- attention: ref path timing + kernel check ------------------------
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 512, 64))
    k = jax.random.normal(ks[1], (1, 2, 512, 64))
    vv = jax.random.normal(ks[2], (1, 2, 512, 64))
    ref_fn = jax.jit(lambda: ref.flash_attention_ref(q, k, vv))
    emit("attention_ref_jnp_512", time_call(ref_fn),
         "oracle=materialised_scores")
    pallas_fn = lambda: ops.attention(q, k, vv, use_pallas=True)
    emit("attention_pallas_interpret_512", time_call(pallas_fn, iters=2),
         "mode=interpret(correctness_only)")

    # --- SSD scan ----------------------------------------------------------
    b, s, h, p, g, n = 1, 512, 4, 64, 1, 64
    kk = jax.random.split(jax.random.key(2), 5)
    x = jax.random.normal(kk[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(kk[1], (b, s, h))) * 0.1
    A = -jnp.exp(jax.random.uniform(kk[2], (h,)))
    B = jax.random.normal(kk[3], (b, s, g, n)) * 0.5
    C = jax.random.normal(kk[4], (b, s, g, n)) * 0.5
    ssd_ref_fn = jax.jit(lambda: ref.ssd_ref(x, dt, A, B, C, 128))
    emit("ssd_ref_jnp_512", time_call(ssd_ref_fn), "chunk=128")
    ssd_pl = lambda: ops.ssd(x, dt, A, B, C, chunk=128, use_pallas=True)
    emit("ssd_pallas_interpret_512", time_call(ssd_pl, iters=2),
         "mode=interpret(correctness_only)")
