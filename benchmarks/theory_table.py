"""Theorem 1/2 right-hand sides vs the simulated average squared gradient
norm on a tabular MDP with computable constants — the bounds must hold.

The two channel settings are declared as a scenario grid on the sweep
engine (one compiled program per channel family); the bound evaluation is
a pure post-processing table."""
from __future__ import annotations

import math

import jax

from repro.core import theory
from repro.core.channel import NakagamiChannel, RayleighChannel
from repro.core.power_control import TruncatedInversion, make_controlled_channel
from repro.core.sweep import Scenario
from repro.rl.env import TabularMDP
from repro.rl.policy import TabularSoftmaxPolicy

from benchmarks.common import emit, run_sweep


def run(n_rounds: int = 150, mc_runs: int = 3):
    mdp = TabularMDP.random(jax.random.key(0), n_states=3, n_actions=2,
                            gamma=0.9, horizon=3)
    pol = TabularSoftmaxPolicy(3, 2)
    consts = theory.MDPConstants(G=math.sqrt(2.0), F=0.5, l_bar=1.0, gamma=0.9)
    V = consts.V()
    delta_j = 1.0 / (1 - 0.9)  # J in [0, l_bar/(1-gamma)]
    n_agents, batch_m = 8, 4

    channels = [
        (RayleighChannel(), "rayleigh", 1),
        (NakagamiChannel(m=0.1, omega=1.0), "nakagami", 2),
        # power-controlled effective gain: the bound is evaluated with the
        # *effective* (m_h, sigma_h^2) the ControlledChannel carries
        (make_controlled_channel(RayleighChannel(), TruncatedInversion()),
         "rayleigh_trunc_inv", 1),
    ]
    scens = [
        Scenario(
            channel=ch, noise_sigma=1e-3,
            alpha=min(1e-2, consts.max_stepsize(ch.mean)),
            n_agents=n_agents, batch_m=batch_m, horizon=mdp.horizon,
            gamma=mdp.gamma, n_rounds=n_rounds, debias=True, tag=name,
        )
        for ch, name, _ in channels
    ]
    res = run_sweep(mdp, pol, scens, mc_runs, seed=1)

    for i, (ch, name, thm) in enumerate(channels):
        empirical = res.avg_grad_sq(i)
        kw = dict(
            K=n_rounds, n_agents=n_agents, batch_m=batch_m,
            alpha=scens[i].alpha, m_h=ch.mean, sigma_h2=ch.var,
            noise_sigma2=1e-6, delta_J=delta_j, V=V,
        )
        bound = (theory.theorem1_bound(**kw) if thm == 1
                 else theory.theorem2_bound(**kw))
        emit(
            f"theory_thm{thm}_{name}", res.scenario_time_us(i),
            f"empirical={empirical:.4f};bound={bound:.4f};"
            f"alpha={scens[i].alpha:.2e};holds={bool(empirical <= bound)}",
        )

    # Corollary 1 schedule table
    for eps in (1e-1, 1e-2, 1e-3):
        s = theory.corollary1_schedule(eps)
        emit(
            f"corollary1_eps{eps:g}", 0.0,
            f"K={s.K};N={s.n_agents};M={s.batch_m};"
            f"KM_per_agent={s.total_trajectories}",
        )
