"""Round-service benchmark: participation rate × staleness at N=10^4.

The paper's Algorithm 2 is fully synchronous; ``repro.service`` relaxes
it to partial/stale/faulty participation (the regime any real 10^4-agent
OTA deployment actually runs in).  Two measurements:

* **rate × staleness sweep** — the streamed (``agent_blocks``) service
  round through the sweep engine: Bernoulli rates batch as lanes of one
  compiled partition per staleness setting, each row carrying the
  realised participation rate, the realised-vs-expected debias drift and
  the mean replayed age from the in-jit telemetry probes, plus a
  full-participation baseline row (which normalises to the *plain*
  streamed round — same program, zero service overhead).
* **driver acceptance run** — :class:`repro.service.driver.RoundService`
  at N=10^4 with 50% Bernoulli participation AND straggler deadline
  closure, streaming via ``agent_blocks``: commit-segment wall time and
  the ledger's participation telemetry (the commit records land as
  ``service`` events on the ambient ledger installed by
  ``benchmarks/run.py --ledger``; render with
  ``python -m repro.telemetry.report``).

Emits rows consumed by ``benchmarks/run.py --json`` →
``BENCH_participation.json`` in CI's ``service`` job.
"""
from __future__ import annotations

import jax

from repro.core import fedpg
from repro.core.channel import RayleighChannel
from repro.core.ota import OTAConfig
from repro.core.sweep import grid, sweep
from repro.rl.envs import make_env
from repro.service.driver import RoundService, ServiceConfig
from repro.service.faults import FaultConfig, StragglerModel
from repro.service.participation import ParticipationConfig
from repro.service.staleness import StalenessConfig
from repro.telemetry.probes import TelemetryConfig

from benchmarks.common import emit

N_AGENTS = 10_000
AGENT_BLOCKS = 64
RATES = (0.25, 0.5)
STALE = (None, StalenessConfig(max_age=4, decay=0.8))


def run(quick: bool = False):
    env = make_env("landmark")
    policy = env.default_policy()
    ota_cfg = OTAConfig(channel=RayleighChannel(), noise_sigma=1e-3,
                        debias=True)
    key = jax.random.key(7)
    n_rounds = 2 if quick else 5
    common = dict(channel=[RayleighChannel()], noise_sigma=1e-3, debias=True,
                  n_agents=N_AGENTS, batch_m=1, horizon=3, n_rounds=n_rounds,
                  agent_blocks=AGENT_BLOCKS)

    # -- rate x staleness sweep, one sweep per staleness setting: the
    #    telemetry stack keeps a field only when every scenario carries
    #    it, and stale/non-stale are separate compile partitions anyway -
    for stale in STALE:
        scenarios = grid(staleness=stale,
                         participation=[ParticipationConfig(rate=r)
                                        for r in RATES], **common)
        res = sweep(env, policy, scenarios, key, mc_runs=1,
                    telemetry=TelemetryConfig())
        max_age = 0 if stale is None else stale.max_age
        for i, s in enumerate(res.scenarios):
            tel = res.telemetry_summary(i) or {}
            emit(
                f"participation_rate{s.participation.rate:g}_stale{max_age}",
                res.scenario_time_us(i),
                f"agents={N_AGENTS};agent_blocks={AGENT_BLOCKS};"
                f"rounds={n_rounds};rate={s.participation.rate:g};"
                f"max_age={max_age};avg_grad_sq={res.avg_grad_sq(i):.4g};"
                f"part_rate="
                f"{tel.get('participation_rate', float('nan')):.4g};"
                f"drift={tel.get('participation_drift', float('nan')):.4g};"
                f"stale_mean={tel.get('staleness_mean', float('nan')):.4g}",
            )
    # full-participation baseline: normalises away, runs the plain
    # streamed round (the zero-overhead contract), in its own sweep so
    # the service sweep's telemetry stack keeps its service fields
    base = grid(participation=[ParticipationConfig(kind="full")], **common)
    bres = sweep(env, policy, base, key, mc_runs=1,
                 telemetry=TelemetryConfig())
    emit(
        "participation_rate1_baseline",
        bres.scenario_time_us(0),
        f"agents={N_AGENTS};agent_blocks={AGENT_BLOCKS};rounds={n_rounds};"
        f"rate=1;avg_grad_sq={bres.avg_grad_sq(0):.4g};"
        "note=normalises_to_plain_streamed_round",
    )

    # -- the driver acceptance run: 50% Bernoulli + straggler deadline
    #    closure, streamed, commit telemetry on the ambient ledger -------
    p = ParticipationConfig(rate=0.5, faults=FaultConfig(
        stragglers=StragglerModel(dist="exp", mean=1.0), deadline=2.0))
    cfg = fedpg.FedPGConfig(n_agents=N_AGENTS, batch_m=1, horizon=3,
                            n_rounds=1)
    svc = RoundService(
        env, policy, cfg, key, participation=p,
        staleness=StalenessConfig(max_age=4, decay=0.8), ota=ota_cfg,
        telemetry=TelemetryConfig(), agent_blocks=AGENT_BLOCKS,
        service=ServiceConfig(rounds_per_commit=2,
                              max_rounds=4 if quick else 8,
                              round_deadline_s=600.0))
    records = svc.run()
    last = records[-1]
    emit(
        "participation_service_driver",
        sum(r["wall_us"] for r in records),
        f"agents={N_AGENTS};agent_blocks={AGENT_BLOCKS};"
        f"rounds={last['round_end']};commits={len(records)};"
        f"rate=0.5;deadline=2;"
        f"part_rate={last.get('participation_rate', float('nan')):.4g};"
        f"drift={last.get('participation_drift', float('nan')):.4g};"
        f"staleness_hist={last.get('staleness_hist')}",
    )
