"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; ``--json PATH`` additionally
writes them as a JSON list (the CI bench-smoke artifact, so the perf
trajectory is recorded per run).  Every row now carries the compile/run
split from ``repro.telemetry.trace.timed_call``; ``--trace PATH`` exports
the span tree as Chrome trace JSON (load in Perfetto) and ``--ledger
PATH`` streams rows/platform/compile-counts as a JSONL run ledger
(render with ``python -m repro.telemetry.report``).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig12,...]
                                            [--json BENCH_smoke.json]
                                            [--trace TRACE_bench.json]
                                            [--ledger LEDGER.jsonl]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from benchmarks import (  # noqa: E402
    et_baseline, fig12_rayleigh, fig3_vs_vanilla, fig45_nakagami,
    fig_env_zoo, fig_large_n, fig_participation, fig_power_control,
    fig_scaling, microbench, ota_kernel, roofline_table, theory_table,
)
from benchmarks.common import ROWS, emit
from repro.telemetry import Ledger, set_ledger
from repro.telemetry import trace as rtrace

SUITES = {
    "fig12": lambda quick: fig12_rayleigh.run(
        mc_runs=2 if quick else 5, n_rounds=120 if quick else 250),
    "fig3": lambda quick: fig3_vs_vanilla.run(
        mc_runs=2 if quick else 5, n_rounds=120 if quick else 250),
    "fig45": lambda quick: fig45_nakagami.run(
        mc_runs=2 if quick else 5, n_rounds=120 if quick else 250),
    "theory": lambda quick: theory_table.run(
        n_rounds=80 if quick else 150, mc_runs=2 if quick else 3),
    "power": lambda quick: fig_power_control.run(
        n_rounds=80 if quick else 120, mc_runs=2 if quick else 3),
    "et": lambda quick: et_baseline.run(n_rounds=100 if quick else 200),
    "envs": lambda quick: fig_env_zoo.run(
        n_rounds=40 if quick else 120, mc_runs=2 if quick else 3),
    # meaningful on a multi-device (or emulated: XLA_FLAGS=
    # --xla_force_host_platform_device_count=8) mesh; see fig_scaling.py
    "scaling": lambda quick: fig_scaling.run(
        n_rounds=30 if quick else 60, lanes=8 if quick else 16),
    "micro": lambda quick: microbench.run(),
    "roofline": lambda quick: roofline_table.run(),
    # fused OTA kernel vs the XLA chain (BENCH_ota_kernel.json in CI)
    "ota_kernel": lambda quick: ota_kernel.run(quick=quick),
    # streamed vs stacked round memory/throughput (BENCH_large_n.json in CI)
    "large_n": lambda quick: fig_large_n.run(quick=quick),
    # round-service rate x staleness sweep + the N=10^4 driver run
    # (BENCH_participation.json in CI's service job)
    "participation": lambda quick: fig_participation.run(quick=quick),
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of " + ",".join(SUITES))
    ap.add_argument("--json", default="",
                    help="also write the result rows as JSON to this path")
    ap.add_argument("--trace", default="",
                    help="export the span tree as Chrome trace JSON here")
    ap.add_argument("--ledger", default="",
                    help="stream a JSONL run ledger to this path")
    args = ap.parse_args()

    ledger = None
    if args.ledger:
        ledger = Ledger(args.ledger)
        ledger.log_platform()
        set_ledger(ledger)

    names = [n for n in args.only.split(",") if n] or list(SUITES)
    print("name,us_per_call,derived")
    t0 = time.time()
    failures = []
    try:
        for name in names:
            with rtrace.span(f"suite:{name}"):
                try:
                    if ledger is not None:
                        with ledger.count_compiles(label=name):
                            SUITES[name](args.quick)
                    else:
                        SUITES[name](args.quick)
                except Exception as e:  # keep the harness running
                    failures.append(name)
                    emit(f"{name}_FAILED", 0.0,
                         f"error={type(e).__name__}:{e}")
        emit("total_wall", (time.time() - t0) * 1e6, f"suites={len(names)}")
    finally:
        if args.trace:
            rtrace.export(args.trace)
        if ledger is not None:
            set_ledger(None)
            ledger.close()
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suites": names, "failures": failures,
                       "rows": ROWS}, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
