"""Paper Fig. 3: over-the-air federated PG vs vanilla (exact-uplink)
G(PO)MDP — same order of convergence, fewer channel uses.

Communication accounting: vanilla TDMA/FDMA needs N orthogonal channel uses
per round; OTA needs 1.  We report the reward trajectories' agreement and
the derived channel-use ratio.

Declared as a two-scenario sweep (OTA Rayleigh uplink vs ``channel=None``
exact uplink) over the scenario-sweep engine."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.ota_pg_particle import RAYLEIGH
from repro.core.channel import make_channel
from repro.core.sweep import Scenario
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy

from benchmarks.common import emit, final_reward, run_sweep


def scenarios(n_rounds: int, n_agents: int, batch_m: int, alpha: float):
    base = dict(
        noise_sigma=RAYLEIGH.noise_sigma, alpha=alpha, n_agents=n_agents,
        batch_m=batch_m, horizon=RAYLEIGH.horizon, gamma=RAYLEIGH.gamma,
        n_rounds=n_rounds,
    )
    return [
        Scenario(channel=make_channel("rayleigh"), debias=True, tag="ota",
                 **base),
        Scenario(channel=None, tag="vanilla", **base),
    ]


def run(mc_runs: int = 5, n_rounds: int = 250, n_agents: int = 10,
        batch_m: int = 10, alpha: float = 1e-3):
    env, pol = LandmarkNav(), MLPPolicy()
    scens = scenarios(n_rounds, n_agents, batch_m, alpha)
    res = run_sweep(env, pol, scens, mc_runs, seed=1)

    i_ota, i_van = res.index(tag="ota"), res.index(tag="vanilla")
    r_ota = jnp.asarray(res.history.rewards[i_ota])
    r_van = jnp.asarray(res.history.rewards[i_van])
    f_ota, f_van = final_reward(r_ota), final_reward(r_van)
    # iterations to reach 90% of the vanilla final improvement
    base = float(jnp.mean(r_van[:, :10]))
    target = base + 0.9 * (f_van - base)
    mean_ota = jnp.mean(r_ota, axis=0)
    mean_van = jnp.mean(r_van, axis=0)

    def first_hit(traj):
        hits = jnp.nonzero(traj >= target, size=1, fill_value=n_rounds)[0]
        return int(hits[0])

    it_ota, it_van = first_hit(mean_ota), first_hit(mean_van)
    emit("fig3_ota_federated_pg", res.scenario_time_us(i_ota),
         f"final_reward={f_ota:.3f};iters_to_90pct={it_ota};channel_uses_per_round=1")
    emit("fig3_vanilla_gpomdp", res.scenario_time_us(i_van),
         f"final_reward={f_van:.3f};iters_to_90pct={it_van};channel_uses_per_round={n_agents}")
    same_order = it_ota <= 2 * max(it_van, 1)
    emit(
        "fig3_same_order_convergence", 0.0,
        f"iters_ratio={it_ota / max(it_van, 1):.2f};"
        f"comm_saving={n_agents}x;pass={bool(same_order)}",
    )
    return {"ota": (f_ota, it_ota), "vanilla": (f_van, it_van)}
