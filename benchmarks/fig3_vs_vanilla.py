"""Paper Fig. 3: over-the-air federated PG vs vanilla (exact-uplink)
G(PO)MDP — same order of convergence, fewer channel uses.

Communication accounting: vanilla TDMA/FDMA needs N orthogonal channel uses
per round; OTA needs 1.  We report the reward trajectories' agreement and
the derived channel-use ratio."""
from __future__ import annotations

import time

import jax.numpy as jnp

from repro.configs.ota_pg_particle import RAYLEIGH
from repro.core.channel import make_channel
from repro.core.ota import OTAConfig
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy

from benchmarks.common import emit, final_reward, run_setting


def run(mc_runs: int = 5, n_rounds: int = 250, n_agents: int = 10,
        batch_m: int = 10, alpha: float = 1e-3):
    env, pol = LandmarkNav(), MLPPolicy()
    cfg = RAYLEIGH.fedpg(n_agents=n_agents, batch_m=batch_m, n_rounds=n_rounds)
    cfg = type(cfg)(**{**cfg.__dict__, "alpha": alpha})
    ota = OTAConfig(
        channel=make_channel("rayleigh"), noise_sigma=RAYLEIGH.noise_sigma,
        debias=True,
    )

    t0 = time.perf_counter()
    r_ota, g_ota = run_setting(env, pol, cfg, ota, mc_runs, seed=1)
    dt_ota = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    r_van, g_van = run_setting(env, pol, cfg, None, mc_runs, seed=1)
    dt_van = (time.perf_counter() - t0) * 1e6

    f_ota, f_van = final_reward(r_ota), final_reward(r_van)
    # iterations to reach 90% of the vanilla final improvement
    base = float(jnp.mean(r_van[:, :10]))
    target = base + 0.9 * (f_van - base)
    mean_ota = jnp.mean(r_ota, axis=0)
    mean_van = jnp.mean(r_van, axis=0)

    def first_hit(traj):
        hits = jnp.nonzero(traj >= target, size=1, fill_value=n_rounds)[0]
        return int(hits[0])

    it_ota, it_van = first_hit(mean_ota), first_hit(mean_van)
    emit("fig3_ota_federated_pg", dt_ota / mc_runs,
         f"final_reward={f_ota:.3f};iters_to_90pct={it_ota};channel_uses_per_round=1")
    emit("fig3_vanilla_gpomdp", dt_van / mc_runs,
         f"final_reward={f_van:.3f};iters_to_90pct={it_van};channel_uses_per_round={n_agents}")
    same_order = it_ota <= 2 * max(it_van, 1)
    emit(
        "fig3_same_order_convergence", 0.0,
        f"iters_ratio={it_ota / max(it_van, 1):.2f};"
        f"comm_saving={n_agents}x;pass={bool(same_order)}",
    )
    return {"ota": (f_ota, it_ota), "vanilla": (f_van, it_van)}
