"""Paper Figs. 4-5: Nakagami-m (m=0.1, Omega=1; sigma_h^2 = 10 m_h^2)
degrades convergence relative to Rayleigh, and increasing M is less
effective (Theorem 2's channel-variance floor).

Declared as a {Nakagami, Rayleigh} x {M=1, M=10} grid over the
scenario-sweep engine, plus the direct Lemma-3 aggregation-error floor."""
from __future__ import annotations

from repro.configs.ota_pg_particle import NAKAGAMI, RAYLEIGH
from repro.core.channel import make_channel
from repro.core.sweep import Scenario
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy

from benchmarks.common import emit, run_sweep


def scenarios(n_rounds: int, n_agents: int, alpha: float = 1e-3):
    scens = []
    for setting in (NAKAGAMI, RAYLEIGH):
        ch = make_channel(setting.channel, **dict(setting.channel_kwargs))
        for m in (1, 10):
            scens.append(Scenario(
                channel=ch, noise_sigma=setting.noise_sigma, alpha=alpha,
                n_agents=n_agents, batch_m=m, horizon=setting.horizon,
                gamma=setting.gamma, n_rounds=n_rounds, debias=True,
                tag=f"{setting.name}_M{m}",
            ))
    return scens


def run(mc_runs: int = 5, n_rounds: int = 250, n_agents: int = 10):
    env, pol = LandmarkNav(), MLPPolicy()
    scens = scenarios(n_rounds, n_agents)
    res = run_sweep(env, pol, scens, mc_runs, seed=2)

    out = {}
    for i, s in enumerate(scens):
        name, m = s.tag.rsplit("_M", 1)
        out[(name, int(m))] = (res.final_reward(i), res.avg_grad_sq(i))
        emit(
            f"fig45_{s.tag}", res.scenario_time_us(i),
            f"reward={out[(name, int(m))][0]:.3f};"
            f"avg_grad_sq={out[(name, int(m))][1]:.4f}",
        )

    nak_worse = out[("nakagami", 10)][0] < out[("rayleigh", 10)][0] + 0.05
    m_gain_ray = out[("rayleigh", 1)][1] / max(out[("rayleigh", 10)][1], 1e-9)
    m_gain_nak = out[("nakagami", 1)][1] / max(out[("nakagami", 10)][1], 1e-9)
    emit(
        "fig4_nakagami_degrades", 0.0,
        f"nak_reward={out[('nakagami', 10)][0]:.3f};"
        f"ray_reward={out[('rayleigh', 10)][0]:.3f};pass={bool(nak_worse)}",
    )
    # Trajectory-level M-gains are sampling-noise dominated at this K (the
    # reward metric never sees the channel); informational only.
    emit(
        "fig5_trajectory_M_gains", 0.0,
        f"M_gain_rayleigh={m_gain_ray:.2f};M_gain_nakagami={m_gain_nak:.2f};"
        f"note=informational",
    )
    floor = aggregation_error_floor(n_agents=n_agents)
    # Remark 3 / Fig. 5: "the sampling processes play no role in reducing
    # the effect caused by the randomness of the channels" — the Nakagami
    # aggregation-error penalty factor over Rayleigh persists as M grows
    # (increasing the batch cannot buy back the channel), so M is strictly
    # less effective under Nakagami.
    penalty_m1 = floor[("nakagami", 1)] / max(floor[("rayleigh", 1)], 1e-9)
    penalty_m10 = floor[("nakagami", 10)] / max(floor[("rayleigh", 10)], 1e-9)
    emit(
        "fig5_batch_less_effective_under_nakagami", 0.0,
        f"aggerr_nak_over_ray_M1={penalty_m1:.2f};"
        f"aggerr_nak_over_ray_M10={penalty_m10:.2f};"
        f"claim=channel_penalty_not_reduced_by_M;"
        f"pass={bool(penalty_m10 > 0.5 * penalty_m1 and penalty_m10 > 3.0)}",
    )
    return out


def aggregation_error_floor(n_agents: int = 10, n_draws: int = 400):
    """Theorem 2's mechanism, measured directly: the Lemma-3 aggregation
    error E||v/(m_h N) - grad J||^2 at a fixed policy for (channel x M).
    The sigma_h^2/m_h^2 factor (0.27 Rayleigh vs 10 Nakagami) multiplies the
    per-agent estimate second moment, so the Nakagami error sits ~37x higher
    at every M — increasing the batch cannot recover the Rayleigh regime
    (Remark 3's floor in its empirically dominant form)."""
    import jax
    import jax.numpy as jnp

    from repro.core import gpomdp
    from repro.core import ota
    from repro.core.ota import OTAConfig
    from repro.rl.sampler import rollout_batch
    from repro.utils.tree import tree_global_norm_sq, tree_sub

    env, pol = LandmarkNav(), MLPPolicy()
    theta = pol.init(jax.random.key(0))

    # reference grad J from a very large batch (the Lemma-3 comparison point)
    @jax.jit
    def big_grad(k):
        traj = rollout_batch(env, pol, theta, k, 20, 4096)
        return gpomdp.gpomdp_gradient(pol, theta, traj, 0.99)

    refs = jax.vmap(big_grad)(jax.random.split(jax.random.key(9), 8))
    g_ref = jax.tree.map(lambda x: jnp.mean(x, 0), refs)

    out = {}
    for setting in (RAYLEIGH, NAKAGAMI):
        ch = make_channel(setting.channel, **dict(setting.channel_kwargs))
        cfg_ota = OTAConfig(channel=ch, noise_sigma=setting.noise_sigma,
                            debias=True)
        for m in (1, 10):
            @jax.jit
            def one(k, m=m):
                k1, k2 = jax.random.split(k)

                def agent(ka):
                    traj = rollout_batch(env, pol, theta, ka, 20, m)
                    return gpomdp.gpomdp_gradient(pol, theta, traj, 0.99)

                grads = jax.vmap(agent)(jax.random.split(k1, n_agents))
                u, _ = ota.aggregate(grads, cfg_ota, key=k2, backend="xla")
                return tree_global_norm_sq(tree_sub(u, g_ref))

            e = jax.vmap(one)(jax.random.split(jax.random.key(3), n_draws))
            out[(setting.name, m)] = float(jnp.mean(e))
    return out
