"""Beyond-figure comparison: OTA vs the event-triggered (LAPG-style [16])
communication-efficient baseline the paper's introduction argues against.

Metric: channel uses per round at matched convergence.  Event-triggered
uploads still need one orthogonal channel use per *uploading agent*; OTA
needs exactly 1 per round regardless of N — the paper's scaling argument."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.ota_pg_particle import RAYLEIGH
from repro.core import fedpg
from repro.core.channel import make_channel
from repro.core.event_triggered import ETConfig, run_jit as et_run
from repro.core.ota import OTAConfig
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy

from repro.telemetry import trace as rtrace

from benchmarks.common import emit


def run(n_rounds: int = 200, n_agents: int = 20, batch_m: int = 5,
        alpha: float = 3e-3):
    env, pol = LandmarkNav(), MLPPolicy()
    cfg = fedpg.FedPGConfig(n_agents=n_agents, batch_m=batch_m,
                            n_rounds=n_rounds, alpha=alpha)
    ota = OTAConfig(channel=make_channel("rayleigh"),
                    noise_sigma=RAYLEIGH.noise_sigma, debias=True)

    # spans time dispatch (not materialisation) — same semantics as the
    # raw-clock version this replaced
    with rtrace.span("et_vs_ota:ota") as sp:
        _, h_ota = fedpg.run_jit(env, pol, cfg, jax.random.key(0), ota=ota)
    dt_ota = sp.duration_us

    results = {"ota": (float(jnp.mean(h_ota.rewards[-20:])), 1.0)}
    emit("et_vs_ota_ota", dt_ota,
         f"final_reward={results['ota'][0]:.3f};channel_uses_per_round=1.0")

    for tau in (0.01, 0.1):
        with rtrace.span(f"et_vs_ota:et_tau{tau:g}") as sp:
            _, h_et = et_run(env, pol, cfg, ETConfig(tau=tau),
                             jax.random.key(0))
        dt = sp.duration_us
        rew = float(jnp.mean(h_et.rewards[-20:]))
        uses = float(jnp.mean(h_et.uploads))
        results[f"et_{tau}"] = (rew, uses)
        emit(
            f"et_vs_ota_eventtrig_tau{tau:g}", dt,
            f"final_reward={rew:.3f};channel_uses_per_round={uses:.1f}",
        )

    # the paper's scaling argument: ET channel cost grows with N, OTA's is 1
    et_uses = results["et_0.01"][1]
    emit(
        "et_vs_ota_scaling_claim", 0.0,
        f"N={n_agents};et_uses={et_uses:.1f};ota_uses=1;"
        f"pass={bool(et_uses > 3.0)}",
    )
    return results
