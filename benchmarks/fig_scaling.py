"""Strong/weak-scaling table for the sharded sweep engine.

Times ONE structural partition's compiled program — the (lanes x mc_runs)
batch that ``sweep(..., mode="sharded")`` lays across the device mesh — at
growing device counts (1, 2, 4, ..., all), so compile time is excluded and
the numbers isolate execution scaling:

* **strong scaling**: a fixed >=8-lane partition on more and more devices
  (speedup = t_1dev / t_Ndev; the acceptance row
  ``fig_scaling_speedup_max`` reports the aggregate throughput ratio vs
  single-device);
* **weak scaling**: lanes proportional to devices (per-lane throughput
  should stay ~flat).

Meaningful numbers need real or emulated devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.fig_scaling [--quick]

(on 1 device the table still runs and reports ratio 1.0).  Note emulated
host devices share the machine's cores, so emulated speedups are bounded by
physical parallelism, not by 8.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_call
from repro.core.channel import RayleighChannel
from repro.core.distribute import place_partition
from repro.core.sweep import _make_lane, _pack_partition, grid, partition_scenarios
from repro.launch.mesh import make_sweep_mesh
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy

N_AGENTS, BATCH_M, HORIZON = 4, 4, 10


def _device_counts(n: int):
    out, d = [], 1
    while d < n:
        out.append(d)
        d *= 2
    out.append(n)
    return out


def _partition_program(n_lanes: int, n_rounds: int, mesh):
    """(jitted, placed_packed, placed_keys) for one n_lanes-wide partition."""
    scens = grid(
        channel=RayleighChannel(),
        noise_sigma=[1e-3 * (i + 1) for i in range(n_lanes)],
        n_agents=N_AGENTS, batch_m=BATCH_M, horizon=HORIZON,
        n_rounds=n_rounds, debias=True,
    )
    part = partition_scenarios(scens)[0]
    packed = _pack_partition(part)
    lane = _make_lane(LandmarkNav(), MLPPolicy(), part)
    keys = jax.random.split(jax.random.key(0), 2)
    jitted, placed, keys_p, _ = place_partition(lane, packed, keys, mesh,
                                                donate=False)
    return jitted, placed, keys_p


def run(n_rounds: int = 60, lanes: int = 16):
    devices = jax.devices()
    counts = _device_counts(len(devices))
    emit("fig_scaling_devices", 0.0,
         f"available={len(devices)};platform={devices[0].platform}")

    # ---- strong scaling: fixed lanes, growing mesh -----------------------
    t_by_count = {}
    for d in counts:
        mesh = make_sweep_mesh(lane_shards=d, devices=devices[:d])
        jitted, placed, keys_p = _partition_program(lanes, n_rounds, mesh)
        t = time_call(jitted, placed, keys_p, warmup=1, iters=3)
        t_by_count[d] = t
        emit(f"fig_scaling_strong_d{d}", t,
             f"lanes={lanes};speedup_vs_1={t_by_count[counts[0]] / t:.3f}")

    # ---- weak scaling: lanes proportional to devices ---------------------
    for d in counts:
        mesh = make_sweep_mesh(lane_shards=d, devices=devices[:d])
        lanes_d = 2 * d
        jitted, placed, keys_p = _partition_program(lanes_d, n_rounds, mesh)
        t = time_call(jitted, placed, keys_p, warmup=1, iters=3)
        emit(f"fig_scaling_weak_d{d}", t,
             f"lanes={lanes_d};us_per_lane={t / lanes_d:.1f}")

    # ---- the acceptance row: aggregate throughput ratio vs 1 device ------
    # best multi-device ratio: emulated host devices beyond the physical
    # core count oversubscribe (d8 on a 2-core runner can lose to d4), so
    # the honest aggregate claim is the best mesh size the hardware carries
    multi = {d: t_by_count[1] / t_by_count[d] for d in counts if d > 1}
    d_best = max(multi, key=multi.get) if multi else 1
    ratio = multi.get(d_best, 1.0)
    emit("fig_scaling_speedup_max", t_by_count.get(d_best, t_by_count[1]),
         f"devices={d_best};lanes={lanes};throughput_ratio={ratio:.3f};"
         f"pass={bool(not multi or ratio > 1.0)}")
    return ratio


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(n_rounds=30 if args.quick else 60, lanes=8 if args.quick else 16)
