"""Beyond-figure grid: the environment zoo as a first-class sweep axis.

The paper evaluates one MDP (the landmark particle task, Section IV); the
over-the-air FL literature stresses workload diversity and per-client
heterogeneity.  This suite runs an env-family x channel grid through the
scenario-sweep engine — each (family, uplink) pair is one structural
partition / one compiled program, and same-family env *parameters* (the
wind axis) batch as lanes inside a single program:

* the paper's ``LandmarkNav`` (anchor) plus windy / multi-landmark
  variants, ``CliffWalk``, a Garnet tabular MDP, and continuous-action LQR
  under ``GaussianPolicy``;
* one *heterogeneous-agent* scenario: a ``HeterogeneousEnv`` fleet where
  every federated agent flies in its own wind while sharing the policy;
* a theory row for the landmark family built with
  ``theory.constants_for_env`` so the Assumption-1 envelope tracks the
  *configured* horizon (``l_bar_for``), not the paper's fixed T=20.

    PYTHONPATH=src python -m benchmarks.fig_env_zoo [--quick]
"""
from __future__ import annotations

import math

import jax

from repro.core import theory
from repro.core.channel import RayleighChannel
from repro.core.sweep import Scenario, sweep
from repro.rl.env import LandmarkNav
from repro.rl.envs import (
    CliffWalk, LQRTask, MultiLandmarkNav, WindyLandmarkNav, garnet,
    make_heterogeneous_env,
)

from benchmarks.common import emit

N_AGENTS, BATCH_M, HORIZON = 4, 4, 10


def _families(n_agents: int):
    """(tag, env) rows of the zoo; one per structural family."""
    return [
        ("landmark", LandmarkNav()),
        ("windy", WindyLandmarkNav(wind=0.05, gust_sigma=0.02)),
        ("multi", MultiLandmarkNav(n_landmarks=3)),
        ("cliff", CliffWalk(width=5, height=3, slip=0.1)),
        ("lqr", LQRTask()),
        ("garnet", garnet(jax.random.key(0), n_states=6, n_actions=3,
                          branching=2)),
        ("hetero_windy", make_heterogeneous_env(
            [WindyLandmarkNav(wind=0.02 * i) for i in range(n_agents)])),
    ]


def scenarios(n_rounds: int):
    base = dict(n_agents=N_AGENTS, batch_m=BATCH_M, horizon=HORIZON,
                n_rounds=n_rounds, alpha=1e-3, debias=True)
    out = []
    for tag, env in _families(N_AGENTS):
        # exact (Algorithm 1) and Rayleigh OTA (Algorithm 2) uplinks
        out.append(Scenario(env=env, channel=None, tag=f"{tag}_exact", **base))
        out.append(Scenario(env=env, channel=RayleighChannel(),
                            noise_sigma=1e-3, tag=f"{tag}_rayleigh", **base))
    # same-family env-parameter lanes: three winds, ONE compiled program
    out.extend(
        Scenario(env=WindyLandmarkNav(wind=w), channel=RayleighChannel(),
                 noise_sigma=1e-3, tag=f"windlane_{w:g}", **base)
        for w in (0.0, 0.05, 0.1)
    )
    return out


def run(n_rounds: int = 120, mc_runs: int = 3):
    scens = scenarios(n_rounds)
    res = sweep(None, None, scens, jax.random.key(1), mc_runs)

    for i, s in enumerate(scens):
        emit(
            f"fig_env_{s.tag}", res.scenario_time_us(i),
            f"env={s.describe()['env']};channel={s.describe()['channel']};"
            f"final_reward={res.final_reward(i, tail=10):.4f};"
            f"avg_grad_sq={res.avg_grad_sq(i):.4f}",
        )

    # the engine story: 7 families x 2 uplinks + a 3-lane wind axis compile
    # far fewer programs than the 17 scenarios
    emit("fig_env_zoo_compiles", 0.0,
         f"partitions={res.n_partitions};scenarios={len(scens)};"
         f"pass={bool(res.n_partitions < len(scens))}")

    # theory satellite: the landmark envelope follows the CONFIGURED horizon
    env = LandmarkNav()
    consts = theory.constants_for_env(env, horizon=HORIZON, gamma=0.99,
                                      G=math.sqrt(2.0), F=0.5)
    stale = env.l_bar  # the fixed-T=20 legacy envelope
    emit(
        "fig_env_lbar_threading", 0.0,
        f"l_bar_T{HORIZON}={consts.l_bar:.4f};l_bar_T20={stale:.4f};"
        f"V={consts.V():.4f};"
        f"pass={bool(consts.l_bar == env.l_bar_for(HORIZON) != stale)}",
    )
    return res


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(n_rounds=40 if args.quick else 120, mc_runs=2 if args.quick else 3)
