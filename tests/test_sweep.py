"""Scenario-sweep engine: batched grids must reproduce the per-scenario
``fedpg.monte_carlo`` path bit-for-bit under the same PRNG keys while
compiling strictly fewer XLA programs, and the declarative grid / result
containers must round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fedpg
from repro.core.channel import (
    BatchedChannel, FixedGainChannel, LogNormalChannel, NakagamiChannel,
    RayleighChannel, batched_channel_arrays, channel_kind,
)
from repro.core.ota import OTAConfig, aggregate_stacked, sample_gains
from repro.core.power_control import TruncatedInversion, UnitPower
from repro.core.sweep import (
    Scenario, SweepResult, grid, partition_scenarios, sweep,
)
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy

SMALL = dict(n_agents=4, batch_m=3, horizon=8, n_rounds=5, debias=True)


@pytest.fixture(scope="module")
def env_pol():
    return LandmarkNav(), MLPPolicy()


def _hist_equal(a: fedpg.History, b: fedpg.History) -> bool:
    return all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(a, b)
    )


# ---------------------------------------------------------------------------
# grid construction + partitioning
# ---------------------------------------------------------------------------

def test_grid_product_and_scalars():
    scens = grid(
        channel=[RayleighChannel(), NakagamiChannel(m=0.1, omega=1.0)],
        noise_sigma=[1e-3, 1e-2],
        alpha=1e-3,          # scalar: fixed setting, not an axis
        n_agents=4,
    )
    assert len(scens) == 4
    assert all(s.alpha == 1e-3 and s.n_agents == 4 for s in scens)


def test_grid_rejects_unknown_axis():
    with pytest.raises(ValueError, match="unknown scenario axes"):
        grid(chanel=[RayleighChannel()])


def test_partitioning_by_structure():
    scens = grid(
        channel=RayleighChannel(), noise_sigma=[1e-3, 1e-2],
        alpha=[1e-3, 1e-4], n_agents=[2, 4],
    )
    parts = partition_scenarios(scens)
    # noise/alpha are continuous; n_agents is structural => 2 partitions.
    assert len(parts) == 2
    assert sorted(len(p.scenarios) for p in parts) == [4, 4]
    # channel family and exact-vs-OTA are structural
    mixed = [Scenario(channel=RayleighChannel(), **{}),
             Scenario(channel=NakagamiChannel(), **{}),
             Scenario(channel=None)]
    assert len(partition_scenarios(mixed)) == 3
    # OTA-only axes are irrelevant to the exact uplink: one shared partition
    exact = [Scenario(channel=None, noise_sigma=0.0, debias=False),
             Scenario(channel=None, noise_sigma=1e-3, debias=True)]
    assert len(partition_scenarios(exact)) == 1


# ---------------------------------------------------------------------------
# the core exactness + compile-count contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["vmap", "map"])
def test_sweep_matches_monte_carlo_bitwise(env_pol, compile_counter, mode):
    """{Rayleigh, Nakagami} x 2 noise levels x 2 alphas: the batched sweep
    must equal per-scenario monte_carlo exactly (same keys, identical
    History arrays) and compile strictly fewer XLA programs."""
    env, pol = env_pol
    scens = grid(
        channel=[RayleighChannel(), NakagamiChannel(m=0.1, omega=1.0)],
        noise_sigma=[1e-3, 1e-2],
        alpha=[1e-3, 1e-4],
        **SMALL,
    )
    key, mc = jax.random.key(0), 2
    # eager helpers are pre-warmed by the compile_counter fixture
    fedpg.clear_compilation_cache()  # count real compiles, not cache hits

    with compile_counter() as c_naive:
        naive = [
            fedpg.monte_carlo(env, pol, s.fedpg_config(), key, mc,
                              ota=s.ota_config())
            for s in scens
        ]
    with compile_counter() as c_sweep:
        res = sweep(env, pol, scens, key, mc, mode=mode)

    assert res.n_partitions == 2  # one per channel family
    for i in range(len(scens)):
        assert _hist_equal(naive[i], res.scenario_history(i)), scens[i]
    assert c_sweep.count < c_naive.count, (c_sweep.count, c_naive.count)


def test_exact_uplink_scenario_matches_monte_carlo(env_pol):
    env, pol = env_pol
    scens = [Scenario(channel=None, alpha=5e-3, **SMALL),
             Scenario(channel=RayleighChannel(), alpha=5e-3, **SMALL)]
    key = jax.random.key(3)
    res = sweep(env, pol, scens, key, 2)
    ref = fedpg.monte_carlo(env, pol, scens[0].fedpg_config(), key, 2,
                            ota=None)
    assert _hist_equal(ref, res.scenario_history(0))
    # exact uplink reports unit gain, OTA does not
    assert np.all(np.asarray(res.history.gain_mean[0]) == 1.0)


def test_identical_scenarios_share_one_lane(env_pol, compile_counter):
    env, pol = env_pol
    s = Scenario(channel=RayleighChannel(), noise_sigma=1e-3, **SMALL)
    # eager helpers (dtype conversions, key ops) are pre-warmed by the
    # compile_counter fixture, so the counters compare lane programs only
    with compile_counter() as c:
        res = sweep(env, pol, [s, s, s], jax.random.key(1), 2)
    assert res.n_partitions == 1
    assert _hist_equal(res.scenario_history(0), res.scenario_history(2))
    # the per-scenario path now amortises identical calls through the
    # compiled-callable cache, so both paths compile exactly once
    fedpg.clear_compilation_cache()
    with compile_counter() as c3:
        [fedpg.monte_carlo(env, pol, s.fedpg_config(), jax.random.key(1), 2,
                           ota=s.ota_config()) for _ in range(3)]
    assert c.count <= c3.count


# ---------------------------------------------------------------------------
# BatchedChannel adapter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("channels", [
    [RayleighChannel(scale=1.0), RayleighChannel(scale=0.5)],
    [NakagamiChannel(m=0.1, omega=1.0), NakagamiChannel(m=0.5, omega=2.0)],
    [LogNormalChannel(mu=0.0, sigma=0.25), LogNormalChannel(mu=0.1, sigma=0.5)],
    [FixedGainChannel(gain=0.7), FixedGainChannel(gain=1.3)],
])
def test_batched_channel_matches_concrete(channels):
    """Lane-sliced BatchedChannel draws == concrete dataclass draws, bitwise."""
    kind, arrays = batched_channel_arrays(channels)
    params = {k: jnp.asarray(v, jnp.float32) for k, v in arrays.items()}
    key = jax.random.key(7)

    def lane(p):
        return BatchedChannel(kind=kind, params=p).sample(key, (16,))

    batched = jax.jit(lambda pk: jax.lax.map(lane, pk))(params)
    for i, ch in enumerate(channels):
        # jitted reference: the engine always compares compiled programs
        # (eager transcendentals can differ from fused ones by 1 ulp)
        ref = jax.jit(lambda c=ch: c.sample(key, (16,)))()
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(batched[i]))
        # float64-precomputed moments round to the concrete values
        np.testing.assert_allclose(float(params["_mean"][i]), ch.mean, rtol=1e-7)
        np.testing.assert_allclose(float(params["_var"][i]), ch.var, rtol=1e-7)


def test_batched_channel_rejects_mixed_kinds():
    with pytest.raises(ValueError, match="cannot batch"):
        batched_channel_arrays([RayleighChannel(), NakagamiChannel()])
    assert channel_kind(RayleighChannel()) == "rayleigh"


def test_sweep_over_channel_params(env_pol):
    """A sweep along a channel-parameter axis (same family) stays a single
    partition and matches per-scenario runs on rewards/gains; grad_sq may
    differ in the last bit when debiasing (runtime norm), so compare with
    tight tolerance there."""
    env, pol = env_pol
    scens = grid(channel=[RayleighChannel(scale=1.0),
                          RayleighChannel(scale=0.5)], **SMALL)
    key = jax.random.key(5)
    res = sweep(env, pol, scens, key, 2)
    assert res.n_partitions == 1
    for i, s in enumerate(scens):
        ref = fedpg.monte_carlo(env, pol, s.fedpg_config(), key, 2,
                                ota=s.ota_config())
        got = res.scenario_history(i)
        np.testing.assert_array_equal(np.asarray(ref.rewards),
                                      np.asarray(got.rewards))
        np.testing.assert_array_equal(np.asarray(ref.gain_mean),
                                      np.asarray(got.gain_mean))
        np.testing.assert_allclose(np.asarray(ref.grad_sq),
                                   np.asarray(got.grad_sq), rtol=1e-6)


# ---------------------------------------------------------------------------
# power control through OTAConfig
# ---------------------------------------------------------------------------

def test_power_control_threads_through_ota_config(key):
    chan = RayleighChannel()
    pc = TruncatedInversion(target=1.0, p_max=5.0, c_min=0.1)
    cfg = OTAConfig(channel=chan, power_control=pc)
    h = sample_gains(cfg, key, 1024)
    c = chan.sample(key, (1024,))
    np.testing.assert_array_equal(np.asarray(h),
                                  np.asarray(c * pc.apply(c)))
    # UnitPower is the identity
    cfg_unit = OTAConfig(channel=chan, power_control=UnitPower())
    np.testing.assert_array_equal(
        np.asarray(sample_gains(cfg_unit, key, 64)),
        np.asarray(chan.sample(key, (64,))))


def test_power_control_none_unchanged(key):
    """No power_control => exact pre-existing sample_gains behaviour."""
    cfg = OTAConfig(channel=RayleighChannel())
    np.testing.assert_array_equal(
        np.asarray(sample_gains(cfg, key, 32)),
        np.asarray(RayleighChannel().sample(key, (32,))))


def test_update_scale_override(key):
    g = {"w": jax.random.normal(key, (4, 3), jnp.float32)}
    cfg = OTAConfig(channel=FixedGainChannel(gain=1.0), update_scale=0.25)
    u, _ = aggregate_stacked(cfg, jax.random.key(1), g)
    expect = jnp.sum(g["w"], axis=0) * 0.25
    np.testing.assert_allclose(np.asarray(u["w"]), np.asarray(expect),
                               rtol=1e-6)
    # the weighted-loss form honours the same override: its input already
    # carries the 1/N, so (N * update_scale) lands on the identical result
    from repro.core.ota import add_awgn
    weighted = {"w": jnp.mean(g["w"], axis=0)}  # (1/N) sum h_i g_i, h=1
    u3 = add_awgn(cfg, jax.random.key(1), weighted, n_agents=4)
    np.testing.assert_allclose(np.asarray(u3["w"]), np.asarray(u["w"]),
                               rtol=1e-6)
    # ideal() clears sweep-only fields
    ideal = cfg.ideal()
    assert ideal.update_scale is None and ideal.power_control is None


def test_sweep_power_control_axis(env_pol):
    """Power-control policy type is structural; its params are continuous."""
    env, pol = env_pol
    scens = grid(
        channel=RayleighChannel(),
        power_control=[None, TruncatedInversion(target=1.0),
                       TruncatedInversion(target=2.0)],
        **SMALL,
    )
    res = sweep(env, pol, scens, jax.random.key(2), 2)
    # None vs TruncatedInversion split; the two inversions batch together.
    assert res.n_partitions == 2
    ref = fedpg.monte_carlo(env, pol, scens[1].fedpg_config(),
                            jax.random.key(2), 2, ota=scens[1].ota_config())
    assert _hist_equal(ref, res.scenario_history(1))


def test_sweep_power_control_param_axis_batches(env_pol, compile_counter):
    """A pure power-control parameter axis batches into one program, with
    per-lane update_scale from the *effective* moments, and every lane
    matches the per-scenario path (rewards/gains bitwise; grad_sq to the
    documented last-bit debias-normaliser tolerance)."""
    from repro.core.power_control import FullInversion, effective_moments

    env, pol = env_pol
    scens = grid(
        channel=RayleighChannel(),
        power_control=[FullInversion(target=t)
                       for t in (0.6, 0.8, 1.0, 1.2, 1.4)],
        **SMALL,
    )
    key = jax.random.key(6)
    fedpg.clear_compilation_cache()
    with compile_counter() as c_naive:
        naive = [fedpg.monte_carlo(env, pol, s.fedpg_config(), key, 2,
                                   ota=s.ota_config()) for s in scens]
    with compile_counter() as c_sweep:
        res = sweep(env, pol, scens, key, 2)
    assert res.n_partitions == 1
    assert c_sweep.count < c_naive.count, (c_sweep.count, c_naive.count)
    for i in range(len(scens)):
        got = res.scenario_history(i)
        np.testing.assert_array_equal(np.asarray(naive[i].rewards),
                                      np.asarray(got.rewards))
        np.testing.assert_array_equal(np.asarray(naive[i].gain_mean),
                                      np.asarray(got.gain_mean))
        np.testing.assert_allclose(np.asarray(naive[i].grad_sq),
                                   np.asarray(got.grad_sq), rtol=1e-6)
    # the debias normaliser is the effective mean, not the raw channel mean
    m_eff, _ = effective_moments(RayleighChannel(), scens[0].power_control)
    assert scens[0].ota_config().update_scale == pytest.approx(
        1.0 / (SMALL["n_agents"] * m_eff))
    assert m_eff != pytest.approx(RayleighChannel().mean)


# ---------------------------------------------------------------------------
# result container
# ---------------------------------------------------------------------------

def test_sweep_result_exports(env_pol, tmp_path):
    env, pol = env_pol
    scens = grid(channel=[RayleighChannel(), None],
                 alpha=5e-3, **SMALL)
    res = sweep(env, pol, scens, jax.random.key(0), 2)
    assert isinstance(res, SweepResult) and len(res) == 2

    rows = res.to_dicts(tail=3)
    assert [r["index"] for r in rows] == [0, 1]
    assert rows[0]["channel"] == "rayleigh" and rows[1]["channel"] == "exact"
    assert all(np.isfinite(r["final_reward"]) for r in rows)
    assert all(np.isfinite(r["avg_grad_sq"]) for r in rows)

    path = tmp_path / "sweep.csv"
    text = res.to_csv(str(path), tail=3)
    lines = path.read_text().strip().splitlines()
    assert lines[0].startswith("index,tag,channel")
    assert len(lines) == 3 and text == path.read_text()

    assert res.index(channel=None) == 1
    with pytest.raises(KeyError):
        res.index(alpha=123.0)

    h = res.scenario_history(0)
    assert h.rewards.shape == (2, SMALL["n_rounds"])


def test_sweep_varying_n_rounds(env_pol):
    """n_rounds is structural: partitions split and histories stay ragged."""
    env, pol = env_pol
    scens = grid(channel=RayleighChannel(), n_rounds=[3, 5],
                 n_agents=2, batch_m=2, horizon=4)
    res = sweep(env, pol, scens, jax.random.key(0), 2)
    assert res.n_partitions == 2
    assert res.scenario_history(0).rewards.shape == (2, 3)
    assert res.scenario_history(1).rewards.shape == (2, 5)
    assert np.isfinite(res.final_reward(1, tail=2))
    assert len(res.to_dicts(tail=2)) == 2


def test_sweep_controlled_channel_batches(env_pol):
    """ControlledChannel is a first-class registry family: same-shaped
    instances (same base kind, same policy type) batch into ONE partition
    and each lane matches the per-scenario path bit-for-bit."""
    from repro.core.power_control import make_controlled_channel

    env, pol = env_pol
    chans = [
        make_controlled_channel(RayleighChannel(scale=sc), TruncatedInversion())
        for sc in (1.0, 0.5)
    ]
    scens = grid(channel=chans, noise_sigma=1e-3, **SMALL)
    key = jax.random.key(4)
    res = sweep(env, pol, scens, key, 2)
    assert res.n_partitions == 1
    for i, s in enumerate(scens):
        ref = fedpg.monte_carlo(env, pol, s.fedpg_config(), key, 2,
                                ota=s.ota_config())
        got = res.scenario_history(i)
        np.testing.assert_array_equal(np.asarray(ref.rewards),
                                      np.asarray(got.rewards))
        np.testing.assert_array_equal(np.asarray(ref.gain_mean),
                                      np.asarray(got.gain_mean))
        np.testing.assert_allclose(np.asarray(ref.grad_sq),
                                   np.asarray(got.grad_sq), rtol=1e-6)
    row = res.to_dicts(tail=2)[0]
    assert row["channel"] == "controlled:rayleigh:TruncatedInversion"
    # debias uses the effective moments, which are exposed in the table
    assert row["m_h_eff"] == pytest.approx(chans[0].mean)
    # same policy type with different params shares one partition; a
    # different policy *type* is a different structural shape
    from repro.core.power_control import FullInversion

    trunc_a = make_controlled_channel(RayleighChannel(),
                                      TruncatedInversion(c_min=0.2))
    trunc_b = make_controlled_channel(RayleighChannel(),
                                      TruncatedInversion(target=2.0))
    full = make_controlled_channel(RayleighChannel(), FullInversion())
    assert len(partition_scenarios(
        grid(channel=[trunc_a, trunc_b], **SMALL))) == 1
    assert len(partition_scenarios(
        grid(channel=[trunc_a, full], **SMALL))) == 2


def test_sweep_custom_channel_outside_registry(env_pol):
    """Truly unregistered channels still sweep as partition constants, and
    varying one is a clear error, not a crash later."""
    from dataclasses import dataclass

    @dataclass(frozen=True)
    class HalfGain(FixedGainChannel):
        pass

    env, pol = env_pol
    s = Scenario(channel=HalfGain(gain=0.5), noise_sigma=1e-3, **SMALL)
    key = jax.random.key(4)
    res = sweep(env, pol, [s], key, 2)
    ref = fedpg.monte_carlo(env, pol, s.fedpg_config(), key, 2,
                            ota=s.ota_config())
    assert _hist_equal(ref, res.scenario_history(0))
    assert res.to_dicts(tail=2)[0]["channel"] == "HalfGain"
    with pytest.raises(ValueError, match="not in the registry"):
        sweep(env, pol,
              [s, Scenario(channel=HalfGain(gain=0.7), noise_sigma=1e-3,
                           **SMALL)], key, 2)


def test_csv_escapes_quotes_and_commas(env_pol):
    env, pol = env_pol
    s = Scenario(channel=None, tag='say "hi", ok', **SMALL)
    res = sweep(env, pol, [s], jax.random.key(0), 2)
    line = res.to_csv(tail=2).splitlines()[1]
    assert '"say ""hi"", ok"' in line  # RFC-4180: quoted, quotes doubled


def test_scenario_time_us_per_partition(env_pol):
    env, pol = env_pol
    scens = [Scenario(channel=RayleighChannel(), **SMALL),
             Scenario(channel=None, **SMALL)]
    res = sweep(env, pol, scens, jax.random.key(0), 2)
    t0, t1 = res.scenario_time_us(0), res.scenario_time_us(1)
    assert t0 > 0 and t1 > 0
    # different partitions keep independent timings
    assert all(p.wall_time_us > 0 for p in res.partitions)
    with pytest.raises(IndexError):
        res.scenario_time_us(5)


def test_sweep_rejects_bad_inputs(env_pol):
    env, pol = env_pol
    with pytest.raises(ValueError, match="empty scenario"):
        sweep(env, pol, [], jax.random.key(0), 2)
    with pytest.raises(ValueError, match="mode"):
        sweep(env, pol, [Scenario(channel=None)], jax.random.key(0), 2,
              mode="pmap")
