"""Trainer semantics: OTA == exact when the channel is ideal, microbatching
equivalence, loss decreases on the synthetic pipeline, serve step sanity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import InputShape
from repro.data.pipeline import make_batch
from repro.models import model as model_lib
from repro.train import server, trainer
from repro.utils.tree import tree_global_norm, tree_sub


@pytest.fixture(scope="module")
def small():
    cfg = get_smoke_config("llama3.2-3b")
    return model_lib.build(cfg)


def _shape(b=8, s=32):
    return InputShape("t", seq_len=s, global_batch=b, kind="train")


def test_ota_ideal_channel_equals_exact(small):
    """aggregator='ota' with a unit fixed gain and sigma=0 must produce the
    SAME update as aggregator='exact' — Algorithm 2 degenerates to 1."""
    batch = make_batch(small.cfg, _shape(), 0)
    key = jax.random.key(0)
    base = dict(n_agents=4, microbatch=2, total_steps=10, lr=1e-2)
    t_exact = trainer.TrainConfig(aggregator="exact", **base)
    t_ota = trainer.TrainConfig(
        aggregator="ota", channel="fixed", channel_kwargs=(("gain", 1.0),),
        noise_db=-1000.0, debias=False, **base,
    )
    s0 = trainer.init_state(small, t_exact, jax.random.key(1))
    s1, m1 = jax.jit(trainer.make_train_step(small, t_exact))(s0, batch, key)
    s0b = trainer.init_state(small, t_ota, jax.random.key(1))
    s2, m2 = jax.jit(trainer.make_train_step(small, t_ota))(s0b, batch, key)
    diff = float(tree_global_norm(tree_sub(s1.params, s2.params)))
    assert diff < 1e-5, diff
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)


def test_microbatch_equivalence(small):
    """microbatch=1 vs 2 give the same accumulated gradient step (exact
    aggregator; float tolerance)."""
    batch = make_batch(small.cfg, _shape(), 1)
    key = jax.random.key(0)
    outs = []
    for mb in (1, 2):
        tcfg = trainer.TrainConfig(aggregator="exact", n_agents=4,
                                   microbatch=mb, total_steps=10, lr=1e-2)
        st = trainer.init_state(small, tcfg, jax.random.key(1))
        st, _ = jax.jit(trainer.make_train_step(small, tcfg))(st, batch, key)
        outs.append(st.params)
    rel = float(
        tree_global_norm(tree_sub(outs[0], outs[1]))
        / tree_global_norm(outs[0])
    )
    assert rel < 1e-4, rel


@pytest.mark.slow
def test_training_reduces_loss():
    """With vocab >> the pipeline's active sub-vocab, the support-learning
    phase gives a fast, unambiguous loss drop under OTA aggregation."""
    cfg = get_smoke_config("llama3.2-3b").with_(vocab=4096)
    m = model_lib.build(cfg)
    tcfg = trainer.TrainConfig(
        aggregator="ota", n_agents=4, microbatch=1, total_steps=100,
        lr=1e-2, warmup=5,
    )
    state = trainer.init_state(m, tcfg, jax.random.key(2))
    step = jax.jit(trainer.make_train_step(m, tcfg))
    key = jax.random.key(3)
    losses = []
    for i in range(60):
        batch = make_batch(cfg, _shape(), i)
        state, metrics = step(state, batch, key)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:5]) - 0.5, losses[::8]


def test_agent_major_layout():
    b = {"x": jnp.arange(8)}
    out = trainer._agent_major(b, n_agents=2, n_micro=2)
    # agents own contiguous halves: agent0 = [0..3], agent1 = [4..7]
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  [[[0, 1], [4, 5]], [[2, 3], [6, 7]]])


def test_serve_step_advances_ring(small):
    from repro.configs.shapes import get_shape
    shape = InputShape("d", seq_len=64, global_batch=2, kind="decode")
    m = small
    params = m.init(jax.random.key(0))
    cache = server.init_cache_for_shape(m, shape)
    assert int(cache.pos) == 63
    step = jax.jit(server.make_serve_step(m, shape))
    tok = jnp.zeros((2, 1), jnp.int32)
    nxt, logits, cache = step(params, cache, tok)
    assert nxt.shape == (2, 1) and int(cache.pos) == 64
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_serve_capacity_honours_window():
    from repro.models.model import serve_capacity
    cfg = get_smoke_config("mixtral-8x22b")  # window 64
    assert serve_capacity(cfg, 32) == 32       # short ctx: full cache
    assert serve_capacity(cfg, 10_000) == 64   # long ctx: ring of window
    dense = get_smoke_config("internlm2-20b").with_(serve_window=None)
    assert serve_capacity(dense, 10_000) == 10_000
