"""Channel models: sampled moments must match the closed-form (m_h, sigma_h^2)
the convergence theory uses, and the paper's two settings must satisfy /
violate the Theorem-1 condition exactly as claimed."""
import math

import jax
import jax.numpy as jnp
import pytest

from repro.core.channel import (
    IdealChannel, LogNormalChannel, NakagamiChannel, RayleighChannel,
    make_channel, noise_sigma_from_db,
)

N_SAMPLES = 200_000


@pytest.mark.parametrize(
    "ch,tol",
    [
        (RayleighChannel(), 0.02),
        (RayleighChannel(scale=2.0), 0.04),
        (NakagamiChannel(m=0.1, omega=1.0), 0.03),
        (NakagamiChannel(m=1.0, omega=2.0), 0.03),
        (LogNormalChannel(mu=0.0, sigma=0.25), 0.02),
    ],
)
def test_channel_moments(ch, tol):
    h = ch.sample(jax.random.key(42), (N_SAMPLES,))
    assert jnp.all(h >= 0.0), "gains must be non-negative"
    assert abs(float(jnp.mean(h)) - ch.mean) < tol * max(ch.mean, 1.0)
    assert abs(float(jnp.var(h)) - ch.var) < 3 * tol * max(ch.var, 1.0)


def test_paper_rayleigh_constants():
    ch = RayleighChannel()
    assert ch.mean == pytest.approx(math.sqrt(math.pi / 2))
    assert ch.var == pytest.approx((4 - math.pi) / 2)
    # paper: condition holds for all N under Rayleigh
    for n in (1, 2, 10, 100):
        assert ch.satisfies_theorem1(n)


def test_paper_nakagami_violates_condition_for_small_n():
    ch = NakagamiChannel(m=0.1, omega=1.0)
    # paper: sigma_h^2 ~= 10 m_h^2
    assert ch.var / ch.mean**2 == pytest.approx(10.0, rel=0.05)
    assert not ch.satisfies_theorem1(5)     # 5+1 < 10+... violated
    assert ch.satisfies_theorem1(20)        # enough agents restores it


def test_ideal_channel_and_factory():
    assert IdealChannel().mean == 1.0 and IdealChannel().var == 0.0
    assert isinstance(make_channel("rayleigh"), RayleighChannel)
    with pytest.raises(ValueError):
        make_channel("does-not-exist")


def test_noise_sigma_from_db():
    # paper: sigma^2 = -60 dB
    assert noise_sigma_from_db(-60.0) ** 2 == pytest.approx(1e-6)
    assert noise_sigma_from_db(0.0) == pytest.approx(1.0)
