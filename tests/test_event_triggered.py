"""Event-triggered baseline: estimator routing regression (it used to
hardcode G(PO)MDP and silently ignore ``FedPGConfig.estimator``) and basic
upload accounting."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import event_triggered, fedpg
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy

SMALL = dict(n_agents=3, batch_m=2, horizon=6, n_rounds=4, alpha=1e-3)


@pytest.fixture(scope="module")
def env_pol():
    return LandmarkNav(), MLPPolicy()


def test_estimator_is_honoured(env_pol):
    """estimator='reinforce' must change the gradients (regression: the ET
    loop used to call gpomdp_gradient unconditionally)."""
    env, pol = env_pol
    cfg_g = fedpg.FedPGConfig(estimator="gpomdp", **SMALL)
    cfg_r = replace(cfg_g, estimator="reinforce")
    et = event_triggered.ETConfig(tau=0.0)  # always upload: pure estimator diff
    _, h_g = event_triggered.run_jit(env, pol, cfg_g, et, jax.random.key(0))
    _, h_r = event_triggered.run_jit(env, pol, cfg_r, et, jax.random.key(0))
    # same PRNG stream, same trajectories — only the estimator differs
    np.testing.assert_array_equal(np.asarray(h_g.rewards[:1]),
                                  np.asarray(h_r.rewards[:1]))
    assert not np.array_equal(np.asarray(h_g.grad_sq), np.asarray(h_r.grad_sq))


def test_unknown_estimator_raises(env_pol):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(estimator="nope", **SMALL)
    with pytest.raises(ValueError, match="unknown estimator"):
        event_triggered.run(env, pol, cfg, event_triggered.ETConfig(),
                            jax.random.key(0))


def test_run_jit_reuses_compiled(env_pol, compile_counter):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(**SMALL)
    et = event_triggered.ETConfig(tau=0.05)
    keys = [jax.random.key(i) for i in range(2)]  # warm eager key helpers
    fedpg.clear_compilation_cache()  # clears the registered ET cache too
    with compile_counter() as c1:
        event_triggered.run_jit(env, pol, cfg, et, keys[0])
    with compile_counter() as c2:
        event_triggered.run_jit(env, pol, cfg, et, keys[1])
    assert c1.count >= 1 and c2.count == 0, (c1.count, c2.count)


def test_upload_accounting_bounds(env_pol):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(**SMALL)
    # tau=0: every agent triggers every round (diff >= 0 always holds)
    _, h = event_triggered.run_jit(env, pol, cfg,
                                   event_triggered.ETConfig(tau=0.0),
                                   jax.random.key(1))
    np.testing.assert_array_equal(np.asarray(h.uploads),
                                  np.full(SMALL["n_rounds"],
                                          SMALL["n_agents"], np.float32))
    # huge tau: after the first (zero-stale) round nobody triggers
    _, h2 = event_triggered.run_jit(env, pol, cfg,
                                    event_triggered.ETConfig(tau=1e9),
                                    jax.random.key(1))
    assert float(jnp.max(h2.uploads[1:])) == 0.0
