"""Hypothesis property tests codifying the sweep engine's bitwise-lane
contract at the *metadata* level (no programs run — these are pure
partition/packing/identity laws over generated grids):

* ``partition_scenarios`` is a partition: every scenario lands in exactly
  one ``Partition``, and structure keys are homogeneous inside each;
* ``_pack_partition`` packs **only** the axes that actually vary inside a
  partition — constant axes must stay closed-over Python literals (that is
  what keeps lanes bit-identical to the per-scenario path);
* ``describe()`` / ``to_csv()`` / ``index()`` round-trip scenario identity.

The assertion bodies are plain helpers so the deterministic smoke test at
the bottom exercises them even on a bare interpreter (where the hypothesis
wrappers skip via tests/_hypothesis_stub.py).
"""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare interpreter: only the property tests skip
    from _hypothesis_stub import given, settings, st

from repro.core.fedpg import History
from repro.core.power_control import FullInversion, TruncatedInversion
from repro.core.sweep import (
    Scenario, SweepResult, _pack_partition, _structure_key, grid,
    partition_scenarios,
)
from repro.core.channel import (
    FixedGainChannel, NakagamiChannel, RayleighChannel,
)

# ---------------------------------------------------------------------------
# strategies: scenario grids over every axis class the engine distinguishes
# (structural ints, channel families, continuous params, power control)
# ---------------------------------------------------------------------------

CHANNELS = [None, RayleighChannel(), RayleighChannel(scale=0.5),
            NakagamiChannel(m=0.1, omega=1.0), FixedGainChannel(gain=0.7)]
POLICIES = [None, TruncatedInversion(target=1.0), TruncatedInversion(target=2.0),
            FullInversion(target=0.8)]

scenario_st = st.builds(
    Scenario,
    channel=st.sampled_from(CHANNELS),
    noise_sigma=st.sampled_from([0.0, 1e-3, 1e-2]),
    alpha=st.sampled_from([1e-3, 1e-4]),
    n_agents=st.sampled_from([2, 4]),
    batch_m=st.sampled_from([2, 3]),
    n_rounds=st.sampled_from([3, 5]),
    estimator=st.sampled_from(["gpomdp", "reinforce"]),
    power_control=st.sampled_from(POLICIES),
    debias=st.booleans(),
    tag=st.sampled_from(["", "a", 'quoted,"tag"']),
)
grid_st = st.lists(scenario_st, min_size=1, max_size=12)


# ---------------------------------------------------------------------------
# assertion bodies (shared by the hypothesis wrappers and the smoke test)
# ---------------------------------------------------------------------------

def check_partition_is_partition(scenarios):
    parts = partition_scenarios(scenarios)
    seen = [i for p in parts for i in p.indices]
    # every scenario in exactly one partition, original order preserved inside
    assert sorted(seen) == list(range(len(scenarios)))
    assert len(seen) == len(set(seen))
    for p in parts:
        assert len(p.indices) == len(p.scenarios)
        for i, s in zip(p.indices, p.scenarios):
            assert scenarios[i] is s
        # structure keys homogeneous inside a partition...
        assert {_structure_key(s) for s in p.scenarios} == {p.key}
    # ...and distinct across partitions
    keys = [p.key for p in parts]
    assert len(keys) == len(set(keys))


def check_pack_only_varying(scenarios):
    for part in partition_scenarios(scenarios):
        packed = _pack_partition(part)
        n = len(part.scenarios)
        exact = part.proto.channel is None

        def vals(axis):
            return [getattr(s, axis) for s in part.scenarios]

        # an axis is packed ONLY if it varies (and reaches the program)
        assert ("alpha" in packed) == (len(set(vals("alpha"))) > 1)
        if exact:
            # exact uplink: no OTA axis may be packed at all
            assert set(packed) <= {"alpha"}
        else:
            assert ("noise_sigma" in packed) == (
                len(set(vals("noise_sigma"))) > 1)
            assert ("channel" in packed) == (len(set(vals("channel"))) > 1)
            assert ("power_control" in packed) == (
                part.proto.power_control is not None
                and len(set(vals("power_control"))) > 1)
            # the debias normaliser packs exactly when debiasing is on and
            # an axis it depends on moves
            expect_scale = part.proto.debias and (
                "channel" in packed or "power_control" in packed)
            assert ("update_scale" in packed) == expect_scale
        # packed leaves are (n,)-shaped float32 in scenario order
        for name, leaf in packed.items():
            leaves = leaf.values() if isinstance(leaf, dict) else [leaf]
            for arr in leaves:
                assert arr.shape[0] == n
                assert arr.dtype == np.float32


def _dummy_result(scenarios, mc_runs=2, n_rounds=3):
    n = len(scenarios)
    mk = lambda: np.zeros((n, mc_runs, n_rounds), np.float32)  # noqa: E731
    return SweepResult(
        scenarios=list(scenarios),
        history=History(rewards=mk(), grad_sq=mk(), gain_mean=mk()),
        partitions=partition_scenarios(scenarios), mc_runs=mc_runs)


def check_describe_csv_index_round_trip(scenarios):
    res = _dummy_result(scenarios)
    rows = res.to_dicts(tail=2)
    describes = [s.describe() for s in scenarios]
    # describe() is injective on distinct scenarios: no two different grid
    # points may collapse to the same table row
    for i, si in enumerate(scenarios):
        for j, sj in enumerate(scenarios):
            if si != sj:
                assert describes[i] != describes[j], (si, sj)
    # to_dicts carries every describe field, in scenario order
    for i, (row, desc) in enumerate(zip(rows, describes)):
        assert row["index"] == i
        assert {k: row[k] for k in desc} == desc
    # CSV round-trips the row count, header and index order (cells with
    # commas/quotes are RFC-4180-escaped, so splitting lines is safe)
    text = res.to_csv(tail=2)
    lines = text.strip().splitlines()
    assert len(lines) == len(scenarios) + 1
    assert lines[0].startswith("index,tag,channel")
    # index() finds each scenario back from its own field values
    for i, s in enumerate(scenarios):
        fields = {f.name: getattr(s, f.name)
                  for f in dataclasses.fields(Scenario)}
        j = res.index(**fields)
        assert scenarios[j] == s
        assert j <= i  # first match wins; an equal earlier scenario is fine


# ---------------------------------------------------------------------------
# hypothesis wrappers
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(scenarios=grid_st)
def test_property_partition_is_partition(scenarios):
    check_partition_is_partition(scenarios)


@settings(max_examples=60, deadline=None)
@given(scenarios=grid_st)
def test_property_pack_only_varying(scenarios):
    check_pack_only_varying(scenarios)


@settings(max_examples=30, deadline=None)
@given(scenarios=grid_st)
def test_property_describe_csv_index_round_trip(scenarios):
    check_describe_csv_index_round_trip(scenarios)


# ---------------------------------------------------------------------------
# deterministic smoke: the same laws on a hand-built grid covering every
# branch (exact + two channel families + power control + debias + tags),
# so the helpers run even without hypothesis installed
# ---------------------------------------------------------------------------

def test_contract_smoke_on_dense_grid():
    scens = (
        grid(channel=[None, RayleighChannel(), RayleighChannel(scale=0.5),
                      NakagamiChannel(m=0.1, omega=1.0)],
             noise_sigma=[0.0, 1e-3], alpha=[1e-3, 1e-4], debias=True,
             n_agents=2, batch_m=2, n_rounds=3)
        + grid(channel=RayleighChannel(),
               power_control=[TruncatedInversion(target=1.0),
                              TruncatedInversion(target=2.0)],
               debias=[True, False], n_agents=2, batch_m=2, n_rounds=3)
        + [Scenario(channel=None, tag='say "hi", ok')]
    )
    check_partition_is_partition(scens)
    check_pack_only_varying(scens)
    check_describe_csv_index_round_trip(scens)
    # duplicated scenarios still land in one partition and index() returns
    # the first copy
    dup = [scens[0], scens[0], scens[1]]
    check_partition_is_partition(dup)
    res = _dummy_result(dup)
    assert res.index(channel=None, noise_sigma=0.0, alpha=1e-3) == 0


def test_property_files_note():
    """Hypothesis is an optional dev dependency: on a bare interpreter the
    @given tests above skip (tests/_hypothesis_stub.py) and the smoke test
    carries the contract; CI installs the real library."""
    assert callable(given)


if __name__ == "__main__":  # manual fuzz without pytest
    import random

    for _ in range(200):
        scens = [random.choice([
            Scenario(channel=random.choice(CHANNELS),
                     noise_sigma=random.choice([0.0, 1e-3, 1e-2]),
                     alpha=random.choice([1e-3, 1e-4]),
                     n_agents=random.choice([2, 4]),
                     estimator=random.choice(["gpomdp", "reinforce"]),
                     power_control=random.choice(POLICIES),
                     debias=random.choice([True, False]))])
            for _ in range(random.randint(1, 12))]
        check_partition_is_partition(scens)
        check_pack_only_varying(scens)
        check_describe_csv_index_round_trip(scens)
    print("manual fuzz: 200 grids OK")
