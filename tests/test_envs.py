"""Environment zoo: dynamics semantics, the Garnet generator's exact-gradient
anchoring of the estimators, GaussianPolicy, the heterogeneous wrapper, and
the horizon-correct l_bar envelope threading into theory."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gpomdp, theory
from repro.rl.env import LandmarkNav, TabularMDP
from repro.rl.envs import (
    CliffWalk, HeterogeneousEnv, LQRTask, MultiLandmarkNav, WindyLandmarkNav,
    check_agent_count, garnet, make_heterogeneous_env,
)
from repro.rl.policy import GaussianPolicy
from repro.rl.sampler import rollout, rollout_batch
from repro.utils.tree import tree_global_norm, tree_sub


# ---------------------------------------------------------------------------
# particle variants
# ---------------------------------------------------------------------------

def test_windy_reduces_to_landmark_when_calm():
    """wind=0, gust_sigma=0 must reproduce LandmarkNav bit-for-bit."""
    base, windy = LandmarkNav(), WindyLandmarkNav(wind=0.0, gust_sigma=0.0)
    pol = base.default_policy()
    theta = pol.init(jax.random.key(0))
    t1 = jax.jit(lambda: rollout(base, pol, theta, jax.random.key(1), 8))()
    t2 = jax.jit(lambda: rollout(windy, pol, theta, jax.random.key(1), 8))()
    for a, b in zip(t1, t2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_windy_drift_moves_the_agent():
    env = WindyLandmarkNav(wind=0.5, gust_sigma=0.0)
    state = jnp.zeros((4,))
    nxt, _ = env.step(jax.random.key(0), state, jnp.asarray(0))  # "stay"
    assert float(nxt[0]) == pytest.approx(0.5)  # +x drift despite staying
    assert float(nxt[1]) == pytest.approx(0.0)


def test_multilandmark_loss_is_nearest():
    env = MultiLandmarkNav(n_landmarks=2)
    # pos (0,0); landmarks at (1,0) and (0.2, 0)
    state = jnp.array([0.0, 0.0, 1.0, 0.0, 0.2, 0.0])
    assert float(env.loss(state)) == pytest.approx(0.2, rel=1e-4)
    assert env.obs_dim == 6
    assert env.default_policy().obs_dim == 6


# ---------------------------------------------------------------------------
# cliff walk
# ---------------------------------------------------------------------------

def test_cliffwalk_semantics():
    env = CliffWalk(width=4, height=3, slip=0.0)
    key = jax.random.key(0)
    s = env.reset(key)
    assert int(jnp.argmax(s)) == env.start_state
    # stepping right from start lands in the cliff: cost + teleport home
    nxt, loss = env.step(key, s, jnp.asarray(3))
    assert float(loss) == pytest.approx(env.cliff_cost)
    assert int(jnp.argmax(nxt)) == env.start_state
    # up is safe: step cost
    nxt, loss = env.step(key, s, jnp.asarray(0))
    assert float(loss) == pytest.approx(env.step_cost)
    assert int(jnp.argmax(nxt)) == env.width  # (0, 1)
    # goal is absorbing with zero loss
    goal = jax.nn.one_hot(env.goal_state, env.obs_dim)
    nxt, loss = env.step(key, goal, jnp.asarray(1))
    assert float(loss) == 0.0
    assert int(jnp.argmax(nxt)) == env.goal_state
    # walls clamp
    nxt, _ = env.step(key, s, jnp.asarray(2))  # left from (0,0)
    assert int(jnp.argmax(nxt)) == env.start_state


def test_cliffwalk_slip_randomises_actions():
    env = CliffWalk(width=4, height=3, slip=1.0)
    s = env.reset(jax.random.key(0))
    cells = {
        int(jnp.argmax(env.step(jax.random.key(i), s, jnp.asarray(0))[0]))
        for i in range(32)
    }
    assert len(cells) > 1  # full slip: the chosen action is irrelevant


# ---------------------------------------------------------------------------
# LQR + GaussianPolicy
# ---------------------------------------------------------------------------

def test_gaussian_policy_log_prob_and_entropy():
    pol = GaussianPolicy(obs_dim=3, act_dim=2)
    params = pol.init(jax.random.key(0))
    obs = jnp.array([0.3, -0.1, 0.7])
    act = jnp.array([0.5, -0.2])
    mu = np.asarray(pol.mean(params, obs))
    std = np.exp(np.asarray(params["log_std"]))
    expect = sum(
        -0.5 * ((float(act[i]) - mu[i]) / std[i]) ** 2
        - math.log(std[i]) - 0.5 * math.log(2 * math.pi)
        for i in range(2)
    )
    assert float(pol.log_prob(params, obs, act)) == pytest.approx(expect, rel=1e-5)
    # closed-form diagonal-Gaussian entropy
    expect_h = float(np.sum(np.log(std))) + 0.5 * 2 * (1 + math.log(2 * math.pi))
    assert float(pol.entropy(params, obs)) == pytest.approx(expect_h, rel=1e-5)
    # sampling statistics match the parameterisation
    keys = jax.random.split(jax.random.key(1), 4000)
    acts = jax.vmap(lambda k: pol.sample(params, k, obs))(keys)
    np.testing.assert_allclose(np.mean(np.asarray(acts), 0), mu, atol=0.08)
    np.testing.assert_allclose(np.std(np.asarray(acts), 0), std, atol=0.08)


def test_lqr_rollout_and_gpomdp_finite():
    """Continuous actions run the full estimator path (vector-action
    log-prob flattening in gpomdp._traj_logps)."""
    env = LQRTask(dim=2)
    pol = env.default_policy()
    theta = pol.init(jax.random.key(0))
    traj = jax.jit(
        lambda: rollout_batch(env, pol, theta, jax.random.key(1), 6, 8)
    )()
    assert traj.actions.shape == (8, 7, 2)  # (batch, T+1, act_dim)
    assert traj.losses.shape == (8, 7)
    assert bool(jnp.all(jnp.isfinite(traj.losses)))
    g = gpomdp.gpomdp_gradient(pol, theta, traj, 0.95)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))
    assert float(tree_global_norm(g)) > 0.0


# ---------------------------------------------------------------------------
# Garnet generator + estimator anchoring (exact_J autodiff)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def garnet_setup():
    mdp = garnet(jax.random.key(0), n_states=4, n_actions=2, branching=2,
                 gamma=0.9, horizon=3)
    pol = mdp.default_policy()
    theta = pol.init(jax.random.key(1))
    return mdp, pol, theta


def test_garnet_is_a_valid_mdp(garnet_setup):
    mdp, _, _ = garnet_setup
    P = np.asarray(mdp.P)
    np.testing.assert_allclose(P.sum(-1), 1.0, rtol=1e-5)
    assert P.min() >= 0.0
    # branching-sparse: each (s, a) row reaches at most `branching` states
    assert (P > 1e-9).sum(-1).max() <= 2
    np.testing.assert_allclose(np.asarray(mdp.rho).sum(), 1.0, rtol=1e-5)
    with pytest.raises(ValueError, match="branching"):
        garnet(jax.random.key(0), n_states=3, branching=9)


@pytest.mark.parametrize("grad_fn,tol", [
    (gpomdp.gpomdp_gradient, 0.08),
    (gpomdp.reinforce_gradient, 0.12),
])
def test_estimators_unbiased_on_garnet(garnet_setup, grad_fn, tol):
    """G(PO)MDP / REINFORCE must match the exact autodiff gradient of the
    Garnet MDP's J(theta) — the generator exists to anchor estimators on
    instances the seed's hand-rolled random() never produces."""
    mdp, pol, theta = garnet_setup
    g_exact = jax.grad(lambda p: mdp.exact_J(pol.action_probs(p)))(theta)

    @jax.jit
    def est(k):
        traj = rollout_batch(mdp, pol, theta, k, mdp.horizon, 1024)
        return grad_fn(pol, theta, traj, mdp.gamma)

    gs = jax.vmap(est)(jax.random.split(jax.random.key(2), 30))
    g_mean = jax.tree.map(lambda x: jnp.mean(x, 0), gs)
    rel = float(
        tree_global_norm(tree_sub(g_mean, g_exact)) / tree_global_norm(g_exact)
    )
    assert rel < tol, f"relative bias {rel}"


# ---------------------------------------------------------------------------
# heterogeneous wrapper
# ---------------------------------------------------------------------------

def test_make_heterogeneous_env_stacks_varying_floats():
    envs = [WindyLandmarkNav(wind=0.02 * i) for i in range(3)]
    het = make_heterogeneous_env(envs)
    assert isinstance(het, HeterogeneousEnv) and het.n_agents == 3
    assert set(het.params) == {"wind"}  # constant fields stay on the base
    np.testing.assert_allclose(np.asarray(het.params["wind"]),
                               [0.0, 0.02, 0.04], rtol=1e-6)
    m = het.member(2)
    assert isinstance(m, WindyLandmarkNav) and m.wind == pytest.approx(0.04)
    assert het.kind_tag() == "hetero:windy:3"
    assert het.default_policy().obs_dim == 4


def test_make_heterogeneous_env_accepts_int_literals_in_float_fields():
    """wind=0 (an int literal in a declared-float field) is a lane value,
    not a structural field — classification follows the dataclass schema."""
    het = make_heterogeneous_env(
        [WindyLandmarkNav(wind=0), WindyLandmarkNav(wind=1)]
    )
    np.testing.assert_allclose(np.asarray(het.params["wind"]), [0.0, 1.0])


def test_make_heterogeneous_garnet_fleet():
    """Array-valued fields stack per agent: a fleet of Garnet draws gives
    every federated agent its own MDP."""
    from repro.core import fedpg

    ms = [garnet(jax.random.key(i), 4, 2, branching=2) for i in range(3)]
    het = make_heterogeneous_env(ms)
    assert set(het.params) == {"P", "l", "rho"}
    assert het.params["P"].shape == (3, 4, 2, 4)
    m1 = het.member(1)
    np.testing.assert_array_equal(np.asarray(m1.P), np.asarray(ms[1].P))
    cfg = fedpg.FedPGConfig(n_agents=3, batch_m=2, horizon=3, n_rounds=2)
    _, hist = fedpg.run(het, het.default_policy(), cfg, jax.random.key(0))
    assert bool(np.all(np.isfinite(np.asarray(hist.rewards))))


def test_make_heterogeneous_env_rejects_bad_fleets():
    with pytest.raises(ValueError, match="empty"):
        make_heterogeneous_env([])
    with pytest.raises(ValueError, match="one env family"):
        make_heterogeneous_env([LandmarkNav(), WindyLandmarkNav()])
    with pytest.raises(ValueError, match="structural"):
        make_heterogeneous_env([MultiLandmarkNav(n_landmarks=2),
                                MultiLandmarkNav(n_landmarks=3)])


def test_check_agent_count_guard():
    het = make_heterogeneous_env([WindyLandmarkNav(wind=w) for w in (0.0, 0.1)])
    check_agent_count(het, 2)            # matching: fine
    check_agent_count(LandmarkNav(), 7)  # plain envs: always fine
    with pytest.raises(ValueError, match="n_agents=2"):
        check_agent_count(het, 4)


# ---------------------------------------------------------------------------
# l_bar threading (horizon-correct Assumption-1 envelopes)
# ---------------------------------------------------------------------------

def test_landmark_l_bar_follows_horizon():
    env = LandmarkNav()
    # legacy property == the paper's fixed T=20 envelope
    assert env.l_bar == pytest.approx(env.l_bar_for(20))
    assert env.l_bar_for(40) > env.l_bar_for(20) > env.l_bar_for(5)
    # exact closed form: 2 * sqrt(2) * (arena + step*T)
    assert env.l_bar_for(10) == pytest.approx(2 * math.sqrt(2) * 2.0)


def test_theory_constants_for_env_use_actual_horizon():
    env = LandmarkNav()
    c10 = theory.constants_for_env(env, horizon=10, gamma=0.99,
                                   G=math.sqrt(2.0), F=0.5)
    c40 = theory.constants_for_env(env, horizon=40, gamma=0.99,
                                   G=math.sqrt(2.0), F=0.5)
    assert c10.l_bar == pytest.approx(env.l_bar_for(10))
    assert c40.l_bar > c10.l_bar
    assert c40.V() > c10.V()  # the bound envelope tracks the horizon
    # tabular envelopes come straight off the loss table
    mdp = TabularMDP.random(jax.random.key(0))
    assert theory.env_l_bar(mdp, 7) == pytest.approx(float(jnp.max(mdp.l)))
    with pytest.raises(ValueError, match="l_bar"):
        theory.env_l_bar(object(), 5)


def test_windy_l_bar_accounts_for_drift():
    calm = WindyLandmarkNav(wind=0.0, gust_sigma=0.0)
    windy = dataclasses.replace(calm, wind=0.2)
    assert calm.l_bar_for(10) == pytest.approx(LandmarkNav().l_bar_for(10))
    assert windy.l_bar_for(10) > calm.l_bar_for(10)
    assert CliffWalk().l_bar_for(99) == pytest.approx(1.0)  # cost-table bound
