"""Tests for ``repro.analyze`` — both layers.

Layer 1 (AST rules) is exercised on a fixture corpus through
``scan_source``: for every rule a tripping snippet, a should-not-trip
sibling, and the ``# repro: noqa[rule-id]`` suppression path.  A planted
multi-violation module proves ``--strict`` exits non-zero on every rule
class through the real CLI entry point.

Layer 2 (trace-level contracts) is exercised against the live registries:
the lane contract must pass for every registered env family on the real
tree, and must *fail* on mutants that promote a partition constant to a
dynamic argument or pack an extra axis; the wire-dtype check must pass the
real uplink and flag a planted narrowing; the compile-budget check must
pass the real caches and flag a planted cache-buster.
"""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.analyze import Report, get_rules, run, scan_source
from repro.analyze.findings import Finding, noqa_rules
from repro.analyze.__main__ import main as analyze_main


def _ids(findings):
    return sorted({f.rule for f in findings})


def _scan(source, rule_id, relpath="<string>"):
    return scan_source(source, relpath=relpath, rules=get_rules([rule_id]))


# ---------------------------------------------------------------------------
# key-reuse
# ---------------------------------------------------------------------------

def test_key_reuse_trips_on_double_consume():
    fs = _scan(
        "import jax\n"
        "def f(key):\n"
        "    a = jax.random.normal(key)\n"
        "    b = jax.random.uniform(key)\n"
        "    return a + b\n",
        "key-reuse")
    assert _ids(fs) == ["key-reuse"] and fs[0].line == 4


def test_key_reuse_trips_on_use_after_split():
    fs = _scan(
        "import jax\n"
        "def f(key):\n"
        "    k1, k2 = jax.random.split(key)\n"
        "    return jax.random.normal(key)\n",
        "key-reuse")
    assert _ids(fs) == ["key-reuse"]


def test_key_reuse_clean_after_split_refresh():
    fs = _scan(
        "import jax\n"
        "def f(key):\n"
        "    k1, k2 = jax.random.split(key)\n"
        "    return jax.random.normal(k1) + jax.random.uniform(k2)\n",
        "key-reuse")
    assert fs == []


def test_key_reuse_clean_on_exclusive_branches():
    fs = _scan(
        "import jax\n"
        "def f(key, flag):\n"
        "    if flag:\n"
        "        x = jax.random.normal(key)\n"
        "    else:\n"
        "        x = jax.random.uniform(key)\n"
        "    return x\n",
        "key-reuse")
    assert fs == []


def test_key_reuse_clean_on_guard_return_chain():
    # a branch that returns must not leak its consumption into the
    # fall-through path (the BatchedChannel.sample dispatch shape)
    fs = _scan(
        "import jax\n"
        "def f(kind, key):\n"
        "    if kind == 'a':\n"
        "        return jax.random.normal(key)\n"
        "    if kind == 'b':\n"
        "        return jax.random.gamma(key, 1.0)\n"
        "    return jax.random.uniform(key)\n",
        "key-reuse")
    assert fs == []


def test_key_reuse_trips_on_cross_iteration_reuse():
    fs = _scan(
        "import jax\n"
        "def f(key, n):\n"
        "    total = 0.0\n"
        "    for _ in range(n):\n"
        "        total += jax.random.normal(key)\n"
        "    return total\n",
        "key-reuse")
    assert _ids(fs) == ["key-reuse"]


def test_key_reuse_clean_with_per_iteration_fold():
    fs = _scan(
        "import jax\n"
        "def f(key, n):\n"
        "    total = 0.0\n"
        "    for i in range(n):\n"
        "        key, sub = jax.random.split(key)\n"
        "        total += jax.random.normal(sub)\n"
        "    return total\n",
        "key-reuse")
    assert fs == []


def test_key_reuse_tracks_constant_subscripts():
    fs = _scan(
        "import jax\n"
        "def f(key):\n"
        "    ks = jax.random.split(key, 3)\n"
        "    a = jax.random.normal(ks[0])\n"
        "    b = jax.random.uniform(ks[0])\n"
        "    return a + b\n",
        "key-reuse")
    assert _ids(fs) == ["key-reuse"]


# ---------------------------------------------------------------------------
# deprecated-aggregation
# ---------------------------------------------------------------------------

def test_deprecated_aggregation_trips_on_call_and_import():
    fs = _scan(
        "from repro.core.ota import exact_aggregate\n"
        "def f(g, key):\n"
        "    return exact_aggregate(g)\n",
        "deprecated-aggregation")
    assert len(fs) == 2  # the import and the call


def test_deprecated_aggregation_clean_on_new_api():
    fs = _scan(
        "from repro.core import ota\n"
        "def f(cfg, g, key):\n"
        "    return ota.aggregate(cfg, g, key)\n",
        "deprecated-aggregation")
    assert fs == []


def test_deprecated_aggregation_excludes_owner_module():
    # the module that defines the deprecated wrappers may reference them
    fs = _scan(
        "def exact_aggregate(g):\n"
        "    return g\n"
        "x = exact_aggregate(None)\n",
        "deprecated-aggregation", relpath="src/repro/core/ota.py")
    assert fs == []


# ---------------------------------------------------------------------------
# xla-flags
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("line", [
    "os.environ['XLA_FLAGS'] = '--xla_foo'",
    "os.environ['XLA_FLAGS'] += ' --xla_foo'",
    "os.environ.setdefault('XLA_FLAGS', '--xla_foo')",
    "os.environ.update({'XLA_FLAGS': '--xla_foo'})",
    "os.putenv('XLA_FLAGS', '--xla_foo')",
])
def test_xla_flags_trips_on_mutation(line):
    fs = _scan(f"import os\n{line}\n", "xla-flags")
    assert _ids(fs) == ["xla-flags"]


def test_xla_flags_clean_on_reads():
    fs = _scan(
        "import os\n"
        "a = os.environ.get('XLA_FLAGS', '')\n"
        "b = os.environ['XLA_FLAGS']\n",
        "xla-flags")
    assert fs == []


def test_xla_flags_excludes_owner_module():
    fs = _scan("import os\nos.environ['XLA_FLAGS'] = 'x'\n",
               "xla-flags", relpath="src/repro/utils/platform.py")
    assert fs == []


# ---------------------------------------------------------------------------
# raw-timing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("line", [
    "t0 = time.perf_counter()",
    "t0 = time.perf_counter_ns()",
    "t0 = time.monotonic()",
])
def test_raw_timing_trips_on_clock_calls(line):
    fs = _scan(f"import time\n{line}\n", "raw-timing")
    assert _ids(fs) == ["raw-timing"]


def test_raw_timing_trips_through_aliases():
    fs = _scan("import time as t\nx = t.perf_counter()\n", "raw-timing")
    assert _ids(fs) == ["raw-timing"]
    fs = _scan("from time import perf_counter as pc\nx = pc()\n",
               "raw-timing")
    assert _ids(fs) == ["raw-timing"]


def test_raw_timing_clean_on_span_usage_and_time_time():
    src = ("import time\n"
           "from repro.telemetry import trace\n"
           "ts = time.time()\n"
           "with trace.span('work'):\n"
           "    pass\n")
    assert _scan(src, "raw-timing") == []


def test_raw_timing_noqa_suppresses():
    src = ("import time\n"
           "t0 = time.perf_counter()  # repro: noqa[raw-timing]\n")
    assert _scan(src, "raw-timing") == []


def test_raw_timing_excludes_owner_package():
    fs = _scan("import time\nt0 = time.perf_counter()\n", "raw-timing",
               relpath="src/repro/telemetry/trace.py")
    assert fs == []


# ---------------------------------------------------------------------------
# in-jit pitfalls
# ---------------------------------------------------------------------------

def test_np_under_trace_trips_on_traced_arg():
    fs = _scan(
        "import jax\nimport numpy as np\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return np.mean(x)\n",
        "np-under-trace")
    assert _ids(fs) == ["np-under-trace"]


def test_np_under_trace_clean_on_static_math_and_untraced():
    fs = _scan(
        "import jax\nimport numpy as np\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return x * np.sqrt(2.0)\n"   # static scalar: fine
        "def g(x):\n"
        "    return np.mean(x)\n",        # not traced: fine
        "np-under-trace")
    assert fs == []


def test_tracer_leak_trips_on_float_of_param():
    fs = _scan(
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return float(x)\n",
        "tracer-leak")
    assert _ids(fs) == ["tracer-leak"]


def test_tracer_leak_clean_outside_trace_and_on_constants():
    fs = _scan(
        "import jax\n"
        "def g(x):\n"
        "    return float(x)\n"           # eager: fine
        "@jax.jit\n"
        "def f(x):\n"
        "    return x * float('1e-3')\n"  # constant: fine
        ,
        "tracer-leak")
    assert fs == []


def test_traced_branch_trips_on_jnp_predicate():
    fs = _scan(
        "import jax\nimport jax.numpy as jnp\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if jnp.any(x):\n"
        "        return x\n"
        "    return -x\n",
        "traced-branch")
    assert _ids(fs) == ["traced-branch"]


def test_traced_branch_clean_on_static_predicate():
    fs = _scan(
        "import jax\n"
        "@jax.jit\n"
        "def f(x, n=3):\n"
        "    if n > 2:\n"        # static python arg: fine
        "        return x\n"
        "    return -x\n",
        "traced-branch")
    assert fs == []


def test_jit_in_loop_trips_in_for_and_comprehension():
    fs = _scan(
        "import jax\n"
        "def f(fns):\n"
        "    out = []\n"
        "    for g in fns:\n"
        "        out.append(jax.jit(g))\n"
        "    return out + [jax.jit(g) for g in fns]\n",
        "jit-in-loop")
    assert len(fs) == 2 and _ids(fs) == ["jit-in-loop"]


def test_jit_in_loop_clean_at_module_level_and_in_nested_def():
    fs = _scan(
        "import jax\n"
        "h = jax.jit(lambda x: x)\n"
        "def f(fns):\n"
        "    makers = []\n"
        "    for g in fns:\n"
        "        def mk(g=g):\n"
        "            return jax.jit(g)\n"  # fresh scope per call anyway
        "        makers.append(mk)\n"
        "    return makers\n",
        "jit-in-loop")
    assert fs == []


# ---------------------------------------------------------------------------
# suppression + report plumbing
# ---------------------------------------------------------------------------

def test_noqa_suppresses_named_rule():
    src = ("import os\n"
           "os.environ['XLA_FLAGS'] = 'x'  # repro: noqa[xla-flags]\n")
    assert _scan(src, "xla-flags") == []


def test_noqa_blanket_suppresses_everything():
    src = ("import os\n"
           "os.environ['XLA_FLAGS'] = 'x'  # repro: noqa\n")
    assert _scan(src, "xla-flags") == []


def test_noqa_wrong_id_does_not_suppress():
    src = ("import os\n"
           "os.environ['XLA_FLAGS'] = 'x'  # repro: noqa[key-reuse]\n")
    assert _ids(_scan(src, "xla-flags")) == ["xla-flags"]


def test_noqa_rules_parser():
    assert noqa_rules("x = 1") is None
    assert noqa_rules("x = 1  # repro: noqa") == frozenset()
    assert noqa_rules("x = 1  # repro: noqa[a-b, c]") == frozenset({"a-b", "c"})


def test_report_exit_codes():
    warn = Report(findings=[Finding("jit-in-loop", "warning", "x.py", 1, "m")])
    assert warn.exit_code() == 0 and warn.exit_code(strict=True) == 1
    err = Report(findings=[Finding("key-reuse", "error", "x.py", 1, "m")])
    assert err.exit_code() == 1 and err.exit_code(strict=True) == 1
    assert Report().exit_code(strict=True) == 0


def test_suppressed_findings_still_counted():
    src = ("import os\n"
           "os.environ['XLA_FLAGS'] = 'x'  # repro: noqa[xla-flags]\n")
    report = Report()
    from repro.analyze.engine import scan_module
    from repro.analyze.astutils import ModuleContext
    import ast as ast_mod
    import pathlib
    ctx = ModuleContext(path=pathlib.Path("<s>"), relpath="<s>",
                        tree=ast_mod.parse(src), source_lines=src.splitlines())
    scan_module(ctx, get_rules(["xla-flags"]), report)
    assert report.findings == [] and len(report.suppressed) == 1
    assert report.counts["suppressed"] == 1
    assert json.loads(report.to_json())["counts"]["suppressed"] == 1


# ---------------------------------------------------------------------------
# the planted-violation module: every rule class through the real CLI
# ---------------------------------------------------------------------------

_PLANTED = '''\
import os
import time
import jax
import jax.numpy as jnp
import numpy as np
from repro.core.ota import exact_aggregate

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
t0 = time.perf_counter()

def reuse(key):
    a = jax.random.normal(key)
    return a + jax.random.uniform(key)

@jax.jit
def traced(x):
    if jnp.any(x):
        return float(x)
    return np.mean(x)

def loop(fns):
    return [jax.jit(g) for g in fns]
'''

_ALL_RULE_CLASSES = [
    "deprecated-aggregation", "jit-in-loop", "key-reuse", "np-under-trace",
    "raw-timing", "traced-branch", "tracer-leak", "xla-flags",
]


def test_planted_module_trips_every_rule_class(tmp_path):
    p = tmp_path / "planted.py"
    p.write_text(_PLANTED)
    report = run([str(p)], ast_only=True)
    assert _ids(report.findings) == _ALL_RULE_CLASSES
    assert report.exit_code(strict=True) == 1


def test_cli_strict_nonzero_on_planted_zero_on_clean(tmp_path):
    bad, good = tmp_path / "bad.py", tmp_path / "good.py"
    bad.write_text(_PLANTED)
    good.write_text("import jax\n\ndef f(key):\n"
                    "    return jax.random.normal(key)\n")
    out = tmp_path / "r.json"
    rc = analyze_main([str(bad), "--ast-only", "--strict",
                       "--json", str(out)])
    assert rc == 1
    data = json.loads(out.read_text())
    assert sorted({f["rule"] for f in data["findings"]}) == _ALL_RULE_CLASSES
    assert analyze_main([str(good), "--ast-only", "--strict",
                         "--json", ""]) == 0


# ---------------------------------------------------------------------------
# layer 2: trace-level contracts
# ---------------------------------------------------------------------------

def test_lane_contract_passes_every_registered_family():
    from repro.analyze import contracts
    from repro.rl.envs import registered_envs

    report = Report()
    contracts.check_lane_contract(report)
    assert report.findings == [], "\n".join(f.render() for f in report.findings)
    # every family was actually visited (no silent coverage loss): families
    # without a continuous axis leave a skip note instead
    assert len(registered_envs()) >= 6  # the zoo as of this PR


def test_lane_contract_fails_constant_promoted_mutant(monkeypatch):
    # promote a partition constant to a dynamic argument: broadcast lane 0's
    # alpha over the packed axis, exactly the bug that un-folds an XLA
    # literal and lets lanes drift from the per-scenario reference
    from repro.analyze import contracts
    from repro.core import sweep as sweep_mod

    orig = sweep_mod._pack_partition

    def mutant(part):
        packed = orig(part)
        if "alpha" in packed:
            packed["alpha"] = jnp.broadcast_to(
                packed["alpha"][:1], packed["alpha"].shape)
        return packed

    monkeypatch.setattr(sweep_mod, "_pack_partition", mutant)
    report = Report()
    contracts.check_lane_contract(report, families=["landmark"])
    msgs = [f.message for f in report.findings if f.rule == "lane-contract"]
    assert any("identical across lanes" in m for m in msgs), msgs


def test_lane_contract_fails_extra_packed_axis_mutant(monkeypatch):
    # pack an axis that does not vary: the set-equality leg must flag it
    from repro.analyze import contracts
    from repro.core import sweep as sweep_mod

    orig = sweep_mod._pack_partition

    def mutant(part):
        packed = orig(part)
        if part.proto.channel is not None and "noise_sigma" not in packed:
            n = len(part.scenarios)
            packed["noise_sigma"] = jnp.full((n,), 1e-3, jnp.float32)
        return packed

    monkeypatch.setattr(sweep_mod, "_pack_partition", mutant)
    report = Report()
    contracts.check_lane_contract(report, families=["landmark"])
    msgs = [f.message for f in report.findings if f.rule == "lane-contract"]
    assert any("packed axes" in m for m in msgs), msgs


def test_wire_dtype_passes_real_uplink():
    from repro.analyze import contracts

    report = Report()
    contracts.check_wire_dtype(report)
    assert report.findings == [], "\n".join(f.render() for f in report.findings)


def test_wire_dtype_flags_planted_narrowing(monkeypatch):
    from repro.analyze import contracts
    from repro.core import ota as ota_mod

    def narrowed(cfg, **kw):
        return jax.make_jaxpr(lambda g: g.astype(jnp.float16))(
            jnp.zeros((4, 8), jnp.float32))

    monkeypatch.setattr(ota_mod, "uplink_jaxpr", narrowed)
    report = Report()
    contracts.check_wire_dtype(report)
    msgs = [f.message for f in report.findings if f.rule == "wire-dtype"]
    assert any("unsanctioned float narrowing" in m for m in msgs), msgs


def test_narrowing_converts_unit():
    from repro.analyze.contracts import narrowing_converts

    down = jax.make_jaxpr(lambda x: x.astype(jnp.bfloat16))(
        jnp.zeros((3,), jnp.float32))
    assert narrowing_converts(down) == [("float32", "bfloat16")]
    up = jax.make_jaxpr(lambda x: x.astype(jnp.float32))(
        jnp.zeros((3,), jnp.float16))
    assert narrowing_converts(up) == []
    to_int = jax.make_jaxpr(lambda x: x.astype(jnp.int8))(
        jnp.zeros((3,), jnp.float32))
    assert narrowing_converts(to_int) == []  # int casts are not wire dtypes


def test_compile_budget_passes_real_caches(compile_counter):
    from repro.analyze import contracts

    report = Report()
    contracts.check_compile_budget(report)
    assert report.findings == [], "\n".join(f.render() for f in report.findings)


def test_compile_budget_flags_planted_cache_buster(monkeypatch, compile_counter):
    from repro.analyze import contracts
    from repro.core import fedpg

    orig = fedpg.monte_carlo

    def cache_busting(*args, **kwargs):
        fedpg.clear_compilation_cache()   # the recompile-per-call bug
        return orig(*args, **kwargs)

    monkeypatch.setattr(fedpg, "monte_carlo", cache_busting)
    report = Report()
    contracts.check_compile_budget(report)
    msgs = [f.message for f in report.findings if f.rule == "compile-budget"]
    assert any("recompiled" in m for m in msgs), msgs


def test_collective_audit_single_device_skips():
    from repro.analyze import contracts

    if jax.device_count() >= 2:
        pytest.skip("multi-device host: the audit runs for real here")
    report = Report()
    contracts.check_collectives(report)
    assert report.findings == []
    assert any("collective-audit" in note for note in report.skipped)


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >= 2 devices (REPRO_EMULATED_DEVICES=8)")
def test_collective_audit_passes_on_mesh():
    from repro.analyze import contracts

    report = Report()
    contracts.check_collectives(report)
    errors = [f for f in report.findings if f.severity == "error"]
    assert errors == [], "\n".join(f.render() for f in errors)

def test_stream_contract_passes_real_streaming():
    from repro.analyze import contracts

    report = Report()
    contracts.check_stream_contract(report)
    assert report.findings == [], "\n".join(f.render() for f in report.findings)


def test_stream_contract_flags_unblocked_layout_mutant(monkeypatch):
    # collapse the blocked layout to one fleet-sized block: the rollout scan
    # carry becomes (N, M, ...) and must differ between the two traced fleet
    # sizes — exactly the "carry grows with N" regression the check exists for
    from repro.analyze import contracts
    from repro.core import ota as ota_mod

    monkeypatch.setattr(ota_mod, "blocked_layout",
                        lambda n, b: (1, int(n), 0))
    report = Report()
    contracts.check_stream_contract(report)
    msgs = [f.message for f in report.findings if f.rule == "stream-contract"]
    assert any("grows with the fleet" in m or "scales with the fleet" in m
               for m in msgs), msgs
