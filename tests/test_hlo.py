"""Unit tests for the HLO collective parser (`repro.utils.hlo`).

Each collective kind's wire-byte estimate is pinned against the ring-
algorithm formulas the module documents, on synthetic single-line HLO —
including the tuple-shaped results of multi-operand collectives and the
per-kind unpacking of async ``-start`` result tuples.
"""
import pytest

from repro.utils import hlo


def _one(line: str):
    stats = hlo.parse_collective_bytes(line)
    assert stats.total_count == 1, line
    [(kind, nbytes)] = stats.bytes_by_kind.items()
    return kind, nbytes


# ---------------------------------------------------------------------------
# per-op-kind estimates (result R, group size g)
# ---------------------------------------------------------------------------

def test_all_reduce_estimate():
    # R = 256 * 4 = 1024, g = 4: wire = 2R(g-1)/g = 1536
    kind, b = _one("%ar = f32[256]{0} all-reduce(f32[256]{0} %x), "
                   "replica_groups={{0,1,2,3}}, to_apply=%add")
    assert (kind, b) == ("all-reduce", 1536.0)


def test_all_gather_estimate():
    # R = 32 * 4 = 128, g = 4 (iota form [2,4]): wire = R(g-1)/g = 96
    kind, b = _one("%ag = f32[32]{0} all-gather(f32[8]{0} %x), "
                   "replica_groups=[2,4]<=[8], dimensions={0}")
    assert (kind, b) == ("all-gather", 96.0)


def test_reduce_scatter_estimate():
    # R = 8 * 4 = 32 (the scattered piece), g = 4: wire = R(g-1) = 96
    kind, b = _one("%rs = f32[8]{0} reduce-scatter(f32[32]{0} %x), "
                   "replica_groups={{0,1,2,3}}, to_apply=%add")
    assert (kind, b) == ("reduce-scatter", 96.0)


def test_all_to_all_estimate():
    # R = 64 * 4 = 256, g = 8: wire = R(g-1)/g = 224
    kind, b = _one("%a2a = f32[64]{0} all-to-all(f32[64]{0} %x), "
                   "replica_groups=[1,8]<=[8], dimensions={0}")
    assert (kind, b) == ("all-to-all", 224.0)


def test_collective_permute_estimate():
    # wire = R exactly, group size irrelevant
    kind, b = _one("%cp = f32[16]{0} collective-permute(f32[16]{0} %x), "
                   "source_target_pairs={{0,1},{1,0}}")
    assert (kind, b) == ("collective-permute", 64.0)


def test_unparsable_groups_default_g2():
    # no replica_groups: conservative g=2; all-reduce wire = 2R/2 = R
    kind, b = _one("%ar = f32[10]{0} all-reduce(f32[10]{0} %x), to_apply=%add")
    assert (kind, b) == ("all-reduce", 40.0)


def test_bf16_shape_bytes():
    assert hlo.shape_bytes("bf16", "256,4") == 2048
    assert hlo.shape_bytes("f32", "") == 4        # scalar f32[]
    assert hlo.shape_bytes("token", "") == 0      # opaque carries nothing


# ---------------------------------------------------------------------------
# tuple-shaped results
# ---------------------------------------------------------------------------

def test_tuple_result_multi_operand_all_reduce():
    # fused variadic all-reduce: result = sum of members = 2 * 16 bytes
    kind, b = _one("%ar = (f32[4]{0}, f32[4]{0}) all-reduce("
                   "f32[4]{0} %a, f32[4]{0} %b), replica_groups={{0,1}}, "
                   "to_apply=%add")
    assert (kind, b) == ("all-reduce", 32.0)  # 2 * 32 * (2-1)/2


def test_all_gather_start_takes_result_member():
    # (operand f32[8], result f32[32]): result member is the max, not half
    kind, b = _one("%ags = (f32[8]{0}, f32[32]{0}) all-gather-start("
                   "f32[8]{0} %x), replica_groups=[2,4]<=[8], dimensions={0}")
    assert (kind, b) == ("all-gather", 96.0)  # same as the sync form


def test_reduce_scatter_start_takes_scattered_member():
    # (operand f32[32], result f32[8], ctx u32[]): scattered piece is the
    # operand (max member) / g — ctx scalars must not skew the estimate
    kind, b = _one("%rss = (f32[32]{0}, f32[8]{0}, u32[], u32[]) "
                   "reduce-scatter-start(f32[32]{0} %x), "
                   "replica_groups={{0,1,2,3}}, to_apply=%add")
    assert (kind, b) == ("reduce-scatter", 96.0)


def test_all_reduce_start_halves_pair():
    kind, b = _one("%ars = (f32[256]{0}, f32[256]{0}) all-reduce-start("
                   "f32[256]{0} %x), replica_groups={{0,1,2,3}}, "
                   "to_apply=%add")
    assert (kind, b) == ("all-reduce", 1536.0)  # same as the sync form


def test_done_half_is_skipped():
    text = ("%ars = (f32[8]{0}, f32[8]{0}) all-reduce-start(f32[8]{0} %x), "
            "replica_groups={{0,1}}, to_apply=%add\n"
            "%ard = f32[8]{0} all-reduce-done((f32[8]{0}, f32[8]{0}) %ars)\n")
    stats = hlo.parse_collective_bytes(text)
    assert stats.total_count == 1
    assert stats.bytes_by_kind["all-reduce"] == 32.0


def test_nested_tuple_fallback_not_dropped():
    # multi-operand async pair: "((ops), (results))" breaks the flat
    # result-region grammar; the lazy fallback must still count the op,
    # taking the larger (result) group
    kind, b = _one("%ags = ((f32[8]{0}, f32[8]{0}), (f32[32]{0}, f32[32]{0}))"
                   " all-gather-start(f32[8]{0} %a, f32[8]{0} %b), "
                   "replica_groups=[2,4]<=[8], dimensions={0}")
    assert kind == "all-gather"
    assert b == pytest.approx(256 * 3 / 4)


def test_tuple_members_nesting():
    assert hlo._tuple_members("(f32[8], (f32[64], f32[64]))") == [
        "f32[8]", "(f32[64], f32[64])"]
    assert hlo._tuple_members("f32[8]{0}") == ["f32[8]{0}"]
    # commas inside shape dims don't split members
    assert hlo._tuple_members("(f32[8,4]{1,0}, f32[2,2]{1,0})") == [
        "f32[8,4]{1,0}", "f32[2,2]{1,0}"]


def test_count_op():
    text = ("%f = f32[8]{0} fusion(f32[8]{0} %x), kind=kLoop\n"
            "%g = f32[8]{0} fusion(f32[8]{0} %f), kind=kLoop\n"
            "%d = f32[8,8]{1,0} dot(f32[8,8]{1,0} %a, f32[8,8]{1,0} %b)\n")
    assert hlo.count_op(text, "fusion") == 2
    assert hlo.count_op(text, "dot") == 1
    assert hlo.count_op(text, "all-reduce") == 0


def test_summary_and_totals():
    text = ("%ar = f32[10]{0} all-reduce(f32[10]{0} %x), to_apply=%add\n"
            "%cp = f32[4]{0} collective-permute(f32[4]{0} %y), "
            "source_target_pairs={{0,1}}\n")
    stats = hlo.parse_collective_bytes(text)
    assert stats.total_count == 2
    assert stats.total_bytes == 40.0 + 16.0
    assert "all-reduce" in stats.summary()
    assert hlo.parse_collective_bytes("").summary() == "no collectives"
