"""Minimal stand-in for ``hypothesis`` when it isn't installed.

``@given(...)`` marks the test skipped (property tests need the real
library); ``@settings`` is a no-op; ``st.<anything>(...)`` returns an inert
placeholder (only ever passed to the skipped ``given``).  Plain unit tests
in the same module keep running on a bare interpreter.
"""
import pytest


def given(*args, **kwargs):
    del args, kwargs

    def deco(fn):
        return pytest.mark.skip(reason="hypothesis not installed")(fn)

    return deco


def settings(*args, **kwargs):
    del args, kwargs
    return lambda fn: fn


class _Strategies:
    def __getattr__(self, name):
        def _strategy(*args, **kwargs):
            return None

        return _strategy


st = _Strategies()
