"""Golden-trace regression suite: checked-in History fingerprints.

Each ``tests/golden/<family>.json`` pins the fingerprint (first/last-round
``rewards``/``grad_sq`` per MC run, printed through float64 so the float32
values round-trip exactly) of one canonical scenario per env family under
each of three uplinks:

    exact       — Algorithm 1 (channel=None)
    rayleigh    — Algorithm 2 over RayleighChannel + AWGN, debiased
    controlled  — power-controlled uplink (TruncatedInversion over Rayleigh)

Tolerance policy (see tests/README.md): every family compares **exactly**
(the sweep engine's bitwise-lane contract) except ``lqr``, whose traced-
parameter matvec/quadratic fusions may reassociate the last mantissa bit —
it compares at ``rtol=1e-6``.

Regenerating after an INTENTIONAL numerical change:

    python -m pytest tests/test_golden.py --update-golden

then inspect the JSON diff — every changed number is a behaviour change the
PR must justify.  ``tests/test_distribute.py`` reuses these scenarios to
hold ``mode="sharded"`` bit-identical to ``mode="vmap"``.
"""
import functools
import json
import pathlib

import jax
import numpy as np
import pytest

from repro.core.channel import RayleighChannel
from repro.core.power_control import TruncatedInversion, make_controlled_channel
from repro.core.sweep import Scenario, sweep
from repro.rl.envs import (
    CliffWalk, LQRTask, MultiLandmarkNav, WindyLandmarkNav, garnet, make_env,
    make_heterogeneous_env,
)

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"

SMALL = dict(n_agents=3, batch_m=2, horizon=6, n_rounds=4)
MC_RUNS = 2
KEY_SEED = 0

# families compare exactly unless listed here (documented reassociation)
RTOL = {"lqr": 1e-6}


def _families():
    """One canonical env per family (deterministic construction)."""
    return {
        "landmark": make_env("landmark"),
        "windy": WindyLandmarkNav(wind=0.05),
        "multilandmark": MultiLandmarkNav(n_landmarks=3),
        "cliffwalk": CliffWalk(width=4, height=3, slip=0.1),
        "lqr": LQRTask(process_sigma=0.1),
        "tabular": garnet(jax.random.key(0), 4, 2, branching=2),
        # one lane per agent (SMALL n_agents=3): the heterogeneous-fleet
        # golden the streamed (agent_blocks) equivalence suite pins against
        "hetero": make_heterogeneous_env(
            [WindyLandmarkNav(wind=w) for w in (0.0, 0.1, 0.2)]),
    }


def _uplinks():
    return {
        "exact": dict(channel=None),
        "rayleigh": dict(channel=RayleighChannel(), noise_sigma=1e-3,
                         debias=True),
        "controlled": dict(
            channel=make_controlled_channel(RayleighChannel(),
                                            TruncatedInversion()),
            noise_sigma=1e-3, debias=True),
    }


def golden_cases():
    """[(family, uplink, Scenario)] — the canonical grid, in a stable order."""
    cases = []
    for fam, env in _families().items():
        for uplink, kw in _uplinks().items():
            cases.append((fam, uplink,
                          Scenario(env=env, tag=f"{fam}:{uplink}", **kw,
                                   **SMALL)))
    return cases


@functools.lru_cache(maxsize=None)
def run_golden_sweep(mode: str = "vmap"):
    """The whole golden grid through one sweep() call; cached so
    test_distribute.py's sharded comparison doesn't recompute the vmap
    reference inside the same process."""
    cases = golden_cases()
    res = sweep(None, None, [s for _, _, s in cases],
                jax.random.key(KEY_SEED), MC_RUNS, mode=mode)
    return {(fam, up): res.scenario_history(i)
            for i, (fam, up, _) in enumerate(cases)}


def fingerprint(hist) -> dict:
    """First/last-round rewards/grad_sq per MC run, as float64-printed
    lists (exact round-trip for the underlying float32 values)."""
    r = np.asarray(hist.rewards, np.float64)
    g = np.asarray(hist.grad_sq, np.float64)
    return {
        "rewards_first": [float(x) for x in r[:, 0]],
        "rewards_last": [float(x) for x in r[:, -1]],
        "grad_sq_first": [float(x) for x in g[:, 0]],
        "grad_sq_last": [float(x) for x in g[:, -1]],
    }


@pytest.mark.parametrize("family", sorted(_families()))
def test_golden_trace(family, request):
    update = request.config.getoption("--update-golden")
    # NB: pass "vmap" explicitly — lru_cache keys () and ("vmap",)
    # separately, and test_distribute.py reuses this exact entry
    hists = run_golden_sweep("vmap")
    got = {up: fingerprint(hists[(family, up)]) for up in _uplinks()}
    path = GOLDEN_DIR / f"{family}.json"

    if update:
        GOLDEN_DIR.mkdir(exist_ok=True)
        doc = {
            "_comment": (
                "Golden History fingerprint (float64-printed float32). "
                "Regenerate ONLY for an intentional numerical change: "
                "python -m pytest tests/test_golden.py --update-golden "
                "— then inspect this diff. Tolerance policy: tests/README.md."
            ),
            "config": {**SMALL, "mc_runs": MC_RUNS, "key_seed": KEY_SEED,
                       "jax": jax.__version__},
            "uplinks": got,
        }
        path.write_text(json.dumps(doc, indent=1) + "\n")
        pytest.skip(f"regenerated {path.name}")

    if not path.exists():
        pytest.fail(f"{path} missing — generate it with --update-golden")
    stored = json.loads(path.read_text())["uplinks"]
    rtol = RTOL.get(family)
    for uplink, fp in got.items():
        for field, vals in fp.items():
            want = stored[uplink][field]
            if rtol is None:
                assert vals == want, (
                    f"{family}/{uplink}/{field}: {vals} != golden {want} "
                    "(exact-compare family; see tests/README.md)")
            else:
                np.testing.assert_allclose(
                    vals, want, rtol=rtol, atol=0.0,
                    err_msg=f"{family}/{uplink}/{field} (rtol={rtol})")


def test_golden_unchanged_by_telemetry():
    """In-jit telemetry probes must not perturb the trained metrics: the
    landmark family re-run with every probe enabled fingerprints bitwise
    identical to the stored (telemetry-off) goldens."""
    from repro.telemetry import TelemetryConfig

    path = GOLDEN_DIR / "landmark.json"
    if not path.exists():
        pytest.skip("landmark golden missing — generate with --update-golden")
    stored = json.loads(path.read_text())["uplinks"]

    env = make_env("landmark")
    scens = [Scenario(env=env, tag=f"landmark:{up}", **kw, **SMALL)
             for up, kw in _uplinks().items()]
    res = sweep(None, None, scens, jax.random.key(KEY_SEED), MC_RUNS,
                telemetry=TelemetryConfig())
    assert res.history.telemetry is not None
    for i, uplink in enumerate(_uplinks()):
        fp = fingerprint(res.scenario_history(i))
        for field, vals in fp.items():
            assert vals == stored[uplink][field], (
                f"telemetry-on landmark/{uplink}/{field}: {vals} != golden "
                f"{stored[uplink][field]}")


def test_golden_covers_every_family_x_uplink():
    """The canonical grid really is families x uplinks, each exactly once."""
    cases = golden_cases()
    assert len(cases) == len(_families()) * len(_uplinks())
    assert len({(f, u) for f, u, _ in cases}) == len(cases)
    # every scenario resolves an env + a policy (no sweep-level defaults)
    from repro.core.sweep import resolve_env_policy
    for _, _, s in cases:
        env, pol = resolve_env_policy(s)
        assert env is not None and pol is not None
