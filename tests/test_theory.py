"""Theory module: constants, bound structure (monotonicity/limits that the
paper claims), and the Lemma 3 bound validated against simulation."""
import math

import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare interpreter: only the property tests skip
    from _hypothesis_stub import given, settings, st

from repro.core import ota, theory
from repro.core.channel import NakagamiChannel, RayleighChannel
from repro.rl.env import TabularMDP
from repro.rl.policy import TabularSoftmaxPolicy
from repro.rl.sampler import rollout_batch
from repro.core import gpomdp
from repro.utils.tree import tree_global_norm_sq, tree_sub


def test_smoothness_constant_formula():
    c = theory.MDPConstants(G=2.0, F=1.0, l_bar=1.0, gamma=0.9)
    # L = (F + G^2 + 2 gamma G^2/(1-gamma)) * gamma*l_bar/(1-gamma)^2
    expected = (1 + 4 + 2 * 0.9 * 4 / 0.1) * (0.9 / 0.01)
    assert c.smoothness_L() == pytest.approx(expected)
    assert c.V() == pytest.approx(2.0 * 1.0 * 0.9 / 0.01)
    assert c.max_stepsize(m_h=2.0) == pytest.approx(1.0 / (2.0 * expected))


def test_lambda_and_condition():
    ray = RayleighChannel()
    nak = NakagamiChannel(m=0.1, omega=1.0)
    assert theory.channel_condition_ok(1, ray.mean, ray.var)
    assert not theory.channel_condition_ok(5, nak.mean, nak.var)
    # Lambda > 0 iff the step's descent term survives (Thm 1 denominator)
    assert theory.Lambda(10, 5, ray.mean, ray.var) > 0


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 64),
    m=st.integers(1, 64),
    k=st.integers(10, 10_000),
)
def test_theorem1_monotone_in_K_and_N_floor(n, m, k):
    """More rounds never worsen the bound (only the first term carries K);
    more agents never worsen the K->inf variance floor (the linear-speedup
    claim applies to the floor — the transient term's N/(N+1) factor makes
    the full bound non-monotone in N at small K, by design of Eq. 10)."""
    ch = RayleighChannel()
    kw = dict(
        batch_m=m, alpha=1e-3, m_h=ch.mean, sigma_h2=ch.var,
        noise_sigma2=1e-6, delta_J=10.0, V=5.0,
    )
    b = theory.theorem1_bound(K=k, n_agents=n, **kw)
    b_k = theory.theorem1_bound(K=2 * k, n_agents=n, **kw)
    assert b_k <= b + 1e-12
    floor_n = theory.theorem1_bound(K=10**12, n_agents=n, **kw)
    floor_2n = theory.theorem1_bound(K=10**12, n_agents=2 * n, **kw)
    assert floor_2n <= floor_n + 1e-12


def test_linear_speedup_structure():
    """Theorem 1: with K ~ N*M scaling out, the variance terms decay as
    1/(N...) — doubling N roughly halves the non-K terms (linear speedup)."""
    ch = RayleighChannel()
    kw = dict(batch_m=10, alpha=1e-3, m_h=ch.mean, sigma_h2=ch.var,
              noise_sigma2=1e-6, delta_J=10.0, V=5.0, K=10**9)
    floors = [theory.theorem1_bound(n_agents=n, **kw) for n in (8, 16, 32)]
    r1 = floors[0] / floors[1]
    r2 = floors[1] / floors[2]
    assert 1.7 < r1 < 2.3 and 1.7 < r2 < 2.3


def test_theorem2_channel_floor_independent_of_K_M():
    """Remark 3: the O(1/N) channel-variance floor is not reduced by K or M."""
    ch = NakagamiChannel(m=0.1, omega=1.0)

    def floor(K, M):
        full = theory.theorem2_bound(
            K=K, n_agents=10, batch_m=M, alpha=1e-3, m_h=ch.mean,
            sigma_h2=ch.var, noise_sigma2=1e-6, delta_J=10.0, V=5.0,
        )
        return full

    # increasing K and M cannot drive the bound to 0: term2 ~ M sigma_h^2 V^2 / denom
    b = floor(10**9, 10**6)
    denom = 10**6 * 11 * ch.mean**2 + ch.var
    analytic_floor = (10**6 * ch.var * 25.0) / denom
    assert b >= analytic_floor * 0.99
    assert analytic_floor > 0.01  # a real floor, not epsilon


def test_corollary1_schedule():
    s = theory.corollary1_schedule(1e-2)
    assert s.K == 100
    assert s.n_agents == 10
    assert s.batch_m == math.ceil(1.0 / (10 * 1e-2))
    s2 = theory.corollary1_schedule(1e-4)
    assert s2.K == 100 * s.K               # K = O(1/eps)
    assert s2.n_agents == 10 * s.n_agents  # N = O(1/sqrt(eps))


def test_lemma3_bound_holds_empirically():
    """E||v/(m_h N) - grad J||^2 <= Lemma-3 RHS on a tabular MDP where the
    exact gradient (hence exact ||grad J||^2) is computable."""
    mdp = TabularMDP.random(jax.random.key(0), n_states=3, n_actions=2,
                            gamma=0.9, horizon=3)
    pol = TabularSoftmaxPolicy(3, 2)
    theta = pol.init(jax.random.key(1))
    g_exact = jax.grad(lambda p: mdp.exact_J(pol.action_probs(p)))(theta)
    grad_sq = float(tree_global_norm_sq(g_exact))

    ch = RayleighChannel()
    n_agents, batch_m, sigma = 4, 2, 1e-3
    cfg = ota.OTAConfig(channel=ch, noise_sigma=sigma, debias=True)

    @jax.jit
    def one(k):
        k1, k2 = jax.random.split(k)
        ks = jax.random.split(k1, n_agents)

        def agent(ka):
            traj = rollout_batch(mdp, pol, theta, ka, mdp.horizon, batch_m)
            return gpomdp.gpomdp_gradient(pol, theta, traj, mdp.gamma)

        grads = jax.vmap(agent)(ks)
        u, _ = ota.aggregate_stacked(cfg, k2, grads)
        return tree_global_norm_sq(tree_sub(u, g_exact))

    errs = jax.vmap(one)(jax.random.split(jax.random.key(2), 2000))
    empirical = float(jnp.mean(errs))

    # V envelope: sup per-trajectory G(PO)MDP norm; G <= sqrt(2*S) for the
    # tabular softmax (one-hot obs), l_bar = 1, per Assumption 1/2.
    consts = theory.MDPConstants(G=math.sqrt(2.0), F=0.5, l_bar=1.0, gamma=0.9)
    bound = theory.lemma3_bound(
        n_agents=n_agents, batch_m=batch_m, m_h=ch.mean, sigma_h2=ch.var,
        noise_sigma2=sigma**2, V=consts.V(), grad_sq=grad_sq,
    )
    assert empirical <= bound, (empirical, bound)
