"""Round-service suite (``repro.service`` + the service paths of
``fedpg``/``event_triggered``).

The contracts under test:

* **Bitwise-off** — a participation config that can never drop an agent
  (full, static ``rate >= 1``, ``subset >= N``) normalises away and the
  emitted program is byte-identical to the plain run (jaxpr string pin +
  value check), on ``fedpg.run`` and the event-triggered baseline alike.
* **Block/shard invariance** — the per-round mask, the replay weights
  and every normaliser scalar are derived from absolute agent ids before
  the block scan, so the streamed service round is bitwise invariant to
  ``agent_blocks`` (padded non-dividing fleets included) and to the
  ``agent_mesh`` shard count.
* **Empty rounds** — a round nobody makes commits an exact-zero update
  (the AWGN draw is discarded, never amplified).
* **Driver determinism** — a checkpoint/resume cycle replays the
  identical key and mask streams: resumed state is bitwise equal to the
  uninterrupted service.
* **Cache keys** — participation/staleness key the compiled-callable
  caches, and a normalised-away config hits the same entry as ``None``.
"""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare interpreter: only the property tests skip
    from _hypothesis_stub import given, settings, st

from repro.core import event_triggered, fedpg
from repro.core.channel import RayleighChannel
from repro.core.ota import OTAConfig
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy
from repro.service import participation as svc_participation
from repro.service.driver import RoundService, ServiceConfig
from repro.service.faults import CrashSchedule, FaultConfig, StragglerModel
from repro.service.participation import ParticipationConfig
from repro.service.staleness import StalenessConfig
from repro.telemetry import Ledger, using_ledger
from repro.telemetry.probes import TelemetryConfig

N_DEV = jax.device_count()
SMALL = dict(n_agents=7, batch_m=2, horizon=5, n_rounds=3)
RAYLEIGH = OTAConfig(channel=RayleighChannel(), noise_sigma=1e-3, debias=True)
BERN = ParticipationConfig(rate=0.5)
STALE = StalenessConfig(max_age=2, decay=0.5)
BLOCK_GRID = (1, 3, 4, 100)


@pytest.fixture(scope="module")
def env_pol():
    return LandmarkNav(), MLPPolicy()


def _bitwise(a, b, what=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), what
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


def _close(a, b, what="", rtol=1e-5):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), what
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=1e-7, err_msg=what)


def _strip_addresses(jaxpr_text: str) -> str:
    # function-object reprs in jvp_jaxpr_thunk params carry addresses
    return re.sub(r"0x[0-9a-f]+", "0x", jaxpr_text)


def _key_state(state):
    """ServiceState with typed keys replaced by their raw bits so the
    whole tree is numpy-comparable."""
    return state._replace(part_key=jax.random.key_data(state.part_key),
                          sched_key=jax.random.key_data(state.sched_key))


# ---------------------------------------------------------------------------
# bitwise-off: never-dropping configs emit the plain program
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("uplink", ["exact", "rayleigh"])
def test_full_participation_is_bitwise_off(env_pol, uplink, key):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(**SMALL)
    ocfg = None if uplink == "exact" else RAYLEIGH
    off_configs = [
        ParticipationConfig(rate=1.0),
        ParticipationConfig(kind="full"),
        ParticipationConfig(kind="subset", subset=cfg.n_agents),
        # inactive faults can't drop anyone either
        ParticipationConfig(kind="full", faults=FaultConfig(
            stragglers=StragglerModel(mean=1.0))),  # deadline=inf
    ]
    j_none = jax.make_jaxpr(
        lambda k: fedpg.run(env, pol, cfg, k, ota=ocfg))(key)
    for p in off_configs:
        j_p = jax.make_jaxpr(
            lambda k: fedpg.run(env, pol, cfg, k, ota=ocfg, participation=p,
                                staleness=STALE))(key)
        assert _strip_addresses(str(j_none)) == _strip_addresses(str(j_p)), p
    ref = fedpg.run_jit(env, pol, cfg, key, ota=ocfg)
    got = fedpg.run_jit(env, pol, cfg, key, ota=ocfg,
                        participation=off_configs[0], staleness=STALE)
    _bitwise(got, ref, "full participation must be byte-identical")


def test_staleness_without_participation_is_off(env_pol, key):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(**SMALL)
    j_none = jax.make_jaxpr(
        lambda k: fedpg.run(env, pol, cfg, k, ota=RAYLEIGH))(key)
    j_st = jax.make_jaxpr(
        lambda k: fedpg.run(env, pol, cfg, k, ota=RAYLEIGH,
                            staleness=STALE))(key)
    assert _strip_addresses(str(j_none)) == _strip_addresses(str(j_st))


# ---------------------------------------------------------------------------
# block invariance of the streamed service round
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("uplink", ["exact", "rayleigh"])
@pytest.mark.parametrize("staleness", [None, STALE])
def test_partial_block_invariance(env_pol, uplink, staleness, key):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(**SMALL)
    ocfg = None if uplink == "exact" else RAYLEIGH
    tel = TelemetryConfig()
    ref = None
    for b in BLOCK_GRID:
        got = fedpg.run_jit(env, pol, cfg, key, ota=ocfg, participation=BERN,
                            staleness=staleness, telemetry=tel,
                            agent_blocks=b)
        if ref is None:
            ref = got
        else:
            _bitwise(got, ref, f"agent_blocks={b} vs {BLOCK_GRID[0]}")
    # vs the stacked (vmap) form: identical PRNG/mask streams — the
    # telemetry (participation rate/drift, staleness age) and gain_mean
    # compare bitwise; sums reassociate, so updates compare tight-close
    stacked = fedpg.run_jit(env, pol, cfg, key, ota=ocfg, participation=BERN,
                            staleness=staleness, telemetry=tel)
    _bitwise(stacked[1].telemetry.participation_rate,
             ref[1].telemetry.participation_rate, "realised rate")
    _bitwise(stacked[1].telemetry.participation_drift,
             ref[1].telemetry.participation_drift, "debias drift")
    if staleness is not None:
        _bitwise(stacked[1].telemetry.staleness_mean,
                 ref[1].telemetry.staleness_mean, "mean replayed age")
    _bitwise(stacked[1].gain_mean, ref[1].gain_mean, "gain_mean")
    _close(stacked[0], ref[0], "theta stacked-vs-streamed")


@settings(max_examples=4, deadline=None)
@given(rate=st.floats(min_value=0.2, max_value=0.9),
       b1=st.sampled_from(BLOCK_GRID), b2=st.sampled_from(BLOCK_GRID),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_property_block_invariance(rate, b1, b2, seed):
    env, pol = LandmarkNav(), MLPPolicy()
    cfg = fedpg.FedPGConfig(n_agents=5, batch_m=1, horizon=4, n_rounds=2)
    p = ParticipationConfig(rate=rate)
    k = jax.random.key(seed)
    a = fedpg.run_jit(env, pol, cfg, k, ota=RAYLEIGH, participation=p,
                      staleness=STALE, agent_blocks=b1)
    b = fedpg.run_jit(env, pol, cfg, k, ota=RAYLEIGH, participation=p,
                      staleness=STALE, agent_blocks=b2)
    _bitwise(a, b, f"blocks {b1} vs {b2} at rate {rate}")


@pytest.mark.skipif(N_DEV < 2, reason="needs an emulated device mesh")
def test_partial_shard_invariance(env_pol, key):
    from repro.core.distribute import agent_mesh_for

    env, pol = env_pol
    cfg = fedpg.FedPGConfig(**SMALL)  # N=7: mesh does not divide the fleet
    tel = TelemetryConfig()
    mesh = agent_mesh_for(min(N_DEV, 4))
    stacked = fedpg.run(env, pol, cfg, key, ota=RAYLEIGH, participation=BERN,
                        telemetry=tel)
    sharded = fedpg.run(env, pol, cfg, key, ota=RAYLEIGH, participation=BERN,
                        telemetry=tel, agent_mesh=mesh, agent_blocks=2)
    # the counter-PRNG mask is derived from absolute ids on every form
    _bitwise(stacked[1].telemetry.participation_rate,
             sharded[1].telemetry.participation_rate,
             "mask must be shard-invariant")
    _bitwise(stacked[1].telemetry.participation_drift,
             sharded[1].telemetry.participation_drift)


# ---------------------------------------------------------------------------
# semantics: subset rotation, empty rounds, staleness indexing
# ---------------------------------------------------------------------------

def test_subset_round_robin_rate(env_pol, key):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(**SMALL)
    p = ParticipationConfig(kind="subset", subset=3)
    _, hist = fedpg.run_jit(env, pol, cfg, key, ota=RAYLEIGH,
                            participation=p, telemetry=TelemetryConfig())
    rate = np.asarray(hist.telemetry.participation_rate)
    # exactly w participants every round, rotating deterministically
    assert np.all(rate == rate[0])
    np.testing.assert_allclose(rate, 3.0 / cfg.n_agents, rtol=1e-6)


@pytest.mark.parametrize("debias", ["realized", "expected"])
def test_empty_rounds_commit_zero_update(env_pol, debias, key):
    # everyone crashes every round: W == 0, the update must be an exact
    # zero (AWGN discarded) and theta must never move
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(**SMALL)
    p = ParticipationConfig(kind="full", debias=debias, faults=FaultConfig(
        crashes=CrashSchedule(frac=1.0, period=1, down=1)))
    theta0 = pol.init(jax.random.split(key, 3)[0])
    theta, hist = fedpg.run_jit(env, pol, cfg, key, ota=RAYLEIGH,
                                participation=p)
    _bitwise(theta, theta0, "empty rounds must not move theta")
    assert np.all(np.asarray(hist.grad_sq) == 0.0)
    assert np.all(np.asarray(hist.gain_mean) == 0.0)


def test_stale_buffer_absolute_index_padded_fleet(env_pol, key):
    # N=7 with block 4 pads a phantom row; the replay buffer must stay
    # indexed by absolute agent id (bitwise equal to the unpadded block 1)
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(**SMALL)
    tel = TelemetryConfig()
    runs = [fedpg.run_jit(env, pol, cfg, key, ota=RAYLEIGH,
                          participation=ParticipationConfig(rate=0.3),
                          staleness=StalenessConfig(max_age=3, decay=0.9),
                          telemetry=tel, agent_blocks=b) for b in (1, 4)]
    _bitwise(runs[0], runs[1], "padded stale buffer must be bitwise")
    # staleness replay changes the update vs no-staleness at equal masks
    bare = fedpg.run_jit(env, pol, cfg, key, ota=RAYLEIGH,
                         participation=ParticipationConfig(rate=0.3),
                         telemetry=tel, agent_blocks=1)
    assert not np.array_equal(np.asarray(runs[0][1].grad_sq),
                              np.asarray(bare[1].grad_sq))


# ---------------------------------------------------------------------------
# compiled-callable cache keys
# ---------------------------------------------------------------------------

def test_cache_keys_include_service_configs(env_pol, key):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(**SMALL)
    fedpg.clear_compilation_cache()
    fedpg.run_jit(env, pol, cfg, key, ota=RAYLEIGH)
    assert fedpg._compiled_run.cache_info().misses == 1
    # a normalised-away config must hit the same entry as None
    fedpg.run_jit(env, pol, cfg, key, ota=RAYLEIGH,
                  participation=ParticipationConfig(rate=1.0),
                  staleness=STALE)
    info = fedpg._compiled_run.cache_info()
    assert (info.misses, info.hits) == (1, 1)
    # an active config is a different program
    fedpg.run_jit(env, pol, cfg, key, ota=RAYLEIGH, participation=BERN)
    assert fedpg._compiled_run.cache_info().misses == 2
    # ...and so is each staleness depth
    fedpg.run_jit(env, pol, cfg, key, ota=RAYLEIGH, participation=BERN,
                  staleness=STALE)
    assert fedpg._compiled_run.cache_info().misses == 3


# ---------------------------------------------------------------------------
# event-triggered baseline under participation
# ---------------------------------------------------------------------------

def test_et_full_participation_bitwise(env_pol, key):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(**SMALL)
    et = event_triggered.ETConfig(tau=0.05)
    j_none = jax.make_jaxpr(
        lambda k: event_triggered.run(env, pol, cfg, et, k))(key)
    j_full = jax.make_jaxpr(
        lambda k: event_triggered.run(
            env, pol, cfg, et, k,
            participation=ParticipationConfig(rate=1.0)))(key)
    assert _strip_addresses(str(j_none)) == _strip_addresses(str(j_full))
    ref = event_triggered.run_jit(env, pol, cfg, et, key)
    got = event_triggered.run_jit(env, pol, cfg, et, key,
                                  participation=ParticipationConfig(kind="full"))
    _bitwise(got, ref)


@pytest.mark.parametrize("agent_blocks", [None, 3])
def test_et_participation_gates_triggers(env_pol, agent_blocks, key):
    # with tau=0 every *participant* triggers, so the upload count must
    # equal the realised participating count of the service mask stream —
    # pinning the exact key derivation (split(key,3) -> split(key_svc))
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(**SMALL)
    et = event_triggered.ETConfig(tau=0.0)
    _, hist = event_triggered.run_jit(env, pol, cfg, et, key,
                                      participation=BERN,
                                      agent_blocks=agent_blocks)
    _, _, key_svc = jax.random.split(key, 3)
    part_key, sched_key = jax.random.split(key_svc)
    ids = jnp.arange(cfg.n_agents, dtype=jnp.int32)
    expect = [
        float(jnp.sum(svc_participation.round_mask(
            BERN, part_key, sched_key, jnp.int32(r), ids, cfg.n_agents)))
        for r in range(cfg.n_rounds)
    ]
    np.testing.assert_array_equal(np.asarray(hist.uploads), expect)
    # non-participating rounds exist in this stream (rate 0.5, N=7)
    assert min(expect) < cfg.n_agents


# ---------------------------------------------------------------------------
# the host-side driver: determinism, checkpoint/resume, ledger
# ---------------------------------------------------------------------------

def _make_service(env, pol, key, tmpdir="", max_rounds=8):
    cfg = fedpg.FedPGConfig(n_agents=7, batch_m=1, horizon=4, n_rounds=1)
    return RoundService(
        env, pol, cfg, key, participation=BERN, staleness=STALE, ota=RAYLEIGH,
        telemetry=TelemetryConfig(), agent_blocks=3,
        service=ServiceConfig(rounds_per_commit=2, max_rounds=max_rounds,
                              checkpoint_dir=str(tmpdir)))


def test_driver_requires_active_participation(env_pol, key):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(**SMALL)
    with pytest.raises(ValueError, match="active participation"):
        RoundService(env, pol, cfg, key,
                     participation=ParticipationConfig(rate=1.0))


def test_driver_checkpoint_resume_bitwise(env_pol, key, tmp_path):
    env, pol = env_pol
    # uninterrupted reference: 8 rounds in 4 commits
    ref = _make_service(env, pol, key)
    recs = ref.run()
    assert len(recs) == 4 and recs[-1]["round_end"] == 8
    # interrupted twin: 2 commits + checkpoint, then a FRESH service
    # resumes and finishes — state must be bitwise identical
    a = _make_service(env, pol, key, tmp_path)
    a.commit(), a.commit()
    b = _make_service(env, pol, key, tmp_path)
    assert b.resume()
    assert int(b.state.round_idx) == 4
    b.run()
    _bitwise(_key_state(b.state), _key_state(ref.state),
             "resumed service must replay the identical stream")


def test_driver_ledger_and_report(env_pol, key, tmp_path):
    from repro.telemetry import report as trep
    from repro.telemetry.ledger import read_ledger

    env, pol = env_pol
    path = str(tmp_path / "ledger.jsonl")
    with Ledger(path) as led, using_ledger(led):
        svc = _make_service(env, pol, key, max_rounds=4)
        svc.run()
    events = [e for e in read_ledger(path) if e["kind"] == "service"]
    assert len(events) == 2
    for ev in events:
        assert {"round_start", "round_end", "reward", "grad_sq",
                "participation_rate", "participation_drift",
                "staleness_hist", "wall_us"} <= set(ev)
        assert 0.0 <= ev["participation_rate"] <= 1.0
        # N=7 agents distributed over age buckets 0..max_age+1
        assert sum(ev["staleness_hist"]) == 7
    text = trep.render(read_ledger(path))
    assert "## Round service" in text
    assert "participation_rate" in text


def test_driver_deadline_flag(env_pol, key):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(n_agents=3, batch_m=1, horizon=3, n_rounds=1)
    svc = RoundService(
        env, pol, cfg, key, participation=BERN,
        service=ServiceConfig(rounds_per_commit=1, max_rounds=1,
                              round_deadline_s=1e-9))
    rec = svc.commit()
    assert rec.get("deadline_exceeded") is True and rec["per_round_s"] > 0
