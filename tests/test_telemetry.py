"""Observability tests: in-jit probes, span tracer, run ledger.

The load-bearing invariant: telemetry OFF (None or an all-probes-off
config) emits a program bitwise identical to the pre-telemetry one — the
jaxpr equality here plus the golden-trace suite pin it.  Probe *math* is
checked against hand-computed references on tiny fixed inputs.
"""
import json
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fedpg, theory
from repro.core.channel import FixedGainChannel, RayleighChannel
from repro.core.ota import OTAConfig
from repro.core.sweep import grid, sweep
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy
from repro.telemetry import (
    Ledger, RoundTelemetry, TelemetryConfig, read_ledger, set_ledger,
    using_ledger,
)
from repro.telemetry import trace as rtrace
from repro.telemetry import probes
from repro.telemetry.report import render

SMALL = dict(n_agents=3, batch_m=2, horizon=6, n_rounds=4)

ALL_OFF = TelemetryConfig(snr=False, grad_norms=False, moment_drift=False,
                          dispersion=False)


def _setting():
    return LandmarkNav(), MLPPolicy()


def _rayleigh_ota():
    return OTAConfig(channel=RayleighChannel(), noise_sigma=0.1, debias=True)


def _strip_addresses(jaxpr_text: str) -> str:
    # function-object reprs in jvp_jaxpr_thunk params carry addresses
    return re.sub(r"0x[0-9a-f]+", "0x", jaxpr_text)


# ---------------------------------------------------------------------------
# telemetry off == pre-telemetry program
# ---------------------------------------------------------------------------

def test_history_prefix_compatible():
    r, g, m = jnp.zeros(3), jnp.ones(3), jnp.ones(3)
    h = fedpg.History(r, g, m)  # 3-positional construction still works
    assert h.telemetry is None
    assert len(jax.tree.leaves(h)) == 3  # None is an empty subtree


@pytest.mark.parametrize("uplink", ["exact", "rayleigh"])
def test_all_off_config_is_bitwise_off(uplink):
    env, pol = _setting()
    cfg = fedpg.FedPGConfig(**SMALL)
    ota = None if uplink == "exact" else _rayleigh_ota()
    key = jax.random.key(0)
    j_none = jax.make_jaxpr(
        lambda k: fedpg.run(env, pol, cfg, k, ota=ota))(key)
    j_off = jax.make_jaxpr(
        lambda k: fedpg.run(env, pol, cfg, k, ota=ota, telemetry=ALL_OFF))(key)
    assert _strip_addresses(str(j_none)) == _strip_addresses(str(j_off))


def test_telemetry_on_leaves_metrics_bitwise_identical():
    env, pol = _setting()
    cfg = fedpg.FedPGConfig(**SMALL)
    ota = _rayleigh_ota()
    key = jax.random.key(0)
    h_off = fedpg.run_jit(env, pol, cfg, key, ota=ota)[1]
    h_on = fedpg.run_jit(env, pol, cfg, key, ota=ota,
                         telemetry=TelemetryConfig())[1]
    for name in ("rewards", "grad_sq", "gain_mean"):
        a = np.asarray(getattr(h_off, name))
        b = np.asarray(getattr(h_on, name))
        assert (a == b).all(), name
    assert h_off.telemetry is None
    assert isinstance(h_on.telemetry, RoundTelemetry)
    assert h_on.telemetry.snr.shape == (SMALL["n_rounds"],)


# ---------------------------------------------------------------------------
# probe math vs hand-computed references
# ---------------------------------------------------------------------------

def test_stacked_probes_hand_computed():
    # 2 agents, 1-leaf pytree of shape (2, 2); everything exactly known
    grads = {"w": jnp.array([[3.0, 4.0], [0.0, 12.0]])}  # norms 5, 12
    gains = jnp.array([2.0, 0.5])
    ota = OTAConfig(channel=FixedGainChannel(gain=1.0), noise_sigma=0.5)
    tel = probes.stacked_round_probes(
        TelemetryConfig(), grads_stacked=grads, gains=gains, ota_cfg=ota,
        n_agents=2, gain_mean=jnp.mean(gains), update_norm=jnp.asarray(7.0))
    # sum_i h_i g_i = 2*[3,4] + 0.5*[0,12] = [6, 14]; ||.||^2 = 232
    # snr = 232 / (d=2 * sigma^2=0.25) = 464
    assert np.isclose(float(tel.snr), 464.0, rtol=1e-6)
    assert np.isclose(float(tel.grad_norm_pre), (5.0 + 12.0) / 2, rtol=1e-6)
    assert float(tel.grad_norm_post) == 7.0
    # FixedGain(1.0), no power control: reference is channel.mean = 1.0
    assert np.isclose(float(tel.moment_drift), 1.25 - 1.0, rtol=1e-6)
    assert np.isclose(float(tel.dispersion), 12.0 / 8.5, rtol=1e-6)


def test_stacked_probes_disabled_fields_are_nan():
    grads = {"w": jnp.ones((2, 3))}
    tel = probes.stacked_round_probes(
        TelemetryConfig(snr=False, grad_norms=False, dispersion=False),
        grads_stacked=grads, gains=jnp.ones((2,)), ota_cfg=None, n_agents=2,
        gain_mean=jnp.ones(()), update_norm=jnp.ones(()))
    assert np.isnan(float(tel.snr))
    assert np.isnan(float(tel.grad_norm_pre))
    assert np.isnan(float(tel.dispersion))
    assert np.isfinite(float(tel.moment_drift))


def test_exact_uplink_probes():
    """Noiseless/exact: SNR is inf, moment drift exactly 0."""
    env, pol = _setting()
    cfg = fedpg.FedPGConfig(**SMALL)
    h = fedpg.run_jit(env, pol, cfg, jax.random.key(0),
                      telemetry=TelemetryConfig())[1]
    assert np.isinf(np.asarray(h.telemetry.snr)).all()
    assert (np.asarray(h.telemetry.moment_drift) == 0.0).all()
    assert (np.asarray(h.telemetry.dispersion) >= 1.0).all()


def test_fixed_gain_drift_is_zero():
    """Deterministic channel: realised mean(h) == closed-form m_h, so the
    drift probe must return exactly 0 every round."""
    env, pol = _setting()
    cfg = fedpg.FedPGConfig(**SMALL)
    ota = OTAConfig(channel=FixedGainChannel(gain=0.7), noise_sigma=0.05)
    h = fedpg.run_jit(env, pol, cfg, jax.random.key(0), ota=ota,
                      telemetry=TelemetryConfig())[1]
    np.testing.assert_allclose(np.asarray(h.telemetry.moment_drift), 0.0,
                               atol=1e-6)


def test_sharded_probes_match_vmap():
    """The shard_map probe reductions agree with the stacked form on a
    deterministic channel (the random realisation is shared)."""
    from repro.core import distribute

    env, pol = _setting()
    cfg = fedpg.FedPGConfig(n_agents=4, batch_m=2, horizon=6, n_rounds=3)
    mesh = distribute.agent_mesh_for(cfg.n_agents)
    ota = OTAConfig(channel=FixedGainChannel(gain=0.8), noise_sigma=0.05,
                    debias=True)
    key = jax.random.key(0)
    tc = TelemetryConfig()
    _, h_v = fedpg.run(env, pol, cfg, key, ota=ota, telemetry=tc)
    _, h_s = fedpg.run(env, pol, cfg, key, ota=ota, telemetry=tc,
                       agent_mesh=mesh)
    for f in RoundTelemetry._fields:
        a, b = getattr(h_v.telemetry, f), getattr(h_s.telemetry, f)
        if a is None or b is None:
            # service-only probes: absent on both forms without an
            # active participation config
            assert a is None and b is None, f
            continue
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, err_msg=f)


def test_summarize():
    tel = RoundTelemetry(
        snr=np.array([np.inf, np.inf]),
        grad_norm_pre=np.array([1.0, 3.0]),
        grad_norm_post=np.array([2.0, 2.0]),
        moment_drift=np.array([np.nan, np.nan]),
        dispersion=np.array([1.0, 2.0]))
    s = probes.summarize(tel)
    assert s["snr"] == float("inf")
    assert s["grad_norm_pre"] == 2.0
    assert s["moment_drift"] is None
    assert probes.summarize(None) is None


# ---------------------------------------------------------------------------
# sweep integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sweep_pair():
    env, pol = _setting()
    scens = grid(channel=[RayleighChannel()], noise_sigma=[1e-2, 1e-1],
                 debias=True, **SMALL)
    key = jax.random.key(0)
    off = sweep(env, pol, scens, key, 2)
    on = sweep(env, pol, scens, key, 2, telemetry=TelemetryConfig())
    return off, on


def test_sweep_telemetry_shapes_and_bitwise(sweep_pair):
    off, on = sweep_pair
    assert off.history.telemetry is None
    assert on.history.telemetry.snr.shape == (2, 2, SMALL["n_rounds"])
    assert (np.asarray(on.history.rewards)
            == np.asarray(off.history.rewards)).all()
    assert (np.asarray(on.history.grad_sq)
            == np.asarray(off.history.grad_sq)).all()


def test_sweep_scenario_accessors(sweep_pair):
    off, on = sweep_pair
    assert off.scenario_history(0).telemetry is None
    assert off.telemetry_summary(0) is None
    sh = on.scenario_history(1)
    assert sh.telemetry.snr.shape == (2, SMALL["n_rounds"])
    summ = on.telemetry_summary(1)
    # service-only probes (participation/staleness) are absent on a
    # sweep without an active participation config
    assert set(summ) == {f for f in RoundTelemetry._fields
                         if getattr(sh.telemetry, f) is not None}
    assert {"snr", "grad_norm_pre", "grad_norm_post", "moment_drift",
            "dispersion"} <= set(summ)
    assert summ["snr"] > 0
    row = on.to_dicts()[0]
    assert "telemetry_snr" in row and "telemetry_dispersion" in row
    assert "telemetry_snr" not in off.to_dicts()[0]


def test_sweep_records_partition_spans(sweep_pair):
    names = [s.name for s in rtrace.spans()]
    assert "partition" in names


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def test_span_nesting_and_chrome_export(tmp_path):
    tr = rtrace.Tracer()
    with tr.span("outer", label="a") as outer:
        with tr.span("inner"):
            pass
    assert outer.duration_us > 0
    assert [c.name for c in outer.children] == ["inner"]

    doc = tr.to_chrome_trace()
    text = json.dumps(doc)  # must be valid strict JSON
    back = json.loads(text)
    complete = [e for e in back["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in complete} == {"outer", "inner"}
    for e in complete:
        assert {"name", "cat", "ph", "ts", "dur", "pid", "tid"} <= set(e)

    path = tmp_path / "trace.json"
    tr2 = rtrace.Tracer()
    with tr2.span("solo"):
        pass
    tr2.export(str(path))
    assert json.loads(path.read_text())["traceEvents"]


def test_timed_call_timing():
    t = rtrace.timed_call(lambda: sum(range(100)), warmup=1, iters=3,
                          name="toy")
    assert isinstance(t, rtrace.Timing)
    assert float(t) == t.run_us > 0
    assert t.compile_us is not None
    assert f"{t:.1f}"  # format like the plain float it replaced


def test_time_call_returns_timing():
    from benchmarks.common import time_call

    t = time_call(jax.jit(lambda x: x * 2), jnp.ones(4), iters=2)
    assert isinstance(t, rtrace.Timing)
    assert t.compile_us is not None and t.compile_us > 0


# ---------------------------------------------------------------------------
# ledger + report
# ---------------------------------------------------------------------------

def test_ledger_schema_and_report(tmp_path, sweep_pair):
    _, on = sweep_pair
    path = tmp_path / "LEDGER.jsonl"
    consts = theory.MDPConstants(G=1.0, F=0.5, l_bar=1.0, gamma=0.9)
    with Ledger(str(path)) as led:
        led.log_platform()
        with led.count_compiles(label="noop"):
            pass
        led.log_sweep(on, constants=consts, label="unit")
    events = read_ledger(str(path))
    kinds = [e["kind"] for e in events]
    assert kinds[0] == "ledger_start"
    assert "platform" in kinds and "compiles" in kinds and "sweep" in kinds
    scen = [e for e in events if e["kind"] == "scenario"]
    assert len(scen) == len(on.scenarios)
    for ev in scen:
        assert {"avg_grad_sq", "final_reward", "floor", "floor_which",
                "distance_to_floor", "telemetry"} <= set(ev)
        assert ev["floor_which"] in ("theorem1", "theorem2")
    text = render(events, title="Unit")
    assert "avg_grad_sq vs theory floors" in text
    assert "## Platform" in text


def test_ledger_skips_malformed_lines(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text('{"kind": "ok", "ts": 1}\nnot json\n{"no_kind": 1}\n')
    with pytest.warns(UserWarning):
        events = read_ledger(str(path))
    assert [e["kind"] for e in events] == ["ok"]


def test_ambient_ledger(tmp_path):
    path = tmp_path / "amb.jsonl"
    from repro.telemetry import get_ledger

    assert get_ledger() is None
    with Ledger(str(path)) as led, using_ledger(led):
        assert get_ledger() is led
    assert get_ledger() is None
    set_ledger(None)  # idempotent


def test_floor_report():
    fr = theory.floor_report(n_agents=10, batch_m=10, m_h=1.0, sigma_h2=0.2,
                             noise_sigma2=1e-4, V=2.0)
    assert fr["floor_which"] in ("theorem1", "theorem2")
    assert fr["floor"] in (fr["floor_theorem1"], fr["floor_theorem2"])
    assert fr["floor"] > 0
