"""Estimator correctness: G(PO)MDP must be unbiased for the exact policy
gradient of a tabular MDP (computable by autodiff through the state
distribution), and must have lower variance than REINFORCE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gpomdp
from repro.rl.env import TabularMDP
from repro.rl.policy import TabularSoftmaxPolicy
from repro.rl.sampler import rollout_batch
from repro.utils.tree import tree_global_norm, tree_sub


@pytest.fixture(scope="module")
def setup():
    mdp = TabularMDP.random(jax.random.key(0), n_states=3, n_actions=2,
                            gamma=0.9, horizon=3)
    pol = TabularSoftmaxPolicy(3, 2)
    theta = pol.init(jax.random.key(1))
    return mdp, pol, theta


def exact_grad(mdp, pol, theta):
    return jax.grad(lambda p: mdp.exact_J(pol.action_probs(p)))(theta)


def test_discounted_to_go():
    losses = jnp.array([1.0, 2.0, 4.0])
    got = gpomdp.discounted_to_go(losses, 0.5)
    # w_t = sum_{u>=t} gamma^u l_u (absolute discounting, Eq. 4)
    np.testing.assert_allclose(np.asarray(got), [1 + 1 + 1, 1 + 1, 1], rtol=1e-6)


def test_gpomdp_unbiased(setup):
    mdp, pol, theta = setup
    g_exact = exact_grad(mdp, pol, theta)

    @jax.jit
    def est(k):
        traj = rollout_batch(mdp, pol, theta, k, mdp.horizon, 2048)
        return gpomdp.gpomdp_gradient(pol, theta, traj, mdp.gamma)

    gs = jax.vmap(est)(jax.random.split(jax.random.key(2), 40))
    g_mean = jax.tree.map(lambda x: jnp.mean(x, 0), gs)
    rel = float(
        tree_global_norm(tree_sub(g_mean, g_exact)) / tree_global_norm(g_exact)
    )
    assert rel < 0.08, f"relative bias {rel}"


def test_reinforce_unbiased(setup):
    mdp, pol, theta = setup
    g_exact = exact_grad(mdp, pol, theta)

    @jax.jit
    def est(k):
        traj = rollout_batch(mdp, pol, theta, k, mdp.horizon, 2048)
        return gpomdp.reinforce_gradient(pol, theta, traj, mdp.gamma)

    gs = jax.vmap(est)(jax.random.split(jax.random.key(3), 60))
    g_mean = jax.tree.map(lambda x: jnp.mean(x, 0), gs)
    rel = float(
        tree_global_norm(tree_sub(g_mean, g_exact)) / tree_global_norm(g_exact)
    )
    assert rel < 0.12, f"relative bias {rel}"


def test_gpomdp_lower_variance_than_reinforce(setup):
    """The causality trick strictly reduces estimator variance (the reason
    the paper uses G(PO)MDP, Section II-B)."""
    mdp, pol, theta = setup

    @jax.jit
    def both(k):
        traj = rollout_batch(mdp, pol, theta, k, mdp.horizon, 1)
        g1 = gpomdp.gpomdp_gradient(pol, theta, traj, mdp.gamma)
        g2 = gpomdp.reinforce_gradient(pol, theta, traj, mdp.gamma)
        return g1["theta"], g2["theta"]

    g1s, g2s = jax.vmap(both)(jax.random.split(jax.random.key(4), 4000))
    var1 = float(jnp.sum(jnp.var(g1s, 0)))
    var2 = float(jnp.sum(jnp.var(g2s, 0)))
    assert var1 < var2, (var1, var2)


def test_weights_hook_scales_gradient(setup):
    """Trajectory weights (the OTA gain hook) linearly scale the estimate."""
    mdp, pol, theta = setup
    traj = rollout_batch(mdp, pol, theta, jax.random.key(5), mdp.horizon, 16)
    g1 = gpomdp.gpomdp_gradient(pol, theta, traj, mdp.gamma)
    w = 2.5 * jnp.ones((16,))
    g2 = gpomdp.gpomdp_gradient(pol, theta, traj, mdp.gamma, weights=w)
    np.testing.assert_allclose(
        np.asarray(g2["theta"]), 2.5 * np.asarray(g1["theta"]), rtol=1e-5
    )
