"""The unified ``ota.aggregate`` dispatcher: every spec must reproduce its
legacy entry point bit-for-bit on the xla backend (the golden-trace
contract), the deprecated wrappers must warn, and the pallas backend must
agree with xla wherever the streams coincide (noiseless paths)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ota
from repro.core.channel import FixedGainChannel, IdealChannel, RayleighChannel


def _grads(key, n_agents, shapes=((3, 4), (5,), (2, 2, 2))):
    ks = jax.random.split(key, len(shapes))
    return {
        f"w{i}": jax.random.normal(k, (n_agents,) + s, jnp.float32)
        for i, (k, s) in enumerate(zip(ks, shapes))
    }


def _legacy(name, *args, **kwargs):
    """Call a deprecated wrapper with its warning silenced."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return getattr(ota, name)(*args, **kwargs)


CFGS = [
    ota.OTAConfig(channel=IdealChannel(), noise_sigma=0.0),
    ota.OTAConfig(channel=RayleighChannel(), noise_sigma=0.1, debias=True),
    ota.OTAConfig(channel=FixedGainChannel(gain=2.5), noise_sigma=0.0,
                  debias=True),
    ota.OTAConfig(channel=RayleighChannel(), noise_sigma=0.3,
                  update_scale=0.0421),
]


@pytest.mark.parametrize("cfg", CFGS, ids=["ideal", "rayleigh", "fixed",
                                           "packed_scale"])
def test_dispatcher_stacked_equals_legacy_bitwise(key, cfg):
    g = _grads(key, 6)
    k = jax.random.key(3)
    u1, h1 = ota.aggregate(g, cfg, key=k, backend="xla")
    u2, h2 = _legacy("aggregate_stacked", cfg, k, g)
    for a, b in zip(jax.tree.leaves(u1), jax.tree.leaves(u2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))


def test_dispatcher_exact_equals_legacy_bitwise(key):
    g = _grads(key, 5)
    u1, h = ota.aggregate(g, None)
    u2 = _legacy("exact_aggregate", g)
    for a, b in zip(jax.tree.leaves(u1), jax.tree.leaves(u2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(h) == 1.0


def test_dispatcher_auto_is_xla_on_cpu():
    """Golden-trace safety: off-TPU, auto must resolve to the xla chain."""
    if jax.default_backend() == "tpu":
        pytest.skip("auto resolves to pallas on TPU by design")
    spec = ota.AggregateSpec(form="stacked", exact=False, backend="auto")
    assert spec.resolved_backend() == "xla"


def test_deprecated_wrappers_warn(key):
    g = _grads(key, 3)
    cfg = CFGS[0]
    with pytest.warns(DeprecationWarning):
        ota.aggregate_stacked(cfg, jax.random.key(0), g)
    with pytest.warns(DeprecationWarning):
        ota.exact_aggregate(g)


def test_spec_validation():
    with pytest.raises(ValueError):
        ota.AggregateSpec(form="nope")
    with pytest.raises(ValueError):
        ota.AggregateSpec(backend="cuda")
    with pytest.raises(ValueError):
        # pallas implements the stacked form only
        ota.AggregateSpec(form="axis", backend="pallas").resolved_backend()
    with pytest.raises(ValueError):
        # axis forms need axis names
        ota.aggregate({"w": jnp.ones((2, 3))}, CFGS[1], key=jax.random.key(0),
                      spec=ota.AggregateSpec(form="axis"))
    with pytest.raises(ValueError):
        # noisy aggregation needs a key
        ota.aggregate({"w": jnp.ones((2, 3))}, CFGS[1])


def test_aggregate_apply_xla_equals_two_step(key):
    """aggregate_apply on xla == aggregate + tree-mapped SGD, bitwise (the
    fedpg round loop's historical op order)."""
    g = _grads(key, 4)
    params = jax.tree.map(lambda x: jnp.zeros(x.shape[1:]), g)
    cfg = CFGS[1]
    k = jax.random.key(8)
    u, h1 = ota.aggregate(g, cfg, key=k, backend="xla")
    manual = jax.tree.map(lambda p, x: p - 0.05 * x, params, u)
    applied, h2 = ota.aggregate_apply(g, cfg, params, key=k, alpha=0.05,
                                      backend="xla")
    for a, b in zip(jax.tree.leaves(applied), jax.tree.leaves(manual)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))


def test_pallas_backend_noiseless_matches_xla(key):
    """With sigma=0 the pallas and xla paths compute the same estimator;
    summation order differs (flat matvec vs per-leaf broadcast sum), so
    parity is allclose-at-f32, not bitwise."""
    g = _grads(key, 6)
    cfg = ota.OTAConfig(channel=RayleighChannel(), noise_sigma=0.0,
                        debias=True)
    k = jax.random.key(5)
    up, hp = ota.aggregate(g, cfg, key=k, backend="pallas")
    ux, hx = ota.aggregate(g, cfg, key=k, backend="xla")
    np.testing.assert_array_equal(np.asarray(hp), np.asarray(hx))
    for a, b in zip(jax.tree.leaves(up), jax.tree.leaves(ux)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-6, atol=1e-7)


def test_pallas_backend_noise_statistics(key):
    """The pallas noise stream differs from threefry by design; check the
    statistics instead: zero grads -> u = sigma*n*scale exactly."""
    n_agents, n_params = 4, 20000
    g = {"w": jnp.zeros((n_agents, n_params), jnp.float32)}
    cfg = ota.OTAConfig(channel=IdealChannel(), noise_sigma=0.8)
    u, _ = ota.aggregate(g, cfg, key=jax.random.key(2), backend="pallas")
    flat = np.asarray(u["w"]).ravel()
    assert abs(flat.mean()) < 0.02
    assert abs(flat.std() - 0.8 / n_agents) < 0.01


def test_aggregate_apply_pallas_smoke(key):
    """Fused sgd path end-to-end over a pytree: finite, close to xla."""
    g = _grads(key, 4)
    params = jax.tree.map(lambda x: jnp.ones(x.shape[1:]), g)
    cfg = ota.OTAConfig(channel=FixedGainChannel(gain=1.5), noise_sigma=0.0,
                        debias=True)
    k = jax.random.key(4)
    p_pl, _ = ota.aggregate_apply(g, cfg, params, key=k, alpha=0.1,
                                  backend="pallas")
    p_xla, _ = ota.aggregate_apply(g, cfg, params, key=k, alpha=0.1,
                                   backend="xla")
    for a, b in zip(jax.tree.leaves(p_pl), jax.tree.leaves(p_xla)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-6, atol=1e-6)


def test_add_awgn_backend_equivalence_noiseless(key):
    grad = _grads(key, 1)
    grad = jax.tree.map(lambda x: x[0], grad)  # un-stack: plain grad pytree
    cfg = ota.OTAConfig(channel=FixedGainChannel(gain=2.0), noise_sigma=0.0,
                        debias=True)
    a = ota.add_awgn(cfg, jax.random.key(1), grad, n_agents=4, backend="xla")
    b = ota.add_awgn(cfg, jax.random.key(1), grad, n_agents=4,
                     backend="pallas")
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-6, atol=1e-7)


def test_dispatcher_axis_forms_match_legacy(key):
    """Axis and axis-stacked forms through the dispatcher == the legacy
    psum entry points, bitwise (same ops inside shard_map)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n = jax.local_device_count()
    if n < 2:
        pytest.skip("needs >=2 devices (CI mesh tier runs with 8)")
    mesh = jax.make_mesh((n,), ("data",))
    g = _grads(key, n)
    cfg = ota.OTAConfig(channel=RayleighChannel(), noise_sigma=0.1,
                        debias=True)
    round_key = jax.random.key(5)

    def new(gl):
        return ota.aggregate(gl, cfg, key=round_key, axis=("data",),
                             n_agents=n)[0]

    def old(gl):
        return _legacy("psum_aggregate", cfg, round_key, gl, ("data",),
                       n_agents=n)

    specs = ({k: P("data") for k in g},)
    out_specs = {k: P() for k in g}
    a = shard_map(new, mesh=mesh, in_specs=specs, out_specs=out_specs,
                  check_rep=False)(g)
    b = shard_map(old, mesh=mesh, in_specs=specs, out_specs=out_specs,
                  check_rep=False)(g)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def new_stacked(gl):
        return ota.aggregate(gl, cfg, key=round_key, axis=("data",),
                             n_agents=n, local_stack=True)

    def old_stacked(gl):
        return _legacy("psum_aggregate_stacked", cfg, round_key, gl,
                       ("data",), n_agents=n)

    in_sp = ({k: P("data") for k in g},)
    out_sp = ({k: P() for k in g}, P("data"))
    a2 = shard_map(new_stacked, mesh=mesh, in_specs=in_sp, out_specs=out_sp,
                   check_rep=False)(g)
    b2 = shard_map(old_stacked, mesh=mesh, in_specs=in_sp, out_specs=out_sp,
                   check_rep=False)(g)
    for x, y in zip(jax.tree.leaves(a2), jax.tree.leaves(b2)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_fedpg_round_backend_pallas_smoke():
    """The whole round loop with ota_backend='pallas' runs on CPU (interpret
    mode) and produces finite metrics close to the xla run."""
    from repro.core import fedpg
    from repro.rl.env import LandmarkNav
    from repro.rl.policy import MLPPolicy

    env, pol = LandmarkNav(), MLPPolicy()
    cfg = fedpg.FedPGConfig(n_agents=3, batch_m=2, horizon=5, n_rounds=2,
                            alpha=1e-3)
    ocfg = ota.OTAConfig(channel=FixedGainChannel(gain=1.2),
                         noise_sigma=0.0, debias=True)
    key = jax.random.key(0)
    _, hist_pl = fedpg.run(env, pol, cfg, key, ota=ocfg,
                           ota_backend="pallas")
    _, hist_xla = fedpg.run(env, pol, cfg, key, ota=ocfg, ota_backend="xla")
    assert np.isfinite(np.asarray(hist_pl.rewards)).all()
    np.testing.assert_allclose(np.asarray(hist_pl.rewards),
                               np.asarray(hist_xla.rewards),
                               rtol=1e-4, atol=1e-5)
