"""Substrate layers: optimizers, data pipeline, checkpointing, HLO parser,
param plans, roofline math."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare interpreter: only the property tests skip
    from _hypothesis_stub import given, settings, st

from repro.checkpoint import latest_step, restore, save
from repro.configs.base import InputShape
from repro.data.pipeline import DataConfig, SyntheticLM, make_batch
from repro.optim.optimizers import (
    adam, adamw, apply_updates, clip_by_global_norm, cosine_schedule,
    momentum, sgd, warmup_cosine,
)
from repro.utils import hlo
from repro.utils.roofline import RooflineReport, model_flops_per_step
from repro.utils.tree import tree_bytes, tree_global_norm, tree_size


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quadratic_params():
    return {"a": jnp.array([3.0, -2.0]), "b": jnp.array(5.0)}


def _loss(p):
    return jnp.sum(p["a"] ** 2) + p["b"] ** 2


@pytest.mark.parametrize(
    "opt",
    [sgd(0.1), momentum(0.05, 0.9), adam(0.2), adamw(0.2, weight_decay=0.0)],
    ids=["sgd", "momentum", "adam", "adamw"],
)
def test_optimizers_minimize_quadratic(opt):
    p = _quadratic_params()
    state = opt.init(p)
    for _ in range(200):
        g = jax.grad(_loss)(p)
        upd, state = opt.update(g, state, p)
        p = apply_updates(p, upd)
    assert float(_loss(p)) < 1e-2


def test_adamw_decays_weights():
    opt = adamw(0.1, weight_decay=0.5)
    p = {"w": jnp.ones((4,))}
    state = opt.init(p)
    g = {"w": jnp.zeros((4,))}
    upd, state = opt.update(g, state, p)
    assert float(upd["w"][0]) < 0.0  # pure decay pulls towards zero


def test_clip_by_global_norm():
    g = {"w": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(tree_global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    g2 = {"w": jnp.full((4,), 0.01)}
    same, _ = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(np.asarray(same["w"]), 0.01)


def test_schedules():
    s = warmup_cosine(1.0, warmup=10, total_steps=110)
    assert float(s(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(s(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(s(jnp.asarray(110))) <= 0.2
    c = cosine_schedule(2.0, 100, final_frac=0.5)
    assert float(c(jnp.asarray(0))) == pytest.approx(2.0)
    assert float(c(jnp.asarray(100))) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_shaped():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8, seed=3)
    ds = SyntheticLM(cfg)
    b1, b2 = ds.batch(5), ds.batch(5)
    assert b1["tokens"].shape == (8, 32)
    assert bool(jnp.all(b1["tokens"] == b2["tokens"]))  # pure fn of step
    b3 = ds.batch(6)
    assert not bool(jnp.all(b1["tokens"] == b3["tokens"]))
    # labels are next-token shifted
    assert bool(jnp.all(b1["labels"][:, :-1] == b1["tokens"][:, 1:]))


def test_pipeline_learnable_structure():
    """A bigram table fit on pipeline output beats uniform entropy — the
    data has real structure for the end-to-end training demo."""
    cfg = DataConfig(vocab=64, seq_len=256, global_batch=16, seed=0)
    ds = SyntheticLM(cfg)
    b = ds.batch(0)
    toks = np.asarray(b["tokens"]).ravel()
    nxt = np.asarray(b["labels"]).ravel()
    counts = np.ones((64, 64))
    for a, c in zip(toks, nxt):
        counts[a % 64, c % 64] += 1
    probs = counts / counts.sum(1, keepdims=True)
    nll = -np.log(probs[toks % 64, nxt % 64]).mean()
    assert nll < np.log(64) * 0.95


def test_make_batch_includes_memory_stub():
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("seamless-m4t-large-v2")
    shape = InputShape("t", seq_len=64, global_batch=2, kind="train")
    b = make_batch(cfg, shape, 0)
    assert "memory" in b and b["memory"].shape == (2, 16, cfg.d_model)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "layer": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                  "b": jnp.ones((3,), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }
    d = str(tmp_path / "ckpt")
    save(d, 3, tree)
    save(d, 10, tree)
    assert latest_step(d) == 10
    like = jax.tree.map(jnp.zeros_like, tree)
    got = restore(d, 10, like)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    save(d, 0, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore(d, 0, {"w": jnp.zeros((3, 3))})
    with pytest.raises(ValueError):
        restore(d, 0, {"w2": jnp.zeros((2, 2))})


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------

def test_hlo_parser_on_synthetic_text():
    txt = """
  %ar = f32[8,128]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%add
  %ag = bf16[256,64]{1,0} all-gather(%y), replica_groups=[2,8]<=[16], dimensions={0}
  %done = f32[8]{0} all-reduce-done(%start)
  %cp = f32[4,4]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %notacoll = f32[2,2]{1,0} dot(%a, %b)
"""
    stats = hlo.parse_collective_bytes(txt)
    assert stats.count_by_kind == {
        "all-reduce": 1, "all-gather": 1, "collective-permute": 1,
    }
    # all-reduce: 2*R*(g-1)/g with R=8*128*4, g=16
    assert stats.bytes_by_kind["all-reduce"] == pytest.approx(
        2 * 8 * 128 * 4 * 15 / 16)
    # all-gather: R*(g-1)/g with R=256*64*2, g=8
    assert stats.bytes_by_kind["all-gather"] == pytest.approx(
        256 * 64 * 2 * 7 / 8)
    assert stats.bytes_by_kind["collective-permute"] == 4 * 4 * 4


def test_hlo_parser_on_real_compiled_psum():
    """Parse a genuinely compiled psum program (1 device -> psum folded away;
    checks the parser tolerates real dumps without crashing)."""
    f = jax.jit(lambda x: x * 2)
    txt = f.lower(jnp.ones((4, 4))).compile().as_text()
    stats = hlo.parse_collective_bytes(txt)
    assert stats.total_count == 0


def test_shape_bytes():
    assert hlo.shape_bytes("f32", "2,3") == 24
    assert hlo.shape_bytes("bf16", "128") == 256
    assert hlo.shape_bytes("pred", "") == 1
    assert hlo.shape_bytes("token", "") == 0


# ---------------------------------------------------------------------------
# roofline math
# ---------------------------------------------------------------------------

def test_roofline_dominant_and_mfu():
    r = RooflineReport(
        arch="x", shape="train_4k", mesh="pod16x16", n_chips=256,
        hlo_flops=197e12, hlo_bytes=819e9 * 2.0, collective_bytes=50e9 * 0.5,
        model_flops=98.5e12,
    ).finalize()
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.dominant == "memory"
    assert r.useful_flop_ratio == pytest.approx(0.5)
    assert r.mfu == pytest.approx(98.5e12 / (2.0 * 197e12))


@settings(max_examples=20, deadline=None)
@given(
    n_params=st.integers(10**6, 10**11),
    tokens=st.integers(1, 10**7),
)
def test_model_flops_property(n_params, tokens):
    t = model_flops_per_step(n_params_active=n_params, tokens=tokens,
                             training=True)
    i = model_flops_per_step(n_params_active=n_params, tokens=tokens,
                             training=False)
    assert t == pytest.approx(3 * i)
    assert i == pytest.approx(2.0 * n_params * tokens)


# ---------------------------------------------------------------------------
# param plans / sharding rules
# ---------------------------------------------------------------------------

def test_partition_specs_divisibility_fallback():
    from jax.sharding import PartitionSpec as P
    from repro.models.param import decl, spec_for, train_rules

    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 4, "model": 16}

    d_ok = decl((64, 4096), ("d_model", "d_ff"))
    d_bad = decl((64, 100), ("d_model", "d_ff"))  # 100 % 16 != 0
    r = train_rules()
    assert spec_for(d_ok, r, FakeMesh()) == P("data", "model")
    assert spec_for(d_bad, r, FakeMesh()) == P("data")


def test_tree_utils():
    t = {"a": jnp.zeros((2, 3), jnp.float32), "b": jnp.zeros((4,), jnp.bfloat16)}
    assert tree_size(t) == 10
    assert tree_bytes(t) == 24 + 8
