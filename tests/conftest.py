"""Shared fixtures. NOTE: no hardcoded XLA_FLAGS here — smoke tests and
benches must see 1 device by default; only launch/dryrun.py forces 512 host
devices (in its own process).  Multi-device jobs (CI's mesh tier running
tests/test_distribute.py + the golden suite) opt in per process with
``REPRO_EMULATED_DEVICES=8`` (or the legacy
``XLA_FLAGS=--xla_force_host_platform_device_count=8``), applied below via
``repro.utils.platform`` before jax initialises.

Marker policy: ``slow`` and ``bench`` tests are deselected by default via
``addopts = -m 'not slow and not bench'`` in pyproject.toml (the tier-1
gate).  Run the full suite with ``pytest -m ""``.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.utils import platform as rplat  # noqa: E402  (pre-jax import)

rplat.apply_emulated_devices()

import jax  # noqa: E402
import pytest  # noqa: E402


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="regenerate tests/golden/*.json from the current code instead "
             "of comparing against them (then inspect the diff!)",
    )


# ---------------------------------------------------------------------------
# XLA compilation counting (used by the sweep-engine tests to prove the
# batched path compiles strictly fewer programs than the per-scenario loop).
# The machinery lives in repro.analyze.budget so the static-analysis
# contract checker can machine-enforce the same budgets in CI; the fixture
# below is a thin re-export keeping the historical test API.
# ---------------------------------------------------------------------------

from repro.analyze.budget import (  # noqa: E402
    CompileCounter,
    warm_eager_helpers,
)


@pytest.fixture
def compile_counter():
    """Factory fixture: ``with compile_counter() as c: ...; c.count``.

    Warms the shared eager helpers first (see :func:`warm_eager_helpers`)
    so counts taken inside the context are partition/lane programs only.
    """
    warm_eager_helpers()
    return CompileCounter


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)
