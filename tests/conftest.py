"""Shared fixtures. NOTE: no hardcoded XLA_FLAGS here — smoke tests and
benches must see 1 device by default; only launch/dryrun.py forces 512 host
devices (in its own process).  Multi-device jobs (CI's mesh tier running
tests/test_distribute.py + the golden suite) opt in per process with
``REPRO_EMULATED_DEVICES=8`` (or the legacy
``XLA_FLAGS=--xla_force_host_platform_device_count=8``), applied below via
``repro.utils.platform`` before jax initialises.

Marker policy: ``slow`` and ``bench`` tests are deselected by default via
``addopts = -m 'not slow and not bench'`` in pyproject.toml (the tier-1
gate).  Run the full suite with ``pytest -m ""``.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.utils import platform as rplat  # noqa: E402  (pre-jax import)

rplat.apply_emulated_devices()

import jax  # noqa: E402
import pytest  # noqa: E402


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="regenerate tests/golden/*.json from the current code instead "
             "of comparing against them (then inspect the diff!)",
    )


# ---------------------------------------------------------------------------
# XLA compilation counting (used by the sweep-engine tests to prove the
# batched path compiles strictly fewer programs than the per-scenario loop).
# The listener must be registered once per process; jax.monitoring offers no
# unregister, so the fixture toggles an "active" flag instead.
# ---------------------------------------------------------------------------

_COMPILE_COUNTER = {"active": False, "count": 0}


def _on_event_duration(event: str, *args, **kwargs) -> None:
    if _COMPILE_COUNTER["active"] and event == "/jax/core/compile/backend_compile_duration":
        _COMPILE_COUNTER["count"] += 1


jax.monitoring.register_event_duration_secs_listener(_on_event_duration)


class CompileCounter:
    """Context manager counting XLA backend compilations while active."""

    def __init__(self):
        self.count = 0

    def __enter__(self):
        _COMPILE_COUNTER["count"] = 0
        _COMPILE_COUNTER["active"] = True
        return self

    def __exit__(self, *exc):
        _COMPILE_COUNTER["active"] = False
        self.count = _COMPILE_COUNTER["count"]
        return False


_EAGER_HELPERS_WARMED = False


def warm_eager_helpers() -> None:
    """Compile JAX's eager scaffolding ONCE per process so compile counters
    compare partition programs, not cold-start helpers.

    A sweep's first run also compiles tiny eager dispatches — key splitting,
    float32 packing converts, effective-moment math, ``l_bar_for``, the env
    registry packer, History unstacking slices.  Tests used to hand-warm
    these (each with its own ad-hoc prologue); the ``compile_counter``
    fixture now runs this helper instead, with shapes deliberately distinct
    from any real test so no *partition* program is pre-compiled on the
    tests' behalf.
    """
    global _EAGER_HELPERS_WARMED
    if _EAGER_HELPERS_WARMED:
        return
    from repro.core import fedpg
    from repro.core.channel import RayleighChannel
    from repro.core.power_control import TruncatedInversion, make_controlled_channel
    from repro.core.sweep import grid, sweep
    from repro.rl.envs import WindyLandmarkNav

    tiny = dict(n_agents=2, batch_m=1, horizon=3, n_rounds=2, debias=True)
    chan = make_controlled_channel(RayleighChannel(), TruncatedInversion())
    scens = grid(env=[WindyLandmarkNav(wind=w) for w in (0.0, 0.31, 0.62)],
                 channel=[chan], noise_sigma=1e-3, **tiny)
    key = jax.random.key(99)
    # mc_runs=2 matches the sweep tests' Monte-Carlo width, so the tiny
    # split/convert programs they dispatch are all compiled here
    sweep(None, None, scens, key, 2)
    for s in scens[:1]:
        from repro.core.sweep import resolve_env_policy
        fedpg.monte_carlo(*resolve_env_policy(s), s.fedpg_config(), key, 2,
                          ota=s.ota_config())
    fedpg.clear_compilation_cache()
    _EAGER_HELPERS_WARMED = True


@pytest.fixture
def compile_counter():
    """Factory fixture: ``with compile_counter() as c: ...; c.count``.

    Warms the shared eager helpers first (see :func:`warm_eager_helpers`)
    so counts taken inside the context are partition/lane programs only.
    """
    warm_eager_helpers()
    return CompileCounter


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)
