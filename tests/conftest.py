"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; only launch/dryrun.py forces 512 host devices (in its own
process)."""
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)


def pytest_addoption(parser):
    parser.addoption(
        "--run-slow", action="store_true", default=False,
        help="run slow tests (subprocess dry-runs, long statistics)",
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow"):
        return
    skip = pytest.mark.skip(reason="needs --run-slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
