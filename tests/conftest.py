"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; only launch/dryrun.py forces 512 host devices (in its own
process).

Marker policy: ``slow`` and ``bench`` tests are deselected by default via
``addopts = -m 'not slow and not bench'`` in pyproject.toml (the tier-1
gate).  Run the full suite with ``pytest -m ""``.
"""
import jax
import pytest

# ---------------------------------------------------------------------------
# XLA compilation counting (used by the sweep-engine tests to prove the
# batched path compiles strictly fewer programs than the per-scenario loop).
# The listener must be registered once per process; jax.monitoring offers no
# unregister, so the fixture toggles an "active" flag instead.
# ---------------------------------------------------------------------------

_COMPILE_COUNTER = {"active": False, "count": 0}


def _on_event_duration(event: str, *args, **kwargs) -> None:
    if _COMPILE_COUNTER["active"] and event == "/jax/core/compile/backend_compile_duration":
        _COMPILE_COUNTER["count"] += 1


jax.monitoring.register_event_duration_secs_listener(_on_event_duration)


class CompileCounter:
    """Context manager counting XLA backend compilations while active."""

    def __init__(self):
        self.count = 0

    def __enter__(self):
        _COMPILE_COUNTER["count"] = 0
        _COMPILE_COUNTER["active"] = True
        return self

    def __exit__(self, *exc):
        _COMPILE_COUNTER["active"] = False
        self.count = _COMPILE_COUNTER["count"]
        return False


@pytest.fixture
def compile_counter():
    """Factory fixture: ``with compile_counter() as c: ...; c.count``."""
    return CompileCounter


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)
