"""End-to-end federated PG (Algorithms 1 and 2) on the paper's environment:
training improves reward, OTA over a benign channel tracks the exact
baseline, and Monte Carlo batching works."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import fedpg
from repro.core.channel import make_channel, noise_sigma_from_db
from repro.core.ota import OTAConfig
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy


@pytest.fixture(scope="module")
def env_pol():
    return LandmarkNav(), MLPPolicy()


def test_algorithm1_learns(env_pol):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(n_agents=8, batch_m=8, n_rounds=300, alpha=5e-3,
                            horizon=20)
    _, hist = fedpg.run_jit(env, pol, cfg, jax.random.key(0))
    first = float(jnp.mean(hist.rewards[:20]))
    last = float(jnp.mean(hist.rewards[-20:]))
    assert last > first + 0.5, (first, last)


def test_algorithm2_learns_and_tracks_exact(env_pol):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(n_agents=8, batch_m=8, n_rounds=300, alpha=5e-3,
                            horizon=20)
    ota = OTAConfig(
        channel=make_channel("rayleigh"),
        noise_sigma=noise_sigma_from_db(-60.0),
        debias=True,
    )
    _, h_exact = fedpg.run_jit(env, pol, cfg, jax.random.key(0))
    _, h_ota = fedpg.run_jit(env, pol, cfg, jax.random.key(0), ota=ota)
    # Fig. 3's claim: same order of convergence — final rewards comparable
    exact_final = float(jnp.mean(h_exact.rewards[-30:]))
    ota_final = float(jnp.mean(h_ota.rewards[-30:]))
    assert ota_final > float(jnp.mean(h_ota.rewards[:20])) + 0.3
    assert abs(ota_final - exact_final) < 1.5, (ota_final, exact_final)


def test_more_agents_reduce_grad_variance(env_pol):
    """Fig. 2 mechanism: the aggregated-gradient norm estimate decreases in N
    at a fixed (early) policy."""
    env, pol = env_pol
    outs = {}
    for n in (2, 16):
        cfg = fedpg.FedPGConfig(n_agents=n, batch_m=4, n_rounds=30,
                                alpha=1e-4, horizon=20)
        ota = OTAConfig(channel=make_channel("rayleigh"),
                        noise_sigma=noise_sigma_from_db(-60.0), debias=True)
        _, hist = fedpg.run_jit(env, pol, cfg, jax.random.key(1), ota=ota)
        outs[n] = float(jnp.mean(hist.grad_sq))
    # ||mean of N estimates||^2 ~ ||grad||^2 + var/N — decreasing in N
    assert outs[16] < outs[2], outs


def test_monte_carlo_vmaps(env_pol):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(n_agents=2, batch_m=2, n_rounds=5, alpha=1e-4)
    hist = fedpg.monte_carlo(env, pol, cfg, jax.random.key(0), n_runs=3)
    assert hist.rewards.shape == (3, 5)
    assert bool(jnp.all(jnp.isfinite(hist.rewards)))


def test_run_jit_and_monte_carlo_reuse_compiled(env_pol, compile_counter):
    """Repeated run_jit/monte_carlo calls with identical (env, policy, cfg,
    ota, n_runs) must reuse the compiled program instead of re-tracing a
    fresh jit closure every call."""
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(n_agents=2, batch_m=2, n_rounds=3, horizon=4)
    ota = OTAConfig(channel=make_channel("rayleigh"), noise_sigma=1e-3,
                    debias=True)
    keys = [jax.random.key(i) for i in range(4)]  # warm eager key helpers
    fedpg.clear_compilation_cache()

    with compile_counter() as c1:
        fedpg.monte_carlo(env, pol, cfg, keys[0], 2, ota=ota)
    with compile_counter() as c2:
        fedpg.monte_carlo(env, pol, cfg, keys[1], 2, ota=ota)
    assert c1.count >= 1 and c2.count == 0, (c1.count, c2.count)

    with compile_counter() as c3:
        fedpg.run_jit(env, pol, cfg, keys[2], ota=ota)
    with compile_counter() as c4:
        fedpg.run_jit(env, pol, cfg, keys[3], ota=ota)
    assert c3.count >= 1 and c4.count == 0, (c3.count, c4.count)

    # a different n_runs is a different program, not a stale cache hit
    hist = fedpg.monte_carlo(env, pol, cfg, keys[0], 3, ota=ota)
    assert hist.rewards.shape == (3, 3)


def test_gain_mean_reflects_channel(env_pol):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(n_agents=16, batch_m=1, n_rounds=20, alpha=0.0)
    ota = OTAConfig(channel=make_channel("rayleigh"), noise_sigma=0.0)
    _, hist = fedpg.run_jit(env, pol, cfg, jax.random.key(0), ota=ota)
    m_h = make_channel("rayleigh").mean
    assert float(jnp.mean(hist.gain_mean)) == pytest.approx(m_h, rel=0.1)
