"""Power control: effective-gain moments (closed form vs Monte Carlo), the
ControlledChannel registry contract, the NaN-moment guard rails, and
cross-form equivalence of the three OTA aggregation implementations under
``power_control`` + ``update_scale`` simultaneously."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ota
from repro.core.channel import (
    BatchedChannel, LogNormalChannel, NakagamiChannel, RayleighChannel,
    batched_channel_arrays, channel_kind, make_channel,
)
from repro.core.power_control import (
    ConstantReceived, ControlledChannel, FullInversion, HeterogeneousBudget,
    TruncatedInversion, UnitPower, closed_form_moments, estimate_moments,
    make_controlled_channel,
)

N_MC = 400_000


# ---------------------------------------------------------------------------
# Closed-form moments vs Monte Carlo.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,n_agents", [
    (TruncatedInversion(), None),
    (TruncatedInversion(target=2.0, p_max=3.0, c_min=0.2), None),
    (TruncatedInversion(target=1.0, p_max=1.5, c_min=0.9), None),  # c_min > t
    (FullInversion(), None),
    (FullInversion(target=0.8, p_max=2.0), None),
    (ConstantReceived(target=1.3), None),
    (HeterogeneousBudget(p_min=0.5, p_max=1.5), 8),
    (UnitPower(), None),
])
def test_closed_form_matches_monte_carlo(policy, n_agents):
    base = RayleighChannel()
    closed = closed_form_moments(base, policy, n_agents=n_agents)
    assert closed is not None
    m, v = closed
    m_mc, v_mc = estimate_moments(base, policy, jax.random.key(1), N_MC,
                                  n_agents=n_agents)
    assert m == pytest.approx(m_mc, rel=0.01, abs=1e-3)
    assert v == pytest.approx(v_mc, rel=0.05, abs=1e-3)


def test_truncated_inversion_rayleigh_incomplete_gamma_terms():
    """Spot-check the incomplete-gamma expressions against a hand-computed
    pure-outage case: p_max -> huge makes h = target above c_min, so
    m = target * exp(-c_min^2/2) and E[h^2] = target^2 * exp(-c_min^2/2)."""
    target, c_min = 1.5, 0.3
    pol = TruncatedInversion(target=target, p_max=1e9, c_min=c_min)
    m, v = closed_form_moments(RayleighChannel(), pol)
    surv = math.exp(-c_min**2 / 2.0)
    assert m == pytest.approx(target * surv, rel=1e-9)
    assert v == pytest.approx(target**2 * surv - (target * surv) ** 2, rel=1e-9)


def test_closed_form_none_for_unknown_base():
    assert closed_form_moments(NakagamiChannel(), TruncatedInversion()) is None
    assert closed_form_moments(LogNormalChannel(), FullInversion()) is None
    # ConstantReceived / UnitPower / HeterogeneousBudget work over any base
    assert closed_form_moments(NakagamiChannel(), ConstantReceived()) == (1.0, 0.0)
    m, v = closed_form_moments(NakagamiChannel(m=0.5, omega=1.0),
                               HeterogeneousBudget(), n_agents=4)
    assert math.isfinite(m) and math.isfinite(v)


def test_constant_received_kills_variance():
    ch = make_controlled_channel(RayleighChannel(), ConstantReceived(target=1.0))
    assert ch.mean == pytest.approx(1.0) and ch.var == 0.0
    h = ch.sample(jax.random.key(0), (1000,))
    np.testing.assert_allclose(np.asarray(h), 1.0, rtol=1e-5)


def test_heterogeneous_budget_needs_n_agents():
    with pytest.raises(ValueError, match="n_agents"):
        closed_form_moments(RayleighChannel(), HeterogeneousBudget())
    with pytest.raises(ValueError, match="n_agents"):
        estimate_moments(RayleighChannel(), HeterogeneousBudget(),
                         jax.random.key(0), 100)


def test_heterogeneous_budget_indexed_matches_vector():
    pol = HeterogeneousBudget(p_min=0.5, p_max=1.5)
    c = jax.random.uniform(jax.random.key(0), (6,)) + 0.5
    vec = pol.apply(c)
    per = jnp.stack([
        pol.apply_indexed(c[i], jnp.asarray(i, jnp.int32), 6) for i in range(6)
    ])
    np.testing.assert_allclose(np.asarray(vec), np.asarray(per), rtol=1e-6)


# ---------------------------------------------------------------------------
# ControlledChannel: registry + constructor + moment guard rails.
# ---------------------------------------------------------------------------

def test_controlled_channel_is_registered():
    ch = make_controlled_channel(RayleighChannel(), TruncatedInversion())
    assert channel_kind(ch) == "controlled:rayleigh:TruncatedInversion"
    via_factory = make_channel("controlled", base=RayleighChannel(),
                               policy=UnitPower(), _mean=1.0, _var=0.5)
    assert channel_kind(via_factory) == "controlled:rayleigh:UnitPower"


def test_controlled_channel_requires_base():
    with pytest.raises(ValueError, match="make_controlled_channel"):
        ControlledChannel(policy=UnitPower())


def test_make_controlled_channel_fills_moments():
    # closed form: no key needed
    ch = make_controlled_channel(RayleighChannel(), FullInversion())
    assert math.isfinite(ch.mean) and math.isfinite(ch.var)
    # MC fallback for a base with no closed form
    ch2 = make_controlled_channel(NakagamiChannel(m=0.5, omega=1.0),
                                  TruncatedInversion(), jax.random.key(3),
                                  n=50_000)
    m_mc, v_mc = estimate_moments(NakagamiChannel(m=0.5, omega=1.0),
                                  TruncatedInversion(), jax.random.key(3),
                                  50_000)
    assert ch2.mean == m_mc and ch2.var == v_mc


def test_nan_moments_rejected_everywhere():
    bare = ControlledChannel(base=RayleighChannel(), policy=TruncatedInversion())
    # OTAConfig build time, with debias
    with pytest.raises(ValueError, match="make_controlled_channel"):
        ota.OTAConfig(channel=bare, debias=True)
    # batched packing
    with pytest.raises(ValueError, match="non-finite"):
        batched_channel_arrays([bare, bare])
    # debias=False never divides by m_h, so the un-estimated channel is fine
    cfg = ota.OTAConfig(channel=bare, debias=False)
    assert cfg.norm_const == 1.0
    # ... and an explicit update_scale bypasses norm_const entirely
    cfg2 = ota.OTAConfig(channel=bare, debias=True, update_scale=0.1)
    u, _ = ota.aggregate_stacked(
        cfg2, jax.random.key(0),
        {"w": jnp.ones((4, 3), jnp.float32)})
    assert bool(jnp.all(jnp.isfinite(u["w"])))


def test_batched_controlled_channel_bitwise():
    """Lane-sliced batched draws == concrete ControlledChannel draws."""
    chans = [
        make_controlled_channel(RayleighChannel(scale=sc),
                                TruncatedInversion(target=t))
        for sc, t in ((1.0, 1.0), (0.5, 2.0))
    ]
    kind, arrays = batched_channel_arrays(chans)
    assert kind == "controlled:rayleigh:TruncatedInversion"
    params = {k: jnp.asarray(v, jnp.float32) for k, v in arrays.items()}
    key = jax.random.key(7)

    def lane(p):
        return BatchedChannel(kind=kind, params=p).sample(key, (16,))

    batched = jax.jit(lambda pk: jax.lax.map(lane, pk))(params)
    for i, ch in enumerate(chans):
        ref = jax.jit(lambda c=ch: c.sample(key, (16,)))()
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(batched[i]))
        np.testing.assert_allclose(float(params["_mean"][i]), ch.mean, rtol=1e-7)
        np.testing.assert_allclose(float(params["_var"][i]), ch.var, rtol=1e-7)


def test_mixed_policy_types_do_not_batch():
    a = make_controlled_channel(RayleighChannel(), TruncatedInversion())
    b = make_controlled_channel(RayleighChannel(), FullInversion())
    with pytest.raises(ValueError, match="cannot batch"):
        batched_channel_arrays([a, b])


# ---------------------------------------------------------------------------
# Cross-form equivalence under power_control + update_scale simultaneously.
# ---------------------------------------------------------------------------

def _grads(key, n_agents, shapes=((3, 4), (5,))):
    ks = jax.random.split(key, len(shapes))
    return {
        f"w{i}": jax.random.normal(k, (n_agents,) + s, jnp.float32)
        for i, (k, s) in enumerate(zip(ks, shapes))
    }


@pytest.mark.parametrize("policy", [
    TruncatedInversion(target=1.0, p_max=5.0, c_min=0.1),
    FullInversion(target=1.2, p_max=4.0),
    HeterogeneousBudget(p_min=0.5, p_max=1.5),
])
def test_stacked_equals_weighted_loss_form(policy):
    """Form 1 (aggregate_stacked) == Form 3 (weighted grad + add_awgn) with
    power_control and update_scale set at the same time."""
    n_agents = 4
    cfg = ota.OTAConfig(channel=RayleighChannel(), noise_sigma=0.05,
                        power_control=policy, update_scale=0.21)
    g = _grads(jax.random.key(2), n_agents)
    round_key = jax.random.key(5)
    u1, h = ota.aggregate_stacked(cfg, round_key, g)

    # weighted-loss form: its input already carries (1/N) sum h_i g_i, and
    # add_awgn uses the same noise key aggregate_stacked derived internally
    key_h, key_n = jax.random.split(round_key)
    np.testing.assert_array_equal(
        np.asarray(h), np.asarray(ota.sample_gains(cfg, key_h, n_agents)))
    weighted = jax.tree.map(
        lambda x: jnp.tensordot(h, x, axes=1) / n_agents, g)
    u3 = ota.add_awgn(cfg, key_n, weighted, n_agents=n_agents)
    for a, b in zip(jax.tree.leaves(u1), jax.tree.leaves(u3)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-7)


@pytest.mark.parametrize("policy", [
    TruncatedInversion(target=1.0, p_max=5.0, c_min=0.1),
    HeterogeneousBudget(p_min=0.5, p_max=1.5),
])
def test_psum_equals_stacked_under_power_control(policy):
    """Form 2 (shard_map psum) == Form 1 given the same gains, with
    power_control and update_scale set at the same time."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P  # noqa: F401

    n = jax.local_device_count()
    if n < 2:
        pytest.skip("needs >=2 devices (run via tests/test_dryrun_subprocess)")
    mesh = jax.make_mesh((n,), ("data",))
    g = _grads(jax.random.key(8), n)
    cfg = ota.OTAConfig(channel=RayleighChannel(), noise_sigma=0.1,
                        power_control=policy, update_scale=0.17)
    round_key = jax.random.key(9)

    def local(gl):
        return ota.psum_aggregate(cfg, round_key, gl, ("data",))

    out = shard_map(
        local, mesh=mesh, in_specs=({k: P("data") for k in g},),
        out_specs={k: P() for k in g}, check_rep=False,
    )(g)

    key_h, _ = jax.random.split(round_key)
    cs = jnp.stack([
        cfg.channel.sample(jax.random.fold_in(key_h, i), ()) for i in range(n)
    ])
    gains = cs * jax.vmap(
        lambda c, i: policy.apply_indexed(c, i, n)
    )(cs, jnp.arange(n, dtype=jnp.int32))
    ref, _ = ota.aggregate_stacked(cfg, round_key, g, gains=gains)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)


def test_debias_uses_effective_mean_without_update_scale():
    """A directly-built OTAConfig(debias=True, power_control=...) divides by
    the *effective* mean E[c p(c)] — same normaliser Scenario folds into
    update_scale — not the raw channel mean."""
    from repro.core.power_control import effective_moments

    pol = TruncatedInversion()
    cfg = ota.OTAConfig(channel=RayleighChannel(), power_control=pol,
                        debias=True)
    n_agents = 4
    m_eff, _ = effective_moments(RayleighChannel(), pol)
    assert cfg.norm_const_for(n_agents) == pytest.approx(m_eff)
    assert cfg.norm_const_for(n_agents) != pytest.approx(RayleighChannel().mean)

    g = _grads(jax.random.key(0), n_agents)
    key = jax.random.key(1)
    u, _ = ota.aggregate_stacked(cfg, key, g)
    explicit = ota.OTAConfig(channel=RayleighChannel(), power_control=pol,
                             debias=True,
                             update_scale=1.0 / (n_agents * m_eff))
    u_ref, _ = ota.aggregate_stacked(explicit, key, g)
    for a, b in zip(jax.tree.leaves(u), jax.tree.leaves(u_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    # per-agent policies get their n_agents from the call site
    cfg_het = ota.OTAConfig(channel=RayleighChannel(), debias=True,
                            power_control=HeterogeneousBudget())
    assert cfg_het.norm_const_for(n_agents) == pytest.approx(
        effective_moments(RayleighChannel(), HeterogeneousBudget(),
                          n_agents=n_agents)[0])


def test_agent_count_mismatch_rejected():
    """Per-agent mixture moments baked for one N cannot silently run at
    another N."""
    from repro.core.power_control import check_agent_count
    from repro.core.sweep import Scenario

    ch = make_controlled_channel(RayleighChannel(), HeterogeneousBudget(),
                                 n_agents=8)
    check_agent_count(ch, 8)  # matching count passes
    with pytest.raises(ValueError, match="n_agents"):
        check_agent_count(ch, 4)
    with pytest.raises(ValueError, match="baked for n_agents=8"):
        Scenario(channel=ch, n_agents=4).ota_config()
    # the direct sampling path is guarded too, not just the Scenario layer
    with pytest.raises(ValueError, match="baked for n_agents=8"):
        ch.sample(jax.random.key(0), (4,))
    _ = ch.sample(jax.random.key(0), (8,))  # matching axis samples fine
    # non-per-agent channels are unconstrained
    check_agent_count(make_controlled_channel(RayleighChannel(),
                                              TruncatedInversion()), 3)


def test_per_agent_policy_rejects_scalar_sample():
    """ControlledChannel over a per-agent policy cannot be sampled without
    an agent axis (the shard_map path must use OTAConfig.power_control)."""
    ch = make_controlled_channel(RayleighChannel(), HeterogeneousBudget(),
                                 n_agents=4)
    with pytest.raises(ValueError, match="agent axis"):
        ch.sample(jax.random.key(0), ())


def test_sample_gains_per_agent_policy_uses_agent_axis():
    cfg = ota.OTAConfig(channel=RayleighChannel(),
                        power_control=HeterogeneousBudget(p_min=0.0, p_max=2.0))
    key = jax.random.key(0)
    h = ota.sample_gains(cfg, key, 5)
    c = RayleighChannel().sample(key, (5,))
    budgets = jnp.linspace(0.0, 2.0, 5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(c * budgets),
                               rtol=1e-6)
