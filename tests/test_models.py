"""Per-arch smoke tests (assignment requirement) + structural equalities:
decode == full forward, SSD chunked == sequential step, SWA ring cache."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import model as model_lib
from repro.models import transformer as T
from repro.models import attention as A
from repro.models import ssm as S
from repro.models.param import init_params


def _mem(cfg, key, b, s):
    if not model_lib.needs_memory(cfg):
        return None
    ml = T.cross_len(cfg, s)
    return jax.random.normal(key, (b, ml, cfg.d_model), jnp.float32)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, key):
    """Reduced same-family config: one forward + one train step on CPU,
    asserting output shapes and finiteness (assignment smoke contract)."""
    from repro.train import trainer
    from repro.configs.base import InputShape
    from repro.data.pipeline import make_batch

    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    m = model_lib.build(cfg)
    params = m.init(key)
    b, s = 2, 64
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    logits, aux = m.forward(params, tokens, _mem(cfg, key, b, s))
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    shape = InputShape("smoke", seq_len=32, global_batch=4, kind="train")
    batch = make_batch(cfg, shape, 0)
    tcfg = trainer.TrainConfig(n_agents=2, microbatch=2, total_steps=4)
    state = trainer.init_state(m, tcfg, key)
    step = jax.jit(trainer.make_train_step(m, tcfg))
    state, metrics = step(state, batch, jax.random.key(9))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(state.step) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch, key):
    """Token-by-token decode reproduces the full-sequence logits (MoE archs
    with a no-drop capacity factor, since batched dispatch drops overflow)."""
    cfg = get_smoke_config(arch)
    if cfg.moe:
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    m = model_lib.build(cfg)
    params = m.init(key)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.key(2), (b, s), 0, cfg.vocab)
    mem = _mem(cfg, key, b, s)
    full, _ = m.forward(params, tokens, mem)

    cache = m.init_cache(b, s, mem_len=(mem.shape[1] if mem is not None else 0))
    if mem is not None:
        memdt = mem.astype(jnp.dtype(cfg.dtype))
        if cfg.family == "encdec":
            enc = T.encode(params, cfg, mem)
            ckv = jax.vmap(lambda lp: A.project_memory(lp["cross"], enc))(
                params["layers"])
        else:
            ckv = jax.vmap(lambda cl: A.project_memory(cl["cross"], memdt))(
                params["cross_layers"])
        cache = cache._replace(cross_kv=ckv)

    dec = jax.jit(lambda c, t: m.decode(params, c, t))
    outs = []
    for t in range(s):
        lg, cache = dec(cache, tokens[:, t:t + 1])
        outs.append(lg[:, 0])
    got = jnp.stack(outs, 1)
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    err = float(jnp.max(jnp.abs(got - full))) / scale
    assert err < 2e-2, err


def test_prefill_then_decode_continues(key):
    """prefill(s tokens) + decode(s+1th) == forward over s+1 tokens."""
    cfg = get_smoke_config("internlm2-20b")
    m = model_lib.build(cfg)
    params = m.init(key)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.key(3), (b, s + 1), 0, cfg.vocab)
    full, _ = m.forward(params, tokens)
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    last_logits, cache = m.prefill(params, tokens[:, :s])
    assert float(jnp.max(jnp.abs(last_logits[:, 0] - full[:, s - 1]))) / scale < 2e-2
    # continue decoding: copy the s-slot prefill KV into a larger buffer
    # (capacity must exceed the prompt, else the ring wraps — production
    # serving allocates prompt+generation slots, cf. examples/serve_smoke.py)
    big = m.init_cache(b, s + 8)
    big = big._replace(
        kv=jax.tree.map(
            lambda dst, src: jax.lax.dynamic_update_slice(
                dst, src, (0,) * dst.ndim),
            big.kv, cache.kv,
        ),
        pos=cache.pos,
    )
    lg, _ = m.decode(params, big, tokens[:, s:s + 1])
    assert float(jnp.max(jnp.abs(lg[:, 0] - full[:, s]))) / scale < 2e-2


def test_swa_ring_cache_matches_windowed_forward(key):
    """Ring-buffered decode with capacity == window reproduces full-cache
    windowed attention — the sub-quadratic long_500k serving path."""
    cfg = get_smoke_config("mixtral-8x22b")  # window=64 in smoke cfg
    cfg = cfg.with_(window=8, serve_window=8,
                    moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    m = model_lib.build(cfg)
    params = m.init(key)
    b, s = 1, 24
    tokens = jax.random.randint(jax.random.key(4), (b, s), 0, cfg.vocab)
    full, _ = m.forward(params, tokens)  # windowed attention (window=8)

    ring = m.init_cache(b, 8)            # ring capacity == window
    outs = []
    for t in range(s):
        lg, ring = m.decode(params, ring, tokens[:, t:t + 1], window=8)
        outs.append(lg[:, 0])
    got = jnp.stack(outs, 1)
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert float(jnp.max(jnp.abs(got - full))) / scale < 2e-2


def test_ssd_chunked_equals_recurrent_step(key):
    """models/ssm.py: ssd_ref (chunked, train path) == ssm_step rollout
    (decode path) through a full mixer layer."""
    cfg = get_smoke_config("mamba2-130m")
    plan = S.ssm_plan(cfg)
    params = init_params(key, plan)
    b, s = 2, 64
    x = 0.5 * jax.random.normal(jax.random.key(5), (b, s, cfg.d_model),
                                jnp.float32)
    full = S.ssm_mixer(params, x, cfg)
    state = S.init_state(cfg, b, jnp.float32)
    outs = []
    for t in range(s):
        y, state = S.ssm_step(params, x[:, t:t + 1], state, cfg)
        outs.append(y[:, 0])
    got = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_param_counts_match_actual(key):
    """cfg.param_counts() total must match the real parameter tree within 2%
    (it feeds the 6ND roofline term)."""
    for arch in ("llama3.2-3b", "mixtral-8x22b", "mamba2-130m", "zamba2-7b"):
        cfg = get_config(arch)
        m = model_lib.build(cfg)
        abstract = m.abstract()
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abstract))
        declared, active = cfg.param_counts()
        assert abs(actual - declared) / actual < 0.02, (arch, actual, declared)
        if cfg.family != "hybrid":
            # hybrid re-applies the shared attn block, so per-token active
            # params legitimately exceed stored params
            assert active <= declared or cfg.tie_embeddings


def test_full_configs_match_assignment():
    """The exact assigned hyperparameters."""
    c = get_config("deepseek-67b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (95, 8192, 64, 8, 22016, 102400)
    c = get_config("mixtral-8x22b")
    assert c.moe.num_experts == 8 and c.moe.top_k == 2 and c.window == 4096
    c = get_config("granite-moe-1b-a400m")
    assert c.moe.num_experts == 32 and c.moe.top_k == 8
    c = get_config("mamba2-130m")
    assert c.ssm.state == 128 and c.n_heads == 0
    c = get_config("zamba2-7b")
    assert c.ssm.state == 64 and c.n_layers == 81
    c = get_config("seamless-m4t-large-v2")
    assert c.vocab == 256206 and c.family == "encdec"
    c = get_config("llama-3.2-vision-11b")
    assert c.cross_attn_every == 5 and c.n_layers == 40
    c = get_config("starcoder2-15b")
    assert c.n_kv_heads == 4 and c.d_ff == 24576
    c = get_config("internlm2-20b")
    assert c.n_layers == 48 and c.vocab == 92544
    c = get_config("llama3.2-3b")
    assert c.n_layers == 28 and c.d_model == 3072
