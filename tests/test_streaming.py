"""Blocked-scan streaming (``agent_blocks``) equivalence suite.

The contract under test (see ``fedpg.make_round_fn`` / ``ota.aggregate``):

* **Block invariance** — for any finite block size the streamed round is a
  strict sequential left-fold over absolute agent indices, so the full
  training history (rewards, grad_sq, gain_mean, telemetry, final theta)
  is **bitwise identical** across every ``agent_blocks`` choice — on the
  vmap form, the shard_map form (phantom-padded, non-dividing fleets
  included), and the pallas uplink backend.
* **vs. the stacked form** — the PRNG streams are identical
  (``gain_mean`` compares bitwise); rewards/updates differ only at the
  floating-point reassociation level (XLA fuses the blocked rollouts and
  the cross-agent sum differently), pinned here at tight tolerance.
* **Absolute indexing** — per-agent state (``HeterogeneousEnv`` lane
  parameters, ``HeterogeneousBudget`` power budgets) follows the agent's
  absolute index, not its position inside a block.
* **Cache keys** — every program-shaping argument of ``fedpg.run`` keys
  the compiled-callable caches; flipping one compiles a distinct program
  instead of silently reusing a stale one.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare interpreter: only the property tests skip
    from _hypothesis_stub import given, settings, st

from repro.core import event_triggered, fedpg, ota
from repro.core.channel import FixedGainChannel, RayleighChannel
from repro.core.ota import OTAConfig
from repro.core.power_control import HeterogeneousBudget
from repro.launch.mesh import make_agent_mesh
from repro.rl.env import LandmarkNav
from repro.rl.policy import MLPPolicy
from repro.rl.envs import WindyLandmarkNav, make_heterogeneous_env
from repro.telemetry.probes import TelemetryConfig

N_DEV = jax.device_count()
SMALL = dict(n_agents=7, batch_m=2, horizon=5, n_rounds=3)
RAYLEIGH = OTAConfig(channel=RayleighChannel(), noise_sigma=1e-3, debias=True)

# distinct blocked layouts (1, 2, 3, the ceil(N/2)=4 cap) plus an
# over-asking block size that must hit the same capped layout as 4
BLOCK_GRID = (1, 2, 3, 4, 100)


@pytest.fixture(scope="module")
def env_pol():
    return LandmarkNav(), MLPPolicy()


def _bitwise(a, b, what=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), what
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


def _close(a, b, what="", rtol=1e-5):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), what
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=1e-7, err_msg=what)


# ---------------------------------------------------------------------------
# block invariance + vs-stacked, vmap form
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("uplink", ["exact", "rayleigh"])
def test_block_invariance_vmap(env_pol, uplink, key):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(**SMALL)
    ocfg = None if uplink == "exact" else RAYLEIGH
    tel = TelemetryConfig() if uplink == "rayleigh" else None

    ref = fedpg.run_jit(env, pol, cfg, key, ota=ocfg, telemetry=tel,
                        agent_blocks=BLOCK_GRID[0])
    for b in BLOCK_GRID[1:]:
        got = fedpg.run_jit(env, pol, cfg, key, ota=ocfg, telemetry=tel,
                            agent_blocks=b)
        _bitwise(got, ref, f"agent_blocks={b} vs {BLOCK_GRID[0]}")


@pytest.mark.parametrize("uplink", ["exact", "rayleigh"])
def test_streamed_vs_stacked(env_pol, uplink, key):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(**SMALL)
    ocfg = None if uplink == "exact" else RAYLEIGH

    theta_n, hist_n = fedpg.run_jit(env, pol, cfg, key, ota=ocfg)
    theta_b, hist_b = fedpg.run_jit(env, pol, cfg, key, ota=ocfg,
                                    agent_blocks=3)
    # identical PRNG streams: the gain draw compares bitwise
    np.testing.assert_array_equal(np.asarray(hist_b.gain_mean),
                                  np.asarray(hist_n.gain_mean))
    # the rest differs only by the documented cross-agent reassociation
    _close(hist_b, hist_n, "history streamed-vs-stacked")
    _close(theta_b, theta_n, "theta streamed-vs-stacked")


def test_pallas_backend_block_invariance(env_pol, key):
    env, pol = env_pol
    # interpret mode on CPU: keep the program tiny
    cfg = fedpg.FedPGConfig(n_agents=5, batch_m=1, horizon=4, n_rounds=2)
    ref = fedpg.run_jit(env, pol, cfg, key, ota=RAYLEIGH,
                        ota_backend="pallas", agent_blocks=1)
    for b in (2, 5):
        got = fedpg.run_jit(env, pol, cfg, key, ota=RAYLEIGH,
                            ota_backend="pallas", agent_blocks=b)
        _bitwise(got, ref, f"pallas agent_blocks={b} vs 1")


# ---------------------------------------------------------------------------
# the sharded (shard_map) form: padding + block invariance
# ---------------------------------------------------------------------------

@pytest.mark.skipif(N_DEV < 2, reason="needs >= 2 devices "
                    "(REPRO_EMULATED_DEVICES=8)")
def test_nondivisible_fleet_needs_blocks(env_pol, key):
    env, pol = env_pol
    mesh = make_agent_mesh(2)
    cfg = fedpg.FedPGConfig(n_agents=5, batch_m=1, horizon=4, n_rounds=2)
    with pytest.raises(ValueError, match="agent_blocks"):
        fedpg.run(env, pol, cfg, key, ota=RAYLEIGH, agent_mesh=mesh)
    # with agent_blocks the same fleet runs on a masked phantom-agent tail
    theta, hist = fedpg.run_jit(env, pol, cfg, key, ota=RAYLEIGH,
                                agent_mesh=mesh, agent_blocks=2)
    assert np.isfinite(np.asarray(hist.rewards)).all()
    assert np.isfinite(np.asarray(hist.gain_mean)).all()


@pytest.mark.skipif(N_DEV < 4, reason="needs >= 4 devices "
                    "(REPRO_EMULATED_DEVICES=8)")
def test_padded_sharded_pins_unsharded(env_pol, key):
    # the ISSUE's pin: N=10 on 4 shards (phantom-padded to 12) vs the
    # unsharded stacked run.  FixedGain makes the channel draw trivially
    # identical across the two gain-derivation schemes (batched split vs
    # absolute-index fold_in); the AWGN key is shared, so gain_mean is
    # bitwise and the d-dimensional metrics sit at psum-reassociation
    # tolerance.
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(n_agents=10, batch_m=2, horizon=5, n_rounds=3)
    ocfg = OTAConfig(channel=FixedGainChannel(gain=1.5), noise_sigma=1e-3,
                     debias=True)
    mesh = make_agent_mesh(4)
    theta_s, hist_s = fedpg.run_jit(env, pol, cfg, key, ota=ocfg,
                                    agent_mesh=mesh, agent_blocks=2)
    theta_v, hist_v = fedpg.run_jit(env, pol, cfg, key, ota=ocfg)
    np.testing.assert_array_equal(np.asarray(hist_s.gain_mean),
                                  np.asarray(hist_v.gain_mean))
    _close(hist_s, hist_v, "padded sharded vs unsharded history")
    _close(theta_s, theta_v, "padded sharded vs unsharded theta")


@pytest.mark.skipif(N_DEV < 4, reason="needs >= 4 devices "
                    "(REPRO_EMULATED_DEVICES=8)")
def test_sharded_block_invariance_padded(env_pol, key):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(n_agents=10, batch_m=2, horizon=5, n_rounds=3)
    mesh = make_agent_mesh(4)
    tel = TelemetryConfig()
    ref = fedpg.run_jit(env, pol, cfg, key, ota=RAYLEIGH, telemetry=tel,
                        agent_mesh=mesh, agent_blocks=1)
    for b in (2, 3):
        theta, hist = fedpg.run_jit(env, pol, cfg, key, ota=RAYLEIGH,
                                    telemetry=tel, agent_mesh=mesh,
                                    agent_blocks=b)
        # the dispersion probe's per-agent max-norm is the one quantity the
        # SPMD partitioner fuses width-dependently (last-mantissa-bit; the
        # mean over the same norms rounds identically) — tolerance there,
        # bitwise everywhere else
        _close(hist.telemetry.dispersion, ref[1].telemetry.dispersion,
               f"sharded agent_blocks={b} dispersion")
        hist = hist._replace(telemetry=hist.telemetry._replace(
            dispersion=ref[1].telemetry.dispersion))
        _bitwise((theta, hist), ref, f"sharded agent_blocks={b} vs 1")


# ---------------------------------------------------------------------------
# absolute-index contracts: heterogeneous fleets + per-agent power budgets
# ---------------------------------------------------------------------------

def test_heterogeneous_env_blocked_absolute_lanes(key):
    # 5 lanes with distinct winds: a block that read lane parameters by
    # in-block position instead of absolute index would swap dynamics
    # between agents — far outside the reassociation tolerance
    henv = make_heterogeneous_env(
        [WindyLandmarkNav(wind=w) for w in (0.0, 0.05, 0.1, 0.15, 0.2)])
    pol = MLPPolicy()
    cfg = fedpg.FedPGConfig(n_agents=5, batch_m=2, horizon=5, n_rounds=3)
    ref = fedpg.run_jit(henv, pol, cfg, key, ota=RAYLEIGH, agent_blocks=1)
    for b in (2, 3):  # b=2 pads the 5-lane fleet with one phantom
        got = fedpg.run_jit(henv, pol, cfg, key, ota=RAYLEIGH,
                            agent_blocks=b)
        _bitwise(got, ref, f"hetero agent_blocks={b} vs 1")
    stacked = fedpg.run_jit(henv, pol, cfg, key, ota=RAYLEIGH)
    np.testing.assert_array_equal(np.asarray(ref[1].gain_mean),
                                  np.asarray(stacked[1].gain_mean))
    _close(ref, stacked, "hetero streamed vs stacked")


def test_heterogeneous_budget_blocked(env_pol, key):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(**SMALL)
    ocfg = OTAConfig(channel=RayleighChannel(), noise_sigma=1e-3,
                     debias=True, power_control=HeterogeneousBudget())
    ref = fedpg.run_jit(env, pol, cfg, key, ota=ocfg, agent_blocks=1)
    for b in (3, 4):
        got = fedpg.run_jit(env, pol, cfg, key, ota=ocfg, agent_blocks=b)
        _bitwise(got, ref, f"hetero-budget agent_blocks={b} vs 1")
    stacked = fedpg.run_jit(env, pol, cfg, key, ota=ocfg)
    np.testing.assert_array_equal(np.asarray(ref[1].gain_mean),
                                  np.asarray(stacked[1].gain_mean))
    _close(ref, stacked, "hetero-budget streamed vs stacked")


@pytest.mark.skipif(N_DEV < 4, reason="needs >= 4 devices "
                    "(REPRO_EMULATED_DEVICES=8)")
def test_heterogeneous_budget_sharded_absolute_index(env_pol, key):
    # FixedGain base + per-agent budgets: the sharded form derives each
    # agent's budget from its ABSOLUTE index (apply_indexed), the stacked
    # form from linspace over the full fleet — any index misalignment in
    # the padded blocked fold shows up here as a wrong per-agent gain
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(n_agents=10, batch_m=2, horizon=5, n_rounds=3)
    ocfg = OTAConfig(channel=FixedGainChannel(gain=1.5), noise_sigma=1e-3,
                     debias=True, power_control=HeterogeneousBudget())
    mesh = make_agent_mesh(4)
    theta_s, hist_s = fedpg.run_jit(env, pol, cfg, key, ota=ocfg,
                                    agent_mesh=mesh, agent_blocks=2)
    theta_v, hist_v = fedpg.run_jit(env, pol, cfg, key, ota=ocfg)
    np.testing.assert_array_equal(np.asarray(hist_s.gain_mean),
                                  np.asarray(hist_v.gain_mean))
    _close(hist_s, hist_v, "hetero-budget sharded vs unsharded")
    _close(theta_s, theta_v, "hetero-budget sharded vs unsharded theta")


# ---------------------------------------------------------------------------
# event-triggered baseline under blocking
# ---------------------------------------------------------------------------

def test_event_triggered_blocked(env_pol, key):
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(**SMALL)
    et = event_triggered.ETConfig(tau=0.05)
    theta_u, hist_u = event_triggered.run_jit(env, pol, cfg, et, key)
    ref = event_triggered.run_jit(env, pol, cfg, et, key, agent_blocks=1)
    for b in (3, 4):
        got = event_triggered.run_jit(env, pol, cfg, et, key, agent_blocks=b)
        _bitwise(got, ref, f"ET agent_blocks={b} vs 1")
    # vs the unblocked loop: trigger decisions (channel uses) must agree
    # exactly; the scalar metrics sit at reassociation tolerance
    np.testing.assert_array_equal(np.asarray(ref[1].uploads),
                                  np.asarray(hist_u.uploads))
    _close(ref[1], hist_u, "ET blocked vs unblocked history")
    _close(ref[0], theta_u, "ET blocked vs unblocked theta")


# ---------------------------------------------------------------------------
# the aggregate-level partition property
# ---------------------------------------------------------------------------

def _agg_grads(seed, n_agents):
    ks = jax.random.split(jax.random.key(seed), 2)
    return {"w": jax.random.normal(ks[0], (n_agents, 3, 4), jnp.float32),
            "b": jax.random.normal(ks[1], (n_agents, 5), jnp.float32)}


@pytest.mark.parametrize("n_agents,b1,b2", [(1, 1, 5), (6, 1, 2), (6, 2, 3),
                                            (7, 3, 7), (9, 4, 100)])
def test_aggregate_partition_grid(n_agents, b1, b2, key):
    g = _agg_grads(17, n_agents)
    for cfg in (None, RAYLEIGH):
        u1, h1 = ota.aggregate(g, cfg, key=key, agent_blocks=b1)
        u2, h2 = ota.aggregate(g, cfg, key=key, agent_blocks=b2)
        _bitwise((u1, h1), (u2, h2),
                 f"aggregate N={n_agents} blocks {b1} vs {b2}")


@given(st.integers(1, 12), st.integers(1, 16), st.integers(1, 16),
       st.integers(0, 2 ** 16))
@settings(max_examples=25, deadline=None)
def test_aggregate_partition_property(n_agents, b1, b2, seed):
    # blocking is a partition of the agent axis: ANY two partitions of the
    # same fleet produce the bitwise-identical update (strict fold)
    g = _agg_grads(seed, n_agents)
    k = jax.random.key(seed + 1)
    for cfg in (None, RAYLEIGH):
        u1, h1 = ota.aggregate(g, cfg, key=k, agent_blocks=b1)
        u2, h2 = ota.aggregate(g, cfg, key=k, agent_blocks=b2)
        _bitwise((u1, h1), (u2, h2),
                 f"aggregate N={n_agents} blocks {b1} vs {b2}")


def test_blocked_layout_is_partition():
    for n in (1, 2, 3, 7, 10, 33):
        for b in (1, 2, 3, 5, 100):
            nb, blk, pad = ota.blocked_layout(n, b)
            assert nb * blk == n + pad
            assert 0 <= pad < blk
            # the >=2-blocks cap: XLA inlines a trip-count-1 scan, which
            # refuses the bitwise block-invariance — never emit one
            assert blk <= max(1, -(-n // 2))
            assert blk <= b
    with pytest.raises(ValueError):
        ota.blocked_layout(4, 0)


def test_cache_key_includes_program_shaping_args(env_pol, compile_counter):
    """Regression for the stale-cache bug: ``telemetry`` / ``ota_backend`` /
    ``agent_blocks`` each shape the compiled program, so flipping any of
    them between two otherwise-identical calls must compile a distinct
    program (and return that program's output) — never silently reuse the
    previous one.  Pre-fix, the caches were keyed on (env, policy, cfg,
    ota, n_runs) only and every flip below returned the stale program."""
    env, pol = env_pol
    cfg = fedpg.FedPGConfig(n_agents=3, batch_m=2, horizon=4, n_rounds=3)
    keys = [jax.random.key(i) for i in range(9)]  # warm eager key helpers
    fedpg.clear_compilation_cache()

    _, base = fedpg.run_jit(env, pol, cfg, keys[0], ota=RAYLEIGH)
    assert base.telemetry is None

    flips = {
        "telemetry": dict(telemetry=TelemetryConfig()),
        "backend": dict(ota_backend="pallas"),
        "agent_blocks": dict(agent_blocks=2),
    }
    for name, kw in flips.items():
        with compile_counter() as c:
            _, hist = fedpg.run_jit(env, pol, cfg, keys[1], ota=RAYLEIGH,
                                    **kw)
        assert c.count >= 1, \
            f"run_jit reused a stale program across a {name} flip"
        assert bool(jnp.all(jnp.isfinite(hist.rewards)))

    # the flips produced the flipped program's OUTPUT, not just a recompile
    _, tele = fedpg.run_jit(env, pol, cfg, keys[2], ota=RAYLEIGH,
                            telemetry=TelemetryConfig())
    assert tele.telemetry is not None
    assert bool(jnp.all(jnp.isfinite(tele.telemetry.grad_norm_pre)))

    # each keyed variant is itself cached: repeat call compiles nothing
    with compile_counter() as c:
        fedpg.run_jit(env, pol, cfg, keys[3], ota=RAYLEIGH, agent_blocks=2)
    assert c.count == 0, "agent_blocks=2 variant was not cached"

    # same contract on the monte_carlo cache
    fedpg.clear_compilation_cache()
    hist = fedpg.monte_carlo(env, pol, cfg, keys[4], 2, ota=RAYLEIGH)
    assert hist.telemetry is None and hist.rewards.shape == (2, 3)
    with compile_counter() as c:
        tele_mc = fedpg.monte_carlo(env, pol, cfg, keys[5], 2, ota=RAYLEIGH,
                                    telemetry=TelemetryConfig())
    assert c.count >= 1, \
        "monte_carlo reused a stale program across a telemetry flip"
    assert tele_mc.telemetry is not None
    with compile_counter() as c:
        blocked = fedpg.monte_carlo(env, pol, cfg, keys[6], 2, ota=RAYLEIGH,
                                    agent_blocks=2)
    assert c.count >= 1, \
        "monte_carlo reused a stale program across an agent_blocks flip"
    assert blocked.rewards.shape == (2, 3)
    with compile_counter() as c:
        fedpg.monte_carlo(env, pol, cfg, keys[7], 2, ota=RAYLEIGH,
                          agent_blocks=2)
    assert c.count == 0, "blocked monte_carlo variant was not cached"
