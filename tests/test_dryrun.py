"""Dry-run machinery: tiny-mesh subprocess lowering (multi-device semantics
need a fresh process with the host-device flag), calibration consistency,
and cache spec structure."""
import json
import os
import subprocess
import sys

import jax
import pytest

from repro.configs import get_config
from repro.configs.shapes import get_shape
from repro.models import model as model_lib
from repro.train import server

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(args, timeout=480):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO,
    )


@pytest.mark.slow
@pytest.mark.parametrize(
    "arch,shape",
    [
        ("llama3.2-3b", "decode_32k"),
        ("granite-moe-1b-a400m", "train_4k"),
        ("mamba2-130m", "long_500k"),
    ],
)
def test_tiny_mesh_dryrun_subprocess(arch, shape, tmp_path):
    out = str(tmp_path / "dry")
    r = _run_dryrun(
        ["--arch", arch, "--shape", shape, "--tiny", "2", "--no-calibrate",
         "--out", out]
    )
    assert r.returncode == 0, r.stderr[-3000:]
    files = os.listdir(out)
    assert len(files) == 1
    rec = json.load(open(os.path.join(out, files[0])))
    assert rec["roofline"]["hlo_flops"] > 0
    assert "CompiledMemoryStats" in rec["memory_analysis"]


def test_cache_specs_structure_matches_cache():
    """cache_specs must mirror init_cache's pytree exactly for every family
    (a structure mismatch would break the decode in_shardings)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("llama3.2-3b", "mamba2-130m", "zamba2-7b",
                 "llama-3.2-vision-11b", "seamless-m4t-large-v2",
                 "mixtral-8x22b"):
        cfg = get_config(arch)
        shape = get_shape("decode_32k")
        m = model_lib.build(cfg)
        cache = server.abstract_cache_for_shape(m, shape)
        specs = server.cache_specs(cfg, shape, mesh)
        t1 = jax.tree.structure(cache)
        t2 = jax.tree.structure(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )
        assert t1 == t2, (arch, t1, t2)


def test_default_microbatch_heuristic():
    from repro.launch.dryrun import _default_microbatch
    big = get_config("deepseek-67b")
    small = get_config("granite-moe-1b-a400m")
    train = get_shape("train_4k")
    assert _default_microbatch(big, train, 16) == 16   # 1 seq/agent/micro
    assert _default_microbatch(small, train, 16) == 1


def test_input_specs_no_allocation():
    """abstract_inputs returns ShapeDtypeStructs only (zero device memory)."""
    for arch in ("deepseek-67b", "seamless-m4t-large-v2"):
        cfg = get_config(arch)
        for sh in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            specs = model_lib.abstract_inputs(cfg, get_shape(sh))
            for v in specs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)
